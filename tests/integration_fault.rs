//! End-to-end fault-injection tests (ISSUE 6): checkpoint/recover for
//! iterative jobs under a deterministic [`FaultPlan`], proved by
//! bit-identity against uninterrupted runs.
//!
//! The acceptance pin: a components session killed at ANY iteration and
//! recovered onto a RANDOM width in 1..=16 (checkpoint-every-1) must
//! produce labels bit-identical to the uninterrupted run. PageRank gets
//! the same treatment — bit-identical at the same width (the snapshot
//! carries the normalizer aggregate), ≤ 1e-12 across widths (float
//! re-association only). `BLAZE_FAULT_SEED` pins the randomized
//! schedules for the CI fault-matrix leg.

use blaze_rs::apps::{components, pagerank};
use blaze_rs::cluster::{ClusterConfig, ElasticCluster, ElasticEvent, FaultPlan, WavePhase};
use blaze_rs::core::{IterativeJob, WaveKilled};
use blaze_rs::store::CheckpointStore;
use blaze_rs::util::rng::Rng;

fn local_elastic(ranks: usize) -> ElasticCluster {
    ElasticCluster::new(ClusterConfig::builder().ranks(ranks).build())
}

fn phase_of(i: u64) -> WavePhase {
    match i {
        0 => WavePhase::Contribute,
        1 => WavePhase::Flush,
        _ => WavePhase::Update,
    }
}

fn replaced(elastic: &ElasticCluster) -> bool {
    elastic.events().iter().any(|e| matches!(e, ElasticEvent::Replaced { .. }))
}

#[test]
fn components_killed_at_any_iteration_recover_bit_identical_at_random_widths() {
    // 6 chains of 9 vertices: known components, converges in ~10 waves.
    let g = components::chain_graph(6, 9);
    let baseline = components::run_dist(&mut local_elastic(4), &g, 20, &[]).unwrap();
    assert!(baseline.converged);

    let seed = FaultPlan::env_seed().unwrap_or(0xB1A2);
    for trial in 0..8u64 {
        let mut rng = Rng::with_stream(seed, trial);
        let kill_iter = rng.below(baseline.iterations as u64) as usize;
        let phase = phase_of(rng.below(3));
        let victim = rng.below(4) as usize;
        let width2 = 1 + rng.below(16) as usize;

        let mut elastic = local_elastic(4);
        elastic.set_fault_plan(FaultPlan::new().with_kill(kill_iter, phase, victim));
        let got =
            components::run_dist_faulty(&mut elastic, &g, 20, 1, width2 as i64 - 4).unwrap();
        assert!(got.converged, "trial {trial}: must still settle");
        assert_eq!(
            got.labels, baseline.labels,
            "trial {trial}: kill at it {kill_iter} ({phase:?}, rank {victim}), \
             recovered onto width {width2} — integer min must be bit-identical"
        );
        // Every kill scheduled inside the session's wave range fires.
        assert!(replaced(&elastic), "trial {trial}: kill at {kill_iter} should have fired");
        assert_eq!(elastic.ranks(), width2, "trial {trial}: replacement width");
        if kill_iter > 0 {
            // Checkpoint-every-1 ⇒ the snapshot is at the kill iteration.
            assert_eq!(got.recoveries.len(), 1, "trial {trial}");
            let r = &got.recoveries[0];
            assert_eq!((r.iteration, r.from_ranks, r.to_ranks), (kill_iter, 4, width2));
            if width2 == 4 {
                assert_eq!(r.epoch, 0, "same-width recovery must not bump the epoch");
            } else {
                assert_eq!(r.epoch, 1, "cross-width recovery is an elastic resize");
            }
            assert!(r.items > 0 && r.bytes > 0 && r.modeled_ms > 0.0);
        }
        // Checkpoint-every-1 wrote one snapshot per completed wave.
        assert!(!got.checkpoints.is_empty());
    }
}

#[test]
fn components_recovery_survives_every_phase_point() {
    let g = components::chain_graph(4, 8);
    let baseline = components::run_dist(&mut local_elastic(3), &g, 20, &[]).unwrap();
    for phase in [WavePhase::Contribute, WavePhase::Flush, WavePhase::Update] {
        let mut elastic = local_elastic(3);
        elastic.set_fault_plan(FaultPlan::new().with_kill(2, phase, 1));
        let got = components::run_dist_faulty(&mut elastic, &g, 20, 1, 0).unwrap();
        assert_eq!(got.labels, baseline.labels, "{phase:?}");
        assert_eq!(got.recoveries.len(), 1, "{phase:?}");
        assert_eq!(got.recoveries[0].iteration, 2, "{phase:?}");
        assert_eq!(got.iterations, baseline.iterations, "{phase:?}");
    }
}

#[test]
fn pagerank_same_width_recovery_is_bit_identical() {
    let g = pagerank::Graph::random(200, 4, 3);
    let baseline = pagerank::run_dist(&mut local_elastic(4), &g, 10, 0.85, &[]).unwrap();

    let mut elastic = local_elastic(4);
    elastic.set_fault_plan(FaultPlan::new().with_kill(5, WavePhase::Flush, 2));
    let got = pagerank::run_dist_faulty(&mut elastic, &g, 10, 0.85, 1, 0).unwrap();
    assert_eq!(got.iterations, 10);
    assert_eq!(got.recoveries.len(), 1);
    assert_eq!(got.recoveries[0].iteration, 5);
    for (v, (a, b)) in got.ranks.iter().zip(&baseline.ranks).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "vertex {v}: same-width recovery must be bit-identical ({a} vs {b})"
        );
    }
    // Checkpoints and the recovery read are real (modeled) session time.
    assert!(got.stats.modeled_ms > baseline.stats.modeled_ms);
    assert!(!got.checkpoints.is_empty());
}

#[test]
fn pagerank_cross_width_recovery_stays_within_float_tolerance() {
    let g = pagerank::Graph::random(200, 4, 3);
    let baseline = pagerank::run_dist(&mut local_elastic(4), &g, 10, 0.85, &[]).unwrap();

    let seed = FaultPlan::env_seed().unwrap_or(0x5047);
    for trial in 0..4u64 {
        let mut rng = Rng::with_stream(seed, trial);
        let kill_iter = rng.below(10) as usize;
        let phase = phase_of(rng.below(3));
        let victim = rng.below(4) as usize;
        let width2 = 1 + rng.below(16) as usize;

        let mut elastic = local_elastic(4);
        elastic.set_fault_plan(FaultPlan::new().with_kill(kill_iter, phase, victim));
        let got = pagerank::run_dist_faulty(&mut elastic, &g, 10, 0.85, 1, width2 as i64 - 4)
            .unwrap();
        assert!(replaced(&elastic), "trial {trial}");
        assert_eq!(elastic.ranks(), width2, "trial {trial}");
        for (v, (a, b)) in got.ranks.iter().zip(&baseline.ranks).enumerate() {
            assert!(
                (a - b).abs() < 1e-12,
                "trial {trial}, vertex {v}: {a} vs {b} (kill at {kill_iter}, width {width2})"
            );
        }
        let total: f64 = got.ranks.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "trial {trial}: still a distribution");
    }
}

#[test]
fn seeded_schedule_recovers_components_under_the_env_seed() {
    // The CI fault-matrix leg pins BLAZE_FAULT_SEED; this test routes it
    // through FaultPlan::seeded so the leg exercises a reproducible,
    // seed-chosen kill point.
    let g = components::chain_graph(5, 7);
    let baseline = components::run_dist(&mut local_elastic(4), &g, 20, &[]).unwrap();
    let seed = FaultPlan::env_seed().unwrap_or(1332);
    let plan = FaultPlan::seeded(seed, baseline.iterations, 4);
    assert_eq!(plan.kills().len(), 1);

    let mut elastic = local_elastic(4);
    elastic.set_fault_plan(plan);
    let got = components::run_dist_faulty(&mut elastic, &g, 20, 1, 0).unwrap();
    assert_eq!(got.labels, baseline.labels);
    assert!(replaced(&elastic), "a seeded kill inside the wave range always fires");
}

#[test]
fn slowdown_triggers_speculative_reexecution_without_changing_results() {
    let n = 20_000u32;
    let run = |plan: Option<FaultPlan>| {
        let mut elastic = local_elastic(4);
        if let Some(p) = plan {
            elastic.set_fault_plan(p);
        }
        let mut job: IterativeJob<u32, u64> =
            IterativeJob::load(&elastic, 9, (0..n).map(|k| (k, k as u64)));
        for _ in 0..2 {
            job.step(
                &mut elastic,
                |k: &u32, s: &u64, emit: &mut dyn FnMut(u32, u64)| emit((k + 1) % n, *s),
                |acc: &mut u64, v: u64| *acc = acc.wrapping_add(v),
                |_k, s: &mut u64, d: Option<u64>| *s = s.wrapping_add(d.unwrap_or(0)),
                |_k, s: &u64| *s % 4096,
            )
            .unwrap();
        }
        let specs = job.speculations().to_vec();
        let stats = job.per_iteration().to_vec();
        let mut states = job.into_states();
        states.sort_unstable();
        (states, specs, stats)
    };

    let (plain_states, plain_specs, _) = run(None);
    assert!(plain_specs.is_empty(), "no plan, no speculation");

    // Rank 1 computes 1000x slower (virtual clock): a deterministic
    // straggler every wave.
    let (slow_states, specs, stats) = run(Some(FaultPlan::new().with_slowdown(1, 1000.0)));
    assert_eq!(slow_states, plain_states, "slowdowns must not change results");
    assert!(!specs.is_empty(), "a 1000x straggler must trip the 2x-median detector");
    for sp in &specs {
        assert_eq!(sp.straggler, 1);
        assert_ne!(sp.backup, 1);
        assert!(sp.backup_won, "backup path must beat waiting out a 1000x straggler");
        assert!(sp.backup_ms < sp.straggler_ms);
        // FaultTracker bookkeeping: the straggler's shard task shows a
        // failed first attempt and a successful re-claim by the backup.
        assert!(sp.attempts.iter().any(|a| a.task == 1 && !a.succeeded));
        assert!(sp
            .attempts
            .iter()
            .any(|a| a.task == 1 && a.succeeded && a.rank.0 == sp.backup && a.attempt == 2));
        // The wave's modeled clock took the cheaper (backup) path.
        let wave = &stats[sp.iteration];
        assert!(wave.modeled_ms < sp.straggler_ms, "{} vs {}", wave.modeled_ms, sp.straggler_ms);
    }
}

#[test]
fn checkpoint_cadence_writes_every_k() {
    let mut elastic = local_elastic(3);
    let mut job: IterativeJob<u32, u64> =
        IterativeJob::load(&elastic, 11, (0..500u32).map(|k| (k, 1u64)));
    let store: CheckpointStore<u32, u64> = CheckpointStore::new();
    job.checkpoint_every(store.clone(), 2);
    for _ in 0..5 {
        job.step(
            &mut elastic,
            |k: &u32, s: &u64, emit: &mut dyn FnMut(u32, u64)| emit((k + 7) % 500, *s),
            |acc: &mut u64, v: u64| *acc += v,
            |_k, s: &mut u64, d: Option<u64>| *s += d.unwrap_or(0),
            |_k, s: &u64| *s,
        )
        .unwrap();
    }
    // Waves 2 and 4 snapshot; wave 5 is off-cadence.
    assert_eq!(store.checkpoints_written(), 2);
    assert_eq!(job.checkpoints().len(), 2);
    assert_eq!(store.latest_iteration(), Some(4));
    assert!(store.latest_aggregate::<u64>().unwrap().is_some());
    // An explicit snapshot is always allowed.
    job.checkpoint_now(&store).unwrap();
    assert_eq!(store.checkpoints_written(), 3);
    assert_eq!(store.latest_iteration(), Some(5));
    assert!(store.bytes_written() > 0);
}

#[test]
fn wave_killed_error_downcasts_and_session_recovers() {
    let mut elastic = local_elastic(4);
    elastic.set_fault_plan(FaultPlan::new().with_kill(1, WavePhase::Update, 0));
    let store: CheckpointStore<u32, u64> = CheckpointStore::new();
    let mut job: IterativeJob<u32, u64> =
        IterativeJob::load(&elastic, 13, (0..300u32).map(|k| (k, k as u64)));
    job.checkpoint_every(store.clone(), 1);

    let step = |job: &mut IterativeJob<u32, u64>, elastic: &mut ElasticCluster| {
        job.step(
            elastic,
            |k: &u32, s: &u64, emit: &mut dyn FnMut(u32, u64)| emit((k + 1) % 300, *s),
            |acc: &mut u64, v: u64| *acc += v,
            |_k, s: &mut u64, d: Option<u64>| *s += d.unwrap_or(0),
            |_k, s: &u64| *s,
        )
    };
    step(&mut job, &mut elastic).unwrap();
    let err = step(&mut job, &mut elastic).unwrap_err();
    let killed = err.downcast_ref::<WaveKilled>().expect("typed kill error");
    assert_eq!(
        *killed,
        WaveKilled { rank: 0, iteration: 1, phase: WavePhase::Update }
    );
    assert!(format!("{killed}").contains("rank 0 killed at iteration 1"));

    elastic.kill_and_replace(0).unwrap();
    let recovered: IterativeJob<u32, u64> =
        IterativeJob::recover_from(&elastic, &store).unwrap().expect("snapshot present");
    assert_eq!(recovered.steps_run(), 1);
    assert_eq!(recovered.len_global(), 300);
    assert_eq!(recovered.recovery().unwrap().iteration, 1);
    // The replayed kill iteration does not re-fire (consumed), so the
    // session completes.
    let mut recovered = recovered;
    step(&mut recovered, &mut elastic).unwrap();
    assert_eq!(recovered.steps_run(), 2);
}

#[test]
fn recover_from_empty_store_is_none() {
    let elastic = local_elastic(2);
    let store: CheckpointStore<u32, u64> = CheckpointStore::new();
    assert!(IterativeJob::<u32, u64>::recover_from(&elastic, &store).unwrap().is_none());
}
