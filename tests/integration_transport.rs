//! The cross-transport byte-identity suite (ISSUE 7 acceptance): the
//! in-memory mailbox transport and the real-TCP transport (spawned
//! `blaze worker` rank processes, full socket mesh) must be
//! **indistinguishable** above the [`blaze_rs::mpi::Transport`] seam —
//! every collective, at randomized widths 1..=16 with skewed payloads
//! and subset-width jobs on warm pools, must produce byte-identical
//! results, byte-identical virtual clocks, and identical traffic
//! deltas under every collective algorithm. Plus the deployment-shaped
//! checks: a classic-mode wordcount over TCP equals the mailbox run
//! pair for pair, and dropping a TCP pool leaves no orphan worker
//! processes behind.
//!
//! The TCP pools here are real: each one spawns 16 `blaze worker`
//! processes (via `CARGO_BIN_EXE_blaze`) wired into a full TCP mesh, so
//! every property case below pushes its payloads through actual kernel
//! sockets. Pools are shared across tests through a `OnceLock` to bound
//! the process count at three fleets.

use std::path::Path;
use std::sync::OnceLock;

use blaze_rs::cluster::ClusterConfig;
use blaze_rs::core::{MapReduceJob, ReductionMode};
use blaze_rs::mpi::{CollectiveAlgo, Rank, RankPool, TransportKind};
use blaze_rs::util::prop::{for_all, vec_of};
use blaze_rs::util::rng::Rng;
use blaze_rs::util::testpool;

/// 4 nodes x 4 slots — same shape as the collective-equivalence suite:
/// real trees, multi-rank nodes for the hierarchical leader paths.
const POOL_RANKS: usize = 16;

fn worker_bin() -> &'static str {
    env!("CARGO_BIN_EXE_blaze")
}

fn pool(algo: CollectiveAlgo, transport: TransportKind) -> RankPool {
    testpool::fleet(4, 4, algo, transport, Some(Path::new(worker_bin())))
}

/// One warm (mailbox, tcp) pool pair per collective algorithm, shared
/// by every test in this file so the suite runs three 16-worker fleets
/// total, not one per property case. The statics are never dropped;
/// workers exit on driver-socket EOF when the test process does.
fn pools() -> &'static [(CollectiveAlgo, RankPool, RankPool)] {
    static POOLS: OnceLock<Vec<(CollectiveAlgo, RankPool, RankPool)>> = OnceLock::new();
    POOLS.get_or_init(|| {
        CollectiveAlgo::ALL
            .iter()
            .map(|a| (*a, pool(*a, TransportKind::Mailbox), pool(*a, TransportKind::Tcp)))
            .collect()
    })
}

/// A skewed payload: log-uniform length up to `max` random bytes.
fn payload(r: &mut Rng, max: usize) -> Vec<u8> {
    vec_of(r, max, |r| r.next_u64() as u8)
}

#[test]
fn prop_every_collective_byte_identical_across_transports() {
    // One SPMD program exercising every collective — bcast, gather,
    // allgather, allreduce (a non-commutative fold to pin rank order),
    // alltoallv, exscan, barrier — at a random width per case on the
    // warm pools. For each algorithm the TCP run must match the mailbox
    // run on results, per-rank virtual clocks (frames carry sender
    // clocks bit-exactly), and the job's traffic delta.
    let pools = pools();
    for_all(
        "collectives over tcp == over mailboxes, results + clocks + traffic",
        |r| {
            let width = 1 + r.below(POOL_RANKS as u64) as usize;
            let root = r.below(width as u64) as usize;
            let per_rank: Vec<Vec<u8>> = (0..width).map(|_| payload(r, 700)).collect();
            let matrix: Vec<Vec<Vec<u8>>> =
                (0..width).map(|_| (0..width).map(|_| payload(r, 300)).collect()).collect();
            (width, root, per_rank, matrix)
        },
        |(width, root, per_rank, matrix)| {
            let job = |c: &blaze_rs::mpi::Communicator| {
                let me = c.rank().0;
                let v = if me == *root { per_rank[*root].clone() } else { Vec::new() };
                let b = c.bcast(Rank(*root), v).unwrap();
                let g = c.gather(Rank(*root), per_rank[me].clone()).unwrap();
                let ag = c.allgather(per_rank[me].clone()).unwrap();
                let cat = c.allreduce(format!("r{me};"), |a, b| a + &b).unwrap();
                let a2a = c.alltoallv(matrix[me].clone()).unwrap();
                let ex = c.exscan_sum(me as u64 + 1).unwrap();
                c.barrier().unwrap();
                (b, g, ag, cat, a2a, ex)
            };
            pools.iter().all(|(algo, mailbox, tcp)| {
                let m = mailbox.run_job(*width, job);
                let t = tcp.run_job(*width, job);
                assert_eq!(m.results, t.results, "{algo}: results diverged across transports");
                assert_eq!(m.clocks, t.clocks, "{algo}: virtual clocks diverged");
                assert_eq!(m.traffic, t.traffic, "{algo}: traffic delta diverged");
                // Sanity against ground truth, not just cross-equality.
                m.results.iter().all(|(b, _, ag, _, _, _)| {
                    b == &per_rank[*root] && ag == per_rank
                }) && m.results.iter().enumerate().all(|(dst, (_, _, _, _, a2a, _))| {
                    a2a.iter().enumerate().all(|(src, buf)| buf == &matrix[src][dst])
                })
            })
        },
    );
}

#[test]
fn prop_subset_width_sequences_stay_aligned_on_warm_tcp_pools() {
    // Multi-round mixed sequences at varying widths, repeatedly
    // submitted to the same warm fleets: any stale frame leaking across
    // pooled jobs through the worker mesh (the epoch filter's job), or
    // any tag misalignment, diverges or deadlocks here.
    let pools = pools();
    for_all(
        "mixed sequences: tcp == mailbox at any width, round count",
        |r| {
            let width = 1 + r.below(POOL_RANKS as u64) as usize;
            let rounds = 1 + r.below(4);
            (width, rounds, payload(r, 200))
        },
        |(width, rounds, data)| {
            let job = |c: &blaze_rs::mpi::Communicator| {
                let mut acc = 0u64;
                let mut blob = Vec::new();
                for round in 0..*rounds {
                    acc = acc
                        .wrapping_add(c.allreduce_sum_u64(c.rank().0 as u64 + round).unwrap());
                    let v = if c.is_root() { data.clone() } else { Vec::new() };
                    blob = c.bcast(Rank::ROOT, v).unwrap();
                    c.barrier().unwrap();
                }
                (acc, blob)
            };
            pools.iter().all(|(_, mailbox, tcp)| {
                let m = mailbox.run_job(*width, job);
                let t = tcp.run_job(*width, job);
                m.results == t.results && m.clocks == t.clocks && m.traffic == t.traffic
            })
        },
    );
}

#[test]
fn wordcount_classic_over_tcp_matches_mailbox_pair_for_pair() {
    // The end-to-end pin: a classic-mode (full shuffle) wordcount on a
    // TCP-backed pool must equal the mailbox run — same counts, same
    // modeled shuffle bytes and message counts — and both must equal
    // the serial truth. Only host_wall_ms (real time) may differ.
    let lines: Vec<String> =
        (0..300).map(|i| format!("w{} w{} w{} shared", i % 23, i % 7, i % 3)).collect();
    let truth = blaze_rs::apps::wordcount::count_serial(&lines);
    let wc_map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
        for w in line.split_whitespace() {
            emit(w.to_string(), 1);
        }
    };

    let mut runs = Vec::new();
    for transport in TransportKind::ALL {
        let cluster = ClusterConfig::builder()
            .nodes(2)
            .slots_per_node(2)
            .seed(7)
            .transport(transport)
            .worker_binary(worker_bin())
            .build();
        let pool = RankPool::from_config(&cluster);
        assert_eq!(pool.transport_kind(), transport);
        let out = MapReduceJob::new(&cluster, &lines)
            .with_mode(ReductionMode::Classic)
            .with_pool(&pool)
            .run_monoid(wc_map, |a: u64, b: u64| a + b)
            .unwrap();
        assert_eq!(out.result, truth, "{transport} diverged from serial truth");
        runs.push((transport, out));
    }

    let (_, mailbox) = &runs[0];
    let (_, tcp) = &runs[1];
    assert_eq!(mailbox.result, tcp.result, "classic wordcount differs across transports");
    let modeled = |s: &blaze_rs::core::JobStats| {
        (s.shuffle_bytes, s.messages, s.remote_messages, s.remote_bytes, s.spilled_bytes)
    };
    assert_eq!(
        modeled(&mailbox.stats),
        modeled(&tcp.stats),
        "modeled traffic differs across transports"
    );
}

#[test]
fn tcp_pool_runs_real_worker_processes_and_reaps_them_on_drop() {
    // The clean-shutdown pin: a TCP pool is backed by real spawned
    // processes (distinct PIDs, all alive while the pool runs) and
    // dropping the pool leaves no orphans — every worker exits on
    // driver-socket EOF and is reaped by the fleet.
    let alive = |pid: u32| unsafe { libc::kill(pid as i32, 0) } == 0;

    let pool = pool(CollectiveAlgo::Tree, TransportKind::Tcp);
    let pids: Vec<u32> = pool.worker_pids().to_vec();
    assert_eq!(pids.len(), POOL_RANKS, "one worker process per rank");
    let me = std::process::id();
    for &pid in &pids {
        assert_ne!(pid, me, "workers must be separate processes");
        assert!(alive(pid), "worker {pid} should be alive while the pool runs");
    }
    // The fleet is functional, not just spawned.
    assert_eq!(pool.run(|c| c.allreduce_sum_u64(1).unwrap()), vec![POOL_RANKS as u64; POOL_RANKS]);

    drop(pool);
    for &pid in &pids {
        assert!(!alive(pid), "worker {pid} orphaned after pool drop");
    }

    // Mailbox pools spawn nothing.
    assert!(RankPool::local(4).worker_pids().is_empty());
}

#[test]
fn point_to_point_and_pending_buffering_work_over_tcp() {
    // Below the collectives: raw send/recv with out-of-order tags and
    // recv_any, pushed through the worker mesh.
    let pool = &pools()[0].2; // star, tcp
    let got = pool.run_on(3, |c| {
        use blaze_rs::mpi::Tag;
        let me = c.rank().0;
        let next = Rank((me + 1) % 3);
        let prev = Rank((me + 2) % 3);
        // Two tags sent in one order, received in the other.
        c.send(next, Tag::user(1), vec![me as u8; 5]).unwrap();
        c.send(next, Tag::user(2), vec![me as u8; 9]).unwrap();
        let b = c.recv(prev, Tag::user(2)).unwrap();
        let a = c.recv(prev, Tag::user(1)).unwrap();
        (a, b)
    });
    for (me, (a, b)) in got.iter().enumerate() {
        let prev = (me + 2) % 3;
        assert_eq!(a, &vec![prev as u8; 5]);
        assert_eq!(b, &vec![prev as u8; 9]);
    }
}
