//! PJRT runtime integration: Rust loads the AOT Pallas kernels and the
//! results match the native implementations exactly.
//!
//! These tests need `make artifacts`; they skip (with a loud message)
//! when the artifact directory is absent so `cargo test` stays runnable
//! on a fresh checkout.

use blaze_rs::apps::{kmeans, linreg, pi, wordcount};
use blaze_rs::cluster::ClusterConfig;
use blaze_rs::core::ReductionMode;
use blaze_rs::runtime::{ArtifactManifest, ComputeService, Runtime, TensorArg};

fn artifacts_ready() -> bool {
    if !cfg!(feature = "xla") {
        eprintln!("SKIP: built without the `xla` feature (PJRT runtime is stubbed)");
        return false;
    }
    let dir = ArtifactManifest::default_dir();
    if ArtifactManifest::load(&dir).is_ok() {
        true
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        false
    }
}

#[test]
fn manifest_lists_all_kernels() {
    if !artifacts_ready() {
        return;
    }
    let m = ArtifactManifest::load(ArtifactManifest::default_dir()).unwrap();
    for name in ["kmeans_step_d2", "kmeans_step_d8", "kmeans_step_d32", "wordcount_segsum", "pi_count", "linreg_d8"] {
        assert!(m.get(name).is_ok(), "missing artifact {name}");
    }
}

#[test]
fn runtime_rejects_bad_shapes_before_pjrt() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::from_default_dir().unwrap();
    let err = rt
        .run("pi_count", &[TensorArg::f32(vec![0.0; 10], &[5, 2])])
        .unwrap_err();
    assert!(format!("{err:#}").contains("shape mismatch"), "{err:#}");
    let err = rt
        .run("pi_count", &[TensorArg::i32(vec![0; 8192 * 2], &[8192, 2])])
        .unwrap_err();
    assert!(format!("{err:#}").contains("dtype mismatch"), "{err:#}");
}

#[test]
fn pi_kernel_counts_exactly() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::from_default_dir().unwrap();
    // Deterministic pattern: first 1000 points inside, rest outside.
    let mut xy = Vec::with_capacity(8192 * 2);
    for i in 0..8192 {
        if i < 1000 {
            xy.extend_from_slice(&[0.1, 0.1]);
        } else {
            xy.extend_from_slice(&[2.0, 2.0]);
        }
    }
    let out = rt.run("pi_count", &[TensorArg::f32(xy, &[8192, 2])]).unwrap();
    assert_eq!(out[0].as_f32().unwrap(), &[1000.0]);
}

#[test]
fn segsum_kernel_matches_scalar_histogram() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::from_default_dir().unwrap();
    let mut keys = vec![0i32; 8192];
    let mut vals = vec![0f32; 8192];
    let mut want = vec![0f32; 1024];
    for i in 0..8192 {
        let k = ((i * 37) % 1024) as i32;
        keys[i] = k;
        vals[i] = (i % 5) as f32;
        want[k as usize] += (i % 5) as f32;
    }
    let out = rt
        .run(
            "wordcount_segsum",
            &[TensorArg::i32(keys, &[8192]), TensorArg::f32(vals, &[8192])],
        )
        .unwrap();
    assert_eq!(out[0].as_f32().unwrap(), want.as_slice());
}

#[test]
fn compute_service_is_shareable_across_threads() {
    if !artifacts_ready() {
        return;
    }
    let service = ComputeService::start_default().unwrap();
    let handle = service.handle();
    handle.warmup("pi_count").unwrap();
    std::thread::scope(|s| {
        for t in 0..4 {
            let h = handle.clone();
            s.spawn(move || {
                let xy = vec![0.1f32; 8192 * 2];
                let out = h.run("pi_count", vec![TensorArg::f32(xy, &[8192, 2])]).unwrap();
                assert_eq!(out[0].as_f32().unwrap(), &[8192.0], "thread {t}");
            });
        }
    });
}

#[test]
fn unknown_kernel_is_clean_error() {
    if !artifacts_ready() {
        return;
    }
    let service = ComputeService::start_default().unwrap();
    let err = service.handle().run("not_a_kernel", vec![]).unwrap_err();
    assert!(format!("{err:#}").contains("not in manifest"), "{err:#}");
}

#[test]
fn kmeans_kernel_equals_native_end_to_end() {
    if !artifacts_ready() {
        return;
    }
    let service = ComputeService::start_default().unwrap();
    let handle = service.handle();
    let cluster = ClusterConfig::builder().ranks(2).seed(5).build();
    for d in kmeans::KERNEL_DIMS {
        // 5000 points: exercises padding (not a multiple of 4096).
        let pts = kmeans::generate_points(5_000, d, kmeans::KERNEL_K, 5);
        let native =
            kmeans::run(&cluster, &pts, kmeans::KERNEL_K, 4, kmeans::ComputePath::Native, None)
                .unwrap();
        let kernel = kmeans::run(
            &cluster,
            &pts,
            kmeans::KERNEL_K,
            4,
            kmeans::ComputePath::Kernel,
            Some(&handle),
        )
        .unwrap();
        for (a, b) in native.centroids.iter().zip(&kernel.centroids) {
            assert!((a - b).abs() < 1e-3, "d={d}: {a} vs {b}");
        }
        assert!(
            (native.inertia - kernel.inertia).abs() / native.inertia.max(1e-9) < 1e-3,
            "d={d}: inertia {} vs {}",
            native.inertia,
            kernel.inertia
        );
    }
}

#[test]
fn wordcount_kernel_equals_framework() {
    if !artifacts_ready() {
        return;
    }
    let service = ComputeService::start_default().unwrap();
    let handle = service.handle();
    let cluster = ClusterConfig::builder().ranks(3).seed(6).build();
    let corpus = wordcount::generate_corpus(3_000, 7, wordcount::SEGSUM_KEYS, 6);
    let framework = wordcount::run(&cluster, &corpus, ReductionMode::Delayed).unwrap();
    let kernel = wordcount::run_segsum_kernel(&cluster, &corpus, &handle).unwrap();
    assert_eq!(framework.result, kernel.result);
}

#[test]
fn pi_kernel_path_matches_batched_estimate_closely() {
    if !artifacts_ready() {
        return;
    }
    let service = ComputeService::start_default().unwrap();
    let handle = service.handle();
    let cluster = ClusterConfig::builder().ranks(2).build();
    let chunks = pi::make_chunks(200_000, 8, 7);
    let kernel = pi::run_kernel(&cluster, &chunks, &handle).unwrap();
    assert!((kernel.result - std::f64::consts::PI).abs() < 0.02, "pi {}", kernel.result);
}

#[test]
fn linreg_kernel_matches_native_gradient_descent() {
    if !artifacts_ready() {
        return;
    }
    let service = ComputeService::start_default().unwrap();
    let handle = service.handle();
    let cluster = ClusterConfig::builder().ranks(2).build();
    let data = linreg::generate(6_000, linreg::KERNEL_D, 0.02, 8);
    let native = linreg::run(&cluster, &data, 60, 0.4, linreg::ComputePath::Native, None).unwrap();
    let kernel =
        linreg::run(&cluster, &data, 60, 0.4, linreg::ComputePath::Kernel, Some(&handle)).unwrap();
    for (a, b) in native.w.iter().zip(&kernel.w) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
    assert!((native.mse - kernel.mse).abs() < 1e-4);
}
