//! End-to-end tests of the `store` subsystem through the engine: the
//! ISSUE 3 acceptance criteria. A delayed-mode wordcount whose staged
//! pairs dwarf a 64 KiB spill threshold must (a) complete, (b) produce
//! output byte-identical to the unlimited-budget run, and (c) keep the
//! job's `PeakTracker` high-water mark within the budget plus a
//! constant per-run overhead — the external-merge-sort memory contract.

use blaze_rs::apps::wordcount;
use blaze_rs::cluster::ClusterConfig;
use blaze_rs::core::ReductionMode;
use blaze_rs::store::block_cap;

const BUDGET: u64 = 64 * 1024;
const RANKS: usize = 2;

fn cluster_with_budget(budget: u64) -> ClusterConfig {
    ClusterConfig::builder().ranks(RANKS).seed(9).shuffle_buffer_bytes(budget).build()
}

/// ~160k staged pairs (≈ 4 MiB of modeled staging across the ranks) —
/// two orders of magnitude past the 64 KiB budget.
fn big_corpus() -> Vec<String> {
    wordcount::generate_corpus(20_000, 8, 2_000, 9)
}

/// The memory contract, spelled out: per rank the pipeline holds the
/// staging buffer (≤ budget), one round of outgoing + incoming shuffle
/// buffers (≤ ~2 budgets), the restage buffer (≤ budget), and one raw
/// block (≤ `block_cap`) per open run during the merges; the driver adds
/// the reduced output map. The engine's tracker sums ranks, so the
/// bound multiplies the per-rank terms by the rank count.
fn peak_bound(spilled_bytes: u64, result_entries: usize) -> u64 {
    // Spilled runs are encoded (denser than the modeled staging charge),
    // so runs ≤ spilled / (budget/4) with plenty of slack; +2 tails and
    // +2 receiver-side runs per rank.
    let runs_est = spilled_bytes / (BUDGET / 4) + 4 * RANKS as u64;
    let per_run = block_cap(BUDGET) as u64;
    let out_est = result_entries as u64 * 40;
    (RANKS as u64) * 4 * BUDGET + runs_est * per_run + out_est + 64 * 1024
}

#[test]
fn delayed_wordcount_past_budget_is_byte_identical_and_bounded() {
    let corpus = big_corpus();
    let truth = wordcount::count_serial(&corpus);

    let roomy =
        wordcount::run(&cluster_with_budget(u64::MAX), &corpus, ReductionMode::Delayed).unwrap();
    let tight =
        wordcount::run(&cluster_with_budget(BUDGET), &corpus, ReductionMode::Delayed).unwrap();

    assert_eq!(roomy.result, truth, "in-core run must match serial truth");
    assert_eq!(tight.result, roomy.result, "out-of-core output byte-identical");
    assert_eq!(roomy.stats.spilled_bytes, 0, "unlimited budget must not spill");
    assert!(
        tight.stats.spilled_bytes > 8 * BUDGET,
        "staged volume must dwarf the budget (spilled {} B)",
        tight.stats.spilled_bytes
    );

    // (c): budget + constant per-run overhead.
    let bound = peak_bound(tight.stats.spilled_bytes, tight.result.len());
    assert!(
        tight.stats.peak_mem_bytes <= bound,
        "peak {} B exceeds contract bound {} B",
        tight.stats.peak_mem_bytes,
        bound
    );
    // ...and materially below the in-core peak — the point of the layer.
    assert!(
        2 * tight.stats.peak_mem_bytes < roomy.stats.peak_mem_bytes,
        "out-of-core peak {} B not below half the in-core peak {} B",
        tight.stats.peak_mem_bytes,
        roomy.stats.peak_mem_bytes
    );
}

#[test]
fn classic_wordcount_past_budget_matches_unlimited() {
    let corpus = big_corpus();
    let roomy =
        wordcount::run(&cluster_with_budget(u64::MAX), &corpus, ReductionMode::Classic).unwrap();
    let tight =
        wordcount::run(&cluster_with_budget(BUDGET), &corpus, ReductionMode::Classic).unwrap();
    assert_eq!(tight.result, roomy.result);
    assert!(tight.stats.spilled_bytes > 0);
    assert_eq!(roomy.stats.spilled_bytes, 0);
    // Raw classic ships every pair no matter the budget; the round-based
    // shuffle only adds its small agreement traffic.
    assert!(tight.stats.shuffle_bytes >= roomy.stats.shuffle_bytes);
}

#[test]
fn combiner_works_under_tight_budget_and_cuts_the_wire() {
    let corpus = big_corpus();
    let truth = wordcount::count_serial(&corpus);
    let cluster = cluster_with_budget(BUDGET);
    let raw = wordcount::run(&cluster, &corpus, ReductionMode::Classic).unwrap();
    let combined = wordcount::run_combined(&cluster, &corpus).unwrap();
    assert_eq!(combined.result, truth);
    assert_eq!(raw.result, truth);
    assert!(combined.stats.combined_bytes > 0, "combiner must fold pairs");
    assert!(
        combined.stats.shuffle_bytes * 2 < raw.stats.shuffle_bytes,
        "combined wire volume {} must be well under raw classic {}",
        combined.stats.shuffle_bytes,
        raw.stats.shuffle_bytes
    );
    // Combining also slashes what has to spill.
    assert!(combined.stats.spilled_bytes < raw.stats.spilled_bytes);
}

#[test]
fn env_threshold_drives_engine_spilling_end_to_end() {
    // The CI low-memory leg contract: with BLAZE_SPILL_THRESHOLD set and
    // no explicit limit, engine jobs spill — and still agree with truth.
    // Uses a subprocess-free approach: an explicit budget equal to the CI
    // leg's 4096 must behave exactly like the env override does.
    let corpus = wordcount::generate_corpus(2_000, 6, 300, 11);
    let truth = wordcount::count_serial(&corpus);
    let cluster = cluster_with_budget(4096);
    for mode in [ReductionMode::Classic, ReductionMode::Delayed] {
        let out = wordcount::run(&cluster, &corpus, mode).unwrap();
        assert_eq!(out.result, truth, "mode {mode}");
        assert!(out.stats.spilled_bytes > 0, "mode {mode} must spill at 4096 B");
    }
}
