//! End-to-end tests of the in-memory iterative engine (ISSUE 5): the
//! tentpole contracts that PageRank on `IterativeJob`/`DistHashMap`
//! matches the serial reference, beats the engine path on per-iteration
//! wire bytes, and survives live `ElasticCluster` resizes with the
//! results intact — plus the same for label-propagation components,
//! where integer deltas make the cross-width guarantee *bit*-exact.

use blaze_rs::apps::{components, pagerank};
use blaze_rs::cluster::{ClusterConfig, DeploymentKind, ElasticCluster};
use blaze_rs::core::{IterativeJob, ReductionMode};

fn local_elastic(ranks: usize) -> ElasticCluster {
    ElasticCluster::new(ClusterConfig::builder().ranks(ranks).build())
}

fn container_elastic(nodes: usize, slots: usize) -> ElasticCluster {
    ElasticCluster::new(
        ClusterConfig::builder()
            .deployment(DeploymentKind::Container)
            .nodes(nodes)
            .slots_per_node(slots)
            .build(),
    )
}

#[test]
fn dist_pagerank_matches_reference_for_ten_plus_iterations() {
    // The acceptance bound: within 1e-9 of the serial reference for
    // >= 10 iterations.
    let g = pagerank::Graph::random(400, 4, 5);
    let mut elastic = local_elastic(4);
    let got = pagerank::run_dist(&mut elastic, &g, 12, 0.85, &[]).unwrap();
    let want = pagerank::reference(&g, 12, 0.85);
    for (v, (a, b)) in got.ranks.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-9, "vertex {v}: {a} vs {b}");
    }
    let total: f64 = got.ranks.iter().sum();
    assert!((total - 1.0).abs() < 1e-9);
    assert_eq!(got.per_iteration.len(), 12);
    assert!(got.per_iteration.iter().all(|it| it.shuffled_bytes > 0 && it.orphan_deltas == 0));
    assert!(got.migrations.is_empty());
}

#[test]
fn dist_pagerank_beats_engine_path_bytes_every_iteration() {
    // The tentpole claim at app level: holding scores + adjacency
    // rank-local and shipping only pre-folded deltas must move strictly
    // fewer bytes than the engine path's per-iteration re-shuffle.
    let g = pagerank::Graph::random(400, 4, 5);
    let cluster = ClusterConfig::builder().ranks(4).build();
    let engine = pagerank::run(&cluster, &g, 8, 0.85, ReductionMode::Delayed).unwrap();
    let mut elastic = ElasticCluster::new(cluster);
    let dist = pagerank::run_dist(&mut elastic, &g, 8, 0.85, &[]).unwrap();
    for (a, b) in engine.ranks.iter().zip(&dist.ranks) {
        assert!((a - b).abs() < 1e-12, "paths must agree: {a} vs {b}");
    }
    let min_engine = engine.per_iteration_shuffle_bytes.iter().min().copied().unwrap();
    for it in &dist.per_iteration {
        assert!(
            it.shuffled_bytes < min_engine,
            "iteration {}: dist {} B >= engine {} B",
            it.iteration,
            it.shuffled_bytes,
            min_engine
        );
    }
}

#[test]
fn dist_pagerank_survives_grow_and_shrink_mid_run() {
    let g = pagerank::Graph::random(300, 4, 9);
    let straight = pagerank::run_dist(&mut container_elastic(2, 2), &g, 10, 0.85, &[]).unwrap();
    let mut elastic = container_elastic(2, 2);
    let resized =
        pagerank::run_dist(&mut elastic, &g, 10, 0.85, &[(3, 1), (7, -2)]).unwrap();
    // 4 ranks -> grow to 6 -> shrink to 2, results indistinguishable
    // beyond float re-association.
    for (a, b) in resized.ranks.iter().zip(&straight.ranks) {
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
    }
    let want = pagerank::reference(&g, 10, 0.85);
    for (a, b) in resized.ranks.iter().zip(&want) {
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }
    assert_eq!(resized.migrations.len(), 2);
    assert_eq!(resized.migrations[0].from_ranks, 4);
    assert_eq!(resized.migrations[0].to_ranks, 6);
    assert_eq!(resized.migrations[1].to_ranks, 2);
    assert!(resized.stats.migrated_bytes > 0);
    assert_eq!(elastic.resizes(), 2);
    // Waves after each resize ran at the new width, same session.
    assert_eq!(resized.per_iteration[2].ranks, 4);
    assert_eq!(resized.per_iteration[3].ranks, 6);
    assert_eq!(resized.per_iteration[9].ranks, 2);
}

#[test]
fn migration_moves_a_minority_of_keys_on_grow() {
    // The BucketRouter promise at session level: growing 4 -> 5 ranks
    // migrates roughly 1/5 of the state, nothing like a full re-shard.
    let n = 1_000u32;
    let mut elastic = local_elastic(4);
    let mut job: IterativeJob<u32, u64> =
        IterativeJob::load(&elastic, 3, (0..n).map(|k| (k, u64::from(k))));
    elastic.grow(1);
    let m = job.rebalance(&mut elastic).unwrap().expect("width changed");
    assert!(m.moved_keys > 0);
    assert!(
        m.moved_keys < u64::from(n) / 2,
        "grow 4->5 moved {} of {n} keys — that is a re-shard, not a rebalance",
        m.moved_keys
    );
    assert_eq!(job.len_global(), n as usize);
}

#[test]
fn components_match_union_find_and_stay_exact_across_resize() {
    let g = components::chain_graph(6, 8);
    let straight = components::run_dist(&mut local_elastic(3), &g, 30, &[]).unwrap();
    let mut elastic = local_elastic(3);
    let resized = components::run_dist(&mut elastic, &g, 30, &[(2, 2), (5, -4)]).unwrap();
    assert_eq!(straight.labels, components::reference(&g));
    // Integer min-deltas: the resized run is BIT-identical, not merely
    // within tolerance.
    assert_eq!(resized.labels, straight.labels);
    assert_eq!(resized.iterations, straight.iterations);
    assert!(resized.converged && straight.converged);
    assert_eq!(resized.migrations.len(), 2);
    assert_eq!(elastic.ranks(), 1);
}

#[test]
fn session_stats_account_shuffle_and_migration_separately() {
    let g = pagerank::Graph::random(200, 4, 1);
    let mut elastic = local_elastic(3);
    let got = pagerank::run_dist(&mut elastic, &g, 6, 0.85, &[(3, 1)]).unwrap();
    let iter_sum: u64 = got.per_iteration.iter().map(|it| it.shuffled_bytes).sum();
    let mig_sum: u64 = got.migrations.iter().map(|m| m.moved_bytes).sum();
    assert_eq!(got.stats.shuffle_bytes, iter_sum, "shuffle_bytes = delta waves only");
    assert_eq!(got.stats.migrated_bytes, mig_sum, "migrated_bytes = resizes only");
    assert!(mig_sum > 0);
    assert!(got.stats.peak_mem_bytes > 0, "session tracker must see the wave buffers");
    assert!(got.stats.modeled_ms > 0.0);
}
