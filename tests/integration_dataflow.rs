//! The dataflow-DAG acceptance suite (ISSUE 10): the
//! filter→join→group_by analytics chain must run on BOTH transports
//! (mailbox threads and real spawned `blaze worker` TCP processes)
//! with results equal to a serial reference; `explain()` must show the
//! map-chain fusion and exactly one shuffle per repartition boundary,
//! pinned by modeled-traffic assertions (co-partitioned stages move
//! zero bytes, repartitioning stages move more than zero); and the
//! fused plan must move strictly fewer bytes than the stage-by-stage
//! materializing equivalent. Hash-join and merge-join must agree with
//! each other and with a nested-loop serial join on both transports.

use std::path::Path;
use std::sync::OnceLock;

use blaze_rs::apps::analytics;
use blaze_rs::cluster::ClusterConfig;
use blaze_rs::core::{JoinStrategy, Stage};
use blaze_rs::mpi::{CollectiveAlgo, RankPool, TransportKind};
use blaze_rs::util::testpool;

const SEED: u64 = 0xDA7A;
const WIDTH: usize = 4;

fn worker_bin() -> &'static str {
    env!("CARGO_BIN_EXE_blaze")
}

/// One warm pool per transport, shared by every test in this file (a
/// TCP pool is real worker processes — spawn one fleet, not one per
/// test). Never dropped; workers exit on driver-socket EOF.
fn pools() -> &'static [(TransportKind, RankPool)] {
    static POOLS: OnceLock<Vec<(TransportKind, RankPool)>> = OnceLock::new();
    POOLS.get_or_init(|| {
        TransportKind::ALL
            .iter()
            .map(|&t| {
                let bin = (t == TransportKind::Tcp).then(|| Path::new(worker_bin()));
                (t, testpool::fleet(1, WIDTH, CollectiveAlgo::Star, t, bin))
            })
            .collect()
    })
}

/// The cluster a plan believes it runs on — single node so any rank
/// subset of the single-node fleet structurally matches.
fn cluster(transport: TransportKind) -> ClusterConfig {
    ClusterConfig::builder()
        .nodes(1)
        .slots_per_node(WIDTH)
        .seed(SEED)
        .transport(transport)
        .worker_binary(worker_bin())
        .build()
}

fn tables() -> &'static (Vec<(u32, String)>, Vec<(u32, u64)>) {
    static T: OnceLock<(Vec<(u32, String)>, Vec<(u32, u64)>)> = OnceLock::new();
    T.get_or_init(|| analytics::generate_tables(30, 400, SEED))
}

const MIN_TOTAL: u64 = 10_000;

#[test]
fn analytics_chain_matches_serial_on_both_transports() {
    // The acceptance chain: filter → join → group_by, serial-checked and
    // traffic-pinned per transport, then cross-checked between them.
    let (customers, orders) = tables();
    let truth = analytics::baskets_serial(customers, orders, MIN_TOTAL);
    let mut per_transport = Vec::new();
    for (t, pool) in pools() {
        let plan = analytics::basket_plan(customers, orders, MIN_TOTAL);
        let ex = plan.explain();
        // Exactly one shuffle per repartition boundary: both join inputs
        // repartition (arbitrary → keyed), nothing else does.
        assert_eq!(ex.stages.len(), 5, "{t}: input+filter, input, join, group_by, collect");
        assert_eq!(ex.stages[0].fused, vec!["filter".to_string()], "{t}: filter fused into scan");
        assert_eq!(ex.stages[2].shuffles, 2, "{t}: join repartitions both sides");
        assert_eq!(ex.stages[3].shuffles, 0, "{t}: group_by over co-partitioned join output");
        assert_eq!(ex.total_shuffles(), 2, "{t}");

        let out = plan.collect_on(&cluster(*t), pool).unwrap();
        // Modeled-traffic pins: the declared boundaries are where bytes
        // actually move, and ONLY there.
        assert_eq!(out.stages.len(), 5, "{t}");
        assert_eq!(out.stages[0].bytes, 0, "{t}: fused scan is rank-local");
        assert!(out.stages[2].bytes > 0, "{t}: join shuffle must move bytes");
        assert_eq!(out.stages[3].bytes, 0, "{t}: co-partitioned group_by moved bytes");
        assert_eq!(out.stats.shuffle_bytes, out.stages.iter().map(|s| s.bytes).sum::<u64>(), "{t}");

        let mut rows = out.rows;
        for (_c, vs) in rows.iter_mut() {
            vs.sort();
        }
        assert_eq!(rows, truth, "{t}: dataflow chain diverged from serial reference");
        per_transport.push((*t, rows));
    }
    let (t0, first) = &per_transport[0];
    for (t, rows) in &per_transport[1..] {
        assert_eq!(rows, first, "{t} and {t0} disagree");
    }
}

#[test]
fn fused_plan_moves_strictly_fewer_bytes_than_materializing_stage_by_stage() {
    // The JVM-era shape the paper's compiled pipeline eliminates:
    // collect every stage to the driver, re-scatter, repeat. Same rows
    // out, strictly more bytes moved (the group_by loses its
    // co-partitioning at each materialization boundary).
    let (customers, orders) = tables();
    let (t, pool) = &pools()[0];
    let c = cluster(*t);

    let fused = analytics::basket_plan(customers, orders, MIN_TOTAL).collect_on(&c, pool).unwrap();

    let filtered = Stage::from_vec(orders.clone())
        .filter(|_cust, total| *total >= MIN_TOTAL)
        .collect_on(&c, pool)
        .unwrap();
    let joined = Stage::from_vec(filtered.rows)
        .join(&Stage::from_vec(customers.clone()))
        .collect_on(&c, pool)
        .unwrap();
    let grouped = Stage::from_vec(joined.rows).group_by().collect_on(&c, pool).unwrap();

    let sorted = |mut rows: Vec<(u32, Vec<(u64, String)>)>| {
        for (_c, vs) in rows.iter_mut() {
            vs.sort();
        }
        rows
    };
    assert_eq!(sorted(fused.rows), sorted(grouped.rows), "fused and staged rows diverge");

    let staged_bytes =
        filtered.stats.shuffle_bytes + joined.stats.shuffle_bytes + grouped.stats.shuffle_bytes;
    assert!(
        fused.stats.shuffle_bytes < staged_bytes,
        "fused plan moved {} bytes, staged equivalent {} — fusion must win strictly",
        fused.stats.shuffle_bytes,
        staged_bytes
    );
    // And the gap is exactly the staged group_by's re-shuffle: the
    // fused plan's group_by rides the join's partitioning for free.
    assert!(grouped.stats.shuffle_bytes > 0, "staged group_by must repartition");
}

/// Nested-loop serial join, sorted by full pair (strategies may order
/// equal-key matches differently).
fn join_serial(left: &[(u32, u64)], right: &[(u32, String)]) -> Vec<(u32, (u64, String))> {
    let mut out = Vec::new();
    for (k, v) in left {
        for (k2, v2) in right {
            if k == k2 {
                out.push((*k, (*v, v2.clone())));
            }
        }
    }
    out.sort();
    out
}

#[test]
fn hash_and_merge_join_agree_with_serial_on_both_transports() {
    let (customers, orders) = tables();
    let truth = join_serial(orders, customers);
    assert!(!truth.is_empty());
    for (t, pool) in pools() {
        let c = cluster(*t);
        let mut got = Vec::new();
        for strategy in [JoinStrategy::Hash, JoinStrategy::Merge] {
            let out = Stage::from_vec(orders.clone())
                .join_with(&Stage::from_vec(customers.clone()), strategy)
                .collect_on(&c, pool)
                .unwrap();
            let mut rows = out.rows;
            rows.sort();
            assert_eq!(rows, truth, "{t}/{strategy:?} join diverged from serial");
            got.push(rows);
        }
        assert_eq!(got[0], got[1], "{t}: hash and merge joins disagree");
    }
}

#[test]
fn merge_join_on_pre_sorted_inputs_is_shuffle_free() {
    // sort() lands both sides as co-partitioned sorted runs; Auto then
    // picks the merge-join and the join stage itself moves zero bytes —
    // the payoff the sorted-run store exists for.
    let (customers, orders) = tables();
    let (t, pool) = &pools()[0];
    let plan = Stage::from_vec(orders.clone())
        .sort()
        .join(&Stage::from_vec(customers.clone()).sort());
    let ex = plan.explain();
    // input, sort, input, sort, join(merge), collect.
    assert_eq!(ex.stages[4].op, "join(merge)");
    assert_eq!(ex.stages[4].shuffles, 0, "both sides already co-partitioned");
    assert_eq!(ex.total_shuffles(), 2, "only the two sorts repartition");

    let out = plan.collect_on(&cluster(*t), pool).unwrap();
    assert_eq!(out.stages[4].bytes, 0, "merge join moved bytes");
    let mut rows = out.rows;
    rows.sort();
    assert_eq!(rows, join_serial(orders, customers));
}
