//! Integration tests for the pooled SPMD executor (`mpi::RankPool`):
//! many consecutive jobs on one pool, mixed rank counts, equivalence with
//! fresh-spawn `run_ranks`, thread reuse without leaks, per-job state
//! isolation, and panic containment through the public API.

use std::collections::HashMap;
use std::thread::ThreadId;

use blaze_rs::dist::ShardRouter;
use blaze_rs::mpi::{run_ranks, Communicator, RankPool, Universe};

const POOL_RANKS: usize = 8;

/// A deterministic job exercising p2p + collectives + the shuffle
/// primitive, parameterized so different waves do different work.
fn mixed_job(round: u64) -> impl Fn(&Communicator) -> (u64, Vec<u64>, usize) + Sync {
    move |c: &Communicator| {
        let me = c.rank().0 as u64;
        let sum = c.allreduce_sum_u64(me + round).unwrap();
        let gathered = c.allgather(me * round).unwrap();
        // alltoallv: rank i sends (round + i + j) bytes to rank j.
        let bufs: Vec<Vec<u8>> = (0..c.size())
            .map(|j| vec![me as u8; (round as usize + me as usize + j) % 7 + 1])
            .collect();
        let received = c.alltoallv(bufs).unwrap();
        let total_recv: usize = received.iter().map(Vec::len).sum();
        c.barrier().unwrap();
        (sum, gathered, total_recv)
    }
}

#[test]
fn twenty_plus_jobs_mixed_rank_counts_match_fresh_spawn() {
    let pool = RankPool::local(POOL_RANKS);
    let widths = [8usize, 5, 3, 1, 8, 2, 6, 4];
    let mut jobs = 0;
    for round in 0..24u64 {
        let nranks = widths[round as usize % widths.len()];
        let job = mixed_job(round);
        let pooled = pool.run_on(nranks, &job);
        let fresh = run_ranks(Universe::local(nranks), &job);
        assert_eq!(pooled, fresh, "round {round} on {nranks} ranks diverged");
        jobs += 1;
    }
    assert!(jobs >= 20);
    assert_eq!(pool.jobs_run(), jobs);
}

#[test]
fn pool_threads_are_reused_and_do_not_leak() {
    let pool = RankPool::local(6);
    assert_eq!(pool.live_threads(), 6);
    let baseline: Vec<ThreadId> = pool.run(|_| std::thread::current().id());
    for round in 0..20u64 {
        // Every job (any width) lands on the same warm threads...
        let nranks = 1 + (round as usize % 6);
        let ids = pool.run_on(nranks, |_| std::thread::current().id());
        assert_eq!(ids, baseline[..nranks], "round {round}: ranks moved threads");
        // ...and the pool's thread census never drifts.
        assert_eq!(pool.live_threads(), 6, "round {round}: thread leak or death");
    }
    assert_eq!(pool.jobs_run(), 21);
}

#[test]
fn per_job_clocks_and_traffic_read_like_fresh_universes() {
    let pool = RankPool::local(4);
    let job = |c: &Communicator| {
        c.advance(10_000);
        c.allreduce_sum_u64(1).unwrap()
    };
    let first = pool.run_job(4, job);
    // A different job in between, then the same job again.
    pool.run(|c| c.allgather(c.rank().0).unwrap());
    let again = pool.run_job(4, job);
    assert_eq!(first.results, again.results);
    assert_eq!(first.clocks, again.clocks, "virtual clocks must reset per job");
    assert_eq!(first.traffic, again.traffic, "traffic must be a per-job delta");
}

#[test]
fn shuffle_heavy_jobs_agree_with_fresh_spawn() {
    // A wordcount-flavoured shuffle repeated on a reused pool: keys are
    // routed with the real ShardRouter, each rank counts what it owns.
    let pool = RankPool::local(4);
    let lines: Vec<String> =
        (0..200).map(|i| format!("w{} w{} common", i % 13, i % 5)).collect();
    let job = |c: &Communicator| -> Vec<(String, u64)> {
        let router = ShardRouter::new(c.size(), 7);
        let chunk = lines.len().div_ceil(c.size());
        let lo = (c.rank().0 * chunk).min(lines.len());
        let hi = ((c.rank().0 + 1) * chunk).min(lines.len());
        let mut bufs: Vec<Vec<u8>> = (0..c.size()).map(|_| Vec::new()).collect();
        for line in &lines[lo..hi] {
            for w in line.split_whitespace() {
                let dst = router.owner(&w.to_string()).0;
                bufs[dst].extend_from_slice(w.as_bytes());
                bufs[dst].push(b'\n');
            }
        }
        let received = c.alltoallv(bufs).unwrap();
        let mut counts: HashMap<String, u64> = HashMap::new();
        for buf in received {
            for w in buf.split(|&b| b == b'\n').filter(|s| !s.is_empty()) {
                *counts.entry(String::from_utf8(w.to_vec()).unwrap()).or_insert(0) += 1;
            }
        }
        let mut out: Vec<(String, u64)> = counts.into_iter().collect();
        out.sort();
        out
    };
    let fresh = run_ranks(Universe::local(4), &job);
    for round in 0..5 {
        assert_eq!(pool.run(&job), fresh, "round {round} diverged");
    }
}

#[test]
fn panic_in_one_job_does_not_poison_later_jobs() {
    let pool = RankPool::local(4);
    // Healthy job first.
    assert_eq!(pool.run(|c| c.allreduce_sum_u64(2).unwrap()), vec![8; 4]);
    // One rank blows up (without stranding peers mid-collective).
    let err = pool
        .try_run_on(4, |c| {
            if c.rank().0 == 3 {
                panic!("deliberate test fault");
            }
            c.rank().0
        })
        .unwrap_err();
    assert!(format!("{err:#}").contains("rank 3 panicked"), "{err:#}");
    // The pool keeps serving full-width collective jobs afterwards.
    for _ in 0..5 {
        assert_eq!(pool.run(|c| c.allreduce_sum_u64(2).unwrap()), vec![8; 4]);
    }
    assert_eq!(pool.live_threads(), 4);
}
