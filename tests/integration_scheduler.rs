//! ISSUE 9 acceptance suite for the concurrent multi-job scheduler:
//! N mixed-width jobs submitted from several client threads onto ONE
//! shared 16-rank pool must be **byte-identical** to serial fresh-spawn
//! runs, on both the mailbox and real-TCP transports; subset-width jobs
//! (4-rank + 12-rank) must demonstrably overlap in time on disjoint
//! rank subsets; a flood of narrow jobs must not starve a full-width
//! job (the deficit-round-robin + starvation-freeze guarantee); a soak
//! leaves no stray rank/dispatcher threads or orphan TCP worker
//! processes; and a slow job's unconsumed frames never leak into a
//! concurrently admitted job that reuses its ranks (epoch fencing).
//!
//! Every test takes `gate()` first: the leak test counts process-global
//! `blaze-*` threads and the overlap tests need all 16 ranks of a
//! dedicated pool free, so the tests in this binary serialize. (Other
//! test binaries are separate processes and cannot interfere.)

use std::collections::HashMap;
use std::path::Path;
use std::sync::{mpsc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use blaze_rs::apps::{pagerank, wordcount};
use blaze_rs::cluster::ClusterConfig;
use blaze_rs::core::{JobCtx, ReductionMode, Scheduler, SchedulerConfig};
use blaze_rs::mpi::{CollectiveAlgo, Rank, Tag, TransportKind};
use blaze_rs::util::testpool;

const POOL_RANKS: usize = 16;
const SEED: u64 = 0xB1A2E;

fn gate() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn worker_bin() -> &'static str {
    env!("CARGO_BIN_EXE_blaze")
}

/// A scheduler over a fresh single-node 16-rank fleet. Single node on
/// purpose: every rank subset is then same-node, so it structurally
/// matches the single-node job clusters below at any width.
fn new_scheduler(transport: TransportKind) -> Scheduler {
    let bin = (transport == TransportKind::Tcp).then(|| Path::new(worker_bin()));
    Scheduler::with_config(
        testpool::fleet(1, POOL_RANKS, CollectiveAlgo::Star, transport, bin),
        SchedulerConfig::default(),
    )
}

/// One warm scheduler per transport, shared by the byte-identity and
/// fencing tests (a TCP fleet is 16 real worker processes — one per
/// transport for the whole suite, not one per test). Never dropped;
/// workers exit on driver-socket EOF when the test process does.
fn schedulers() -> &'static [(TransportKind, Scheduler)] {
    static S: OnceLock<Vec<(TransportKind, Scheduler)>> = OnceLock::new();
    S.get_or_init(|| TransportKind::ALL.iter().map(|t| (*t, new_scheduler(*t))).collect())
}

/// The cluster a `width`-rank job believes it runs on — the SAME config
/// feeds the serial fresh-spawn baseline and the pool-placed run, so any
/// divergence is the scheduler's fault, not the config's.
fn job_cluster(width: usize, transport: TransportKind) -> ClusterConfig {
    ClusterConfig::builder()
        .nodes(1)
        .slots_per_node(width)
        .seed(SEED)
        .transport(transport)
        .worker_binary(worker_bin())
        .build()
}

fn corpus() -> &'static Vec<String> {
    static C: OnceLock<Vec<String>> = OnceLock::new();
    C.get_or_init(|| wordcount::generate_corpus(120, 6, 40, SEED))
}

fn graph() -> &'static pagerank::Graph {
    static G: OnceLock<pagerank::Graph> = OnceLock::new();
    G.get_or_init(|| pagerank::Graph::random(200, 4, SEED))
}

#[derive(Clone, Copy, Debug)]
enum Kind {
    Wc(ReductionMode),
    Pr,
}

/// What byte-identity means per app: the exact result plus the modeled
/// shuffle traffic. (Clocks fold in measured host CPU time and are not
/// run-to-run comparable — same carve-out as the tracing suite.)
#[derive(Debug, PartialEq)]
enum Out {
    Wc(HashMap<String, u64>, u64, u64),
    Pr(Vec<f64>, Vec<u64>),
}

/// The mixed stream: widths 1..=4 across all three reduction modes plus
/// two iterative PageRanks, so several jobs co-reside on 16 ranks.
fn specs() -> Vec<(usize, Kind)> {
    vec![
        (4, Kind::Wc(ReductionMode::Classic)),
        (2, Kind::Wc(ReductionMode::Eager)),
        (3, Kind::Pr),
        (2, Kind::Wc(ReductionMode::Delayed)),
        (4, Kind::Pr),
        (1, Kind::Wc(ReductionMode::Eager)),
        (2, Kind::Wc(ReductionMode::Classic)),
        (3, Kind::Wc(ReductionMode::Delayed)),
    ]
}

/// Serial truth: a fresh-spawn cluster of exactly the job's width.
fn baseline(width: usize, kind: Kind, transport: TransportKind) -> Out {
    let cluster = job_cluster(width, transport);
    match kind {
        Kind::Wc(mode) => {
            let r = wordcount::run(&cluster, corpus(), mode).unwrap();
            Out::Wc(r.result, r.stats.shuffle_bytes, r.stats.messages)
        }
        Kind::Pr => {
            let r = pagerank::run(&cluster, graph(), 4, 0.85, ReductionMode::Delayed).unwrap();
            Out::Pr(r.ranks, r.per_iteration_shuffle_bytes)
        }
    }
}

/// The same job, placed on the scheduler's reserved rank subset.
fn placed(ctx: &JobCtx, width: usize, kind: Kind, transport: TransportKind) -> anyhow::Result<Out> {
    let cluster = job_cluster(width, transport);
    match kind {
        Kind::Wc(mode) => {
            let r = wordcount::run_placed(&cluster, ctx.pool(), ctx.ranks(), corpus(), mode)?;
            Ok(Out::Wc(r.result, r.stats.shuffle_bytes, r.stats.messages))
        }
        Kind::Pr => {
            let r = pagerank::run_placed(
                &cluster,
                ctx.pool(),
                ctx.ranks(),
                graph(),
                4,
                0.85,
                ReductionMode::Delayed,
            )?;
            Ok(Out::Pr(r.ranks, r.per_iteration_shuffle_bytes))
        }
    }
}

#[test]
fn concurrent_mixed_width_jobs_are_byte_identical_to_serial_runs() {
    let _g = gate();
    for (transport, sched) in schedulers() {
        let specs = specs();
        let want: Vec<Out> = specs.iter().map(|&(w, k)| baseline(w, k, *transport)).collect();

        // Four client threads submit interleaved shares of the stream
        // concurrently, then each waits for its own handles.
        let got: Vec<(usize, Out)> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..4)
                .map(|client| {
                    let specs = &specs;
                    s.spawn(move || {
                        let handles: Vec<_> = specs
                            .iter()
                            .enumerate()
                            .skip(client)
                            .step_by(4)
                            .map(|(i, &(w, k))| {
                                let t = *transport;
                                let h = sched
                                    .submit(&format!("client-{client}"), w, move |ctx| {
                                        placed(ctx, w, k, t)
                                    })
                                    .unwrap();
                                (i, h)
                            })
                            .collect();
                        handles
                            .into_iter()
                            .map(|(i, h)| (i, h.wait().result.unwrap()))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            joins.into_iter().flat_map(|j| j.join().unwrap()).collect()
        });

        assert_eq!(got.len(), specs.len());
        for (i, out) in got {
            assert_eq!(out, want[i], "{transport}: concurrent job {i} diverged from serial run");
        }
        // Every concurrently-run job went through the shared pool's
        // admission log with a within-pool reservation.
        let events = sched.events();
        assert!(events.len() >= specs.len(), "{transport}: admission log too short");
        for e in &events {
            assert!(e.ranks.iter().all(|&r| r < POOL_RANKS));
            assert_eq!(e.ranks.len(), e.width);
        }
    }
}

#[test]
fn subset_width_jobs_demonstrably_overlap_on_disjoint_rank_subsets() {
    let _g = gate();
    for transport in TransportKind::ALL {
        // Dedicated scheduler: the rendezvous needs 4 + 12 ranks free at
        // once, which the shared fleet cannot guarantee.
        let sched = new_scheduler(transport);
        let (started_tx, started_rx) = mpsc::channel();
        let started_tx2 = started_tx.clone();
        let (release_a_tx, release_a_rx) = mpsc::channel::<()>();
        let (release_b_tx, release_b_rx) = mpsc::channel::<()>();

        // Each job proves its ranks are live with a real SPMD wave,
        // reports in, and then HOLDS its reservation until released.
        // Channel rendezvous, not a Barrier: if co-scheduling broke,
        // the recv_timeout below fails the test instead of deadlocking.
        let ha = sched
            .submit("narrow", 4, move |ctx| {
                ctx.run_spmd(|c| c.rank().0)?;
                started_tx.send("narrow").unwrap();
                release_a_rx.recv_timeout(Duration::from_secs(60))?;
                ctx.run_spmd(|c| c.rank().0)?;
                Ok(())
            })
            .unwrap();
        let hb = sched
            .submit("wide", 12, move |ctx| {
                ctx.run_spmd(|c| c.rank().0)?;
                started_tx2.send("wide").unwrap();
                release_b_rx.recv_timeout(Duration::from_secs(60))?;
                ctx.run_spmd(|c| c.rank().0)?;
                Ok(())
            })
            .unwrap();

        // Both jobs report in while NEITHER has been released: they are
        // in flight simultaneously on the one pool.
        let first = started_rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|_| panic!("{transport}: no job started"));
        let second = started_rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|_| panic!("{transport}: {first} ran alone — jobs did not co-schedule"));
        assert_ne!(first, second);
        release_a_tx.send(()).unwrap();
        release_b_tx.send(()).unwrap();

        let oa = ha.wait();
        let ob = hb.wait();
        oa.result.unwrap();
        ob.result.unwrap();
        assert_eq!(sched.peak_concurrent_jobs(), 2, "{transport}");

        // The admission log agrees, and the reservations tile the pool:
        // 4 + 12 disjoint ranks on 16.
        let events = sched.events();
        assert_eq!(events.len(), 2);
        assert!(events[0].overlaps(&events[1]), "{transport}: events claim no overlap");
        let mut all: Vec<usize> =
            events.iter().flat_map(|e| e.ranks.iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..POOL_RANKS).collect::<Vec<_>>(), "{transport}: reservations overlap");
        assert_eq!(oa.stats.ranks.len(), 4);
        assert_eq!(ob.stats.ranks.len(), 12);

        // Dropping a TCP scheduler reaps its worker fleet.
        let pids: Vec<u32> = sched.pool().worker_pids().to_vec();
        if transport == TransportKind::Tcp {
            assert_eq!(pids.len(), POOL_RANKS);
        }
        drop(sched);
        for pid in pids {
            let alive = unsafe { libc::kill(pid as i32, 0) } == 0;
            assert!(!alive, "{transport}: worker {pid} survived scheduler drop");
        }
    }
}

#[test]
fn full_width_job_is_not_starved_by_a_flood_of_narrow_jobs() {
    let _g = gate();
    // Adversarial knobs: tiny quantum, aggressive starvation freeze.
    let sched = Scheduler::with_config(
        testpool::fleet(1, POOL_RANKS, CollectiveAlgo::Star, TransportKind::Mailbox, None),
        SchedulerConfig { quantum: 2, max_queue: 256, starvation_rounds: 2 },
    );
    fn hog(ctx: &JobCtx) -> anyhow::Result<()> {
        ctx.run_spmd(|_c| std::thread::sleep(Duration::from_millis(2)))?;
        Ok(())
    }
    let mut hogs = Vec::new();
    for _ in 0..24 {
        hogs.push(sched.submit("hog", 1, hog).unwrap());
    }
    // The full-width job arrives mid-flood: it fits only when ALL 16
    // ranks drain, which the starvation freeze must force even though
    // width-1 work keeps arriving behind it.
    let wide = sched
        .submit("patient", POOL_RANKS, |ctx| {
            ctx.run_spmd(|c| c.rank().0)?;
            Ok(())
        })
        .unwrap();
    for _ in 0..24 {
        hogs.push(sched.submit("hog", 1, hog).unwrap());
    }

    let out = wide.wait();
    out.result.unwrap();
    assert_eq!(out.stats.ranks.len(), POOL_RANKS);
    for h in hogs {
        h.wait().result.unwrap();
    }
    let tenants = sched.tenant_stats();
    let find = |name: &str| tenants.iter().find(|t| t.name == name).unwrap().clone();
    assert_eq!(find("hog").admitted_jobs, 48);
    assert_eq!(find("hog").admitted_rank_units, 48);
    assert_eq!(find("patient").admitted_jobs, 1);
    assert_eq!(find("patient").admitted_rank_units, POOL_RANKS as u64);
    sched.drain();
    assert_eq!(sched.active_jobs(), 0);
    assert_eq!(sched.queued_jobs(), 0);
}

/// Threads whose comm name marks them as pool ranks or scheduler
/// dispatchers (`/proc/self/task/<tid>/comm`; names fit the 15-char cap).
fn blaze_thread_count() -> usize {
    std::fs::read_dir("/proc/self/task")
        .expect("linux procfs")
        .filter(|e| {
            let comm = e
                .as_ref()
                .map(|e| std::fs::read_to_string(e.path().join("comm")).unwrap_or_default())
                .unwrap_or_default();
            comm.starts_with("blaze-rank-") || comm.starts_with("blaze-sched-")
        })
        .count()
}

#[test]
fn soak_leaves_no_stray_threads_or_queue_residue() {
    let _g = gate();
    // Fold the long-lived shared fleets into the baseline first, so this
    // test measures only its own scheduler's threads.
    let _ = schedulers();
    let baseline = blaze_thread_count();

    let sched = new_scheduler(TransportKind::Mailbox);
    assert!(blaze_thread_count() > baseline, "scheduler spawned no threads?");
    std::thread::scope(|s| {
        for client in 0..4 {
            let sched = &sched;
            s.spawn(move || {
                for i in 0..16 {
                    let width = 1 + (client + i) % 8;
                    let out = sched
                        .submit(&format!("soak-{client}"), width, move |ctx| {
                            let ranks = ctx.run_spmd(|c| c.rank().0)?;
                            Ok(ranks.len())
                        })
                        .unwrap()
                        .wait();
                    assert_eq!(out.result.unwrap(), width);
                }
            });
        }
    });
    assert_eq!(sched.active_jobs(), 0, "soak left active jobs");
    assert_eq!(sched.queued_jobs(), 0, "soak left queued jobs");
    let events = sched.events();
    assert_eq!(events.len(), 64);
    assert!(events.iter().all(|e| e.completed_at.is_some()));
    drop(sched);

    // Drop joins dispatchers and rank threads synchronously; allow a few
    // scheduler ticks for the kernel to retire task entries.
    for _ in 0..100 {
        if blaze_thread_count() == baseline {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(blaze_thread_count(), baseline, "soak leaked rank/dispatcher threads");
}

#[test]
fn late_frames_from_a_slow_job_never_leak_into_the_next_job() {
    let _g = gate();
    for (transport, sched) in schedulers() {
        let tag = Tag::user(7717);
        // Job A takes the FULL pool, so the probe below must reuse its
        // exact ranks. Rank 0 sends three tagged frames; rank 1 consumes
        // only one and leaves two unconsumed in its mailbox; then every
        // rank dawdles — A is still in flight when B is admitted behind
        // it (interleaved submission, sequential execution on the same
        // ranks).
        let ha = sched
            .submit("slow", POOL_RANKS, move |ctx| {
                ctx.run_spmd(move |c| {
                    match c.rank().0 {
                        0 => {
                            for _ in 0..3 {
                                c.send(Rank(1), tag, b"stale-from-A".to_vec()).unwrap();
                            }
                        }
                        1 => {
                            let one = c.recv(Rank(0), tag).unwrap();
                            assert_eq!(one, b"stale-from-A");
                        }
                        _ => {}
                    }
                    std::thread::sleep(Duration::from_millis(10));
                })?;
                Ok(())
            })
            .unwrap();
        let hb = sched
            .submit("probe", POOL_RANKS, move |ctx| {
                let mut waves = ctx.run_spmd(move |c| match c.rank().0 {
                    0 => {
                        c.send(Rank(1), tag, b"fresh-from-B".to_vec()).unwrap();
                        Vec::new()
                    }
                    1 => c.recv(Rank(0), tag).unwrap(),
                    _ => Vec::new(),
                })?;
                Ok(waves.swap_remove(1))
            })
            .unwrap();

        ha.wait().result.unwrap();
        let got = hb.wait().result.unwrap();
        assert_eq!(
            got,
            b"fresh-from-B".to_vec(),
            "{transport}: a stale frame from the previous epoch leaked into the probe job"
        );
    }
}
