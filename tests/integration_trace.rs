//! PR 8 acceptance suite for the tracing subsystem: spans are
//! well-nested per rank, send/recv spans match up across the mailbox
//! AND real-TCP transports (with worker `Relay` spans causally linked
//! through the wire span ids), results/clocks/traffic are byte-identical
//! with tracing on vs off, and both a wordcount and an iterative
//! PageRank over TCP export valid Chrome trace-event JSON.
//!
//! Every test takes `gate()` first: tracing enablement is a
//! process-global scope count and `take_last`/worker-span-dir state are
//! process-global stashes, so the tests in this binary serialize.
//! (Other test binaries are separate processes and cannot interfere.)

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

use blaze_rs::apps::{pagerank, wordcount};
use blaze_rs::cluster::{ClusterConfig, ElasticCluster};
use blaze_rs::core::ReductionMode;
use blaze_rs::mpi::{
    CollectiveAlgo, Communicator, Rank, RankPool, Tag, Topology, TransportKind, Universe,
};
use blaze_rs::trace::{self, JobTrace, SpanEvent, SpanKind, TraceConfig};
use blaze_rs::util::testpool;
use blaze_rs::util::Json;

fn gate() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn worker_bin() -> &'static str {
    env!("CARGO_BIN_EXE_blaze")
}

/// Deterministic skewed corpus — enough distinct keys to shuffle.
fn lines(n: usize) -> Vec<String> {
    (0..n)
        .map(|i| format!("w{} w{} w{} common the", i % 7, i % 13, (i * i) % 23))
        .collect()
}

/// A fresh unique export path under the OS temp dir (removed by the
/// test that created it).
fn export_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("blaze-trace-{name}-{}.json", std::process::id()))
}

/// Per (process, rank) lane the `[seq_open, seq_close]` intervals must
/// form a laminar family: any two are nested or disjoint (the RAII
/// guards close in LIFO order; instants are degenerate intervals).
fn assert_laminar(spans: &[SpanEvent]) {
    let mut lanes: HashMap<(u32, usize), Vec<&SpanEvent>> = HashMap::new();
    for e in spans {
        lanes.entry((e.proc_id, e.rank)).or_default().push(e);
    }
    for ((proc_id, rank), mut evs) in lanes {
        evs.sort_by_key(|e| (e.seq_open, std::cmp::Reverse(e.seq_close)));
        let mut open: Vec<u64> = Vec::new(); // seq_close of enclosing spans
        for e in evs {
            assert!(
                e.seq_close >= e.seq_open,
                "{:?} closes before it opens (proc {proc_id} rank {rank})",
                e.kind
            );
            while open.last().is_some_and(|&top| top < e.seq_open) {
                open.pop();
            }
            if let Some(&top) = open.last() {
                assert!(
                    e.seq_close <= top,
                    "{:?} [{}..{}] straddles enclosing span closing at {} \
                     (proc {proc_id} rank {rank})",
                    e.kind,
                    e.seq_open,
                    e.seq_close,
                    top
                );
            }
            open.push(e.seq_close);
        }
    }
}

fn send_ids(spans: &[SpanEvent]) -> HashSet<u64> {
    spans.iter().filter(|e| e.kind == SpanKind::Send).map(|e| e.id).collect()
}

fn recv_links(spans: &[SpanEvent]) -> Vec<u64> {
    spans.iter().filter(|e| e.kind == SpanKind::Recv).map(|e| e.link).collect()
}

/// A fixed SPMD program whose wire behavior is fully known: every send
/// is received exactly once (ring exchange; the collectives consume all
/// their internal messages), and every cost comes from `advance`, never
/// from measured host time — so virtual clocks are deterministic.
fn ring_job(c: &Communicator) -> (Vec<u8>, u64) {
    let me = c.rank().0;
    c.advance(1_000 * (me as u64 + 1));
    let next = Rank((me + 1) % c.size());
    let prev = Rank((me + c.size() - 1) % c.size());
    c.send(next, Tag(9), vec![me as u8; (me + 1) * 64]).unwrap();
    let got = c.recv(prev, Tag(9)).unwrap();
    let sum = c.allreduce_sum_u64(me as u64 + got.len() as u64).unwrap();
    c.barrier().unwrap();
    (got, sum)
}

#[test]
fn engine_phase_spans_cover_the_taxonomy_and_nest_laminarly() {
    let _g = gate();
    let input = lines(600);
    for mode in ReductionMode::ALL {
        let cluster = ClusterConfig::builder()
            .nodes(2)
            .slots_per_node(2)
            .seed(11)
            .trace(TraceConfig::Record)
            .build();
        let out = wordcount::run(&cluster, &input, mode).unwrap();
        assert!(!out.result.is_empty());

        let tr = trace::take_last()
            .unwrap_or_else(|| panic!("{mode}: Record run left no stashed trace"));
        assert!(!tr.is_empty(), "{mode}: empty trace");
        assert_laminar(tr.spans());

        let phases = tr.per_phase();
        let expected: &[SpanKind] = match mode {
            ReductionMode::Classic => {
                &[SpanKind::Job, SpanKind::Map, SpanKind::Shuffle, SpanKind::Reduce]
            }
            ReductionMode::Eager => {
                &[SpanKind::Job, SpanKind::Map, SpanKind::Combine, SpanKind::Shuffle]
            }
            ReductionMode::Delayed => &[
                SpanKind::Job,
                SpanKind::Map,
                SpanKind::Shuffle,
                SpanKind::ShuffleRound,
                SpanKind::Reduce,
            ],
        };
        for kind in expected {
            assert!(
                phases.contains_key(kind),
                "{mode}: no {kind:?} span; got {:?}",
                phases.keys().collect::<Vec<_>>()
            );
        }

        // Wire-level causality inside one process: every recv links back
        // to an allocated send id, and ids are never reused.
        let sends: Vec<u64> =
            tr.spans().iter().filter(|e| e.kind == SpanKind::Send).map(|e| e.id).collect();
        let ids = send_ids(tr.spans());
        assert_eq!(sends.len(), ids.len(), "{mode}: duplicate send span ids");
        assert!(!ids.is_empty(), "{mode}: multi-rank job recorded no sends");
        assert!(!ids.contains(&0), "{mode}: send recorded with id 0");
        let links = recv_links(tr.spans());
        assert!(!links.is_empty(), "{mode}: no recv spans");
        for link in &links {
            assert!(ids.contains(link), "{mode}: recv links unknown send id {link}");
        }

        // Analysis surface smoke: aggregates, histogram, critical path,
        // human summary all see the data.
        assert!(!tr.per_rank().is_empty());
        assert!(tr.duration_histogram(SpanKind::Map).count() >= 1);
        assert!(!tr.critical_path().is_empty());
        assert!(tr.summary().contains("spans"));
    }
}

#[test]
fn send_and_recv_spans_match_across_mailbox_and_tcp() {
    let _g = gate();
    // Enable BEFORE spawning the fleet: the TCP launcher only arms the
    // worker-side span files when tracing is on at launch time.
    let _t = trace::enable_scope(true);

    let mailbox =
        testpool::fleet(2, 2, CollectiveAlgo::Star, TransportKind::Mailbox, None);
    let tcp = testpool::fleet(
        2,
        2,
        CollectiveAlgo::Star,
        TransportKind::Tcp,
        Some(Path::new(worker_bin())),
    );

    let mb_out = mailbox.run_job(4, ring_job);
    let tcp_out = tcp.run_job(4, ring_job);

    // The transports must be indistinguishable above the seam — traced.
    assert_eq!(mb_out.results, tcp_out.results);
    assert_eq!(mb_out.clocks, tcp_out.clocks);
    assert_eq!(mb_out.traffic, tcp_out.traffic);

    // Every message in `ring_job` is consumed, so the recv links are
    // exactly the send ids — on both transports.
    for (name, out) in [("mailbox", &mb_out), ("tcp", &tcp_out)] {
        let ids = send_ids(&out.trace);
        let links: HashSet<u64> = recv_links(&out.trace).into_iter().collect();
        assert!(!ids.is_empty(), "{name}: no send spans");
        assert!(!ids.contains(&0), "{name}: send id 0");
        assert_eq!(ids, links, "{name}: recv links != send ids");
        assert_laminar(&out.trace);
    }

    // Dropping the TCP pool reaps the fleet; each worker flushes its
    // relay spans on driver EOF. Every relayed frame must carry a span
    // id the driver allocated at send time — cross-process causality.
    let driver_ids = send_ids(&tcp_out.trace);
    drop(tcp);
    let relays = trace::collect_worker_spans();
    assert!(!relays.is_empty(), "TCP workers recorded no relay spans");
    for ev in &relays {
        assert_eq!(ev.kind, SpanKind::Relay, "worker file held a non-relay span");
        assert!(ev.proc_id >= 1, "worker span on the driver's process lane");
        assert!(
            driver_ids.contains(&ev.link),
            "relay links unknown wire span id {}",
            ev.link
        );
    }
}

#[test]
fn tracing_on_vs_off_is_byte_identical() {
    let _g = gate();

    // Pool level, deterministic costs: results, per-rank virtual clocks
    // and the traffic delta must not move by a byte when tracing is on.
    let cfg = ClusterConfig::builder().nodes(2).slots_per_node(2).build();
    let off = RankPool::new(Universe::new(Topology::block(2, 2), cfg.network_model()))
        .run_job(4, ring_job);
    assert!(off.trace.is_empty(), "untraced job harvested spans");
    let on = {
        let _t = trace::enable_scope(true);
        RankPool::new(Universe::new(Topology::block(2, 2), cfg.network_model()))
            .run_job(4, ring_job)
    };
    assert!(!on.trace.is_empty(), "traced job harvested no spans");
    assert_eq!(off.results, on.results);
    assert_eq!(off.clocks, on.clocks, "tracing perturbed the virtual clocks");
    assert_eq!(off.traffic, on.traffic, "tracing perturbed the wire traffic");

    // Engine level, every reduction mode: identical results and modeled
    // traffic/memory. (Engine clocks fold in measured host CPU time via
    // `timed`, so the time split is not run-to-run reproducible and is
    // not compared — the clock pin is the deterministic job above.)
    let input = lines(400);
    for mode in ReductionMode::ALL {
        let run = |tc: TraceConfig| {
            let cluster = ClusterConfig::builder()
                .nodes(2)
                .slots_per_node(2)
                .seed(3)
                .trace(tc)
                .build();
            wordcount::run(&cluster, &input, mode).unwrap()
        };
        let off = run(TraceConfig::Off);
        let on = run(TraceConfig::Record);
        let _ = trace::take_last();
        assert_eq!(off.result, on.result, "{mode}: tracing changed the answer");
        let (a, b) = (&off.stats, &on.stats);
        assert_eq!(a.shuffle_bytes, b.shuffle_bytes, "{mode}: shuffle_bytes moved");
        assert_eq!(a.messages, b.messages, "{mode}: messages moved");
        assert_eq!(a.remote_messages, b.remote_messages, "{mode}: remote_messages moved");
        assert_eq!(a.remote_bytes, b.remote_bytes, "{mode}: remote_bytes moved");
        assert_eq!(a.peak_mem_bytes, b.peak_mem_bytes, "{mode}: peak_mem_bytes moved");
        assert_eq!(a.spilled_bytes, b.spilled_bytes, "{mode}: spilled_bytes moved");
        assert_eq!(a.combined_bytes, b.combined_bytes, "{mode}: combined_bytes moved");
    }
}

/// Pull the (non-metadata) trace events out of an exported Chrome JSON.
fn chrome_events(json: &Json) -> &[Json] {
    match json.get("traceEvents") {
        Some(Json::Arr(events)) => events,
        other => panic!("traceEvents missing or not an array: {other:?}"),
    }
}

fn ph(event: &Json) -> &str {
    event.get("ph").and_then(Json::as_str).unwrap_or("")
}

#[test]
fn wordcount_over_tcp_exports_valid_chrome_trace() {
    let _g = gate();
    let path = export_path("wordcount");
    let cluster = ClusterConfig::builder()
        .nodes(2)
        .slots_per_node(2)
        .seed(7)
        .transport(TransportKind::Tcp)
        .worker_binary(worker_bin())
        .trace(TraceConfig::Export(path.clone()))
        .build();
    let out = wordcount::run(&cluster, &lines(300), ReductionMode::Classic).unwrap();
    assert!(!out.result.is_empty());

    // The merged trace includes the worker processes' relay spans (the
    // engine's throwaway pool is dropped, and its fleet reaped, before
    // the export is written).
    let tr = trace::take_last().expect("Export run left no stashed trace");
    let phases = tr.per_phase();
    assert!(phases.contains_key(&SpanKind::Relay), "no worker relay spans in the export");
    let ids = send_ids(tr.spans());
    for ev in tr.spans().iter().filter(|e| e.kind == SpanKind::Relay) {
        assert!(ev.proc_id >= 1, "relay span on the driver's process lane");
        assert!(ids.contains(&ev.link), "relay links unknown wire span id {}", ev.link);
    }

    // The file itself round-trips the Chrome trace-event schema.
    let text = std::fs::read_to_string(&path).expect("export file written");
    let json = Json::parse(&text).expect("export is well-formed JSON");
    trace::validate_chrome_json(&json).expect("export violates the Chrome schema");
    let events = chrome_events(&json);
    assert!(events.iter().any(|e| ph(e) == "X"), "no complete events");
    assert!(events.iter().any(|e| ph(e) == "s"), "no flow-start (send) events");
    assert!(events.iter().any(|e| ph(e) == "f"), "no flow-finish (recv) events");
    assert!(
        events.iter().any(|e| e.get("pid").and_then(Json::as_u64).is_some_and(|p| p >= 1)),
        "no events on a worker process lane"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn pagerank_over_tcp_exports_causally_linked_trace() {
    let _g = gate();
    let _t = trace::enable_scope(true);
    trace::job_start(trace::DRIVER_RANK, 0, 0);

    let cfg = ClusterConfig::builder()
        .nodes(2)
        .slots_per_node(2)
        .seed(5)
        .transport(TransportKind::Tcp)
        .worker_binary(worker_bin())
        .build();
    let graph = pagerank::Graph::random(240, 5, 33);
    let mut elastic = ElasticCluster::new(cfg);
    let r = pagerank::run_dist(&mut elastic, &graph, 3, 0.85, &[]).unwrap();
    assert_eq!(r.ranks.len(), graph.vertices);

    let mut tr = JobTrace::merge([trace::take(), r.trace]);
    let driver_ids = send_ids(tr.spans());
    // Reap the fleet so the workers flush their span files, then stitch
    // the cross-process timeline together.
    drop(elastic);
    let relays = trace::collect_worker_spans();
    assert!(!relays.is_empty(), "TCP workers recorded no relay spans");
    for ev in &relays {
        assert_eq!(ev.kind, SpanKind::Relay);
        assert!(
            driver_ids.contains(&ev.link),
            "relay links unknown wire span id {}",
            ev.link
        );
    }
    tr.extend(relays);

    // The iterative taxonomy is all there, one Wave per rank per step.
    let phases = tr.per_phase();
    for kind in [SpanKind::Wave, SpanKind::Contribute, SpanKind::Flush, SpanKind::Update] {
        assert!(phases.contains_key(&kind), "no {kind:?} span in the session trace");
    }
    assert!(phases[&SpanKind::Wave].count >= 3 * 4, "fewer waves than steps x ranks");

    let path = export_path("pagerank");
    tr.export(&path).unwrap();
    let json = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    trace::validate_chrome_json(&json).expect("export violates the Chrome schema");
    assert!(chrome_events(&json).iter().any(|e| ph(e) == "s"));
    let _ = std::fs::remove_file(&path);
}
