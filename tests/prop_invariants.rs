//! Property-based tests (in-tree runner: `blaze_rs::util::prop`) on the
//! framework's core invariants: codec roundtrips, router determinism,
//! rebalance leveling, partitioner tiling, JSON/TOML roundtrips, and
//! engine-vs-serial equivalence on random inputs.

use std::collections::HashMap;

use blaze_rs::cluster::ClusterConfig;
use blaze_rs::core::ReductionMode;
use blaze_rs::dist::{rebalance_plan, ShardRouter};
use blaze_rs::serial::{from_bytes, to_bytes, FastSerialize};
use blaze_rs::util::prop::{for_all, string, vec_of};
use blaze_rs::util::rng::Rng;
use blaze_rs::util::Json;

fn roundtrips<T: FastSerialize + PartialEq + std::fmt::Debug>(v: &T) -> bool {
    match from_bytes::<T>(&to_bytes(v)) {
        Ok(back) => back == *v,
        Err(_) => false,
    }
}

#[test]
fn prop_codec_roundtrip_u64() {
    for_all("u64 roundtrip", |r| r.next_u64(), roundtrips);
}

#[test]
fn prop_codec_roundtrip_i64_zigzag() {
    for_all("i64 roundtrip", |r| r.next_u64() as i64, roundtrips);
}

#[test]
fn prop_codec_roundtrip_strings() {
    for_all("string roundtrip", |r| string(r, 200), roundtrips);
}

#[test]
fn prop_codec_roundtrip_wordcount_records() {
    for_all(
        "(String, u64) vec roundtrip",
        |r| vec_of(r, 60, |r| (string(r, 20), r.next_u64())),
        roundtrips,
    );
}

#[test]
fn prop_codec_roundtrip_kmeans_records() {
    for_all(
        "(u32, Vec<f32>) roundtrip",
        |r| {
            let d = 1 + r.below(16) as usize;
            (
                r.next_u32(),
                (0..d).map(|_| f32::from_bits(r.next_u32())).collect::<Vec<f32>>(),
            )
        },
        |v| {
            // NaN != NaN: compare bit patterns.
            let bytes = to_bytes(v);
            let back: (u32, Vec<f32>) = from_bytes(&bytes).unwrap();
            back.0 == v.0
                && back.1.len() == v.1.len()
                && back.1.iter().zip(&v.1).all(|(a, b)| a.to_bits() == b.to_bits())
        },
    );
}

#[test]
fn prop_codec_decode_never_panics_on_garbage() {
    for_all(
        "decode garbage is Err not panic",
        |r| vec_of(r, 64, |r| r.next_u64() as u8),
        |bytes| {
            // Any of these may fail, none may panic.
            let _ = from_bytes::<Vec<(String, u64)>>(bytes);
            let _ = from_bytes::<HashMap<String, u64>>(bytes);
            let _ = from_bytes::<(u32, Vec<f32>)>(bytes);
            true
        },
    );
}

#[test]
fn prop_router_total_and_deterministic() {
    for_all(
        "router: owner < n, deterministic",
        |r| (1 + r.below(32) as usize, r.next_u64(), vec_of(r, 50, |r| string(r, 12))),
        |(n, salt, keys)| {
            let a = ShardRouter::new(*n, *salt);
            let b = ShardRouter::new(*n, *salt);
            keys.iter().all(|k| {
                let o = a.owner(k);
                o.0 < *n && o == b.owner(k)
            })
        },
    );
}

#[test]
fn prop_rebalance_levels_and_conserves() {
    for_all(
        "rebalance: level within 1, conserves mass, no self-moves",
        |r| vec_of(r, 16, |r| r.below(1000) as usize),
        |counts| {
            if counts.is_empty() {
                return true;
            }
            let total: usize = counts.iter().sum();
            let plan = rebalance_plan(counts);
            let mut after = counts.clone();
            for m in &plan {
                if m.from == m.to || m.count == 0 {
                    return false;
                }
                after[m.from] -= m.count;
                after[m.to] += m.count;
            }
            let max = *after.iter().max().unwrap();
            let min = *after.iter().min().unwrap();
            after.iter().sum::<usize>() == total && max - min <= 1
        },
    );
}

#[test]
fn prop_range_partitioner_tiles() {
    use blaze_rs::core::RangePartitioner;
    for_all(
        "range partitioner tiles the key space",
        |r| (1 + r.below(64) as u32 * 16 + 1, 1 + r.below(12) as usize),
        |(keys, ranks)| {
            let p = RangePartitioner::new(*keys, *ranks);
            let mut covered = 0u32;
            for rank in 0..*ranks {
                let range = p.range_of(blaze_rs::mpi::Rank(rank));
                covered += range.end - range.start;
                for key in range.clone() {
                    if p.owner(key).0 != rank {
                        return false;
                    }
                }
            }
            covered == *keys
        },
    );
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_json(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.below(2) == 1),
            2 => Json::Num((r.next_u32() as f64) / 8.0),
            3 => Json::Str(string(r, 24)),
            4 => Json::Arr((0..r.below(5)).map(|_| gen_json(r, depth - 1)).collect()),
            _ => Json::Obj(
                (0..r.below(5))
                    .map(|i| (format!("k{i}"), gen_json(r, depth - 1)))
                    .collect(),
            ),
        }
    }
    for_all(
        "json parse(to_string(v)) == v",
        |r| gen_json(r, 3),
        |v| {
            Json::parse(&v.to_string_pretty()).ok().as_ref() == Some(v)
                && Json::parse(&v.to_string_compact()).ok().as_ref() == Some(v)
        },
    );
}

#[test]
fn prop_engine_equals_serial_wordcount() {
    // Random small corpora, random rank counts, every mode: the engine's
    // result must equal the single-threaded truth.
    for_all(
        "engine == serial wordcount",
        |r| {
            let lines = vec_of(r, 40, |r| {
                (0..1 + r.below(8)).map(|_| format!("w{}", r.below(12))).collect::<Vec<_>>().join(" ")
            });
            let ranks = 1 + r.below(6) as usize;
            let mode = match r.below(3) {
                0 => ReductionMode::Classic,
                1 => ReductionMode::Eager,
                _ => ReductionMode::Delayed,
            };
            (lines, ranks, mode)
        },
        |(lines, ranks, mode)| {
            let cluster = ClusterConfig::builder().ranks(*ranks).build();
            let got = blaze_rs::apps::wordcount::run(&cluster, lines, *mode).unwrap();
            got.result == blaze_rs::apps::wordcount::count_serial(lines)
        },
    );
}

#[test]
fn prop_varint_size_monotone() {
    use blaze_rs::serial::Encoder;
    for_all(
        "varint length is non-decreasing in value",
        |r| {
            let a = r.next_u64();
            let b = r.next_u64();
            (a.min(b), a.max(b))
        },
        |(small, large)| {
            let len = |v: u64| {
                let mut e = Encoder::new();
                e.put_varint(v);
                e.len()
            };
            len(*small) <= len(*large)
        },
    );
}

#[test]
fn prop_stable_hash_no_collision_burst() {
    // Not a collision-freeness claim — just that random key sets of 100
    // don't collide into <90 distinct hashes (would indicate brokenness).
    for_all(
        "hash spreads random keys",
        |r| vec_of(r, 100, |r| r.next_u64()),
        |keys| {
            let s = blaze_rs::util::hash::SeededState::new(7);
            let mut hs: Vec<u64> = keys.iter().map(|k| s.hash_one(k)).collect();
            hs.sort_unstable();
            hs.dedup();
            hs.len() + 10 >= keys.len().min(100)
        },
    );
}
