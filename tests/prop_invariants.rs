//! Property-based tests (in-tree runner: `blaze_rs::util::prop`) on the
//! framework's core invariants: codec roundtrips, transport wire-frame
//! framing under adversarial reads, router determinism, rebalance
//! leveling, partitioner tiling, JSON/TOML roundtrips, and
//! engine-vs-serial equivalence on random inputs.

use std::collections::HashMap;

use blaze_rs::cluster::ClusterConfig;
use blaze_rs::core::ReductionMode;
use blaze_rs::dist::{rebalance_plan, ShardRouter};
use blaze_rs::serial::{from_bytes, to_bytes, FastSerialize};
use blaze_rs::util::prop::{for_all, size, string, vec_of};
use blaze_rs::util::rng::Rng;
use blaze_rs::util::Json;

fn roundtrips<T: FastSerialize + PartialEq + std::fmt::Debug>(v: &T) -> bool {
    match from_bytes::<T>(&to_bytes(v)) {
        Ok(back) => back == *v,
        Err(_) => false,
    }
}

#[test]
fn prop_codec_roundtrip_u64() {
    for_all("u64 roundtrip", |r| r.next_u64(), roundtrips);
}

#[test]
fn prop_codec_roundtrip_i64_zigzag() {
    for_all("i64 roundtrip", |r| r.next_u64() as i64, roundtrips);
}

#[test]
fn prop_codec_roundtrip_strings() {
    for_all("string roundtrip", |r| string(r, 200), roundtrips);
}

#[test]
fn prop_codec_roundtrip_wordcount_records() {
    for_all(
        "(String, u64) vec roundtrip",
        |r| vec_of(r, 60, |r| (string(r, 20), r.next_u64())),
        roundtrips,
    );
}

#[test]
fn prop_codec_roundtrip_kmeans_records() {
    for_all(
        "(u32, Vec<f32>) roundtrip",
        |r| {
            let d = 1 + r.below(16) as usize;
            (
                r.next_u32(),
                (0..d).map(|_| f32::from_bits(r.next_u32())).collect::<Vec<f32>>(),
            )
        },
        |v| {
            // NaN != NaN: compare bit patterns.
            let bytes = to_bytes(v);
            let back: (u32, Vec<f32>) = from_bytes(&bytes).unwrap();
            back.0 == v.0
                && back.1.len() == v.1.len()
                && back.1.iter().zip(&v.1).all(|(a, b)| a.to_bits() == b.to_bits())
        },
    );
}

#[test]
fn prop_codec_decode_never_panics_on_garbage() {
    for_all(
        "decode garbage is Err not panic",
        |r| vec_of(r, 64, |r| r.next_u64() as u8),
        |bytes| {
            // Any of these may fail, none may panic.
            let _ = from_bytes::<Vec<(String, u64)>>(bytes);
            let _ = from_bytes::<HashMap<String, u64>>(bytes);
            let _ = from_bytes::<(u32, Vec<f32>)>(bytes);
            true
        },
    );
}

#[test]
fn prop_router_total_and_deterministic() {
    for_all(
        "router: owner < n, deterministic",
        |r| (1 + r.below(32) as usize, r.next_u64(), vec_of(r, 50, |r| string(r, 12))),
        |(n, salt, keys)| {
            let a = ShardRouter::new(*n, *salt);
            let b = ShardRouter::new(*n, *salt);
            keys.iter().all(|k| {
                let o = a.owner(k);
                o.0 < *n && o == b.owner(k)
            })
        },
    );
}

#[test]
fn prop_rebalance_levels_and_conserves() {
    for_all(
        "rebalance: level within 1, conserves mass, no self-moves, donor xor receiver",
        |r| vec_of(r, 16, |r| r.below(1000) as usize),
        |counts| {
            if counts.is_empty() {
                return true;
            }
            let total: usize = counts.iter().sum();
            let plan = rebalance_plan(counts);
            let mut after = counts.clone();
            for m in &plan {
                if m.from == m.to || m.count == 0 {
                    return false;
                }
                // A shard never both sends and receives: any such plan
                // would move mass that could have stayed put.
                if plan.iter().any(|o| o.to == m.from) {
                    return false;
                }
                after[m.from] -= m.count;
                after[m.to] += m.count;
            }
            let max = *after.iter().max().unwrap();
            let min = *after.iter().min().unwrap();
            after.iter().sum::<usize>() == total && max - min <= 1
        },
    );
}

#[test]
fn prop_bucket_router_resize_conserves_ownership_and_range() {
    // The live-rebalance router: after ANY resize, every key routes to a
    // rank inside the new width, keys whose bucket was not reassigned
    // stay put, and a second identical history gives identical placement.
    use blaze_rs::dist::{BucketRouter, KeyRouter};
    for_all(
        "bucket router: resize keeps routes in range, moves only reported buckets",
        |r| {
            let old = 1 + r.below(8) as usize;
            let new = 1 + r.below(8) as usize;
            let keys = vec_of(r, 200, |r| r.next_u32());
            (old, new, keys, r.next_u64())
        },
        |(old, new, keys, salt)| {
            let mut router = BucketRouter::new(*old, *salt);
            let twin = {
                let mut t = BucketRouter::new(*old, *salt);
                let mut loads = vec![0usize; t.buckets()];
                for k in keys {
                    loads[t.bucket_of(k)] += 1;
                }
                t.resize(*new, &loads);
                t
            };
            let before: Vec<_> = keys.iter().map(|k| router.route(k)).collect();
            let mut loads = vec![0usize; router.buckets()];
            for k in keys {
                loads[router.bucket_of(k)] += 1;
            }
            let moves = router.resize(*new, &loads);
            router == twin
                && router.epoch() == 1
                && keys.iter().zip(&before).all(|(k, &was)| {
                    let now = router.route(k);
                    now.0 < *new
                        && (now == was
                            || moves.iter().any(|m| m.bucket == router.bucket_of(k)))
                })
        },
    );
}

#[test]
fn prop_disthashmap_migration_preserves_contents_across_grow_shrink() {
    // The ISSUE 5 satellite: a simulated grow -> shrink cycle on a live
    // IterativeJob (DistHashMap shards under the session BucketRouter)
    // must leave the merged global contents identical — no key lost,
    // duplicated, or stranded on a rank that does not own it.
    use blaze_rs::cluster::{DeploymentKind, ElasticCluster};
    use blaze_rs::core::IterativeJob;
    for_all(
        "grow->shrink migration keeps the merged global map identical",
        |r| {
            let pairs = vec_of(r, 120, |r| (r.next_u32() >> 8, r.next_u64()));
            (pairs, 1 + r.below(2) as usize, 1 + r.below(2) as usize, r.next_u64())
        },
        |(pairs, grow_by, shrink_by, salt)| {
            let mut elastic = ElasticCluster::new(
                ClusterConfig::builder()
                    .deployment(DeploymentKind::Container)
                    .nodes(2)
                    .slots_per_node(2)
                    .build(),
            );
            let want: HashMap<u32, u64> = pairs.iter().copied().collect();
            let total = want.len() as u64;
            let mut job: IterativeJob<u32, u64> =
                IterativeJob::load(&elastic, *salt, want.clone());
            elastic.grow(*grow_by);
            let grown = job.rebalance(&mut elastic).unwrap().expect("width changed");
            elastic.shrink(*shrink_by).unwrap();
            job.rebalance(&mut elastic).unwrap().expect("width changed");
            let mut got: HashMap<u32, u64> = HashMap::new();
            let disjoint = job.into_states().into_iter().all(|(k, v)| got.insert(k, v).is_none());
            disjoint && got == want && grown.moved_keys <= total
        },
    );
}

#[test]
fn prop_range_partitioner_tiles() {
    use blaze_rs::core::RangePartitioner;
    for_all(
        "range partitioner tiles the key space",
        |r| (1 + r.below(64) as u32 * 16 + 1, 1 + r.below(12) as usize),
        |(keys, ranks)| {
            let p = RangePartitioner::new(*keys, *ranks);
            let mut covered = 0u32;
            for rank in 0..*ranks {
                let range = p.range_of(blaze_rs::mpi::Rank(rank));
                covered += range.end - range.start;
                for key in range.clone() {
                    if p.owner(key).0 != rank {
                        return false;
                    }
                }
            }
            covered == *keys
        },
    );
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_json(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.below(2) == 1),
            2 => Json::Num((r.next_u32() as f64) / 8.0),
            3 => Json::Str(string(r, 24)),
            4 => Json::Arr((0..r.below(5)).map(|_| gen_json(r, depth - 1)).collect()),
            _ => Json::Obj(
                (0..r.below(5))
                    .map(|i| (format!("k{i}"), gen_json(r, depth - 1)))
                    .collect(),
            ),
        }
    }
    for_all(
        "json parse(to_string(v)) == v",
        |r| gen_json(r, 3),
        |v| {
            Json::parse(&v.to_string_pretty()).ok().as_ref() == Some(v)
                && Json::parse(&v.to_string_compact()).ok().as_ref() == Some(v)
        },
    );
}

#[test]
fn prop_engine_equals_serial_wordcount() {
    // Random small corpora, random rank counts, every mode: the engine's
    // result must equal the single-threaded truth.
    for_all(
        "engine == serial wordcount",
        |r| {
            let lines = vec_of(r, 40, |r| {
                (0..1 + r.below(8)).map(|_| format!("w{}", r.below(12))).collect::<Vec<_>>().join(" ")
            });
            let ranks = 1 + r.below(6) as usize;
            let mode = match r.below(3) {
                0 => ReductionMode::Classic,
                1 => ReductionMode::Eager,
                _ => ReductionMode::Delayed,
            };
            (lines, ranks, mode)
        },
        |(lines, ranks, mode)| {
            let cluster = ClusterConfig::builder().ranks(*ranks).build();
            let got = blaze_rs::apps::wordcount::run(&cluster, lines, *mode).unwrap();
            got.result == blaze_rs::apps::wordcount::count_serial(lines)
        },
    );
}

#[test]
fn prop_pooled_jobs_match_fresh_spawn_on_random_workloads() {
    // Shuffle determinism on a REUSED pool: every case is another job on
    // the same warm rank threads, and each must equal what a fresh-spawn
    // `run_ranks` universe computes for the same input.
    use blaze_rs::mpi::{run_ranks, Communicator, RankPool, Universe};
    let pool = RankPool::local(6);
    for_all(
        "pool.run_on == run_ranks for random records, widths, salts",
        |r| {
            let recs = vec_of(r, 80, |r| (string(r, 12), r.next_u64() >> 16));
            let ranks = 1 + r.below(6) as usize;
            let salt = r.next_u64();
            (recs, ranks, salt)
        },
        |(recs, ranks, salt)| {
            let job = |c: &Communicator| -> Vec<(String, u64)> {
                let router = ShardRouter::new(c.size(), *salt);
                let chunk = recs.len().div_ceil(c.size()).max(1);
                let lo = (c.rank().0 * chunk).min(recs.len());
                let hi = ((c.rank().0 + 1) * chunk).min(recs.len());
                let mut outbound: Vec<Vec<(String, u64)>> =
                    (0..c.size()).map(|_| Vec::new()).collect();
                for (k, v) in &recs[lo..hi] {
                    outbound[router.owner(k).0].push((k.clone(), *v));
                }
                let bufs: Vec<Vec<u8>> = outbound.iter().map(to_bytes).collect();
                let mut counts: HashMap<String, u64> = HashMap::new();
                for buf in c.alltoallv(bufs).unwrap() {
                    for (k, v) in from_bytes::<Vec<(String, u64)>>(&buf).unwrap() {
                        let e = counts.entry(k).or_insert(0);
                        *e = e.wrapping_add(v);
                    }
                }
                let mut out: Vec<(String, u64)> = counts.into_iter().collect();
                out.sort();
                out
            };
            pool.run_on(*ranks, &job) == run_ranks(Universe::local(*ranks), &job)
        },
    );
}

#[test]
fn prop_pooled_alltoallv_conserves_and_stays_deterministic_across_resizes() {
    // `alltoallv` conservation (every byte sent is received, transposed,
    // with the sender's payload intact) on an `ElasticCluster` session
    // pool, including right after simulated grow/shrink events — and the
    // pooled wave must be repeatable and equal to a fresh-spawn universe
    // of the current membership.
    use std::cell::RefCell;

    use blaze_rs::cluster::{DeploymentKind, ElasticCluster};
    use blaze_rs::mpi::{run_ranks, Communicator, Topology, Universe};

    let elastic = RefCell::new(ElasticCluster::new(
        ClusterConfig::builder()
            .deployment(DeploymentKind::Container)
            .nodes(2)
            .slots_per_node(2)
            .build(),
    ));
    for_all(
        "elastic pool alltoallv conserves bytes and matches fresh spawn",
        |r| {
            // Payload size matrix for the widest possible membership
            // (3 nodes x 2 slots); narrower waves use the top-left block.
            let sizes: Vec<Vec<usize>> =
                (0..6).map(|_| (0..6).map(|_| r.below(64) as usize).collect()).collect();
            (sizes, r.below(3))
        },
        |(sizes, resize)| {
            let mut elastic = elastic.borrow_mut();
            match *resize {
                1 if elastic.nodes() < 3 => elastic.grow(1),
                2 if elastic.nodes() > 1 => elastic.shrink(1).unwrap(),
                _ => {}
            }
            let job = |c: &Communicator| -> (Vec<usize>, u64, u64, bool) {
                let me = c.rank().0;
                let bufs: Vec<Vec<u8>> =
                    (0..c.size()).map(|j| vec![me as u8; sizes[me][j]]).collect();
                let sent: u64 = bufs.iter().map(|b| b.len() as u64).sum();
                let received = c.alltoallv(bufs).unwrap();
                let intact = received
                    .iter()
                    .enumerate()
                    .all(|(src, b)| b.iter().all(|&byte| byte == src as u8));
                let recv_lens: Vec<usize> = received.iter().map(Vec::len).collect();
                let recv_total: u64 = recv_lens.iter().map(|&l| l as u64).sum();
                let global_sent = c.allreduce_sum_u64(sent).unwrap();
                let global_recv = c.allreduce_sum_u64(recv_total).unwrap();
                (recv_lens, global_sent, global_recv, intact)
            };
            let cfg = elastic.config().clone();
            let pool = elastic.pool_for_wave();
            let pooled = pool.run(&job);
            let repeat = pool.run(&job);
            let fresh =
                run_ranks(Universe::new(Topology::from_config(&cfg), cfg.network_model()), &job);
            pooled == repeat
                && pooled == fresh
                && pooled.iter().enumerate().all(|(j, (recv_lens, sent, recv, intact))| {
                    *intact
                        && sent == recv
                        && recv_lens.iter().enumerate().all(|(i, &l)| l == sizes[i][j])
                })
        },
    );
}

#[test]
fn prop_out_of_core_equals_in_memory_across_budgets() {
    // The store subsystem's core invariant: for ANY memory budget —
    // including a few hundred bytes, where every stage spills, the
    // shuffle needs many rounds, and the merges fan in dozens of runs —
    // delayed and classic modes must produce exactly the in-memory
    // (unlimited-budget) result. Every case is another job on one warm
    // RankPool, so this also workouts store state isolation across
    // pooled jobs.
    use blaze_rs::core::{MapReduceJob, ReductionMode};
    use blaze_rs::mpi::RankPool;

    const MAX_RANKS: usize = 4;
    let pool = RankPool::from_config(&ClusterConfig::builder().ranks(MAX_RANKS).build());
    let wc_map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
        for w in line.split_whitespace() {
            emit(w.to_string(), 1);
        }
    };
    for_all(
        "out-of-core == in-memory for delayed+classic over random budgets",
        |r| {
            let lines = vec_of(r, 24, |r| {
                (0..1 + r.below(6))
                    .map(|_| format!("w{}", r.below(16)))
                    .collect::<Vec<_>>()
                    .join(" ")
            });
            let ranks = 1 + r.below(MAX_RANKS as u64) as usize;
            // Budgets from "a few hundred bytes" up through comfortable.
            let budget = 200 + r.below(8_000);
            let mode = if r.below(2) == 0 { ReductionMode::Classic } else { ReductionMode::Delayed };
            (lines, ranks, budget, mode)
        },
        |(lines, ranks, budget, mode)| {
            let tight =
                ClusterConfig::builder().ranks(*ranks).shuffle_buffer_bytes(*budget).build();
            let roomy =
                ClusterConfig::builder().ranks(*ranks).shuffle_buffer_bytes(u64::MAX).build();
            let run = |cluster: &ClusterConfig| {
                MapReduceJob::new(cluster, lines)
                    .with_mode(*mode)
                    .with_pool(&pool)
                    .run_monoid(wc_map, |a: u64, b: u64| a + b)
                    .unwrap()
                    .result
            };
            let truth = blaze_rs::apps::wordcount::count_serial(lines);
            let out_of_core = run(&tight);
            out_of_core == run(&roomy) && out_of_core == truth
        },
    );
}

#[test]
fn prop_classic_combiner_never_changes_the_result() {
    // Hadoop's combiner contract as a property: folding equal-key values
    // at run-write/merge time must be invisible in the output, for any
    // budget and width — only JobStats bytes may differ.
    use blaze_rs::core::MapReduceJob;
    use blaze_rs::mpi::RankPool;

    let pool = RankPool::from_config(&ClusterConfig::builder().ranks(4).build());
    let wc_map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
        for w in line.split_whitespace() {
            emit(w.to_string(), 1);
        }
    };
    for_all(
        "classic+combiner == classic for random corpora and budgets",
        |r| {
            let lines = vec_of(r, 20, |r| {
                (0..1 + r.below(6))
                    .map(|_| format!("w{}", r.below(8)))
                    .collect::<Vec<_>>()
                    .join(" ")
            });
            (lines, 1 + r.below(4) as usize, 250 + r.below(4_000))
        },
        |(lines, ranks, budget)| {
            let cluster =
                ClusterConfig::builder().ranks(*ranks).shuffle_buffer_bytes(*budget).build();
            let raw = MapReduceJob::new(&cluster, lines)
                .with_pool(&pool)
                .run_classic(wc_map, |_k, vs: &mut dyn Iterator<Item = u64>| vs.sum())
                .unwrap();
            let combined = MapReduceJob::new(&cluster, lines)
                .with_pool(&pool)
                .run_classic_with_combiner(
                    wc_map,
                    |a: &mut u64, b: u64| *a += b,
                    |_k, vs: &mut dyn Iterator<Item = u64>| vs.sum(),
                )
                .unwrap();
            raw.result == combined.result && raw.stats.combined_bytes == 0
        },
    );
}

#[test]
fn prop_checkpoint_roundtrip_restores_onto_any_width() {
    // The ISSUE 6 satellite: write a session's shards into the
    // checkpoint store at width p, restore onto width p' in 1..=16 —
    // the recovered job must hold the exact same key→value multiset,
    // sit at the target width, and carry the right router epoch
    // (unchanged for p == p', bumped once by the recovery resize
    // otherwise). Shrinks on failure by dropping pairs and narrowing
    // the target width, so a regression reports a minimal witness.
    use blaze_rs::cluster::ElasticCluster;
    use blaze_rs::core::IterativeJob;
    use blaze_rs::store::CheckpointStore;
    use blaze_rs::util::prop::for_all_shrink;

    for_all_shrink(
        "checkpoint(p) -> recover(p') keeps the multiset, width, epoch",
        |r| {
            let pairs = vec_of(r, 80, |r| (r.next_u32() >> 8, r.next_u64()));
            (pairs, 1 + r.below(4) as usize, 1 + r.below(16) as usize, r.next_u64())
        },
        |(pairs, p, p2, salt)| {
            let mut candidates: Vec<_> = (0..pairs.len())
                .map(|i| {
                    let mut fewer = pairs.clone();
                    fewer.remove(i);
                    (fewer, *p, *p2, *salt)
                })
                .collect();
            if *p2 > 1 {
                candidates.push((pairs.clone(), *p, 1, *salt));
            }
            candidates
        },
        |(pairs, p, p2, salt)| {
            let want: HashMap<u32, u64> = pairs.iter().copied().collect();
            let src = ElasticCluster::new(ClusterConfig::builder().ranks(*p).build());
            let mut job: IterativeJob<u32, u64> = IterativeJob::load(&src, *salt, want.clone());
            let store: CheckpointStore<u32, u64> = CheckpointStore::new();
            job.checkpoint_now(&store).unwrap();

            let dst = ElasticCluster::new(ClusterConfig::builder().ranks(*p2).build());
            let back: IterativeJob<u32, u64> =
                IterativeJob::recover_from(&dst, &store).unwrap().expect("snapshot present");
            let r = back.recovery().expect("recovery stats recorded").clone();
            let mut got: HashMap<u32, u64> = HashMap::new();
            let disjoint =
                back.into_states().into_iter().all(|(k, v)| got.insert(k, v).is_none());
            disjoint
                && got == want
                && r.items == want.len() as u64
                && (r.from_ranks, r.to_ranks) == (*p, *p2)
                && r.epoch == u64::from(p != p2)
        },
    );
}

/// A reader that hands back the stream in pseudo-random slivers — the
/// adversarial-chunking harness for the transport frame codec (a TCP
/// `read` may return any number of bytes at any boundary).
struct ChunkedReader {
    data: Vec<u8>,
    pos: usize,
    rng: Rng,
}

impl std::io::Read for ChunkedReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos == self.data.len() {
            return Ok(0);
        }
        let left = self.data.len() - self.pos;
        let n = (1 + self.rng.below(97) as usize).min(left).min(buf.len());
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

fn random_wire_frame(r: &mut Rng) -> blaze_rs::mpi::wire::WireFrame {
    use blaze_rs::mpi::{Rank, Tag};
    // Cover the edges deliberately: empty payloads, typical shuffle
    // pairs, and bodies larger than the store's 16 KiB block cap.
    let len = match r.below(3) {
        0 => 0,
        1 => size(r, 700),
        _ => (16 << 10) + 1 + size(r, 112 << 10),
    };
    blaze_rs::mpi::wire::WireFrame {
        dst: Rank(r.below(16) as usize),
        src: Rank(r.below(16) as usize),
        tag: Tag::user(r.below(1 << 20) as u32),
        epoch: r.below(1 << 20),
        clock_ns: r.next_u64() >> 16,
        payload: (0..len).map(|_| r.next_u64() as u8).collect(),
    }
}

#[test]
fn prop_wire_frames_roundtrip_under_adversarial_chunked_reads() {
    use blaze_rs::mpi::wire::{encode_frame, frame_dst, FrameReader};
    for_all(
        "wire frames survive any read chunking; clean EOF at the boundary",
        |r| {
            let frames: Vec<_> = (0..1 + r.below(3)).map(|_| random_wire_frame(r)).collect();
            (frames, r.next_u64())
        },
        |(frames, chunk_seed)| {
            let mut stream = Vec::new();
            for f in frames {
                let encoded = encode_frame(f);
                // The relay's routing peek must agree with a full decode.
                if frame_dst(&encoded[4..]).unwrap() != f.dst.0 {
                    return false;
                }
                stream.extend_from_slice(&encoded);
            }
            let mut reader = FrameReader::new(ChunkedReader {
                data: stream,
                pos: 0,
                rng: Rng::with_stream(*chunk_seed, 0x51),
            });
            for want in frames {
                match reader.read_frame() {
                    Ok(Some(got)) if got == *want => {}
                    _ => return false,
                }
            }
            matches!(reader.read_frame(), Ok(None))
        },
    );
}

#[test]
fn prop_torn_wire_frames_error_never_truncate_silently() {
    use blaze_rs::mpi::wire::{encode_frame, FrameReader};
    for_all(
        "a mid-frame cut is an error, frames before the cut still decode",
        |r| {
            let frames: Vec<_> = (0..1 + r.below(3)).map(|_| random_wire_frame(r)).collect();
            let lens: Vec<usize> = frames.iter().map(|f| encode_frame(f).len()).collect();
            let total: usize = lens.iter().sum();
            // A cut strictly inside the stream, nudged off frame
            // boundaries (a boundary cut is a *clean* EOF by design).
            let mut cut = 1 + r.below(total as u64 - 1) as usize;
            let mut boundary = 0;
            for len in &lens {
                boundary += len;
                if cut == boundary {
                    cut += 1;
                    break;
                }
            }
            (frames, cut)
        },
        |(frames, cut)| {
            let mut stream = Vec::new();
            for f in frames {
                stream.extend_from_slice(&encode_frame(f));
            }
            stream.truncate(*cut);
            let mut reader = FrameReader::new(&stream[..]);
            // Whole frames before the cut decode intact...
            let mut end = 0;
            for f in frames {
                end += encode_frame(f).len();
                if end > *cut {
                    break;
                }
                match reader.read_frame() {
                    Ok(Some(got)) if got == *f => {}
                    _ => return false,
                }
            }
            // ...and the torn tail is a loud error, never Ok(None).
            reader.read_frame().is_err()
        },
    );
}

#[test]
fn prop_varint_size_monotone() {
    use blaze_rs::serial::Encoder;
    for_all(
        "varint length is non-decreasing in value",
        |r| {
            let a = r.next_u64();
            let b = r.next_u64();
            (a.min(b), a.max(b))
        },
        |(small, large)| {
            let len = |v: u64| {
                let mut e = Encoder::new();
                e.put_varint(v);
                e.len()
            };
            len(*small) <= len(*large)
        },
    );
}

#[test]
fn prop_coscheduled_jobs_never_overlap_rank_subsets() {
    // The ISSUE 9 satellite: random job widths/durations thrown at a
    // 16-rank pool through the concurrent scheduler. Invariants, from
    // the admission log: every job completes; every reservation is a
    // strictly-ascending in-range subset of exactly `width` ranks; and
    // any two jobs that overlapped in time sat on **disjoint** subsets.
    // Shrinks toward fewer jobs, then toward narrowing one job to
    // width 1, so a regression reports a minimal witness.
    use blaze_rs::core::{Scheduler, SchedulerConfig};
    use blaze_rs::mpi::RankPool;
    use blaze_rs::util::prop::for_all_shrink;

    const POOL: usize = 16;
    for_all_shrink(
        "co-scheduled jobs reserve disjoint subsets of a 16-rank pool",
        |r| {
            vec_of(r, 10, |r| {
                (1 + r.below(POOL as u64) as usize, r.below(3))
            })
        },
        |jobs| {
            let mut cands: Vec<Vec<(usize, u64)>> = (0..jobs.len())
                .map(|i| {
                    let mut fewer = jobs.clone();
                    fewer.remove(i);
                    fewer
                })
                .collect();
            if let Some(i) = jobs.iter().position(|(w, _)| *w > 1) {
                let mut narrower = jobs.clone();
                narrower[i].0 = 1;
                cands.push(narrower);
            }
            cands
        },
        |jobs| {
            let sched = Scheduler::with_config(
                RankPool::local(POOL),
                SchedulerConfig { quantum: 4, max_queue: 64, starvation_rounds: 3 },
            );
            let handles: Vec<_> = jobs
                .iter()
                .enumerate()
                .map(|(i, (width, sleep_ms))| {
                    let sleep_ms = *sleep_ms;
                    sched
                        .submit(&format!("t{}", i % 3), *width, move |ctx| {
                            ctx.run_spmd(|_c| {
                                std::thread::sleep(std::time::Duration::from_millis(sleep_ms))
                            })?;
                            Ok(())
                        })
                        .unwrap()
                })
                .collect();
            let all_ok = handles.into_iter().all(|h| h.wait().result.is_ok());
            let events = sched.events();
            all_ok
                && events.len() == jobs.len()
                && events.iter().all(|e| {
                    e.completed_at.is_some()
                        && e.ranks.len() == e.width
                        && (1..=POOL).contains(&e.width)
                        && e.ranks.iter().all(|&r| r < POOL)
                        && e.ranks.windows(2).all(|w| w[0] < w[1])
                })
                && events.iter().enumerate().all(|(i, a)| {
                    events.iter().skip(i + 1).all(|b| {
                        !a.overlaps(b) || a.ranks.iter().all(|r| !b.ranks.contains(r))
                    })
                })
        },
    );
}

#[test]
fn prop_random_dataflow_plans_match_serial_reference() {
    // The ISSUE 10 satellite: random operator chains over random inputs
    // through `core::dataflow` must produce exactly the rows a serial
    // interpreter of the same plan produces, at any width — fusion,
    // partitioning inference, and shuffle placement are invisible in
    // the result. Shrinks toward shorter plans, smaller inputs, and
    // width 1, so a regression reports a minimal witness.
    use blaze_rs::core::Stage;
    use blaze_rs::util::prop::for_all_shrink;

    // Op codes 0..6: map, filter, flat_map, map_values, reduce_by_key,
    // sort — the fixed closures below are the single source of truth
    // for both the dataflow plan and the serial interpreter.
    fn apply_plan(rows: &[(u32, u64)], ops: &[u64]) -> Stage<u32, u64> {
        let mut s = Stage::from_vec(rows.to_vec());
        for &op in ops {
            s = match op {
                0 => s.map(|k, v| (k.wrapping_mul(31) % 64, v ^ 0x5A)),
                1 => s.filter(|k, _v| k % 3 != 0),
                2 => s.flat_map(|k, v, emit| {
                    emit(k, v);
                    if v % 2 == 0 {
                        emit((k + 1) % 64, v / 2);
                    }
                }),
                3 => s.map_values(|v| v.wrapping_mul(3).wrapping_add(1)),
                4 => s.reduce_by_key(u64::wrapping_add),
                _ => s.sort(),
            };
        }
        s
    }
    fn apply_serial(rows: &[(u32, u64)], ops: &[u64]) -> Vec<(u32, u64)> {
        let mut rows = rows.to_vec();
        for &op in ops {
            rows = match op {
                0 => rows.into_iter().map(|(k, v)| (k.wrapping_mul(31) % 64, v ^ 0x5A)).collect(),
                1 => rows.into_iter().filter(|(k, _v)| k % 3 != 0).collect(),
                2 => {
                    let mut out = Vec::new();
                    for (k, v) in rows {
                        out.push((k, v));
                        if v % 2 == 0 {
                            out.push(((k + 1) % 64, v / 2));
                        }
                    }
                    out
                }
                3 => rows
                    .into_iter()
                    .map(|(k, v)| (k, v.wrapping_mul(3).wrapping_add(1)))
                    .collect(),
                4 => {
                    let mut acc: std::collections::BTreeMap<u32, u64> =
                        std::collections::BTreeMap::new();
                    for (k, v) in rows {
                        let e = acc.entry(k).or_insert(0);
                        *e = e.wrapping_add(v);
                    }
                    acc.into_iter().collect()
                }
                // sort only changes physical layout, never the multiset.
                _ => rows,
            };
        }
        rows.sort();
        rows
    }
    for_all_shrink(
        "random dataflow plan == serial interpreter of the same ops",
        |r| {
            let rows = vec_of(r, 40, |r| (r.below(64) as u32, r.next_u64() >> 32));
            let ops = vec_of(r, 6, |r| r.below(6));
            (rows, ops, 1 + r.below(4) as usize)
        },
        |(rows, ops, ranks)| {
            let mut cands = Vec::new();
            for i in 0..ops.len() {
                let mut fewer = ops.clone();
                fewer.remove(i);
                cands.push((rows.clone(), fewer, *ranks));
            }
            if rows.len() > 1 {
                cands.push((rows[..rows.len() / 2].to_vec(), ops.clone(), *ranks));
            }
            if *ranks > 1 {
                cands.push((rows.clone(), ops.clone(), 1));
            }
            cands
        },
        |(rows, ops, ranks)| {
            let cluster = ClusterConfig::builder().ranks(*ranks).seed(11).build();
            let plan = apply_plan(rows, ops);
            // Plan-shape sanity rides along: a single-input chain never
            // needs more than one shuffle per wide op.
            let wide = ops.iter().filter(|&&op| op >= 4).count();
            if plan.explain().total_shuffles() > wide {
                return false;
            }
            let out = plan.collect(&cluster).unwrap();
            let mut got = out.rows;
            got.sort();
            got == apply_serial(rows, ops)
        },
    );
}

#[test]
fn prop_stable_hash_no_collision_burst() {
    // Not a collision-freeness claim — just that random key sets of 100
    // don't collide into <90 distinct hashes (would indicate brokenness).
    for_all(
        "hash spreads random keys",
        |r| vec_of(r, 100, |r| r.next_u64()),
        |keys| {
            let s = blaze_rs::util::hash::SeededState::new(7);
            let mut hs: Vec<u64> = keys.iter().map(|k| s.hash_one(k)).collect();
            hs.sort_unstable();
            hs.dedup();
            hs.len() + 10 >= keys.len().min(100)
        },
    );
}
