//! The collective-equivalence suite (ISSUE 4 acceptance): Star, Tree and
//! Hierarchical collectives must produce **byte-identical** results for
//! bcast / gather / allgather / allreduce / alltoallv at randomized
//! widths (1..=16), skewed payload sizes, and subset-width jobs on warm
//! `RankPool`s — every property case is another job on the same warm
//! threads, so this also workouts collective-tag realignment across
//! algorithms. Plus the traffic-shape assertions: a tree allreduce
//! touches the root `O(log P)` times where the star touches it `O(P)`
//! times, and hierarchical alltoallv coalesces cross-node messages to
//! one bundle per (rank, remote node).

use blaze_rs::cluster::NetworkModel;
use blaze_rs::mpi::{CollectiveAlgo, Rank, RankPool, Topology, Universe};
use blaze_rs::util::prop::{for_all, size, vec_of};
use blaze_rs::util::rng::Rng;

/// 4 nodes x 4 slots: wide enough for real trees, multi-rank nodes for
/// the hierarchical (node-leader) paths.
const POOL_RANKS: usize = 16;

fn pool(algo: CollectiveAlgo) -> RankPool {
    RankPool::new(
        Universe::new(Topology::block(4, 4), NetworkModel::free()).with_collective_algo(algo),
    )
}

/// One warm pool per algorithm, shared by every case of a property.
fn pools() -> Vec<(CollectiveAlgo, RankPool)> {
    CollectiveAlgo::ALL.iter().map(|a| (*a, pool(*a))).collect()
}

/// A skewed payload: log-uniform length up to `max` random bytes.
fn payload(r: &mut Rng, max: usize) -> Vec<u8> {
    vec_of(r, max, |r| r.next_u64() as u8)
}

fn ceil_log2(n: usize) -> u64 {
    if n <= 1 {
        0
    } else {
        64 - (n as u64 - 1).leading_zeros() as u64
    }
}

#[test]
fn prop_bcast_identical_across_algos() {
    let pools = pools();
    for_all(
        "bcast: star == tree == hierarchical",
        |r| {
            let width = 1 + r.below(POOL_RANKS as u64) as usize;
            let root = r.below(width as u64) as usize;
            (width, root, payload(r, 2_000))
        },
        |(width, root, data)| {
            let outs: Vec<Vec<Vec<u8>>> = pools
                .iter()
                .map(|(_, p)| {
                    p.run_on(*width, |c| {
                        let v = if c.rank().0 == *root { data.clone() } else { Vec::new() };
                        c.bcast(Rank(*root), v).unwrap()
                    })
                })
                .collect();
            outs[1] == outs[0]
                && outs[2] == outs[0]
                && outs[0].iter().all(|b| b == data)
        },
    );
}

#[test]
fn prop_gather_identical_across_algos() {
    let pools = pools();
    for_all(
        "gather: star == tree == hierarchical, rank order at any root",
        |r| {
            let width = 1 + r.below(POOL_RANKS as u64) as usize;
            let root = r.below(width as u64) as usize;
            let per_rank: Vec<Vec<u8>> = (0..width).map(|_| payload(r, 600)).collect();
            (width, root, per_rank)
        },
        |(width, root, per_rank)| {
            let outs: Vec<Vec<Option<Vec<Vec<u8>>>>> = pools
                .iter()
                .map(|(_, p)| {
                    p.run_on(*width, |c| {
                        c.gather(Rank(*root), per_rank[c.rank().0].clone()).unwrap()
                    })
                })
                .collect();
            outs[1] == outs[0]
                && outs[2] == outs[0]
                && outs[0][*root].as_ref() == Some(per_rank)
                && outs[0].iter().enumerate().all(|(i, o)| (i == *root) != o.is_none())
        },
    );
}

#[test]
fn prop_allgather_identical_across_algos() {
    let pools = pools();
    for_all(
        "allgather: star == tree == hierarchical, everywhere",
        |r| {
            let width = 1 + r.below(POOL_RANKS as u64) as usize;
            let per_rank: Vec<Vec<u8>> = (0..width).map(|_| payload(r, 600)).collect();
            (width, per_rank)
        },
        |(width, per_rank)| {
            let outs: Vec<Vec<Vec<Vec<u8>>>> = pools
                .iter()
                .map(|(_, p)| {
                    p.run_on(*width, |c| {
                        c.allgather(per_rank[c.rank().0].clone()).unwrap()
                    })
                })
                .collect();
            outs[1] == outs[0]
                && outs[2] == outs[0]
                && outs[0].iter().all(|got| got == per_rank)
        },
    );
}

#[test]
fn prop_allreduce_identical_across_algos_even_non_commutative() {
    // String concatenation is associative but NOT commutative: identical
    // results across algorithms pin the rank-order root fold (the
    // bit-identity contract that keeps float reductions stable too).
    let pools = pools();
    for_all(
        "allreduce: star == tree == hierarchical, rank-order fold",
        |r| {
            let width = 1 + r.below(POOL_RANKS as u64) as usize;
            let words: Vec<String> =
                (0..width).map(|i| format!("r{i}:{};", size(r, 500))).collect();
            (width, words)
        },
        |(width, words)| {
            let expect: String = words.concat();
            let sums: u64 = (0..*width as u64).sum();
            let outs: Vec<Vec<(String, u64)>> = pools
                .iter()
                .map(|(_, p)| {
                    p.run_on(*width, |c| {
                        let cat = c
                            .allreduce(words[c.rank().0].clone(), |a, b| a + &b)
                            .unwrap();
                        let sum = c.allreduce_sum_u64(c.rank().0 as u64).unwrap();
                        (cat, sum)
                    })
                })
                .collect();
            outs[1] == outs[0]
                && outs[2] == outs[0]
                && outs[0].iter().all(|(cat, sum)| cat == &expect && *sum == sums)
        },
    );
}

#[test]
fn prop_alltoallv_identical_across_algos() {
    let pools = pools();
    for_all(
        "alltoallv: star == tree == hierarchical, exact transpose",
        |r| {
            let width = 1 + r.below(POOL_RANKS as u64) as usize;
            // Skewed (src, dst) payload matrix, many empty cells.
            let matrix: Vec<Vec<Vec<u8>>> = (0..width)
                .map(|_| (0..width).map(|_| payload(r, 300)).collect())
                .collect();
            (width, matrix)
        },
        |(width, matrix)| {
            let outs: Vec<Vec<Vec<Vec<u8>>>> = pools
                .iter()
                .map(|(_, p)| {
                    p.run_on(*width, |c| {
                        c.alltoallv(matrix[c.rank().0].clone()).unwrap()
                    })
                })
                .collect();
            // received[dst][src] must equal matrix[src][dst], identically
            // under every algorithm.
            outs[1] == outs[0]
                && outs[2] == outs[0]
                && outs[0].iter().enumerate().all(|(dst, row)| {
                    row.iter().enumerate().all(|(src, buf)| buf == &matrix[src][dst])
                })
        },
    );
}

#[test]
fn prop_mixed_collective_sequences_stay_aligned_on_warm_pools() {
    // A whole SPMD program per case — interleaved collectives at a random
    // width, repeated on the same warm pools. Any tag misalignment across
    // algorithms or leftover state between pooled jobs deadlocks or
    // diverges here.
    let pools = pools();
    for_all(
        "mixed sequence: identical transcript across algos",
        |r| {
            let width = 1 + r.below(POOL_RANKS as u64) as usize;
            let rounds = 1 + r.below(4);
            (width, rounds, payload(r, 200))
        },
        |(width, rounds, data)| {
            let outs: Vec<Vec<(u64, Vec<u8>, u64)>> = pools
                .iter()
                .map(|(_, p)| {
                    p.run_on(*width, |c| {
                        let mut acc = 0u64;
                        let mut blob = Vec::new();
                        for round in 0..*rounds {
                            acc = acc.wrapping_add(
                                c.allreduce_sum_u64(c.rank().0 as u64 + round).unwrap(),
                            );
                            let v = if c.is_root() { data.clone() } else { Vec::new() };
                            blob = c.bcast(Rank::ROOT, v).unwrap();
                            c.barrier().unwrap();
                        }
                        let total = c.exscan_sum(1).unwrap();
                        (acc, blob, total)
                    })
                })
                .collect();
            outs[1] == outs[0] && outs[2] == outs[0]
        },
    );
}

#[test]
fn tree_allreduce_root_messages_are_log_p_at_every_width() {
    // The O(log P) traffic assertion, swept across widths on warm pools:
    // the tree root sends/receives exactly 2*ceil(log2 P) messages per
    // allreduce; the star root pays 2*(P-1).
    let star = pool(CollectiveAlgo::Star);
    let tree = pool(CollectiveAlgo::Tree);
    for width in [2usize, 3, 5, 8, 13, 16] {
        let count = |p: &RankPool| {
            p.run_on(width, |c| {
                c.allreduce_sum_u64(1).unwrap();
                c.sent_messages() + c.received_messages()
            })[0]
        };
        let star_msgs = count(&star);
        let tree_msgs = count(&tree);
        assert_eq!(star_msgs, 2 * (width as u64 - 1), "star root at width {width}");
        assert_eq!(tree_msgs, 2 * ceil_log2(width), "tree root at width {width}");
        if width >= 4 {
            assert!(tree_msgs < star_msgs, "tree must beat star at width {width}");
        }
    }
}

#[test]
fn hierarchical_alltoallv_coalesces_cross_node_traffic() {
    // Surfaced through the pool's per-job traffic delta (what JobStats
    // reads): full-width and subset-width shuffles cross node boundaries
    // in one bundle per (rank, remote node) under Hierarchical.
    let star = pool(CollectiveAlgo::Star);
    let hier = pool(CollectiveAlgo::Hierarchical);
    for width in [16usize, 6] {
        let run = |p: &RankPool| {
            p.run_job(width, |c| {
                let bufs: Vec<Vec<u8>> =
                    (0..c.size()).map(|j| vec![c.rank().0 as u8; j + 1]).collect();
                let got = c.alltoallv(bufs).unwrap();
                let ok = got
                    .iter()
                    .enumerate()
                    .all(|(src, b)| b.len() == c.rank().0 + 1 && b.iter().all(|&x| x == src as u8));
                assert!(ok, "transpose intact");
            })
        };
        let star_remote = run(&star).traffic.remote_messages;
        let hier_remote = run(&hier).traffic.remote_messages;
        assert!(
            hier_remote < star_remote,
            "width {width}: hier {hier_remote} vs star {star_remote} remote messages"
        );
        if width == 16 {
            // 16 ranks x 12 remote peers pairwise vs 16 ranks x 3 bundles.
            assert_eq!(star_remote, 16 * 12);
            assert_eq!(hier_remote, 16 * 3);
        }
    }
}

#[test]
fn hierarchical_leader_staging_is_charged_to_peak_accounting() {
    // PR 4 follow-up: the node leader transiently buffers its node's
    // whole inbound round under Hierarchical alltoallv (the
    // locality-for-memory trade). With a tracker attached, that staging
    // must show up in the peak — and only on leaders, and only
    // transiently (current returns to zero).
    use blaze_rs::metrics::PeakTracker;

    let exchange = |c: &blaze_rs::mpi::Communicator| {
        let tracker = PeakTracker::new();
        c.set_memory_tracker(Some(tracker.clone()));
        let bufs: Vec<Vec<u8>> = (0..c.size()).map(|_| vec![0xAB; 1024]).collect();
        let got = c.alltoallv(bufs).unwrap();
        c.set_memory_tracker(None);
        assert!(got.iter().all(|b| b.len() == 1024), "transpose intact");
        (tracker.peak_bytes(), tracker.current_bytes())
    };

    // Width 16 on block(4,4): leaders are ranks 0, 4, 8, 12; each
    // stages 12 remote bundles of 4 x 1 KiB pairs (plus framing).
    let hier = pool(CollectiveAlgo::Hierarchical);
    for (rank, (peak, current)) in hier.run(exchange).into_iter().enumerate() {
        assert_eq!(current, 0, "rank {rank}: staging must be freed after the scatter");
        if rank % 4 == 0 {
            assert!(peak >= 12 * 1024, "leader {rank} staged only {peak} bytes");
        } else {
            assert_eq!(peak, 0, "non-leader {rank} must stage nothing");
        }
    }

    // Pairwise exchanges (Star/Tree) stage nothing anywhere.
    let star = pool(CollectiveAlgo::Star);
    for (rank, (peak, current)) in star.run(exchange).into_iter().enumerate() {
        assert_eq!((peak, current), (0, 0), "rank {rank}: pairwise alltoallv must not stage");
    }
}

#[test]
fn equivalence_holds_across_engine_jobs_on_warm_pools() {
    // End-to-end: the same wordcount on one warm pool per algorithm (the
    // pools model the SAME cluster shape apart from the algo) must give
    // identical results — collectives are invisible to the job output.
    use blaze_rs::cluster::ClusterConfig;
    use blaze_rs::core::{MapReduceJob, ReductionMode};

    let lines: Vec<String> =
        (0..240).map(|i| format!("w{} w{} shared", i % 17, i % 5)).collect();
    let wc_map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
        for w in line.split_whitespace() {
            emit(w.to_string(), 1);
        }
    };
    let mut baseline = None;
    for algo in CollectiveAlgo::ALL {
        let cluster = ClusterConfig::builder()
            .nodes(2)
            .slots_per_node(2)
            .seed(11)
            .collective_algo(algo)
            .build();
        let pool = RankPool::from_config(&cluster);
        for mode in ReductionMode::ALL {
            let out = MapReduceJob::new(&cluster, &lines)
                .with_mode(mode)
                .with_pool(&pool)
                .run_monoid(wc_map, |a: u64, b| a + b)
                .unwrap();
            match &baseline {
                None => baseline = Some(out.result),
                Some(truth) => assert_eq!(&out.result, truth, "{algo}/{mode} diverged"),
            }
        }
    }
}
