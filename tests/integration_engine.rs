//! End-to-end integration tests over the public API: engines x
//! deployments x scheduling, correctness vs serial truth, fault
//! injection, spilling and skew behaviour.

use std::collections::HashMap;

use blaze_rs::apps::{matmul, pi, wordcount};
use blaze_rs::cluster::{ClusterConfig, DeploymentKind};
use blaze_rs::core::{TaskFault, JobConfig, MapReduceJob, ReductionMode, Scheduling};
use blaze_rs::mpi::Rank;

fn wc_map(line: &String, emit: &mut dyn FnMut(String, u64)) {
    for w in line.split_whitespace() {
        emit(w.to_string(), 1);
    }
}

#[test]
fn wordcount_correct_across_deployments_and_modes() {
    let corpus = wordcount::generate_corpus(200, 6, 40, 11);
    let truth = wordcount::count_serial(&corpus);
    for kind in DeploymentKind::ALL {
        for mode in ReductionMode::ALL {
            let cluster = ClusterConfig::builder()
                .deployment(kind)
                .nodes(2)
                .slots_per_node(2)
                .seed(11)
                .build();
            let got = wordcount::run(&cluster, &corpus, mode).unwrap();
            assert_eq!(got.result, truth, "kind={kind} mode={mode}");
        }
    }
}

#[test]
fn deployment_changes_modeled_time_not_result() {
    // Large enough that thread-CPU metering jitter (ms-scale on a
    // time-shared host) can't invert the 8x RPi compute factor.
    let corpus = wordcount::generate_corpus(8_000, 8, 60, 12);
    let local = wordcount::run(
        &ClusterConfig::builder().deployment(DeploymentKind::Local).ranks(4).build(),
        &corpus,
        ReductionMode::Eager,
    )
    .unwrap();
    let rpi = wordcount::run(
        &ClusterConfig::builder().deployment(DeploymentKind::BareMetal).ranks(4).build(),
        &corpus,
        ReductionMode::Eager,
    )
    .unwrap();
    assert_eq!(local.result, rpi.result);
    // RPi: 8x compute scaling + real network charges.
    assert!(
        rpi.stats.modeled_ms > 2.0 * local.stats.modeled_ms,
        "rpi {} vs local {}",
        rpi.stats.modeled_ms,
        local.stats.modeled_ms
    );
    assert!(rpi.stats.net_ms > local.stats.net_ms);
}

#[test]
fn fault_injection_every_victim_rank() {
    let corpus = wordcount::generate_corpus(120, 5, 30, 13);
    let truth = wordcount::count_serial(&corpus);
    let cluster = ClusterConfig::builder().ranks(4).seed(13).build();
    for victim in 0..4 {
        let got = MapReduceJob::new(&cluster, &corpus)
            .with_fault(TaskFault { rank: Rank(victim), after_tasks: 1 })
            .run_eager(wc_map, |a, b| *a += b)
            .unwrap();
        assert_eq!(got.result, truth, "victim rank {victim}");
    }
}

#[test]
fn immediate_death_before_any_task() {
    let corpus = wordcount::generate_corpus(60, 5, 20, 14);
    let truth = wordcount::count_serial(&corpus);
    let cluster = ClusterConfig::builder().ranks(3).build();
    let got = MapReduceJob::new(&cluster, &corpus)
        .with_fault(TaskFault { rank: Rank(1), after_tasks: 0 })
        .run_eager(wc_map, |a, b| *a += b)
        .unwrap();
    assert_eq!(got.result, truth);
}

#[test]
fn spill_path_exercised_under_tight_memory() {
    let corpus = wordcount::generate_corpus(2_000, 10, 5_000, 15);
    let truth = wordcount::count_serial(&corpus);
    let cluster = ClusterConfig::builder()
        .ranks(2)
        .shuffle_buffer_bytes(16 * 1024) // tiny budget: force out-of-core
        .build();
    let got = MapReduceJob::new(&cluster, &corpus)
        .with_mode(ReductionMode::Classic)
        .run_classic(wc_map, |_k, vs: &mut dyn Iterator<Item = u64>| vs.sum())
        .unwrap();
    assert_eq!(got.result, truth);
    assert!(got.stats.spilled_bytes > 0, "expected disk spill");
}

#[test]
fn skewed_input_dynamic_beats_static_on_modeled_time() {
    // One enormous line + many short ones: with static round-robin, one
    // rank eats the big line and stragglers dominate; dynamic spreads the
    // remaining chunks — the §I data-skew claim.
    let mut corpus = vec![wordcount::generate_corpus(1, 20_000, 50, 16)[0].clone()];
    corpus.extend(wordcount::generate_corpus(4_000, 2, 50, 17));
    let cluster = ClusterConfig::builder().ranks(4).seed(16).build();
    let mk = |sched| JobConfig { scheduling: sched, tasks_per_rank: 8, ..Default::default() };
    let sta = MapReduceJob::new(&cluster, &corpus)
        .with_config(mk(Scheduling::Static))
        .run_eager(wc_map, |a, b| *a += b)
        .unwrap();
    let dyn_ = MapReduceJob::new(&cluster, &corpus)
        .with_config(mk(Scheduling::Dynamic))
        .run_eager(wc_map, |a, b| *a += b)
        .unwrap();
    assert_eq!(sta.result, dyn_.result);
    // Timing on a time-shared host is too noisy for a strict inequality
    // (thread-CPU jitter is ms-scale when 4 rank threads share one core);
    // both runs must simply complete with sane stats. The skew-mitigation
    // *behaviour* (stragglers re-claimed from the shared table) is
    // asserted deterministically in core::scheduler tests.
    assert!(dyn_.stats.compute_ms > 0.0 && sta.stats.compute_ms > 0.0);
}

#[test]
fn pi_all_paths_agree_and_converge() {
    let cluster = ClusterConfig::builder().ranks(4).build();
    let chunks = pi::make_chunks(400_000, 32, 18);
    let batched = pi::run_eager_batched(&cluster, &chunks).unwrap();
    assert!((batched.result - std::f64::consts::PI).abs() < 0.02);
}

#[test]
fn matmul_larger_instance_all_modes() {
    let a = matmul::Matrix::random(20, 30, 19);
    let b = matmul::Matrix::random(30, 10, 20);
    let truth = a.multiply(&b);
    let cluster = ClusterConfig::builder().nodes(2).slots_per_node(2).build();
    for mode in ReductionMode::ALL {
        let got = matmul::run(&cluster, &a, &b, mode).unwrap();
        assert!(got.result.max_abs_diff(&truth) < 1e-9, "mode {mode}");
    }
}

#[test]
fn results_deterministic_across_runs() {
    let corpus = wordcount::generate_corpus(300, 6, 100, 21);
    let cluster = ClusterConfig::builder().ranks(4).seed(21).build();
    // Dynamic scheduling races task->rank placement, so traffic varies
    // run to run; with Static scheduling the whole run is bit-stable.
    let cfg = blaze_rs::core::JobConfig {
        mode: ReductionMode::Delayed,
        scheduling: Scheduling::Static,
        ..Default::default()
    };
    let a = MapReduceJob::new(&cluster, &corpus)
        .with_config(cfg.clone())
        .run_delayed(wc_map, |_k, vs: &mut dyn Iterator<Item = u64>| vs.sum())
        .unwrap();
    let b = MapReduceJob::new(&cluster, &corpus)
        .with_config(cfg)
        .run_delayed(wc_map, |_k, vs: &mut dyn Iterator<Item = u64>| vs.sum())
        .unwrap();
    assert_eq!(a.result, b.result);
    assert_eq!(a.stats.shuffle_bytes, b.stats.shuffle_bytes);
    assert_eq!(a.stats.messages, b.stats.messages);
}

#[test]
fn stats_accounting_internally_consistent() {
    let corpus = wordcount::generate_corpus(500, 8, 200, 22);
    let cluster = ClusterConfig::builder()
        .deployment(DeploymentKind::Container)
        .nodes(4)
        .slots_per_node(2)
        .build();
    let out = wordcount::run(&cluster, &corpus, ReductionMode::Eager).unwrap();
    let s = &out.stats;
    assert!(s.remote_bytes <= s.shuffle_bytes);
    // Startup reported separately, never folded into job time.
    assert!(s.startup_ms == 1_200.0);
    assert!(s.modeled_ms < s.startup_ms);
    // Slowest rank's clock covers its own parts.
    assert!(s.modeled_ms + 1e-6 >= s.net_ms.min(s.compute_ms));
    assert!(s.modeled_ms + 1e-6 >= s.compute_ms);
}

#[test]
fn merged_result_has_single_ownership() {
    // Engine must never see a key from two ranks (router desync guard).
    let corpus = wordcount::generate_corpus(300, 4, 1000, 23);
    let cluster = ClusterConfig::builder().ranks(8).build();
    let out = wordcount::run(&cluster, &corpus, ReductionMode::Eager).unwrap();
    let total: u64 = out.result.values().sum();
    let truth: u64 = wordcount::count_serial(&corpus).values().sum();
    assert_eq!(total, truth);
}

#[test]
fn dist_containers_compose_with_engine_salt() {
    // Same corpus, different seeds -> same results, different placement.
    let corpus = wordcount::generate_corpus(100, 4, 50, 24);
    let truth = wordcount::count_serial(&corpus);
    let mut shuffle_bytes = Vec::new();
    for seed in [1u64, 2, 3] {
        let cluster = ClusterConfig::builder().ranks(4).seed(seed).build();
        let out = wordcount::run(&cluster, &corpus, ReductionMode::Eager).unwrap();
        assert_eq!(out.result, truth);
        shuffle_bytes.push(out.stats.shuffle_bytes);
    }
    // Placement changed at least once across salts (overwhelmingly likely).
    assert!(shuffle_bytes.windows(2).any(|w| w[0] != w[1]) || shuffle_bytes[0] > 0);
}

#[test]
fn spark_baseline_correct_on_all_workloads() {
    use blaze_rs::baseline::SparkContext;
    let cluster = ClusterConfig::builder().ranks(4).build();
    let corpus = wordcount::generate_corpus(300, 6, 100, 25);
    let (wc, _) = SparkContext::new(&cluster).wordcount(&corpus);
    assert_eq!(wc, wordcount::count_serial(&corpus));

    let chunks = pi::make_chunks(200_000, 16, 25);
    let (pi_est, _) = SparkContext::new(&cluster).pi(&chunks);
    assert!((pi_est - std::f64::consts::PI).abs() < 0.03);
}

#[test]
fn spark_memory_gap_grows_with_input() {
    use blaze_rs::baseline::SparkContext;
    let cluster = ClusterConfig::builder().ranks(4).build();
    let mut ratios = Vec::new();
    for lines in [500usize, 2_000] {
        let corpus = wordcount::generate_corpus(lines, 8, 500, 26);
        let blaze = wordcount::run(&cluster, &corpus, ReductionMode::Eager).unwrap();
        let (_, spark) = SparkContext::new(&cluster).wordcount(&corpus);
        ratios.push(spark.peak_mem_bytes as f64 / blaze.stats.peak_mem_bytes.max(1) as f64);
    }
    assert!(ratios.iter().all(|&r| r > 2.0), "ratios {ratios:?}");
}

#[test]
fn elastic_cluster_rebalances_between_waves() {
    use blaze_rs::cluster::ElasticCluster;
    let corpus = wordcount::generate_corpus(200, 5, 60, 27);
    let truth = wordcount::count_serial(&corpus);
    let mut elastic = ElasticCluster::new(
        ClusterConfig::builder().deployment(DeploymentKind::Container).nodes(2).slots_per_node(1).build(),
    );
    let wave1 = wordcount::run(elastic.config(), &corpus, ReductionMode::Eager).unwrap();
    assert_eq!(wave1.result, truth);
    elastic.grow(2);
    let wave2 = wordcount::run(elastic.config(), &corpus, ReductionMode::Eager).unwrap();
    assert_eq!(wave2.result, truth);
    assert_eq!(elastic.ranks(), 4);
    elastic.shrink(3).unwrap();
    let wave3 = wordcount::run(elastic.config(), &corpus, ReductionMode::Eager).unwrap();
    assert_eq!(wave3.result, truth);
}

#[test]
fn hostfile_driven_topology_runs() {
    use blaze_rs::cluster::NodeSpec;
    use blaze_rs::mpi::{run_ranks, Hostfile, Topology, Universe};
    let hf = Hostfile::parse("rpi0 slots=2\nrpi1 slots=2\n").unwrap();
    let specs = vec![NodeSpec::raspberry_pi(0), NodeSpec::raspberry_pi(1)];
    let topo = Topology::from_hostfile(&hf, &specs).unwrap();
    let net = blaze_rs::cluster::NetworkModel::from_profile(
        &DeploymentKind::BareMetal.profile(),
    );
    let sums = run_ranks(Universe::new(topo, net), |c| {
        c.allreduce_sum_u64(c.rank().0 as u64).unwrap()
    });
    assert_eq!(sums, vec![6, 6, 6, 6]);
}

#[test]
fn delayed_groups_survive_heavy_duplication() {
    // 50k emissions of 8 keys across 4 ranks: group sizes must be exact.
    let items: Vec<u32> = (0..50_000).collect();
    let cluster = ClusterConfig::builder().ranks(4).build();
    let out = MapReduceJob::new(&cluster, &items)
        .run_delayed(
            |&i: &u32, emit: &mut dyn FnMut(u32, u32)| emit(i % 8, 1),
            |_k, vs: &mut dyn Iterator<Item = u32>| vs.count() as u32,
        )
        .unwrap();
    let mut sizes: Vec<u32> = out.result.values().copied().collect();
    sizes.sort_unstable();
    assert_eq!(sizes, vec![6250; 8]);
}

#[test]
fn empty_and_single_item_inputs() {
    let cluster = ClusterConfig::builder().ranks(4).build();
    let empty: Vec<String> = vec![];
    assert!(wordcount::run(&cluster, &empty, ReductionMode::Delayed).unwrap().result.is_empty());
    let one = vec!["solo".to_string()];
    let got = wordcount::run(&cluster, &one, ReductionMode::Classic).unwrap();
    let mut want = HashMap::new();
    want.insert("solo".to_string(), 1);
    assert_eq!(got.result, want);
}
