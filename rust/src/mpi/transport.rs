//! The transport seam beneath [`crate::mpi::Communicator`].
//!
//! Everything above this line — collectives, shuffle, the engines — is
//! written against [`Communicator`]'s send/recv. Everything below it is a
//! [`Transport`]: the substrate that actually moves a [`Message`] from
//! one rank's endpoint to another's. Two substrates exist:
//!
//! - [`MailboxTransport`] — the original in-process wiring: one unbounded
//!   mpsc channel per rank, senders shared by every endpoint.
//! - [`super::tcp`]'s `TcpEndpoint` — length-framed TCP to a spawned
//!   `blaze worker` process per rank; inter-rank bytes cross a real
//!   socket mesh between real OS processes.
//!
//! The contract is byte-identity: a program must produce bit-identical
//! results (and virtual clocks) on every transport. The cross-transport
//! equivalence suite in `tests/integration_transport.rs` pins that.
//!
//! [`Communicator`]: super::Communicator

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::datatypes::{Message, Rank};

/// Point-to-point substrate for one rank's endpoint.
///
/// Semantics every implementation must provide (the collectives and the
/// pool's inter-job reset are built on exactly these):
///
/// - **Eager, unbounded send.** [`Transport::send`] buffers and returns
///   without waiting for a matching receive — MPI's eager protocol at
///   our message sizes. A send may only fail if the destination endpoint
///   is gone (hung up), never because the destination has not posted a
///   receive.
/// - **Blocking, ordered receive.** [`Transport::recv`] blocks for the
///   next message addressed to this rank. Messages from one source
///   arrive in the order they were sent (per-pair FIFO); no ordering is
///   promised across sources. Tag matching and out-of-order buffering
///   live above the seam, in `Communicator`.
/// - **Faithful envelopes.** The delivered [`Message`] carries `src`,
///   `tag`, `epoch` and `clock_ns` bit-exactly as sent — the virtual
///   clock protocol rides the transport, so byte-identity of results
///   *and clocks* across transports depends on it.
/// - **Best-effort drain.** [`Transport::drain`] discards whatever
///   backlog is locally available without blocking. It need not catch
///   messages still in flight; the `Communicator`'s epoch filter (bumped
///   each pooled job) is what makes stragglers harmless.
///
/// Implementations must be `Send` (an endpoint moves to its rank's
/// thread) but are used from exactly one thread at a time, so interior
/// mutability without locking (e.g. `RefCell`) is fine.
pub trait Transport: Send {
    /// Deliver `msg` to rank `dst`'s endpoint. Non-blocking (eager).
    fn send(&self, dst: Rank, msg: Message) -> Result<()>;

    /// Block for the next message addressed to this rank.
    fn recv(&self) -> Result<Message>;

    /// Discard any locally-available backlog (inter-job reset).
    fn drain(&self);
}

/// Which substrate a universe wires its ranks with. Resolution order
/// everywhere the selector is threaded (mirroring
/// [`super::CollectiveAlgo`] and the spill threshold): an explicit
/// choice beats the `BLAZE_TRANSPORT` environment override beats the
/// [`TransportKind::Mailbox`] default.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TransportKind {
    /// In-process mpsc mailboxes — ranks are threads, zero copies cross
    /// a socket. The fast path for tests and single-host runs.
    #[default]
    Mailbox,
    /// Length-framed TCP to spawned `blaze worker` rank processes; every
    /// inter-rank byte crosses a real socket between real OS processes.
    Tcp,
}

impl TransportKind {
    pub const ALL: [TransportKind; 2] = [TransportKind::Mailbox, TransportKind::Tcp];

    /// The `BLAZE_TRANSPORT` override, or the Mailbox default.
    /// Unparseable values are ignored (same forgiveness as the
    /// collective-algo and spill-threshold overrides).
    pub fn from_env_or_default() -> TransportKind {
        let env = std::env::var("BLAZE_TRANSPORT").ok();
        Self::resolve(env.as_deref())
    }

    /// Resolution with the env value injected — tests exercise the
    /// precedence without mutating process-global environment.
    pub(crate) fn resolve(env: Option<&str>) -> TransportKind {
        env.and_then(|s| s.trim().parse().ok()).unwrap_or_default()
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            TransportKind::Mailbox => "mailbox",
            TransportKind::Tcp => "tcp",
        };
        f.write_str(s)
    }
}

impl std::str::FromStr for TransportKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "mailbox" | "mem" | "in-memory" | "inmemory" => Ok(TransportKind::Mailbox),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(anyhow!("unknown transport {other:?}")),
        }
    }
}

/// The original in-process substrate: one unbounded mpsc channel per
/// rank; every endpoint holds the full sender table.
pub struct MailboxTransport {
    senders: Arc<Vec<Sender<Message>>>,
    rx: Receiver<Message>,
}

impl MailboxTransport {
    pub(crate) fn new(senders: Arc<Vec<Sender<Message>>>, rx: Receiver<Message>) -> Self {
        MailboxTransport { senders, rx }
    }
}

impl Transport for MailboxTransport {
    fn send(&self, dst: Rank, msg: Message) -> Result<()> {
        self.senders
            .get(dst.0)
            .ok_or_else(|| anyhow!("send to {dst} outside universe of {}", self.senders.len()))?
            .send(msg)
            .map_err(|_| anyhow!("{dst} has hung up"))
    }

    fn recv(&self) -> Result<Message> {
        self.rx.recv().map_err(|_| anyhow!("universe torn down mid-recv"))
    }

    fn drain(&self) {
        while self.rx.try_recv().is_ok() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parse_display_roundtrip() {
        for kind in TransportKind::ALL {
            assert_eq!(kind.to_string().parse::<TransportKind>().unwrap(), kind);
        }
        assert_eq!("TCP".parse::<TransportKind>().unwrap(), TransportKind::Tcp);
        assert_eq!("mem".parse::<TransportKind>().unwrap(), TransportKind::Mailbox);
        assert!("quic".parse::<TransportKind>().is_err());
    }

    #[test]
    fn transport_resolution_env_beats_default() {
        assert_eq!(TransportKind::resolve(None), TransportKind::Mailbox);
        assert_eq!(TransportKind::resolve(Some("tcp")), TransportKind::Tcp);
        assert_eq!(TransportKind::resolve(Some("bogus")), TransportKind::Mailbox);
    }
}
