//! Rank placement: which node hosts which rank, plus hostfile parsing.
//!
//! The paper's §IV setups all end with "Create Hostfile with all the IP
//! addresses of the slaves. Mpirun [...] along with hostfile each time."
//! [`Hostfile`] parses that format (`host slots=N`, comments with `#`);
//! [`Topology`] is the resolved placement the communicator consults for
//! same-node vs cross-node message costs and compute scaling.

use anyhow::{ensure, Result};

use crate::cluster::{ClusterConfig, NodeSpec};

use super::datatypes::Rank;

/// One hostfile line: `hostname slots=N`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostfileEntry {
    pub host: String,
    pub slots: usize,
}

/// Parsed MPI hostfile.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Hostfile {
    pub entries: Vec<HostfileEntry>,
}

impl Hostfile {
    /// Parse the OpenMPI hostfile dialect: one host per line, optional
    /// `slots=N` (default 1), `#` comments, blank lines ignored.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let host = parts.next().unwrap().to_string();
            let mut slots = 1usize;
            for opt in parts {
                if let Some(v) = opt.strip_prefix("slots=") {
                    slots = v.parse().map_err(|e| {
                        anyhow::anyhow!("hostfile line {}: bad slots {v:?}: {e}", lineno + 1)
                    })?;
                    ensure!(slots > 0, "hostfile line {}: slots=0", lineno + 1);
                } else {
                    anyhow::bail!("hostfile line {}: unknown option {opt:?}", lineno + 1);
                }
            }
            entries.push(HostfileEntry { host, slots });
        }
        ensure!(!entries.is_empty(), "hostfile has no hosts");
        Ok(Self { entries })
    }

    pub fn total_slots(&self) -> usize {
        self.entries.iter().map(|e| e.slots).sum()
    }
}

/// Resolved rank -> node placement.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    /// node index per rank (block placement).
    node_of_rank: Vec<usize>,
    /// compute-time multiplier per rank (from the node's profile).
    compute_scale: Vec<f64>,
    hostnames: Vec<String>,
}

impl Topology {
    /// All ranks on one Local node.
    pub fn single_node(ranks: usize) -> Self {
        Self {
            node_of_rank: vec![0; ranks],
            compute_scale: vec![1.0; ranks],
            hostnames: vec!["local0".into()],
        }
    }

    /// `nodes` x `slots` block placement with unit compute scale.
    pub fn block(nodes: usize, slots: usize) -> Self {
        let mut node_of_rank = Vec::with_capacity(nodes * slots);
        for node in 0..nodes {
            node_of_rank.extend(std::iter::repeat(node).take(slots));
        }
        Self {
            node_of_rank,
            compute_scale: vec![1.0; nodes * slots],
            hostnames: (0..nodes).map(|i| format!("node{i}")).collect(),
        }
    }

    /// Placement from a [`ClusterConfig`] (profile-scaled compute).
    pub fn from_config(cfg: &ClusterConfig) -> Self {
        let specs = cfg.node_specs();
        let mut node_of_rank = Vec::with_capacity(cfg.ranks());
        let mut compute_scale = Vec::with_capacity(cfg.ranks());
        for rank in 0..cfg.ranks() {
            let node = cfg.node_of_rank(rank);
            node_of_rank.push(node);
            compute_scale.push(specs[node].profile.effective_compute_scale());
        }
        Self {
            node_of_rank,
            compute_scale,
            hostnames: specs.iter().map(|s| s.hostname.clone()).collect(),
        }
    }

    /// Placement from a hostfile + per-node specs.
    pub fn from_hostfile(hf: &Hostfile, specs: &[NodeSpec]) -> Result<Self> {
        ensure!(
            hf.entries.len() == specs.len(),
            "hostfile has {} hosts but {} node specs supplied",
            hf.entries.len(),
            specs.len()
        );
        let mut node_of_rank = Vec::new();
        let mut compute_scale = Vec::new();
        for (node, entry) in hf.entries.iter().enumerate() {
            for _ in 0..entry.slots {
                node_of_rank.push(node);
                compute_scale.push(specs[node].profile.effective_compute_scale());
            }
        }
        Ok(Self {
            node_of_rank,
            compute_scale,
            hostnames: hf.entries.iter().map(|e| e.host.clone()).collect(),
        })
    }

    pub fn ranks(&self) -> usize {
        self.node_of_rank.len()
    }

    pub fn nodes(&self) -> usize {
        self.hostnames.len()
    }

    pub fn node_of(&self, rank: Rank) -> usize {
        self.node_of_rank[rank.0]
    }

    pub fn hostname_of(&self, rank: Rank) -> &str {
        &self.hostnames[self.node_of(rank)]
    }

    pub fn same_node(&self, a: Rank, b: Rank) -> bool {
        self.node_of_rank[a.0] == self.node_of_rank[b.0]
    }

    pub fn compute_scale(&self, rank: Rank) -> f64 {
        self.compute_scale[rank.0]
    }

    /// Do the first `n` ranks of `self` and `other` share placement and
    /// compute scaling? This is what lets a warm [`crate::mpi::RankPool`]
    /// stand in for a fresh, narrower universe: a job on ranks `0..n`
    /// only ever consults those prefixes of the cost model.
    pub fn agrees_on_prefix(&self, other: &Topology, n: usize) -> bool {
        self.node_of_rank.len() >= n
            && other.node_of_rank.len() >= n
            && self.node_of_rank[..n] == other.node_of_rank[..n]
            && self.compute_scale[..n] == other.compute_scale[..n]
    }

    /// View of `self` restricted to `sel` (in group order): job-local
    /// index `i` maps to global rank `sel[i]`. Node ids are kept from
    /// the parent so cost-model groupings survive the projection;
    /// hostnames are carried along unchanged.
    pub(crate) fn select(&self, sel: &[Rank]) -> Topology {
        Topology {
            node_of_rank: sel.iter().map(|r| self.node_of_rank[r.0]).collect(),
            compute_scale: sel.iter().map(|r| self.compute_scale[r.0]).collect(),
            hostnames: self.hostnames.clone(),
        }
    }

    /// Does a job cluster described by `other` (ranks `0..sel.len()`)
    /// match the pool ranks `sel` of `self`, *structurally*? Unlike
    /// [`Self::agrees_on_prefix`] the node ids may differ numerically —
    /// a subset drawn from nodes {2,3} of a big pool matches a fresh
    /// two-node cluster — but the same-node relation between every pair
    /// of selected ranks and each rank's compute scale must agree.
    pub fn agrees_on_ranks(&self, other: &Topology, sel: &[usize]) -> bool {
        if other.node_of_rank.len() != sel.len() {
            return false;
        }
        if sel.iter().any(|&r| r >= self.node_of_rank.len()) {
            return false;
        }
        for (i, &ri) in sel.iter().enumerate() {
            if self.compute_scale[ri] != other.compute_scale[i] {
                return false;
            }
            for (j, &rj) in sel.iter().enumerate().skip(i + 1) {
                let pool_same = self.node_of_rank[ri] == self.node_of_rank[rj];
                let job_same = other.node_of_rank[i] == other.node_of_rank[j];
                if pool_same != job_same {
                    return false;
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterConfig, DeploymentKind};

    #[test]
    fn hostfile_parse_with_comments_and_slots() {
        let hf = Hostfile::parse(
            "# paper §IV.A hostfile\nrpi0 slots=4\nrpi1 slots=4 # slave 1\n\nrpi2\n",
        )
        .unwrap();
        assert_eq!(hf.entries.len(), 3);
        assert_eq!(hf.entries[0].slots, 4);
        assert_eq!(hf.entries[2].slots, 1);
        assert_eq!(hf.total_slots(), 9);
    }

    #[test]
    fn hostfile_rejects_garbage() {
        assert!(Hostfile::parse("").is_err());
        assert!(Hostfile::parse("h slots=0").is_err());
        assert!(Hostfile::parse("h wat=1").is_err());
        assert!(Hostfile::parse("h slots=banana").is_err());
    }

    #[test]
    fn block_topology_same_node() {
        let t = Topology::block(2, 2);
        assert!(t.same_node(Rank(0), Rank(1)));
        assert!(!t.same_node(Rank(1), Rank(2)));
        assert_eq!(t.nodes(), 2);
    }

    #[test]
    fn from_config_scales_compute_for_rpi() {
        let cfg = ClusterConfig::builder()
            .deployment(DeploymentKind::BareMetal)
            .nodes(2)
            .slots_per_node(1)
            .build();
        let t = Topology::from_config(&cfg);
        assert!(t.compute_scale(Rank(0)) >= 8.0);
    }

    #[test]
    fn select_keeps_node_structure() {
        let t = Topology::block(4, 4);
        // Ranks 4..8 live on node 1; a selected view keeps them co-located.
        let sel = [Rank(4), Rank(5), Rank(6)];
        let v = t.select(&sel);
        assert_eq!(v.ranks(), 3);
        assert!(v.same_node(Rank(0), Rank(2)));
        // Cross-node selection stays cross-node.
        let v2 = t.select(&[Rank(0), Rank(4)]);
        assert!(!v2.same_node(Rank(0), Rank(1)));
    }

    #[test]
    fn agrees_on_ranks_is_structural() {
        let pool = Topology::block(4, 4);
        // A width-3 job cluster on one node matches any same-node triple,
        // even one drawn from node 2 (node ids differ numerically).
        let job = Topology::block(1, 3);
        assert!(pool.agrees_on_ranks(&job, &[8, 9, 10]));
        // ...but not a triple spanning nodes.
        assert!(!pool.agrees_on_ranks(&job, &[3, 4, 5]));
        // A 2x1 job cluster needs a cross-node pair.
        let job2 = Topology::block(2, 1);
        assert!(pool.agrees_on_ranks(&job2, &[3, 4]));
        assert!(!pool.agrees_on_ranks(&job2, &[4, 5]));
        // Width mismatch and out-of-range ranks are rejected.
        assert!(!pool.agrees_on_ranks(&job, &[0, 1]));
        assert!(!pool.agrees_on_ranks(&job, &[14, 15, 16]));
    }

    #[test]
    fn hostfile_topology_roundtrip() {
        let hf = Hostfile::parse("a slots=2\nb slots=1\n").unwrap();
        let specs = vec![NodeSpec::local(0), NodeSpec::local(1)];
        let t = Topology::from_hostfile(&hf, &specs).unwrap();
        assert_eq!(t.ranks(), 3);
        assert_eq!(t.hostname_of(Rank(2)), "b");
    }
}
