//! Length-framed TCP transport over spawned `blaze worker` rank processes.
//!
//! The deployment model (README "Real deployment"): the driver process
//! keeps the SPMD rank closures on its own [`super::RankPool`] threads,
//! but wires each rank's endpoint to a dedicated worker **process** over
//! TCP. Workers form a full socket mesh among themselves, so a message
//! from rank `i` to rank `j` crosses three real sockets:
//! driver → worker<sub>i</sub> → worker<sub>j</sub> → driver. Every
//! inter-rank byte therefore transits real kernel sockets between real
//! OS processes, while results and virtual clocks stay byte-identical to
//! the in-process mailboxes (frames carry the sender clock bit-exactly;
//! all cost modeling stays in [`super::Communicator`]).
//!
//! Handshake, in order, all messages length-framed serial blobs:
//!
//! 1. launcher binds `127.0.0.1:0`, spawns `n` × `blaze worker
//!    --connect ADDR`;
//! 2. each worker binds its own mesh listener, connects back, sends
//!    `Hello { mesh_port }`; ranks are assigned in accept order;
//! 3. launcher sends every worker `Assign { rank, world, mesh_ports }`;
//! 4. worker `r` connects to every peer `s < r` (sending
//!    `MeshHello { from }`) and accepts the rest, then sends `Ready`;
//! 5. the control stream becomes the data stream: driver-written frames
//!    are routed by the worker (to itself or a mesh peer); frames
//!    addressed to the worker's rank flow back up the same stream.
//!
//! Shutdown is EOF-driven: dropping a rank's endpoint closes its stream,
//! the worker's router sees EOF and the process exits; the fleet handle
//! reaps children on drop (kill after a grace period), so suites leave
//! no orphans — `tests/integration_transport.rs` asserts exactly that.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::serial::{Decoder, Encoder};

use super::datatypes::{Message, Rank};
use super::transport::Transport;
use super::wire::{frame_dst, write_frame, write_frame_body, FrameReader, WireFrame};

/// Whole-handshake deadline; also the per-read timeout while shaking.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// Grace period for workers to exit on EOF before the fleet kills them.
const REAP_TIMEOUT: Duration = Duration::from_secs(5);
/// Sanity cap on handshake blobs (they are tens of bytes).
const MAX_HANDSHAKE_BYTES: usize = 1 << 16;

const MAGIC_HELLO: u64 = 0xB1A2_E701;
const MAGIC_ASSIGN: u64 = 0xB1A2_E702;
const MAGIC_MESH: u64 = 0xB1A2_E703;
const MAGIC_READY: u64 = 0xB1A2_E704;

// ---------------------------------------------------------------- blobs

fn write_blob(w: &mut impl Write, body: &[u8]) -> Result<()> {
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    Ok(())
}

fn read_blob(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut header = [0u8; 4];
    r.read_exact(&mut header).context("reading handshake header")?;
    let len = u32::from_le_bytes(header) as usize;
    ensure!(len <= MAX_HANDSHAKE_BYTES, "handshake blob of {len} bytes exceeds cap");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading handshake body")?;
    Ok(body)
}

fn tagged(magic: u64, fields: &[u64]) -> Vec<u8> {
    let mut enc = Encoder::with_capacity(16 + fields.len() * 10);
    enc.put_varint(magic);
    for f in fields {
        enc.put_varint(*f);
    }
    enc.into_bytes()
}

fn expect_magic(dec: &mut Decoder<'_>, want: u64, what: &str) -> Result<()> {
    let got = dec.get_varint()?;
    ensure!(got == want, "bad {what} magic {got:#x} (is the worker binary the blaze CLI?)");
    Ok(())
}

// ------------------------------------------------------------- launcher

/// Owns the spawned worker processes; the last endpoint to drop reaps
/// them (workers exit on stream EOF; stragglers are killed after
/// [`REAP_TIMEOUT`]).
struct TcpFleet {
    children: Mutex<Vec<Child>>,
}

impl Drop for TcpFleet {
    fn drop(&mut self) {
        let mut children = match self.children.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        let deadline = Instant::now() + REAP_TIMEOUT;
        for child in children.iter_mut() {
            loop {
                match child.try_wait() {
                    Ok(Some(_)) | Err(_) => break,
                    Ok(None) if Instant::now() >= deadline => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                    Ok(None) => thread::sleep(Duration::from_millis(5)),
                }
            }
        }
    }
}

/// One rank's endpoint: the driver side of that rank's worker stream.
/// Stream halves are declared before the fleet handle on purpose: when
/// the last endpoint drops, every stream is already closed (workers see
/// EOF and exit) before the fleet waits on the children.
pub(crate) struct TcpEndpoint {
    reader: std::cell::RefCell<FrameReader<TcpStream>>,
    writer: std::cell::RefCell<TcpStream>,
    world: usize,
    _fleet: Arc<TcpFleet>,
}

impl Transport for TcpEndpoint {
    fn send(&self, dst: Rank, msg: Message) -> Result<()> {
        ensure!(dst.0 < self.world, "send to {dst} outside universe of {}", self.world);
        let frame = WireFrame::from_message(dst, msg);
        write_frame(&mut *self.writer.borrow_mut(), &frame)
            .with_context(|| format!("tcp send to {dst} (worker hung up?)"))
    }

    fn recv(&self) -> Result<Message> {
        match self.reader.borrow_mut().read_frame()? {
            Some(frame) => Ok(frame.into_message()),
            None => bail!("transport peer hung up mid-recv (worker exited)"),
        }
    }

    fn drain(&self) {
        // Nothing to do: frames still in flight through the worker mesh
        // cannot be snatched back; the communicator's epoch filter is
        // what discards them on arrival.
    }
}

fn resolve_worker_bin(explicit: Option<&Path>) -> Result<PathBuf> {
    if let Some(path) = explicit {
        return Ok(path.to_path_buf());
    }
    if let Ok(path) = std::env::var("BLAZE_WORKER_BIN") {
        if !path.trim().is_empty() {
            return Ok(PathBuf::from(path));
        }
    }
    std::env::current_exe().context("resolving current executable as the worker binary")
}

/// Spawn `n` worker processes, run the handshake, and return one
/// connected endpoint per rank plus the worker PIDs (for shutdown
/// tests). `worker_bin` resolution: explicit > `BLAZE_WORKER_BIN` env >
/// the current executable (the `mpirun` model: the driver binary is the
/// worker binary).
pub(crate) fn launch_fleet(
    n: usize,
    worker_bin: Option<&Path>,
) -> Result<(Vec<Box<dyn Transport>>, Vec<u32>)> {
    ensure!(n >= 1, "a tcp fleet needs at least one rank");
    let listener = TcpListener::bind("127.0.0.1:0").context("binding launcher listener")?;
    let addr = listener.local_addr()?;
    let bin = resolve_worker_bin(worker_bin)?;

    // When tracing is on at launch time, every worker gets a shared
    // span directory: each flushes its Relay spans there at driver EOF,
    // and the driver collects the files after the fleet is reaped.
    let trace_dir = if crate::trace::enabled() {
        static FLEET_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = FLEET_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("blaze-trace-{}-{}", std::process::id(), seq));
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating trace dir {}", dir.display()))?;
        crate::trace::register_worker_dir(dir.clone());
        Some(dir)
    } else {
        None
    };

    let mut children = Vec::with_capacity(n);
    let mut pids = Vec::with_capacity(n);
    for i in 0..n {
        let mut cmd = Command::new(&bin);
        cmd.arg("worker").arg("--connect").arg(addr.to_string());
        if let Some(dir) = &trace_dir {
            cmd.arg("--trace-dir").arg(dir);
        }
        let child = cmd
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning worker {i} from {}", bin.display()))?;
        pids.push(child.id());
        children.push(child);
    }
    let fleet = Arc::new(TcpFleet { children: Mutex::new(children) });

    // Accept with a deadline, failing fast if a worker dies during the
    // handshake (e.g. BLAZE_WORKER_BIN points at a non-blaze binary).
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + HANDSHAKE_TIMEOUT;
    let mut streams: Vec<TcpStream> = Vec::with_capacity(n);
    while streams.len() < n {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                stream.set_nodelay(true)?;
                stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
                streams.push(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!("worker handshake timed out: {}/{n} workers connected", streams.len());
                }
                let mut children = fleet.children.lock().unwrap();
                for child in children.iter_mut() {
                    if let Ok(Some(status)) = child.try_wait() {
                        bail!(
                            "worker exited during handshake ({status}) — is {} the blaze CLI?",
                            bin.display()
                        );
                    }
                }
                drop(children);
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e).context("accepting worker connection"),
        }
    }

    // Hello: rank = accept order; collect each worker's mesh port.
    let mut mesh_ports = Vec::with_capacity(n);
    for (rank, stream) in streams.iter_mut().enumerate() {
        let blob = read_blob(stream).with_context(|| format!("hello from rank{rank}"))?;
        let mut dec = Decoder::new(&blob);
        expect_magic(&mut dec, MAGIC_HELLO, "hello")?;
        mesh_ports.push(dec.get_varint()? as u64);
    }

    // Assign + mesh ports, then wait for every Ready.
    for (rank, stream) in streams.iter_mut().enumerate() {
        let mut fields = vec![rank as u64, n as u64];
        fields.extend_from_slice(&mesh_ports);
        write_blob(stream, &tagged(MAGIC_ASSIGN, &fields))?;
    }
    for (rank, stream) in streams.iter_mut().enumerate() {
        let blob = read_blob(stream).with_context(|| format!("ready from rank{rank}"))?;
        let mut dec = Decoder::new(&blob);
        expect_magic(&mut dec, MAGIC_READY, "ready")?;
    }

    let mut endpoints: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
    for stream in streams {
        stream.set_read_timeout(None)?;
        let reader = stream.try_clone().context("cloning worker stream")?;
        endpoints.push(Box::new(TcpEndpoint {
            reader: std::cell::RefCell::new(FrameReader::new(reader)),
            writer: std::cell::RefCell::new(stream),
            world: n,
            _fleet: fleet.clone(),
        }));
    }
    Ok((endpoints, pids))
}

// --------------------------------------------------------------- worker

/// Entry point of the `blaze worker` subcommand: connect back to the
/// launcher at `connect`, complete the handshake, then relay frames
/// until the driver closes the stream (normal shutdown). When the
/// launcher passed `--trace-dir`, the worker records a `Relay` span for
/// every frame it routes (linked to the sender's span id riding the
/// wire) and flushes them into `trace_dir` on shutdown.
pub fn worker_main(connect: &str, trace_dir: Option<&str>) -> Result<()> {
    let driver = TcpStream::connect(connect)
        .with_context(|| format!("worker connecting back to launcher at {connect}"))?;
    driver.set_nodelay(true)?;
    driver.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;

    let mesh_listener = TcpListener::bind("127.0.0.1:0").context("binding mesh listener")?;
    let mesh_port = mesh_listener.local_addr()?.port();

    let mut driver_w = driver.try_clone()?;
    let mut driver_r = driver;
    write_blob(&mut driver_w, &tagged(MAGIC_HELLO, &[mesh_port as u64]))?;

    let assign = read_blob(&mut driver_r).context("reading rank assignment")?;
    let mut dec = Decoder::new(&assign);
    expect_magic(&mut dec, MAGIC_ASSIGN, "assign")?;
    let rank = dec.get_varint()? as usize;
    let world = dec.get_varint()? as usize;
    let mut mesh_ports = Vec::with_capacity(world);
    for _ in 0..world {
        mesh_ports.push(dec.get_varint()? as u16);
    }

    // Full mesh: initiate to lower ranks, accept from higher ones.
    let mut peers: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
    for (peer, &port) in mesh_ports.iter().enumerate().take(rank) {
        let mut stream = TcpStream::connect(("127.0.0.1", port))
            .with_context(|| format!("rank{rank} connecting to rank{peer} mesh"))?;
        stream.set_nodelay(true)?;
        write_blob(&mut stream, &tagged(MAGIC_MESH, &[rank as u64]))?;
        peers[peer] = Some(stream);
    }
    mesh_listener.set_nonblocking(true)?;
    let deadline = Instant::now() + HANDSHAKE_TIMEOUT;
    let mut accepted = 0;
    while accepted < world - rank - 1 {
        match mesh_listener.accept() {
            Ok((mut stream, _)) => {
                stream.set_nonblocking(false)?;
                stream.set_nodelay(true)?;
                stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
                let hello = read_blob(&mut stream).context("reading mesh hello")?;
                let mut dec = Decoder::new(&hello);
                expect_magic(&mut dec, MAGIC_MESH, "mesh hello")?;
                let from = dec.get_varint()? as usize;
                ensure!(from < world && peers[from].is_none(), "bad mesh peer rank{from}");
                stream.set_read_timeout(None)?;
                peers[from] = Some(stream);
                accepted += 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                ensure!(Instant::now() < deadline, "rank{rank} mesh handshake timed out");
                thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e).context("accepting mesh connection"),
        }
    }

    write_blob(&mut driver_w, &tagged(MAGIC_READY, &[rank as u64]))?;
    driver_r.set_read_timeout(None)?;
    if trace_dir.is_some() {
        crate::trace::set_enabled(true);
        // Worker processes get their own Chrome pid lane: rank + 1
        // (the driver process is lane 0).
        crate::trace::job_start(rank, rank as u32 + 1, 0);
    }
    run_data_plane(rank, driver_r, driver_w, peers, trace_dir.map(PathBuf::from))
}

/// The worker's steady state: route driver frames to self or mesh
/// peers, pump mesh-inbound (and self-addressed) frames back up to the
/// driver. Returns on driver EOF — the process then exits, which is the
/// fleet's shutdown signal.
fn run_data_plane(
    rank: usize,
    driver_r: TcpStream,
    driver_w: TcpStream,
    peers: Vec<Option<TcpStream>>,
    trace_dir: Option<PathBuf>,
) -> Result<()> {
    // Unbounded local queue: mesh readers and the router enqueue frames
    // bound for this rank's driver endpoint; one pump thread writes
    // them. The always-draining queue is what keeps the relay
    // deadlock-free under any traffic pattern (kernel-buffer
    // backpressure is always transient).
    let (to_driver, inbound) = mpsc::channel::<Vec<u8>>();

    let mut mesh_writers: Vec<Option<TcpStream>> = Vec::with_capacity(peers.len());
    for peer in peers {
        match peer {
            Some(stream) => {
                let read_half = stream.try_clone()?;
                mesh_writers.push(Some(stream));
                let queue = to_driver.clone();
                thread::spawn(move || {
                    let mut frames = FrameReader::new(read_half);
                    while let Ok(Some(body)) = frames.read_frame_body() {
                        if queue.send(body).is_err() {
                            break;
                        }
                    }
                    // Peer EOF is normal teardown; our own exit is
                    // driven by driver EOF on the router below.
                });
            }
            None => mesh_writers.push(None),
        }
    }

    thread::spawn(move || {
        let mut w = driver_w;
        while let Ok(body) = inbound.recv() {
            if write_frame_body(&mut w, &body).is_err() {
                break;
            }
        }
    });

    // Router on the worker's main thread: returning ends the process.
    // Every frame rank `r` sends enters the mesh through worker `r`'s
    // router, so recording a Relay span here sees each frame exactly
    // once fleet-wide.
    let flush = |dir: &Option<PathBuf>| {
        if let Some(dir) = dir {
            let _ = crate::trace::write_worker_spans(dir, rank);
        }
    };
    let mut frames = FrameReader::new(driver_r);
    loop {
        match frames.read_frame_body()? {
            None => {
                flush(&trace_dir); // driver hung up: normal shutdown
                return Ok(());
            }
            Some(body) => {
                let dst = frame_dst(&body)?;
                if trace_dir.is_some() {
                    if let Ok((_, _, clock_ns, span, len)) = super::wire::frame_trace_info(&body) {
                        crate::trace::set_vclock(clock_ns);
                        crate::trace::instant(crate::trace::SpanKind::Relay, 0, len, 0, span);
                    }
                }
                if dst == rank {
                    if to_driver.send(body).is_err() {
                        flush(&trace_dir);
                        return Ok(());
                    }
                } else {
                    let writer = mesh_writers
                        .get_mut(dst)
                        .and_then(|slot| slot.as_mut())
                        .ok_or_else(|| anyhow!("rank{rank}: frame for unknown rank{dst}"))?;
                    write_frame_body(writer, &body)
                        .with_context(|| format!("rank{rank} relaying to rank{dst}"))?;
                }
            }
        }
    }
}
