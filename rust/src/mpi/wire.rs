//! Length-framed wire codec for transport frames.
//!
//! One frame = `u32`-LE body length followed by a serial-codec body
//! (varint `dst`, `src`, `tag`, `epoch`, `clock_ns`, `span`, then the
//! length-prefixed payload). The body reuses the same [`crate::serial`]
//! block codec every spill run and shuffle payload already uses, so the
//! socket format is the store format: a frame body is decodable with the
//! same `Decoder` the rest of the system speaks.
//!
//! [`FrameReader`] is the stream side: it tolerates arbitrarily chunked
//! reads (a `read` may return one byte at a time), reports a clean EOF at
//! a frame boundary as `Ok(None)`, and turns a torn frame — EOF inside a
//! header or body — into an error rather than a panic or a silent
//! truncation. The property suite in `tests/prop_invariants.rs` drives it
//! with adversarial split points.

use std::io::{ErrorKind, Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::serial::{Decoder, Encoder};

use super::datatypes::{Message, Rank, Tag};

/// Upper bound on a frame body — a sanity cap against corrupt or
/// malicious length prefixes, far above any payload the system ships.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// A decoded transport frame: a [`Message`] plus its destination rank
/// (the wire needs routing; the in-process mailboxes do not).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireFrame {
    pub dst: Rank,
    pub src: Rank,
    pub tag: Tag,
    pub epoch: u64,
    pub clock_ns: u64,
    /// Tracing span id (0 = tracing off). Metadata only — never charged
    /// to the virtual clock, whose costs are payload-length functions.
    pub span: u64,
    pub payload: Vec<u8>,
}

impl WireFrame {
    /// Wrap an outbound [`Message`] with its destination.
    pub fn from_message(dst: Rank, msg: Message) -> Self {
        WireFrame {
            dst,
            src: msg.src,
            tag: msg.tag,
            epoch: msg.epoch,
            clock_ns: msg.clock_ns,
            span: msg.span,
            payload: msg.payload,
        }
    }

    /// Strip the routing envelope back off.
    pub fn into_message(self) -> Message {
        Message {
            src: self.src,
            tag: self.tag,
            epoch: self.epoch,
            clock_ns: self.clock_ns,
            span: self.span,
            payload: self.payload,
        }
    }
}

/// Encode a frame: length prefix + serial body.
pub fn encode_frame(frame: &WireFrame) -> Vec<u8> {
    let mut body = Encoder::with_capacity(frame.payload.len() + 40);
    body.put_varint(frame.dst.0 as u64);
    body.put_varint(frame.src.0 as u64);
    body.put_varint(frame.tag.0);
    body.put_varint(frame.epoch);
    body.put_varint(frame.clock_ns);
    body.put_varint(frame.span);
    body.put_bytes(&frame.payload);
    let body = body.into_bytes();
    let mut out = Vec::with_capacity(body.len() + 4);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Decode a frame body (the bytes after the length prefix).
pub fn decode_frame(body: &[u8]) -> Result<WireFrame> {
    let mut dec = Decoder::new(body);
    let dst = Rank(usize::try_from(dec.get_varint()?).context("frame dst overflows usize")?);
    let src = Rank(usize::try_from(dec.get_varint()?).context("frame src overflows usize")?);
    let tag = Tag(dec.get_varint()?);
    let epoch = dec.get_varint()?;
    let clock_ns = dec.get_varint()?;
    let span = dec.get_varint()?;
    let payload = dec.get_bytes()?.to_vec();
    dec.finish().context("trailing bytes after frame payload")?;
    Ok(WireFrame { dst, src, tag, epoch, clock_ns, span, payload })
}

/// Peek the destination rank of an encoded frame body without decoding
/// the rest — the worker relay routes on this.
pub fn frame_dst(body: &[u8]) -> Result<usize> {
    let mut dec = Decoder::new(body);
    usize::try_from(dec.get_varint()?).context("frame dst overflows usize")
}

/// Decode just the header fields a tracing relay needs —
/// `(dst, src, clock_ns, span, payload_len)` — without copying the
/// payload out. Only called on the relay path when tracing is on.
pub fn frame_trace_info(body: &[u8]) -> Result<(usize, usize, u64, u64, u64)> {
    let mut dec = Decoder::new(body);
    let dst = usize::try_from(dec.get_varint()?).context("frame dst overflows usize")?;
    let src = usize::try_from(dec.get_varint()?).context("frame src overflows usize")?;
    let _tag = dec.get_varint()?;
    let _epoch = dec.get_varint()?;
    let clock_ns = dec.get_varint()?;
    let span = dec.get_varint()?;
    let payload_len = dec.get_bytes()?.len() as u64;
    Ok((dst, src, clock_ns, span, payload_len))
}

/// Write one encoded frame (length prefix + body) to `w`.
pub fn write_frame(w: &mut impl Write, frame: &WireFrame) -> Result<()> {
    w.write_all(&encode_frame(frame))?;
    Ok(())
}

/// Write a frame whose body is already encoded — the relay fast path.
pub fn write_frame_body(w: &mut impl Write, body: &[u8]) -> Result<()> {
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    Ok(())
}

/// Incremental frame reader over any [`Read`]: loops partial reads until
/// a whole frame is in hand.
pub struct FrameReader<R> {
    inner: R,
}

impl<R: Read> FrameReader<R> {
    pub fn new(inner: R) -> Self {
        FrameReader { inner }
    }

    /// Next raw frame body, or `Ok(None)` on clean EOF at a frame
    /// boundary. EOF mid-header or mid-body is a torn frame: an error.
    pub fn read_frame_body(&mut self) -> Result<Option<Vec<u8>>> {
        let mut header = [0u8; 4];
        if !self.fill(&mut header, "frame header")? {
            return Ok(None);
        }
        let len = u32::from_le_bytes(header) as usize;
        ensure!(len <= MAX_FRAME_BYTES, "frame length {len} exceeds cap {MAX_FRAME_BYTES}");
        let mut body = vec![0u8; len];
        if len > 0 {
            let full = self.fill(&mut body, "frame body")?;
            ensure!(full, "torn frame: EOF at start of {len}-byte body");
        }
        Ok(Some(body))
    }

    /// Next decoded frame, or `Ok(None)` on clean EOF.
    pub fn read_frame(&mut self) -> Result<Option<WireFrame>> {
        match self.read_frame_body()? {
            Some(body) => decode_frame(&body).map(Some),
            None => Ok(None),
        }
    }

    /// Fill `buf` completely. `Ok(false)` = clean EOF before the first
    /// byte; EOF after a partial fill is a torn frame and errors.
    fn fill(&mut self, buf: &mut [u8], what: &str) -> Result<bool> {
        let mut got = 0;
        while got < buf.len() {
            match self.inner.read(&mut buf[got..]) {
                Ok(0) => {
                    if got == 0 {
                        return Ok(false);
                    }
                    bail!("torn frame: EOF after {got} of {} {what} bytes", buf.len());
                }
                Ok(n) => got += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            }
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(payload: Vec<u8>) -> WireFrame {
        WireFrame {
            dst: Rank(3),
            src: Rank(1),
            tag: Tag::user(9),
            epoch: 2,
            clock_ns: 77,
            span: 41,
            payload,
        }
    }

    #[test]
    fn roundtrip_including_empty_payload() {
        for payload in [vec![], vec![0xAB; 1], vec![7; 65_536]] {
            let f = frame(payload);
            let bytes = encode_frame(&f);
            let mut reader = FrameReader::new(&bytes[..]);
            assert_eq!(reader.read_frame().unwrap().unwrap(), f);
            assert!(reader.read_frame().unwrap().is_none(), "clean EOF after one frame");
        }
    }

    #[test]
    fn frame_dst_matches_full_decode() {
        let f = frame(vec![1, 2, 3]);
        let bytes = encode_frame(&f);
        assert_eq!(frame_dst(&bytes[4..]).unwrap(), f.dst.0);
    }

    #[test]
    fn frame_trace_info_peeks_span_without_full_decode() {
        let f = frame(vec![1, 2, 3, 4, 5]);
        let bytes = encode_frame(&f);
        let (dst, src, clock, span, len) = frame_trace_info(&bytes[4..]).unwrap();
        assert_eq!((dst, src), (f.dst.0, f.src.0));
        assert_eq!((clock, span), (f.clock_ns, f.span));
        assert_eq!(len, 5);
    }

    #[test]
    fn torn_header_and_torn_body_are_errors() {
        let bytes = encode_frame(&frame(vec![5; 32]));
        for cut in [1, 3, 4, bytes.len() - 1] {
            let mut reader = FrameReader::new(&bytes[..cut]);
            assert!(reader.read_frame().is_err(), "cut at {cut} must be a torn frame");
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut bytes = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0; 8]);
        assert!(FrameReader::new(&bytes[..]).read_frame().is_err());
    }
}
