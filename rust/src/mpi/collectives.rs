//! Collective operations over [`Communicator`], built from p2p sends so
//! the virtual clock sees every byte and every synchronization point.
//!
//! ## Algorithms
//!
//! Every rooted collective is implemented by two byte-level primitives —
//! a broadcast and a gather — each available in three shapes, selected by
//! [`CollectiveAlgo`]:
//!
//!  * **Star** — the seed implementation: gather is recv-from-everyone at
//!    the root, broadcast is send-to-everyone from the root. The root
//!    pays `O(P)` message injections on its uplink, which is exactly the
//!    serialization that makes Fig 10's small-key-range wordcount
//!    anti-scale.
//!  * **Tree** — a binomial tree over the ranks (MPICH's shape): the
//!    root touches `O(log P)` messages and the virtual-clock depth is
//!    `O(log P)` levels of injection + propagation instead of `O(P)`
//!    injections at the root.
//!  * **Hierarchical** — a node-leader tree that consults
//!    [`crate::mpi::Topology::node_of`]: cross-node hops happen once per
//!    node (binomial over the node leaders), intra-node fan-out/fan-in
//!    stays on same-node links. [`Communicator::alltoallv`] additionally
//!    coalesces all pairs bound for one destination node into a single
//!    framed message to that node's leader, which scatters locally — the
//!    Thrill/M3R node-level message-coalescing shape.
//!
//! `allreduce` gathers the operands and folds **at the root, in rank
//! order**, whatever the algorithm — so its result is bit-identical
//! across Star/Tree/Hierarchical even for float operations whose
//! rounding depends on association. The tree still buys the `O(log P)`
//! clock depth; it just does not re-associate the fold.
//!
//! Tag discipline: collectives allocate tags from a per-rank sequence
//! counter ([`Communicator::next_collective_tag`]). Programs are SPMD —
//! every rank executes the same collective sequence with the same
//! algorithm in effect — so counters stay aligned without negotiation,
//! the same assumption MPI makes about communicator-ordered collectives.
//! The tag count per call is deterministic *given the algorithm in
//! effect* (e.g. `alltoallv` takes one tag pairwise, three coalesced);
//! algorithm switches are themselves SPMD-synchronized
//! ([`Communicator::set_collective_algo`]), so every rank still draws
//! the same tag sequence — including when a job switches algorithms
//! mid-flight, as the equivalence suite does.
//!
//! The blocking shapes matter for the paper: `alltoallv` is the shuffle
//! (MR-MPI's `MPI_Alltoall` §II), and `barrier`/`allreduce` are the global
//! synchronization points Mimir blames for MR-MPI's memory retention.

use std::collections::{BTreeMap, HashMap};

use anyhow::Result;

use crate::serial::{from_bytes, to_bytes, Decoder, Encoder, FastSerialize};

use super::comm::Communicator;
use super::datatypes::{Rank, Tag};

/// Which wire shape the collectives use. Resolution order everywhere the
/// selector is threaded (mirroring
/// [`crate::cluster::ClusterConfig::spill_threshold_bytes`]): an explicit
/// choice beats the `BLAZE_COLLECTIVE_ALGO` environment override beats
/// the [`CollectiveAlgo::Star`] default.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum CollectiveAlgo {
    /// Gather-to-root / send-from-root: `O(P)` injections at the root.
    #[default]
    Star,
    /// Binomial tree over ranks: `O(log P)` depth and root messages.
    Tree,
    /// Binomial tree over node leaders + same-node fan-out, with
    /// node-coalesced `alltoallv` bundles.
    Hierarchical,
}

impl CollectiveAlgo {
    pub const ALL: [CollectiveAlgo; 3] =
        [CollectiveAlgo::Star, CollectiveAlgo::Tree, CollectiveAlgo::Hierarchical];

    /// The `BLAZE_COLLECTIVE_ALGO` override, or the Star default.
    /// Unparseable values are ignored (same forgiveness as the spill
    /// threshold's env override).
    pub fn from_env_or_default() -> CollectiveAlgo {
        let env = std::env::var("BLAZE_COLLECTIVE_ALGO").ok();
        Self::resolve(env.as_deref())
    }

    /// Resolution with the env value injected — tests exercise the
    /// precedence without mutating process-global environment.
    pub(crate) fn resolve(env: Option<&str>) -> CollectiveAlgo {
        env.and_then(|s| s.trim().parse().ok()).unwrap_or_default()
    }
}

impl std::fmt::Display for CollectiveAlgo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            CollectiveAlgo::Star => "star",
            CollectiveAlgo::Tree => "tree",
            CollectiveAlgo::Hierarchical => "hierarchical",
        };
        f.write_str(s)
    }
}

impl std::str::FromStr for CollectiveAlgo {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "star" => Ok(CollectiveAlgo::Star),
            "tree" => Ok(CollectiveAlgo::Tree),
            "hierarchical" | "hier" => Ok(CollectiveAlgo::Hierarchical),
            other => Err(anyhow::anyhow!("unknown collective algorithm {other:?}")),
        }
    }
}

/// `(rank, payload)` entries riding a gather tree edge: varint count,
/// then per entry a varint rank and length-prefixed bytes.
fn encode_entries(entries: &[(u64, Vec<u8>)]) -> Vec<u8> {
    let total: usize = entries.iter().map(|(_, b)| b.len() + 10).sum();
    let mut enc = Encoder::with_capacity(total + 10);
    enc.put_varint(entries.len() as u64);
    for (rank, bytes) in entries {
        enc.put_varint(*rank);
        enc.put_bytes(bytes);
    }
    enc.into_bytes()
}

fn decode_entries_into(bytes: &[u8], entries: &mut Vec<(u64, Vec<u8>)>) -> Result<()> {
    let mut dec = Decoder::new(bytes);
    let count = dec.get_varint()?;
    // Never reserve more than what could possibly remain (corrupt-count
    // guard, same as the serial codec's Vec decode).
    entries.reserve((count as usize).min(dec.remaining()));
    for _ in 0..count {
        let rank = dec.get_varint()?;
        entries.push((rank, dec.get_bytes()?.to_vec()));
    }
    dec.finish()
}

/// Length-prefixed segment list (the allgather wire format).
fn encode_segments(segments: &[Vec<u8>]) -> Vec<u8> {
    let total: usize = segments.iter().map(|s| s.len() + 10).sum();
    let mut enc = Encoder::with_capacity(total + 10);
    enc.put_varint(segments.len() as u64);
    for seg in segments {
        enc.put_bytes(seg);
    }
    enc.into_bytes()
}

fn decode_segments(bytes: &[u8]) -> Result<Vec<Vec<u8>>> {
    let mut dec = Decoder::new(bytes);
    let count = dec.get_varint()?;
    let mut out = Vec::with_capacity((count as usize).min(dec.remaining()));
    for _ in 0..count {
        out.push(dec.get_bytes()?.to_vec());
    }
    dec.finish()?;
    Ok(out)
}

impl Communicator {
    /// Active ranks grouped by node, for the hierarchical algorithms.
    /// `groups[0]` is `root`'s node with `root` moved to the front; every
    /// other group leads with its lowest rank. `g[0]` is the node's
    /// **leader**: the only rank on the node that talks cross-node.
    fn node_groups(&self, root: Rank) -> Vec<Vec<Rank>> {
        let topo = self.topology();
        let mut by_node: BTreeMap<usize, Vec<Rank>> = BTreeMap::new();
        for r in 0..self.size() {
            by_node.entry(topo.node_of(Rank(r))).or_default().push(Rank(r));
        }
        let mut groups: Vec<Vec<Rank>> = by_node.into_values().collect();
        for g in &mut groups {
            if let Some(i) = g.iter().position(|r| *r == root) {
                g.swap(0, i);
            }
        }
        if let Some(i) = groups.iter().position(|g| g[0] == root) {
            groups.swap(0, i);
        }
        groups
    }

    /// Byte-level broadcast from `root`. `payload` must be `Some` on the
    /// root (returned as-is there) and is ignored elsewhere.
    fn bcast_bytes(&self, root: Rank, tag: Tag, payload: Option<Vec<u8>>) -> Result<Vec<u8>> {
        match self.collective_algo() {
            CollectiveAlgo::Star => self.bcast_bytes_star(root, tag, payload),
            CollectiveAlgo::Tree => self.bcast_bytes_tree(root, tag, payload),
            CollectiveAlgo::Hierarchical => self.bcast_bytes_hier(root, tag, payload),
        }
    }

    fn bcast_bytes_star(&self, root: Rank, tag: Tag, payload: Option<Vec<u8>>) -> Result<Vec<u8>> {
        if self.rank() == root {
            let bytes = payload.expect("root broadcasts a payload");
            for r in 0..self.size() {
                if r != root.0 {
                    self.send(Rank(r), tag, bytes.clone())?;
                }
            }
            Ok(bytes)
        } else {
            self.recv(root, tag)
        }
    }

    /// Binomial broadcast: virtual rank `vr = (rank - root) mod P`; a
    /// rank receives from `vr - lsb(vr)` and forwards to `vr + m` for
    /// each mask `m` descending below its lowest set bit.
    fn bcast_bytes_tree(&self, root: Rank, tag: Tag, payload: Option<Vec<u8>>) -> Result<Vec<u8>> {
        let n = self.size();
        let vr = (self.rank().0 + n - root.0) % n;
        let actual = |v: usize| Rank((v + root.0) % n);
        let mut bytes = payload.unwrap_or_default();
        let mut mask = 1usize;
        while mask < n {
            if vr & mask != 0 {
                bytes = self.recv(actual(vr - mask), tag)?;
                break;
            }
            mask <<= 1;
        }
        mask >>= 1;
        while mask > 0 {
            if vr + mask < n {
                self.send(actual(vr + mask), tag, bytes.clone())?;
            }
            mask >>= 1;
        }
        Ok(bytes)
    }

    /// Node-leader broadcast: binomial over the leaders (rooted at
    /// `root`, which is always its own node's leader), then a same-node
    /// fan-out from each leader — one cross-node hop per node.
    fn bcast_bytes_hier(&self, root: Rank, tag: Tag, payload: Option<Vec<u8>>) -> Result<Vec<u8>> {
        let me = self.rank();
        let groups = self.node_groups(root);
        let gi = groups.iter().position(|g| g.contains(&me)).expect("rank in a node group");
        let leader = groups[gi][0];
        if me != leader {
            return self.recv(leader, tag);
        }
        let m = groups.len();
        let mut bytes = payload.unwrap_or_default();
        let mut mask = 1usize;
        while mask < m {
            if gi & mask != 0 {
                bytes = self.recv(groups[gi - mask][0], tag)?;
                break;
            }
            mask <<= 1;
        }
        mask >>= 1;
        while mask > 0 {
            if gi + mask < m {
                self.send(groups[gi + mask][0], tag, bytes.clone())?;
            }
            mask >>= 1;
        }
        for &member in &groups[gi][1..] {
            self.send(member, tag, bytes.clone())?;
        }
        Ok(bytes)
    }

    /// Byte-level gather to `root`: `Some(payloads)` in rank order at the
    /// root, `None` elsewhere.
    fn gather_bytes(&self, root: Rank, tag: Tag, payload: Vec<u8>) -> Result<Option<Vec<Vec<u8>>>> {
        match self.collective_algo() {
            CollectiveAlgo::Star => self.gather_bytes_star(root, tag, payload),
            CollectiveAlgo::Tree => self.gather_bytes_tree(root, tag, payload),
            CollectiveAlgo::Hierarchical => self.gather_bytes_hier(root, tag, payload),
        }
    }

    fn gather_bytes_star(
        &self,
        root: Rank,
        tag: Tag,
        payload: Vec<u8>,
    ) -> Result<Option<Vec<Vec<u8>>>> {
        if self.rank() == root {
            let mut slots: Vec<Option<Vec<u8>>> = (0..self.size()).map(|_| None).collect();
            slots[root.0] = Some(payload);
            for _ in 1..self.size() {
                let (src, bytes) = self.recv_any(tag)?;
                slots[src.0] = Some(bytes);
            }
            Ok(Some(slots.into_iter().map(Option::unwrap).collect()))
        } else {
            self.send(root, tag, payload)?;
            Ok(None)
        }
    }

    /// Reverse binomial: each rank absorbs its subtree's `(rank, bytes)`
    /// entries child by child, then forwards the accumulated list to its
    /// parent — the root ends with all `P` entries after `O(log P)`
    /// receives.
    fn gather_bytes_tree(
        &self,
        root: Rank,
        tag: Tag,
        payload: Vec<u8>,
    ) -> Result<Option<Vec<Vec<u8>>>> {
        let n = self.size();
        let me = self.rank();
        let vr = (me.0 + n - root.0) % n;
        let actual = |v: usize| Rank((v + root.0) % n);
        let mut entries: Vec<(u64, Vec<u8>)> = vec![(me.0 as u64, payload)];
        let mut mask = 1usize;
        while mask < n {
            if vr & mask != 0 {
                self.send(actual(vr - mask), tag, encode_entries(&entries))?;
                return Ok(None);
            }
            if vr + mask < n {
                let bytes = self.recv(actual(vr + mask), tag)?;
                decode_entries_into(&bytes, &mut entries)?;
            }
            mask <<= 1;
        }
        Ok(Some(rank_ordered(entries, n)?))
    }

    /// Node-leader gather: members hand their payload to their node's
    /// leader on same-node links, leaders run the binomial gather toward
    /// `root` — again one cross-node hop per node.
    fn gather_bytes_hier(
        &self,
        root: Rank,
        tag: Tag,
        payload: Vec<u8>,
    ) -> Result<Option<Vec<Vec<u8>>>> {
        let me = self.rank();
        let groups = self.node_groups(root);
        let gi = groups.iter().position(|g| g.contains(&me)).expect("rank in a node group");
        let leader = groups[gi][0];
        if me != leader {
            self.send(leader, tag, payload)?;
            return Ok(None);
        }
        let mut entries: Vec<(u64, Vec<u8>)> = vec![(me.0 as u64, payload)];
        for &member in &groups[gi][1..] {
            let bytes = self.recv(member, tag)?;
            entries.push((member.0 as u64, bytes));
        }
        let m = groups.len();
        let mut mask = 1usize;
        while mask < m {
            if gi & mask != 0 {
                self.send(groups[gi - mask][0], tag, encode_entries(&entries))?;
                return Ok(None);
            }
            if gi + mask < m {
                let bytes = self.recv(groups[gi + mask][0], tag)?;
                decode_entries_into(&bytes, &mut entries)?;
            }
            mask <<= 1;
        }
        Ok(Some(rank_ordered(entries, self.size())?))
    }

    /// Synchronize all ranks (and their virtual clocks): an empty gather
    /// followed by an empty broadcast, each in the selected shape — so a
    /// tree barrier completes in `O(log P)` virtual-clock depth.
    pub fn barrier(&self) -> Result<()> {
        let _s = crate::trace::span(crate::trace::SpanKind::Barrier);
        let gather_tag = self.next_collective_tag();
        let release_tag = self.next_collective_tag();
        let gathered = self.gather_bytes(Rank::ROOT, gather_tag, Vec::new())?;
        self.bcast_bytes(Rank::ROOT, release_tag, gathered.map(|_| Vec::new()))?;
        Ok(())
    }

    /// Broadcast `value` from `root` to all ranks. Non-root ranks pass
    /// their (ignored) local value too — SPMD style.
    pub fn bcast<T: FastSerialize>(&self, root: Rank, value: T) -> Result<T> {
        let _s = crate::trace::span(crate::trace::SpanKind::Bcast);
        let tag = self.next_collective_tag();
        if self.rank() == root {
            self.bcast_bytes(root, tag, Some(to_bytes(&value)))?;
            Ok(value)
        } else {
            let bytes = self.bcast_bytes(root, tag, None)?;
            from_bytes(&bytes)
        }
    }

    /// Gather every rank's value at `root`. Returns `Some(values)` (rank
    /// order) at root, `None` elsewhere.
    pub fn gather<T: FastSerialize>(&self, root: Rank, value: T) -> Result<Option<Vec<T>>> {
        let _s = crate::trace::span(crate::trace::SpanKind::Gather);
        let tag = self.next_collective_tag();
        match self.gather_bytes(root, tag, to_bytes(&value))? {
            None => Ok(None),
            Some(slots) => {
                let mut out = Vec::with_capacity(slots.len());
                for bytes in &slots {
                    out.push(from_bytes(bytes)?);
                }
                Ok(Some(out))
            }
        }
    }

    /// Gather at root, then broadcast the vector to everyone.
    pub fn allgather<T: FastSerialize>(&self, value: T) -> Result<Vec<T>> {
        let _s = crate::trace::span(crate::trace::SpanKind::Allgather);
        let gather_tag = self.next_collective_tag();
        let bcast_tag = self.next_collective_tag();
        let gathered = self.gather_bytes(Rank::ROOT, gather_tag, to_bytes(&value))?;
        let packed =
            self.bcast_bytes(Rank::ROOT, bcast_tag, gathered.map(|s| encode_segments(&s)))?;
        let segments = decode_segments(&packed)?;
        let mut out = Vec::with_capacity(segments.len());
        for seg in &segments {
            out.push(from_bytes(seg)?);
        }
        Ok(out)
    }

    /// The shuffle primitive: rank i's `bufs[j]` is delivered as the
    /// return value's element i on rank j. `bufs.len()` must equal world
    /// size; `bufs[self]` short-circuits without touching the network.
    ///
    /// Under [`CollectiveAlgo::Hierarchical`] the exchange is
    /// **node-coalesced**: all pairs bound for ranks on one remote node
    /// travel as a single framed bundle to that node's leader, which
    /// scatters them to local destinations on same-node links (one
    /// re-coalesced message per member). Same-node pairs always go
    /// direct. Cross-node message count drops from `P * (P - slots)` to
    /// `P * (nodes - 1)`; the leader transiently buffers its node's
    /// inbound round, which is the locality-for-memory trade M3R makes.
    pub fn alltoallv(&self, bufs: Vec<Vec<u8>>) -> Result<Vec<Vec<u8>>> {
        anyhow::ensure!(
            bufs.len() == self.size(),
            "alltoallv needs one buffer per rank ({} != {})",
            bufs.len(),
            self.size()
        );
        let s = crate::trace::span(crate::trace::SpanKind::Alltoallv);
        s.add_bytes(bufs.iter().map(|b| b.len() as u64).sum());
        match self.collective_algo() {
            CollectiveAlgo::Hierarchical => self.alltoallv_coalesced(bufs),
            _ => self.alltoallv_pairwise(bufs),
        }
    }

    /// One message per (src, dst) pair — Star and Tree.
    fn alltoallv_pairwise(&self, mut bufs: Vec<Vec<u8>>) -> Result<Vec<Vec<u8>>> {
        let tag = self.next_collective_tag();
        let me = self.rank().0;
        let mut out: Vec<Vec<u8>> = (0..self.size()).map(|_| Vec::new()).collect();
        out[me] = std::mem::take(&mut bufs[me]);
        // Send everything first (injection serializes on the sender's
        // uplink — realistic), then receive; arrivals settle the clock at
        // max(sender_stamp + propagation) instead of cascading (n-1)
        // latencies through a ring.
        for d in 1..self.size() {
            let dst = (me + d) % self.size();
            self.send(Rank(dst), tag, std::mem::take(&mut bufs[dst]))?;
        }
        for d in 1..self.size() {
            let src = (me + self.size() - d) % self.size();
            out[src] = self.recv(Rank(src), tag)?;
        }
        Ok(out)
    }

    /// Node-coalesced exchange (see [`Communicator::alltoallv`]).
    fn alltoallv_coalesced(&self, mut bufs: Vec<Vec<u8>>) -> Result<Vec<Vec<u8>>> {
        let n = self.size();
        let me = self.rank();
        let direct_tag = self.next_collective_tag();
        let bundle_tag = self.next_collective_tag();
        let scatter_tag = self.next_collective_tag();
        let groups = self.node_groups(Rank::ROOT);
        let gi = groups.iter().position(|g| g.contains(&me)).expect("rank in a node group");
        let leader = groups[gi][0];

        let mut out: Vec<Vec<u8>> = (0..n).map(|_| Vec::new()).collect();
        out[me.0] = std::mem::take(&mut bufs[me.0]);

        // Send phase first: same-node pairs direct, one framed bundle per
        // remote node addressed to its leader.
        for &dst in &groups[gi] {
            if dst != me {
                self.send(dst, direct_tag, std::mem::take(&mut bufs[dst.0]))?;
            }
        }
        for (gj, g) in groups.iter().enumerate() {
            if gj == gi {
                continue;
            }
            // One bundle per remote node, in the shared (rank, bytes)
            // entry frame — here the "rank" is the destination.
            let entries: Vec<(u64, Vec<u8>)> =
                g.iter().map(|d| (d.0 as u64, std::mem::take(&mut bufs[d.0]))).collect();
            self.send(g[0], bundle_tag, encode_entries(&entries))?;
        }

        // Receive phase: direct same-node messages...
        for &src in &groups[gi] {
            if src != me {
                out[src.0] = self.recv(src, direct_tag)?;
            }
        }
        if me == leader {
            // ...then one bundle per remote rank; entries for this rank
            // are absorbed, the rest regrouped into one scatter per local
            // member (the second half of the coalescing win: members hear
            // one message per round, not one per remote rank).
            //
            // The inbound round is transiently resident on the leader —
            // the memory half of the locality-for-memory trade — so it is
            // charged against the job's [`PeakTracker`] when one is
            // attached (the shuffle layer attaches its tracker around
            // each exchange).
            let tracker = self.memory_tracker();
            let mut staged = 0u64;
            let remote = n - groups[gi].len();
            let mut for_member: HashMap<usize, Vec<(u64, Vec<u8>)>> = HashMap::new();
            for _ in 0..remote {
                let (src, bytes) = self.recv_any(bundle_tag)?;
                if let Some(t) = &tracker {
                    t.alloc(bytes.len() as u64);
                    staged += bytes.len() as u64;
                }
                let mut entries = Vec::new();
                decode_entries_into(&bytes, &mut entries)?;
                for (dst, payload) in entries {
                    if dst as usize == me.0 {
                        out[src.0] = payload;
                    } else {
                        for_member.entry(dst as usize).or_default().push((src.0 as u64, payload));
                    }
                }
            }
            for &member in &groups[gi][1..] {
                let list = for_member.remove(&member.0).unwrap_or_default();
                self.send(member, scatter_tag, encode_entries(&list))?;
            }
            if let Some(t) = &tracker {
                t.free(staged);
            }
        } else {
            let bytes = self.recv(leader, scatter_tag)?;
            let mut entries = Vec::new();
            decode_entries_into(&bytes, &mut entries)?;
            for (src, payload) in entries {
                out[src as usize] = payload;
            }
        }
        Ok(out)
    }

    /// Reduce `value` across ranks with `op` (must be associative +
    /// commutative), result on every rank. The fold is applied at the
    /// root in rank order under every algorithm, so the result is
    /// bit-identical across [`CollectiveAlgo`]s even for float ops.
    pub fn allreduce<T, F>(&self, value: T, op: F) -> Result<T>
    where
        T: FastSerialize,
        F: Fn(T, T) -> T,
    {
        let _s = crate::trace::span(crate::trace::SpanKind::Allreduce);
        let gather_tag = self.next_collective_tag();
        let bcast_tag = self.next_collective_tag();
        match self.gather_bytes(Rank::ROOT, gather_tag, to_bytes(&value))? {
            Some(slots) => {
                let mut it = slots.iter();
                let mut acc: T = from_bytes(it.next().expect("gather of >=1 rank"))?;
                for bytes in it {
                    acc = op(acc, from_bytes(bytes)?);
                }
                self.bcast_bytes(Rank::ROOT, bcast_tag, Some(to_bytes(&acc)))?;
                Ok(acc)
            }
            None => {
                let bytes = self.bcast_bytes(Rank::ROOT, bcast_tag, None)?;
                from_bytes(&bytes)
            }
        }
    }

    /// Exclusive prefix sum of `value` over ranks: rank i gets
    /// `sum(values[0..i])`. Used for global indexing in `DistVector`.
    pub fn exscan_sum(&self, value: u64) -> Result<u64> {
        let _s = crate::trace::span(crate::trace::SpanKind::Exscan);
        let all = self.allgather(value)?;
        Ok(all[..self.rank().0].iter().sum())
    }

    /// Sum of `value` across all ranks, on every rank.
    pub fn allreduce_sum_u64(&self, value: u64) -> Result<u64> {
        self.allreduce(value, |a, b| a + b)
    }

    /// Element-wise f32 vector sum across ranks (k-means sums/counts).
    pub fn allreduce_sum_f32(&self, value: Vec<f32>) -> Result<Vec<f32>> {
        self.allreduce(value, |mut a, b| {
            assert_eq!(a.len(), b.len(), "allreduce_sum_f32 length mismatch");
            for (x, y) in a.iter_mut().zip(&b) {
                *x += y;
            }
            a
        })
    }
}

/// Order gathered `(rank, bytes)` entries into rank-indexed slots.
fn rank_ordered(entries: Vec<(u64, Vec<u8>)>, n: usize) -> Result<Vec<Vec<u8>>> {
    anyhow::ensure!(entries.len() == n, "gather collected {} of {n} entries", entries.len());
    let mut slots: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
    for (rank, bytes) in entries {
        let slot = slots
            .get_mut(rank as usize)
            .ok_or_else(|| anyhow::anyhow!("gathered entry for out-of-range rank {rank}"))?;
        anyhow::ensure!(slot.is_none(), "rank {rank} contributed twice");
        *slot = Some(bytes);
    }
    Ok(slots.into_iter().map(|s| s.expect("every rank contributes once")).collect())
}

#[cfg(test)]
mod tests {
    use super::super::comm::Universe;
    use super::super::process::run_ranks;
    use super::*;
    use crate::cluster::NetworkModel;
    use crate::mpi::Topology;

    /// A 2-nodes x 2-slots universe pinned to `algo` (free network).
    fn uni(algo: CollectiveAlgo) -> Universe {
        Universe::new(Topology::block(2, 2), NetworkModel::free()).with_collective_algo(algo)
    }

    #[test]
    fn algo_parse_roundtrip_and_env_resolution() {
        for algo in CollectiveAlgo::ALL {
            assert_eq!(algo.to_string().parse::<CollectiveAlgo>().unwrap(), algo);
        }
        assert_eq!("hier".parse::<CollectiveAlgo>().unwrap(), CollectiveAlgo::Hierarchical);
        assert!("ring".parse::<CollectiveAlgo>().is_err());
        assert_eq!(CollectiveAlgo::resolve(None), CollectiveAlgo::Star);
        assert_eq!(CollectiveAlgo::resolve(Some("tree")), CollectiveAlgo::Tree);
        assert_eq!(CollectiveAlgo::resolve(Some(" tree ")), CollectiveAlgo::Tree);
        assert_eq!(CollectiveAlgo::resolve(Some("nonsense")), CollectiveAlgo::Star);
    }

    #[test]
    fn bcast_from_root() {
        let got = run_ranks(Universe::local(4), |c| {
            let v = if c.is_root() { 42u64 } else { 0 };
            c.bcast(Rank::ROOT, v).unwrap()
        });
        assert_eq!(got, vec![42; 4]);
    }

    #[test]
    fn bcast_from_nonzero_root_every_algo() {
        for algo in CollectiveAlgo::ALL {
            let got = run_ranks(uni(algo), |c| {
                let v = if c.rank().0 == 2 { format!("from2-{algo}") } else { String::new() };
                c.bcast(Rank(2), v).unwrap()
            });
            assert_eq!(got, vec![format!("from2-{algo}"); 4], "{algo}");
        }
    }

    #[test]
    fn gather_in_rank_order() {
        let got = run_ranks(Universe::local(3), |c| c.gather(Rank::ROOT, c.rank().0 as u64).unwrap());
        assert_eq!(got[0], Some(vec![0, 1, 2]));
        assert_eq!(got[1], None);
    }

    #[test]
    fn gather_to_nonleader_root_every_algo() {
        // Root = rank 3 (NOT its node's lowest rank): the hierarchical
        // path must still land the full rank-ordered vector there.
        for algo in CollectiveAlgo::ALL {
            let got = run_ranks(uni(algo), |c| c.gather(Rank(3), c.rank().0 as u64).unwrap());
            assert_eq!(got[3], Some(vec![0, 1, 2, 3]), "{algo}");
            assert!(got[..3].iter().all(Option::is_none), "{algo}");
        }
    }

    #[test]
    fn allgather_everywhere() {
        let got = run_ranks(Universe::local(3), |c| c.allgather(c.rank().0 as u32).unwrap());
        for v in got {
            assert_eq!(v, vec![0, 1, 2]);
        }
    }

    #[test]
    fn alltoallv_transpose_every_algo() {
        for algo in CollectiveAlgo::ALL {
            let got = run_ranks(uni(algo), |c| {
                let me = c.rank().0 as u8;
                // bufs[j] = [me, j]
                let bufs: Vec<Vec<u8>> = (0..4).map(|j| vec![me, j as u8]).collect();
                c.alltoallv(bufs).unwrap()
            });
            for (j, row) in got.iter().enumerate() {
                for (i, buf) in row.iter().enumerate() {
                    assert_eq!(buf, &vec![i as u8, j as u8], "{algo} src {i} dst {j}");
                }
            }
        }
    }

    #[test]
    fn allreduce_sum() {
        let got = run_ranks(Universe::local(4), |c| c.allreduce_sum_u64(c.rank().0 as u64 + 1).unwrap());
        assert_eq!(got, vec![10; 4]);
    }

    #[test]
    fn allreduce_fold_order_is_rank_order_every_algo() {
        // String concatenation is associative but NOT commutative: the
        // identical result across algorithms pins the root-side
        // rank-order fold (the bit-identity contract).
        for algo in CollectiveAlgo::ALL {
            let got = run_ranks(uni(algo), |c| {
                c.allreduce(format!("r{}", c.rank().0), |a, b| a + &b).unwrap()
            });
            assert_eq!(got, vec!["r0r1r2r3".to_string(); 4], "{algo}");
        }
    }

    #[test]
    fn allreduce_vector_sum() {
        let got = run_ranks(Universe::local(2), |c| {
            c.allreduce_sum_f32(vec![1.0, 2.0]).unwrap()
        });
        assert_eq!(got, vec![vec![2.0, 4.0]; 2]);
    }

    #[test]
    fn exscan_is_exclusive() {
        let got = run_ranks(Universe::local(4), |c| c.exscan_sum(10).unwrap());
        assert_eq!(got, vec![0, 10, 20, 30]);
    }

    #[test]
    fn barrier_syncs_clocks_every_algo() {
        use crate::cluster::DeploymentKind;
        for algo in CollectiveAlgo::ALL {
            let u = Universe::new(
                Topology::block(4, 1),
                NetworkModel::from_profile(&DeploymentKind::BareMetal.profile()),
            )
            .with_collective_algo(algo);
            let clocks = run_ranks(u, |c| {
                if c.rank().0 == 2 {
                    c.advance(5_000_000); // one slow rank
                }
                c.barrier().unwrap();
                c.clock_ns()
            });
            // After a barrier every clock is at least the slow rank's time.
            for clk in clocks {
                assert!(clk >= 5_000_000, "{algo}: clock {clk}");
            }
        }
    }

    #[test]
    fn repeated_collectives_stay_matched() {
        let got = run_ranks(Universe::local(3), |c| {
            let mut acc = 0u64;
            for i in 0..50 {
                acc += c.allreduce_sum_u64(i).unwrap();
                c.barrier().unwrap();
            }
            acc
        });
        let expect: u64 = (0..50u64).map(|i| i * 3).sum();
        assert_eq!(got, vec![expect; 3]);
    }

    #[test]
    fn mid_job_algo_switch_keeps_tags_aligned() {
        // The equivalence suite's usage pattern: one job runs the same
        // collective under all three algorithms back to back (every rank
        // switching at the same point), interleaved with barriers.
        let got = run_ranks(Universe::local(5), |c| {
            let mut sums = Vec::new();
            for algo in CollectiveAlgo::ALL {
                c.set_collective_algo(algo);
                sums.push(c.allreduce_sum_u64(c.rank().0 as u64).unwrap());
                c.barrier().unwrap();
                sums.push(c.allgather(1u64).unwrap().iter().sum::<u64>());
            }
            sums
        });
        assert_eq!(got, vec![vec![10, 5, 10, 5, 10, 5]; 5]);
    }

    #[test]
    fn tree_allreduce_touches_root_log_p_times() {
        let p = 16usize;
        let log2p = 4u64; // ceil(log2(16))
        let count_root_msgs = |algo: CollectiveAlgo| {
            let u = Universe::new(Topology::block(p, 1), NetworkModel::free())
                .with_collective_algo(algo);
            run_ranks(u, |c| {
                c.allreduce_sum_u64(1).unwrap();
                c.sent_messages() + c.received_messages()
            })[0]
        };
        let star = count_root_msgs(CollectiveAlgo::Star);
        let tree = count_root_msgs(CollectiveAlgo::Tree);
        assert_eq!(star, 2 * (p as u64 - 1), "star root touches O(P) messages");
        assert_eq!(tree, 2 * log2p, "tree root touches O(log P) messages");
    }

    #[test]
    fn coalesced_alltoallv_cuts_cross_node_messages() {
        let remote_msgs = |algo: CollectiveAlgo| {
            // 4 nodes x 4 slots.
            let u = Universe::new(Topology::block(4, 4), NetworkModel::free())
                .with_collective_algo(algo);
            let stats = u.stats();
            run_ranks(u, |c| {
                let bufs: Vec<Vec<u8>> =
                    (0..c.size()).map(|j| vec![c.rank().0 as u8; j + 1]).collect();
                let got = c.alltoallv(bufs).unwrap();
                // Every source sent this rank a (rank + 1)-byte buffer.
                let total: usize = got.iter().map(Vec::len).sum();
                assert_eq!(total, 16 * (c.rank().0 + 1));
            });
            stats.snapshot().2
        };
        let star = remote_msgs(CollectiveAlgo::Star);
        let hier = remote_msgs(CollectiveAlgo::Hierarchical);
        // Star: each of 16 ranks sends to 12 remote ranks = 192 remote
        // messages. Coalesced: each rank sends 3 bundles = 48.
        assert_eq!(star, 16 * 12);
        assert_eq!(hier, 16 * 3);
    }
}
