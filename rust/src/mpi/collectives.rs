//! Collective operations over [`Communicator`], built from p2p sends so
//! the virtual clock sees every byte and every synchronization point.
//!
//! Tag discipline: collectives allocate tags from a per-rank sequence
//! counter ([`Communicator::next_collective_tag`]). Programs are SPMD —
//! every rank executes the same collective sequence — so counters stay
//! aligned without negotiation, the same assumption MPI makes about
//! communicator-ordered collectives.
//!
//! The blocking shapes matter for the paper: `alltoallv` is the shuffle
//! (MR-MPI's `MPI_Alltoall` §II), and `barrier`/`allreduce` are the global
//! synchronization points Mimir blames for MR-MPI's memory retention.

use anyhow::Result;

use crate::serial::{from_bytes, to_bytes, FastSerialize};

use super::comm::Communicator;
use super::datatypes::Rank;

impl Communicator {
    /// Synchronize all ranks (and their virtual clocks) — gather-to-root
    /// then broadcast, the classic two-phase tree flattened to star shape
    /// (fine at our rank counts; cost model charges per message).
    pub fn barrier(&self) -> Result<()> {
        let gather_tag = self.next_collective_tag();
        let release_tag = self.next_collective_tag();
        if self.is_root() {
            for _ in 1..self.size() {
                let _ = self.recv_any(gather_tag)?;
            }
            for r in 1..self.size() {
                self.send(Rank(r), release_tag, Vec::new())?;
            }
        } else {
            self.send(Rank::ROOT, gather_tag, Vec::new())?;
            self.recv(Rank::ROOT, release_tag)?;
        }
        Ok(())
    }

    /// Broadcast `value` from `root` to all ranks. Non-root ranks pass
    /// their (ignored) local value too — SPMD style.
    pub fn bcast<T: FastSerialize>(&self, root: Rank, value: T) -> Result<T> {
        let tag = self.next_collective_tag();
        if self.rank() == root {
            let bytes = to_bytes(&value);
            for r in 0..self.size() {
                if r != root.0 {
                    self.send(Rank(r), tag, bytes.clone())?;
                }
            }
            Ok(value)
        } else {
            let bytes = self.recv(root, tag)?;
            from_bytes(&bytes)
        }
    }

    /// Gather every rank's value at `root`. Returns `Some(values)` (rank
    /// order) at root, `None` elsewhere.
    pub fn gather<T: FastSerialize>(&self, root: Rank, value: T) -> Result<Option<Vec<T>>> {
        let tag = self.next_collective_tag();
        if self.rank() == root {
            let mut slots: Vec<Option<T>> = (0..self.size()).map(|_| None).collect();
            slots[root.0] = Some(value);
            for _ in 1..self.size() {
                let (src, bytes) = self.recv_any(tag)?;
                slots[src.0] = Some(from_bytes(&bytes)?);
            }
            Ok(Some(slots.into_iter().map(Option::unwrap).collect()))
        } else {
            self.send(root, tag, to_bytes(&value))?;
            Ok(None)
        }
    }

    /// Gather at root, then broadcast the vector to everyone.
    pub fn allgather<T: FastSerialize + Clone>(&self, value: T) -> Result<Vec<T>> {
        let gathered = self.gather(Rank::ROOT, value)?;
        self.bcast(Rank::ROOT, gathered.unwrap_or_default())
    }

    /// The shuffle primitive: rank i's `bufs[j]` is delivered as the
    /// return value's element i on rank j. `bufs.len()` must equal world
    /// size; `bufs[self]` short-circuits without touching the network.
    pub fn alltoallv(&self, mut bufs: Vec<Vec<u8>>) -> Result<Vec<Vec<u8>>> {
        anyhow::ensure!(
            bufs.len() == self.size(),
            "alltoallv needs one buffer per rank ({} != {})",
            bufs.len(),
            self.size()
        );
        let tag = self.next_collective_tag();
        let me = self.rank().0;
        let mut out: Vec<Vec<u8>> = (0..self.size()).map(|_| Vec::new()).collect();
        out[me] = std::mem::take(&mut bufs[me]);
        // Send everything first (injection serializes on the sender's
        // uplink — realistic), then receive; arrivals settle the clock at
        // max(sender_stamp + propagation) instead of cascading (n-1)
        // latencies through a ring.
        for d in 1..self.size() {
            let dst = (me + d) % self.size();
            self.send(Rank(dst), tag, std::mem::take(&mut bufs[dst]))?;
        }
        for d in 1..self.size() {
            let src = (me + self.size() - d) % self.size();
            out[src] = self.recv(Rank(src), tag)?;
        }
        Ok(out)
    }

    /// Reduce `value` across ranks with `op` (must be associative +
    /// commutative), result on every rank.
    pub fn allreduce<T, F>(&self, value: T, op: F) -> Result<T>
    where
        T: FastSerialize + Clone,
        F: Fn(T, T) -> T,
    {
        // Allocate the result-distribution tag BEFORE gather so every
        // rank's collective sequence stays aligned.
        let tag = self.next_collective_tag();
        let gathered = self.gather(Rank::ROOT, value)?;
        if self.is_root() {
            let mut it = gathered.expect("root gathers").into_iter();
            let first = it.next().expect("gather of >=1 rank");
            let reduced = it.fold(first, &op);
            let bytes = to_bytes(&reduced);
            for r in 1..self.size() {
                self.send(Rank(r), tag, bytes.clone())?;
            }
            Ok(reduced)
        } else {
            let bytes = self.recv(Rank::ROOT, tag)?;
            from_bytes(&bytes)
        }
    }

    /// Exclusive prefix sum of `value` over ranks: rank i gets
    /// `sum(values[0..i])`. Used for global indexing in `DistVector`.
    pub fn exscan_sum(&self, value: u64) -> Result<u64> {
        let all = self.allgather(value)?;
        Ok(all[..self.rank().0].iter().sum())
    }

    /// Sum of `value` across all ranks, on every rank.
    pub fn allreduce_sum_u64(&self, value: u64) -> Result<u64> {
        self.allreduce(value, |a, b| a + b)
    }

    /// Element-wise f32 vector sum across ranks (k-means sums/counts).
    pub fn allreduce_sum_f32(&self, value: Vec<f32>) -> Result<Vec<f32>> {
        self.allreduce(value, |mut a, b| {
            assert_eq!(a.len(), b.len(), "allreduce_sum_f32 length mismatch");
            for (x, y) in a.iter_mut().zip(&b) {
                *x += y;
            }
            a
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::comm::Universe;
    use super::super::process::run_ranks;
    use super::*;

    #[test]
    fn bcast_from_root() {
        let got = run_ranks(Universe::local(4), |c| {
            let v = if c.is_root() { 42u64 } else { 0 };
            c.bcast(Rank::ROOT, v).unwrap()
        });
        assert_eq!(got, vec![42; 4]);
    }

    #[test]
    fn gather_in_rank_order() {
        let got = run_ranks(Universe::local(3), |c| c.gather(Rank::ROOT, c.rank().0 as u64).unwrap());
        assert_eq!(got[0], Some(vec![0, 1, 2]));
        assert_eq!(got[1], None);
    }

    #[test]
    fn allgather_everywhere() {
        let got = run_ranks(Universe::local(3), |c| c.allgather(c.rank().0 as u32).unwrap());
        for v in got {
            assert_eq!(v, vec![0, 1, 2]);
        }
    }

    #[test]
    fn alltoallv_transpose() {
        let got = run_ranks(Universe::local(3), |c| {
            let me = c.rank().0 as u8;
            // bufs[j] = [me, j]
            let bufs: Vec<Vec<u8>> = (0..3).map(|j| vec![me, j as u8]).collect();
            c.alltoallv(bufs).unwrap()
        });
        for (j, row) in got.iter().enumerate() {
            for (i, buf) in row.iter().enumerate() {
                assert_eq!(buf, &vec![i as u8, j as u8]);
            }
        }
    }

    #[test]
    fn allreduce_sum() {
        let got = run_ranks(Universe::local(4), |c| c.allreduce_sum_u64(c.rank().0 as u64 + 1).unwrap());
        assert_eq!(got, vec![10; 4]);
    }

    #[test]
    fn allreduce_vector_sum() {
        let got = run_ranks(Universe::local(2), |c| {
            c.allreduce_sum_f32(vec![1.0, 2.0]).unwrap()
        });
        assert_eq!(got, vec![vec![2.0, 4.0]; 2]);
    }

    #[test]
    fn exscan_is_exclusive() {
        let got = run_ranks(Universe::local(4), |c| c.exscan_sum(10).unwrap());
        assert_eq!(got, vec![0, 10, 20, 30]);
    }

    #[test]
    fn barrier_syncs_clocks() {
        use crate::cluster::{DeploymentKind, NetworkModel};
        use crate::mpi::Topology;
        let uni = Universe::new(
            Topology::block(4, 1),
            NetworkModel::from_profile(&DeploymentKind::BareMetal.profile()),
        );
        let clocks = run_ranks(uni, |c| {
            if c.rank().0 == 2 {
                c.advance(5_000_000); // one slow rank
            }
            c.barrier().unwrap();
            c.clock_ns()
        });
        // After a barrier every clock is at least the slow rank's time.
        for clk in clocks {
            assert!(clk >= 5_000_000, "clock {clk}");
        }
    }

    #[test]
    fn repeated_collectives_stay_matched() {
        let got = run_ranks(Universe::local(3), |c| {
            let mut acc = 0u64;
            for i in 0..50 {
                acc += c.allreduce_sum_u64(i).unwrap();
                c.barrier().unwrap();
            }
            acc
        });
        let expect: u64 = (0..50u64).map(|i| i * 3).sum();
        assert_eq!(got, vec![expect; 3]);
    }
}
