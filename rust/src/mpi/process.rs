//! The rank launcher — our `mpirun`.
//!
//! Spawns one OS thread per rank, hands each its [`Communicator`], and
//! joins them, propagating panics. SPMD like MPI: every rank runs the same
//! closure, branching on `comm.rank()`.

use super::comm::{Communicator, Universe};

/// Run `f` on every rank of `universe`; results returned in rank order.
///
/// Panics in any rank abort the whole job (matching the paper's complaint
/// that "MPI isn't fault tolerant" — controlled failure handling lives a
/// layer up in [`crate::cluster::FaultTracker`]).
pub fn run_ranks<T, F>(universe: Universe, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&Communicator) -> T + Sync,
{
    run_ranks_with_universe(universe, f).0
}

/// Like [`run_ranks`], also returning the universe-wide traffic stats and
/// the per-rank virtual clocks `(results, (clocks_ns, compute_ns, net_ns))`.
#[allow(clippy::type_complexity)]
pub fn run_ranks_with_universe<T, F>(
    universe: Universe,
    f: F,
) -> (Vec<T>, Vec<(u64, u64, u64)>)
where
    T: Send,
    F: Fn(&Communicator) -> T + Sync,
{
    let comms = universe.communicators();
    let f = &f;
    let results: Vec<(T, (u64, u64, u64))> = std::thread::scope(|scope| {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                scope.spawn(move || {
                    let out = f(&comm);
                    (out, (comm.clock_ns(), comm.compute_ns(), comm.net_wait_ns()))
                })
            })
            .collect();
        handles
            .into_iter()
            .enumerate()
            .map(|(i, h)| match h.join() {
                Ok(v) => v,
                Err(e) => std::panic::panic_any(format!("rank {i} panicked: {e:?}")),
            })
            .collect()
    });
    let mut outs = Vec::with_capacity(results.len());
    let mut clocks = Vec::with_capacity(results.len());
    for (out, clk) in results {
        outs.push(out);
        clocks.push(clk);
    }
    (outs, clocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::{Rank, Tag};

    #[test]
    fn spmd_results_in_rank_order() {
        let got = run_ranks(Universe::local(5), |c| c.rank().0 * 10);
        assert_eq!(got, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn ranks_communicate_inside_runner() {
        let got = run_ranks(Universe::local(2), |c| {
            if c.is_root() {
                c.send(Rank(1), Tag::user(0), vec![9]).unwrap();
                0u8
            } else {
                c.recv(Rank(0), Tag::user(0)).unwrap()[0]
            }
        });
        assert_eq!(got, vec![0, 9]);
    }

    #[test]
    fn clocks_are_reported() {
        let (_, clocks) = run_ranks_with_universe(Universe::local(2), |c| {
            c.advance(1_000);
        });
        assert!(clocks.iter().all(|&(clk, comp, _)| clk == 1_000 && comp == 1_000));
    }

    #[test]
    #[should_panic(expected = "rank 1 panicked")]
    fn rank_panic_propagates() {
        run_ranks(Universe::local(2), |c| {
            if c.rank().0 == 1 {
                panic!("boom");
            }
        });
    }
}
