//! The rank launcher — our `mpirun`.
//!
//! One-shot SPMD: every rank runs the same closure, branching on
//! `comm.rank()`. Since the pooled-executor refactor this is a thin
//! wrapper that builds a throwaway [`RankPool`] for the universe, runs a
//! single job on it, and tears it down — iterative callers should hold a
//! [`RankPool`] instead and pay thread start-up once.

use super::comm::{Communicator, Universe};
use super::pool::RankPool;

/// Run `f` on every rank of `universe`; results returned in rank order.
///
/// Panics in any rank abort the whole job (matching the paper's complaint
/// that "MPI isn't fault tolerant" — controlled failure handling lives a
/// layer up in [`crate::cluster::FaultTracker`]).
pub fn run_ranks<T, F>(universe: Universe, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&Communicator) -> T + Sync,
{
    run_ranks_with_universe(universe, f).0
}

/// Like [`run_ranks`], also returning
/// the per-rank virtual clocks `(results, (clocks_ns, compute_ns, net_ns))`.
#[allow(clippy::type_complexity)]
pub fn run_ranks_with_universe<T, F>(
    universe: Universe,
    f: F,
) -> (Vec<T>, Vec<(u64, u64, u64)>)
where
    T: Send,
    F: Fn(&Communicator) -> T + Sync,
{
    let pool = RankPool::new(universe);
    let out = pool.run_job(pool.size(), f);
    (out.results, out.clocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::{Rank, Tag};

    #[test]
    fn spmd_results_in_rank_order() {
        let got = run_ranks(Universe::local(5), |c| c.rank().0 * 10);
        assert_eq!(got, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn ranks_communicate_inside_runner() {
        let got = run_ranks(Universe::local(2), |c| {
            if c.is_root() {
                c.send(Rank(1), Tag::user(0), vec![9]).unwrap();
                0u8
            } else {
                c.recv(Rank(0), Tag::user(0)).unwrap()[0]
            }
        });
        assert_eq!(got, vec![0, 9]);
    }

    #[test]
    fn clocks_are_reported() {
        let (_, clocks) = run_ranks_with_universe(Universe::local(2), |c| {
            c.advance(1_000);
        });
        assert!(clocks.iter().all(|&(clk, comp, _)| clk == 1_000 && comp == 1_000));
    }

    #[test]
    #[should_panic(expected = "rank 1 panicked")]
    fn rank_panic_propagates() {
        run_ranks(Universe::local(2), |c| {
            if c.rank().0 == 1 {
                panic!("boom");
            }
        });
    }
}
