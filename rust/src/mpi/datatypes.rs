//! Core message-passing datatypes: ranks, tags, wire messages.

use std::fmt;

/// A process index within a [`super::Universe`], 0-based like MPI ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Rank(pub usize);

impl Rank {
    pub const ROOT: Rank = Rank(0);

    pub fn index(self) -> usize {
        self.0
    }

    pub fn is_root(self) -> bool {
        self.0 == 0
    }
}

impl fmt::Display for Rank {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rank{}", self.0)
    }
}

impl From<usize> for Rank {
    fn from(i: usize) -> Self {
        Rank(i)
    }
}

/// Message tag. User point-to-point tags live below
/// [`Tag::COLLECTIVE_BASE`]; the collective layer allocates its own tags
/// above it from a per-rank sequence counter so deterministic program
/// order keeps them matched across ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tag(pub u64);

impl Tag {
    pub const COLLECTIVE_BASE: u64 = 1 << 32;

    pub fn user(t: u32) -> Self {
        Tag(t as u64)
    }

    pub(crate) fn collective(seq: u64) -> Self {
        Tag(Self::COLLECTIVE_BASE + seq)
    }
}

/// A wire message: payload plus the sender's virtual clock (ns, how
/// modeled network time propagates — see module docs) and the pooled-job
/// epoch it was sent in (how receivers discard stale in-flight frames
/// from a previous job — see `Communicator`).
///
/// `span` is the tracing span id riding the frame (0 when tracing is
/// off): the receiver links its `Recv` span — and any worker process
/// relaying the frame links its `Relay` span — back to the sender's
/// `Send` span, which is how one causal timeline is stitched across
/// real process boundaries. It is metadata only: modeled costs are
/// functions of `payload.len()`, so tracing never perturbs clocks.
#[derive(Debug)]
pub struct Message {
    pub src: Rank,
    pub tag: Tag,
    pub epoch: u64,
    pub clock_ns: u64,
    pub span: u64,
    pub payload: Vec<u8>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_display_and_root() {
        assert_eq!(Rank(3).to_string(), "rank3");
        assert!(Rank::ROOT.is_root());
        assert!(!Rank(1).is_root());
    }

    #[test]
    fn collective_tags_are_disjoint_from_user_tags() {
        assert!(Tag::collective(0).0 >= Tag::COLLECTIVE_BASE);
        assert!(Tag::user(u32::MAX).0 < Tag::COLLECTIVE_BASE);
    }
}
