//! The communicator: per-rank endpoint of the message-passing universe,
//! with virtual-clock cost accounting (see module docs in `mpi/mod.rs`).
//! The substrate beneath it — in-process mailboxes or TCP rank
//! processes — is a [`Transport`] chosen per universe.

use std::cell::{Cell, RefCell};
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::cluster::{ClusterConfig, NetworkModel};
use crate::metrics::PeakTracker;

use super::collectives::CollectiveAlgo;
use super::datatypes::{Message, Rank, Tag};
use super::topology::Topology;
use super::transport::{MailboxTransport, Transport, TransportKind};

/// Whole-universe traffic counters (atomics — written by all ranks).
#[derive(Debug, Default)]
pub struct TrafficStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
    pub remote_messages: AtomicU64,
    pub remote_bytes: AtomicU64,
}

impl TrafficStats {
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.messages.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
            self.remote_messages.load(Ordering::Relaxed),
            self.remote_bytes.load(Ordering::Relaxed),
        )
    }
}

/// Factory for a set of connected [`Communicator`]s: the "MPI world".
pub struct Universe {
    topology: Topology,
    network: NetworkModel,
    algo: CollectiveAlgo,
    transport: TransportKind,
    worker_bin: Option<PathBuf>,
    stats: Arc<TrafficStats>,
}

impl Universe {
    /// A universe with the collective algorithm resolved from the
    /// `BLAZE_COLLECTIVE_ALGO` environment (default
    /// [`CollectiveAlgo::Star`]) and the transport from `BLAZE_TRANSPORT`
    /// (default [`TransportKind::Mailbox`]); override with
    /// [`Universe::with_collective_algo`] / [`Universe::with_transport`].
    pub fn new(topology: Topology, network: NetworkModel) -> Self {
        Self {
            topology,
            network,
            algo: CollectiveAlgo::from_env_or_default(),
            transport: TransportKind::from_env_or_default(),
            worker_bin: None,
            stats: Arc::new(TrafficStats::default()),
        }
    }

    /// The universe a [`ClusterConfig`] describes: placement, network
    /// model, collective algorithm, transport (and worker binary for
    /// TCP), each following its own explicit > env > default resolution.
    pub fn from_cluster(cfg: &ClusterConfig) -> Self {
        Self::new(Topology::from_config(cfg), cfg.network_model())
            .with_collective_algo(cfg.collective_algo())
            .with_transport(cfg.transport())
            .with_worker_binary_opt(cfg.worker_bin.clone())
    }

    /// A universe of `n` ranks on one Local-profile node — unit tests.
    pub fn local(n: usize) -> Self {
        Self::new(Topology::single_node(n), NetworkModel::free())
    }

    /// Pin the collective algorithm (explicit beats the env default).
    pub fn with_collective_algo(mut self, algo: CollectiveAlgo) -> Self {
        self.algo = algo;
        self
    }

    /// Pin the transport substrate (explicit beats the env default).
    pub fn with_transport(mut self, transport: TransportKind) -> Self {
        self.transport = transport;
        self
    }

    /// Worker binary for the TCP transport (explicit beats the
    /// `BLAZE_WORKER_BIN` env beats the current executable).
    pub fn with_worker_binary(self, bin: impl Into<PathBuf>) -> Self {
        self.with_worker_binary_opt(Some(bin.into()))
    }

    pub(crate) fn with_worker_binary_opt(mut self, bin: Option<PathBuf>) -> Self {
        self.worker_bin = bin;
        self
    }

    pub fn collective_algo(&self) -> CollectiveAlgo {
        self.algo
    }

    pub fn transport_kind(&self) -> TransportKind {
        self.transport
    }

    pub fn size(&self) -> usize {
        self.topology.ranks()
    }

    pub fn stats(&self) -> Arc<TrafficStats> {
        self.stats.clone()
    }

    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    pub fn network(&self) -> &NetworkModel {
        &self.network
    }

    /// Build one [`Communicator`] per rank. Consumes the universe; the
    /// stats handle survives via [`Universe::stats`]. Panics if the TCP
    /// fleet cannot be launched — use [`Universe::build`] for the
    /// fallible form.
    pub fn communicators(self) -> Vec<Communicator> {
        self.build().expect("building communicators").0
    }

    /// Fallible [`Universe::communicators`]: also returns the spawned
    /// worker PIDs (empty for the mailbox transport) so shutdown tests
    /// can assert no orphans outlive the pool.
    pub fn build(self) -> Result<(Vec<Communicator>, Vec<u32>)> {
        let n = self.size();
        let (transports, worker_pids): (Vec<Box<dyn Transport>>, Vec<u32>) = match self.transport
        {
            TransportKind::Mailbox => {
                let mut senders = Vec::with_capacity(n);
                let mut receivers = Vec::with_capacity(n);
                for _ in 0..n {
                    let (tx, rx) = channel::<Message>();
                    senders.push(tx);
                    receivers.push(rx);
                }
                let senders = Arc::new(senders);
                let boxed = receivers
                    .into_iter()
                    .map(|rx| {
                        Box::new(MailboxTransport::new(senders.clone(), rx)) as Box<dyn Transport>
                    })
                    .collect();
                (boxed, Vec::new())
            }
            TransportKind::Tcp if n == 0 => (Vec::new(), Vec::new()),
            TransportKind::Tcp => super::tcp::launch_fleet(n, self.worker_bin.as_deref())?,
        };
        let topology = Arc::new(self.topology);
        let network = Arc::new(self.network);
        // One identity group (local rank i == global rank i) shared by
        // every communicator; a RankPool swaps in per-job subsets.
        let identity: Arc<Vec<Rank>> = Arc::new((0..n).map(Rank).collect());
        let comms = transports
            .into_iter()
            .enumerate()
            .map(|(i, transport)| Communicator {
                rank: Rank(i),
                world: n,
                group: RefCell::new(identity.clone()),
                local: Cell::new(Rank(i)),
                identity: identity.clone(),
                transport,
                pending: RefCell::new(HashMap::new()),
                epoch: Cell::new(0),
                topology: topology.clone(),
                job_topo: RefCell::new(topology.clone()),
                network: network.clone(),
                stats: self.stats.clone(),
                mem: RefCell::new(None),
                clock_ns: Cell::new(0),
                compute_ns: Cell::new(0),
                net_wait_ns: Cell::new(0),
                collective_seq: Cell::new(0),
                default_algo: self.algo,
                algo: Cell::new(self.algo),
                sent_messages: Cell::new(0),
                sent_bytes: Cell::new(0),
                sent_remote_messages: Cell::new(0),
                sent_remote_bytes: Cell::new(0),
                received_messages: Cell::new(0),
            })
            .collect();
        Ok((comms, worker_pids))
    }
}

/// Per-rank communication endpoint. NOT `Sync` — each rank thread owns its
/// communicator exclusively, exactly like an MPI process owns its
/// `MPI_COMM_WORLD` slot.
pub struct Communicator {
    /// Global rank: this endpoint's fixed position in the universe.
    rank: Rank,
    /// Ranks wired into this universe (mailboxes, senders).
    world: usize,
    /// Ranks participating in the *current* job, in job order: local
    /// rank `i` is global rank `group[i]`. The identity mapping for a
    /// one-shot universe; a [`crate::mpi::RankPool`] swaps in a subset
    /// per job so disjoint jobs can run concurrently on one pool.
    group: RefCell<Arc<Vec<Rank>>>,
    /// This endpoint's job-local rank (its index in `group`).
    local: Cell<Rank>,
    /// Cached identity group, restored between pooled jobs.
    identity: Arc<Vec<Rank>>,
    /// The substrate moving bytes: in-process mailboxes or TCP rank
    /// processes — everything above this field is transport-agnostic.
    transport: Box<dyn Transport>,
    /// Out-of-order buffer: messages received while waiting for a
    /// different (src, tag).
    pending: RefCell<HashMap<(Rank, Tag), VecDeque<Message>>>,
    /// Pooled-job generation. Sends stamp it into every message; recv
    /// drops frames from older epochs. Over TCP a previous job's frame
    /// can still be in flight through the worker mesh when the next job
    /// starts (drain can't reach it), so the epoch — bumped in lockstep
    /// by every rank during the pool's prepare barrier — is what makes
    /// inter-job isolation exact on every transport.
    epoch: Cell<u64>,
    /// World topology — global-rank indexed; cost accounting (same-node
    /// tests, compute scaling) always consults this one.
    topology: Arc<Topology>,
    /// Job-view topology — local-rank indexed; what collectives see so a
    /// subset job groups its ranks by node exactly like a fresh universe
    /// of that shape would. Equal to `topology` for the identity group.
    job_topo: RefCell<Arc<Topology>>,
    network: Arc<NetworkModel>,
    stats: Arc<TrafficStats>,
    /// Optional tracker charged for transport-internal staging buffers
    /// (hierarchical alltoallv node leaders); attached by the shuffle
    /// while a collective runs, cleared between pooled jobs.
    mem: RefCell<Option<Arc<PeakTracker>>>,
    /// Virtual time (ns): compute charged via [`Communicator::advance`] /
    /// [`Communicator::timed`], network via message receipt.
    clock_ns: Cell<u64>,
    compute_ns: Cell<u64>,
    net_wait_ns: Cell<u64>,
    collective_seq: Cell<u64>,
    /// The universe's algorithm, restored between pooled jobs.
    default_algo: CollectiveAlgo,
    /// Collective algorithm currently in effect (see
    /// [`Communicator::set_collective_algo`]).
    algo: Cell<CollectiveAlgo>,
    /// Per-rank traffic, reset per pooled job — this is what lets tests
    /// and figures see that a tree allreduce touches the root O(log P)
    /// times where the star touches it O(P) times.
    sent_messages: Cell<u64>,
    sent_bytes: Cell<u64>,
    /// Of those, messages/bytes that crossed a node boundary — summed per
    /// job subset by the pool, so concurrent jobs never see each other's
    /// traffic (the universe-wide [`TrafficStats`] cannot distinguish
    /// simultaneous jobs).
    sent_remote_messages: Cell<u64>,
    sent_remote_bytes: Cell<u64>,
    received_messages: Cell<u64>,
}

impl Communicator {
    /// Job-local rank: this endpoint's index within the current job's
    /// group. Equals [`Communicator::global_rank`] outside a pool.
    pub fn rank(&self) -> Rank {
        self.local.get()
    }

    /// Global rank: fixed position in the universe, independent of any
    /// job-group narrowing.
    pub fn global_rank(&self) -> Rank {
        self.rank
    }

    /// Ranks participating in the current job (collectives span these).
    pub fn size(&self) -> usize {
        self.group.borrow().len()
    }

    /// Ranks physically wired into the universe (>= [`Communicator::size`]).
    pub fn world_size(&self) -> usize {
        self.world
    }

    pub fn is_root(&self) -> bool {
        self.local.get().is_root()
    }

    /// The current job's topology view, local-rank indexed. A subset job
    /// sees its own ranks re-numbered `0..size()` with the parent's node
    /// structure projected through — so the hierarchical collectives
    /// group leaders exactly as a fresh universe of this shape would.
    pub fn topology(&self) -> Arc<Topology> {
        self.job_topo.borrow().clone()
    }

    /// Current virtual time in ns.
    pub fn clock_ns(&self) -> u64 {
        self.clock_ns.get()
    }

    /// Virtual ns spent computing (vs waiting on the network).
    pub fn compute_ns(&self) -> u64 {
        self.compute_ns.get()
    }

    /// Virtual ns attributed to network transfer/wait.
    pub fn net_wait_ns(&self) -> u64 {
        self.net_wait_ns.get()
    }

    /// Collective algorithm currently in effect on this rank.
    pub fn collective_algo(&self) -> CollectiveAlgo {
        self.algo.get()
    }

    /// Switch the collective algorithm. SPMD discipline applies: every
    /// rank of a job must switch at the same point in its collective
    /// sequence, exactly like the tag counter — the equivalence suite
    /// uses this to compare algorithms on one warm pool. Reset to the
    /// universe's algorithm between pooled jobs.
    pub fn set_collective_algo(&self, algo: CollectiveAlgo) {
        self.algo.set(algo);
    }

    /// Messages this rank has sent in the current job.
    pub fn sent_messages(&self) -> u64 {
        self.sent_messages.get()
    }

    /// Payload bytes this rank has sent in the current job.
    pub fn sent_bytes(&self) -> u64 {
        self.sent_bytes.get()
    }

    /// Messages this rank has consumed (matched by a recv) in the
    /// current job.
    pub fn received_messages(&self) -> u64 {
        self.received_messages.get()
    }

    /// Messages this rank has sent across node boundaries in the
    /// current job.
    pub fn sent_remote_messages(&self) -> u64 {
        self.sent_remote_messages.get()
    }

    /// Payload bytes this rank has sent across node boundaries in the
    /// current job.
    pub fn sent_remote_bytes(&self) -> u64 {
        self.sent_remote_bytes.get()
    }

    pub(crate) fn next_collective_tag(&self) -> Tag {
        let seq = self.collective_seq.get();
        self.collective_seq.set(seq + 1);
        Tag::collective(seq)
    }

    /// Narrow the communicator to a job group for the duration of one
    /// pooled job (see [`crate::mpi::RankPool`]). `group` lists the
    /// member *global* ranks in job order and must contain this rank.
    /// The identity prefix `[0, 1, .., n-1]` keeps the world topology
    /// view; any other subset projects it with [`Topology::select`].
    pub(crate) fn set_group(&self, group: Arc<Vec<Rank>>) {
        let local = group
            .iter()
            .position(|r| *r == self.rank)
            .unwrap_or_else(|| panic!("rank {} not in job group {group:?}", self.rank));
        self.local.set(Rank(local));
        let is_prefix = group.iter().enumerate().all(|(i, r)| r.0 == i);
        let topo = if is_prefix {
            self.topology.clone()
        } else {
            Arc::new(self.topology.select(&group))
        };
        *self.job_topo.borrow_mut() = topo;
        *self.group.borrow_mut() = group;
    }

    /// Restore fresh-universe state between pooled jobs: discard any
    /// unconsumed messages (matched or buffered), zero the virtual clocks,
    /// realign the collective tag sequence, and enter the job's `epoch` —
    /// a pool-global job id, so concurrently running jobs on disjoint
    /// subsets live in different epochs and never accept each other's
    /// frames. Called by the pool's prepare phase, after every member rank
    /// of the previous job on this endpoint has finished and before any
    /// rank of the next job starts.
    pub(crate) fn reset_job_state(&self, epoch: u64) {
        self.transport.drain();
        self.epoch.set(epoch);
        self.pending.borrow_mut().clear();
        self.mem.borrow_mut().take();
        self.clock_ns.set(0);
        self.compute_ns.set(0);
        self.net_wait_ns.set(0);
        self.collective_seq.set(0);
        *self.group.borrow_mut() = self.identity.clone();
        self.local.set(self.rank);
        *self.job_topo.borrow_mut() = self.topology.clone();
        self.algo.set(self.default_algo);
        self.sent_messages.set(0);
        self.sent_bytes.set(0);
        self.sent_remote_messages.set(0);
        self.sent_remote_bytes.set(0);
        self.received_messages.set(0);
    }

    /// Pooled-job epoch this communicator is currently in.
    pub(crate) fn epoch(&self) -> u64 {
        self.epoch.get()
    }

    /// Charge `ns` of modeled compute time to this rank's clock.
    pub fn advance(&self, ns: u64) {
        self.clock_ns.set(self.clock_ns.get() + ns);
        self.compute_ns.set(self.compute_ns.get() + ns);
        crate::trace::set_vclock(self.clock_ns.get());
    }

    /// Charge `ns` of compute scaled by this rank's deployment factor
    /// (how "an RPi is ~8x slower" enters the curves). Used for work done
    /// on behalf of this rank elsewhere (e.g. the compute service).
    pub fn advance_scaled(&self, ns: u64) {
        let scale = self.topology.compute_scale(self.rank);
        self.advance((ns as f64 * scale) as u64);
    }

    /// Run `f`, measure the *thread CPU time* it consumes, charge it
    /// scaled by the deployment's compute factor. Thread CPU time (not
    /// wall) keeps rank charges correct when the host has fewer cores
    /// than simulated ranks — see util::cputime.
    pub fn timed<T>(&self, f: impl FnOnce() -> T) -> T {
        let start = crate::util::cputime::thread_cpu_time_ns();
        let out = f();
        let used = crate::util::cputime::thread_cpu_time_ns().saturating_sub(start);
        self.advance_scaled(used);
        out
    }

    /// Global rank of job-local `local` (index into the current group).
    fn to_global(&self, local: Rank) -> Rank {
        self.group.borrow()[local.0]
    }

    /// Job-local rank of global `global`. Only called on message sources,
    /// which epoch fencing guarantees are members of the current group.
    fn to_local(&self, global: Rank) -> Rank {
        let group = self.group.borrow();
        let i = group
            .iter()
            .position(|r| *r == global)
            .unwrap_or_else(|| panic!("sender {global} not in job group {group:?}"));
        Rank(i)
    }

    /// Point-to-point send (non-blocking, unbounded buffering — MPI's
    /// eager protocol for our message sizes). `dst` is a job-local rank.
    pub fn send(&self, dst: Rank, tag: Tag, payload: Vec<u8>) -> Result<()> {
        ensure!(dst.0 < self.size(), "send to {dst} outside universe of {}", self.size());
        let dst = self.to_global(dst);
        let bytes = payload.len() as u64;
        let same_node = self.topology.same_node(self.rank, dst);
        self.sent_messages.set(self.sent_messages.get() + 1);
        self.sent_bytes.set(self.sent_bytes.get() + bytes);
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes.fetch_add(bytes, Ordering::Relaxed);
        if !same_node {
            self.sent_remote_messages.set(self.sent_remote_messages.get() + 1);
            self.sent_remote_bytes.set(self.sent_remote_bytes.get() + bytes);
            self.stats.remote_messages.fetch_add(1, Ordering::Relaxed);
            self.stats.remote_bytes.fetch_add(bytes, Ordering::Relaxed);
        }
        // Sender pays injection serially (per-message envelope overhead +
        // uplink bandwidth) — this is the term that makes chatty shuffles
        // anti-scale with node count (paper Fig 10). The message is
        // stamped post-injection; the receiver adds propagation latency.
        let inject = self.network.injection_ns(payload.len(), same_node);
        self.clock_ns.set(self.clock_ns.get() + inject);
        self.net_wait_ns.set(self.net_wait_ns.get() + inject);
        // Span id for the frame (0 when tracing is off). Allocated after
        // the injection charge so the Send instant sits at the stamped
        // clock; never charged to the clock itself.
        let span = if crate::trace::enabled() {
            crate::trace::set_vclock(self.clock_ns.get());
            crate::trace::on_send(tag.0, bytes)
        } else {
            0
        };
        self.transport.send(
            dst,
            Message {
                src: self.rank,
                tag,
                epoch: self.epoch.get(),
                clock_ns: self.clock_ns.get(),
                span,
                payload,
            },
        )
    }

    /// Blocking receive matched on (src, tag), `src` job-local. Advances
    /// the virtual clock per the Lamport-with-costs rule.
    pub fn recv(&self, src: Rank, tag: Tag) -> Result<Vec<u8>> {
        let src = self.to_global(src);
        // Already buffered? (pending map is keyed by global src)
        if let Some(msg) = self.pop_pending(src, tag) {
            return Ok(self.absorb(msg));
        }
        loop {
            let msg = self.transport.recv()?;
            if msg.epoch != self.epoch.get() {
                continue; // stale frame from a previous/concurrent pooled job
            }
            if msg.src == src && msg.tag == tag {
                return Ok(self.absorb(msg));
            }
            self.push_pending(msg);
        }
    }

    /// Receive from any source with the given tag; returns the job-local
    /// (src, payload).
    pub fn recv_any(&self, tag: Tag) -> Result<(Rank, Vec<u8>)> {
        if let Some(msg) = self.pop_pending_any(tag) {
            let src = self.to_local(msg.src);
            return Ok((src, self.absorb(msg)));
        }
        loop {
            let msg = self.transport.recv()?;
            if msg.epoch != self.epoch.get() {
                continue; // stale frame from a previous/concurrent pooled job
            }
            if msg.tag == tag {
                let src = self.to_local(msg.src);
                return Ok((src, self.absorb(msg)));
            }
            self.push_pending(msg);
        }
    }

    /// Attach (or clear) a [`PeakTracker`] that transport-internal
    /// staging buffers are charged to — today the hierarchical
    /// `alltoallv` node-leader bundles. The shuffle sets this around its
    /// collective calls so engine peak-memory accounting sees leader
    /// staging; the pool clears it between jobs.
    pub fn set_memory_tracker(&self, tracker: Option<Arc<PeakTracker>>) {
        *self.mem.borrow_mut() = tracker;
    }

    pub(crate) fn memory_tracker(&self) -> Option<Arc<PeakTracker>> {
        self.mem.borrow().clone()
    }

    /// Clock bookkeeping on message receipt:
    /// `clock = max(clock, sender_clock + transfer_cost)`.
    fn absorb(&self, msg: Message) -> Vec<u8> {
        self.received_messages.set(self.received_messages.get() + 1);
        let same_node = self.topology.same_node(msg.src, self.rank);
        let cost = self.network.propagation_ns(same_node);
        let arrival = msg.clock_ns.saturating_add(cost);
        let now = self.clock_ns.get();
        if arrival > now {
            self.net_wait_ns.set(self.net_wait_ns.get() + (arrival - now));
            self.clock_ns.set(arrival);
        }
        if crate::trace::enabled() {
            crate::trace::set_vclock(self.clock_ns.get());
            crate::trace::on_recv(msg.tag.0, msg.payload.len() as u64, msg.span);
        }
        msg.payload
    }

    fn push_pending(&self, msg: Message) {
        self.pending
            .borrow_mut()
            .entry((msg.src, msg.tag))
            .or_default()
            .push_back(msg);
    }

    fn pop_pending(&self, src: Rank, tag: Tag) -> Option<Message> {
        let mut pending = self.pending.borrow_mut();
        let queue = pending.get_mut(&(src, tag))?;
        let msg = queue.pop_front();
        if queue.is_empty() {
            pending.remove(&(src, tag));
        }
        msg
    }

    fn pop_pending_any(&self, tag: Tag) -> Option<Message> {
        let mut pending = self.pending.borrow_mut();
        let key = pending.keys().find(|(_, t)| *t == tag).copied()?;
        let queue = pending.get_mut(&key)?;
        let msg = queue.pop_front();
        if queue.is_empty() {
            pending.remove(&key);
        }
        msg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{DeploymentKind, NetworkModel};

    #[test]
    fn p2p_roundtrip_two_ranks() {
        let comms = Universe::local(2).communicators();
        let [c0, c1]: [Communicator; 2] = comms.try_into().map_err(|_| ()).unwrap();
        let t = std::thread::spawn(move || {
            let payload = c1.recv(Rank(0), Tag::user(7)).unwrap();
            assert_eq!(payload, b"hello");
            c1.send(Rank(0), Tag::user(8), b"world".to_vec()).unwrap();
        });
        c0.send(Rank(1), Tag::user(7), b"hello".to_vec()).unwrap();
        assert_eq!(c0.recv(Rank(1), Tag::user(8)).unwrap(), b"world");
        t.join().unwrap();
    }

    #[test]
    fn out_of_order_tags_are_buffered() {
        let comms = Universe::local(2).communicators();
        let [c0, c1]: [Communicator; 2] = comms.try_into().map_err(|_| ()).unwrap();
        c0.send(Rank(1), Tag::user(1), vec![1]).unwrap();
        c0.send(Rank(1), Tag::user(2), vec![2]).unwrap();
        // Receive in reverse order.
        assert_eq!(c1.recv(Rank(0), Tag::user(2)).unwrap(), vec![2]);
        assert_eq!(c1.recv(Rank(0), Tag::user(1)).unwrap(), vec![1]);
    }

    #[test]
    fn clock_charges_network_cost_cross_node() {
        let topo = Topology::block(2, 1); // 2 nodes x 1 slot
        let net = NetworkModel::from_profile(&DeploymentKind::BareMetal.profile());
        let comms = Universe::new(topo, net).communicators();
        let [c0, c1]: [Communicator; 2] = comms.try_into().map_err(|_| ()).unwrap();
        c0.send(Rank(1), Tag::user(0), vec![0u8; 1024]).unwrap();
        c1.recv(Rank(0), Tag::user(0)).unwrap();
        // 200 µs latency + 1 KiB at ~300 Mbit/s ≈ 227 µs.
        assert!(c1.clock_ns() >= 200_000, "clock {}", c1.clock_ns());
        assert!(c1.net_wait_ns() > 0);
        assert_eq!(c1.compute_ns(), 0);
    }

    #[test]
    fn stats_count_remote_vs_local() {
        let topo = Topology::block(2, 2); // ranks 0,1 node0; 2,3 node1
        let uni = Universe::new(topo, NetworkModel::free());
        let stats = uni.stats();
        let comms = uni.communicators();
        let mut it = comms.into_iter();
        let c0 = it.next().unwrap();
        let c1 = it.next().unwrap();
        let c2 = it.next().unwrap();
        c0.send(Rank(1), Tag::user(0), vec![0; 10]).unwrap(); // local
        c0.send(Rank(2), Tag::user(0), vec![0; 20]).unwrap(); // remote
        c1.recv(Rank(0), Tag::user(0)).unwrap();
        c2.recv(Rank(0), Tag::user(0)).unwrap();
        let (msgs, bytes, rmsgs, rbytes) = stats.snapshot();
        assert_eq!((msgs, bytes), (2, 30));
        assert_eq!((rmsgs, rbytes), (1, 20));
    }

    #[test]
    fn timed_advances_compute_clock() {
        // timed() meters thread CPU time (not wall), so burn cycles.
        let comms = Universe::local(1).communicators();
        let c = &comms[0];
        c.timed(|| {
            let mut acc = 0u64;
            for i in 0..3_000_000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(acc);
        });
        assert!(c.compute_ns() > 0, "compute {}", c.compute_ns());
        assert_eq!(c.net_wait_ns(), 0);
    }

    #[test]
    fn timed_does_not_charge_sleep() {
        let comms = Universe::local(1).communicators();
        let c = &comms[0];
        c.timed(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(c.compute_ns() < 5_000_000, "sleep charged {}", c.compute_ns());
    }

    #[test]
    fn send_out_of_range_is_error() {
        let comms = Universe::local(1).communicators();
        assert!(comms[0].send(Rank(5), Tag::user(0), vec![]).is_err());
    }

    #[test]
    fn subset_group_renumbers_and_translates() {
        // block(2,2): ranks {0,1} node0, {2,3} node1. Group {1,3} spans
        // nodes; its members see each other as local ranks 0 and 1.
        let comms = Universe::new(Topology::block(2, 2), NetworkModel::free()).communicators();
        let mut it = comms.into_iter();
        let _c0 = it.next().unwrap();
        let c1 = it.next().unwrap();
        let _c2 = it.next().unwrap();
        let c3 = it.next().unwrap();
        let group = Arc::new(vec![Rank(1), Rank(3)]);
        c1.set_group(group.clone());
        c3.set_group(group);
        assert_eq!((c1.rank(), c1.global_rank()), (Rank(0), Rank(1)));
        assert_eq!((c3.rank(), c3.global_rank()), (Rank(1), Rank(3)));
        assert_eq!(c1.size(), 2);
        // The job topology is the projected view: 2 ranks, cross-node.
        let topo = c3.topology();
        assert_eq!(topo.ranks(), 2);
        assert!(!topo.same_node(Rank(0), Rank(1)));
        // Local send/recv translate through the group.
        c1.send(Rank(1), Tag::user(3), b"sub".to_vec()).unwrap();
        let (src, payload) = c3.recv_any(Tag::user(3)).unwrap();
        assert_eq!((src, payload.as_slice()), (Rank(0), &b"sub"[..]));
        // Per-rank remote counters saw the cross-node hop.
        assert_eq!(c1.sent_remote_messages(), 1);
        assert_eq!(c1.sent_remote_bytes(), 3);
        // reset_job_state restores the identity view.
        c1.reset_job_state(7);
        assert_eq!(c1.rank(), Rank(1));
        assert_eq!(c1.size(), 4);
        assert_eq!(c1.epoch(), 7);
    }
}
