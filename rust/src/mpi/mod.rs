//! MPI-style communication substrate.
//!
//! The paper runs Blaze over MPICH/OpenMPI on three substrates (Raspberry
//! Pi, VirtualBox VMs, Docker swarm). We cannot ship real MPI here, so this
//! module is the substitution DESIGN.md §3 documents: **ranks are threads**
//! inside one process, exchanging byte-accurate messages over channels,
//! while a **virtual clock** charges every byte and every synchronization
//! the cost the chosen deployment profile says it would have on the wire.
//!
//! The clock protocol is Lamport-with-costs: every message carries the
//! sender's virtual time; on receive the destination sets
//! `clock = max(own, sender + transfer_cost(bytes))`. Collectives are built
//! from p2p sends, so barriers/allreduce naturally synchronize clocks to
//! the slowest participant — exactly the global-barrier behaviour Mimir
//! criticizes MR-MPI for, reproduced rather than hidden.
//!
//! Everything the framework above (shuffle, dist containers, engines) does
//! with the network goes through [`Communicator`], so modeled time and
//! traffic stats are complete.
//!
//! ## Execution: one-shot vs pooled
//!
//! [`run_ranks`] is the one-shot launcher (fresh threads per job, like
//! `mpirun` per job). [`RankPool`] is the pooled SPMD executor: it starts
//! the rank threads once, keeps the universe's mailbox/clock/stats wiring
//! alive between jobs, and feeds successive jobs to the warm threads —
//! the lifecycle (start → prepare/submit → inter-job barrier semantics →
//! panic containment → shutdown) is documented on [`pool`]'s module docs.
//! Iterative drivers (`core::MapReduceJob::with_pool`, the apps' pooled
//! entry points, `cluster::ElasticCluster::pool_for_wave`) all ride on it;
//! `run_ranks` itself is now a thin wrapper that builds a throwaway pool.
//!
//! ## Collective algorithms
//!
//! Collectives come in three wire shapes — [`CollectiveAlgo::Star`],
//! [`CollectiveAlgo::Tree`] (binomial, `O(log P)` depth) and
//! [`CollectiveAlgo::Hierarchical`] (node-leader trees + node-coalesced
//! `alltoallv`) — selected per universe (explicit >
//! `BLAZE_COLLECTIVE_ALGO` env > Star) and switchable mid-job under SPMD
//! discipline. The collectives module docs spell out the shapes and the
//! bit-identity contract ([`Communicator::allreduce`] folds at the root
//! in rank order under every algorithm).
//!
//! ## Transports
//!
//! Beneath [`Communicator`] sits the [`Transport`] seam: the substrate
//! that moves a [`Message`] between rank endpoints. Two substrates are
//! wired in — [`TransportKind::Mailbox`] (the original in-process mpsc
//! channels) and [`TransportKind::Tcp`] (length-framed TCP through
//! spawned `blaze worker` rank processes, [`tcp`] module docs describe
//! the handshake and relay). Selection mirrors every other knob:
//! explicit > `BLAZE_TRANSPORT` env > Mailbox. The contract is
//! byte-identity — results *and* virtual clocks are bit-equal on every
//! transport, pinned by `tests/integration_transport.rs`.

mod collectives;
mod comm;
mod datatypes;
pub mod pool;
mod process;
pub mod tcp;
mod topology;
pub mod transport;
pub mod wire;

pub use collectives::CollectiveAlgo;
pub use comm::{Communicator, TrafficStats, Universe};
pub use datatypes::{Message, Rank, Tag};
pub use pool::{JobOutput, RankPool, TrafficDelta};
pub use process::{run_ranks, run_ranks_with_universe};
pub use tcp::worker_main as tcp_worker_main;
pub use topology::{Hostfile, Topology};
pub use transport::{Transport, TransportKind};
