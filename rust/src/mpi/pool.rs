//! The pooled SPMD executor — persistent rank threads fed jobs over
//! channels.
//!
//! [`super::process::run_ranks`] spawns and joins one OS thread per rank
//! per job, so iterative applications (k-means, linear regression,
//! PageRank run one job per wave) pay thread spawn/join on every
//! iteration — exactly the per-job runtime overhead the paper argues a
//! compiled environment should avoid, and the reason Thrill keeps worker
//! threads alive across operations and M3R reuses long-lived workers
//! across Hadoop jobs. [`RankPool`] starts the rank threads **once**,
//! keeps the whole `Universe` wiring (mailboxes, topology, traffic stats)
//! alive between jobs, and feeds each job to the warm threads.
//!
//! ## Lifecycle
//!
//! 1. **Start** — [`RankPool::new`] consumes a [`Universe`], builds one
//!    [`Communicator`] per rank and parks each on its own named OS thread.
//! 2. **Submit** — [`RankPool::run_job`] / [`RankPool::try_run_on`] run a
//!    closure SPMD on the first `nranks <= size` ranks. Submission is
//!    two-phase: a *prepare* command first restores fresh-universe state
//!    on every rank (drain mailboxes, zero virtual clocks, realign
//!    collective tags) and is acknowledged by all ranks **before** any
//!    rank receives the job — so a rank can never drain a peer's
//!    just-sent message belonging to the new job. Results, per-job clock
//!    readings and a per-job traffic delta come back in rank order.
//! 3. **Barrier semantics between jobs** — a job is complete only when
//!    every active rank has reported; the next job's prepare phase
//!    therefore happens-after all sends of the previous job. Jobs on one
//!    pool are serialized (a submission mutex), so concurrent callers
//!    interleave at job granularity, never inside a job.
//! 4. **Panic containment** — a rank closure that panics is caught on the
//!    rank thread; the thread survives and the panic is reported to the
//!    submitter ([`RankPool::try_run_on`] returns `Err`, the `run*`
//!    wrappers re-panic like `run_ranks` always did). Subsequent jobs run
//!    normally; the next prepare phase discards anything the dead job
//!    left in flight. Caveat (same as fresh-spawn MPI semantics): if a
//!    panicking rank leaves a *peer* blocked in `recv`, the job never
//!    completes — and because jobs serialize on the pool, a wedged job
//!    also blocks every later submitter of a **shared** pool (and its
//!    `Drop`). Keep deliberately-faulty jobs on a dedicated pool;
//!    controlled failure handling lives a layer up in
//!    [`crate::cluster::FaultTracker`].
//! 5. **Shutdown** — dropping the pool sends every thread a shutdown
//!    command and joins it.
//!
//! ```
//! use blaze_rs::mpi::RankPool;
//!
//! let pool = RankPool::local(4);
//! // Many jobs, one set of threads — this is the iterative-app shape.
//! for _ in 0..3 {
//!     let sums = pool.run(|c| c.allreduce_sum_u64(1).unwrap());
//!     assert_eq!(sums, vec![4; 4]);
//! }
//! // Jobs narrower than the pool run on a prefix of the warm ranks.
//! assert_eq!(pool.run_on(2, |c| c.rank().0), vec![0, 1]);
//! assert_eq!(pool.jobs_run(), 4);
//! ```

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::cluster::{ClusterConfig, NetworkModel};
use crate::trace::SpanEvent;

use super::collectives::CollectiveAlgo;
use super::comm::{Communicator, TrafficStats, Universe};
use super::topology::Topology;
use super::transport::TransportKind;

/// A job body shipped to a rank thread. Lifetime-erased: see the SAFETY
/// argument in [`RankPool::submit_raw`].
type Task = Box<dyn FnOnce(&Communicator) + Send>;

/// One rank's job outcome: `(result, (clock_ns, compute_ns, net_wait_ns),
/// recorded spans)` — or the rank closure's panic payload.
type RankOutcome<T> = std::thread::Result<(T, (u64, u64, u64), Vec<SpanEvent>)>;

enum Command {
    /// Restore fresh-universe state, then ack on the enclosed channel.
    Prepare(Sender<()>),
    /// Run one job on the first `active` ranks; `task` is `None` on ranks
    /// idle for this job.
    Run { active: usize, task: Option<Task> },
    Shutdown,
}

/// Universe-wide traffic attributable to one pooled job.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrafficDelta {
    pub messages: u64,
    pub bytes: u64,
    pub remote_messages: u64,
    pub remote_bytes: u64,
}

/// Everything one pooled job produced: per-rank results (rank order),
/// per-rank virtual clocks `(clock_ns, compute_ns, net_wait_ns)` — reset
/// at job start, so these read like a fresh universe's — the job's
/// traffic delta, and (when [`crate::trace`] recording is on) every span
/// the rank threads recorded during the job, already harvested from
/// their thread-local sinks. Empty when tracing is off.
#[derive(Debug)]
pub struct JobOutput<T> {
    pub results: Vec<T>,
    pub clocks: Vec<(u64, u64, u64)>,
    pub traffic: TrafficDelta,
    pub trace: Vec<SpanEvent>,
}

struct Worker {
    tx: Sender<Command>,
    handle: Option<JoinHandle<()>>,
}

/// Persistent SPMD executor: one warm OS thread per rank of a universe,
/// reused across jobs. See the module docs for the lifecycle.
pub struct RankPool {
    workers: Vec<Worker>,
    topology: Topology,
    network: NetworkModel,
    /// Default collective algorithm of the pool's universe; restored on
    /// every rank by the prepare phase, so each pooled job starts from
    /// the universe's algorithm no matter what the previous job switched
    /// to mid-flight.
    algo: CollectiveAlgo,
    /// The substrate the pool's ranks are wired with; part of the pool's
    /// identity (a mailbox pool must not stand in for a tcp cluster).
    transport: TransportKind,
    /// PIDs of spawned `blaze worker` processes (empty for mailbox) —
    /// shutdown tests assert none outlive the pool.
    worker_pids: Vec<u32>,
    stats: Arc<TrafficStats>,
    /// Serializes jobs: one at a time, whole-pool granularity.
    submit: Mutex<()>,
    jobs_run: AtomicU64,
}

impl std::fmt::Debug for RankPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RankPool")
            .field("size", &self.workers.len())
            .field("jobs_run", &self.jobs_run.load(Ordering::Relaxed))
            .finish()
    }
}

fn worker_loop(comm: Communicator, rx: Receiver<Command>) {
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Command::Prepare(ack) => {
                comm.reset_job_state();
                let _ = ack.send(());
            }
            Command::Run { active, task } => {
                if let Some(task) = task {
                    comm.set_active_size(active);
                    task(&comm);
                }
            }
            Command::Shutdown => break,
        }
    }
}

impl RankPool {
    /// Start one persistent thread per rank of `universe`. Panics if the
    /// universe's transport cannot be brought up (e.g. the TCP worker
    /// fleet fails its handshake).
    pub fn new(universe: Universe) -> Self {
        let topology = universe.topology().clone();
        let network = universe.network().clone();
        let algo = universe.collective_algo();
        let transport = universe.transport_kind();
        let stats = universe.stats();
        let (comms, worker_pids) = universe.build().expect("wiring rank transports");
        let workers = comms
            .into_iter()
            .map(|comm| {
                let (tx, rx) = channel::<Command>();
                let handle = std::thread::Builder::new()
                    .name(format!("blaze-rank-{}", comm.rank().0))
                    .spawn(move || worker_loop(comm, rx))
                    .expect("spawn rank thread");
                Worker { tx, handle: Some(handle) }
            })
            .collect();
        Self {
            workers,
            topology,
            network,
            algo,
            transport,
            worker_pids,
            stats,
            submit: Mutex::new(()),
            jobs_run: AtomicU64::new(0),
        }
    }

    /// Pool over `n` ranks on one Local-profile node — tests and benches.
    pub fn local(n: usize) -> Self {
        Self::new(Universe::local(n))
    }

    /// Pool wired exactly like the one-shot universe `MapReduceJob` would
    /// build for `cfg` — the way sessions share threads across jobs.
    pub fn from_config(cfg: &ClusterConfig) -> Self {
        Self::new(Universe::from_cluster(cfg))
    }

    /// The collective algorithm pooled jobs start with.
    pub fn collective_algo(&self) -> CollectiveAlgo {
        self.algo
    }

    /// The substrate this pool's ranks are wired with.
    pub fn transport_kind(&self) -> TransportKind {
        self.transport
    }

    /// PIDs of the spawned `blaze worker` processes backing a TCP pool
    /// (empty for the mailbox transport). After the pool drops, none of
    /// these may still be alive — `tests/integration_transport.rs` holds
    /// the launcher to that.
    pub fn worker_pids(&self) -> &[u32] {
        &self.worker_pids
    }

    /// Number of warm rank threads (the maximum job width).
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Jobs completed over the pool's lifetime.
    pub fn jobs_run(&self) -> u64 {
        self.jobs_run.load(Ordering::Relaxed)
    }

    /// Rank threads currently alive — constant at [`RankPool::size`] for
    /// a healthy pool; the leak checks in `tests/integration_pool.rs`
    /// assert it never drifts across jobs.
    pub fn live_threads(&self) -> usize {
        self.workers
            .iter()
            .filter(|w| w.handle.as_ref().is_some_and(|h| !h.is_finished()))
            .count()
    }

    /// Does this pool model exactly this placement, network, collective
    /// algorithm, and transport substrate?
    pub fn matches(
        &self,
        topology: &Topology,
        network: &NetworkModel,
        algo: CollectiveAlgo,
        transport: TransportKind,
    ) -> bool {
        self.network == *network
            && self.algo == algo
            && self.transport == transport
            && self.topology == *topology
    }

    /// Loud guard for pool-backed entry points: error unless this pool
    /// can stand in for the fresh universe `cluster` would get (first
    /// `cluster.ranks()` ranks of the placement + the network model +
    /// the cluster's resolved collective algorithm).
    pub fn ensure_models(&self, cluster: &ClusterConfig) -> Result<()> {
        let ranks = cluster.ranks();
        anyhow::ensure!(
            self.matches_prefix(
                &Topology::from_config(cluster),
                &cluster.network_model(),
                cluster.collective_algo(),
                cluster.transport(),
                ranks
            ),
            "rank pool ({} ranks, {} collectives, {} transport) does not model this cluster's \
             first {ranks} ranks — build it with RankPool::from_config(&cluster)",
            self.size(),
            self.algo,
            self.transport
        );
        Ok(())
    }

    /// Can this pool stand in for a fresh `nranks`-rank universe with the
    /// given placement/network/algorithm/transport? True when the models
    /// agree on the first `nranks` ranks — the prefix a narrowed job runs
    /// on.
    pub fn matches_prefix(
        &self,
        topology: &Topology,
        network: &NetworkModel,
        algo: CollectiveAlgo,
        transport: TransportKind,
        nranks: usize,
    ) -> bool {
        nranks <= self.size()
            && self.network == *network
            && self.algo == algo
            && self.transport == transport
            && self.topology.agrees_on_prefix(topology, nranks)
    }

    /// Run `f` SPMD on every rank; panics if any rank panicked (first
    /// rank in rank order, message-compatible with `run_ranks`).
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        self.run_job(self.size(), f).results
    }

    /// Like [`RankPool::run`] on the first `nranks` ranks only.
    pub fn run_on<T, F>(&self, nranks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        self.run_job(nranks, f).results
    }

    /// Full-fat submission: results + per-job clocks + traffic delta.
    /// Rank panics propagate as a panic, like `run_ranks`.
    pub fn run_job<T, F>(&self, nranks: usize, f: F) -> JobOutput<T>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        let (raw, traffic) = self.submit_raw(nranks, f);
        let mut results = Vec::with_capacity(raw.len());
        let mut clocks = Vec::with_capacity(raw.len());
        let mut trace = Vec::new();
        for (i, r) in raw.into_iter().enumerate() {
            match r {
                Ok((v, clk, spans)) => {
                    results.push(v);
                    clocks.push(clk);
                    trace.extend(spans);
                }
                Err(e) => {
                    std::panic::panic_any(format!("rank {i} panicked: {}", panic_message(&*e)))
                }
            }
        }
        JobOutput { results, clocks, traffic, trace }
    }

    /// Panic-containing submission: a rank panic surfaces as `Err`
    /// (listing every panicked rank) instead of unwinding the caller, and
    /// the pool stays fully usable for subsequent jobs.
    pub fn try_run_on<T, F>(&self, nranks: usize, f: F) -> Result<JobOutput<T>>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        let (raw, traffic) = self.submit_raw(nranks, f);
        let mut results = Vec::with_capacity(raw.len());
        let mut clocks = Vec::with_capacity(raw.len());
        let mut trace = Vec::new();
        let mut panics = Vec::new();
        for (i, r) in raw.into_iter().enumerate() {
            match r {
                Ok((v, clk, spans)) => {
                    results.push(v);
                    clocks.push(clk);
                    trace.extend(spans);
                }
                Err(e) => panics.push(format!("rank {i} panicked: {}", panic_message(&*e))),
            }
        }
        if !panics.is_empty() {
            bail!("{}", panics.join("; "));
        }
        Ok(JobOutput { results, clocks, traffic, trace })
    }

    /// Two-phase dispatch; returns per-active-rank outcomes in rank order
    /// plus the job's traffic delta.
    fn submit_raw<T, F>(
        &self,
        nranks: usize,
        f: F,
    ) -> (Vec<RankOutcome<T>>, TrafficDelta)
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        assert!(
            nranks <= self.size(),
            "job wants {nranks} ranks but the pool has {}",
            self.size()
        );
        let _job = self.submit.lock().unwrap_or_else(|poison| poison.into_inner());

        // Phase 1 — prepare: every rank restores fresh-universe state and
        // acks. All acks are collected before any Run command goes out, so
        // no rank can drain a message the new job already sent it.
        let (ack_tx, ack_rx) = channel::<()>();
        for w in &self.workers {
            w.tx.send(Command::Prepare(ack_tx.clone())).expect("rank thread alive");
        }
        drop(ack_tx);
        for _ in &self.workers {
            ack_rx.recv().expect("rank thread alive for prepare ack");
        }

        let before = self.stats.snapshot();

        // Phase 2 — dispatch the job to the active prefix.
        let (res_tx, res_rx) = channel::<(usize, RankOutcome<T>)>();
        let f: &(dyn Fn(&Communicator) -> T + Sync) = &f;
        for (i, w) in self.workers.iter().enumerate() {
            let task = (i < nranks).then(|| {
                let res_tx = res_tx.clone();
                let boxed: Box<dyn FnOnce(&Communicator) + Send + '_> = Box::new(move |comm| {
                    let out = catch_unwind(AssertUnwindSafe(|| {
                        // Reset this rank thread's span sink for the job
                        // (cheap; a no-op recorder when tracing is off).
                        if crate::trace::enabled() {
                            crate::trace::job_start(comm.rank().0, 0, comm.epoch());
                        }
                        let v = f(comm);
                        let clk = (comm.clock_ns(), comm.compute_ns(), comm.net_wait_ns());
                        (v, clk, crate::trace::take())
                    }));
                    let _ = res_tx.send((comm.rank().0, out));
                });
                // SAFETY: `boxed` borrows `f` (and `T` may borrow the
                // caller's environment), but we block below until every
                // active rank has sent its result — and sending is the
                // closure's final action, after its last read through the
                // borrow. Whatever the worker still holds afterwards (the
                // spent box, its sender clone) is only *dropped*, which
                // never dereferences the erased borrows: dropping a shared
                // reference is a no-op and the result channel's queue is
                // fully drained before we return. The `recv` expects below
                // can only fail once every sender is dropped, i.e. after
                // all borrows are already dead, so even the panic path
                // cannot outrun a live borrow.
                unsafe {
                    std::mem::transmute::<Box<dyn FnOnce(&Communicator) + Send + '_>, Task>(boxed)
                }
            });
            w.tx.send(Command::Run { active: nranks, task }).expect("rank thread alive");
        }
        drop(res_tx);

        let mut slots: Vec<Option<RankOutcome<T>>> = (0..nranks).map(|_| None).collect();
        for _ in 0..nranks {
            let (rank, out) = res_rx.recv().expect("rank thread alive mid-job");
            slots[rank] = Some(out);
        }
        let after = self.stats.snapshot();
        self.jobs_run.fetch_add(1, Ordering::Relaxed);
        let traffic = TrafficDelta {
            messages: after.0 - before.0,
            bytes: after.1 - before.1,
            remote_messages: after.2 - before.2,
            remote_bytes: after.3 - before.3,
        };
        (slots.into_iter().map(|s| s.expect("every active rank reports")).collect(), traffic)
    }
}

impl Drop for RankPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Command::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(handle) = w.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::{Rank, Tag};

    #[test]
    fn pool_runs_many_jobs_on_same_threads() {
        let pool = RankPool::local(3);
        let ids0 = pool.run(|_| std::thread::current().id());
        for round in 0..10u64 {
            let got = pool.run(|c| c.allreduce_sum_u64(round).unwrap());
            assert_eq!(got, vec![round * 3; 3]);
            assert_eq!(pool.run(|_| std::thread::current().id()), ids0);
        }
        assert_eq!(pool.live_threads(), 3);
        assert_eq!(pool.jobs_run(), 21);
    }

    #[test]
    fn narrowed_jobs_use_rank_prefix() {
        let pool = RankPool::local(5);
        // Narrowed jobs see the job width as size() and the pool width as
        // world_size().
        assert_eq!(
            pool.run_on(2, |c| (c.rank().0, c.size(), c.world_size())),
            vec![(0, 2, 5), (1, 2, 5)]
        );
        // Collectives span only the active prefix.
        assert_eq!(pool.run_on(3, |c| c.allgather(c.rank().0 as u32).unwrap()), vec![
            vec![0, 1, 2];
            3
        ]);
        // Back to full width afterwards.
        assert_eq!(pool.run(|c| c.size()), vec![5; 5]);
    }

    #[test]
    fn clocks_and_traffic_reset_between_jobs() {
        let pool = RankPool::local(2);
        let job = |c: &Communicator| {
            c.advance(1_000);
            c.send(Rank((c.rank().0 + 1) % 2), Tag::user(0), vec![0u8; 100]).unwrap();
            c.recv(Rank((c.rank().0 + 1) % 2), Tag::user(0)).unwrap().len()
        };
        let first = pool.run_job(2, job);
        let second = pool.run_job(2, job);
        assert_eq!(first.results, vec![100, 100]);
        assert_eq!(first.clocks, second.clocks, "clocks must reset per job");
        assert_eq!(first.traffic, second.traffic, "traffic delta must be per job");
        assert_eq!(first.traffic.messages, 2);
        assert_eq!(first.traffic.bytes, 200);
    }

    #[test]
    fn unconsumed_messages_do_not_leak_into_next_job() {
        let pool = RankPool::local(2);
        // Job 1 leaves an unconsumed message in rank 1's mailbox.
        pool.run(|c| {
            if c.is_root() {
                c.send(Rank(1), Tag::user(0), vec![0xEE]).unwrap();
            }
        });
        // Job 2 sends on the SAME (src, tag): must see the fresh payload.
        let got = pool.run(|c| {
            if c.is_root() {
                c.send(Rank(1), Tag::user(0), vec![0x11]).unwrap();
                0
            } else {
                c.recv(Rank(0), Tag::user(0)).unwrap()[0]
            }
        });
        assert_eq!(got, vec![0, 0x11]);
    }

    #[test]
    fn rank_panic_is_contained_and_pool_survives() {
        let pool = RankPool::local(4);
        let err = pool
            .try_run_on(4, |c| {
                if c.rank().0 == 2 {
                    panic!("injected fault");
                }
                c.rank().0
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 2 panicked"), "{msg}");
        assert!(msg.contains("injected fault"), "{msg}");
        // The pool is not poisoned: collectives still work on all ranks.
        for _ in 0..3 {
            assert_eq!(pool.run(|c| c.allreduce_sum_u64(1).unwrap()), vec![4; 4]);
        }
        assert_eq!(pool.live_threads(), 4);
    }

    #[test]
    #[should_panic(expected = "rank 1 panicked")]
    fn run_propagates_rank_panic_like_run_ranks() {
        let pool = RankPool::local(2);
        pool.run(|c| {
            if c.rank().0 == 1 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn borrowed_environment_jobs_are_supported() {
        // Non-'static captures: the engine's closures borrow the input
        // slice and task feed; make sure the erased-lifetime path holds.
        let data: Vec<u64> = (0..100).collect();
        let pool = RankPool::local(4);
        let total = pool.run(|c| {
            let chunk = data.len() / c.size();
            let lo = c.rank().0 * chunk;
            let local: u64 = data[lo..lo + chunk].iter().sum();
            c.allreduce_sum_u64(local).unwrap()
        });
        assert_eq!(total, vec![data.iter().sum::<u64>(); 4]);
    }

    #[test]
    fn collective_algo_restored_between_pooled_jobs() {
        let pool = RankPool::new(Universe::local(3).with_collective_algo(CollectiveAlgo::Tree));
        assert_eq!(pool.collective_algo(), CollectiveAlgo::Tree);
        let before = pool.run(|c| {
            let a = c.collective_algo();
            c.set_collective_algo(CollectiveAlgo::Star);
            a
        });
        assert_eq!(before, vec![CollectiveAlgo::Tree; 3]);
        // The prepare phase realigns algorithm (and tags) for job 2.
        assert_eq!(pool.run(|c| c.collective_algo()), vec![CollectiveAlgo::Tree; 3]);
    }

    #[test]
    fn empty_pool_runs_empty_jobs() {
        let pool = RankPool::local(0);
        let out = pool.run_job(0, |c: &Communicator| c.rank().0);
        assert!(out.results.is_empty());
        assert_eq!(out.traffic, TrafficDelta::default());
    }
}
