//! The pooled SPMD executor — persistent rank threads fed jobs over
//! channels.
//!
//! [`super::process::run_ranks`] spawns and joins one OS thread per rank
//! per job, so iterative applications (k-means, linear regression,
//! PageRank run one job per wave) pay thread spawn/join on every
//! iteration — exactly the per-job runtime overhead the paper argues a
//! compiled environment should avoid, and the reason Thrill keeps worker
//! threads alive across operations and M3R reuses long-lived workers
//! across Hadoop jobs. [`RankPool`] starts the rank threads **once**,
//! keeps the whole `Universe` wiring (mailboxes, topology, traffic stats)
//! alive between jobs, and feeds each job to the warm threads.
//!
//! ## Lifecycle
//!
//! 1. **Start** — [`RankPool::new`] consumes a [`Universe`], builds one
//!    [`Communicator`] per rank and parks each on its own named OS thread.
//! 2. **Submit** — [`RankPool::run_job`] / [`RankPool::try_run_on`] run a
//!    closure SPMD on the first `nranks <= size` ranks;
//!    [`RankPool::run_job_on`] / [`RankPool::try_run_job_on`] run it on an
//!    arbitrary *subset* of ranks, which the member communicators see
//!    re-numbered `0..width` like a fresh universe of that shape.
//!    Submission is two-phase: a *prepare* command first restores
//!    fresh-universe state on every member rank (drain mailboxes, zero
//!    virtual clocks, realign collective tags, enter the job's epoch) and
//!    is acknowledged by all members **before** any member receives the
//!    job — so a rank can never drain a peer's just-sent message belonging
//!    to the new job. Results, per-job clock readings and a per-job
//!    traffic delta come back in job-local rank order.
//! 3. **Concurrency** — each rank has its own busy lock; a job takes the
//!    locks of exactly its member ranks (in ascending rank order, so
//!    overlapping jobs cannot deadlock). Jobs on **disjoint** subsets
//!    hold disjoint locks and run simultaneously; jobs sharing any rank
//!    serialize on it. Every job gets a pool-unique epoch stamped into
//!    its frames, so concurrent jobs' message planes are disjoint even
//!    on a shared TCP worker mesh. A job is complete only when every
//!    member rank has reported; the next job's prepare phase on those
//!    ranks therefore happens-after all their sends.
//! 4. **Panic containment** — a rank closure that panics is caught on the
//!    rank thread; the thread survives and the panic is reported to the
//!    submitter ([`RankPool::try_run_on`] returns `Err`, the `run*`
//!    wrappers re-panic like `run_ranks` always did). Subsequent jobs run
//!    normally; the next prepare phase discards anything the dead job
//!    left in flight. Caveat (same as fresh-spawn MPI semantics): if a
//!    panicking rank leaves a *peer* blocked in `recv`, the job never
//!    completes — and a wedged job blocks every later submitter that
//!    **shares a rank** with it (and the pool's `Drop`). Keep
//!    deliberately-faulty jobs on a dedicated pool; controlled failure
//!    handling lives a layer up in [`crate::cluster::FaultTracker`].
//! 5. **Shutdown** — dropping the pool sends every thread a shutdown
//!    command and joins it.
//!
//! ```
//! use blaze_rs::mpi::RankPool;
//!
//! let pool = RankPool::local(4);
//! // Many jobs, one set of threads — this is the iterative-app shape.
//! for _ in 0..3 {
//!     let sums = pool.run(|c| c.allreduce_sum_u64(1).unwrap());
//!     assert_eq!(sums, vec![4; 4]);
//! }
//! // Jobs narrower than the pool run on a prefix of the warm ranks...
//! assert_eq!(pool.run_on(2, |c| c.rank().0), vec![0, 1]);
//! // ...or on any subset, re-numbered 0..width.
//! let out = pool.run_job_on(&[1, 3], |c| c.rank().0);
//! assert_eq!(out.results, vec![0, 1]);
//! assert_eq!(pool.jobs_run(), 5);
//! ```

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::cluster::{ClusterConfig, NetworkModel};
use crate::trace::SpanEvent;

use super::collectives::CollectiveAlgo;
use super::comm::{Communicator, Universe};
use super::datatypes::Rank;
use super::topology::Topology;
use super::transport::TransportKind;

/// A job body shipped to a rank thread. Lifetime-erased: see the SAFETY
/// argument in [`RankPool::submit_raw`].
type Task = Box<dyn FnOnce(&Communicator) + Send>;

/// One rank's per-job traffic readings:
/// `(sent_messages, sent_bytes, sent_remote_messages, sent_remote_bytes)`.
type RankTraffic = (u64, u64, u64, u64);

/// One rank's job outcome: `(result, (clock_ns, compute_ns, net_wait_ns),
/// per-rank traffic, recorded spans)` — or the rank closure's panic
/// payload.
type RankOutcome<T> = std::thread::Result<(T, (u64, u64, u64), RankTraffic, Vec<SpanEvent>)>;

enum Command {
    /// Restore fresh-universe state, enter `epoch`, then ack on the
    /// enclosed channel.
    Prepare { epoch: u64, ack: Sender<()> },
    /// Run one job on the member ranks listed in `group` (this rank is
    /// always a member — non-members are never sent a `Run`).
    Run { group: Arc<Vec<Rank>>, task: Task },
    Shutdown,
}

/// Traffic attributable to one pooled job: the sum of its member ranks'
/// per-rank counters, so concurrent jobs on disjoint subsets never see
/// each other's bytes.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrafficDelta {
    pub messages: u64,
    pub bytes: u64,
    pub remote_messages: u64,
    pub remote_bytes: u64,
}

/// Everything one pooled job produced: per-rank results (job-local rank
/// order), per-rank virtual clocks `(clock_ns, compute_ns, net_wait_ns)`
/// — reset at job start, so these read like a fresh universe's — the
/// job's traffic delta, and (when [`crate::trace`] recording is on) every
/// span the rank threads recorded during the job, already harvested from
/// their thread-local sinks. Empty when tracing is off.
#[derive(Debug)]
pub struct JobOutput<T> {
    pub results: Vec<T>,
    pub clocks: Vec<(u64, u64, u64)>,
    pub traffic: TrafficDelta,
    pub trace: Vec<SpanEvent>,
}

struct Worker {
    /// Command channel to the rank thread. `Sender` is cloneable but we
    /// want exactly-one-submitter-at-a-time semantics per rank, so the
    /// sender sits behind a mutex and submitters hold `busy` anyway.
    tx: Mutex<Sender<Command>>,
    /// Held by the job currently occupying this rank. Jobs lock their
    /// member ranks in ascending order, so overlapping jobs serialize
    /// instead of deadlocking.
    busy: Mutex<()>,
    handle: Option<JoinHandle<()>>,
}

impl Worker {
    fn send(&self, cmd: Command) {
        self.tx
            .lock()
            .unwrap_or_else(|poison| poison.into_inner())
            .send(cmd)
            .expect("rank thread alive");
    }
}

/// Persistent SPMD executor: one warm OS thread per rank of a universe,
/// reused across jobs — and shared by concurrent jobs on disjoint rank
/// subsets. See the module docs for the lifecycle.
pub struct RankPool {
    workers: Vec<Worker>,
    topology: Topology,
    network: NetworkModel,
    /// Default collective algorithm of the pool's universe; restored on
    /// every rank by the prepare phase, so each pooled job starts from
    /// the universe's algorithm no matter what the previous job switched
    /// to mid-flight.
    algo: CollectiveAlgo,
    /// The substrate the pool's ranks are wired with; part of the pool's
    /// identity (a mailbox pool must not stand in for a tcp cluster).
    transport: TransportKind,
    /// PIDs of spawned `blaze worker` processes (empty for mailbox) —
    /// shutdown tests assert none outlive the pool.
    worker_pids: Vec<u32>,
    /// Pool-global job id generator; doubles as the message epoch, so
    /// two jobs in flight at once fence each other's frames.
    epochs: AtomicU64,
    jobs_run: AtomicU64,
}

impl std::fmt::Debug for RankPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RankPool")
            .field("size", &self.workers.len())
            .field("jobs_run", &self.jobs_run.load(Ordering::Relaxed))
            .finish()
    }
}

fn worker_loop(comm: Communicator, rx: Receiver<Command>) {
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Command::Prepare { epoch, ack } => {
                comm.reset_job_state(epoch);
                let _ = ack.send(());
            }
            Command::Run { group, task } => {
                comm.set_group(group);
                task(&comm);
            }
            Command::Shutdown => break,
        }
    }
}

impl RankPool {
    /// Start one persistent thread per rank of `universe`. Panics if the
    /// universe's transport cannot be brought up (e.g. the TCP worker
    /// fleet fails its handshake).
    pub fn new(universe: Universe) -> Self {
        let topology = universe.topology().clone();
        let network = universe.network().clone();
        let algo = universe.collective_algo();
        let transport = universe.transport_kind();
        let (comms, worker_pids) = universe.build().expect("wiring rank transports");
        let workers = comms
            .into_iter()
            .map(|comm| {
                let (tx, rx) = channel::<Command>();
                let handle = std::thread::Builder::new()
                    .name(format!("blaze-rank-{}", comm.rank().0))
                    .spawn(move || worker_loop(comm, rx))
                    .expect("spawn rank thread");
                Worker { tx: Mutex::new(tx), busy: Mutex::new(()), handle: Some(handle) }
            })
            .collect();
        Self {
            workers,
            topology,
            network,
            algo,
            transport,
            worker_pids,
            epochs: AtomicU64::new(0),
            jobs_run: AtomicU64::new(0),
        }
    }

    /// Pool over `n` ranks on one Local-profile node — tests and benches.
    pub fn local(n: usize) -> Self {
        Self::new(Universe::local(n))
    }

    /// Pool wired exactly like the one-shot universe `MapReduceJob` would
    /// build for `cfg` — the way sessions share threads across jobs.
    pub fn from_config(cfg: &ClusterConfig) -> Self {
        Self::new(Universe::from_cluster(cfg))
    }

    /// The collective algorithm pooled jobs start with.
    pub fn collective_algo(&self) -> CollectiveAlgo {
        self.algo
    }

    /// The substrate this pool's ranks are wired with.
    pub fn transport_kind(&self) -> TransportKind {
        self.transport
    }

    /// PIDs of the spawned `blaze worker` processes backing a TCP pool
    /// (empty for the mailbox transport). After the pool drops, none of
    /// these may still be alive — `tests/integration_transport.rs` holds
    /// the launcher to that.
    pub fn worker_pids(&self) -> &[u32] {
        &self.worker_pids
    }

    /// Number of warm rank threads (the maximum job width).
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Jobs completed over the pool's lifetime.
    pub fn jobs_run(&self) -> u64 {
        self.jobs_run.load(Ordering::Relaxed)
    }

    /// Rank threads currently alive — constant at [`RankPool::size`] for
    /// a healthy pool; the leak checks in `tests/integration_pool.rs`
    /// assert it never drifts across jobs.
    pub fn live_threads(&self) -> usize {
        self.workers
            .iter()
            .filter(|w| w.handle.as_ref().is_some_and(|h| !h.is_finished()))
            .count()
    }

    /// Does this pool model exactly this placement, network, collective
    /// algorithm, and transport substrate?
    pub fn matches(
        &self,
        topology: &Topology,
        network: &NetworkModel,
        algo: CollectiveAlgo,
        transport: TransportKind,
    ) -> bool {
        self.network == *network
            && self.algo == algo
            && self.transport == transport
            && self.topology == *topology
    }

    /// Loud guard for pool-backed entry points: error unless this pool
    /// can stand in for the fresh universe `cluster` would get (first
    /// `cluster.ranks()` ranks of the placement + the network model +
    /// the cluster's resolved collective algorithm).
    pub fn ensure_models(&self, cluster: &ClusterConfig) -> Result<()> {
        let ranks = cluster.ranks();
        anyhow::ensure!(
            self.matches_prefix(
                &Topology::from_config(cluster),
                &cluster.network_model(),
                cluster.collective_algo(),
                cluster.transport(),
                ranks
            ),
            "rank pool ({} ranks, {} collectives, {} transport) does not model this cluster's \
             first {ranks} ranks — build it with RankPool::from_config(&cluster)",
            self.size(),
            self.algo,
            self.transport
        );
        Ok(())
    }

    /// [`RankPool::ensure_models`] for a job placed on an arbitrary rank
    /// subset: error unless the pool can stand in for the fresh universe
    /// `cluster` would get when its ranks `0..width` are mapped onto the
    /// pool ranks `ranks` (structural placement match + network model +
    /// resolved collective algorithm + transport).
    pub fn ensure_models_on(&self, cluster: &ClusterConfig, ranks: &[usize]) -> Result<()> {
        anyhow::ensure!(
            cluster.ranks() == ranks.len(),
            "cluster is {} ranks wide but the placement lists {} pool ranks",
            cluster.ranks(),
            ranks.len()
        );
        anyhow::ensure!(
            self.matches_subset(
                &Topology::from_config(cluster),
                &cluster.network_model(),
                cluster.collective_algo(),
                cluster.transport(),
                ranks
            ),
            "rank pool ({} ranks, {} collectives, {} transport) does not model this cluster on \
             pool ranks {ranks:?} — build it with RankPool::from_config(&cluster)",
            self.size(),
            self.algo,
            self.transport
        );
        Ok(())
    }

    /// Can this pool stand in for a fresh `nranks`-rank universe with the
    /// given placement/network/algorithm/transport? True when the models
    /// agree on the first `nranks` ranks — the prefix a narrowed job runs
    /// on.
    pub fn matches_prefix(
        &self,
        topology: &Topology,
        network: &NetworkModel,
        algo: CollectiveAlgo,
        transport: TransportKind,
        nranks: usize,
    ) -> bool {
        nranks <= self.size()
            && self.network == *network
            && self.algo == algo
            && self.transport == transport
            && self.topology.agrees_on_prefix(topology, nranks)
    }

    /// [`RankPool::matches_prefix`] for an arbitrary rank subset: the job
    /// topology's ranks `0..ranks.len()` must match the pool ranks
    /// `ranks` structurally (same-node relation + compute scaling; see
    /// [`Topology::agrees_on_ranks`]).
    pub fn matches_subset(
        &self,
        topology: &Topology,
        network: &NetworkModel,
        algo: CollectiveAlgo,
        transport: TransportKind,
        ranks: &[usize],
    ) -> bool {
        ranks.iter().all(|&r| r < self.size())
            && self.network == *network
            && self.algo == algo
            && self.transport == transport
            && self.topology.agrees_on_ranks(topology, ranks)
    }

    /// Run `f` SPMD on every rank; panics if any rank panicked (first
    /// rank in rank order, message-compatible with `run_ranks`).
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        self.run_job(self.size(), f).results
    }

    /// Like [`RankPool::run`] on the first `nranks` ranks only.
    pub fn run_on<T, F>(&self, nranks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        self.run_job(nranks, f).results
    }

    /// Full-fat submission on the rank prefix `0..nranks`: results +
    /// per-job clocks + traffic delta. Rank panics propagate as a panic,
    /// like `run_ranks`.
    pub fn run_job<T, F>(&self, nranks: usize, f: F) -> JobOutput<T>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        let ranks: Vec<usize> = (0..nranks).collect();
        self.run_job_on(&ranks, f)
    }

    /// Full-fat submission on an arbitrary rank subset (strictly
    /// ascending pool ranks). Member communicators see themselves
    /// re-numbered `0..ranks.len()`; results come back in that job-local
    /// order. Rank panics propagate as a panic.
    pub fn run_job_on<T, F>(&self, ranks: &[usize], f: F) -> JobOutput<T>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        let (raw, traffic) = self.submit_raw(ranks, f);
        let mut results = Vec::with_capacity(raw.len());
        let mut clocks = Vec::with_capacity(raw.len());
        let mut trace = Vec::new();
        for (i, r) in raw.into_iter().enumerate() {
            match r {
                Ok((v, clk, _tfc, spans)) => {
                    results.push(v);
                    clocks.push(clk);
                    trace.extend(spans);
                }
                Err(e) => {
                    std::panic::panic_any(format!("rank {i} panicked: {}", panic_message(&*e)))
                }
            }
        }
        JobOutput { results, clocks, traffic, trace }
    }

    /// Panic-containing submission on the rank prefix: a rank panic
    /// surfaces as `Err` (listing every panicked rank) instead of
    /// unwinding the caller, and the pool stays fully usable for
    /// subsequent jobs.
    pub fn try_run_on<T, F>(&self, nranks: usize, f: F) -> Result<JobOutput<T>>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        let ranks: Vec<usize> = (0..nranks).collect();
        self.try_run_job_on(&ranks, f)
    }

    /// Panic-containing [`RankPool::run_job_on`].
    pub fn try_run_job_on<T, F>(&self, ranks: &[usize], f: F) -> Result<JobOutput<T>>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        let (raw, traffic) = self.submit_raw(ranks, f);
        let mut results = Vec::with_capacity(raw.len());
        let mut clocks = Vec::with_capacity(raw.len());
        let mut trace = Vec::new();
        let mut panics = Vec::new();
        for (i, r) in raw.into_iter().enumerate() {
            match r {
                Ok((v, clk, _tfc, spans)) => {
                    results.push(v);
                    clocks.push(clk);
                    trace.extend(spans);
                }
                Err(e) => panics.push(format!("rank {i} panicked: {}", panic_message(&*e))),
            }
        }
        if !panics.is_empty() {
            bail!("{}", panics.join("; "));
        }
        Ok(JobOutput { results, clocks, traffic, trace })
    }

    /// Two-phase dispatch to the member ranks; returns per-member
    /// outcomes in job-local rank order plus the job's traffic delta
    /// (sum of the member ranks' per-rank counters — panicked ranks
    /// contribute nothing).
    fn submit_raw<T, F>(&self, ranks: &[usize], f: F) -> (Vec<RankOutcome<T>>, TrafficDelta)
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        assert!(
            ranks.windows(2).all(|w| w[0] < w[1]),
            "job placement must list strictly ascending pool ranks, got {ranks:?}"
        );
        if let Some(&last) = ranks.last() {
            assert!(last < self.size(), "job wants rank {last} but the pool has {}", self.size());
        } else {
            self.jobs_run.fetch_add(1, Ordering::Relaxed);
            return (Vec::new(), TrafficDelta::default());
        }

        // Occupy exactly the member ranks, in ascending order — ordered
        // acquisition means two jobs contending for an overlapping subset
        // serialize on the lowest shared rank instead of deadlocking;
        // disjoint jobs don't touch each other's locks at all.
        let _busy: Vec<MutexGuard<'_, ()>> = ranks
            .iter()
            .map(|&r| self.workers[r].busy.lock().unwrap_or_else(|poison| poison.into_inner()))
            .collect();

        // Pool-unique job id; doubles as the message epoch.
        let epoch = self.epochs.fetch_add(1, Ordering::Relaxed) + 1;

        // Phase 1 — prepare: every member rank restores fresh-universe
        // state, enters the job's epoch, and acks. All acks are collected
        // before any Run command goes out, so no member can drain a
        // message the new job already sent it.
        let (ack_tx, ack_rx) = channel::<()>();
        for &r in ranks {
            self.workers[r].send(Command::Prepare { epoch, ack: ack_tx.clone() });
        }
        drop(ack_tx);
        for _ in ranks {
            ack_rx.recv().expect("rank thread alive for prepare ack");
        }

        // Phase 2 — dispatch the job to the members.
        let group: Arc<Vec<Rank>> = Arc::new(ranks.iter().map(|&r| Rank(r)).collect());
        let (res_tx, res_rx) = channel::<(usize, RankOutcome<T>)>();
        let f: &(dyn Fn(&Communicator) -> T + Sync) = &f;
        for &r in ranks {
            let res_tx = res_tx.clone();
            let boxed: Box<dyn FnOnce(&Communicator) + Send + '_> = Box::new(move |comm| {
                let out = catch_unwind(AssertUnwindSafe(|| {
                    // Reset this rank thread's span sink for the job
                    // (cheap; a no-op recorder when tracing is off).
                    if crate::trace::enabled() {
                        crate::trace::job_start(comm.global_rank().0, 0, comm.epoch());
                    }
                    let v = f(comm);
                    let clk = (comm.clock_ns(), comm.compute_ns(), comm.net_wait_ns());
                    let tfc = (
                        comm.sent_messages(),
                        comm.sent_bytes(),
                        comm.sent_remote_messages(),
                        comm.sent_remote_bytes(),
                    );
                    (v, clk, tfc, crate::trace::take())
                }));
                let _ = res_tx.send((comm.rank().0, out));
            });
            // SAFETY: `boxed` borrows `f` (and `T` may borrow the
            // caller's environment), but we block below until every
            // member rank has sent its result — and sending is the
            // closure's final action, after its last read through the
            // borrow. Whatever the worker still holds afterwards (the
            // spent box, its sender clone) is only *dropped*, which
            // never dereferences the erased borrows: dropping a shared
            // reference is a no-op and the result channel's queue is
            // fully drained before we return. The `recv` expects below
            // can only fail once every sender is dropped, i.e. after
            // all borrows are already dead, so even the panic path
            // cannot outrun a live borrow.
            let task = unsafe {
                std::mem::transmute::<Box<dyn FnOnce(&Communicator) + Send + '_>, Task>(boxed)
            };
            self.workers[r].send(Command::Run { group: group.clone(), task });
        }
        drop(res_tx);

        let mut slots: Vec<Option<RankOutcome<T>>> = (0..ranks.len()).map(|_| None).collect();
        for _ in ranks {
            let (local, out) = res_rx.recv().expect("rank thread alive mid-job");
            slots[local] = Some(out);
        }
        self.jobs_run.fetch_add(1, Ordering::Relaxed);
        let mut traffic = TrafficDelta::default();
        for slot in &slots {
            if let Some(Ok((_, _, (msgs, bytes, rmsgs, rbytes), _))) = slot.as_ref() {
                traffic.messages += msgs;
                traffic.bytes += bytes;
                traffic.remote_messages += rmsgs;
                traffic.remote_bytes += rbytes;
            }
        }
        (slots.into_iter().map(|s| s.expect("every member rank reports")).collect(), traffic)
    }
}

impl Drop for RankPool {
    fn drop(&mut self) {
        for w in &self.workers {
            w.send(Command::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(handle) = w.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::{Rank, Tag};

    #[test]
    fn pool_runs_many_jobs_on_same_threads() {
        let pool = RankPool::local(3);
        let ids0 = pool.run(|_| std::thread::current().id());
        for round in 0..10u64 {
            let got = pool.run(|c| c.allreduce_sum_u64(round).unwrap());
            assert_eq!(got, vec![round * 3; 3]);
            assert_eq!(pool.run(|_| std::thread::current().id()), ids0);
        }
        assert_eq!(pool.live_threads(), 3);
        assert_eq!(pool.jobs_run(), 21);
    }

    #[test]
    fn narrowed_jobs_use_rank_prefix() {
        let pool = RankPool::local(5);
        // Narrowed jobs see the job width as size() and the pool width as
        // world_size().
        assert_eq!(
            pool.run_on(2, |c| (c.rank().0, c.size(), c.world_size())),
            vec![(0, 2, 5), (1, 2, 5)]
        );
        // Collectives span only the active prefix.
        assert_eq!(pool.run_on(3, |c| c.allgather(c.rank().0 as u32).unwrap()), vec![
            vec![0, 1, 2];
            3
        ]);
        // Back to full width afterwards.
        assert_eq!(pool.run(|c| c.size()), vec![5; 5]);
    }

    #[test]
    fn subset_jobs_renumber_ranks() {
        let pool = RankPool::local(6);
        // A job on ranks {1, 3, 5} sees itself as a 3-rank universe.
        let out = pool.run_job_on(&[1, 3, 5], |c| {
            (c.rank().0, c.global_rank().0, c.size(), c.world_size())
        });
        assert_eq!(out.results, vec![(0, 1, 3, 6), (1, 3, 3, 6), (2, 5, 3, 6)]);
        // Collectives span exactly the subset, in job-local numbering.
        assert_eq!(pool.run_job_on(&[2, 4], |c| c.allgather(c.rank().0 as u32).unwrap()).results, vec![
            vec![0, 1];
            2
        ]);
        // Point-to-point addressing is job-local too.
        let got = pool.run_job_on(&[0, 5], |c| {
            if c.is_root() {
                c.send(Rank(1), Tag::user(9), vec![0xAB]).unwrap();
                0u8
            } else {
                c.recv(Rank(0), Tag::user(9)).unwrap()[0]
            }
        });
        assert_eq!(got.results, vec![0, 0xAB]);
    }

    #[test]
    fn subset_placement_is_validated() {
        let pool = RankPool::local(4);
        for bad in [&[1usize, 1][..], &[3, 1], &[2, 4]] {
            let attempt = catch_unwind(AssertUnwindSafe(|| {
                pool.run_job_on(bad, |c| c.rank().0);
            }));
            assert!(attempt.is_err(), "placement {bad:?} must be rejected");
        }
        // The pool survives rejected submissions.
        assert_eq!(pool.run(|c| c.size()), vec![4; 4]);
    }

    #[test]
    fn disjoint_jobs_run_concurrently() {
        let pool = RankPool::local(4);
        // Cross-signal between two jobs: each root announces itself, then
        // waits for the other job's announcement. Only possible if both
        // jobs are in flight at once; a serializing pool would time out
        // (and fail the assertions — not hang).
        let (a_tx, a_rx) = channel::<()>();
        let (b_tx, b_rx) = channel::<()>();
        let (a_tx, a_rx) = (Mutex::new(a_tx), Mutex::new(a_rx));
        let (b_tx, b_rx) = (Mutex::new(b_tx), Mutex::new(b_rx));
        let timeout = std::time::Duration::from_secs(10);
        std::thread::scope(|s| {
            let ja = s.spawn(|| {
                pool.run_job_on(&[0, 1], |c| {
                    if c.is_root() {
                        a_tx.lock().unwrap().send(()).unwrap();
                        b_rx.lock()
                            .unwrap()
                            .recv_timeout(timeout)
                            .expect("job B never overlapped with job A");
                    }
                    c.allreduce_sum_u64(1).unwrap()
                })
            });
            let jb = s.spawn(|| {
                pool.run_job_on(&[2, 3], |c| {
                    if c.is_root() {
                        b_tx.lock().unwrap().send(()).unwrap();
                        a_rx.lock()
                            .unwrap()
                            .recv_timeout(timeout)
                            .expect("job A never overlapped with job B");
                    }
                    c.allreduce_sum_u64(1).unwrap()
                })
            });
            assert_eq!(ja.join().unwrap().results, vec![2, 2]);
            assert_eq!(jb.join().unwrap().results, vec![2, 2]);
        });
        assert_eq!(pool.jobs_run(), 2);
    }

    #[test]
    fn overlapping_jobs_serialize_on_shared_ranks() {
        let pool = RankPool::local(3);
        // Jobs {0,1} and {1,2} share rank 1: they must serialize there,
        // both complete, and each sees a coherent 2-rank universe.
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let pool = &pool;
                    s.spawn(move || {
                        let ranks: &[usize] = if i % 2 == 0 { &[0, 1] } else { &[1, 2] };
                        pool.run_job_on(ranks, |c| c.allgather(c.rank().0 as u32).unwrap())
                    })
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap().results, vec![vec![0, 1]; 2]);
            }
        });
        assert_eq!(pool.jobs_run(), 8);
    }

    #[test]
    fn clocks_and_traffic_reset_between_jobs() {
        let pool = RankPool::local(2);
        let job = |c: &Communicator| {
            c.advance(1_000);
            c.send(Rank((c.rank().0 + 1) % 2), Tag::user(0), vec![0u8; 100]).unwrap();
            c.recv(Rank((c.rank().0 + 1) % 2), Tag::user(0)).unwrap().len()
        };
        let first = pool.run_job(2, job);
        let second = pool.run_job(2, job);
        assert_eq!(first.results, vec![100, 100]);
        assert_eq!(first.clocks, second.clocks, "clocks must reset per job");
        assert_eq!(first.traffic, second.traffic, "traffic delta must be per job");
        assert_eq!(first.traffic.messages, 2);
        assert_eq!(first.traffic.bytes, 200);
    }

    #[test]
    fn unconsumed_messages_do_not_leak_into_next_job() {
        let pool = RankPool::local(2);
        // Job 1 leaves an unconsumed message in rank 1's mailbox.
        pool.run(|c| {
            if c.is_root() {
                c.send(Rank(1), Tag::user(0), vec![0xEE]).unwrap();
            }
        });
        // Job 2 sends on the SAME (src, tag): must see the fresh payload.
        let got = pool.run(|c| {
            if c.is_root() {
                c.send(Rank(1), Tag::user(0), vec![0x11]).unwrap();
                0
            } else {
                c.recv(Rank(0), Tag::user(0)).unwrap()[0]
            }
        });
        assert_eq!(got, vec![0, 0x11]);
    }

    #[test]
    fn rank_panic_is_contained_and_pool_survives() {
        let pool = RankPool::local(4);
        let err = pool
            .try_run_on(4, |c| {
                if c.rank().0 == 2 {
                    panic!("injected fault");
                }
                c.rank().0
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 2 panicked"), "{msg}");
        assert!(msg.contains("injected fault"), "{msg}");
        // The pool is not poisoned: collectives still work on all ranks.
        for _ in 0..3 {
            assert_eq!(pool.run(|c| c.allreduce_sum_u64(1).unwrap()), vec![4; 4]);
        }
        assert_eq!(pool.live_threads(), 4);
    }

    #[test]
    #[should_panic(expected = "rank 1 panicked")]
    fn run_propagates_rank_panic_like_run_ranks() {
        let pool = RankPool::local(2);
        pool.run(|c| {
            if c.rank().0 == 1 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn borrowed_environment_jobs_are_supported() {
        // Non-'static captures: the engine's closures borrow the input
        // slice and task feed; make sure the erased-lifetime path holds.
        let data: Vec<u64> = (0..100).collect();
        let pool = RankPool::local(4);
        let total = pool.run(|c| {
            let chunk = data.len() / c.size();
            let lo = c.rank().0 * chunk;
            let local: u64 = data[lo..lo + chunk].iter().sum();
            c.allreduce_sum_u64(local).unwrap()
        });
        assert_eq!(total, vec![data.iter().sum::<u64>(); 4]);
    }

    #[test]
    fn collective_algo_restored_between_pooled_jobs() {
        let pool = RankPool::new(Universe::local(3).with_collective_algo(CollectiveAlgo::Tree));
        assert_eq!(pool.collective_algo(), CollectiveAlgo::Tree);
        let before = pool.run(|c| {
            let a = c.collective_algo();
            c.set_collective_algo(CollectiveAlgo::Star);
            a
        });
        assert_eq!(before, vec![CollectiveAlgo::Tree; 3]);
        // The prepare phase realigns algorithm (and tags) for job 2.
        assert_eq!(pool.run(|c| c.collective_algo()), vec![CollectiveAlgo::Tree; 3]);
    }

    #[test]
    fn empty_pool_runs_empty_jobs() {
        let pool = RankPool::local(0);
        let out = pool.run_job(0, |c: &Communicator| c.rank().0);
        assert!(out.results.is_empty());
        assert_eq!(out.traffic, TrafficDelta::default());
    }
}
