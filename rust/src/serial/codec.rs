//! `FastSerialize`: the trait every key/value type implements to cross the
//! wire. Implementations for the primitive zoo, strings, vectors, pairs,
//! options and maps — enough to express all of the paper's workloads
//! (wordcount: `(String, u64)`, k-means: `(u32, Vec<f32>)`, pi: `(u8, u64)`,
//! matmul/linreg: `((u32, u32), f64)`).

use std::collections::HashMap;
use std::hash::{BuildHasher, Hash};

use anyhow::Result;

use super::{Decoder, Encoder};

/// Schema-less binary serialization. Contract: `decode(encode(x)) == x`
/// and decoding consumes exactly the bytes encoding produced (verified by
/// proptest in tests/proptest_serial.rs).
pub trait FastSerialize: Sized {
    fn encode(&self, enc: &mut Encoder);
    fn decode(dec: &mut Decoder<'_>) -> Result<Self>;

    /// Size hint in bytes for buffer pre-allocation (0 = unknown).
    fn size_hint(&self) -> usize {
        0
    }
}

macro_rules! impl_fixed {
    ($ty:ty, $put:ident, $get:ident, $n:expr) => {
        impl FastSerialize for $ty {
            #[inline]
            fn encode(&self, enc: &mut Encoder) {
                enc.$put(*self);
            }
            #[inline]
            fn decode(dec: &mut Decoder<'_>) -> Result<Self> {
                dec.$get()
            }
            #[inline]
            fn size_hint(&self) -> usize {
                $n
            }
        }
    };
}

impl_fixed!(u8, put_u8, get_u8, 1);
impl_fixed!(f32, put_f32, get_f32, 4);
impl_fixed!(f64, put_f64, get_f64, 8);

// Integers ride varints: shuffle traffic is dominated by small counts.
macro_rules! impl_varint_unsigned {
    ($ty:ty) => {
        impl FastSerialize for $ty {
            #[inline]
            fn encode(&self, enc: &mut Encoder) {
                enc.put_varint(*self as u64);
            }
            #[inline]
            fn decode(dec: &mut Decoder<'_>) -> Result<Self> {
                let v = dec.get_varint()?;
                Ok(<$ty>::try_from(v)?)
            }
            #[inline]
            fn size_hint(&self) -> usize {
                (64 - (*self as u64 | 1).leading_zeros() as usize).div_ceil(7)
            }
        }
    };
}

macro_rules! impl_varint_signed {
    ($ty:ty) => {
        impl FastSerialize for $ty {
            #[inline]
            fn encode(&self, enc: &mut Encoder) {
                enc.put_varint_signed(*self as i64);
            }
            #[inline]
            fn decode(dec: &mut Decoder<'_>) -> Result<Self> {
                let v = dec.get_varint_signed()?;
                Ok(<$ty>::try_from(v)?)
            }
            #[inline]
            fn size_hint(&self) -> usize {
                10
            }
        }
    };
}

impl_varint_unsigned!(u16);
impl_varint_unsigned!(u32);
impl_varint_unsigned!(u64);
impl_varint_unsigned!(usize);
impl_varint_signed!(i16);
impl_varint_signed!(i32);
impl_varint_signed!(i64);

impl FastSerialize for bool {
    #[inline]
    fn encode(&self, enc: &mut Encoder) {
        enc.put_u8(*self as u8);
    }
    #[inline]
    fn decode(dec: &mut Decoder<'_>) -> Result<Self> {
        Ok(dec.get_u8()? != 0)
    }
    #[inline]
    fn size_hint(&self) -> usize {
        1
    }
}

impl FastSerialize for String {
    #[inline]
    fn encode(&self, enc: &mut Encoder) {
        enc.put_str(self);
    }
    #[inline]
    fn decode(dec: &mut Decoder<'_>) -> Result<Self> {
        Ok(dec.get_str()?.to_owned())
    }
    #[inline]
    fn size_hint(&self) -> usize {
        self.len() + 5
    }
}

impl FastSerialize for () {
    #[inline]
    fn encode(&self, _enc: &mut Encoder) {}
    #[inline]
    fn decode(_dec: &mut Decoder<'_>) -> Result<Self> {
        Ok(())
    }
    #[inline]
    fn size_hint(&self) -> usize {
        0
    }
}

impl<T: FastSerialize> FastSerialize for Option<T> {
    fn encode(&self, enc: &mut Encoder) {
        match self {
            None => enc.put_u8(0),
            Some(v) => {
                enc.put_u8(1);
                v.encode(enc);
            }
        }
    }
    fn decode(dec: &mut Decoder<'_>) -> Result<Self> {
        Ok(match dec.get_u8()? {
            0 => None,
            _ => Some(T::decode(dec)?),
        })
    }
    fn size_hint(&self) -> usize {
        1 + self.as_ref().map_or(0, FastSerialize::size_hint)
    }
}

impl<T: FastSerialize> FastSerialize for Vec<T> {
    fn encode(&self, enc: &mut Encoder) {
        enc.put_varint(self.len() as u64);
        for item in self {
            item.encode(enc);
        }
    }
    fn decode(dec: &mut Decoder<'_>) -> Result<Self> {
        let n = dec.get_varint()? as usize;
        // Guard absurd lengths: never reserve more than what remains.
        let mut v = Vec::with_capacity(n.min(dec.remaining()));
        for _ in 0..n {
            v.push(T::decode(dec)?);
        }
        Ok(v)
    }
    fn size_hint(&self) -> usize {
        5 + self.iter().map(FastSerialize::size_hint).sum::<usize>()
    }
}

impl<A: FastSerialize, B: FastSerialize> FastSerialize for (A, B) {
    fn encode(&self, enc: &mut Encoder) {
        self.0.encode(enc);
        self.1.encode(enc);
    }
    fn decode(dec: &mut Decoder<'_>) -> Result<Self> {
        Ok((A::decode(dec)?, B::decode(dec)?))
    }
    fn size_hint(&self) -> usize {
        self.0.size_hint() + self.1.size_hint()
    }
}

impl<A: FastSerialize, B: FastSerialize, C: FastSerialize> FastSerialize for (A, B, C) {
    fn encode(&self, enc: &mut Encoder) {
        self.0.encode(enc);
        self.1.encode(enc);
        self.2.encode(enc);
    }
    fn decode(dec: &mut Decoder<'_>) -> Result<Self> {
        Ok((A::decode(dec)?, B::decode(dec)?, C::decode(dec)?))
    }
    fn size_hint(&self) -> usize {
        self.0.size_hint() + self.1.size_hint() + self.2.size_hint()
    }
}

impl<K, V, S> FastSerialize for HashMap<K, V, S>
where
    K: FastSerialize + Eq + Hash,
    V: FastSerialize,
    S: BuildHasher + Default,
{
    fn encode(&self, enc: &mut Encoder) {
        enc.put_varint(self.len() as u64);
        for (k, v) in self {
            k.encode(enc);
            v.encode(enc);
        }
    }
    fn decode(dec: &mut Decoder<'_>) -> Result<Self> {
        let n = dec.get_varint()? as usize;
        let mut m = HashMap::with_capacity_and_hasher(n.min(dec.remaining()), S::default());
        for _ in 0..n {
            let k = K::decode(dec)?;
            let v = V::decode(dec)?;
            m.insert(k, v);
        }
        Ok(m)
    }
    fn size_hint(&self) -> usize {
        5 + self.iter().map(|(k, v)| k.size_hint() + v.size_hint()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{from_bytes, to_bytes};
    use super::*;

    fn roundtrip<T: FastSerialize + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = to_bytes(&v);
        let back: T = from_bytes(&bytes).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn primitives() {
        roundtrip(0u8);
        roundtrip(42u32);
        roundtrip(u64::MAX);
        roundtrip(-7i32);
        roundtrip(i64::MIN);
        roundtrip(3.25f32);
        roundtrip(true);
        roundtrip(());
    }

    #[test]
    fn wordcount_record() {
        roundtrip(("brown".to_string(), 17u64));
    }

    #[test]
    fn kmeans_record() {
        roundtrip((3u32, vec![1.0f32, -2.5, 0.0]));
    }

    #[test]
    fn matmul_record() {
        roundtrip(((2u32, 9u32), 1.5f64));
    }

    #[test]
    fn nested_containers() {
        roundtrip(vec![Some(("k".to_string(), vec![1u64, 2, 3])), None]);
    }

    #[test]
    fn hashmap_roundtrip() {
        let mut m = HashMap::new();
        m.insert("a".to_string(), 1u64);
        m.insert("b".to_string(), 2u64);
        roundtrip(m);
    }

    #[test]
    fn decode_of_truncated_vec_fails() {
        let bytes = to_bytes(&vec![1u64, 2, 3]);
        assert!(from_bytes::<Vec<u64>>(&bytes[..bytes.len() - 1]).is_err());
    }

    #[test]
    fn absurd_length_prefix_fails_cleanly() {
        let mut enc = Encoder::new();
        enc.put_varint(u64::MAX); // claims 2^64 elements
        assert!(from_bytes::<Vec<u8>>(enc.as_bytes()).is_err());
    }

    #[test]
    fn size_hint_is_upper_boundish() {
        let v = ("hello".to_string(), 123u64);
        let hint = v.size_hint();
        let actual = to_bytes(&v).len();
        assert!(hint >= actual, "hint {hint} < actual {actual}");
    }
}
