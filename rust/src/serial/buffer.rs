//! Byte-level encoder/decoder: little-endian fixed-width numbers, LEB128
//! varints, zig-zag signed varints, length-prefixed bytes/strings.

use anyhow::{anyhow, ensure, Result};

/// Append-only byte sink. Reuse via [`Encoder::clear`] to amortize
/// allocation in the shuffle hot loop (see core/shuffle.rs).
#[derive(Debug, Default, Clone)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap) }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Reset length, keep capacity — buffer reuse for the hot loop.
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    #[inline]
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// LEB128 unsigned varint (1 byte for < 128 — most shuffle counts).
    #[inline]
    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return;
            }
            self.buf.push(byte | 0x80);
        }
    }

    /// Zig-zag signed varint: small magnitudes stay small either sign.
    #[inline]
    pub fn put_varint_signed(&mut self, v: i64) {
        self.put_varint(((v << 1) ^ (v >> 63)) as u64);
    }

    /// Length-prefixed raw bytes.
    #[inline]
    pub fn put_bytes(&mut self, bytes: &[u8]) {
        self.put_varint(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }

    /// Length-prefixed UTF-8 string.
    #[inline]
    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }

    /// Raw bytes with NO length prefix (caller knows the length).
    #[inline]
    pub fn put_raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }
}

/// Cursor over a byte slice; every read is bounds-checked.
#[derive(Debug)]
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Error unless the buffer was fully consumed.
    pub fn finish(&self) -> Result<()> {
        ensure!(
            self.is_empty(),
            "trailing garbage: {} of {} bytes unread",
            self.remaining(),
            self.buf.len()
        );
        Ok(())
    }

    #[inline]
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.remaining() >= n, "decode underrun: need {n}, have {}", self.remaining());
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    #[inline]
    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    #[inline]
    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    #[inline]
    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    #[inline]
    pub fn get_i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    #[inline]
    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    #[inline]
    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    #[inline]
    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    #[inline]
    pub fn get_varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.get_u8()?;
            ensure!(shift < 64, "varint overlong");
            // The 10th byte may only carry one significant bit.
            if shift == 63 {
                ensure!(byte & 0x7e == 0, "varint overflows u64");
            }
            v |= ((byte & 0x7f) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    #[inline]
    pub fn get_varint_signed(&mut self) -> Result<i64> {
        let z = self.get_varint()?;
        Ok(((z >> 1) as i64) ^ -((z & 1) as i64))
    }

    /// Length-prefixed raw bytes (borrowed — zero copy).
    #[inline]
    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_varint()?;
        let n = usize::try_from(n).map_err(|_| anyhow!("byte length {n} overflows usize"))?;
        self.take(n)
    }

    /// Length-prefixed UTF-8 string (borrowed — zero copy).
    #[inline]
    pub fn get_str(&mut self) -> Result<&'a str> {
        Ok(std::str::from_utf8(self.get_bytes()?)?)
    }

    /// Raw bytes with no length prefix.
    #[inline]
    pub fn get_raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_width_roundtrip() {
        let mut e = Encoder::new();
        e.put_u8(7);
        e.put_u32(0xdead_beef);
        e.put_u64(u64::MAX);
        e.put_i32(-42);
        e.put_i64(i64::MIN);
        e.put_f32(1.5);
        e.put_f64(-2.25);
        let mut d = Decoder::new(e.as_bytes());
        assert_eq!(d.get_u8().unwrap(), 7);
        assert_eq!(d.get_u32().unwrap(), 0xdead_beef);
        assert_eq!(d.get_u64().unwrap(), u64::MAX);
        assert_eq!(d.get_i32().unwrap(), -42);
        assert_eq!(d.get_i64().unwrap(), i64::MIN);
        assert_eq!(d.get_f32().unwrap(), 1.5);
        assert_eq!(d.get_f64().unwrap(), -2.25);
        d.finish().unwrap();
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut e = Encoder::new();
            e.put_varint(v);
            let mut d = Decoder::new(e.as_bytes());
            assert_eq!(d.get_varint().unwrap(), v, "value {v}");
            d.finish().unwrap();
        }
    }

    #[test]
    fn varint_signed_boundaries() {
        for v in [0i64, 1, -1, 63, -64, 64, i64::MAX, i64::MIN] {
            let mut e = Encoder::new();
            e.put_varint_signed(v);
            let mut d = Decoder::new(e.as_bytes());
            assert_eq!(d.get_varint_signed().unwrap(), v, "value {v}");
        }
    }

    #[test]
    fn varint_small_is_one_byte() {
        let mut e = Encoder::new();
        e.put_varint(127);
        assert_eq!(e.len(), 1);
    }

    #[test]
    fn underrun_is_error_not_panic() {
        let mut d = Decoder::new(&[1, 2]);
        assert!(d.get_u32().is_err());
    }

    #[test]
    fn overlong_varint_rejected() {
        // 11 continuation bytes: longer than any valid u64 varint.
        let bytes = [0x80u8; 11];
        let mut d = Decoder::new(&bytes);
        assert!(d.get_varint().is_err());
    }

    #[test]
    fn trailing_garbage_detected() {
        let mut e = Encoder::new();
        e.put_u8(1);
        e.put_u8(2);
        let mut d = Decoder::new(e.as_bytes());
        d.get_u8().unwrap();
        assert!(d.finish().is_err());
    }

    #[test]
    fn str_roundtrip_zero_copy() {
        let mut e = Encoder::new();
        e.put_str("héllo wörld");
        let mut d = Decoder::new(e.as_bytes());
        assert_eq!(d.get_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn encoder_clear_keeps_capacity() {
        let mut e = Encoder::with_capacity(1024);
        e.put_raw(&[0u8; 512]);
        e.clear();
        assert_eq!(e.len(), 0);
        assert!(e.buf.capacity() >= 1024);
    }
}
