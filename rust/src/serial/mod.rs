//! Fast serialization — the Blaze "no-protobuf" wire format.
//!
//! Blaze's pitch (and the paper's §II) is that MPI MapReduce frameworks
//! waste time in ProtoBuf-style serialization; a schema-less, allocation-
//! free binary codec is faster. This module is that codec: little-endian
//! fixed-width primitives, LEB128 varints for lengths/counts, zig-zag for
//! signed varints, and `FastSerialize` as the single trait every key/value
//! type implements to ride the shuffle.
//!
//! `benches/micro_hot_paths.rs` compares this codec against `serde_json`
//! on shuffle-shaped records (the paper's "faster serialization" claim);
//! `tests/` + proptest round-trip every implementation.

mod buffer;
mod codec;

pub use buffer::{Decoder, Encoder};
pub use codec::FastSerialize;

use anyhow::Result;

/// Encode a value to a fresh byte vector.
pub fn to_bytes<T: FastSerialize>(value: &T) -> Vec<u8> {
    let mut enc = Encoder::new();
    value.encode(&mut enc);
    enc.into_bytes()
}

/// Decode a value from a byte slice, requiring full consumption.
pub fn from_bytes<T: FastSerialize>(bytes: &[u8]) -> Result<T> {
    let mut dec = Decoder::new(bytes);
    let value = T::decode(&mut dec)?;
    dec.finish()?;
    Ok(value)
}
