//! `DistVector`: a rank-sharded vector — the paper's "DistVector of
//! locally-grouped runs" (§III.D pseudocode step 3).
//!
//! Each rank owns a local `Vec<T>` shard; local mutation (push/extend/
//! sort) costs nothing on the wire. The collective operations —
//! [`DistVector::len_global`], [`DistVector::global_offset`],
//! [`DistVector::rebalance`] — are built on the communicator's
//! collectives, so the virtual clock charges them like any other
//! exchange. Delayed reduction materializes its grouped runs in one of
//! these, sorts the shard in place (merge sort), and then dissolves it
//! into the shuffle via [`DistVector::into_local`].

use anyhow::Result;

use crate::mpi::{Communicator, Rank};
use crate::serial::{from_bytes, to_bytes, FastSerialize};

use super::balance::rebalance_plan;

/// A vector sharded across the ranks of one communicator.
pub struct DistVector<'c, T> {
    comm: &'c Communicator,
    local: Vec<T>,
}

impl<'c, T> DistVector<'c, T> {
    /// An empty shard on this rank.
    pub fn new(comm: &'c Communicator) -> Self {
        Self { comm, local: Vec::new() }
    }

    /// Wrap an already-built local shard (delayed reduction's grouped
    /// runs enter the container this way).
    pub fn from_local(comm: &'c Communicator, local: Vec<T>) -> Self {
        Self { comm, local }
    }

    /// Append one element to the local shard (no communication).
    pub fn push(&mut self, item: T) {
        self.local.push(item);
    }

    /// Append many elements to the local shard (no communication).
    pub fn extend(&mut self, items: impl IntoIterator<Item = T>) {
        self.local.extend(items);
    }

    pub fn len_local(&self) -> usize {
        self.local.len()
    }

    pub fn is_empty_local(&self) -> bool {
        self.local.is_empty()
    }

    pub fn local(&self) -> &[T] {
        &self.local
    }

    pub fn local_mut(&mut self) -> &mut Vec<T> {
        &mut self.local
    }

    /// Dissolve the container, keeping this rank's shard.
    pub fn into_local(self) -> Vec<T> {
        self.local
    }

    pub fn comm(&self) -> &'c Communicator {
        self.comm
    }

    /// COLLECTIVE: total element count across all ranks.
    pub fn len_global(&self) -> Result<u64> {
        self.comm.allreduce_sum_u64(self.local.len() as u64)
    }

    /// COLLECTIVE: this shard's starting index in the global order
    /// (exclusive prefix sum of shard lengths over ranks).
    pub fn global_offset(&self) -> Result<u64> {
        self.comm.exscan_sum(self.local.len() as u64)
    }
}

impl<'c, T: FastSerialize> DistVector<'c, T> {
    /// COLLECTIVE: level shard sizes to within one element using the
    /// minimal-move [`rebalance_plan`]. Donors ship elements from the
    /// tail of their shard; receivers append. Every rank derives the
    /// identical plan from one `allgather` of shard lengths, so the
    /// point-to-point transfers pair up without negotiation.
    pub fn rebalance(&mut self) -> Result<()> {
        let lens: Vec<u64> = self.comm.allgather(self.local.len() as u64)?;
        let counts: Vec<usize> = lens.into_iter().map(|l| l as usize).collect();
        let plan = rebalance_plan(&counts);
        if plan.is_empty() {
            return Ok(());
        }
        // One tag for the whole exchange: sends are matched by
        // (source, tag), and each rank appears at most once per plan
        // entry, so order stays deterministic. All ranks reach this
        // point (the plan is nonempty everywhere or nowhere), keeping
        // the collective tag counters aligned.
        let tag = self.comm.next_collective_tag();
        let me = self.comm.rank().0;
        for m in &plan {
            if m.from == me {
                let moved: Vec<T> = self.local.split_off(self.local.len() - m.count);
                self.comm.send(Rank(m.to), tag, to_bytes(&moved))?;
            }
        }
        for m in &plan {
            if m.to == me {
                let bytes = self.comm.recv(Rank(m.from), tag)?;
                let mut moved: Vec<T> = from_bytes(&bytes)?;
                self.local.append(&mut moved);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testpool::pool_run;

    #[test]
    fn local_ops_do_not_touch_the_network() {
        let got = pool_run(2, |c| {
            let mut dv: DistVector<u32> = DistVector::new(c);
            dv.push(1);
            dv.extend([2, 3]);
            dv.local_mut().sort_unstable_by(|a, b| b.cmp(a));
            (dv.len_local(), dv.local().to_vec(), dv.into_local())
        });
        for (len, local, owned) in got {
            assert_eq!(len, 3);
            assert_eq!(local, vec![3, 2, 1]);
            assert_eq!(owned, vec![3, 2, 1]);
        }
    }

    #[test]
    fn global_len_and_offset() {
        let got = pool_run(4, |c| {
            let mut dv: DistVector<u64> = DistVector::new(c);
            dv.extend(0..c.rank().0 as u64); // rank r holds r elements
            (dv.len_global().unwrap(), dv.global_offset().unwrap())
        });
        // Lengths are [0, 1, 2, 3]: total 6, offsets [0, 0, 1, 3].
        assert_eq!(got, vec![(6, 0), (6, 0), (6, 1), (6, 3)]);
    }

    #[test]
    fn rebalance_levels_and_preserves_multiset() {
        let shards = pool_run(4, |c| {
            let r = c.rank().0 as u64;
            let mut dv: DistVector<u64> = DistVector::new(c);
            // Rank r pushes 3r elements: lengths [0, 3, 6, 9].
            dv.extend((0..3 * r).map(|i| r * 100 + i));
            dv.rebalance().unwrap();
            dv.into_local()
        });
        let lens: Vec<usize> = shards.iter().map(Vec::len).collect();
        let max = *lens.iter().max().unwrap();
        let min = *lens.iter().min().unwrap();
        assert!(max - min <= 1, "lens {lens:?}");
        let mut all: Vec<u64> = shards.into_iter().flatten().collect();
        all.sort_unstable();
        let mut want: Vec<u64> =
            (0..4u64).flat_map(|r| (0..3 * r).map(move |i| r * 100 + i)).collect();
        want.sort_unstable();
        assert_eq!(all, want, "elements lost or duplicated");
    }

    #[test]
    fn rebalance_on_balanced_data_is_a_no_op() {
        let shards = pool_run(3, |c| {
            let mut dv: DistVector<u64> = DistVector::from_local(c, vec![c.rank().0 as u64; 5]);
            dv.rebalance().unwrap();
            dv.into_local()
        });
        for (r, shard) in shards.iter().enumerate() {
            assert_eq!(shard, &vec![r as u64; 5]);
        }
    }
}
