//! `ShardRouter`: the deterministic, salted key→owner map.
//!
//! Every rank must route a key to the same shard without talking to a
//! master, so ownership is a pure function of `(shards, salt, key)`:
//! a seeded [`StableHasher`] (never process-random state) reduced mod the
//! shard count. The salt folds in the cluster seed + job salt
//! (`engine::MapReduceJob::salt`), so two jobs on the same cluster can
//! place the same keys differently — which is how the engine's
//! "different seeds, same results, different placement" tests probe for
//! accidental coupling.

use std::hash::{Hash, Hasher};

use crate::mpi::Rank;
use crate::util::hash::StableHasher;

/// Stream constant folded into the salt so router hashes are independent
/// of other `StableHasher` users sharing a seed.
const ROUTER_STREAM: u64 = 0x5248_4F55_5445_5221;

/// Key→owner placement: the capability every shuffle-like exchange needs
/// from a router. Implemented by [`ShardRouter`] (stateless hash mod
/// shard count — placement is a pure function, moves ~everything on a
/// width change) and [`crate::dist::BucketRouter`] (epoch-versioned
/// bucket table — placement survives resizes with minimal-move
/// migration). [`crate::core::shuffle::shuffle_pairs`] and
/// [`crate::dist::DistHashMap`] are generic over it, which is how the
/// iterative engine's delta shuffle rides the exact same exchange as the
/// batch engines.
pub trait KeyRouter {
    /// Number of ranks the router maps keys into — the communicator
    /// width any exchange using this router must run at.
    fn width(&self) -> usize;

    /// Owning rank of `key`. Deterministic: every rank computes the same
    /// owner without negotiation.
    fn route<K: Hash + ?Sized>(&self, key: &K) -> Rank;
}

/// Deterministic salted key→shard router (one shard per reducer rank).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRouter {
    shards: usize,
    salt: u64,
}

impl ShardRouter {
    /// A router over `shards` shards. Two routers built with the same
    /// `(shards, salt)` agree on every key, on every rank, forever.
    pub fn new(shards: usize, salt: u64) -> Self {
        assert!(shards > 0, "router needs at least one shard");
        Self { shards, salt }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn salt(&self) -> u64 {
        self.salt
    }

    /// Owning rank of `key`.
    #[inline]
    pub fn owner<K: Hash + ?Sized>(&self, key: &K) -> Rank {
        let mut h = StableHasher::with_seed(self.salt ^ ROUTER_STREAM);
        key.hash(&mut h);
        Rank((h.finish() % self.shards as u64) as usize)
    }
}

impl KeyRouter for ShardRouter {
    fn width(&self) -> usize {
        self.shards
    }

    #[inline]
    fn route<K: Hash + ?Sized>(&self, key: &K) -> Rank {
        ShardRouter::owner(self, key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_across_instances() {
        let a = ShardRouter::new(7, 42);
        let b = ShardRouter::new(7, 42);
        for i in 0..500u64 {
            let key = format!("key-{i}");
            assert_eq!(a.owner(&key), b.owner(&key), "key {key}");
        }
    }

    #[test]
    fn owners_in_range() {
        for shards in [1usize, 2, 3, 16, 31] {
            let r = ShardRouter::new(shards, 9);
            for i in 0..200u64 {
                assert!(r.owner(&i).0 < shards);
            }
        }
    }

    #[test]
    fn salt_changes_placement() {
        let a = ShardRouter::new(8, 1);
        let b = ShardRouter::new(8, 2);
        let moved = (0..200u64).filter(|i| a.owner(i) != b.owner(i)).count();
        // With 8 shards ~7/8 of keys should move under a new salt.
        assert!(moved > 100, "only {moved}/200 keys moved");
    }

    #[test]
    fn spreads_sequential_keys() {
        let r = ShardRouter::new(16, 0);
        let mut hist = [0usize; 16];
        for i in 0..1_600u64 {
            hist[r.owner(&i).0] += 1;
        }
        for (shard, n) in hist.iter().enumerate() {
            assert!((40..200).contains(n), "shard {shard}: {n} ({hist:?})");
        }
    }

    #[test]
    fn str_and_string_agree() {
        let r = ShardRouter::new(5, 3);
        assert_eq!(r.owner("wordlike"), r.owner(&"wordlike".to_string()));
    }
}
