//! Shard leveling: compute the minimal set of moves that balances shard
//! populations to within one element.
//!
//! Used by [`crate::dist::DistVector::rebalance`] after skewed pushes and
//! by [`crate::cluster::ElasticCluster`] when the shard count changes
//! between waves (DELMA-style grow/shrink). The plan is a pure function
//! of the shard counts, so every rank derives the identical plan from one
//! `allgather` — no coordinator round.

/// One planned transfer: move `count` elements from shard `from` to
/// shard `to`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Move {
    pub from: usize,
    pub to: usize,
    pub count: usize,
}

/// Plan the minimal-mass set of moves that levels `counts` to within one
/// element (max - min <= 1 after applying the plan).
///
/// Guarantees:
/// * conservation — applying the plan preserves the total count;
/// * no self-moves and no zero-count moves;
/// * each shard is only a donor or only a receiver, never both;
/// * moved mass is minimal: the `total % n` "+1" targets go to the
///   largest shards, so no element travels that could have stayed.
pub fn rebalance_plan(counts: &[usize]) -> Vec<Move> {
    let n = counts.len();
    if n == 0 {
        return Vec::new();
    }
    let total: usize = counts.iter().sum();
    let base = total / n;
    let extra = total % n;

    // Give the +1 targets to the `extra` most-populated shards (ties
    // broken by index for determinism): any other assignment moves at
    // least as much mass.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| counts[b].cmp(&counts[a]).then(a.cmp(&b)));
    let mut target = vec![base as i64; n];
    for &i in order.iter().take(extra) {
        target[i] += 1;
    }

    let mut surplus: Vec<i64> =
        counts.iter().zip(&target).map(|(&c, &t)| c as i64 - t).collect();
    let donors: Vec<usize> = (0..n).filter(|&i| surplus[i] > 0).collect();
    let receivers: Vec<usize> = (0..n).filter(|&i| surplus[i] < 0).collect();

    // Two-pointer matching: drain each donor into receivers in index
    // order. Plan length is at most donors + receivers - 1 < n.
    let mut moves = Vec::new();
    let (mut di, mut ri) = (0, 0);
    while di < donors.len() && ri < receivers.len() {
        let d = donors[di];
        let r = receivers[ri];
        let amount = surplus[d].min(-surplus[r]);
        debug_assert!(amount > 0);
        moves.push(Move { from: d, to: r, count: amount as usize });
        surplus[d] -= amount;
        surplus[r] += amount;
        if surplus[d] == 0 {
            di += 1;
        }
        if surplus[r] == 0 {
            ri += 1;
        }
    }
    debug_assert!(surplus.iter().all(|&s| s == 0));
    moves
}

#[cfg(test)]
mod tests {
    use super::*;

    fn apply(counts: &[usize], plan: &[Move]) -> Vec<usize> {
        let mut after = counts.to_vec();
        for m in plan {
            assert_ne!(m.from, m.to, "self-move in {plan:?}");
            assert!(m.count > 0, "zero-count move in {plan:?}");
            after[m.from] -= m.count;
            after[m.to] += m.count;
        }
        after
    }

    #[test]
    fn conserves_and_levels() {
        for counts in [
            vec![10usize, 0, 0, 2],
            vec![1, 1, 1],
            vec![0, 0, 7],
            vec![3],
            vec![100, 1, 50, 2, 99],
        ] {
            let total: usize = counts.iter().sum();
            let plan = rebalance_plan(&counts);
            let after = apply(&counts, &plan);
            assert_eq!(after.iter().sum::<usize>(), total, "{counts:?}");
            let max = *after.iter().max().unwrap();
            let min = *after.iter().min().unwrap();
            assert!(max - min <= 1, "{counts:?} -> {after:?}");
        }
    }

    #[test]
    fn balanced_input_needs_no_moves() {
        assert!(rebalance_plan(&[5, 5, 5]).is_empty());
        // 14 over 3 shards levels as {5, 4, 5}: already within one.
        assert!(rebalance_plan(&[5, 4, 5]).is_empty());
        assert!(rebalance_plan(&[]).is_empty());
        assert!(rebalance_plan(&[0, 0]).is_empty());
    }

    #[test]
    fn moved_mass_is_minimal() {
        // [10, 0, 0, 2]: targets are 3 each, donor 0 must shed exactly 7.
        let plan = rebalance_plan(&[10, 0, 0, 2]);
        let moved: usize = plan.iter().map(|m| m.count).sum();
        assert_eq!(moved, 7, "{plan:?}");
        // The +1 target goes to the largest shard: [4, 1] -> targets
        // {3, 2}, one move of 1 (not 2, which a low-index +1 would cost).
        let plan = rebalance_plan(&[4, 1]);
        assert_eq!(plan, vec![Move { from: 0, to: 1, count: 1 }]);
    }

    #[test]
    fn no_shard_both_sends_and_receives() {
        let plan = rebalance_plan(&[9, 0, 4, 0, 9]);
        for m in &plan {
            assert!(plan.iter().all(|o| o.to != m.from), "{plan:?}");
        }
        assert!(plan.len() < 5, "at most n-1 moves: {plan:?}");
    }

    #[test]
    fn deterministic() {
        let counts = vec![7, 3, 9, 0, 0, 5];
        assert_eq!(rebalance_plan(&counts), rebalance_plan(&counts));
    }
}
