//! Distributed containers — the data-structure layer the paper's engines
//! are written against (§III.D: "Intermediate reducer combines the keys
//! into a DistVector", results land in a `DistHashMap`-shaped shard).
//!
//! The design follows the container-centric lineage the related work
//! establishes: Thrill's DIAs show that a compiled MapReduce stack is
//! really a library of distributed collections plus collectives, and
//! M3R's in-memory key ownership shows that a *stable* key→rank map is
//! the lever for iterative jobs. Concretely:
//!
//! * [`ShardRouter`] — the salted, deterministic key→owner hash every
//!   shuffle and container shares. Same salt + shard count ⇒ same
//!   placement on every rank, with no negotiation (the determinism
//!   property `tests/prop_invariants.rs` checks).
//! * [`BucketRouter`] — the epoch-versioned bucketed router behind live
//!   elastic rebalancing: keys hash into fixed virtual buckets, a
//!   versioned bucket→rank table owns placement, and
//!   [`BucketRouter::resize`] re-homes only the minimal-move set
//!   [`rebalance_plan`] picks. [`crate::core::IterativeJob`] keys its
//!   pinned per-key state (and its delta shuffle) by it.
//! * [`KeyRouter`] — the trait both routers implement; the shuffle and
//!   [`DistHashMap`] are generic over it.
//! * [`DistVector`] — a rank-sharded `Vec`: local pushes are free, global
//!   length/offset are one collective away, and [`DistVector::rebalance`]
//!   levels shard sizes using a [`rebalance_plan`].
//! * [`DistHashMap`] — stage-anywhere / flush-to-owner key-value shards:
//!   `stage` buffers pairs on whichever rank produced them; `flush`
//!   shuffles every staged pair to `router.owner(key)` and combines.
//! * [`rebalance_plan`] — the minimal-move leveling plan shared by
//!   `DistVector::rebalance` and [`crate::cluster::ElasticCluster`]
//!   resizes.
//!
//! All collective operations here are SPMD: every rank of the
//! communicator must make the same call in the same order, exactly like
//! the MPI collectives they are built from.

mod balance;
mod bucket;
mod hashmap;
mod router;
mod vector;

pub use balance::{rebalance_plan, Move};
pub use bucket::{BucketMove, BucketRouter, DEFAULT_BUCKETS};
pub use hashmap::DistHashMap;
pub use router::{KeyRouter, ShardRouter};
pub use vector::DistVector;
