//! `BucketRouter`: the epoch-versioned key→owner map that makes live
//! elastic rebalancing cheap.
//!
//! [`super::ShardRouter`] is a pure function of `(shards, salt, key)`, so
//! changing the shard count re-places almost every key — fine for a
//! one-shot shuffle, fatal for an iterative job whose per-key state is
//! pinned rank-local (the M3R ownership win). `BucketRouter` adds one
//! level of indirection: keys hash into a fixed set of virtual
//! **buckets**, and a versioned `bucket → rank` table says who owns each
//! bucket. A [`BucketRouter::resize`] re-homes only the buckets that
//! *must* move — everything stranded on removed ranks, plus the
//! minimal-mass leveling set [`super::rebalance_plan`] picks — and bumps
//! the router **epoch** so containers can tell a stale placement from a
//! live one. Growing `P -> P+1` therefore migrates ~`1/(P+1)` of the
//! keys instead of `P/(P+1)`.
//!
//! Everything is deterministic: the table is a pure function of the
//! resize history and the bucket loads passed in, so every rank (or the
//! driver, between waves) derives the identical placement with no
//! coordinator round.

use std::hash::{Hash, Hasher};

use crate::mpi::Rank;
use crate::util::hash::StableHasher;

use super::balance::rebalance_plan;
use super::router::KeyRouter;

/// Stream constant folded into the salt so bucket hashes are independent
/// of [`super::ShardRouter`]'s (and any other `StableHasher` user's).
const BUCKET_STREAM: u64 = 0x4255_434B_4554_5221;

/// Virtual buckets per router: enough granularity that leveling at
/// bucket grain tracks the key-grain [`rebalance_plan`] closely, small
/// enough that the table is a cache line or two.
pub const DEFAULT_BUCKETS: usize = 128;

/// One bucket reassignment from a [`BucketRouter::resize`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketMove {
    /// The reassigned bucket.
    pub bucket: usize,
    /// Rank that owned it before the resize (may exceed the new width —
    /// that is exactly the stranded-bucket case a shrink re-homes).
    pub from: usize,
    /// Rank that owns it after the resize (always `< new width`).
    pub to: usize,
}

/// Epoch-versioned bucketed key→owner router (see the module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketRouter {
    salt: u64,
    /// `assign[b]` = rank owning bucket `b`.
    assign: Vec<usize>,
    ranks: usize,
    epoch: u64,
}

impl BucketRouter {
    /// A router over `ranks` ranks with [`DEFAULT_BUCKETS`] buckets at
    /// epoch 0, buckets dealt round-robin. Two routers built with the
    /// same `(ranks, salt)` and taken through the same resize history
    /// (same loads) agree on every key.
    pub fn new(ranks: usize, salt: u64) -> Self {
        Self::with_buckets(ranks, DEFAULT_BUCKETS, salt)
    }

    /// Like [`BucketRouter::new`] with an explicit bucket count.
    /// `ranks > buckets` is allowed (some ranks own nothing until a
    /// resize levels loads onto them).
    pub fn with_buckets(ranks: usize, buckets: usize, salt: u64) -> Self {
        assert!(ranks > 0, "router needs at least one rank");
        assert!(buckets > 0, "router needs at least one bucket");
        Self { salt, assign: (0..buckets).map(|b| b % ranks).collect(), ranks, epoch: 0 }
    }

    /// Reconstruct a router from persisted placement — the checkpoint
    /// restore path ([`crate::store::CheckpointStore`]): the saved
    /// `assign` table, salt, width and epoch come back verbatim, so a
    /// same-width recovery places every key exactly where the
    /// checkpointed session had it. A different-width recovery then
    /// rides the ordinary [`BucketRouter::resize`].
    pub fn restore(salt: u64, assign: Vec<usize>, ranks: usize, epoch: u64) -> Self {
        assert!(ranks > 0, "router needs at least one rank");
        assert!(!assign.is_empty(), "router needs at least one bucket");
        assert!(
            assign.iter().all(|&r| r < ranks),
            "assign table names a rank outside 0..{ranks}"
        );
        Self { salt, assign, ranks, epoch }
    }

    pub fn salt(&self) -> u64 {
        self.salt
    }

    /// The live `bucket → rank` table (what a checkpoint persists).
    pub fn assignments(&self) -> &[usize] {
        &self.assign
    }

    pub fn buckets(&self) -> usize {
        self.assign.len()
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Resizes survived so far — bumped once per [`BucketRouter::resize`].
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The rank currently owning bucket `b`.
    pub fn rank_of_bucket(&self, b: usize) -> Rank {
        Rank(self.assign[b])
    }

    /// The virtual bucket `key` hashes into — stable across resizes.
    #[inline]
    pub fn bucket_of<K: Hash + ?Sized>(&self, key: &K) -> usize {
        let mut h = StableHasher::with_seed(self.salt ^ BUCKET_STREAM);
        key.hash(&mut h);
        (h.finish() % self.assign.len() as u64) as usize
    }

    /// Retarget the table at `new_ranks` ranks, moving as little mass as
    /// possible. `loads[b]` is the current key population of bucket `b`
    /// (the caller knows it: bucket contents live with their owners).
    ///
    /// Deterministic, two phases:
    /// 1. buckets stranded on removed ranks go, heaviest first, to the
    ///    lightest surviving rank (ties by index);
    /// 2. the per-rank loads are leveled with the shared minimal-move
    ///    [`rebalance_plan`], realized at bucket granularity — a move's
    ///    mass is matched from the donor's heaviest buckets without ever
    ///    overshooting, so no key travels that key-grain leveling would
    ///    have kept in place.
    ///
    /// Bumps the epoch and returns the reassignments (empty when the
    /// width is unchanged and loads are already level).
    pub fn resize(&mut self, new_ranks: usize, loads: &[usize]) -> Vec<BucketMove> {
        assert!(new_ranks > 0, "cannot resize to zero ranks");
        assert_eq!(loads.len(), self.assign.len(), "one load per bucket");
        let before = self.assign.clone();

        let mut rank_load = vec![0usize; new_ranks];
        let mut stranded: Vec<usize> = Vec::new();
        for (b, &r) in self.assign.iter().enumerate() {
            if r < new_ranks {
                rank_load[r] += loads[b];
            } else {
                stranded.push(b);
            }
        }
        // Phase 1: re-home stranded buckets, heaviest first onto the
        // lightest rank (ties by index) — deterministic greedy.
        stranded.sort_by(|&a, &b| loads[b].cmp(&loads[a]).then(a.cmp(&b)));
        for b in stranded {
            let r = (0..new_ranks).min_by_key(|&r| (rank_load[r], r)).expect("new_ranks > 0");
            self.assign[b] = r;
            rank_load[r] += loads[b];
        }

        // Phase 2: level with the minimal-move plan at bucket grain.
        for m in &rebalance_plan(&rank_load) {
            let mut remaining = m.count;
            let mut donors: Vec<usize> =
                (0..self.assign.len()).filter(|&b| self.assign[b] == m.from).collect();
            donors.sort_by(|&a, &b| loads[b].cmp(&loads[a]).then(a.cmp(&b)));
            for b in donors {
                if remaining == 0 {
                    break;
                }
                if loads[b] > 0 && loads[b] <= remaining {
                    self.assign[b] = m.to;
                    remaining -= loads[b];
                }
            }
        }

        self.ranks = new_ranks;
        self.epoch += 1;
        before
            .into_iter()
            .enumerate()
            .filter(|&(b, old)| self.assign[b] != old)
            .map(|(b, old)| BucketMove { bucket: b, from: old, to: self.assign[b] })
            .collect()
    }
}

impl KeyRouter for BucketRouter {
    fn width(&self) -> usize {
        self.ranks
    }

    #[inline]
    fn route<K: Hash + ?Sized>(&self, key: &K) -> Rank {
        Rank(self.assign[self.bucket_of(key)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads_for(router: &BucketRouter, keys: &[u64]) -> Vec<usize> {
        let mut loads = vec![0usize; router.buckets()];
        for k in keys {
            loads[router.bucket_of(k)] += 1;
        }
        loads
    }

    #[test]
    fn deterministic_and_in_range() {
        let a = BucketRouter::new(5, 9);
        let b = BucketRouter::new(5, 9);
        for k in 0..500u64 {
            assert_eq!(a.route(&k), b.route(&k));
            assert!(a.route(&k).0 < 5);
        }
    }

    #[test]
    fn initial_assignment_is_round_robin_balanced() {
        let r = BucketRouter::with_buckets(4, 16, 0);
        let mut per_rank = [0usize; 4];
        for b in 0..16 {
            per_rank[r.rank_of_bucket(b).0] += 1;
        }
        assert_eq!(per_rank, [4; 4]);
        assert_eq!(r.epoch(), 0);
    }

    #[test]
    fn grow_moves_a_minority_of_keys() {
        let keys: Vec<u64> = (0..4_000).collect();
        let mut router = BucketRouter::new(4, 7);
        let before: Vec<_> = keys.iter().map(|k| router.route(k)).collect();
        let loads = loads_for(&router, &keys);
        let moves = router.resize(5, &loads);
        assert!(!moves.is_empty(), "grow must hand the new rank some buckets");
        assert_eq!(router.epoch(), 1);
        let moved = keys.iter().zip(&before).filter(|(k, &b)| router.route(*k) != b).count();
        // Min-mass target for 4 -> 5 ranks is ~1/5 of the keys; a mod-5
        // rehash would move ~4/5. Allow slack for bucket granularity.
        assert!(moved * 3 < keys.len(), "moved {moved}/{} keys", keys.len());
        // Every moved key corresponds to a reported bucket move.
        for (k, &b) in keys.iter().zip(&before) {
            if router.route(k) != b {
                assert!(moves.iter().any(|m| m.bucket == router.bucket_of(k)), "unreported move");
            }
        }
    }

    #[test]
    fn shrink_rehomes_every_stranded_bucket() {
        let keys: Vec<u64> = (0..2_000).collect();
        let mut router = BucketRouter::new(6, 3);
        let loads = loads_for(&router, &keys);
        router.resize(4, &loads);
        for b in 0..router.buckets() {
            assert!(router.rank_of_bucket(b).0 < 4, "bucket {b} stranded");
        }
        for k in &keys {
            assert!(router.route(k).0 < 4);
        }
    }

    #[test]
    fn resize_levels_loads_to_bucket_granularity() {
        let keys: Vec<u64> = (0..8_000).collect();
        let mut router = BucketRouter::new(3, 11);
        let loads = loads_for(&router, &keys);
        router.resize(8, &loads);
        let mut per_rank = vec![0usize; 8];
        for k in &keys {
            per_rank[router.route(k).0] += 1;
        }
        let max = *per_rank.iter().max().unwrap();
        let min = *per_rank.iter().min().unwrap();
        // Perfect leveling is 1000/rank; the never-overshoot rule leaves
        // each mover short by at most ~one bucket (128 buckets, ~62 keys
        // each), so the residual imbalance is a small bucket multiple.
        assert!(max - min <= 4 * (8_000 / DEFAULT_BUCKETS), "{per_rank:?}");
    }

    #[test]
    fn resize_history_is_reproducible() {
        let keys: Vec<u64> = (0..1_000).collect();
        let build = || {
            let mut r = BucketRouter::new(4, 13);
            let l1 = loads_for(&r, &keys);
            r.resize(6, &l1);
            let l2 = loads_for(&r, &keys);
            r.resize(2, &l2);
            r
        };
        assert_eq!(build(), build());
        assert_eq!(build().epoch(), 2);
    }

    #[test]
    fn restore_round_trips_placement_salt_and_epoch() {
        let keys: Vec<u64> = (0..1_000).collect();
        let mut r = BucketRouter::new(4, 13);
        let loads = loads_for(&r, &keys);
        r.resize(6, &loads);
        let back =
            BucketRouter::restore(r.salt(), r.assignments().to_vec(), r.ranks(), r.epoch());
        assert_eq!(back, r, "restore must reproduce the router verbatim");
        for k in &keys {
            assert_eq!(back.route(k), r.route(k));
        }
        assert_eq!(back.epoch(), 1);
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn restore_rejects_out_of_range_assignment() {
        let _ = BucketRouter::restore(0, vec![0, 5], 2, 0);
    }

    #[test]
    fn same_width_resize_levels_skewed_buckets() {
        // All mass sits on rank 0's 32 buckets (10 keys each): a
        // same-width resize must deal them out 80 keys per rank.
        let mut router = BucketRouter::new(4, 5);
        let loads: Vec<usize> = (0..router.buckets())
            .map(|b| if router.rank_of_bucket(b).0 == 0 { 10 } else { 0 })
            .collect();
        let moves = router.resize(4, &loads);
        assert!(!moves.is_empty());
        let mut per_rank = [0usize; 4];
        for (b, &l) in loads.iter().enumerate() {
            per_rank[router.rank_of_bucket(b).0] += l;
        }
        assert_eq!(per_rank, [80; 4], "{per_rank:?}");
    }
}
