//! `DistHashMap`: stage-anywhere / flush-to-owner key-value shards —
//! the container the paper's results "land in" (§III.D step 6), and the
//! M3R-style stable-ownership map that makes iterative jobs cheap: the
//! same router places the same keys on the same ranks every wave.
//!
//! Usage shape (SPMD, all ranks):
//!
//! 1. `stage(key, value)` wherever the pair is produced — no
//!    communication, any rank may stage any key;
//! 2. `flush(combine)` — COLLECTIVE: every staged pair rides one
//!    `alltoallv` shuffle to `router.owner(key)`, where it is folded
//!    into the owner's local shard with `combine`;
//! 3. `get_local` / `iter_local` read the owned shard (only the owner
//!    sees a key).

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;

use anyhow::Result;

use crate::core::shuffle::shuffle_pairs;
use crate::metrics::PeakTracker;
use crate::mpi::{Communicator, Rank};
use crate::serial::FastSerialize;

use super::router::{KeyRouter, ShardRouter};

/// A hash map sharded by key ownership across the ranks of one
/// communicator. Generic over the [`KeyRouter`] deciding placement:
/// [`ShardRouter`] (the default — stateless, one-shot jobs) or
/// [`crate::dist::BucketRouter`] (epoch-versioned — iterative jobs whose
/// shards must survive elastic resizes).
pub struct DistHashMap<'c, K, V, R = ShardRouter> {
    comm: &'c Communicator,
    router: R,
    staged: Vec<(K, V)>,
    owned: HashMap<K, V>,
    tracker: Arc<PeakTracker>,
}

impl<'c, K, V> DistHashMap<'c, K, V>
where
    K: FastSerialize + Hash + Eq,
    V: FastSerialize,
{
    /// An empty shard whose router spans the communicator (one shard per
    /// rank) under `salt`. Every rank must use the same salt or flushed
    /// keys will land on disagreeing owners.
    pub fn new(comm: &'c Communicator, salt: u64) -> Self {
        Self::with_tracker(comm, salt, PeakTracker::new())
    }

    /// Like [`DistHashMap::new`], charging flush shuffle buffers to a
    /// shared tracker (e.g. the engine's per-job tracker) so container
    /// traffic shows up in job peak-memory accounting.
    pub fn with_tracker(comm: &'c Communicator, salt: u64, tracker: Arc<PeakTracker>) -> Self {
        Self::from_local(comm, ShardRouter::new(comm.size(), salt), HashMap::new(), tracker)
    }
}

impl<'c, K, V, R> DistHashMap<'c, K, V, R>
where
    K: FastSerialize + Hash + Eq,
    V: FastSerialize,
    R: KeyRouter,
{
    /// Wrap an already-owned shard under an explicit router — the way
    /// [`crate::core::IterativeJob`] re-enters its pinned per-rank state
    /// each wave. Every rank must pass an identical router, and every
    /// key in `owned` must route to this rank.
    pub fn from_local(
        comm: &'c Communicator,
        router: R,
        owned: HashMap<K, V>,
        tracker: Arc<PeakTracker>,
    ) -> Self {
        debug_assert!(
            owned.keys().all(|k| router.route(k) == comm.rank()),
            "from_local shard holds keys this rank does not own"
        );
        Self { comm, router, staged: Vec::new(), owned, tracker }
    }

    /// The tracker flush shuffle buffers are charged to.
    pub fn tracker(&self) -> &Arc<PeakTracker> {
        &self.tracker
    }

    pub fn router(&self) -> &R {
        &self.router
    }

    /// The rank that owns `key` after a flush.
    pub fn owner(&self, key: &K) -> Rank {
        self.router.route(key)
    }

    /// Buffer a pair locally — any rank may stage any key.
    pub fn stage(&mut self, key: K, value: V) {
        self.staged.push((key, value));
    }

    /// Pairs staged since the last flush.
    pub fn staged_len(&self) -> usize {
        self.staged.len()
    }

    /// Keys owned by this rank.
    pub fn len_local(&self) -> usize {
        self.owned.len()
    }

    /// Read an owned entry; `None` on every rank but the owner.
    pub fn get_local(&self, key: &K) -> Option<&V> {
        self.owned.get(key)
    }

    pub fn iter_local(&self) -> impl Iterator<Item = (&K, &V)> {
        self.owned.iter()
    }

    /// Dissolve the container, keeping this rank's owned shard.
    pub fn into_local(self) -> HashMap<K, V> {
        self.owned
    }

    /// COLLECTIVE: total owned keys across all ranks.
    pub fn len_global(&self) -> Result<u64> {
        self.comm.allreduce_sum_u64(self.owned.len() as u64)
    }

    /// COLLECTIVE: route every staged pair to its owner and fold it into
    /// the owner's shard. `combine(acc, v)` resolves an arriving value
    /// with the value already owned; first arrival inserts.
    ///
    /// Error semantics match the MPI collectives underneath: a failed
    /// exchange (a peer rank hung up mid-`alltoallv`) poisons the whole
    /// universe, so staged pairs are consumed either way and the map
    /// must not be reused after an `Err`. In-wave rank death aborts the
    /// wave; recovery happens a layer up (`cluster::FaultTracker`
    /// re-runs the wave), never by re-flushing a poisoned container.
    pub fn flush(&mut self, combine: impl Fn(&mut V, V)) -> Result<()> {
        let staged = std::mem::take(&mut self.staged);
        let incoming = shuffle_pairs(self.comm, &self.router, staged, &self.tracker)?;
        for (k, v) in incoming {
            debug_assert_eq!(self.router.route(&k), self.comm.rank(), "shuffle misroute");
            match self.owned.entry(k) {
                Entry::Occupied(mut e) => combine(e.get_mut(), v),
                Entry::Vacant(e) => {
                    e.insert(v);
                }
            }
        }
        Ok(())
    }

    /// COLLECTIVE: [`DistHashMap::flush`] with a **stage-side pre-fold**
    /// — equal-key staged pairs are combined locally before the shuffle
    /// (the eager-reduction trick applied to container traffic), so at
    /// most one value per (rank, key) crosses the wire. `combine` must
    /// therefore be associative and commutative; the owner-side fold per
    /// key still happens in source-rank order, so repeated runs are
    /// deterministic. This is the delta-shuffle the iterative engine
    /// rides: a vertex contributing to a hot key many times pays the
    /// wire once.
    pub fn flush_combining(&mut self, combine: impl Fn(&mut V, V)) -> Result<()> {
        let staged = std::mem::take(&mut self.staged);
        let mut cache: HashMap<K, V> = HashMap::with_capacity(staged.len().min(4096));
        for (k, v) in staged {
            match cache.entry(k) {
                Entry::Occupied(mut e) => combine(e.get_mut(), v),
                Entry::Vacant(e) => {
                    e.insert(v);
                }
            }
        }
        self.staged = cache.into_iter().collect();
        self.flush(combine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testpool::pool_run;

    #[test]
    fn flush_routes_every_staged_key_to_its_owner() {
        const SALT: u64 = 11;
        let shards = pool_run(3, |c| {
            let mut dm: DistHashMap<String, u64> = DistHashMap::new(c, SALT);
            // Every rank stages every key: owners must fold 3 stages each.
            for i in 0..10 {
                dm.stage(format!("k{i}"), 1);
            }
            dm.flush(|acc, v| *acc += v).unwrap();
            assert_eq!(dm.staged_len(), 0, "flush must drain the stage buffer");
            dm.into_local()
        });
        let reference = ShardRouter::new(3, SALT);
        let mut seen = 0;
        for (rank, shard) in shards.iter().enumerate() {
            for (k, v) in shard {
                assert_eq!(reference.owner(k).0, rank, "key {k} on wrong rank");
                assert_eq!(*v, 3, "key {k} missed a staged value");
                seen += 1;
            }
        }
        assert_eq!(seen, 10, "keys lost or duplicated across shards");
    }

    #[test]
    fn non_owners_read_none() {
        let got = pool_run(4, |c| {
            let mut dm: DistHashMap<String, u64> = DistHashMap::new(c, 0);
            dm.stage("shared-key".into(), 1);
            dm.flush(|acc, v| *acc += v).unwrap();
            (dm.get_local(&"shared-key".to_string()).copied(), dm.len_global().unwrap())
        });
        let owners: Vec<u64> = got.iter().filter_map(|(v, _)| *v).collect();
        assert_eq!(owners, vec![4], "exactly one owner folding all 4 stages");
        assert!(got.iter().all(|&(_, global)| global == 1));
    }

    #[test]
    fn flush_combining_matches_flush_and_cuts_wire_pairs() {
        use crate::dist::BucketRouter;
        let got = pool_run(3, |c| {
            // 600 stages over 6 hot keys per rank: the pre-fold should
            // ship at most one pair per (rank, key).
            let combine = |acc: &mut u64, v: u64| *acc += v;
            let tracker = PeakTracker::new();
            let mut raw: DistHashMap<'_, u32, u64, BucketRouter> = DistHashMap::from_local(
                c,
                BucketRouter::new(c.size(), 9),
                HashMap::new(),
                tracker.clone(),
            );
            for i in 0..600u32 {
                raw.stage(i % 6, 1);
            }
            raw.flush(combine).unwrap();
            let raw_bytes = c.sent_bytes();
            let mut folded: DistHashMap<'_, u32, u64, BucketRouter> =
                DistHashMap::from_local(c, BucketRouter::new(c.size(), 9), HashMap::new(), tracker);
            for i in 0..600u32 {
                folded.stage(i % 6, 1);
            }
            folded.flush_combining(combine).unwrap();
            let folded_bytes = c.sent_bytes() - raw_bytes;
            (raw.into_local(), folded.into_local(), raw_bytes, folded_bytes)
        });
        let mut raw_merged: HashMap<u32, u64> = HashMap::new();
        let mut folded_merged: HashMap<u32, u64> = HashMap::new();
        for (raw, folded, raw_bytes, folded_bytes) in got {
            assert!(
                folded_bytes * 4 < raw_bytes,
                "pre-fold must collapse the wire volume ({folded_bytes} vs {raw_bytes})"
            );
            raw_merged.extend(raw);
            folded_merged.extend(folded);
        }
        assert_eq!(raw_merged, folded_merged, "pre-fold may never change the result");
        assert_eq!(raw_merged.len(), 6);
        assert!(raw_merged.values().all(|&v| v == 300), "{raw_merged:?}");
    }

    #[test]
    fn repeated_flushes_accumulate() {
        let got = pool_run(2, |c| {
            let mut dm: DistHashMap<u32, u64> = DistHashMap::new(c, 5);
            for wave in 1..=3u64 {
                for key in 0..4u32 {
                    dm.stage(key, wave);
                }
                dm.flush(|acc, v| *acc += v).unwrap();
            }
            dm.into_local()
        });
        // Each key: 2 ranks x (1 + 2 + 3) = 12, owned exactly once.
        let mut merged: HashMap<u32, u64> = HashMap::new();
        for shard in got {
            for (k, v) in shard {
                assert!(merged.insert(k, v).is_none(), "key {k} on two ranks");
            }
        }
        assert_eq!(merged.len(), 4);
        assert!(merged.values().all(|&v| v == 12), "{merged:?}");
    }
}
