//! Micro-benchmark harness (replaces criterion): warmup, N timed
//! iterations, robust stats, aligned printing. Used by `benches/*.rs`
//! (built with `harness = false`) and `blaze bench-figure`.

use std::time::{Duration, Instant};

/// Summary statistics for one benchmark.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub stddev_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// criterion-ish one-liner.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}  median {:>12}  ±{:>10}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.stddev_ns),
            self.iters
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
/// The closure's return value is black-boxed so work isn't optimized out.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        black_box(f());
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let median = samples[iters / 2];
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: median,
        min_ns: samples[0],
        max_ns: samples[iters - 1],
        stddev_ns: var.sqrt(),
    }
}

/// Time one run of `f` (for expensive end-to-end jobs where modeled time,
/// not host time, is the figure's y-axis).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let out = black_box(f());
    (out, t.elapsed())
}

/// Optimization barrier (std::hint::black_box re-export point so benches
/// don't depend on the unstable-history directly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let r = bench("noop-ish", 2, 25, || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(r.min_ns <= r.median_ns);
        assert!(r.median_ns <= r.max_ns);
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.iters, 25);
    }

    #[test]
    fn line_formats() {
        let r = bench("fmt", 0, 3, || 1 + 1);
        let line = r.line();
        assert!(line.contains("fmt"));
        assert!(line.contains("iters"));
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }
}
