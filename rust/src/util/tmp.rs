//! Self-deleting temp files (replaces the `tempfile` crate) — used by the
//! shuffle's out-of-core spill path.

use std::fs::{File, OpenOptions};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// An open read/write file that unlinks itself on drop.
#[derive(Debug)]
pub struct TempFile {
    file: Option<File>,
    path: PathBuf,
}

impl TempFile {
    pub fn new(prefix: &str) -> Result<Self> {
        let dir = std::env::temp_dir();
        let unique = format!(
            "{prefix}-{}-{}-{}",
            std::process::id(),
            COUNTER.fetch_add(1, Ordering::Relaxed),
            // Wall-clock entropy so parallel test binaries don't collide.
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos())
                .unwrap_or(0),
        );
        let path = dir.join(unique);
        let file = OpenOptions::new()
            .create_new(true)
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("creating temp file {}", path.display()))?;
        Ok(Self { file: Some(file), path })
    }

    pub fn file(&mut self) -> &mut File {
        self.file.as_mut().expect("file present until drop")
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempFile {
    fn drop(&mut self) {
        drop(self.file.take());
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Seek, SeekFrom, Write};

    #[test]
    fn write_read_roundtrip() {
        let mut t = TempFile::new("blaze-test").unwrap();
        t.file().write_all(b"hello spill").unwrap();
        t.file().seek(SeekFrom::Start(0)).unwrap();
        let mut buf = String::new();
        t.file().read_to_string(&mut buf).unwrap();
        assert_eq!(buf, "hello spill");
    }

    #[test]
    fn unlinked_on_drop() {
        let path = {
            let t = TempFile::new("blaze-drop").unwrap();
            assert!(t.path().exists());
            t.path().to_path_buf()
        };
        assert!(!path.exists());
    }

    #[test]
    fn unique_names() {
        let a = TempFile::new("blaze-uniq").unwrap();
        let b = TempFile::new("blaze-uniq").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
