//! Seeded, deterministic hashing (replaces ahash).
//!
//! Every rank must route a key to the same shard, so the hasher state is a
//! pure function of the seed — never of process-random state. Core is an
//! FxHash-style multiply-rotate over 8-byte chunks with a SplitMix64
//! finalizer for avalanche (consecutive integer keys must still spread
//! across shards — see tests).

use std::hash::{BuildHasher, Hasher};

const K: u64 = 0x517C_C1B7_2722_0A95; // fxhash multiplier

/// Streaming hasher with a seed-derived initial state.
#[derive(Debug, Clone)]
pub struct StableHasher {
    state: u64,
}

impl StableHasher {
    #[inline]
    pub fn with_seed(seed: u64) -> Self {
        Self { state: seed ^ 0xcbf2_9ce4_8422_2325 }
    }

    #[inline]
    fn mix(&mut self, word: u64) {
        self.state = (self.state.rotate_left(26) ^ word).wrapping_mul(K);
    }
}

impl Hasher for StableHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.mix(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            // Fold the length in so "ab" != "ab\0".
            self.mix(u64::from_le_bytes(tail) ^ ((rem.len() as u64) << 56));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.mix(v);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.mix(v as u64);
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.mix(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.mix(v as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        // SplitMix64 finalizer: full avalanche.
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// `BuildHasher` whose hashers depend only on the stored seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeededState {
    seed: u64,
}

impl SeededState {
    pub const fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// Convenience one-shot hash.
    pub fn hash_one<T: std::hash::Hash>(&self, value: &T) -> u64 {
        let mut h = self.build_hasher();
        value.hash(&mut h);
        h.finish()
    }
}

impl BuildHasher for SeededState {
    type Hasher = StableHasher;

    #[inline]
    fn build_hasher(&self) -> StableHasher {
        StableHasher::with_seed(self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_across_instances() {
        let a = SeededState::new(9);
        let b = SeededState::new(9);
        for s in ["alpha", "beta", "gamma", ""] {
            assert_eq!(a.hash_one(&s), b.hash_one(&s));
        }
    }

    #[test]
    fn seed_changes_hash() {
        let a = SeededState::new(1);
        let b = SeededState::new(2);
        let differing = (0u64..100).filter(|i| a.hash_one(i) != b.hash_one(i)).count();
        assert!(differing > 90);
    }

    #[test]
    fn sequential_ints_spread_over_buckets() {
        let s = SeededState::new(0);
        let n = 16u64;
        let mut hist = vec![0usize; n as usize];
        for i in 0u64..16_000 {
            hist[(s.hash_one(&i) % n) as usize] += 1;
        }
        for (b, h) in hist.iter().enumerate() {
            assert!((700..1300).contains(h), "bucket {b}: {h} ({hist:?})");
        }
    }

    #[test]
    fn prefix_strings_differ() {
        let s = SeededState::new(0);
        assert_ne!(s.hash_one(&"ab"), s.hash_one(&"ab\0"));
        assert_ne!(s.hash_one(&"a"), s.hash_one(&"aa"));
    }

    #[test]
    fn usable_in_std_hashmap() {
        let mut m: std::collections::HashMap<String, u32, SeededState> =
            std::collections::HashMap::with_hasher(SeededState::new(4));
        m.insert("k".into(), 1);
        assert_eq!(m["k"], 1);
    }
}
