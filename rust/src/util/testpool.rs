//! Shared [`RankPool`] builders for tests and harnesses.
//!
//! [`fleet`] is the one way the integration suites (transport, trace,
//! scheduler) and the `serve-bench` harness assemble a pool with an
//! explicit topology/algorithm/transport — previously each test file
//! carried its own copy of the `Universe::new(..).with_*` chain.
//!
//! [`pool_run`] (test builds only) routes module unit tests through a
//! warm per-thread pool instead of a fresh `Universe::local(n)` per
//! test, so the unit-test suite itself is a many-jobs-on-one-pool
//! workout of the pooled executor: every `core::`/`dist::` test is
//! another job on reused threads, with the prepare phase isolating them
//! exactly like fresh universes (same results, reset clocks, realigned
//! collective tags).
//!
//! One pool per *test thread* (not one global pool): a process-wide
//! pool would strip libtest's test-level parallelism and let one wedged
//! job block every other test. Each libtest thread lazily builds its
//! own pool and reuses it for every test it runs, which keeps both the
//! reuse workout and the parallelism.

use std::path::Path;

use crate::cluster::NetworkModel;
#[cfg(test)]
use crate::mpi::Communicator;
use crate::mpi::{CollectiveAlgo, RankPool, Topology, TransportKind, Universe};

/// Build a warm pool over a `nodes x slots` block topology with a free
/// network model — the shared-fleet shape of the integration suites.
/// `worker_bin` is required by [`TransportKind::Tcp`] fleets launched
/// from test binaries (pass `env!("CARGO_BIN_EXE_blaze")`); `None`
/// lets the launcher default to the current executable, which is what
/// the `blaze` CLI itself wants.
pub fn fleet(
    nodes: usize,
    slots: usize,
    algo: CollectiveAlgo,
    transport: TransportKind,
    worker_bin: Option<&Path>,
) -> RankPool {
    let mut universe = Universe::new(Topology::block(nodes, slots), NetworkModel::free())
        .with_collective_algo(algo)
        .with_transport(transport);
    if let Some(bin) = worker_bin {
        universe = universe.with_worker_binary(bin);
    }
    RankPool::new(universe)
}

/// Width of each per-thread pool; unit tests use at most 5 ranks today,
/// and narrower jobs run on a prefix of the warm threads.
#[cfg(test)]
pub(crate) const POOL_RANKS: usize = 8;

/// Pooled drop-in for `run_ranks(Universe::local(n), f)` in unit tests.
#[cfg(test)]
pub(crate) fn pool_run<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&Communicator) -> T + Sync,
{
    thread_local! {
        static POOL: RankPool = RankPool::local(POOL_RANKS);
    }
    assert!(n <= POOL_RANKS, "test wants {n} ranks, per-thread pool has {POOL_RANKS}");
    POOL.with(|pool| pool.run_on(n, f))
}
