//! Shared [`RankPool`]s for unit tests.
//!
//! Module unit tests used to build a fresh `Universe::local(n)` (and one
//! OS thread per rank) per test via `run_ranks`. [`pool_run`] routes them
//! through a warm pool instead, so the unit-test suite itself is a
//! many-jobs-on-one-pool workout of the pooled executor: every
//! `core::`/`dist::` test is another job on reused threads, with the
//! prepare phase isolating them exactly like fresh universes (same
//! results, reset clocks, realigned collective tags).
//!
//! One pool per *test thread* (not one global pool): jobs on a pool
//! serialize, so a process-wide pool would strip libtest's test-level
//! parallelism and let one wedged job block every other test. Each
//! libtest thread lazily builds its own pool and reuses it for every
//! test it runs, which keeps both the reuse workout and the parallelism.

use crate::mpi::{Communicator, RankPool};

/// Width of each per-thread pool; unit tests use at most 5 ranks today,
/// and narrower jobs run on a prefix of the warm threads.
pub(crate) const POOL_RANKS: usize = 8;

/// Pooled drop-in for `run_ranks(Universe::local(n), f)` in unit tests.
pub(crate) fn pool_run<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&Communicator) -> T + Sync,
{
    thread_local! {
        static POOL: RankPool = RankPool::local(POOL_RANKS);
    }
    assert!(n <= POOL_RANKS, "test wants {n} ranks, per-thread pool has {POOL_RANKS}");
    POOL.with(|pool| pool.run_on(n, f))
}
