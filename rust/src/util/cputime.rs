//! Per-thread CPU time — the simulator's compute meter.
//!
//! The host may have fewer cores than simulated ranks (CI runs this on a
//! single core), so *wall* time on a rank thread includes time spent
//! descheduled while sibling ranks run. `CLOCK_THREAD_CPUTIME_ID` charges
//! each rank exactly the cycles it consumed, which is what the virtual
//! clock wants: N ranks splitting a job N ways each accrue ~1/N the
//! compute, independent of host core count.

/// Nanoseconds of CPU time consumed by the calling thread.
pub fn thread_cpu_time_ns() -> u64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: ts is a valid out-pointer; the clock id is a constant.
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    debug_assert_eq!(rc, 0, "clock_gettime failed");
    ts.tv_sec as u64 * 1_000_000_000 + ts.tv_nsec as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_and_advances_under_load() {
        let a = thread_cpu_time_ns();
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_add(std::hint::black_box(i));
        }
        std::hint::black_box(acc);
        let b = thread_cpu_time_ns();
        assert!(b > a, "{a} -> {b}");
    }

    #[test]
    fn sleep_consumes_almost_no_cpu_time() {
        let a = thread_cpu_time_ns();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let b = thread_cpu_time_ns();
        assert!(b - a < 10_000_000, "sleep charged {} ns of CPU", b - a);
    }
}
