//! Mini property-test runner (replaces proptest): seeded generators +
//! a `for_all` driver that reports the failing seed for reproduction.
//!
//! No shrinking — cases are generated small-biased instead (sizes drawn
//! log-uniform), which keeps counterexamples readable in practice.

use super::rng::Rng;

/// Number of cases per property (override with env `BLAZE_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("BLAZE_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256)
}

/// Run `prop` on `cases` generated inputs; panics with the failing seed.
pub fn for_all<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    let cases = default_cases();
    for case in 0..cases as u64 {
        let mut rng = Rng::with_stream(0xB1A2_E000 ^ case, case);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed stream {case}):\n{input:#?}"
            );
        }
    }
}

/// Log-uniform size in [0, max] — biases toward small cases.
pub fn size(rng: &mut Rng, max: usize) -> usize {
    if max == 0 {
        return 0;
    }
    let bits = 64 - (max as u64).leading_zeros() as u64;
    let b = rng.below(bits + 1);
    let cap = ((1u64 << b).min(max as u64)).max(1);
    rng.below(cap + 1) as usize
}

/// Random ASCII-ish string (identifier alphabet + some unicode).
pub fn string(rng: &mut Rng, max_len: usize) -> String {
    const ALPHA: &[char] =
        &['a', 'b', 'c', 'x', 'y', 'z', '0', '7', '_', ' ', 'é', '雪', '\u{1F600}'];
    let len = size(rng, max_len);
    (0..len).map(|_| ALPHA[rng.below(ALPHA.len() as u64) as usize]).collect()
}

/// Vec of T.
pub fn vec_of<T>(rng: &mut Rng, max_len: usize, item: impl Fn(&mut Rng) -> T) -> Vec<T> {
    let len = size(rng, max_len);
    (0..len).map(|_| item(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        for_all("reverse-twice", |r| vec_of(r, 50, |r| r.next_u32()), |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-false\" failed")]
    fn failing_property_reports_seed() {
        for_all("always-false", |r| r.next_u32(), |_| false);
    }

    #[test]
    fn sizes_cover_small_and_large() {
        let mut rng = Rng::new(1);
        let sizes: Vec<usize> = (0..500).map(|_| size(&mut rng, 1000)).collect();
        assert!(sizes.iter().any(|&s| s == 0));
        assert!(sizes.iter().any(|&s| s > 100));
        assert!(sizes.iter().all(|&s| s <= 1000));
    }
}
