//! Mini property-test runner (replaces proptest): seeded generators +
//! a `for_all` driver that reports the failing seed for reproduction.
//!
//! `for_all` does no shrinking — cases are generated small-biased
//! instead (sizes drawn log-uniform), which keeps counterexamples
//! readable in practice. [`for_all_shrink`] adds greedy shrinking for
//! properties whose inputs have a natural candidate-set reducer.

use super::rng::Rng;

/// Number of cases per property (override with env `BLAZE_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("BLAZE_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256)
}

/// Run `prop` on `cases` generated inputs; panics with the failing seed.
pub fn for_all<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    let cases = default_cases();
    for case in 0..cases as u64 {
        let mut rng = Rng::with_stream(0xB1A2_E000 ^ case, case);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed stream {case}):\n{input:#?}"
            );
        }
    }
}

/// [`for_all`] plus greedy shrink-on-failure: when a case fails,
/// `shrink(&input)` proposes smaller candidates; the first candidate
/// that *still fails* replaces the input, repeatedly, until no candidate
/// fails (a local minimum) or the step bound runs out. Panics with the
/// minimized counterexample and the originating seed stream.
pub fn for_all_shrink<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> bool,
) {
    let cases = default_cases();
    for case in 0..cases as u64 {
        let mut rng = Rng::with_stream(0xB1A2_E000 ^ case, case);
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        let mut minimal = input;
        let mut steps = 0;
        'outer: while steps < 200 {
            for candidate in shrink(&minimal) {
                steps += 1;
                if !prop(&candidate) {
                    minimal = candidate;
                    continue 'outer;
                }
                if steps >= 200 {
                    break;
                }
            }
            break;
        }
        panic!(
            "property {name:?} failed on case {case} (seed stream {case}); \
             shrunk over {steps} candidate(s) to:\n{minimal:#?}"
        );
    }
}

/// Log-uniform size in [0, max] — biases toward small cases.
pub fn size(rng: &mut Rng, max: usize) -> usize {
    if max == 0 {
        return 0;
    }
    let bits = 64 - (max as u64).leading_zeros() as u64;
    let b = rng.below(bits + 1);
    let cap = ((1u64 << b).min(max as u64)).max(1);
    rng.below(cap + 1) as usize
}

/// Random ASCII-ish string (identifier alphabet + some unicode).
pub fn string(rng: &mut Rng, max_len: usize) -> String {
    const ALPHA: &[char] =
        &['a', 'b', 'c', 'x', 'y', 'z', '0', '7', '_', ' ', 'é', '雪', '\u{1F600}'];
    let len = size(rng, max_len);
    (0..len).map(|_| ALPHA[rng.below(ALPHA.len() as u64) as usize]).collect()
}

/// Vec of T.
pub fn vec_of<T>(rng: &mut Rng, max_len: usize, item: impl Fn(&mut Rng) -> T) -> Vec<T> {
    let len = size(rng, max_len);
    (0..len).map(|_| item(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        for_all("reverse-twice", |r| vec_of(r, 50, |r| r.next_u32()), |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-false\" failed")]
    fn failing_property_reports_seed() {
        for_all("always-false", |r| r.next_u32(), |_| false);
    }

    #[test]
    fn shrinking_reaches_the_minimal_counterexample() {
        // Property: "no vec contains a 7". Shrinker drops one element at
        // a time; the minimum failing case is exactly [7].
        let err = std::panic::catch_unwind(|| {
            for_all_shrink(
                "no-sevens",
                |r| {
                    let mut v = vec_of(r, 20, |r| r.below(6) as u32);
                    v.push(7); // every case fails
                    r.shuffle(&mut v);
                    v
                },
                |v: &Vec<u32>| {
                    (0..v.len())
                        .map(|i| {
                            let mut w = v.clone();
                            w.remove(i);
                            w
                        })
                        .collect()
                },
                |v| !v.contains(&7),
            );
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().expect("panic payload");
        assert!(msg.contains("no-sevens"), "{msg}");
        assert!(msg.contains("[\n    7,\n]"), "not shrunk to [7]: {msg}");
    }

    #[test]
    fn shrinking_passes_through_when_property_holds() {
        for_all_shrink(
            "sum-commutes",
            |r| (r.below(1000), r.below(1000)),
            |_| Vec::new(),
            |&(a, b)| a + b == b + a,
        );
    }

    #[test]
    fn sizes_cover_small_and_large() {
        let mut rng = Rng::new(1);
        let sizes: Vec<usize> = (0..500).map(|_| size(&mut rng, 1000)).collect();
        assert!(sizes.iter().any(|&s| s == 0));
        assert!(sizes.iter().any(|&s| s > 100));
        assert!(sizes.iter().all(|&s| s <= 1000));
    }
}
