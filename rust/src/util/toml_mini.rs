//! TOML-subset parser for cluster/job config files.
//!
//! Supports what `blaze run --cluster cluster.toml` needs: top-level and
//! `[section]` tables, `key = value` with strings, integers, floats,
//! booleans, and `#` comments. No arrays-of-tables, no multi-line strings
//! — config files here are flat.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section -> key -> value`; top-level keys live under
/// the empty-string section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let ctx = || format!("line {}", lineno + 1);
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').with_context(ctx)?.trim();
                ensure!(!name.is_empty(), "empty section header at line {}", lineno + 1);
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            ensure!(!key.is_empty(), "empty key at line {}", lineno + 1);
            let value = parse_value(value.trim())
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            let prev = doc
                .sections
                .entry(section.clone())
                .or_default()
                .insert(key.to_string(), value);
            ensure!(prev.is_none(), "duplicate key {key:?} at line {}", lineno + 1);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// Top-level key.
    pub fn top(&self, key: &str) -> Option<&TomlValue> {
        self.get("", key)
    }

    pub fn sections(&self) -> impl Iterator<Item = (&str, &BTreeMap<String, TomlValue>)> {
        self.sections.iter().map(|(k, v)| (k.as_str(), v))
    }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue> {
    ensure!(!text.is_empty(), "empty value");
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest.strip_suffix('"').context("unterminated string")?;
        ensure!(!inner.contains('"'), "embedded quote in string");
        return Ok(TomlValue::Str(inner.replace("\\n", "\n").replace("\\t", "\t")));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = text.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("unparseable value {text:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_cluster_config_shape() {
        let doc = TomlDoc::parse(
            r#"
# paper §IV.B testbed
deployment = "vm"
nodes = 4
slots-per-node = 2
seed = 42

[limits]
mem-fraction = 0.6
spill = true
"#,
        )
        .unwrap();
        assert_eq!(doc.top("deployment").unwrap().as_str(), Some("vm"));
        assert_eq!(doc.top("nodes").unwrap().as_int(), Some(4));
        assert_eq!(doc.get("limits", "mem-fraction").unwrap().as_float(), Some(0.6));
        assert_eq!(doc.get("limits", "spill").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn comments_inside_strings_survive() {
        let doc = TomlDoc::parse("name = \"a # b\" # real comment\n").unwrap();
        assert_eq!(doc.top("name").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn int_vs_float() {
        let doc = TomlDoc::parse("a = 3\nb = 3.0\nc = 1_000\n").unwrap();
        assert_eq!(doc.top("a").unwrap().as_int(), Some(3));
        assert_eq!(doc.top("b").unwrap().as_int(), None);
        assert_eq!(doc.top("b").unwrap().as_float(), Some(3.0));
        assert_eq!(doc.top("c").unwrap().as_int(), Some(1000));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("keyonly\n").is_err());
        assert!(TomlDoc::parse("a = \n").is_err());
        assert!(TomlDoc::parse("a = 1\na = 2\n").is_err());
        assert!(TomlDoc::parse("a = \"x\n").is_err());
    }

    #[test]
    fn empty_doc_ok() {
        let doc = TomlDoc::parse("\n# nothing\n").unwrap();
        assert!(doc.top("x").is_none());
    }
}
