//! Minimal JSON: a value type, a recursive-descent parser and a writer.
//!
//! Used for `artifacts/manifest.json` (written by python) and the bench
//! reports. Supports the full JSON grammar except `\uXXXX` surrogate
//! pairs beyond the BMP (the manifest and reports are ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, ensure, Result};

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    pub fn obj(entries: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // -- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing ergonomics.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // -- writer ----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out.push('\n');
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => write_seq(out, indent, '[', ']', items.iter(), |out, v, ind| {
                v.write(out, ind)
            }),
            Json::Obj(map) => write_seq(out, indent, '{', '}', map.iter(), |out, (k, v), ind| {
                write_escaped(out, k);
                out.push_str(": ");
                v.write(out, ind);
            }),
        }
    }
}

fn write_seq<T>(
    out: &mut String,
    indent: Option<usize>,
    open: char,
    close: char,
    items: impl ExactSizeIterator<Item = T>,
    mut write_item: impl FnMut(&mut String, T, Option<usize>),
) {
    if items.len() == 0 {
        out.push(open);
        out.push(close);
        return;
    }
    out.push(open);
    let inner = indent.map(|i| i + 1);
    let mut first = true;
    for item in items {
        if !first {
            out.push(',');
        }
        first = false;
        match inner {
            Some(i) => {
                out.push('\n');
                out.push_str(&"  ".repeat(i));
            }
            None => {}
        }
        write_item(out, item, inner);
    }
    if let Some(i) = indent {
        out.push('\n');
        out.push_str(&"  ".repeat(i));
    }
    out.push(close);
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        ensure!(
            self.peek() == Some(c),
            "expected {:?} at byte {}, found {:?}",
            c as char,
            self.pos,
            self.peek().map(|b| b as char)
        );
        self.pos += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        ensure!(
            self.bytes[self.pos..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += s.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.skip_ws();
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        other => bail!("expected , or ] at byte {}, got {other:?}", self.pos),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    self.skip_ws();
                    let val = self.value()?;
                    map.insert(key, val);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(map));
                        }
                        other => bail!("expected , or }} at byte {}, got {other:?}", self.pos),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {other:?} at byte {}", self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| anyhow!("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| anyhow!("dangling escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            ensure!(self.pos + 4 <= self.bytes.len(), "short \\u escape");
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u{hex} (surrogates unsupported)"))?,
                            );
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // Re-consume as UTF-8: back up and take the full char.
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj([
            ("name", Json::str("kmeans_step_d8")),
            ("inputs", Json::arr([Json::obj([("shape", Json::arr([Json::num(4096.0), Json::num(8.0)]))])])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let text = v.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v);
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
    }

    #[test]
    fn parses_python_style_manifest() {
        let text = r#"{
  "format": "hlo-text",
  "artifacts": [
    {"name": "pi_count", "inputs": [{"shape": [8192, 2], "dtype": "float32"}]}
  ]
}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.req("format").unwrap().as_str(), Some("hlo-text"));
        let arts = v.req("artifacts").unwrap().as_arr().unwrap();
        let shape: Vec<usize> = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![8192, 2]);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::str("line\nquote\" tab\t back\\ unicode\u{1F600}");
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn numbers() {
        for (text, val) in [("0", 0.0), ("-12", -12.0), ("3.5", 3.5), ("1e3", 1000.0), ("-2.5e-2", -0.025)] {
            assert_eq!(Json::parse(text).unwrap(), Json::Num(val), "{text}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"\\q\""] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn u64_conversion_guards() {
        assert_eq!(Json::Num(4096.0).as_u64(), Some(4096));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }
}
