//! Deterministic RNG: SplitMix64 seeding + xoshiro256++ stream.
//!
//! All synthetic data in benches/figures comes through here, so every
//! figure is bit-reproducible from the cluster seed. Normal deviates via
//! Box-Muller (the K-means cluster blobs of Fig 8/9).

/// SplitMix64 step — used to expand a single u64 seed into stream state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna) — fast, solid 64-bit generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller deviate.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed deterministically; distinct `stream` values give independent
    /// sequences from the same seed (rank-private generators).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid; splitmix of anything is ~never zero,
        // but belt and braces:
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` — Lemire's widening-multiply method with the
    /// exact rejection band, so the draw is unbiased.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut low = m as u64;
        if low < n {
            let threshold = n.wrapping_neg() % n;
            while low < threshold {
                m = (self.next_u64() as u128) * (n as u128);
                low = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal deviate (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights (Zipf-ish key draws in
    /// the wordcount generator).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Rng::with_stream(7, 0);
        let mut b = Rng::with_stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, sorted, "shuffle left identity (astronomically unlikely)");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
