//! In-tree substrate utilities.
//!
//! The build depends only on `anyhow` and `libc` (plus the optional,
//! feature-gated `xla` PJRT bindings), so everything a framework usually
//! pulls from crates.io is
//! implemented here from scratch: deterministic RNG, seeded hashing, a
//! JSON value type + parser, a TOML-subset config parser, self-deleting
//! temp files, a micro-benchmark harness, and a property-test runner.

pub mod bench;
pub mod cputime;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod testpool;
pub mod tmp;
pub mod toml_mini;

pub use bench::{bench, BenchResult};
pub use hash::{SeededState, StableHasher};
pub use json::Json;
pub use rng::Rng;
pub use tmp::TempFile;
pub use toml_mini::TomlDoc;
