//! Structured tracing: per-rank span timelines with cross-process
//! causality, merged per job and exportable as Chrome trace-event JSON
//! (viewable in Perfetto / `chrome://tracing`).
//!
//! ## Model
//!
//! A **span** is one timed region of one rank's execution — a map
//! phase, a collective, a spill, an iterative wave sub-phase — stamped
//! with the rank's **virtual clock** (the same Lamport-with-costs time
//! every figure is plotted in), the job epoch, a byte count, and a
//! [`SpanKind`] from the typed taxonomy below. Point-like happenings
//! (one frame sent, a kill armed, a checkpoint written) are **instant**
//! spans with `start_ns == end_ns`.
//!
//! Recording is per-thread and lock-free: each rank thread appends into
//! a thread-local buffer ([`job_start`] resets it at dispatch,
//! [`take`] harvests it with the job's results), so a traced job takes
//! no locks on the hot path and an untraced one pays a single relaxed
//! atomic load per potential span ([`enabled`]).
//!
//! ## Causality across processes
//!
//! Every wire frame carries a span id (`Message::span`): [`on_send`]
//! allocates the id and records a `Send` instant, the receiver records
//! a `Recv` instant whose `link` is that id, and a TCP worker process
//! relaying the frame records a `Relay` instant with the same `link`.
//! Merging the driver buffers with the worker span files
//! ([`collect_worker_spans`]) therefore stitches one causal timeline
//! across real process boundaries; the Chrome export turns each
//! send→recv pair into a flow arrow.
//!
//! ## Zero interference
//!
//! Tracing never touches the virtual clock protocol: span ids ride the
//! wire *outside* the modeled payload (injection/propagation costs are
//! functions of `payload.len()` only), so results, clocks and traffic
//! are byte-identical with tracing on or off — pinned by
//! `tests/integration_trace.rs`.
//!
//! ## Nesting invariant
//!
//! Spans opened via [`span`] close in LIFO order (RAII guards), and
//! every event records open/close sequence numbers; per rank the
//! `[seq_open, seq_close]` intervals form a laminar family (any two are
//! nested or disjoint). The property test asserts this from the data.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::metrics::Histogram;
use crate::util::Json;

/// Rank value used for events recorded on the driver thread (engine
/// merges, checkpoint writes, fault bookkeeping).
pub const DRIVER_RANK: usize = usize::MAX;

/// The typed event taxonomy. Every span in a [`JobTrace`] is one of
/// these; `category` groups them by subsystem for the Chrome export.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    // core: engine phases
    Job,
    Map,
    Combine,
    Shuffle,
    ShuffleRound,
    Reduce,
    /// One dataflow plan stage (see `core::dataflow`): narrow chains
    /// fused into the stage's single pass, bytes = the stage's shuffle
    /// traffic (0 for co-partitioned stages).
    Stage,
    // core: iterative waves
    Wave,
    Contribute,
    Flush,
    Update,
    Migrate,
    // store
    Spill,
    Merge,
    Checkpoint,
    Recover,
    // mpi
    Send,
    Recv,
    Relay,
    Barrier,
    Bcast,
    Gather,
    Allgather,
    Alltoallv,
    Allreduce,
    Exscan,
    // cluster: faults
    Kill,
    Replace,
    Speculate,
}

impl SpanKind {
    pub const ALL: [SpanKind; 29] = [
        SpanKind::Job,
        SpanKind::Map,
        SpanKind::Combine,
        SpanKind::Shuffle,
        SpanKind::ShuffleRound,
        SpanKind::Reduce,
        SpanKind::Stage,
        SpanKind::Wave,
        SpanKind::Contribute,
        SpanKind::Flush,
        SpanKind::Update,
        SpanKind::Migrate,
        SpanKind::Spill,
        SpanKind::Merge,
        SpanKind::Checkpoint,
        SpanKind::Recover,
        SpanKind::Send,
        SpanKind::Recv,
        SpanKind::Relay,
        SpanKind::Barrier,
        SpanKind::Bcast,
        SpanKind::Gather,
        SpanKind::Allgather,
        SpanKind::Alltoallv,
        SpanKind::Allreduce,
        SpanKind::Exscan,
        SpanKind::Kill,
        SpanKind::Replace,
        SpanKind::Speculate,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Job => "job",
            SpanKind::Map => "map",
            SpanKind::Combine => "combine",
            SpanKind::Shuffle => "shuffle",
            SpanKind::ShuffleRound => "shuffle_round",
            SpanKind::Reduce => "reduce",
            SpanKind::Stage => "stage",
            SpanKind::Wave => "wave",
            SpanKind::Contribute => "contribute",
            SpanKind::Flush => "flush",
            SpanKind::Update => "update",
            SpanKind::Migrate => "migrate",
            SpanKind::Spill => "spill",
            SpanKind::Merge => "merge",
            SpanKind::Checkpoint => "checkpoint",
            SpanKind::Recover => "recover",
            SpanKind::Send => "send",
            SpanKind::Recv => "recv",
            SpanKind::Relay => "relay",
            SpanKind::Barrier => "barrier",
            SpanKind::Bcast => "bcast",
            SpanKind::Gather => "gather",
            SpanKind::Allgather => "allgather",
            SpanKind::Alltoallv => "alltoallv",
            SpanKind::Allreduce => "allreduce",
            SpanKind::Exscan => "exscan",
            SpanKind::Kill => "kill",
            SpanKind::Replace => "replace",
            SpanKind::Speculate => "speculate",
        }
    }

    /// Subsystem the kind belongs to (the Chrome `cat` field).
    pub fn category(self) -> &'static str {
        match self {
            SpanKind::Job
            | SpanKind::Map
            | SpanKind::Combine
            | SpanKind::Shuffle
            | SpanKind::ShuffleRound
            | SpanKind::Reduce
            | SpanKind::Stage
            | SpanKind::Wave
            | SpanKind::Contribute
            | SpanKind::Flush
            | SpanKind::Update
            | SpanKind::Migrate => "core",
            SpanKind::Spill | SpanKind::Merge | SpanKind::Checkpoint | SpanKind::Recover => {
                "store"
            }
            SpanKind::Send
            | SpanKind::Recv
            | SpanKind::Relay
            | SpanKind::Barrier
            | SpanKind::Bcast
            | SpanKind::Gather
            | SpanKind::Allgather
            | SpanKind::Alltoallv
            | SpanKind::Allreduce
            | SpanKind::Exscan => "mpi",
            SpanKind::Kill | SpanKind::Replace | SpanKind::Speculate => "cluster",
        }
    }

    pub fn parse(s: &str) -> Option<SpanKind> {
        SpanKind::ALL.into_iter().find(|k| k.as_str() == s)
    }
}

impl std::fmt::Display for SpanKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One recorded span. Timestamps are virtual-clock nanoseconds of the
/// recording rank; `seq_open`/`seq_close` are the rank-local event
/// sequence numbers the nesting invariant is stated over.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    pub kind: SpanKind,
    /// Rank that recorded the span ([`DRIVER_RANK`] for driver-side).
    pub rank: usize,
    /// Process lane: 0 = the driver process, `rank + 1` = that rank's
    /// spawned TCP worker process.
    pub proc_id: u32,
    /// Job epoch the span belongs to.
    pub epoch: u64,
    /// Message tag for wire-level spans, 0 otherwise.
    pub tag: u64,
    pub start_ns: u64,
    pub end_ns: u64,
    pub bytes: u64,
    /// Span id riding the wire (0 = none). Unique per process.
    pub id: u64,
    /// Id of the causally-preceding span (0 = none).
    pub link: u64,
    pub seq_open: u64,
    pub seq_close: u64,
}

impl SpanEvent {
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    fn rank_json(&self) -> f64 {
        if self.rank == DRIVER_RANK {
            -1.0
        } else {
            self.rank as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("kind", Json::str(self.kind.as_str())),
            ("rank", Json::num(self.rank_json())),
            ("proc", Json::num(self.proc_id as f64)),
            ("epoch", Json::num(self.epoch as f64)),
            ("tag", Json::num(self.tag as f64)),
            ("start_ns", Json::num(self.start_ns as f64)),
            ("end_ns", Json::num(self.end_ns as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            ("id", Json::num(self.id as f64)),
            ("link", Json::num(self.link as f64)),
            ("seq_open", Json::num(self.seq_open as f64)),
            ("seq_close", Json::num(self.seq_close as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SpanEvent> {
        let kind_s = j.req("kind")?.as_str().context("span kind must be a string")?;
        let kind = SpanKind::parse(kind_s).ok_or_else(|| anyhow!("unknown span kind {kind_s}"))?;
        let num = |key: &str| -> Result<u64> {
            Ok(j.req(key)?.as_f64().with_context(|| format!("span {key} must be a number"))?
                as u64)
        };
        let rank_raw = j.req("rank")?.as_f64().context("span rank must be a number")?;
        let rank = if rank_raw < 0.0 { DRIVER_RANK } else { rank_raw as usize };
        Ok(SpanEvent {
            kind,
            rank,
            proc_id: num("proc")? as u32,
            epoch: num("epoch")?,
            tag: num("tag")?,
            start_ns: num("start_ns")?,
            end_ns: num("end_ns")?,
            bytes: num("bytes")?,
            id: num("id")?,
            link: num("link")?,
            seq_open: num("seq_open")?,
            seq_close: num("seq_close")?,
        })
    }
}

// ---------------------------------------------------------------------
// Recording
// ---------------------------------------------------------------------

/// Count of live [`enable_scope`] guards; tracing records while > 0.
/// A count (not a boolean) so concurrently traced jobs in one process
/// compose: the first scope to end can never switch recording off under
/// a scope that is still running.
static SCOPES: AtomicU64 = AtomicU64::new(0);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);

/// Is tracing currently recording? One relaxed load — this is the whole
/// cost of a potential span when tracing is off.
#[inline]
pub fn enabled() -> bool {
    SCOPES.load(Ordering::Relaxed) > 0
}

/// Coarse process-wide switch: sets the scope count to 1/0 outright.
/// For processes with one recording lifetime (the TCP worker at
/// startup, tests) — in-process callers should prefer [`enable_scope`],
/// which nests by counting.
pub fn set_enabled(on: bool) {
    SCOPES.store(u64::from(on), Ordering::Relaxed);
}

/// RAII enable: holds tracing on for the guard's lifetime (scopes
/// count, so overlapping guards compose). `enable_scope(false)` is a
/// disarmed no-op guard — an untraced job never turns recording off
/// under a concurrently-traced one.
pub fn enable_scope(on: bool) -> EnableGuard {
    if !on {
        return EnableGuard { armed: false };
    }
    SCOPES.fetch_add(1, Ordering::Relaxed);
    EnableGuard { armed: true }
}

pub struct EnableGuard {
    armed: bool,
}

impl Drop for EnableGuard {
    fn drop(&mut self) {
        if self.armed {
            // saturating_sub: a coarse set_enabled(false) may have
            // zeroed the count while this scope was live.
            let _ = SCOPES.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
        }
    }
}

struct Sink {
    events: Vec<SpanEvent>,
    open: Vec<usize>,
    seq: u64,
    rank: usize,
    proc_id: u32,
    epoch: u64,
    vclock: u64,
}

impl Sink {
    const fn new() -> Self {
        Sink {
            events: Vec::new(),
            open: Vec::new(),
            seq: 0,
            rank: DRIVER_RANK,
            proc_id: 0,
            epoch: 0,
            vclock: 0,
        }
    }
}

thread_local! {
    static SINK: RefCell<Sink> = const { RefCell::new(Sink::new()) };
}

/// Reset this thread's buffer for a new job: clears any stale events
/// and binds the rank / process lane / epoch every subsequent span is
/// stamped with. Called by the pool at dispatch (rank threads), the
/// engine at `execute` (driver thread), and the TCP worker at startup.
pub fn job_start(rank: usize, proc_id: u32, epoch: u64) {
    SINK.with(|s| {
        let mut s = s.borrow_mut();
        s.events.clear();
        s.open.clear();
        s.seq = 0;
        s.rank = rank;
        s.proc_id = proc_id;
        s.epoch = epoch;
        s.vclock = 0;
    });
}

/// Harvest (and clear) this thread's recorded events.
pub fn take() -> Vec<SpanEvent> {
    SINK.with(|s| {
        let mut s = s.borrow_mut();
        s.open.clear();
        std::mem::take(&mut s.events)
    })
}

/// Mirror of the recording rank's virtual clock; the [`Communicator`]
/// updates it at every clock mutation while tracing is on, so span
/// timestamps and store-layer events share the modeled timeline.
///
/// [`Communicator`]: crate::mpi::Communicator
#[inline]
pub fn set_vclock(ns: u64) {
    if !enabled() {
        return;
    }
    SINK.with(|s| s.borrow_mut().vclock = ns);
}

/// Current virtual-clock mirror for this thread.
pub fn vclock() -> u64 {
    SINK.with(|s| s.borrow().vclock)
}

/// Open a span; it closes (stamping the end clock) when the guard
/// drops. Returns a disarmed no-op guard when tracing is off.
#[must_use = "the span closes when this guard drops"]
pub fn span(kind: SpanKind) -> SpanGuard {
    if !enabled() {
        return SpanGuard { idx: usize::MAX };
    }
    let idx = SINK.with(|s| {
        let mut s = s.borrow_mut();
        let seq = s.seq;
        s.seq += 1;
        let ev = SpanEvent {
            kind,
            rank: s.rank,
            proc_id: s.proc_id,
            epoch: s.epoch,
            tag: 0,
            start_ns: s.vclock,
            end_ns: s.vclock,
            bytes: 0,
            id: 0,
            link: 0,
            seq_open: seq,
            seq_close: seq,
        };
        s.events.push(ev);
        let idx = s.events.len() - 1;
        s.open.push(idx);
        idx
    });
    SpanGuard { idx }
}

/// RAII handle for an open span (see [`span`]).
pub struct SpanGuard {
    idx: usize,
}

impl SpanGuard {
    /// Attribute `n` more bytes to this span.
    pub fn add_bytes(&self, n: u64) {
        if self.idx == usize::MAX {
            return;
        }
        SINK.with(|s| {
            let mut s = s.borrow_mut();
            if let Some(ev) = s.events.get_mut(self.idx) {
                ev.bytes += n;
            }
        });
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.idx == usize::MAX {
            return;
        }
        SINK.with(|s| {
            let mut s = s.borrow_mut();
            let seq = s.seq;
            s.seq += 1;
            let vclock = s.vclock;
            if let Some(ev) = s.events.get_mut(self.idx) {
                ev.end_ns = vclock.max(ev.start_ns);
                ev.seq_close = seq;
            }
            if s.open.last() == Some(&self.idx) {
                s.open.pop();
            }
        });
    }
}

/// Record a point-like span at the current virtual clock.
pub fn instant(kind: SpanKind, tag: u64, bytes: u64, id: u64, link: u64) {
    if !enabled() {
        return;
    }
    SINK.with(|s| {
        let mut s = s.borrow_mut();
        let seq = s.seq;
        s.seq += 1;
        let ev = SpanEvent {
            kind,
            rank: s.rank,
            proc_id: s.proc_id,
            epoch: s.epoch,
            tag,
            start_ns: s.vclock,
            end_ns: s.vclock,
            bytes,
            id: 0,
            link: 0,
            seq_open: seq,
            seq_close: seq,
        };
        let mut ev = ev;
        ev.id = id;
        ev.link = link;
        s.events.push(ev);
    });
}

/// Record a span with explicit timestamps (driver-side events whose
/// duration is modeled rather than bracketed, e.g. checkpoint I/O).
pub fn span_manual(kind: SpanKind, start_ns: u64, end_ns: u64, bytes: u64) {
    if !enabled() {
        return;
    }
    SINK.with(|s| {
        let mut s = s.borrow_mut();
        let seq = s.seq;
        s.seq += 1;
        s.events.push(SpanEvent {
            kind,
            rank: s.rank,
            proc_id: s.proc_id,
            epoch: s.epoch,
            tag: 0,
            start_ns,
            end_ns: end_ns.max(start_ns),
            bytes,
            id: 0,
            link: 0,
            seq_open: seq,
            seq_close: seq,
        });
    });
}

/// Allocate a wire span id and record the `Send` instant. Returns the
/// id to stamp on the frame (0 when tracing is off — the frame then
/// carries no span).
#[inline]
pub fn on_send(tag: u64, bytes: u64) -> u64 {
    if !enabled() {
        return 0;
    }
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    instant(SpanKind::Send, tag, bytes, id, 0);
    id
}

/// Record the `Recv` instant for a frame carrying span id `link`.
#[inline]
pub fn on_recv(tag: u64, bytes: u64, link: u64) {
    if !enabled() {
        return;
    }
    instant(SpanKind::Recv, tag, bytes, 0, link);
}

// ---------------------------------------------------------------------
// Worker span files (cross-process collection)
// ---------------------------------------------------------------------

static WORKER_DIRS: Mutex<Vec<PathBuf>> = Mutex::new(Vec::new());

/// Register a directory that spawned worker processes will flush their
/// span files into (called by the TCP fleet launcher when tracing).
pub fn register_worker_dir(dir: PathBuf) {
    WORKER_DIRS.lock().expect("trace worker-dir lock").push(dir);
}

/// Flush this thread's events as one span file into `dir` (worker-side:
/// called when the data plane sees driver EOF, i.e. at fleet teardown).
pub fn write_worker_spans(dir: &Path, rank: usize) -> Result<()> {
    let events = take();
    let arr = Json::arr(events.iter().map(SpanEvent::to_json));
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let path = dir.join(format!("spans-rank{rank}.json"));
    std::fs::write(&path, arr.to_string_compact())
        .with_context(|| format!("writing worker span file {}", path.display()))?;
    Ok(())
}

/// Read (and consume) every span file the registered worker dirs hold.
/// Workers flush at fleet teardown, so call this after dropping the
/// pool whose workers you want the relay spans of.
pub fn collect_worker_spans() -> Vec<SpanEvent> {
    let dirs: Vec<PathBuf> = WORKER_DIRS.lock().expect("trace worker-dir lock").clone();
    let mut out = Vec::new();
    for dir in dirs {
        let Ok(entries) = std::fs::read_dir(&dir) else { continue };
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let Ok(text) = std::fs::read_to_string(&path) else { continue };
            if let Ok(json) = Json::parse(&text) {
                if let Some(arr) = json.as_arr() {
                    for item in arr {
                        if let Ok(ev) = SpanEvent::from_json(item) {
                            out.push(ev);
                        }
                    }
                }
            }
            let _ = std::fs::remove_file(&path);
        }
    }
    out
}

// ---------------------------------------------------------------------
// Last-trace stash (in-process queries, the `blaze trace` CLI)
// ---------------------------------------------------------------------

static LAST: Mutex<Option<JobTrace>> = Mutex::new(None);

/// Stash the most recent job's merged trace for in-process queries.
pub fn store_last(trace: JobTrace) {
    *LAST.lock().expect("trace stash lock") = Some(trace);
}

/// Take the most recent job's merged trace, if any.
pub fn take_last() -> Option<JobTrace> {
    LAST.lock().expect("trace stash lock").take()
}

// ---------------------------------------------------------------------
// Trace configuration
// ---------------------------------------------------------------------

/// Resolved tracing mode for a cluster: `Off` (default, near-zero
/// cost), `Record` (spans buffered, queryable in-process), or
/// `Export(path)` (record + write Chrome trace-event JSON on job
/// completion). Parsed from `.trace_path(...)` / the `trace` TOML key /
/// `BLAZE_TRACE`, mirroring the collective-algo and transport knobs.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum TraceConfig {
    #[default]
    Off,
    Record,
    Export(PathBuf),
}

impl TraceConfig {
    /// Should spans be recorded at all?
    pub fn is_enabled(&self) -> bool {
        !matches!(self, TraceConfig::Off)
    }

    pub fn export_path(&self) -> Option<&Path> {
        match self {
            TraceConfig::Export(p) => Some(p),
            _ => None,
        }
    }
}

impl std::str::FromStr for TraceConfig {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "off" | "0" | "false" | "none" => Ok(TraceConfig::Off),
            "on" | "1" | "true" | "record" => Ok(TraceConfig::Record),
            _ => Ok(TraceConfig::Export(PathBuf::from(s.trim()))),
        }
    }
}

impl std::fmt::Display for TraceConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceConfig::Off => f.write_str("off"),
            TraceConfig::Record => f.write_str("on"),
            TraceConfig::Export(p) => write!(f, "{}", p.display()),
        }
    }
}

// ---------------------------------------------------------------------
// JobTrace: merged, queryable, exportable
// ---------------------------------------------------------------------

/// Aggregate over one span kind (or one rank): how many spans, how much
/// virtual time inside them, how many bytes attributed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PhaseAgg {
    pub count: u64,
    pub total_ns: u64,
    pub bytes: u64,
}

/// All spans of one job, merged across ranks (and worker processes) and
/// ordered by virtual clock. Queryable in-process and exportable as
/// Chrome trace-event JSON.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobTrace {
    spans: Vec<SpanEvent>,
}

impl JobTrace {
    /// Merge per-rank buffers by virtual clock (start time, then rank,
    /// then open order).
    pub fn merge(buffers: impl IntoIterator<Item = Vec<SpanEvent>>) -> JobTrace {
        let mut spans: Vec<SpanEvent> = buffers.into_iter().flatten().collect();
        spans.sort_by_key(|e| (e.start_ns, e.proc_id, e.rank, e.seq_open));
        JobTrace { spans }
    }

    /// Append more events (e.g. worker relay spans collected after
    /// fleet teardown) and restore the clock ordering.
    pub fn extend(&mut self, more: impl IntoIterator<Item = SpanEvent>) {
        self.spans.extend(more);
        self.spans.sort_by_key(|e| (e.start_ns, e.proc_id, e.rank, e.seq_open));
    }

    pub fn spans(&self) -> &[SpanEvent] {
        &self.spans
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Per-kind aggregates across all ranks.
    pub fn per_phase(&self) -> BTreeMap<SpanKind, PhaseAgg> {
        let mut out: BTreeMap<SpanKind, PhaseAgg> = BTreeMap::new();
        for ev in &self.spans {
            let agg = out.entry(ev.kind).or_default();
            agg.count += 1;
            agg.total_ns += ev.duration_ns();
            agg.bytes += ev.bytes;
        }
        out
    }

    /// Per-(process, rank) aggregates.
    pub fn per_rank(&self) -> BTreeMap<(u32, usize), PhaseAgg> {
        let mut out: BTreeMap<(u32, usize), PhaseAgg> = BTreeMap::new();
        for ev in &self.spans {
            let agg = out.entry((ev.proc_id, ev.rank)).or_default();
            agg.count += 1;
            agg.total_ns += ev.duration_ns();
            agg.bytes += ev.bytes;
        }
        out
    }

    /// Histogram of span durations (ns) for one kind — p50/p99 etc. via
    /// [`Histogram`].
    pub fn duration_histogram(&self, kind: SpanKind) -> Histogram {
        let mut h = Histogram::new();
        for ev in self.spans.iter().filter(|e| e.kind == kind) {
            h.observe(ev.duration_ns());
        }
        h
    }

    /// Greedy critical path, walked backwards from the span with the
    /// latest virtual end time: follow the wire link when the span has
    /// one (cross-rank hop), otherwise the latest earlier span on the
    /// same rank. Returned in execution order.
    pub fn critical_path(&self) -> Vec<&SpanEvent> {
        if self.spans.is_empty() {
            return Vec::new();
        }
        let by_id: HashMap<u64, &SpanEvent> =
            self.spans.iter().filter(|e| e.id != 0).map(|e| (e.id, e)).collect();
        let mut cur = self
            .spans
            .iter()
            .max_by_key(|e| (e.end_ns, e.seq_close))
            .expect("non-empty trace");
        let mut path = vec![cur];
        let mut guard = 0usize;
        while guard < self.spans.len() {
            guard += 1;
            let next = if cur.link != 0 {
                by_id.get(&cur.link).copied()
            } else {
                self.spans
                    .iter()
                    .filter(|e| {
                        e.proc_id == cur.proc_id
                            && e.rank == cur.rank
                            && e.seq_close < cur.seq_open
                    })
                    .max_by_key(|e| e.seq_close)
            };
            match next {
                Some(prev) if !std::ptr::eq(prev, cur) => {
                    path.push(prev);
                    cur = prev;
                }
                _ => break,
            }
        }
        path.reverse();
        path
    }

    /// Human-readable per-phase / per-rank breakdown.
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "trace: {} spans", self.spans.len());
        let _ = writeln!(out, "  {:<14} {:>7} {:>14} {:>12}", "phase", "count", "total_ms", "bytes");
        for (kind, agg) in self.per_phase() {
            let _ = writeln!(
                out,
                "  {:<14} {:>7} {:>14.3} {:>12}",
                kind.as_str(),
                agg.count,
                agg.total_ns as f64 / 1e6,
                agg.bytes
            );
        }
        let _ = writeln!(out, "  per-rank (proc/rank: spans, busy_ms):");
        for ((proc_id, rank), agg) in self.per_rank() {
            let rank_s = if rank == DRIVER_RANK { "driver".to_string() } else { rank.to_string() };
            let _ = writeln!(
                out,
                "    p{proc_id}/{rank_s}: {} spans, {:.3} ms",
                agg.count,
                agg.total_ns as f64 / 1e6
            );
        }
        let path = self.critical_path();
        if !path.is_empty() {
            let _ = writeln!(out, "  critical path ({} hops):", path.len());
            for ev in path.iter().rev().take(12).rev() {
                let _ = writeln!(
                    out,
                    "    {:>12} ns  {:<14} rank {} ({} B)",
                    ev.start_ns,
                    ev.kind.as_str(),
                    if ev.rank == DRIVER_RANK { "driver".to_string() } else { ev.rank.to_string() },
                    ev.bytes
                );
            }
        }
        out
    }

    /// Export as Chrome trace-event JSON (the Perfetto / chrome://tracing
    /// format): one `"X"` complete event per span (`ts`/`dur` in µs of
    /// virtual time, `pid` = process lane, `tid` = rank) plus `"s"`/`"f"`
    /// flow events stitching every send→recv/relay pair into an arrow.
    pub fn to_chrome_json(&self) -> Json {
        let mut events = Vec::new();
        for ev in &self.spans {
            let tid = if ev.rank == DRIVER_RANK { 1_000_000.0 } else { ev.rank as f64 };
            let ts = ev.start_ns as f64 / 1e3;
            let dur = ev.duration_ns() as f64 / 1e3;
            events.push(Json::obj([
                ("name", Json::str(ev.kind.as_str())),
                ("cat", Json::str(ev.kind.category())),
                ("ph", Json::str("X")),
                ("ts", Json::num(ts)),
                ("dur", Json::num(dur)),
                ("pid", Json::num(ev.proc_id as f64)),
                ("tid", Json::num(tid)),
                (
                    "args",
                    Json::obj([
                        ("bytes", Json::num(ev.bytes as f64)),
                        ("epoch", Json::num(ev.epoch as f64)),
                        ("tag", Json::num(ev.tag as f64)),
                        ("span_id", Json::num(ev.id as f64)),
                        ("link", Json::num(ev.link as f64)),
                        ("rank", Json::num(ev.rank_json())),
                    ]),
                ),
            ]));
            if ev.kind == SpanKind::Send && ev.id != 0 {
                events.push(Json::obj([
                    ("name", Json::str("frame")),
                    ("cat", Json::str("mpi")),
                    ("ph", Json::str("s")),
                    ("id", Json::num(ev.id as f64)),
                    ("ts", Json::num(ts)),
                    ("pid", Json::num(ev.proc_id as f64)),
                    ("tid", Json::num(tid)),
                ]));
            }
            if ev.link != 0 && matches!(ev.kind, SpanKind::Recv | SpanKind::Relay) {
                events.push(Json::obj([
                    ("name", Json::str("frame")),
                    ("cat", Json::str("mpi")),
                    ("ph", Json::str("f")),
                    ("bp", Json::str("e")),
                    ("id", Json::num(ev.link as f64)),
                    ("ts", Json::num(ts)),
                    ("pid", Json::num(ev.proc_id as f64)),
                    ("tid", Json::num(tid)),
                ]));
            }
        }
        Json::obj([
            ("traceEvents", Json::arr(events)),
            ("displayTimeUnit", Json::str("ns")),
            (
                "otherData",
                Json::obj([
                    ("clock", Json::str("virtual (modeled) nanoseconds, exported as µs ts")),
                    ("producer", Json::str("blaze-rs trace subsystem")),
                ]),
            ),
        ])
    }

    /// Write the Chrome export to `path`.
    pub fn export(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        std::fs::write(path, self.to_chrome_json().to_string_compact())
            .with_context(|| format!("writing trace export {}", path.display()))
    }
}

/// Validate that `json` is structurally a Chrome trace-event document:
/// a `traceEvents` array whose entries carry the required fields per
/// phase type. The CI schema step round-trips an exported file through
/// [`Json::parse`] and this check.
pub fn validate_chrome_json(json: &Json) -> Result<()> {
    let events = json
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("trace export must have a traceEvents array")?;
    ensure!(!events.is_empty(), "traceEvents must not be empty");
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .with_context(|| format!("event {i}: missing ph"))?;
        ensure!(
            ev.get("name").and_then(Json::as_str).is_some(),
            "event {i}: missing name"
        );
        ensure!(ev.get("ts").and_then(Json::as_f64).is_some(), "event {i}: missing ts");
        ensure!(ev.get("pid").and_then(Json::as_f64).is_some(), "event {i}: missing pid");
        ensure!(ev.get("tid").and_then(Json::as_f64).is_some(), "event {i}: missing tid");
        match ph {
            "X" => {
                ensure!(
                    ev.get("dur").and_then(Json::as_f64).is_some(),
                    "event {i}: X event missing dur"
                );
            }
            "s" | "f" => {
                ensure!(
                    ev.get("id").and_then(Json::as_f64).is_some(),
                    "event {i}: flow event missing id"
                );
            }
            other => bail!("event {i}: unsupported phase type {other:?}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that flip the process-wide recording state;
    /// the pure data-structure tests below run freely in parallel.
    fn state_gate() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn ev(kind: SpanKind, rank: usize, start: u64, end: u64) -> SpanEvent {
        SpanEvent {
            kind,
            rank,
            proc_id: 0,
            epoch: 1,
            tag: 0,
            start_ns: start,
            end_ns: end,
            bytes: 10,
            id: 0,
            link: 0,
            seq_open: start,
            seq_close: end,
        }
    }

    #[test]
    fn span_guard_records_nested_laminar_events() {
        let _gate = state_gate();
        let _g = enable_scope(true);
        job_start(3, 0, 7);
        set_vclock(100);
        {
            let outer = span(SpanKind::Map);
            outer.add_bytes(5);
            set_vclock(200);
            {
                let _inner = span(SpanKind::Spill);
                set_vclock(300);
            }
            set_vclock(400);
        }
        let events = take();
        assert_eq!(events.len(), 2);
        let outer = &events[0];
        let inner = &events[1];
        assert_eq!(outer.kind, SpanKind::Map);
        assert_eq!((outer.rank, outer.epoch), (3, 7));
        assert_eq!((outer.start_ns, outer.end_ns), (100, 400));
        assert_eq!(outer.bytes, 5);
        assert_eq!(inner.kind, SpanKind::Spill);
        assert_eq!((inner.start_ns, inner.end_ns), (200, 300));
        // Laminar: inner's [open, close] strictly inside outer's.
        assert!(outer.seq_open < inner.seq_open && inner.seq_close < outer.seq_close);
    }

    #[test]
    fn disabled_records_nothing_and_send_ids_are_zero() {
        // The off-state assertion cannot be made race-free while the
        // BLAZE_TRACE leg force-enables tracing in concurrent tests.
        if std::env::var("BLAZE_TRACE").map(|v| !v.trim().is_empty()).unwrap_or(false) {
            return;
        }
        let _gate = state_gate();
        set_enabled(false);
        job_start(0, 0, 1);
        let g = span(SpanKind::Map);
        g.add_bytes(9);
        drop(g);
        assert_eq!(on_send(1, 10), 0);
        on_recv(1, 10, 0);
        assert!(take().is_empty());
    }

    #[test]
    fn send_ids_are_unique_and_recv_links_them() {
        let _gate = state_gate();
        let _g = enable_scope(true);
        job_start(0, 0, 1);
        let a = on_send(5, 10);
        let b = on_send(5, 20);
        assert_ne!(a, 0);
        assert_ne!(a, b);
        on_recv(5, 10, a);
        let events = take();
        let sends: Vec<_> = events.iter().filter(|e| e.kind == SpanKind::Send).collect();
        let recvs: Vec<_> = events.iter().filter(|e| e.kind == SpanKind::Recv).collect();
        assert_eq!(sends.len(), 2);
        assert_eq!(recvs.len(), 1);
        assert_eq!(recvs[0].link, a);
    }

    #[test]
    fn trace_config_parses_like_the_other_knobs() {
        let off: TraceConfig = "off".parse().unwrap();
        assert_eq!(off, TraceConfig::Off);
        assert_eq!("0".parse::<TraceConfig>().unwrap(), TraceConfig::Off);
        assert_eq!("on".parse::<TraceConfig>().unwrap(), TraceConfig::Record);
        assert_eq!("1".parse::<TraceConfig>().unwrap(), TraceConfig::Record);
        let exp: TraceConfig = "/tmp/out.json".parse().unwrap();
        assert_eq!(exp, TraceConfig::Export(PathBuf::from("/tmp/out.json")));
        assert!(exp.is_enabled());
        assert!(!off.is_enabled());
        assert_eq!(exp.export_path(), Some(Path::new("/tmp/out.json")));
        assert_eq!(format!("{off} {exp}"), "off /tmp/out.json");
    }

    #[test]
    fn merge_orders_by_clock_and_aggregates() {
        let t = JobTrace::merge([
            vec![ev(SpanKind::Map, 1, 50, 80), ev(SpanKind::Reduce, 1, 90, 100)],
            vec![ev(SpanKind::Map, 0, 10, 40)],
        ]);
        let starts: Vec<u64> = t.spans().iter().map(|e| e.start_ns).collect();
        assert_eq!(starts, vec![10, 50, 90]);
        let phases = t.per_phase();
        assert_eq!(phases[&SpanKind::Map].count, 2);
        assert_eq!(phases[&SpanKind::Map].total_ns, 60);
        assert_eq!(phases[&SpanKind::Reduce].total_ns, 10);
        let ranks = t.per_rank();
        assert_eq!(ranks[&(0, 1)].count, 2);
        let h = t.duration_histogram(SpanKind::Map);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn critical_path_follows_links_across_ranks() {
        let mut send = ev(SpanKind::Send, 0, 10, 10);
        send.id = 77;
        send.seq_open = 0;
        send.seq_close = 0;
        let mut early = ev(SpanKind::Map, 0, 0, 9);
        early.seq_open = 1;
        early.seq_close = 1;
        // seq on rank 0: Map then Send.
        early.seq_open = 0;
        early.seq_close = 0;
        send.seq_open = 1;
        send.seq_close = 1;
        let mut recv = ev(SpanKind::Recv, 1, 30, 30);
        recv.link = 77;
        recv.seq_open = 0;
        recv.seq_close = 0;
        let mut reduce = ev(SpanKind::Reduce, 1, 30, 90);
        reduce.seq_open = 1;
        reduce.seq_close = 2;
        let t = JobTrace::merge([vec![early, send], vec![recv, reduce]]);
        let path = t.critical_path();
        let kinds: Vec<SpanKind> = path.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![SpanKind::Map, SpanKind::Send, SpanKind::Recv, SpanKind::Reduce],
            "path must hop rank 1 <- link <- rank 0"
        );
    }

    #[test]
    fn chrome_export_roundtrips_and_validates() {
        let mut send = ev(SpanKind::Send, 0, 10, 10);
        send.id = 5;
        let mut recv = ev(SpanKind::Recv, 1, 20, 20);
        recv.link = 5;
        let t = JobTrace::merge([vec![ev(SpanKind::Map, 0, 0, 50), send], vec![recv]]);
        let json = t.to_chrome_json();
        let text = json.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        validate_chrome_json(&parsed).unwrap();
        // Flow arrows present: one "s" for the send, one "f" for the recv.
        let events = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        let phases: Vec<&str> =
            events.iter().filter_map(|e| e.get("ph").and_then(Json::as_str)).collect();
        assert!(phases.contains(&"s") && phases.contains(&"f"));
        validate_chrome_json(&Json::parse("{\"traceEvents\":[]}").unwrap()).unwrap_err();
    }

    #[test]
    fn span_event_json_roundtrip() {
        let mut e = ev(SpanKind::Relay, 4, 123, 456);
        e.proc_id = 5;
        e.link = 99;
        e.tag = 3;
        let back = SpanEvent::from_json(&e.to_json()).unwrap();
        assert_eq!(back, e);
        let mut d = ev(SpanKind::Checkpoint, DRIVER_RANK, 1, 2);
        d.bytes = 7;
        let back = SpanEvent::from_json(&d.to_json()).unwrap();
        assert_eq!(back.rank, DRIVER_RANK);
        assert_eq!(back, d);
    }

    #[test]
    fn manual_span_and_summary_render() {
        let _gate = state_gate();
        let _g = enable_scope(true);
        job_start(DRIVER_RANK, 0, 2);
        span_manual(SpanKind::Checkpoint, 100, 900, 4096);
        let t = JobTrace::merge([take()]);
        assert_eq!(t.per_phase()[&SpanKind::Checkpoint].total_ns, 800);
        let s = t.summary();
        assert!(s.contains("checkpoint"));
        assert!(s.contains("driver"));
    }
}
