//! Delayed Reduction — the paper's contribution (§III.D, Figs 6-7).
//!
//! Paper pseudocode, step by step:
//!  1. a source collection feeds the mappers;
//!  2. mappers emit `(K, V)` pairs;
//!  3. an *intermediate reducer* combines keys into a `DistVector` of
//!     locally-grouped runs — grouping, not reducing, so the value
//!     multiset survives (this is what eager reduction destroys and why
//!     matmul/linreg "felt rigidity");
//!  4. runs are sorted with **merge sort** and shuffled across the
//!     cluster, yielding `(K, Iterable<V>)` on the owning rank;
//!  5. the final reducer runs over the iterable — *"immediately or later.
//!     Laziness of Reduction is displayed"* — hence [`DelayedOutput`];
//!  6. results land in a `DistHashMap`-shaped shard.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;

use anyhow::Result;

use crate::dist::{DistVector, ShardRouter};
use crate::metrics::PeakTracker;
use crate::mpi::Communicator;
use crate::serial::FastSerialize;

use super::context::{Emitter, GroupEmitter};
use super::scheduler::TaskFeed;
use super::shuffle::shuffle_pairs;

/// The lazily-reducible output of the delayed pipeline on one rank:
/// key-sorted groups of `(K, Iterable<V>)`, final reduce not yet applied.
#[derive(Debug)]
pub struct DelayedOutput<K, V> {
    groups: Vec<(K, Vec<V>)>,
}

impl<K: Ord + Hash + Eq, V> DelayedOutput<K, V> {
    /// Iterate `(key, values)` groups without reducing — step 5's "later".
    pub fn iter_groups(&self) -> impl Iterator<Item = (&K, &[V])> {
        self.groups.iter().map(|(k, vs)| (k, vs.as_slice()))
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Apply the final reducer now — step 5's "immediately".
    pub fn reduce_now<R: Fn(&K, Vec<V>) -> V>(self, reduce: R) -> HashMap<K, V> {
        let mut out = HashMap::with_capacity(self.groups.len());
        for (k, vs) in self.groups {
            let reduced = reduce(&k, vs);
            out.insert(k, reduced);
        }
        out
    }
}

/// SPMD rank body up to (and excluding) the final reduce: map, local
/// group, merge-sort, shuffle, merge. Returns this rank's
/// [`DelayedOutput`] — call `reduce_now` for step 5, or iterate lazily.
pub fn delayed_rank_groups<I, K, V, M>(
    comm: &Communicator,
    feed: &TaskFeed<'_, I>,
    map: &M,
    salt: u64,
    tracker: &Arc<PeakTracker>,
) -> Result<DelayedOutput<K, V>>
where
    I: Sync,
    K: FastSerialize + Hash + Eq + Ord + Send,
    V: FastSerialize + Send,
    M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
{
    // Steps 1-3: map + intermediate (grouping) reducer.
    let mut emitter: GroupEmitter<K, V> = GroupEmitter::new();
    let mut rank_feed = feed.for_rank(comm.rank());
    while let Some((task, chunk)) = rank_feed.next() {
        comm.timed(|| {
            for item in chunk {
                map(item, &mut |k, v| emitter.emit(k, v));
            }
        });
        rank_feed.complete(task);
    }

    // The temporary DistVector of locally-grouped runs.
    let mut runs: DistVector<'_, (K, Vec<V>)> =
        DistVector::from_local(comm, comm.timed(|| emitter.groups.into_iter().collect()));
    let run_bytes: u64 = runs
        .local()
        .iter()
        .map(|(k, vs)| {
            (k.size_hint() + vs.iter().map(FastSerialize::size_hint).sum::<usize>() + 32) as u64
        })
        .sum();
    tracker.alloc(run_bytes);

    // Step 4a: merge sort the local run by key. `sort_by` is a stable
    // adaptive merge sort — literally the paper's "sorting using Merge
    // Sort".
    comm.timed(|| runs.local_mut().sort_by(|a, b| a.0.cmp(&b.0)));

    // Step 4b: shuffle runs to key owners.
    let router = ShardRouter::new(comm.size(), salt);
    let incoming = shuffle_pairs(comm, &router, runs.into_local(), tracker)?;
    tracker.free(run_bytes);

    // Step 4c: merge the (per-source sorted) incoming runs into key-sorted
    // groups. Sorting a concatenation of sorted runs is the k-way merge
    // phase of merge sort; Rust's stable sort detects and merges the runs.
    let groups = comm.timed(|| {
        let mut incoming = incoming;
        incoming.sort_by(|a, b| a.0.cmp(&b.0));
        let mut groups: Vec<(K, Vec<V>)> = Vec::new();
        for (k, mut vs) in incoming {
            match groups.last_mut() {
                Some((lk, lvs)) if *lk == k => lvs.append(&mut vs),
                _ => groups.push((k, vs)),
            }
        }
        groups
    });
    let group_bytes: u64 = groups
        .iter()
        .map(|(k, vs)| {
            (k.size_hint() + vs.iter().map(FastSerialize::size_hint).sum::<usize>() + 32) as u64
        })
        .sum();
    tracker.alloc(group_bytes);
    // Charge stays until the output is dropped/reduced; engine frees after
    // reduce via its own accounting of the result map.
    tracker.free(group_bytes);
    Ok(DelayedOutput { groups })
}

/// Full delayed-reduction rank body: groups then reduces immediately.
/// Returns (result shard, spilled bytes = 0; grouping happens in memory —
/// out-of-core delayed reduction is future work, as in the paper).
pub fn delayed_rank<I, K, V, M, R>(
    comm: &Communicator,
    feed: &TaskFeed<'_, I>,
    map: &M,
    reduce: &R,
    salt: u64,
    tracker: &Arc<PeakTracker>,
) -> Result<(HashMap<K, V>, u64)>
where
    I: Sync,
    K: FastSerialize + Hash + Eq + Ord + Send,
    V: FastSerialize + Send,
    M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
    R: Fn(&K, Vec<V>) -> V + Sync,
{
    let output = delayed_rank_groups(comm, feed, map, salt, tracker)?;
    let out = comm.timed(|| output.reduce_now(reduce));
    let out_bytes: u64 =
        out.iter().map(|(k, v)| (k.size_hint() + v.size_hint() + 16) as u64).sum();
    tracker.alloc(out_bytes);
    Ok((out, 0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::Scheduling;
    use crate::util::testpool::pool_run;

    #[test]
    fn delayed_wordcount_matches_truth() {
        let input: Vec<String> =
            ["a b a", "b c b", "a"].iter().map(|s| s.to_string()).collect();
        let feed = TaskFeed::new(&input, 2, 1, Scheduling::Static, None);
        let results = pool_run(2, |c| {
            let map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
                for w in line.split_whitespace() {
                    emit(w.to_string(), 1);
                }
            };
            let reduce = |_k: &String, vs: Vec<u64>| vs.into_iter().sum::<u64>();
            let tracker = PeakTracker::new();
            delayed_rank(c, &feed, &map, &reduce, 0, &tracker).unwrap().0
        });
        let mut merged: HashMap<String, u64> = HashMap::new();
        for shard in results {
            merged.extend(shard);
        }
        assert_eq!(merged[&"a".to_string()], 3);
        assert_eq!(merged[&"b".to_string()], 3);
        assert_eq!(merged[&"c".to_string()], 1);
    }

    #[test]
    fn groups_are_key_sorted_and_complete() {
        let input: Vec<u32> = (0..20).collect();
        let feed = TaskFeed::new(&input, 2, 1, Scheduling::Static, None);
        let outputs = pool_run(2, |c| {
            let map = |i: &u32, emit: &mut dyn FnMut(u32, u32)| emit(i % 4, *i);
            let tracker = PeakTracker::new();
            let out = delayed_rank_groups(c, &feed, &map, 0, &tracker).unwrap();
            let keys: Vec<u32> = out.iter_groups().map(|(k, _)| *k).collect();
            let mut sorted = keys.clone();
            sorted.sort_unstable();
            assert_eq!(keys, sorted, "groups must be key-sorted");
            out.iter_groups()
                .map(|(k, vs)| (*k, vs.len()))
                .collect::<Vec<_>>()
        });
        // Each key 0..4 appears on exactly one rank with all 5 values.
        let mut totals: HashMap<u32, usize> = HashMap::new();
        for groups in outputs {
            for (k, n) in groups {
                assert!(totals.insert(k, n).is_none(), "key {k} on two ranks");
            }
        }
        assert_eq!(totals.len(), 4);
        assert!(totals.values().all(|&n| n == 5));
    }

    #[test]
    fn laziness_reduce_later_still_correct() {
        // The "can be called immediately or later" property: iterate the
        // groups first (e.g. to inspect), then reduce.
        let input: Vec<u32> = (1..=6).collect();
        let feed = TaskFeed::new(&input, 1, 1, Scheduling::Static, None);
        let results = pool_run(1, |c| {
            let map = |i: &u32, emit: &mut dyn FnMut(u8, u32)| emit((i % 2) as u8, *i);
            let tracker = PeakTracker::new();
            let out = delayed_rank_groups(c, &feed, &map, 0, &tracker).unwrap();
            let inspected: usize = out.iter_groups().map(|(_, vs)| vs.len()).sum();
            assert_eq!(inspected, 6);
            out.reduce_now(|_, vs| vs.into_iter().sum::<u32>())
        });
        assert_eq!(results[0][&0u8], 2 + 4 + 6);
        assert_eq!(results[0][&1u8], 1 + 3 + 5);
    }

    #[test]
    fn iterable_reduce_beyond_monoid() {
        // Median: impossible with eager (scalar) combine, fine with the
        // iterable reducer — the §III.D motivation in miniature.
        let input: Vec<u32> = vec![5, 1, 9, 3, 7];
        let feed = TaskFeed::new(&input, 2, 1, Scheduling::Static, None);
        let results = pool_run(2, |c| {
            let map = |i: &u32, emit: &mut dyn FnMut(u8, u32)| emit(0, *i);
            let reduce = |_k: &u8, mut vs: Vec<u32>| {
                vs.sort_unstable();
                vs[vs.len() / 2]
            };
            let tracker = PeakTracker::new();
            delayed_rank(c, &feed, &map, &reduce, 0, &tracker).unwrap().0
        });
        let owner: Vec<_> = results.into_iter().filter(|m| !m.is_empty()).collect();
        assert_eq!(owner[0][&0u8], 5);
    }
}
