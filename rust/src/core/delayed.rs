//! Delayed Reduction — the paper's contribution (§III.D, Figs 6-7),
//! now out-of-core.
//!
//! Paper pseudocode, step by step:
//!  1. a source collection feeds the mappers;
//!  2. mappers emit `(K, V)` pairs;
//!  3. an *intermediate reducer* stages pairs into locally key-ordered
//!     runs — grouping, not reducing, so the value multiset survives
//!     (this is what eager reduction destroys and why matmul/linreg
//!     "felt rigidity"). Runs past the memory budget spill to disk via
//!     [`crate::store::RunWriter`];
//!  4. runs are sorted with **merge sort** (each run by Rust's stable
//!     merge sort, runs merged by the loser-tree
//!     [`crate::store::KWayMerge`] — external merge sort end to end)
//!     and shuffled across the cluster in budget-bounded rounds,
//!     yielding `(K, Iterable<V>)` on the owning rank;
//!  5. the final reducer runs over the iterable — *"immediately or
//!     later. Laziness of Reduction is displayed"* — hence
//!     [`DelayedOutput`], whose `for_each_group` streams groups without
//!     ever materializing the dataset;
//!  6. results land in a `DistHashMap`-shaped shard.
//!
//! The §III.D caveat ("grouping happens in memory") is gone: with a
//! finite budget the only full-dataset copies live in spill runs on
//! disk, and peak tracked memory stays near the budget plus a constant
//! per-run overhead (asserted in `tests/integration_store.rs`).

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;

use anyhow::Result;

use crate::dist::ShardRouter;
use crate::metrics::{MemoryScope, PeakTracker};
use crate::mpi::Communicator;
use crate::serial::FastSerialize;
use crate::store::{GroupStream, GroupValues, RunSet, RunWriter};

use super::scheduler::TaskFeed;
use super::shuffle::{shuffle_runs, stage_sorted_runs};

/// The lazily-reducible output of the delayed pipeline on one rank:
/// key-ordered groups of `(K, Iterable<V>)`, final reduce not yet
/// applied. Backed by the rank's incoming [`RunSet`] — iterating or
/// reducing streams groups off the merge; nothing is materialized
/// unless [`DelayedOutput::iter_groups`] asks for it.
pub struct DelayedOutput<K, V> {
    runs: Option<RunSet<K, V>>,
    groups: Vec<(K, Vec<V>)>,
    materialized: bool,
    /// Tracker charge for the materialized groups (freed on drop).
    group_scope: Option<MemoryScope>,
    tracker: Arc<PeakTracker>,
    spilled_bytes: u64,
}

impl<K, V> DelayedOutput<K, V>
where
    K: FastSerialize + Hash + Eq + Ord,
    V: FastSerialize,
{
    fn from_runs(runs: RunSet<K, V>, spilled_bytes: u64, tracker: Arc<PeakTracker>) -> Self {
        Self {
            runs: Some(runs),
            groups: Vec::new(),
            materialized: false,
            group_scope: None,
            tracker,
            spilled_bytes,
        }
    }

    /// Bytes this rank spilled while grouping (0 = stayed in core).
    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes
    }

    /// Step 5's "later", out-of-core: stream `(key, lazy values)`
    /// groups in ascending key order. Values stream straight off the
    /// merge — nothing is materialized unless `f` collects it.
    pub fn for_each_group(
        mut self,
        mut f: impl FnMut(&K, &mut dyn Iterator<Item = V>),
    ) -> Result<()> {
        if self.materialized {
            for (k, vs) in self.groups.drain(..) {
                f(&k, &mut vs.into_iter());
            }
            return Ok(());
        }
        let Some(runs) = self.runs.take() else { return Ok(()) };
        GroupStream::new(runs.into_merge()?).for_each_group(f)
    }

    /// Compat shim for [`DelayedOutput::for_each_group`] with the
    /// pre-PR-10 materialized `(K, Vec<V>)` callback shape.
    pub fn for_each_group_vec(self, mut f: impl FnMut(K, Vec<V>)) -> Result<()>
    where
        K: Clone,
    {
        self.for_each_group(|k, vs| f(k.clone(), vs.collect()))
    }

    /// Materialize all groups in memory (the pre-out-of-core shape; use
    /// [`DelayedOutput::for_each_group`] to stay within the budget).
    /// The whole dataset is real memory again, so it is charged to the
    /// tracker until this output is dropped.
    fn materialize(&mut self) -> Result<()> {
        if self.materialized {
            return Ok(());
        }
        if let Some(runs) = self.runs.take() {
            let mut stream = GroupStream::new(runs.into_merge()?);
            while let Some(g) = stream.next_group()? {
                self.groups.push(g);
            }
        }
        let group_bytes: u64 = self
            .groups
            .iter()
            .map(|(k, vs)| {
                (k.size_hint() + vs.iter().map(FastSerialize::size_hint).sum::<usize>() + 32)
                    as u64
            })
            .sum();
        self.group_scope = Some(MemoryScope::charge(&self.tracker, group_bytes));
        self.materialized = true;
        Ok(())
    }

    /// Iterate `(key, values)` groups without reducing — step 5's
    /// "later", in-memory form. Materializes the groups on first call.
    pub fn iter_groups(&mut self) -> Result<impl Iterator<Item = (&K, &[V])>> {
        self.materialize()?;
        Ok(self.groups.iter().map(|(k, vs)| (k, vs.as_slice())))
    }

    pub fn num_groups(&mut self) -> Result<usize> {
        self.materialize()?;
        Ok(self.groups.len())
    }

    /// Apply the final reducer now — step 5's "immediately". Streams
    /// groups off the runs; only the reduced result is materialized.
    pub fn reduce_now<R>(mut self, reduce: R) -> Result<HashMap<K, V>>
    where
        R: Fn(&K, &mut dyn Iterator<Item = V>) -> V,
    {
        let mut out = HashMap::new();
        if self.materialized {
            for (k, vs) in self.groups.drain(..) {
                let reduced = reduce(&k, &mut vs.into_iter());
                out.insert(k, reduced);
            }
            return Ok(out);
        }
        let Some(runs) = self.runs.take() else { return Ok(out) };
        let mut stream = GroupStream::new(runs.into_merge()?);
        while let Some((key, first)) = stream.begin_group()? {
            let mut vals = GroupValues::new(&mut stream, &key, first);
            let reduced = reduce(&key, &mut vals);
            vals.finish()?;
            out.insert(key, reduced);
        }
        Ok(out)
    }
}

/// SPMD rank body up to (and excluding) the final reduce: map, stage
/// into sorted runs under `spill_budget` bytes, shuffle in bounded
/// rounds, merge. Returns this rank's [`DelayedOutput`] — call
/// `reduce_now` for step 5, or stream groups lazily.
pub fn delayed_rank_groups<I, K, V, M>(
    comm: &Communicator,
    feed: &TaskFeed<'_, I>,
    map: &M,
    salt: u64,
    spill_budget: u64,
    tracker: &Arc<PeakTracker>,
) -> Result<DelayedOutput<K, V>>
where
    I: Sync,
    K: FastSerialize + Hash + Eq + Ord + Send,
    V: FastSerialize + Send,
    M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
{
    // Steps 1-3 + 4a: map + intermediate (grouping) stage into sorted
    // runs. No combiner here — delayed reduction's whole point is that
    // the multiset survives.
    let writer: RunWriter<'_, K, V> = RunWriter::new(spill_budget, tracker.clone());
    let local_runs = stage_sorted_runs(comm, feed, map, writer)?;
    let map_spilled = local_runs.spilled_bytes();

    // Step 4b: shuffle runs to key owners in budget-bounded rounds.
    let router = ShardRouter::new(comm.size(), salt);
    let (incoming, _) = shuffle_runs(comm, &router, local_runs, spill_budget, None, tracker)?;

    // Step 4c happens lazily: the loser-tree merge of the incoming runs
    // is the k-way phase of merge sort, pulled by the DelayedOutput.
    let spilled = map_spilled + incoming.spilled_bytes();
    Ok(DelayedOutput::from_runs(incoming, spilled, tracker.clone()))
}

/// Full delayed-reduction rank body: groups then reduces immediately.
/// Returns (result shard, spilled bytes, combined bytes = 0 — delayed
/// mode never combines; the multiset is the contract).
pub fn delayed_rank<I, K, V, M, R>(
    comm: &Communicator,
    feed: &TaskFeed<'_, I>,
    map: &M,
    reduce: &R,
    salt: u64,
    spill_budget: u64,
    tracker: &Arc<PeakTracker>,
) -> Result<(HashMap<K, V>, u64, u64)>
where
    I: Sync,
    K: FastSerialize + Hash + Eq + Ord + Send,
    V: FastSerialize + Send,
    M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
    R: Fn(&K, &mut dyn Iterator<Item = V>) -> V + Sync,
{
    let output = delayed_rank_groups(comm, feed, map, salt, spill_budget, tracker)?;
    let spilled = output.spilled_bytes();
    let reduce_span = crate::trace::span(crate::trace::SpanKind::Reduce);
    let out = comm.timed(|| output.reduce_now(reduce))?;
    drop(reduce_span);
    let out_bytes: u64 =
        out.iter().map(|(k, v)| (k.size_hint() + v.size_hint() + 16) as u64).sum();
    tracker.alloc(out_bytes);
    Ok((out, spilled, 0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::Scheduling;
    use crate::util::testpool::pool_run;

    #[test]
    fn delayed_wordcount_matches_truth() {
        let input: Vec<String> =
            ["a b a", "b c b", "a"].iter().map(|s| s.to_string()).collect();
        let feed = TaskFeed::new(&input, 2, 1, Scheduling::Static, None);
        let results = pool_run(2, |c| {
            let map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
                for w in line.split_whitespace() {
                    emit(w.to_string(), 1);
                }
            };
            let reduce =
                |_k: &String, vs: &mut dyn Iterator<Item = u64>| vs.sum::<u64>();
            let tracker = PeakTracker::new();
            delayed_rank(c, &feed, &map, &reduce, 0, u64::MAX, &tracker).unwrap().0
        });
        let mut merged: HashMap<String, u64> = HashMap::new();
        for shard in results {
            merged.extend(shard);
        }
        assert_eq!(merged[&"a".to_string()], 3);
        assert_eq!(merged[&"b".to_string()], 3);
        assert_eq!(merged[&"c".to_string()], 1);
    }

    #[test]
    fn groups_are_key_sorted_and_complete() {
        let input: Vec<u32> = (0..20).collect();
        let feed = TaskFeed::new(&input, 2, 1, Scheduling::Static, None);
        let outputs = pool_run(2, |c| {
            let map = |i: &u32, emit: &mut dyn FnMut(u32, u32)| emit(i % 4, *i);
            let tracker = PeakTracker::new();
            let mut out =
                delayed_rank_groups(c, &feed, &map, 0, u64::MAX, &tracker).unwrap();
            let keys: Vec<u32> = out.iter_groups().unwrap().map(|(k, _)| *k).collect();
            let mut sorted = keys.clone();
            sorted.sort_unstable();
            assert_eq!(keys, sorted, "groups must be key-sorted");
            out.iter_groups()
                .unwrap()
                .map(|(k, vs)| (*k, vs.len()))
                .collect::<Vec<_>>()
        });
        // Each key 0..4 appears on exactly one rank with all 5 values.
        let mut totals: HashMap<u32, usize> = HashMap::new();
        for groups in outputs {
            for (k, n) in groups {
                assert!(totals.insert(k, n).is_none(), "key {k} on two ranks");
            }
        }
        assert_eq!(totals.len(), 4);
        assert!(totals.values().all(|&n| n == 5));
    }

    #[test]
    fn laziness_reduce_later_still_correct() {
        // The "can be called immediately or later" property: iterate the
        // groups first (e.g. to inspect), then reduce.
        let input: Vec<u32> = (1..=6).collect();
        let feed = TaskFeed::new(&input, 1, 1, Scheduling::Static, None);
        let results = pool_run(1, |c| {
            let map = |i: &u32, emit: &mut dyn FnMut(u8, u32)| emit((i % 2) as u8, *i);
            let tracker = PeakTracker::new();
            let mut out =
                delayed_rank_groups(c, &feed, &map, 0, u64::MAX, &tracker).unwrap();
            let inspected: usize =
                out.iter_groups().unwrap().map(|(_, vs)| vs.len()).sum();
            assert_eq!(inspected, 6);
            out.reduce_now(|_, vs| vs.sum::<u32>()).unwrap()
        });
        assert_eq!(results[0][&0u8], 2 + 4 + 6);
        assert_eq!(results[0][&1u8], 1 + 3 + 5);
    }

    #[test]
    fn iterable_reduce_beyond_monoid() {
        // Median: impossible with eager (scalar) combine, fine with the
        // iterable reducer — the §III.D motivation in miniature.
        let input: Vec<u32> = vec![5, 1, 9, 3, 7];
        let feed = TaskFeed::new(&input, 2, 1, Scheduling::Static, None);
        let results = pool_run(2, |c| {
            let map = |i: &u32, emit: &mut dyn FnMut(u8, u32)| emit(0, *i);
            let reduce = |_k: &u8, vs: &mut dyn Iterator<Item = u32>| {
                let mut vs: Vec<u32> = vs.collect();
                vs.sort_unstable();
                vs[vs.len() / 2]
            };
            let tracker = PeakTracker::new();
            delayed_rank(c, &feed, &map, &reduce, 0, u64::MAX, &tracker).unwrap().0
        });
        let owner: Vec<_> = results.into_iter().filter(|m| !m.is_empty()).collect();
        assert_eq!(owner[0][&0u8], 5);
    }

    #[test]
    fn out_of_core_budget_matches_in_memory_run() {
        // The tentpole property at rank level: a budget of a few hundred
        // bytes must spill, stream, and still produce the in-memory
        // answer — with the value multiset intact.
        let input: Vec<u32> = (0..600).collect();
        let feed = TaskFeed::new(&input, 2, 1, Scheduling::Static, None);
        let run_with = |budget: u64| {
            pool_run(2, |c| {
                let map = |i: &u32, emit: &mut dyn FnMut(u32, u64)| {
                    emit(i % 16, (*i as u64) * 3)
                };
                let reduce = |_k: &u32, vs: &mut dyn Iterator<Item = u64>| {
                    let vs: Vec<u64> = vs.collect();
                    assert!(!vs.is_empty());
                    vs.into_iter().sum::<u64>()
                };
                let tracker = PeakTracker::new();
                delayed_rank(c, &feed, &map, &reduce, 0, budget, &tracker).unwrap()
            })
        };
        let in_mem = run_with(u64::MAX);
        let spilled = run_with(300);
        assert!(
            spilled.iter().map(|(_, s, _)| s).sum::<u64>() > 0,
            "tiny budget must hit disk"
        );
        let merge = |rs: &[(HashMap<u32, u64>, u64, u64)]| {
            let mut all: HashMap<u32, u64> = HashMap::new();
            for (shard, _, _) in rs {
                all.extend(shard.iter().map(|(k, v)| (*k, *v)));
            }
            all
        };
        assert_eq!(merge(&in_mem), merge(&spilled), "byte-identical grouped sums");
    }

    #[test]
    fn streaming_for_each_group_visits_every_group_once() {
        let input: Vec<u32> = (0..100).collect();
        let feed = TaskFeed::new(&input, 1, 1, Scheduling::Static, None);
        let visited = pool_run(1, |c| {
            let map = |i: &u32, emit: &mut dyn FnMut(u32, u32)| emit(i % 10, *i);
            let tracker = PeakTracker::new();
            let out =
                delayed_rank_groups(c, &feed, &map, 0, 256, &tracker).unwrap();
            let mut seen: Vec<(u32, usize)> = Vec::new();
            out.for_each_group(|k, vs| seen.push((*k, vs.count()))).unwrap();
            seen
        });
        assert_eq!(visited[0].len(), 10);
        assert!(visited[0].windows(2).all(|w| w[0].0 < w[1].0), "ascending keys");
        assert!(visited[0].iter().all(|(_, n)| *n == 10));
    }
}
