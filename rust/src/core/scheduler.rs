//! Task scheduling: how input chunks are handed to ranks.
//!
//! * [`Scheduling::Static`] — MPI-style even pre-split. Fast, but a skewed
//!   chunk makes a straggler (the "data skew" problem §I pins on Hadoop).
//! * [`Scheduling::Dynamic`] — ranks claim chunks from the shared
//!   [`FaultTracker`] table, which doubles as the Mariane-style completion
//!   table: kill a rank mid-job (fault injection) and survivors re-claim
//!   its reclaimed tasks at the next wave.

use std::ops::Range;

use crate::cluster::FaultTracker;
use crate::mpi::Rank;

use super::job::Scheduling;

/// Inject one task-level failure: `rank` stops claiming after completing
/// `after_tasks` tasks and its work is reassigned. (The wave-level
/// schedule of rank kills and slowdowns is [`crate::cluster::FaultPlan`].)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskFault {
    pub rank: Rank,
    pub after_tasks: usize,
}

/// Shared, thread-safe task table over a slice of input.
pub struct TaskFeed<'a, I> {
    input: &'a [I],
    ranges: Vec<Range<usize>>,
    scheduling: Scheduling,
    ranks: usize,
    tracker: FaultTracker,
    fault: Option<TaskFault>,
}

impl<'a, I> TaskFeed<'a, I> {
    pub fn new(
        input: &'a [I],
        ranks: usize,
        tasks_per_rank: usize,
        scheduling: Scheduling,
        fault: Option<TaskFault>,
    ) -> Self {
        let num_tasks = (ranks * tasks_per_rank.max(1)).max(1);
        let ranges = split_ranges(input.len(), num_tasks);
        let tracker = FaultTracker::new(ranges.len());
        Self { input, ranges, scheduling, ranks, tracker, fault }
    }

    pub fn num_tasks(&self) -> usize {
        self.ranges.len()
    }

    pub fn tracker(&self) -> &FaultTracker {
        &self.tracker
    }

    /// Per-rank claiming cursor.
    pub fn for_rank(&'a self, rank: Rank) -> RankFeed<'a, I> {
        RankFeed { feed: self, rank, static_cursor: rank.index(), claimed: 0 }
    }

    /// True when every task is Done (Dynamic) — Static mode has no global
    /// view, callers rely on rank completion instead.
    pub fn all_done(&self) -> bool {
        self.tracker.all_done()
    }
}

/// Split `len` items into `n` near-even contiguous ranges (empty ranges
/// trimmed).
fn split_ranges(len: usize, n: usize) -> Vec<Range<usize>> {
    let n = n.max(1);
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let size = base + usize::from(i < extra);
        if size == 0 {
            continue;
        }
        out.push(start..start + size);
        start += size;
    }
    out
}

/// One rank's view of the feed.
pub struct RankFeed<'a, I> {
    feed: &'a TaskFeed<'a, I>,
    rank: Rank,
    static_cursor: usize,
    claimed: usize,
}

impl<'a, I> RankFeed<'a, I> {
    /// Claim the next chunk, or `None` when this rank is done (or dead).
    /// Applies the fault plan: the doomed rank silently stops claiming
    /// after its quota and its running tasks return to the pool.
    pub fn next(&mut self) -> Option<(usize, &'a [I])> {
        if let Some(fault) = self.feed.fault {
            if fault.rank == self.rank && self.claimed >= fault.after_tasks {
                // Simulated death: reclaim anything still marked Running.
                self.feed.tracker.mark_rank_failed(self.rank);
                return None;
            }
        }
        let task = match self.feed.scheduling {
            Scheduling::Dynamic => self.feed.tracker.claim_next(self.rank)?,
            Scheduling::Static => {
                // Pure round-robin pre-assignment; the completion table is
                // only maintained in Dynamic mode (static MPI jobs have no
                // master to consult — that is exactly their weakness).
                let t = self.static_cursor;
                if t >= self.feed.ranges.len() {
                    return None;
                }
                self.static_cursor += self.feed.ranks;
                t
            }
        };
        self.claimed += 1;
        let range = self.feed.ranges[task].clone();
        Some((task, &self.feed.input[range]))
    }

    /// Mark a claimed task complete.
    pub fn complete(&self, task: usize) {
        self.feed.tracker.complete(task, self.rank);
    }

    pub fn claimed(&self) -> usize {
        self.claimed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_input_exactly() {
        let ranges = split_ranges(10, 3);
        assert_eq!(ranges, vec![0..4, 4..7, 7..10]);
        let ranges = split_ranges(2, 4);
        assert_eq!(ranges, vec![0..1, 1..2]);
        assert!(split_ranges(0, 4).is_empty());
    }

    #[test]
    fn dynamic_feed_hands_out_everything_once() {
        let input: Vec<u32> = (0..100).collect();
        let feed = TaskFeed::new(&input, 4, 4, Scheduling::Dynamic, None);
        let mut seen = vec![false; feed.num_tasks()];
        let mut total_items = 0;
        for r in 0..4 {
            let mut rf = feed.for_rank(Rank(r));
            while let Some((task, chunk)) = rf.next() {
                assert!(!seen[task], "task {task} claimed twice");
                seen[task] = true;
                total_items += chunk.len();
                rf.complete(task);
            }
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(total_items, 100);
        assert!(feed.all_done());
    }

    #[test]
    fn static_feed_is_round_robin() {
        let input: Vec<u32> = (0..8).collect();
        let feed = TaskFeed::new(&input, 2, 2, Scheduling::Static, None);
        let mut r0 = feed.for_rank(Rank(0));
        let tasks0: Vec<usize> = std::iter::from_fn(|| r0.next().map(|(t, _)| t)).collect();
        let mut r1 = feed.for_rank(Rank(1));
        let tasks1: Vec<usize> = std::iter::from_fn(|| r1.next().map(|(t, _)| t)).collect();
        assert_eq!(tasks0, vec![0, 2]);
        assert_eq!(tasks1, vec![1, 3]);
    }

    #[test]
    fn fault_plan_stops_claims_and_releases_tasks() {
        let input: Vec<u32> = (0..40).collect();
        let feed = TaskFeed::new(
            &input,
            2,
            4, // 8 tasks
            Scheduling::Dynamic,
            Some(TaskFault { rank: Rank(1), after_tasks: 1 }),
        );
        // Rank 1 claims one task, completes it, then dies.
        let mut r1 = feed.for_rank(Rank(1));
        let (t, _) = r1.next().unwrap();
        r1.complete(t);
        assert!(r1.next().is_none());
        // Rank 0 finishes everything else.
        let mut r0 = feed.for_rank(Rank(0));
        while let Some((task, _)) = r0.next() {
            r0.complete(task);
        }
        assert!(feed.all_done());
    }
}

// ============================================================================
// Concurrent multi-job scheduling — the admission layer above `RankPool`.
// ============================================================================
//
// Everything above this line schedules *tasks within one job*; everything
// below schedules *jobs onto one warm pool*. [`Scheduler`] accepts jobs
// from many client threads, queues them per tenant, admits them with
// deficit-round-robin fairness, and co-schedules jobs of different widths
// onto disjoint rank subsets — a 4-rank and a 12-rank job run
// simultaneously on a 16-rank pool. Per-job epochs (stamped by the pool)
// keep concurrent jobs' message planes disjoint; the scheduler's job is
// rank-subset reservation, queueing, fairness accounting, and completion
// notification via [`JobHandle`].

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::cluster::ClusterConfig;
use crate::metrics::Registry;
use crate::mpi::{Communicator, RankPool, TrafficDelta};
use crate::trace::SpanEvent;

/// Admission knobs, resolved like every other cluster knob (explicit
/// builder/TOML beats the `BLAZE_SCHED` env beats these defaults — see
/// [`ClusterConfig::resolve_scheduler`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulerConfig {
    /// Rank-units of deficit credited to a tenant per admission round.
    /// Bigger = burstier tenants; 1 = strict per-rank-unit round-robin.
    pub quantum: u64,
    /// Maximum jobs waiting across all tenants; submissions beyond it
    /// are rejected (admission control, not silent buffering).
    pub max_queue: usize,
    /// After this many admission rounds in which a queued head job was
    /// skipped because it didn't fit the free ranks, the scheduler
    /// freezes all admission until that job is placed — the
    /// no-starvation guarantee for wide jobs.
    pub starvation_rounds: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { quantum: 8, max_queue: 1024, starvation_rounds: 4 }
    }
}

impl SchedulerConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.quantum >= 1, "scheduler quantum must be >= 1");
        ensure!(self.max_queue >= 1, "scheduler max-queue must be >= 1");
        ensure!(self.starvation_rounds >= 1, "scheduler starvation-rounds must be >= 1");
        Ok(())
    }

    /// Parse the `BLAZE_SCHED` dialect:
    /// `quantum=8,max-queue=1024,starvation-rounds=4` (any subset of
    /// keys, any order; unknown keys are errors).
    pub fn parse(s: &str) -> Result<Self> {
        let mut cfg = Self::default();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("scheduler knob {part:?} is not key=value"))?;
            match key.trim() {
                "quantum" => cfg.quantum = value.trim().parse()?,
                "max-queue" => cfg.max_queue = value.trim().parse()?,
                "starvation-rounds" => cfg.starvation_rounds = value.trim().parse()?,
                other => bail!("unknown scheduler knob {other:?}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

impl std::fmt::Display for SchedulerConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "quantum={},max-queue={},starvation-rounds={}",
            self.quantum, self.max_queue, self.starvation_rounds
        )
    }
}

impl std::str::FromStr for SchedulerConfig {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
    }
}

/// What a scheduled job sees: its reserved rank subset on the shared
/// pool. [`JobCtx::run_spmd`] is the bread-and-butter entry — each call
/// is one SPMD wave over exactly the reserved ranks, with the traffic /
/// modeled-clock / trace harvest accumulated into the job's
/// [`SchedJobStats`].
pub struct JobCtx<'a> {
    pool: &'a RankPool,
    ranks: &'a [usize],
    harvest: RefCell<Harvest>,
}

#[derive(Default)]
struct Harvest {
    traffic: TrafficDelta,
    modeled_clock_ns: u64,
    spmd_waves: u64,
    trace: Vec<SpanEvent>,
}

impl<'a> JobCtx<'a> {
    /// Number of ranks reserved for this job.
    pub fn width(&self) -> usize {
        self.ranks.len()
    }

    /// The pool ranks reserved for this job (strictly ascending).
    pub fn ranks(&self) -> &[usize] {
        self.ranks
    }

    /// The shared pool, for placement-aware entry points
    /// ([`crate::core::MapReduceJob::with_placement`] and friends) that
    /// manage their own waves. Jobs that go through the pool directly
    /// must stay on [`JobCtx::ranks`].
    pub fn pool(&self) -> &'a RankPool {
        self.pool
    }

    /// Run one SPMD wave on the job's reserved ranks. The closure sees a
    /// fresh `width()`-rank universe (local ranks `0..width()`); results
    /// come back in local rank order. Traffic, the slowest rank's virtual
    /// clock, and recorded spans are folded into the job's stats.
    pub fn run_spmd<T, F>(&self, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(&Communicator) -> T + Sync,
    {
        let out = self.pool.try_run_job_on(self.ranks, f)?;
        let mut h = self.harvest.borrow_mut();
        h.traffic.messages += out.traffic.messages;
        h.traffic.bytes += out.traffic.bytes;
        h.traffic.remote_messages += out.traffic.remote_messages;
        h.traffic.remote_bytes += out.traffic.remote_bytes;
        h.modeled_clock_ns += out.clocks.iter().map(|c| c.0).max().unwrap_or(0);
        h.spmd_waves += 1;
        h.trace.extend(out.trace);
        Ok(out.results)
    }
}

/// Per-job accounting the scheduler attaches to every outcome — the
/// queue-wait / execution split is what the sustained-load bench gates on.
#[derive(Debug, Clone)]
pub struct SchedJobStats {
    /// Pool-unique job id (also the job's message epoch).
    pub job: u64,
    pub tenant: String,
    pub width: usize,
    /// Pool ranks the job ran on.
    pub ranks: Vec<usize>,
    /// Submission-to-start latency.
    pub queue_wait_ms: f64,
    /// Start-to-finish host wall time.
    pub exec_ms: f64,
    /// Sum over the job's `run_spmd` waves.
    pub traffic: TrafficDelta,
    /// Sum over waves of the slowest rank's virtual clock.
    pub modeled_clock_ns: u64,
    pub spmd_waves: u64,
    /// Spans harvested from the job's waves (empty when tracing is off).
    pub trace: Vec<SpanEvent>,
}

/// A finished job: the closure's result (or its panic/error) + stats.
/// Failures still carry stats, so latency accounting covers failed jobs.
#[derive(Debug)]
pub struct JobOutcome<R> {
    pub result: Result<R>,
    pub stats: SchedJobStats,
}

struct HandleInner<R> {
    slot: Mutex<Option<JobOutcome<R>>>,
    cv: Condvar,
}

/// Completion future for one submitted job. `wait()` blocks until the
/// scheduler has run the job; `is_done()` polls.
pub struct JobHandle<R> {
    id: u64,
    inner: Arc<HandleInner<R>>,
}

impl<R> JobHandle<R> {
    /// Pool-unique job id (also the job's message epoch).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Has the job finished (successfully or not)?
    pub fn is_done(&self) -> bool {
        self.inner.slot.lock().unwrap_or_else(|p| p.into_inner()).is_some()
    }

    /// Block until the job finishes; consumes the handle.
    pub fn wait(self) -> JobOutcome<R> {
        let mut slot = self.inner.slot.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(out) = slot.take() {
                return out;
            }
            slot = self.inner.cv.wait(slot).unwrap_or_else(|p| p.into_inner());
        }
    }
}

/// One admission/completion record — the scheduler's logical clock bumps
/// at every admission and completion, so two jobs overlapped in time iff
/// `a.admitted_at < b.completed_at && b.admitted_at < a.completed_at`.
#[derive(Debug, Clone)]
pub struct JobEvent {
    pub job: u64,
    pub tenant: String,
    pub width: usize,
    pub ranks: Vec<usize>,
    pub admitted_at: u64,
    pub completed_at: Option<u64>,
}

impl JobEvent {
    /// Were `self` and `other` in flight at the same time?
    pub fn overlaps(&self, other: &JobEvent) -> bool {
        match (self.completed_at, other.completed_at) {
            (Some(sc), Some(oc)) => self.admitted_at < oc && other.admitted_at < sc,
            // An unfinished job overlaps everything admitted before its
            // (future) completion.
            (None, Some(oc)) => self.admitted_at < oc,
            (Some(sc), None) => other.admitted_at < sc,
            (None, None) => true,
        }
    }
}

/// Per-tenant fairness accounting snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantStats {
    pub name: String,
    pub admitted_jobs: u64,
    /// Sum of admitted widths — the deficit-round-robin currency.
    pub admitted_rank_units: u64,
}

struct QueuedJob {
    id: u64,
    width: usize,
    /// Admission rounds in which this job sat at its tenant's head but
    /// didn't fit the free ranks (starvation detector).
    skips: u64,
    run: Box<dyn FnOnce(&RankPool, &[usize]) + Send>,
}

struct Tenant {
    name: String,
    deficit: u64,
    queue: VecDeque<QueuedJob>,
    admitted_jobs: u64,
    admitted_rank_units: u64,
}

struct State {
    tenants: Vec<Tenant>,
    /// Round-robin cursor over tenants.
    rr: usize,
    free: Vec<bool>,
    free_count: usize,
    queued: usize,
    active: usize,
    peak_active: usize,
    next_job: u64,
    shutdown: bool,
    /// Job id frozen for admission (see `starvation_rounds`).
    starving: Option<u64>,
    events: Vec<JobEvent>,
    /// Logical clock: bumped at every admission and completion.
    clock: u64,
}

struct Shared {
    pool: RankPool,
    cfg: SchedulerConfig,
    metrics: Arc<Registry>,
    state: Mutex<State>,
    /// Signalled on submit / completion / shutdown — dispatchers wait
    /// here for something to admit.
    work: Condvar,
    /// Signalled when the scheduler goes idle (nothing queued or active).
    idle: Condvar,
}

/// Admission layer above a warm [`RankPool`]: many client threads submit
/// jobs ([`Scheduler::submit`]) tagged with a tenant name; the scheduler
/// queues per tenant, admits with deficit-round-robin fairness, reserves
/// a disjoint rank subset per job (lowest free ranks), and runs admitted
/// jobs concurrently — each [`JobHandle`] resolves when its job is done.
///
/// Lock ordering: `state` is the outer lock, the metrics registry the
/// (leaf) inner one; nothing ever takes them in the other order.
pub struct Scheduler {
    shared: Arc<Shared>,
    dispatchers: Vec<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scheduler")
            .field("pool_size", &self.shared.pool.size())
            .field("cfg", &self.shared.cfg)
            .finish()
    }
}

impl Scheduler {
    /// Scheduler over `pool` with default knobs.
    pub fn new(pool: RankPool) -> Self {
        Self::with_config(pool, SchedulerConfig::default())
    }

    /// Scheduler over `pool` with explicit knobs.
    pub fn with_config(pool: RankPool, cfg: SchedulerConfig) -> Self {
        cfg.validate().expect("scheduler config");
        let n = pool.size();
        let shared = Arc::new(Shared {
            pool,
            cfg,
            metrics: Arc::new(Registry::new()),
            state: Mutex::new(State {
                tenants: Vec::new(),
                rr: 0,
                free: vec![true; n],
                free_count: n,
                queued: 0,
                active: 0,
                peak_active: 0,
                next_job: 0,
                shutdown: false,
                starving: None,
                events: Vec::new(),
                clock: 0,
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
        });
        // One dispatcher per rank: enough to keep the pool full even with
        // all-width-1 jobs; a dispatcher blocks only while its job runs.
        let dispatchers = (0..n)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("blaze-sched-{i}"))
                    .spawn(move || dispatcher_loop(shared))
                    .expect("spawn scheduler dispatcher")
            })
            .collect();
        Self { shared, dispatchers }
    }

    /// Scheduler wired like `cluster` prescribes: pool from the cluster,
    /// knobs from its resolved scheduler config (builder/TOML beats
    /// `BLAZE_SCHED` beats defaults).
    pub fn from_config(cluster: &ClusterConfig) -> Self {
        Self::with_config(RankPool::from_config(cluster), cluster.scheduler_config())
    }

    /// Submit one job for `tenant` needing `width` ranks. Returns
    /// immediately with a completion handle; errors if the width can
    /// never be placed or the queue is at `max_queue`.
    pub fn submit<R, F>(&self, tenant: &str, width: usize, job: F) -> Result<JobHandle<R>>
    where
        R: Send + 'static,
        F: FnOnce(&JobCtx<'_>) -> Result<R> + Send + 'static,
    {
        ensure!(width >= 1, "job width must be >= 1");
        ensure!(
            width <= self.shared.pool.size(),
            "job wants {width} ranks but the pool has {}",
            self.shared.pool.size()
        );
        let inner = Arc::new(HandleInner { slot: Mutex::new(None), cv: Condvar::new() });
        let mut st = self.shared.state.lock().unwrap_or_else(|p| p.into_inner());
        ensure!(!st.shutdown, "scheduler is shutting down");
        ensure!(
            st.queued < self.shared.cfg.max_queue,
            "scheduler queue full ({} jobs waiting)",
            st.queued
        );
        st.next_job += 1;
        let id = st.next_job;
        let handle = JobHandle { id, inner: inner.clone() };
        let tenant_name = tenant.to_string();
        let metrics = self.shared.metrics.clone();
        let submitted = Instant::now();
        let run = Box::new(move |pool: &RankPool, ranks: &[usize]| {
            let queue_wait_ms = submitted.elapsed().as_secs_f64() * 1e3;
            let started = Instant::now();
            let ctx = JobCtx { pool, ranks, harvest: RefCell::new(Harvest::default()) };
            let result = match catch_unwind(AssertUnwindSafe(|| job(&ctx))) {
                Ok(r) => r,
                Err(payload) => {
                    Err(anyhow::anyhow!("job panicked: {}", sched_panic_message(&*payload)))
                }
            };
            let exec_ms = started.elapsed().as_secs_f64() * 1e3;
            let harvest = ctx.harvest.into_inner();
            metrics.observe("sched.queue_wait_ms", queue_wait_ms.round() as u64);
            metrics.observe("sched.exec_ms", exec_ms.round() as u64);
            let stats = SchedJobStats {
                job: id,
                tenant: tenant_name,
                width: ranks.len(),
                ranks: ranks.to_vec(),
                queue_wait_ms,
                exec_ms,
                traffic: harvest.traffic,
                modeled_clock_ns: harvest.modeled_clock_ns,
                spmd_waves: harvest.spmd_waves,
                trace: harvest.trace,
            };
            let mut slot = inner.slot.lock().unwrap_or_else(|p| p.into_inner());
            *slot = Some(JobOutcome { result, stats });
            drop(slot);
            inner.cv.notify_all();
        });
        let ti = tenant_index(&mut st, tenant);
        st.tenants[ti].queue.push_back(QueuedJob { id, width, skips: 0, run });
        st.queued += 1;
        drop(st);
        self.shared.work.notify_all();
        Ok(handle)
    }

    /// Block until nothing is queued or running.
    pub fn drain(&self) {
        let mut st = self.shared.state.lock().unwrap_or_else(|p| p.into_inner());
        while st.queued > 0 || st.active > 0 {
            st = self.shared.idle.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// The scheduler's metrics: `sched.active_jobs` / `sched.occupied_ranks`
    /// gauges, `sched.admitted` / `sched.completed` counters,
    /// `sched.queue_wait_ms` / `sched.exec_ms` histograms.
    pub fn metrics(&self) -> &Registry {
        &self.shared.metrics
    }

    /// Admission/completion history (see [`JobEvent::overlaps`]).
    pub fn events(&self) -> Vec<JobEvent> {
        self.shared.state.lock().unwrap_or_else(|p| p.into_inner()).events.clone()
    }

    /// Per-tenant fairness accounting, in first-submission order.
    pub fn tenant_stats(&self) -> Vec<TenantStats> {
        let st = self.shared.state.lock().unwrap_or_else(|p| p.into_inner());
        st.tenants
            .iter()
            .map(|t| TenantStats {
                name: t.name.clone(),
                admitted_jobs: t.admitted_jobs,
                admitted_rank_units: t.admitted_rank_units,
            })
            .collect()
    }

    /// Most jobs ever in flight simultaneously.
    pub fn peak_concurrent_jobs(&self) -> usize {
        self.shared.state.lock().unwrap_or_else(|p| p.into_inner()).peak_active
    }

    /// Jobs currently running.
    pub fn active_jobs(&self) -> usize {
        self.shared.state.lock().unwrap_or_else(|p| p.into_inner()).active
    }

    /// Jobs waiting for admission.
    pub fn queued_jobs(&self) -> usize {
        self.shared.state.lock().unwrap_or_else(|p| p.into_inner()).queued
    }

    pub fn pool(&self) -> &RankPool {
        &self.shared.pool
    }

    pub fn pool_size(&self) -> usize {
        self.shared.pool.size()
    }

    pub fn config(&self) -> SchedulerConfig {
        self.shared.cfg
    }
}

impl Drop for Scheduler {
    /// Graceful drain: queued jobs still run (every width eventually
    /// fits an emptying pool), their handles resolve, then dispatchers
    /// exit and the pool shuts down.
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|p| p.into_inner());
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for d in self.dispatchers.drain(..) {
            let _ = d.join();
        }
    }
}

fn tenant_index(st: &mut State, name: &str) -> usize {
    if let Some(i) = st.tenants.iter().position(|t| t.name == name) {
        return i;
    }
    st.tenants.push(Tenant {
        name: name.to_string(),
        deficit: 0,
        queue: VecDeque::new(),
        admitted_jobs: 0,
        admitted_rank_units: 0,
    });
    st.tenants.len() - 1
}

/// Reserve the `width` lowest free ranks.
fn take_ranks(st: &mut State, width: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(width);
    for r in 0..st.free.len() {
        if st.free[r] {
            st.free[r] = false;
            out.push(r);
            if out.len() == width {
                break;
            }
        }
    }
    debug_assert_eq!(out.len(), width, "free_count out of sync");
    st.free_count -= width;
    out
}

/// Admission bookkeeping for a job popped off tenant `ti`'s queue.
fn admit(shared: &Shared, st: &mut State, job: QueuedJob, ti: usize) -> (QueuedJob, Vec<usize>) {
    let ranks = take_ranks(st, job.width);
    st.queued -= 1;
    st.active += 1;
    st.peak_active = st.peak_active.max(st.active);
    st.clock += 1;
    let admitted_at = st.clock;
    let tenant = st.tenants[ti].name.clone();
    st.events.push(JobEvent {
        job: job.id,
        tenant,
        width: job.width,
        ranks: ranks.clone(),
        admitted_at,
        completed_at: None,
    });
    shared.metrics.counter_add("sched.admitted", 1);
    shared.metrics.gauge_set("sched.active_jobs", st.active as f64);
    shared.metrics.gauge_set("sched.occupied_ranks", (st.free.len() - st.free_count) as f64);
    (job, ranks)
}

/// Pick the next admissible job, or `None` when nothing can be admitted
/// right now (dispatcher then waits on the `work` condvar — a completion
/// or submission re-wakes it).
fn pick(shared: &Shared, st: &mut State) -> Option<(QueuedJob, Vec<usize>)> {
    let cfg = shared.cfg;
    // Starvation freeze: once a head job has been skipped
    // `starvation_rounds` times, nothing else is admitted until it fits —
    // running jobs finish, ranks free up, and the starving job lands.
    if let Some(sid) = st.starving {
        let found = st
            .tenants
            .iter()
            .position(|t| t.queue.front().map(|j| j.id) == Some(sid));
        match found {
            Some(ti) if st.tenants[ti].queue.front().unwrap().width <= st.free_count => {
                let job = st.tenants[ti].queue.pop_front().unwrap();
                let t = &mut st.tenants[ti];
                t.deficit = t.deficit.saturating_sub(job.width as u64);
                t.admitted_jobs += 1;
                t.admitted_rank_units += job.width as u64;
                st.starving = None;
                st.rr = (ti + 1) % st.tenants.len();
                return Some(admit(shared, st, job, ti));
            }
            Some(_) => return None,
            None => st.starving = None, // stale (job gone) — fall through
        }
    }
    let nt = st.tenants.len();
    if nt == 0 {
        return None;
    }
    // Deficit round-robin. The outer loop re-credits quanta until either
    // a head is admitted or no head fits the free ranks at all; the cap
    // (>= pool width) guarantees affordability is always reachable, so
    // this terminates.
    let cap = cfg.quantum.saturating_mul(4).max(st.free.len() as u64);
    loop {
        let mut any_fits = false;
        for k in 0..nt {
            let ti = (st.rr + k) % nt;
            let free = st.free_count as u64;
            let t = &mut st.tenants[ti];
            let Some(head_width) = t.queue.front().map(|j| j.width as u64) else {
                continue;
            };
            t.deficit = (t.deficit + cfg.quantum).min(cap);
            if head_width <= free {
                any_fits = true;
                if head_width <= t.deficit {
                    t.deficit -= head_width;
                    t.admitted_jobs += 1;
                    t.admitted_rank_units += head_width;
                    let job = t.queue.pop_front().unwrap();
                    st.rr = (ti + 1) % nt;
                    return Some(admit(shared, st, job, ti));
                }
            } else {
                let head = t.queue.front_mut().unwrap();
                head.skips += 1;
                if head.skips >= cfg.starvation_rounds {
                    st.starving = Some(head.id);
                    return None;
                }
            }
        }
        if !any_fits {
            return None;
        }
    }
}

fn dispatcher_loop(shared: Arc<Shared>) {
    loop {
        let picked = {
            let mut st = shared.state.lock().unwrap_or_else(|p| p.into_inner());
            loop {
                if let Some(admitted) = pick(&shared, &mut st) {
                    break Some(admitted);
                }
                if st.shutdown && st.queued == 0 {
                    break None;
                }
                st = shared.work.wait(st).unwrap_or_else(|p| p.into_inner());
            }
        };
        let Some((job, ranks)) = picked else {
            return;
        };
        let QueuedJob { id, run, .. } = job;
        run(&shared.pool, &ranks);
        let mut st = shared.state.lock().unwrap_or_else(|p| p.into_inner());
        for &r in &ranks {
            debug_assert!(!st.free[r], "completing job frees a rank it never held");
            st.free[r] = true;
        }
        st.free_count += ranks.len();
        st.active -= 1;
        st.clock += 1;
        let completed_at = st.clock;
        if let Some(ev) = st.events.iter_mut().rev().find(|e| e.job == id) {
            ev.completed_at = Some(completed_at);
        }
        shared.metrics.counter_add("sched.completed", 1);
        shared.metrics.gauge_set("sched.active_jobs", st.active as f64);
        shared.metrics.gauge_set("sched.occupied_ranks", (st.free.len() - st.free_count) as f64);
        let idle = st.queued == 0 && st.active == 0;
        drop(st);
        if idle {
            shared.idle.notify_all();
        }
        shared.work.notify_all();
    }
}

fn sched_panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

#[cfg(test)]
mod sched_tests {
    use super::*;
    use crate::mpi::RankPool;

    #[test]
    fn sched_config_parse_roundtrip() {
        let cfg = SchedulerConfig { quantum: 3, max_queue: 9, starvation_rounds: 2 };
        let back: SchedulerConfig = cfg.to_string().parse().unwrap();
        assert_eq!(back, cfg);
        let partial = SchedulerConfig::parse("quantum=5").unwrap();
        assert_eq!(partial.quantum, 5);
        assert_eq!(partial.max_queue, SchedulerConfig::default().max_queue);
        assert!(SchedulerConfig::parse("wat=1").is_err());
        assert!(SchedulerConfig::parse("quantum=0").is_err());
        assert!(SchedulerConfig::parse("quantum").is_err());
    }

    #[test]
    fn submit_wait_roundtrip_with_spmd_wave() {
        let sched = Scheduler::new(RankPool::local(4));
        let h = sched
            .submit("t0", 2, |ctx| {
                let sums = ctx.run_spmd(|c| c.allreduce_sum_u64(1).unwrap())?;
                Ok(sums)
            })
            .unwrap();
        let out = h.wait();
        assert_eq!(out.result.unwrap(), vec![2, 2]);
        assert_eq!(out.stats.width, 2);
        assert_eq!(out.stats.ranks.len(), 2);
        assert_eq!(out.stats.spmd_waves, 1);
        assert!(out.stats.traffic.messages > 0);
        assert!(out.stats.queue_wait_ms >= 0.0);
        assert_eq!(sched.metrics().counter("sched.admitted"), 1);
        assert_eq!(sched.metrics().counter("sched.completed"), 1);
    }

    #[test]
    fn width_and_queue_validation() {
        let sched = Scheduler::new(RankPool::local(2));
        assert!(sched.submit::<(), _>("t", 0, |_| Ok(())).is_err());
        assert!(sched.submit::<(), _>("t", 3, |_| Ok(())).is_err());
    }

    #[test]
    fn job_panic_is_an_err_outcome_and_scheduler_survives() {
        let sched = Scheduler::new(RankPool::local(2));
        let h = sched
            .submit::<(), _>("t", 1, |_| panic!("kaboom"))
            .unwrap();
        let out = h.wait();
        let msg = format!("{:#}", out.result.unwrap_err());
        assert!(msg.contains("kaboom"), "{msg}");
        assert_eq!(out.stats.width, 1);
        // Scheduler keeps serving.
        let h2 = sched.submit("t", 2, |ctx| ctx.run_spmd(|c| c.rank().0)).unwrap();
        assert_eq!(h2.wait().result.unwrap(), vec![0, 1]);
    }

    #[test]
    fn queue_overflow_is_rejected() {
        let cfg = SchedulerConfig { max_queue: 1, ..Default::default() };
        let sched = Scheduler::with_config(RankPool::local(1), cfg);
        // Occupy the single rank so later submissions stay queued.
        let gate = Arc::new(Mutex::new(()));
        let held = gate.lock().unwrap();
        let blocker = {
            let gate = gate.clone();
            sched
                .submit("t", 1, move |_| {
                    drop(gate.lock().unwrap_or_else(|p| p.into_inner()));
                    Ok(())
                })
                .unwrap()
        };
        // Wait until the blocker is running (not queued).
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while sched.active_jobs() == 0 {
            assert!(Instant::now() < deadline, "blocker never admitted");
            std::thread::yield_now();
        }
        let queued = sched.submit("t", 1, |_| Ok(())).unwrap();
        let overflow = sched.submit::<(), _>("t", 1, |_| Ok(()));
        assert!(overflow.is_err(), "third job must bounce off max_queue=1");
        drop(held);
        assert!(blocker.wait().result.is_ok());
        assert!(queued.wait().result.is_ok());
    }

    #[test]
    fn drain_waits_for_everything() {
        let sched = Scheduler::new(RankPool::local(4));
        let handles: Vec<_> = (0..10)
            .map(|i| {
                sched
                    .submit(if i % 2 == 0 { "a" } else { "b" }, 1 + i % 3, move |ctx| {
                        ctx.run_spmd(|c| c.allreduce_sum_u64(1).unwrap()).map(|v| v[0])
                    })
                    .unwrap()
            })
            .collect();
        sched.drain();
        assert_eq!(sched.active_jobs(), 0);
        assert_eq!(sched.queued_jobs(), 0);
        for h in handles {
            assert!(h.wait().result.is_ok());
        }
        let by_tenant = sched.tenant_stats();
        assert_eq!(by_tenant.len(), 2);
        assert_eq!(by_tenant.iter().map(|t| t.admitted_jobs).sum::<u64>(), 10);
    }

    #[test]
    fn disjoint_widths_overlap_in_time() {
        let sched = Scheduler::new(RankPool::local(4));
        // Two 2-rank jobs that each wait for the other: completes only if
        // the scheduler really co-schedules them.
        let (a_tx, a_rx) = std::sync::mpsc::channel::<()>();
        let (b_tx, b_rx) = std::sync::mpsc::channel::<()>();
        let timeout = std::time::Duration::from_secs(10);
        let ha = sched
            .submit("a", 2, move |ctx| {
                ctx.run_spmd(|c| {
                    if c.is_root() {
                        a_tx.send(()).unwrap();
                    }
                })?;
                b_rx.recv_timeout(timeout)?;
                Ok(())
            })
            .unwrap();
        let hb = sched
            .submit("b", 2, move |ctx| {
                ctx.run_spmd(|c| {
                    if c.is_root() {
                        b_tx.send(()).unwrap();
                    }
                })?;
                a_rx.recv_timeout(timeout)?;
                Ok(())
            })
            .unwrap();
        assert!(ha.wait().result.is_ok());
        assert!(hb.wait().result.is_ok());
        assert_eq!(sched.peak_concurrent_jobs(), 2);
        let events = sched.events();
        assert_eq!(events.len(), 2);
        assert!(events[0].overlaps(&events[1]));
        // Disjoint rank reservations.
        assert!(events[0].ranks.iter().all(|r| !events[1].ranks.contains(r)));
    }

    #[test]
    fn drop_drains_queued_jobs() {
        let handles: Vec<_> = {
            let sched = Scheduler::new(RankPool::local(2));
            (0..6)
                .map(|_| {
                    sched
                        .submit("t", 1, |ctx| ctx.run_spmd(|c| c.rank().0).map(|v| v[0]))
                        .unwrap()
                })
                .collect()
            // Scheduler drops here with jobs possibly still queued.
        };
        for h in handles {
            assert_eq!(h.wait().result.unwrap(), 0);
        }
    }
}
