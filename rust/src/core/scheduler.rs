//! Task scheduling: how input chunks are handed to ranks.
//!
//! * [`Scheduling::Static`] — MPI-style even pre-split. Fast, but a skewed
//!   chunk makes a straggler (the "data skew" problem §I pins on Hadoop).
//! * [`Scheduling::Dynamic`] — ranks claim chunks from the shared
//!   [`FaultTracker`] table, which doubles as the Mariane-style completion
//!   table: kill a rank mid-job (fault injection) and survivors re-claim
//!   its reclaimed tasks at the next wave.

use std::ops::Range;

use crate::cluster::FaultTracker;
use crate::mpi::Rank;

use super::job::Scheduling;

/// Inject one task-level failure: `rank` stops claiming after completing
/// `after_tasks` tasks and its work is reassigned. (The wave-level
/// schedule of rank kills and slowdowns is [`crate::cluster::FaultPlan`].)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskFault {
    pub rank: Rank,
    pub after_tasks: usize,
}

/// Shared, thread-safe task table over a slice of input.
pub struct TaskFeed<'a, I> {
    input: &'a [I],
    ranges: Vec<Range<usize>>,
    scheduling: Scheduling,
    ranks: usize,
    tracker: FaultTracker,
    fault: Option<TaskFault>,
}

impl<'a, I> TaskFeed<'a, I> {
    pub fn new(
        input: &'a [I],
        ranks: usize,
        tasks_per_rank: usize,
        scheduling: Scheduling,
        fault: Option<TaskFault>,
    ) -> Self {
        let num_tasks = (ranks * tasks_per_rank.max(1)).max(1);
        let ranges = split_ranges(input.len(), num_tasks);
        let tracker = FaultTracker::new(ranges.len());
        Self { input, ranges, scheduling, ranks, tracker, fault }
    }

    pub fn num_tasks(&self) -> usize {
        self.ranges.len()
    }

    pub fn tracker(&self) -> &FaultTracker {
        &self.tracker
    }

    /// Per-rank claiming cursor.
    pub fn for_rank(&'a self, rank: Rank) -> RankFeed<'a, I> {
        RankFeed { feed: self, rank, static_cursor: rank.index(), claimed: 0 }
    }

    /// True when every task is Done (Dynamic) — Static mode has no global
    /// view, callers rely on rank completion instead.
    pub fn all_done(&self) -> bool {
        self.tracker.all_done()
    }
}

/// Split `len` items into `n` near-even contiguous ranges (empty ranges
/// trimmed).
fn split_ranges(len: usize, n: usize) -> Vec<Range<usize>> {
    let n = n.max(1);
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let size = base + usize::from(i < extra);
        if size == 0 {
            continue;
        }
        out.push(start..start + size);
        start += size;
    }
    out
}

/// One rank's view of the feed.
pub struct RankFeed<'a, I> {
    feed: &'a TaskFeed<'a, I>,
    rank: Rank,
    static_cursor: usize,
    claimed: usize,
}

impl<'a, I> RankFeed<'a, I> {
    /// Claim the next chunk, or `None` when this rank is done (or dead).
    /// Applies the fault plan: the doomed rank silently stops claiming
    /// after its quota and its running tasks return to the pool.
    pub fn next(&mut self) -> Option<(usize, &'a [I])> {
        if let Some(fault) = self.feed.fault {
            if fault.rank == self.rank && self.claimed >= fault.after_tasks {
                // Simulated death: reclaim anything still marked Running.
                self.feed.tracker.mark_rank_failed(self.rank);
                return None;
            }
        }
        let task = match self.feed.scheduling {
            Scheduling::Dynamic => self.feed.tracker.claim_next(self.rank)?,
            Scheduling::Static => {
                // Pure round-robin pre-assignment; the completion table is
                // only maintained in Dynamic mode (static MPI jobs have no
                // master to consult — that is exactly their weakness).
                let t = self.static_cursor;
                if t >= self.feed.ranges.len() {
                    return None;
                }
                self.static_cursor += self.feed.ranks;
                t
            }
        };
        self.claimed += 1;
        let range = self.feed.ranges[task].clone();
        Some((task, &self.feed.input[range]))
    }

    /// Mark a claimed task complete.
    pub fn complete(&self, task: usize) {
        self.feed.tracker.complete(task, self.rank);
    }

    pub fn claimed(&self) -> usize {
        self.claimed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_input_exactly() {
        let ranges = split_ranges(10, 3);
        assert_eq!(ranges, vec![0..4, 4..7, 7..10]);
        let ranges = split_ranges(2, 4);
        assert_eq!(ranges, vec![0..1, 1..2]);
        assert!(split_ranges(0, 4).is_empty());
    }

    #[test]
    fn dynamic_feed_hands_out_everything_once() {
        let input: Vec<u32> = (0..100).collect();
        let feed = TaskFeed::new(&input, 4, 4, Scheduling::Dynamic, None);
        let mut seen = vec![false; feed.num_tasks()];
        let mut total_items = 0;
        for r in 0..4 {
            let mut rf = feed.for_rank(Rank(r));
            while let Some((task, chunk)) = rf.next() {
                assert!(!seen[task], "task {task} claimed twice");
                seen[task] = true;
                total_items += chunk.len();
                rf.complete(task);
            }
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(total_items, 100);
        assert!(feed.all_done());
    }

    #[test]
    fn static_feed_is_round_robin() {
        let input: Vec<u32> = (0..8).collect();
        let feed = TaskFeed::new(&input, 2, 2, Scheduling::Static, None);
        let mut r0 = feed.for_rank(Rank(0));
        let tasks0: Vec<usize> = std::iter::from_fn(|| r0.next().map(|(t, _)| t)).collect();
        let mut r1 = feed.for_rank(Rank(1));
        let tasks1: Vec<usize> = std::iter::from_fn(|| r1.next().map(|(t, _)| t)).collect();
        assert_eq!(tasks0, vec![0, 2]);
        assert_eq!(tasks1, vec![1, 3]);
    }

    #[test]
    fn fault_plan_stops_claims_and_releases_tasks() {
        let input: Vec<u32> = (0..40).collect();
        let feed = TaskFeed::new(
            &input,
            2,
            4, // 8 tasks
            Scheduling::Dynamic,
            Some(TaskFault { rank: Rank(1), after_tasks: 1 }),
        );
        // Rank 1 claims one task, completes it, then dies.
        let mut r1 = feed.for_rank(Rank(1));
        let (t, _) = r1.next().unwrap();
        r1.complete(t);
        assert!(r1.next().is_none());
        // Rank 0 finishes everything else.
        let mut r0 = feed.for_rank(Rank(0));
        while let Some((task, _)) = r0.next() {
            r0.complete(task);
        }
        assert!(feed.all_done());
    }
}
