//! Classic MapReduce — the Hadoop pattern the paper's Fig 1 shows:
//! map everything, shuffle *every* pair, group by key on the reducer,
//! reduce. The baseline both Blaze modes are measured against, and the
//! mode whose raw-pair shuffle volume makes Fig 10's small-key-range
//! wordcount anti-scale.
//!
//! Map output rides a [`SpillBuffer`]: past the node memory budget pairs
//! go to disk (MR-MPI's out-of-core pages).

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;

use anyhow::Result;

use crate::dist::ShardRouter;
use crate::metrics::PeakTracker;
use crate::mpi::Communicator;
use crate::serial::FastSerialize;

use super::scheduler::TaskFeed;
use super::shuffle::{shuffle_pairs, SpillBuffer};

/// SPMD rank body for one classic job. Returns (result shard, spilled
/// bytes). `reduce` sees the full value multiset per key.
pub fn classic_rank<I, K, V, M, R>(
    comm: &Communicator,
    feed: &TaskFeed<'_, I>,
    map: &M,
    reduce: &R,
    salt: u64,
    spill_threshold: u64,
    tracker: &Arc<PeakTracker>,
) -> Result<(HashMap<K, V>, u64)>
where
    I: Sync,
    K: FastSerialize + Hash + Eq + Send,
    V: FastSerialize + Send,
    M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
    R: Fn(&K, Vec<V>) -> V + Sync,
{
    // Map phase: every pair is kept (possibly spilled), none combined.
    let mut buffer: SpillBuffer<K, V> = SpillBuffer::new(spill_threshold, tracker.clone());
    let mut rank_feed = feed.for_rank(comm.rank());
    while let Some((task, chunk)) = rank_feed.next() {
        let res: Result<()> = comm.timed(|| {
            let mut err = None;
            for item in chunk {
                map(item, &mut |k, v| {
                    if err.is_none() {
                        if let Err(e) = buffer.push(k, v) {
                            err = Some(e);
                        }
                    }
                });
            }
            err.map_or(Ok(()), Err)
        });
        res?;
        rank_feed.complete(task);
    }

    let spilled = buffer.spilled_bytes();
    let pairs = comm.timed(|| buffer.drain())?;

    // Shuffle every raw pair.
    let router = ShardRouter::new(comm.size(), salt);
    let mine = shuffle_pairs(comm, &router, pairs, tracker)?;

    // Group + reduce on the owner.
    let out = comm.timed(|| {
        let mut groups: HashMap<K, Vec<V>> = HashMap::with_capacity(mine.len() / 2 + 1);
        for (k, v) in mine {
            groups.entry(k).or_default().push(v);
        }
        let group_bytes: u64 = groups
            .iter()
            .map(|(k, vs)| {
                (k.size_hint() + vs.iter().map(FastSerialize::size_hint).sum::<usize>() + 32)
                    as u64
            })
            .sum();
        tracker.alloc(group_bytes);
        let mut out = HashMap::with_capacity(groups.len());
        for (k, vs) in groups {
            let reduced = reduce(&k, vs);
            out.insert(k, reduced);
        }
        tracker.free(group_bytes);
        out
    });
    let out_bytes: u64 =
        out.iter().map(|(k, v)| (k.size_hint() + v.size_hint() + 16) as u64).sum();
    tracker.alloc(out_bytes);
    Ok((out, spilled))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::Scheduling;
    use crate::util::testpool::pool_run;

    #[test]
    fn classic_wordcount_matches_truth() {
        let input: Vec<String> =
            ["x y x", "y z y", "x"].iter().map(|s| s.to_string()).collect();
        let feed = TaskFeed::new(&input, 3, 1, Scheduling::Static, None);
        let results = pool_run(3, |c| {
            let map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
                for w in line.split_whitespace() {
                    emit(w.to_string(), 1);
                }
            };
            let reduce = |_k: &String, vs: Vec<u64>| vs.into_iter().sum::<u64>();
            let tracker = PeakTracker::new();
            classic_rank(c, &feed, &map, &reduce, 0, u64::MAX, &tracker).unwrap().0
        });
        let mut merged: HashMap<String, u64> = HashMap::new();
        for shard in results {
            merged.extend(shard);
        }
        assert_eq!(merged[&"x".to_string()], 3);
        assert_eq!(merged[&"y".to_string()], 3);
        assert_eq!(merged[&"z".to_string()], 1);
    }

    #[test]
    fn classic_reduce_sees_full_multiset() {
        let input: Vec<u32> = (0..10).collect();
        let feed = TaskFeed::new(&input, 2, 1, Scheduling::Static, None);
        let results = pool_run(2, |c| {
            // All items map to one key; reducer asserts it sees all 10.
            let map = |i: &u32, emit: &mut dyn FnMut(u8, u32)| emit(0u8, *i);
            let reduce = |_k: &u8, vs: Vec<u32>| {
                assert_eq!(vs.len(), 10);
                vs.into_iter().max().unwrap()
            };
            let tracker = PeakTracker::new();
            classic_rank(c, &feed, &map, &reduce, 0, u64::MAX, &tracker).unwrap().0
        });
        let owner_shard: Vec<_> = results.into_iter().filter(|m| !m.is_empty()).collect();
        assert_eq!(owner_shard.len(), 1);
        assert_eq!(owner_shard[0][&0u8], 9);
    }

    #[test]
    fn classic_with_tiny_spill_threshold_still_correct() {
        let input: Vec<String> = (0..50).map(|i| format!("w{} w{}", i % 5, i % 3)).collect();
        let feed = TaskFeed::new(&input, 2, 2, Scheduling::Static, None);
        let results = pool_run(2, |c| {
            let map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
                for w in line.split_whitespace() {
                    emit(w.to_string(), 1);
                }
            };
            let reduce = |_k: &String, vs: Vec<u64>| vs.into_iter().sum::<u64>();
            let tracker = PeakTracker::new();
            classic_rank(c, &feed, &map, &reduce, 0, 128, &tracker).unwrap()
        });
        let spilled: u64 = results.iter().map(|(_, s)| s).sum();
        assert!(spilled > 0, "tiny threshold must force spilling");
        let mut merged: HashMap<String, u64> = HashMap::new();
        for (shard, _) in results {
            merged.extend(shard);
        }
        let total: u64 = merged.values().sum();
        assert_eq!(total, 100, "50 lines x 2 words");
    }
}
