//! Classic MapReduce — the Hadoop pattern the paper's Fig 1 shows:
//! map everything, shuffle *every* pair, group by key on the reducer,
//! reduce. The baseline both Blaze modes are measured against, and the
//! mode whose raw-pair shuffle volume makes Fig 10's small-key-range
//! wordcount anti-scale.
//!
//! Map output stages into [`crate::store::RunWriter`] sorted runs: past
//! the node memory budget pairs go to disk (MR-MPI's out-of-core
//! pages, now key-ordered), the shuffle runs in budget-bounded rounds,
//! and the reducer streams `(K, Iterable<V>)` groups off a loser-tree
//! merge — the whole pipeline is bounded by the budget, not the input.
//!
//! An optional **map-side combiner** (Hadoop's) folds equal-key values
//! at run-write and merge time before the wire; without one, every raw
//! pair still crosses the network, preserving the Fig 10 baseline.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;

use anyhow::Result;

use crate::dist::ShardRouter;
use crate::metrics::PeakTracker;
use crate::mpi::Communicator;
use crate::serial::FastSerialize;
use crate::store::{Combiner, GroupStream, GroupValues, RunWriter};

use super::scheduler::TaskFeed;
use super::shuffle::{shuffle_runs, stage_sorted_runs};

/// SPMD rank body for one classic job. Returns (result shard, spilled
/// bytes, combiner-folded bytes). `reduce` sees the full value multiset
/// per key as a **lazy iterator** straight off the merge — no group is
/// materialized unless the reducer collects it (partially pre-folded
/// when a combiner is supplied — Hadoop's combiner contract).
#[allow(clippy::too_many_arguments)]
pub fn classic_rank<I, K, V, M, R>(
    comm: &Communicator,
    feed: &TaskFeed<'_, I>,
    map: &M,
    reduce: &R,
    combiner: Option<Combiner<'_, V>>,
    salt: u64,
    spill_threshold: u64,
    tracker: &Arc<PeakTracker>,
) -> Result<(HashMap<K, V>, u64, u64)>
where
    I: Sync,
    K: FastSerialize + Hash + Eq + Ord + Send,
    V: FastSerialize + Send,
    M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
    R: Fn(&K, &mut dyn Iterator<Item = V>) -> V + Sync,
{
    // Map phase: every pair staged (possibly spilled as a sorted run);
    // the combiner, when present, folds equal keys at run-write time.
    let mut writer: RunWriter<'_, K, V> = RunWriter::new(spill_threshold, tracker.clone());
    if let Some(c) = combiner {
        writer = writer.with_combiner(c);
    }
    let local_runs = stage_sorted_runs(comm, feed, map, writer)?;
    let map_spilled = local_runs.spilled_bytes();
    let write_combined = local_runs.combined_bytes();

    // Shuffle the runs in budget-bounded rounds (combiner also folds
    // across this rank's runs at merge time, pre-wire).
    let router = ShardRouter::new(comm.size(), salt);
    let (incoming, merge_combined) =
        shuffle_runs(comm, &router, local_runs, spill_threshold, combiner, tracker)?;
    let spilled = map_spilled + incoming.spilled_bytes();
    let combined = write_combined + merge_combined;

    // Group + reduce on the owner, streaming one group at a time.
    let reduce_span = crate::trace::span(crate::trace::SpanKind::Reduce);
    let out = comm.timed(|| -> Result<HashMap<K, V>> {
        let mut stream = GroupStream::new(incoming.into_merge()?);
        let mut out = HashMap::new();
        while let Some((key, first)) = stream.begin_group()? {
            let mut vals = GroupValues::new(&mut stream, &key, first);
            let reduced = reduce(&key, &mut vals);
            vals.finish()?;
            out.insert(key, reduced);
        }
        Ok(out)
    })?;
    drop(reduce_span);
    let out_bytes: u64 =
        out.iter().map(|(k, v)| (k.size_hint() + v.size_hint() + 16) as u64).sum();
    tracker.alloc(out_bytes);
    Ok((out, spilled, combined))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::Scheduling;
    use crate::util::testpool::pool_run;

    #[test]
    fn classic_wordcount_matches_truth() {
        let input: Vec<String> =
            ["x y x", "y z y", "x"].iter().map(|s| s.to_string()).collect();
        let feed = TaskFeed::new(&input, 3, 1, Scheduling::Static, None);
        let results = pool_run(3, |c| {
            let map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
                for w in line.split_whitespace() {
                    emit(w.to_string(), 1);
                }
            };
            let reduce =
                |_k: &String, vs: &mut dyn Iterator<Item = u64>| vs.sum::<u64>();
            let tracker = PeakTracker::new();
            classic_rank(c, &feed, &map, &reduce, None, 0, u64::MAX, &tracker).unwrap().0
        });
        let mut merged: HashMap<String, u64> = HashMap::new();
        for shard in results {
            merged.extend(shard);
        }
        assert_eq!(merged[&"x".to_string()], 3);
        assert_eq!(merged[&"y".to_string()], 3);
        assert_eq!(merged[&"z".to_string()], 1);
    }

    #[test]
    fn classic_reduce_sees_full_multiset() {
        let input: Vec<u32> = (0..10).collect();
        let feed = TaskFeed::new(&input, 2, 1, Scheduling::Static, None);
        let results = pool_run(2, |c| {
            // All items map to one key; reducer asserts it sees all 10.
            let map = |i: &u32, emit: &mut dyn FnMut(u8, u32)| emit(0u8, *i);
            let reduce = |_k: &u8, vs: &mut dyn Iterator<Item = u32>| {
                let vs: Vec<u32> = vs.collect();
                assert_eq!(vs.len(), 10);
                vs.into_iter().max().unwrap()
            };
            let tracker = PeakTracker::new();
            classic_rank(c, &feed, &map, &reduce, None, 0, u64::MAX, &tracker).unwrap().0
        });
        let owner_shard: Vec<_> = results.into_iter().filter(|m| !m.is_empty()).collect();
        assert_eq!(owner_shard.len(), 1);
        assert_eq!(owner_shard[0][&0u8], 9);
    }

    #[test]
    fn classic_with_tiny_spill_threshold_still_correct() {
        let input: Vec<String> = (0..50).map(|i| format!("w{} w{}", i % 5, i % 3)).collect();
        let feed = TaskFeed::new(&input, 2, 2, Scheduling::Static, None);
        let results = pool_run(2, |c| {
            let map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
                for w in line.split_whitespace() {
                    emit(w.to_string(), 1);
                }
            };
            let reduce =
                |_k: &String, vs: &mut dyn Iterator<Item = u64>| vs.sum::<u64>();
            let tracker = PeakTracker::new();
            let (shard, spilled, _) =
                classic_rank(c, &feed, &map, &reduce, None, 0, 128, &tracker).unwrap();
            (shard, spilled)
        });
        let spilled: u64 = results.iter().map(|(_, s)| s).sum();
        assert!(spilled > 0, "tiny threshold must force spilling");
        let mut merged: HashMap<String, u64> = HashMap::new();
        for (shard, _) in results {
            merged.extend(shard);
        }
        let total: u64 = merged.values().sum();
        assert_eq!(total, 100, "50 lines x 2 words");
    }

    #[test]
    fn combiner_preserves_result_and_cuts_shuffled_pairs() {
        let input: Vec<String> =
            (0..60).map(|i| format!("hot hot w{} hot", i % 4)).collect();
        let feed = TaskFeed::new(&input, 2, 2, Scheduling::Static, None);
        let run = |with_combiner: bool| {
            pool_run(2, |c| {
                let map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
                    for w in line.split_whitespace() {
                        emit(w.to_string(), 1);
                    }
                };
                let reduce =
                |_k: &String, vs: &mut dyn Iterator<Item = u64>| vs.sum::<u64>();
                let combine = |acc: &mut u64, v: u64| *acc += v;
                let tracker = PeakTracker::new();
                classic_rank(
                    c,
                    &feed,
                    &map,
                    &reduce,
                    with_combiner.then_some(&combine as Combiner<'_, u64>),
                    0,
                    256,
                    &tracker,
                )
                .unwrap()
            })
        };
        let raw = run(false);
        let combined = run(true);
        let merge = |rs: &[(HashMap<String, u64>, u64, u64)]| {
            let mut all: HashMap<String, u64> = HashMap::new();
            for (shard, _, _) in rs {
                all.extend(shard.iter().map(|(k, v)| (k.clone(), *v)));
            }
            all
        };
        assert_eq!(merge(&raw), merge(&combined), "combiner must not change results");
        assert_eq!(merge(&raw)[&"hot".to_string()], 180);
        assert_eq!(raw.iter().map(|(_, _, cb)| cb).sum::<u64>(), 0);
        assert!(
            combined.iter().map(|(_, _, cb)| cb).sum::<u64>() > 0,
            "combiner must fold bytes"
        );
    }
}
