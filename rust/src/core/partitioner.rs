//! Partitioners: key -> reducer-rank placement beyond the default hash
//! router.
//!
//! [`RangePartitioner`] assigns contiguous integer key ranges to ranks —
//! the layout the AOT `wordcount_segsum` kernel needs (each reducer rank
//! owns keys `[lo, hi)` and reduces them with one histogram contraction).

use crate::mpi::Rank;

/// Contiguous-range partitioner over integer keys `0..num_keys`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RangePartitioner {
    num_keys: u32,
    ranks: usize,
}

impl RangePartitioner {
    pub fn new(num_keys: u32, ranks: usize) -> Self {
        assert!(ranks > 0, "need at least one rank");
        assert!(num_keys > 0, "need at least one key");
        Self { num_keys, ranks }
    }

    pub fn num_keys(&self) -> u32 {
        self.num_keys
    }

    /// Owning rank of a key (keys >= num_keys clamp to the last rank).
    pub fn owner(&self, key: u32) -> Rank {
        let key = key.min(self.num_keys - 1) as u64;
        Rank(((key * self.ranks as u64) / self.num_keys as u64) as usize)
    }

    /// Key range `[lo, hi)` owned by a rank.
    pub fn range_of(&self, rank: Rank) -> std::ops::Range<u32> {
        let r = rank.0 as u64;
        let n = self.ranks as u64;
        let k = self.num_keys as u64;
        let lo = (r * k).div_ceil(n) as u32;
        let hi = ((r + 1) * k).div_ceil(n) as u32;
        lo..hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_tile_the_key_space() {
        for (keys, ranks) in [(1024u32, 4usize), (10, 3), (7, 7), (5, 8)] {
            let p = RangePartitioner::new(keys, ranks);
            let mut covered = 0u32;
            let mut prev_hi = 0u32;
            for r in 0..ranks {
                let range = p.range_of(Rank(r));
                assert_eq!(range.start, prev_hi, "gap before rank {r}");
                prev_hi = range.end;
                covered += range.end - range.start;
            }
            assert_eq!(prev_hi, keys);
            assert_eq!(covered, keys);
        }
    }

    #[test]
    fn owner_agrees_with_range() {
        let p = RangePartitioner::new(1000, 6);
        for key in 0..1000 {
            let owner = p.owner(key);
            assert!(
                p.range_of(owner).contains(&key),
                "key {key} owner {owner} range {:?}",
                p.range_of(owner)
            );
        }
    }

    #[test]
    fn out_of_range_keys_clamp() {
        let p = RangePartitioner::new(16, 4);
        assert_eq!(p.owner(u32::MAX), Rank(3));
    }
}
