//! The shuffle: partition `(K, V)` pairs by key owner and exchange them,
//! with out-of-core paths built on [`crate::store`].
//!
//! Two collectives live here:
//!
//!  * [`shuffle_pairs`] — one `alltoallv` of every pair at once. Eager
//!    reduction uses it: the thread-local cache already bounds its
//!    volume to one value per distinct key.
//!  * [`shuffle_runs`] — the out-of-core shuffle for classic and
//!    delayed modes: drains a key-ordered [`RunSet`] through its merge,
//!    exchanges it in rounds of at most `budget / n` bytes per
//!    destination (so no rank ever *receives* more than ~`budget` per
//!    round), and restages the incoming pairs into a fresh budget-bound
//!    `RunSet` on the owner. Ranks agree on the round count with an
//!    allreduce, so the collective stays aligned at any skew.
//!
//! Receiver-side restage is **re-sort-free**: each incoming per-source
//! chunk is already key-ordered (the sender drains its merge in key
//! order), so it is staged via [`RunWriter::push_sorted_run`] as its own
//! run — zero comparisons at restage; the final loser-tree merge pays
//! `O(log k)` per pair instead.
//!
//! Both collectives ride [`crate::mpi::Communicator::alltoallv`], so
//! under [`crate::mpi::CollectiveAlgo::Hierarchical`] every shuffle
//! round is node-coalesced: pairs bound for ranks on one destination
//! node cross the wire as a single framed bundle to that node's leader.
use std::hash::Hash;
use std::sync::Arc;

use anyhow::Result;

use crate::dist::{KeyRouter, ShardRouter};
use crate::metrics::PeakTracker;
use crate::mpi::Communicator;
use crate::serial::{Decoder, Encoder, FastSerialize};
use crate::store::{Combiner, RunSet, RunWriter};

use super::scheduler::TaskFeed;

/// COLLECTIVE: partition `pairs` by `router.route(key)` and exchange.
/// Returns the pairs this rank owns. Peak memory for the serialized
/// buffers is charged to `tracker`. Generic over the [`KeyRouter`]:
/// the engines pass a [`ShardRouter`], the iterative layer a
/// [`crate::dist::BucketRouter`] — same wire path either way.
pub fn shuffle_pairs<K, V, R>(
    comm: &Communicator,
    router: &R,
    pairs: Vec<(K, V)>,
    tracker: &Arc<PeakTracker>,
) -> Result<Vec<(K, V)>>
where
    K: FastSerialize + Hash + Eq,
    V: FastSerialize,
    R: KeyRouter,
{
    let n = comm.size();
    debug_assert_eq!(router.width(), n, "router/communicator size mismatch");
    let shuffle_span = crate::trace::span(crate::trace::SpanKind::Shuffle);

    // Serialize straight into per-destination encoders: no intermediate
    // per-destination Vec<(K,V)> (hot-path allocation kept linear).
    // Pre-size each encoder at the expected per-destination share — saves
    // the doubling-regrowth memcpys in the partition loop (§Perf iter 1).
    let est_total: usize = pairs.iter().map(|(k, v)| k.size_hint() + v.size_hint()).sum();
    let per_dest = est_total / n + 16;
    let mut encoders: Vec<Encoder> = (0..n).map(|_| Encoder::with_capacity(per_dest)).collect();
    let mut counts = vec![0u64; n];
    for (k, v) in &pairs {
        let dst = router.route(k).0;
        counts[dst] += 1;
        k.encode(&mut encoders[dst]);
        v.encode(&mut encoders[dst]);
    }
    drop(pairs);

    let mut bufs = Vec::with_capacity(n);
    let mut total = 0u64;
    for (dst, enc) in encoders.into_iter().enumerate() {
        let mut framed = Encoder::with_capacity(enc.len() + 10);
        framed.put_varint(counts[dst]);
        framed.put_raw(enc.as_bytes());
        total += framed.len() as u64;
        bufs.push(framed.into_bytes());
    }
    tracker.alloc(total);
    shuffle_span.add_bytes(total);

    // Attach the tracker for the exchange so Hierarchical node-leader
    // staging buffers are charged to the same job-level peak.
    comm.set_memory_tracker(Some(tracker.clone()));
    let incoming = comm.alltoallv(bufs);
    comm.set_memory_tracker(None);
    let incoming = incoming?;
    tracker.free(total);

    let in_total: u64 = incoming.iter().map(|b| b.len() as u64).sum();
    tracker.alloc(in_total);
    let mut out = Vec::new();
    for buf in &incoming {
        let mut dec = Decoder::new(buf);
        let count = dec.get_varint()?;
        out.reserve(count as usize);
        for _ in 0..count {
            let k = K::decode(&mut dec)?;
            let v = V::decode(&mut dec)?;
            out.push((k, v));
        }
        dec.finish()?;
    }
    tracker.free(in_total);
    Ok(out)
}

/// The shared map-phase stage loop for the run-backed engines: feed
/// this rank's task chunks through `map`, pushing every emitted pair
/// into `writer` (first emit error wins and fails the rank), then close
/// the writer into its [`RunSet`]. Classic and delayed both stage this
/// way — one place to fix emit-error semantics.
pub(crate) fn stage_sorted_runs<I, K, V, M>(
    comm: &Communicator,
    feed: &TaskFeed<'_, I>,
    map: &M,
    mut writer: RunWriter<'_, K, V>,
) -> Result<RunSet<K, V>>
where
    I: Sync,
    K: FastSerialize + Ord,
    V: FastSerialize,
    M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
{
    let _map_span = crate::trace::span(crate::trace::SpanKind::Map);
    let mut rank_feed = feed.for_rank(comm.rank());
    while let Some((task, chunk)) = rank_feed.next() {
        let res: Result<()> = comm.timed(|| {
            let mut err = None;
            for item in chunk {
                map(item, &mut |k, v| {
                    if err.is_none() {
                        if let Err(e) = writer.push(k, v) {
                            err = Some(e);
                        }
                    }
                });
            }
            err.map_or(Ok(()), Err)
        });
        res?;
        rank_feed.complete(task);
    }
    comm.timed(|| writer.finish())
}

/// COLLECTIVE: the out-of-core shuffle. Drains `runs` in key order,
/// exchanges pairs in rounds bounded by `budget`, and restages what this
/// rank owns into a fresh budget-bound [`RunSet`] (each incoming
/// per-source chunk staged as an already-sorted run — no restage
/// re-sort). With a combiner, equal-key values are folded while draining
/// (merge-time: across this rank's runs, pre-wire) and within each
/// incoming chunk on the owner; cross-chunk folding happens at the final
/// merge, which the consumers drive.
///
/// Returns `(incoming run set, bytes the sender-side merge combined
/// away)`. Memory: one round holds at most ~`budget` of outgoing framed
/// buffers and ~`budget` of incoming bytes, on top of the run machinery's
/// per-run block overhead.
pub fn shuffle_runs<K, V>(
    comm: &Communicator,
    router: &ShardRouter,
    runs: RunSet<K, V>,
    budget: u64,
    combiner: Option<Combiner<'_, V>>,
    tracker: &Arc<PeakTracker>,
) -> Result<(RunSet<K, V>, u64)>
where
    K: FastSerialize + Hash + Ord,
    V: FastSerialize,
{
    let n = comm.size();
    debug_assert_eq!(router.shards(), n, "router/communicator size mismatch");
    let shuffle_span = crate::trace::span(crate::trace::SpanKind::Shuffle);

    let mut source = runs.into_merge()?;
    if let Some(c) = combiner {
        source = source.with_combiner(c);
    }
    let mut receiver: RunWriter<'_, K, V> = RunWriter::new(budget, tracker.clone());
    if let Some(c) = combiner {
        receiver = receiver.with_combiner(c);
    }

    // Per-round, per-destination byte cap: a receiver hears from n
    // senders, so capping each at budget/n bounds what any rank takes in
    // per round by ~budget (minimum one record per round to guarantee
    // progress under tiny budgets).
    let per_dest_cap = (budget / n as u64).max(1);

    let mut pending: Option<(K, V)> = None;
    loop {
        let round_span = crate::trace::span(crate::trace::SpanKind::ShuffleRound);
        // Fill this round's buffers in key order. Stop at the first pair
        // whose destination is full: pairs for one destination must stay
        // in key order, so we cannot skip past it. Buffers are raw
        // record streams (no count frame): the receiver decodes until
        // the buffer is exhausted, which avoids re-copying ~budget bytes
        // per round just to prepend a length.
        let mut encoders: Vec<Encoder> = (0..n).map(|_| Encoder::new()).collect();
        let fill: Result<()> = comm.timed(|| {
            loop {
                let (k, v) = match pending.take() {
                    Some(p) => p,
                    None => match source.next()? {
                        Some(p) => p,
                        None => break,
                    },
                };
                let dst = router.owner(&k).0;
                if encoders[dst].len() as u64 >= per_dest_cap {
                    pending = Some((k, v));
                    break;
                }
                k.encode(&mut encoders[dst]);
                v.encode(&mut encoders[dst]);
            }
            Ok(())
        });
        fill?;

        let mut bufs: Vec<Vec<u8>> = Vec::with_capacity(n);
        let mut total = 0u64;
        for enc in encoders {
            total += enc.len() as u64;
            bufs.push(enc.into_bytes());
        }
        // Charged once assembled; the fill phase itself holds at most
        // the same bytes, so the high-water timing is the exchange.
        tracker.alloc(total);
        round_span.add_bytes(total);
        shuffle_span.add_bytes(total);
        comm.set_memory_tracker(Some(tracker.clone()));
        let incoming = comm.alltoallv(bufs);
        comm.set_memory_tracker(None);
        let incoming = incoming?;
        tracker.free(total);

        let in_total: u64 = incoming.iter().map(|b| b.len() as u64).sum();
        tracker.alloc(in_total);
        // Each per-source chunk arrived key-ordered (the sender drains
        // its merge in key order), so it restages as its own presorted
        // run: zero comparisons here, `O(log k)` per pair at the final
        // merge instead of a full re-sort per round.
        let absorb: Result<()> = comm.timed(|| {
            for buf in &incoming {
                if buf.is_empty() {
                    continue;
                }
                let mut dec = Decoder::new(buf);
                let mut chunk: Vec<(K, V)> = Vec::new();
                while !dec.is_empty() {
                    let k = K::decode(&mut dec)?;
                    let v = V::decode(&mut dec)?;
                    chunk.push((k, v));
                }
                receiver.push_sorted_run(chunk)?;
            }
            Ok(())
        });
        absorb?;
        drop(incoming);
        tracker.free(in_total);

        // Collective agreement: another round only while someone still
        // has pairs in flight (keeps every rank's alltoallv count equal).
        let more = u64::from(pending.is_some());
        if comm.allreduce_sum_u64(more)? == 0 {
            break;
        }
    }

    let sender_combined = source.combined_bytes();
    Ok((receiver.finish()?, sender_combined))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testpool::pool_run;

    #[test]
    fn shuffle_routes_every_pair_to_owner() {
        let got = pool_run(3, |c| {
            let router = ShardRouter::new(3, 0);
            let tracker = PeakTracker::new();
            let pairs: Vec<(u32, u64)> =
                (0..30).map(|i| (i as u32, (c.rank().0 * 100 + i) as u64)).collect();
            let mine = shuffle_pairs(c, &router, pairs, &tracker).unwrap();
            // Everything I received is mine; count total below.
            assert!(mine.iter().all(|(k, _)| router.owner(k) == c.rank()));
            assert_eq!(tracker.current_bytes(), 0, "shuffle buffers all freed");
            mine.len() as u64
        });
        assert_eq!(got.iter().sum::<u64>(), 90);
    }

    #[test]
    fn shuffle_runs_routes_and_sorts_under_tiny_budget() {
        let got = pool_run(3, |c| {
            let router = ShardRouter::new(3, 7);
            let tracker = PeakTracker::new();
            let mut w: RunWriter<'_, u32, u64> = RunWriter::new(200, tracker.clone());
            for i in 0..200u32 {
                w.push(i % 40, (c.rank().0 as u64) << 32 | i as u64).unwrap();
            }
            let runs = w.finish().unwrap();
            let (mine, _) =
                shuffle_runs(c, &router, runs, 200, None, &tracker).unwrap();
            let mut m = mine.into_merge().unwrap();
            let mut count = 0u64;
            let mut last: Option<u32> = None;
            while let Some((k, _)) = m.next().unwrap() {
                assert_eq!(router.owner(&k), c.rank(), "pair landed on owner");
                if let Some(prev) = last {
                    assert!(prev <= k, "owner stream stays key-ordered");
                }
                last = Some(k);
                count += 1;
            }
            drop(m);
            assert_eq!(tracker.current_bytes(), 0, "all charges released");
            count
        });
        assert_eq!(got.iter().sum::<u64>(), 600, "every pair arrived exactly once");
    }

    #[test]
    fn shuffle_runs_restage_preserves_value_order_within_keys() {
        // The presorted-restage path must keep the (round, source,
        // position) value order a reducer observes — same contract the
        // old sort-at-restage path had via stable sorting.
        let got = pool_run(2, |c| {
            let router = ShardRouter::new(2, 3);
            let tracker = PeakTracker::new();
            let mut w: RunWriter<'_, u32, u64> = RunWriter::new(u64::MAX, tracker.clone());
            // Every rank emits 3 values per key, locally ordered 0,1,2.
            for i in 0..60u32 {
                w.push(i % 20, ((c.rank().0 as u64) << 8) | (i / 20) as u64).unwrap();
            }
            let runs = w.finish().unwrap();
            let (mine, _) = shuffle_runs(c, &router, runs, 300, None, &tracker).unwrap();
            let mut m = mine.into_merge().unwrap();
            let mut per_key: std::collections::HashMap<u32, Vec<u64>> =
                std::collections::HashMap::new();
            while let Some((k, v)) = m.next().unwrap() {
                per_key.entry(k).or_default().push(v);
            }
            for (k, vs) in &per_key {
                assert_eq!(vs.len(), 6, "key {k}: 3 values from each of 2 ranks");
                // Within one source rank, sequence positions ascend.
                for src in 0..2u64 {
                    let seq: Vec<u64> =
                        vs.iter().filter(|v| *v >> 8 == src).map(|v| v & 0xff).collect();
                    assert_eq!(seq, vec![0, 1, 2], "key {k} src {src}");
                }
            }
            per_key.len() as u64
        });
        assert_eq!(got.iter().sum::<u64>(), 20, "20 keys split across owners");
    }

    #[test]
    fn shuffle_runs_combiner_folds_before_the_wire() {
        let got = pool_run(2, |c| {
            let tracker = PeakTracker::new();
            let router = ShardRouter::new(2, 1);
            let combine = |acc: &mut u64, v: u64| *acc += v;
            let mut w: RunWriter<'_, u32, u64> =
                RunWriter::new(150, tracker.clone()).with_combiner(&combine);
            // 3 hot keys, 300 emissions: the combiner should collapse
            // nearly everything before the exchange.
            for i in 0..300u32 {
                w.push(i % 3, 1).unwrap();
            }
            let runs = w.finish().unwrap();
            let write_combined = runs.combined_bytes();
            let (mine, merge_combined) =
                shuffle_runs(c, &router, runs, 150, Some(&combine), &tracker).unwrap();
            let mut m = mine.into_merge().unwrap().with_combiner(&combine);
            let mut total = 0u64;
            while let Some((_, v)) = m.next().unwrap() {
                total += v;
            }
            (total, write_combined + merge_combined)
        });
        let grand: u64 = got.iter().map(|(t, _)| t).sum();
        assert_eq!(grand, 600, "combined counts conserved end to end");
        assert!(got.iter().any(|(_, c)| *c > 0), "combiner must fold bytes pre-wire");
    }
}
