//! The shuffle: partition `(K, V)` pairs by key owner and exchange them
//! with one `alltoallv`, with optional out-of-core spilling.
//!
//! Spilling reproduces MR-MPI's page/out-of-core behaviour the paper's
//! related work dwells on: when staged pairs exceed the node's memory
//! budget ([`crate::cluster::ClusterConfig::spill_threshold_bytes`]), the
//! overflow is serialized to a temp file and re-read at exchange time. The
//! spilled byte count feeds `JobStats::spilled_bytes` so benches can show
//! the in-core -> out-of-core crossover.

use std::hash::Hash;
use std::io::{Read, Seek, SeekFrom, Write};
use std::sync::Arc;

use crate::util::tmp::TempFile;

use anyhow::{Context, Result};

use crate::dist::ShardRouter;
use crate::metrics::PeakTracker;
use crate::mpi::Communicator;
use crate::serial::{Decoder, Encoder, FastSerialize};

/// Buffer for map-side pairs with a spill-to-disk overflow path.
pub struct SpillBuffer<K, V> {
    in_mem: Vec<(K, V)>,
    mem_bytes: u64,
    threshold: u64,
    spill: Option<TempFile>,
    spilled_bytes: u64,
    spilled_items: u64,
    tracker: Arc<PeakTracker>,
}

impl<K: FastSerialize, V: FastSerialize> SpillBuffer<K, V> {
    /// `threshold` = max in-memory bytes before spilling (u64::MAX = never).
    pub fn new(threshold: u64, tracker: Arc<PeakTracker>) -> Self {
        Self {
            in_mem: Vec::new(),
            mem_bytes: 0,
            threshold,
            spill: None,
            spilled_bytes: 0,
            spilled_items: 0,
            tracker,
        }
    }

    pub fn push(&mut self, key: K, value: V) -> Result<()> {
        let sz = (key.size_hint() + value.size_hint()) as u64 + 16;
        self.mem_bytes += sz;
        self.tracker.alloc(sz);
        self.in_mem.push((key, value));
        if self.mem_bytes > self.threshold {
            self.spill_now()?;
        }
        Ok(())
    }

    pub fn len_in_mem(&self) -> usize {
        self.in_mem.len()
    }

    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes
    }

    /// Serialize the in-memory pairs to the spill file and drop them.
    fn spill_now(&mut self) -> Result<()> {
        if self.in_mem.is_empty() {
            return Ok(());
        }
        if self.spill.is_none() {
            let f = TempFile::new("blaze-spill").context("creating shuffle spill file")?;
            self.spill = Some(f);
        }
        let tf = self.spill.as_mut().expect("spill file just ensured");
        let file = tf.file();
        let mut enc = Encoder::with_capacity(self.mem_bytes as usize);
        enc.put_varint(self.in_mem.len() as u64);
        for (k, v) in &self.in_mem {
            k.encode(&mut enc);
            v.encode(&mut enc);
        }
        let chunk = enc.into_bytes();
        file.write_all(&(chunk.len() as u64).to_le_bytes())?;
        file.write_all(&chunk)?;
        self.spilled_bytes += chunk.len() as u64;
        self.spilled_items += self.in_mem.len() as u64;
        self.in_mem.clear();
        self.tracker.free(self.mem_bytes);
        self.mem_bytes = 0;
        Ok(())
    }

    /// Drain everything (disk chunks first, then memory) into a vector.
    pub fn drain(mut self) -> Result<Vec<(K, V)>> {
        let mut out = Vec::with_capacity(self.in_mem.len() + self.spilled_items as usize);
        if let Some(mut tf) = self.spill.take() {
            let file = tf.file();
            file.seek(SeekFrom::Start(0))?;
            let mut raw = Vec::new();
            file.read_to_end(&mut raw)?;
            let mut pos = 0usize;
            while pos < raw.len() {
                let len =
                    u64::from_le_bytes(raw[pos..pos + 8].try_into().unwrap()) as usize;
                pos += 8;
                let mut dec = Decoder::new(&raw[pos..pos + len]);
                pos += len;
                let count = dec.get_varint()?;
                for _ in 0..count {
                    let k = K::decode(&mut dec)?;
                    let v = V::decode(&mut dec)?;
                    out.push((k, v));
                }
                dec.finish()?;
            }
        }
        out.append(&mut self.in_mem);
        self.tracker.free(self.mem_bytes);
        self.mem_bytes = 0;
        Ok(out)
    }
}

impl<K, V> Drop for SpillBuffer<K, V> {
    fn drop(&mut self) {
        self.tracker.free(self.mem_bytes);
    }
}

/// COLLECTIVE: partition `pairs` by `router.owner(key)` and exchange.
/// Returns the pairs this rank owns. Peak memory for the serialized
/// buffers is charged to `tracker`.
pub fn shuffle_pairs<K, V>(
    comm: &Communicator,
    router: &ShardRouter,
    pairs: Vec<(K, V)>,
    tracker: &Arc<PeakTracker>,
) -> Result<Vec<(K, V)>>
where
    K: FastSerialize + Hash + Eq,
    V: FastSerialize,
{
    let n = comm.size();
    debug_assert_eq!(router.shards(), n, "router/communicator size mismatch");

    // Serialize straight into per-destination encoders: no intermediate
    // per-destination Vec<(K,V)> (hot-path allocation kept linear).
    // Pre-size each encoder at the expected per-destination share — saves
    // the doubling-regrowth memcpys in the partition loop (§Perf iter 1).
    let est_total: usize = pairs.iter().map(|(k, v)| k.size_hint() + v.size_hint()).sum();
    let per_dest = est_total / n + 16;
    let mut encoders: Vec<Encoder> = (0..n).map(|_| Encoder::with_capacity(per_dest)).collect();
    let mut counts = vec![0u64; n];
    for (k, v) in &pairs {
        let dst = router.owner(k).0;
        counts[dst] += 1;
        k.encode(&mut encoders[dst]);
        v.encode(&mut encoders[dst]);
    }
    drop(pairs);

    let mut bufs = Vec::with_capacity(n);
    let mut total = 0u64;
    for (dst, enc) in encoders.into_iter().enumerate() {
        let mut framed = Encoder::with_capacity(enc.len() + 10);
        framed.put_varint(counts[dst]);
        framed.put_raw(enc.as_bytes());
        total += framed.len() as u64;
        bufs.push(framed.into_bytes());
    }
    tracker.alloc(total);

    let incoming = comm.alltoallv(bufs)?;
    tracker.free(total);

    let in_total: u64 = incoming.iter().map(|b| b.len() as u64).sum();
    tracker.alloc(in_total);
    let mut out = Vec::new();
    for buf in &incoming {
        let mut dec = Decoder::new(buf);
        let count = dec.get_varint()?;
        out.reserve(count as usize);
        for _ in 0..count {
            let k = K::decode(&mut dec)?;
            let v = V::decode(&mut dec)?;
            out.push((k, v));
        }
        dec.finish()?;
    }
    tracker.free(in_total);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testpool::pool_run;

    #[test]
    fn shuffle_routes_every_pair_to_owner() {
        let got = pool_run(3, |c| {
            let router = ShardRouter::new(3, 0);
            let tracker = PeakTracker::new();
            let pairs: Vec<(u32, u64)> =
                (0..30).map(|i| (i as u32, (c.rank().0 * 100 + i) as u64)).collect();
            let mine = shuffle_pairs(c, &router, pairs, &tracker).unwrap();
            // Everything I received is mine; count total below.
            assert!(mine.iter().all(|(k, _)| router.owner(k) == c.rank()));
            assert_eq!(tracker.current_bytes(), 0, "shuffle buffers all freed");
            mine.len() as u64
        });
        assert_eq!(got.iter().sum::<u64>(), 90);
    }

    #[test]
    fn spill_buffer_roundtrip_without_spill() {
        let t = PeakTracker::new();
        let mut b: SpillBuffer<String, u64> = SpillBuffer::new(u64::MAX, t.clone());
        b.push("a".into(), 1).unwrap();
        b.push("b".into(), 2).unwrap();
        assert_eq!(b.spilled_bytes(), 0);
        let items = b.drain().unwrap();
        assert_eq!(items, vec![("a".into(), 1), ("b".into(), 2)]);
        assert_eq!(t.current_bytes(), 0);
    }

    #[test]
    fn spill_buffer_spills_past_threshold_and_preserves_order() {
        let t = PeakTracker::new();
        let mut b: SpillBuffer<u64, u64> = SpillBuffer::new(256, t.clone());
        for i in 0..100u64 {
            b.push(i, i * 2).unwrap();
        }
        assert!(b.spilled_bytes() > 0, "should have spilled");
        assert!(b.len_in_mem() < 100);
        let items = b.drain().unwrap();
        assert_eq!(items.len(), 100);
        // Disk chunks precede memory; within chunks order preserved.
        let expected: Vec<(u64, u64)> = (0..100).map(|i| (i, i * 2)).collect();
        assert_eq!(items, expected);
    }

    #[test]
    fn spill_peak_memory_bounded() {
        let t = PeakTracker::new();
        let mut b: SpillBuffer<u64, u64> = SpillBuffer::new(512, t.clone());
        for i in 0..10_000u64 {
            b.push(i, i).unwrap();
        }
        // Peak stays near the threshold, not the full data size.
        assert!(t.peak_bytes() < 2_048, "peak {}", t.peak_bytes());
        let items = b.drain().unwrap();
        assert_eq!(items.len(), 10_000);
    }
}
