//! The shuffle: partition `(K, V)` pairs by key owner and exchange them,
//! with out-of-core paths built on [`crate::store`].
//!
//! Two collectives live here:
//!
//!  * [`shuffle_pairs`] — one `alltoallv` of every pair at once. Eager
//!    reduction uses it: the thread-local cache already bounds its
//!    volume to one value per distinct key.
//!  * [`shuffle_runs`] — the out-of-core shuffle for classic and
//!    delayed modes: drains a key-ordered [`RunSet`] through its merge,
//!    exchanges it in rounds of at most `budget / n` bytes per
//!    destination (so no rank ever *receives* more than ~`budget` per
//!    round), and restages the incoming pairs into a fresh budget-bound
//!    `RunSet` on the owner. Ranks agree on the round count with an
//!    allreduce, so the collective stays aligned at any skew.
//!
//! [`SpillBuffer`] remains as the order-preserving *unsorted* staging
//! buffer (MR-MPI's pages); its drain streams the spill file back one
//! block at a time through [`crate::store::RunReader`] instead of the
//! old whole-file `read_to_end`, so recovery memory is bounded by the
//! block size, not the spill size.

use std::hash::Hash;
use std::io::{Seek, SeekFrom, Write};
use std::sync::Arc;

use crate::util::tmp::TempFile;

use anyhow::{Context, Result};

use crate::dist::ShardRouter;
use crate::metrics::PeakTracker;
use crate::mpi::Communicator;
use crate::serial::{Decoder, Encoder, FastSerialize};
use crate::store::{Combiner, RunReader, RunSet, RunWriter};

use super::scheduler::TaskFeed;

/// Buffer for map-side pairs with a spill-to-disk overflow path.
/// Order-preserving (disk chunks first, then memory) — the *sorted*
/// counterpart is [`crate::store::RunWriter`].
pub struct SpillBuffer<K, V> {
    in_mem: Vec<(K, V)>,
    mem_bytes: u64,
    threshold: u64,
    spill: Option<TempFile>,
    spilled_bytes: u64,
    spilled_items: u64,
    tracker: Arc<PeakTracker>,
}

impl<K: FastSerialize, V: FastSerialize> SpillBuffer<K, V> {
    /// `threshold` = max in-memory bytes before spilling (u64::MAX = never).
    pub fn new(threshold: u64, tracker: Arc<PeakTracker>) -> Self {
        Self {
            in_mem: Vec::new(),
            mem_bytes: 0,
            threshold,
            spill: None,
            spilled_bytes: 0,
            spilled_items: 0,
            tracker,
        }
    }

    pub fn push(&mut self, key: K, value: V) -> Result<()> {
        let sz = (key.size_hint() + value.size_hint()) as u64 + 16;
        self.mem_bytes += sz;
        self.tracker.alloc(sz);
        self.in_mem.push((key, value));
        if self.mem_bytes > self.threshold {
            self.spill_now()?;
        }
        Ok(())
    }

    pub fn len_in_mem(&self) -> usize {
        self.in_mem.len()
    }

    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes
    }

    /// Serialize the in-memory pairs to the spill file and drop them.
    /// The chunk frame is the store's run-block format, which is what
    /// lets [`RunReader`] stream it back.
    fn spill_now(&mut self) -> Result<()> {
        if self.in_mem.is_empty() {
            return Ok(());
        }
        if self.spill.is_none() {
            let f = TempFile::new("blaze-spill").context("creating shuffle spill file")?;
            self.spill = Some(f);
        }
        let tf = self.spill.as_mut().expect("spill file just ensured");
        let file = tf.file();
        let mut enc = Encoder::with_capacity(self.mem_bytes as usize);
        enc.put_varint(self.in_mem.len() as u64);
        for (k, v) in &self.in_mem {
            k.encode(&mut enc);
            v.encode(&mut enc);
        }
        let chunk = enc.into_bytes();
        file.write_all(&(chunk.len() as u64).to_le_bytes())?;
        file.write_all(&chunk)?;
        self.spilled_bytes += chunk.len() as u64;
        self.spilled_items += self.in_mem.len() as u64;
        self.in_mem.clear();
        self.tracker.free(self.mem_bytes);
        self.mem_bytes = 0;
        Ok(())
    }

    /// Stream everything out in insertion order (disk chunks first, then
    /// memory), holding at most one spill block in memory at a time.
    pub fn drain_for_each(mut self, mut f: impl FnMut(K, V)) -> Result<()> {
        if let Some(mut tf) = self.spill.take() {
            let end = tf.file().seek(SeekFrom::End(0))?;
            let shared =
                Arc::new(tf.file().try_clone().context("cloning spill file for drain")?);
            let mut reader: RunReader<K, V> =
                RunReader::new(shared, 0, end, self.tracker.clone());
            while let Some((k, v)) = reader.next()? {
                f(k, v);
            }
        }
        for (k, v) in self.in_mem.drain(..) {
            f(k, v);
        }
        self.tracker.free(self.mem_bytes);
        self.mem_bytes = 0;
        Ok(())
    }

    /// Drain everything (disk chunks first, then memory) into a vector.
    /// Reads the spill in bounded blocks (via [`RunReader`]), never the
    /// whole file at once.
    pub fn drain(self) -> Result<Vec<(K, V)>> {
        let mut out = Vec::with_capacity(self.in_mem.len() + self.spilled_items as usize);
        self.drain_for_each(|k, v| out.push((k, v)))?;
        Ok(out)
    }
}

impl<K, V> Drop for SpillBuffer<K, V> {
    fn drop(&mut self) {
        self.tracker.free(self.mem_bytes);
    }
}

/// COLLECTIVE: partition `pairs` by `router.owner(key)` and exchange.
/// Returns the pairs this rank owns. Peak memory for the serialized
/// buffers is charged to `tracker`.
pub fn shuffle_pairs<K, V>(
    comm: &Communicator,
    router: &ShardRouter,
    pairs: Vec<(K, V)>,
    tracker: &Arc<PeakTracker>,
) -> Result<Vec<(K, V)>>
where
    K: FastSerialize + Hash + Eq,
    V: FastSerialize,
{
    let n = comm.size();
    debug_assert_eq!(router.shards(), n, "router/communicator size mismatch");

    // Serialize straight into per-destination encoders: no intermediate
    // per-destination Vec<(K,V)> (hot-path allocation kept linear).
    // Pre-size each encoder at the expected per-destination share — saves
    // the doubling-regrowth memcpys in the partition loop (§Perf iter 1).
    let est_total: usize = pairs.iter().map(|(k, v)| k.size_hint() + v.size_hint()).sum();
    let per_dest = est_total / n + 16;
    let mut encoders: Vec<Encoder> = (0..n).map(|_| Encoder::with_capacity(per_dest)).collect();
    let mut counts = vec![0u64; n];
    for (k, v) in &pairs {
        let dst = router.owner(k).0;
        counts[dst] += 1;
        k.encode(&mut encoders[dst]);
        v.encode(&mut encoders[dst]);
    }
    drop(pairs);

    let mut bufs = Vec::with_capacity(n);
    let mut total = 0u64;
    for (dst, enc) in encoders.into_iter().enumerate() {
        let mut framed = Encoder::with_capacity(enc.len() + 10);
        framed.put_varint(counts[dst]);
        framed.put_raw(enc.as_bytes());
        total += framed.len() as u64;
        bufs.push(framed.into_bytes());
    }
    tracker.alloc(total);

    let incoming = comm.alltoallv(bufs)?;
    tracker.free(total);

    let in_total: u64 = incoming.iter().map(|b| b.len() as u64).sum();
    tracker.alloc(in_total);
    let mut out = Vec::new();
    for buf in &incoming {
        let mut dec = Decoder::new(buf);
        let count = dec.get_varint()?;
        out.reserve(count as usize);
        for _ in 0..count {
            let k = K::decode(&mut dec)?;
            let v = V::decode(&mut dec)?;
            out.push((k, v));
        }
        dec.finish()?;
    }
    tracker.free(in_total);
    Ok(out)
}

/// The shared map-phase stage loop for the run-backed engines: feed
/// this rank's task chunks through `map`, pushing every emitted pair
/// into `writer` (first emit error wins and fails the rank), then close
/// the writer into its [`RunSet`]. Classic and delayed both stage this
/// way — one place to fix emit-error semantics.
pub(crate) fn stage_sorted_runs<I, K, V, M>(
    comm: &Communicator,
    feed: &TaskFeed<'_, I>,
    map: &M,
    mut writer: RunWriter<'_, K, V>,
) -> Result<RunSet<K, V>>
where
    I: Sync,
    K: FastSerialize + Ord,
    V: FastSerialize,
    M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
{
    let mut rank_feed = feed.for_rank(comm.rank());
    while let Some((task, chunk)) = rank_feed.next() {
        let res: Result<()> = comm.timed(|| {
            let mut err = None;
            for item in chunk {
                map(item, &mut |k, v| {
                    if err.is_none() {
                        if let Err(e) = writer.push(k, v) {
                            err = Some(e);
                        }
                    }
                });
            }
            err.map_or(Ok(()), Err)
        });
        res?;
        rank_feed.complete(task);
    }
    comm.timed(|| writer.finish())
}

/// COLLECTIVE: the out-of-core shuffle. Drains `runs` in key order,
/// exchanges pairs in rounds bounded by `budget`, and restages what this
/// rank owns into a fresh budget-bound [`RunSet`] (each incoming round
/// re-sorted and re-spilled under the same budget). With a combiner,
/// equal-key values are folded both while draining (merge-time: across
/// this rank's runs, pre-wire) and while restaging on the owner.
///
/// Returns `(incoming run set, bytes the sender-side merge combined
/// away)`. Memory: one round holds at most ~`budget` of outgoing framed
/// buffers and ~`budget` of incoming bytes, on top of the run machinery's
/// per-run block overhead.
pub fn shuffle_runs<K, V>(
    comm: &Communicator,
    router: &ShardRouter,
    runs: RunSet<K, V>,
    budget: u64,
    combiner: Option<Combiner<'_, V>>,
    tracker: &Arc<PeakTracker>,
) -> Result<(RunSet<K, V>, u64)>
where
    K: FastSerialize + Hash + Ord,
    V: FastSerialize,
{
    let n = comm.size();
    debug_assert_eq!(router.shards(), n, "router/communicator size mismatch");

    let mut source = runs.into_merge()?;
    if let Some(c) = combiner {
        source = source.with_combiner(c);
    }
    let mut receiver: RunWriter<'_, K, V> = RunWriter::new(budget, tracker.clone());
    if let Some(c) = combiner {
        receiver = receiver.with_combiner(c);
    }

    // Per-round, per-destination byte cap: a receiver hears from n
    // senders, so capping each at budget/n bounds what any rank takes in
    // per round by ~budget (minimum one record per round to guarantee
    // progress under tiny budgets).
    let per_dest_cap = (budget / n as u64).max(1);

    let mut pending: Option<(K, V)> = None;
    loop {
        // Fill this round's buffers in key order. Stop at the first pair
        // whose destination is full: pairs for one destination must stay
        // in key order, so we cannot skip past it. Buffers are raw
        // record streams (no count frame): the receiver decodes until
        // the buffer is exhausted, which avoids re-copying ~budget bytes
        // per round just to prepend a length.
        let mut encoders: Vec<Encoder> = (0..n).map(|_| Encoder::new()).collect();
        let fill: Result<()> = comm.timed(|| {
            loop {
                let (k, v) = match pending.take() {
                    Some(p) => p,
                    None => match source.next()? {
                        Some(p) => p,
                        None => break,
                    },
                };
                let dst = router.owner(&k).0;
                if encoders[dst].len() as u64 >= per_dest_cap {
                    pending = Some((k, v));
                    break;
                }
                k.encode(&mut encoders[dst]);
                v.encode(&mut encoders[dst]);
            }
            Ok(())
        });
        fill?;

        let mut bufs: Vec<Vec<u8>> = Vec::with_capacity(n);
        let mut total = 0u64;
        for enc in encoders {
            total += enc.len() as u64;
            bufs.push(enc.into_bytes());
        }
        // Charged once assembled; the fill phase itself holds at most
        // the same bytes, so the high-water timing is the exchange.
        tracker.alloc(total);
        let incoming = comm.alltoallv(bufs)?;
        tracker.free(total);

        let in_total: u64 = incoming.iter().map(|b| b.len() as u64).sum();
        tracker.alloc(in_total);
        let absorb: Result<()> = comm.timed(|| {
            for buf in &incoming {
                let mut dec = Decoder::new(buf);
                while !dec.is_empty() {
                    let k = K::decode(&mut dec)?;
                    let v = V::decode(&mut dec)?;
                    receiver.push(k, v)?;
                }
            }
            Ok(())
        });
        absorb?;
        drop(incoming);
        tracker.free(in_total);

        // Collective agreement: another round only while someone still
        // has pairs in flight (keeps every rank's alltoallv count equal).
        let more = u64::from(pending.is_some());
        if comm.allreduce_sum_u64(more)? == 0 {
            break;
        }
    }

    let sender_combined = source.combined_bytes();
    Ok((receiver.finish()?, sender_combined))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testpool::pool_run;

    #[test]
    fn shuffle_routes_every_pair_to_owner() {
        let got = pool_run(3, |c| {
            let router = ShardRouter::new(3, 0);
            let tracker = PeakTracker::new();
            let pairs: Vec<(u32, u64)> =
                (0..30).map(|i| (i as u32, (c.rank().0 * 100 + i) as u64)).collect();
            let mine = shuffle_pairs(c, &router, pairs, &tracker).unwrap();
            // Everything I received is mine; count total below.
            assert!(mine.iter().all(|(k, _)| router.owner(k) == c.rank()));
            assert_eq!(tracker.current_bytes(), 0, "shuffle buffers all freed");
            mine.len() as u64
        });
        assert_eq!(got.iter().sum::<u64>(), 90);
    }

    #[test]
    fn spill_buffer_roundtrip_without_spill() {
        let t = PeakTracker::new();
        let mut b: SpillBuffer<String, u64> = SpillBuffer::new(u64::MAX, t.clone());
        b.push("a".into(), 1).unwrap();
        b.push("b".into(), 2).unwrap();
        assert_eq!(b.spilled_bytes(), 0);
        let items = b.drain().unwrap();
        assert_eq!(items, vec![("a".into(), 1), ("b".into(), 2)]);
        assert_eq!(t.current_bytes(), 0);
    }

    #[test]
    fn spill_buffer_spills_past_threshold_and_preserves_order() {
        let t = PeakTracker::new();
        let mut b: SpillBuffer<u64, u64> = SpillBuffer::new(256, t.clone());
        for i in 0..100u64 {
            b.push(i, i * 2).unwrap();
        }
        assert!(b.spilled_bytes() > 0, "should have spilled");
        assert!(b.len_in_mem() < 100);
        let items = b.drain().unwrap();
        assert_eq!(items.len(), 100);
        // Disk chunks precede memory; within chunks order preserved.
        let expected: Vec<(u64, u64)> = (0..100).map(|i| (i, i * 2)).collect();
        assert_eq!(items, expected);
    }

    #[test]
    fn spill_peak_memory_bounded() {
        let t = PeakTracker::new();
        let mut b: SpillBuffer<u64, u64> = SpillBuffer::new(512, t.clone());
        for i in 0..10_000u64 {
            b.push(i, i).unwrap();
        }
        // Peak stays near the threshold, not the full data size.
        assert!(t.peak_bytes() < 2_048, "peak {}", t.peak_bytes());
        let items = b.drain().unwrap();
        assert_eq!(items.len(), 10_000);
    }

    #[test]
    fn spill_buffer_streaming_drain_matches_vec_drain() {
        let make = |t: &Arc<PeakTracker>| {
            let mut b: SpillBuffer<u64, u64> = SpillBuffer::new(128, t.clone());
            for i in 0..500u64 {
                b.push(i % 7, i).unwrap();
            }
            b
        };
        let t = PeakTracker::new();
        let vec_drained = make(&t).drain().unwrap();
        let mut streamed = Vec::new();
        make(&t)
            .drain_for_each(|k, v| streamed.push((k, v)))
            .unwrap();
        assert_eq!(vec_drained, streamed);
        assert_eq!(t.current_bytes(), 0);
    }

    #[test]
    fn shuffle_runs_routes_and_sorts_under_tiny_budget() {
        let got = pool_run(3, |c| {
            let router = ShardRouter::new(3, 7);
            let tracker = PeakTracker::new();
            let mut w: RunWriter<'_, u32, u64> = RunWriter::new(200, tracker.clone());
            for i in 0..200u32 {
                w.push(i % 40, (c.rank().0 as u64) << 32 | i as u64).unwrap();
            }
            let runs = w.finish().unwrap();
            let (mine, _) =
                shuffle_runs(c, &router, runs, 200, None, &tracker).unwrap();
            let mut m = mine.into_merge().unwrap();
            let mut count = 0u64;
            let mut last: Option<u32> = None;
            while let Some((k, _)) = m.next().unwrap() {
                assert_eq!(router.owner(&k), c.rank(), "pair landed on owner");
                if let Some(prev) = last {
                    assert!(prev <= k, "owner stream stays key-ordered");
                }
                last = Some(k);
                count += 1;
            }
            drop(m);
            assert_eq!(tracker.current_bytes(), 0, "all charges released");
            count
        });
        assert_eq!(got.iter().sum::<u64>(), 600, "every pair arrived exactly once");
    }

    #[test]
    fn shuffle_runs_combiner_folds_before_the_wire() {
        let got = pool_run(2, |c| {
            let tracker = PeakTracker::new();
            let router = ShardRouter::new(2, 1);
            let combine = |acc: &mut u64, v: u64| *acc += v;
            let mut w: RunWriter<'_, u32, u64> =
                RunWriter::new(150, tracker.clone()).with_combiner(&combine);
            // 3 hot keys, 300 emissions: the combiner should collapse
            // nearly everything before the exchange.
            for i in 0..300u32 {
                w.push(i % 3, 1).unwrap();
            }
            let runs = w.finish().unwrap();
            let write_combined = runs.combined_bytes();
            let (mine, merge_combined) =
                shuffle_runs(c, &router, runs, 150, Some(&combine), &tracker).unwrap();
            let mut m = mine.into_merge().unwrap();
            let mut total = 0u64;
            while let Some((_, v)) = m.next().unwrap() {
                total += v;
            }
            (total, write_combined + merge_combined)
        });
        let grand: u64 = got.iter().map(|(t, _)| t).sum();
        assert_eq!(grand, 600, "combined counts conserved end to end");
        assert!(got.iter().any(|(_, c)| *c > 0), "combiner must fold bytes pre-wire");
    }
}
