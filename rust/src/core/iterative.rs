//! The in-memory iterative engine: per-key state pinned rank-local
//! across iterations, with only contribution **deltas** crossing the
//! wire each wave — and live elastic rebalancing when the cluster grows
//! or shrinks mid-run.
//!
//! This is the M3R lesson applied to our stack (and the reason the dist
//! layer exists, per ROADMAP): the batch engines re-shuffle the world
//! every job, so an iterative app (PageRank, label propagation, k-means)
//! pays the full input+state exchange per iteration even though the
//! partitioning never changes. [`IterativeJob`] instead keys every
//! per-item state by one [`BucketRouter`] for the whole session:
//!
//! 1. [`IterativeJob::load`] partitions `(K, S)` states onto the ranks
//!    the router names — after that the state never moves (except for
//!    resizes, below).
//! 2. [`IterativeJob::step`] runs one wave on the session's warm
//!    [`crate::mpi::RankPool`]: each rank walks its own states in sorted
//!    key order emitting `(K, D)` **deltas**, the deltas ride one
//!    [`DistHashMap::flush_combining`] (stage-side pre-fold, so at most
//!    one delta per `(rank, key)` hits the wire) to their owners — the
//!    *same* router, so owner and state always coincide — and the owner
//!    applies `update` in place. A per-step `measure` fold is
//!    allreduced for free (convergence checks, normalizers).
//! 3. On [`crate::cluster::ElasticCluster::grow`]/`shrink`, the next
//!    `step` (or an explicit [`IterativeJob::rebalance`]) applies
//!    [`crate::dist::rebalance_plan`] through [`BucketRouter::resize`]:
//!    only the minimal-move bucket set migrates, over the same
//!    `alltoallv` shuffle, the router epoch is bumped, and the iteration
//!    resumes at the new width. Migrated bytes are reported per resize
//!    and in [`JobStats::migrated_bytes`].
//!
//! Determinism: contributions are emitted in sorted-key order, the
//! stage-side pre-fold accumulates per key in that order, and owners
//! fold arrivals in source-rank order — so repeated runs are
//! bit-identical, and runs across different widths/resizes differ only
//! by floating-point re-association in `combine`/`aggregate` (exactly
//! identical for integer deltas, ulp-level for `f64` sums).
//!
//! **Failure is a first-class scenario** (the M3R caveat answered):
//!
//! * [`IterativeJob::checkpoint_every`] snapshots the shards into a
//!   [`CheckpointStore`] every `k` iterations — one sorted run per
//!   non-empty router bucket (the PR 3 block format verbatim), tagged
//!   with the router's salt/epoch/placement table and the wave's
//!   encoded aggregate. `BLAZE_CHECKPOINT_EVERY` forces a cadence on
//!   every session (the CI fault leg).
//! * [`IterativeJob::recover_from`] rebuilds a session from the latest
//!   snapshot as **an elastic resize from disk**: same-width recovery
//!   restores placement verbatim (the continuation is bit-identical for
//!   any app); a different width rides [`BucketRouter::resize`] with
//!   bucket loads taken from the per-run item counts — integer apps
//!   stay bit-identical at *any* recovery width, float apps re-associate
//!   at the usual ulp level.
//! * A [`crate::cluster::FaultPlan`] on the [`ElasticCluster`] injects
//!   deterministic rank kills at `(iteration, phase)` points: the wave
//!   arms the kill *before* dispatch so every rank knows it — the victim
//!   panics at the phase point (its taken shard is lost with the
//!   unwind, like real process death) and survivors return early before
//!   entering any collective, so nobody wedges. The driver sees a typed
//!   [`WaveKilled`] error, calls
//!   [`crate::cluster::ElasticCluster::kill_and_replace`], and resumes
//!   via `recover_from` at the last checkpointed iteration. Each
//!   scheduled kill fires exactly once, so the replayed iteration
//!   passes.
//! * Per-rank virtual-clock slowdowns in the plan turn ranks into
//!   deterministic stragglers; the wave epilogue then runs Mariane-style
//!   speculative re-execution bookkeeping ([`FaultTracker`] attempts):
//!   a straggler whose clock exceeds 2× the median has its shard-task
//!   re-claimed by the fastest peer, and the wave's modeled time takes
//!   the cheaper of the two completion paths
//!   ([`SpeculationStats`] records who won).

use std::collections::HashMap;
use std::fmt;
use std::hash::Hash;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::cluster::{ElasticCluster, FaultTracker, WavePhase};
use crate::dist::{BucketRouter, DistHashMap, KeyRouter};
use crate::metrics::PeakTracker;
use crate::mpi::{Communicator, Rank};
use crate::serial::{to_bytes, FastSerialize};
use crate::store::{CheckpointMeta, CheckpointStats, CheckpointStore};

use super::job::JobStats;
use super::monoid::Monoid;

/// Apply the entries of a mid-run elasticity plan due at `iteration` to
/// `elastic`: each `(at, node_delta)` pair with `at == iteration` grows
/// (`> 0`) or shrinks (`< 0`) the cluster by that many nodes. The shared
/// driver-loop helper for iterative apps (`pagerank::run_dist`,
/// `components::run_dist`): the next [`IterativeJob::step`] sees the new
/// width and migrates.
pub fn apply_resizes(
    elastic: &mut ElasticCluster,
    resizes: &[(usize, i64)],
    iteration: usize,
) -> Result<()> {
    for &(at, delta) in resizes {
        if at == iteration {
            if delta > 0 {
                elastic.grow(delta as usize);
            } else if delta < 0 {
                elastic.shrink(delta.unsigned_abs() as usize)?;
            }
            // delta == 0 is a no-op, not a phantom Grew{added: 0} event —
            // the audit log and router epoch must stay in step.
        }
    }
    Ok(())
}

/// The typed error a killed wave surfaces: the driver downcasts
/// (`err.downcast_ref::<WaveKilled>()`), replaces the dead membership
/// ([`ElasticCluster::kill_and_replace`]) and resumes from the last
/// checkpoint ([`IterativeJob::recover_from`]). After a `WaveKilled`
/// the session object itself is dead — the victim's shard went down
/// with its rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaveKilled {
    /// The rank that died.
    pub rank: usize,
    /// Iteration the wave was running.
    pub iteration: usize,
    /// Phase point the kill fired at.
    pub phase: WavePhase,
}

impl fmt::Display for WaveKilled {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rank {} killed at iteration {} in the {:?} phase",
            self.rank, self.iteration, self.phase
        )
    }
}

impl std::error::Error for WaveKilled {}

/// What a successful [`IterativeJob::step`] returns: the wave's cost
/// accounting plus the allreduced [`Monoid`] aggregate (typed, so
/// integer convergence checks are exact `==`, no float-identity hacks).
#[derive(Debug, Clone)]
pub struct StepOutcome<M> {
    pub stats: IterationStats,
    /// Global `measure` fold over every state, post-update.
    pub aggregate: M,
}

/// One wave's speculative re-execution verdict (only recorded when the
/// session's [`crate::cluster::FaultPlan`] carries slowdowns and a
/// straggler tripped the 2×-median detector).
#[derive(Debug, Clone, PartialEq)]
pub struct SpeculationStats {
    pub iteration: usize,
    /// Rank whose wave clock tripped the detector.
    pub straggler: usize,
    /// Fastest surviving rank, which re-claimed the straggler's shard
    /// task.
    pub backup: usize,
    /// The straggler's (slowed) wave clock.
    pub straggler_ms: f64,
    /// The backup path: the backup's own wave clock plus the shard's
    /// un-slowed re-execution.
    pub backup_ms: f64,
    /// Whether the backup path beat waiting out the straggler (the
    /// wave's modeled time takes the winner).
    pub backup_won: bool,
    /// [`FaultTracker`] attempt history for the wave's shard tasks.
    pub attempts: Vec<crate::cluster::TaskAttempt>,
}

/// What one [`IterativeJob::recover_from`] read and rebuilt.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryStats {
    /// Iteration the session resumed at (the checkpoint's).
    pub iteration: usize,
    /// Width the checkpoint was taken at.
    pub from_ranks: usize,
    /// Width recovered onto.
    pub to_ranks: usize,
    /// Router epoch after recovery (bumped iff the widths differ).
    pub epoch: u64,
    /// Bucket runs read off disk.
    pub runs_read: usize,
    /// Pairs restored.
    pub items: u64,
    /// Bytes read.
    pub bytes: u64,
    /// Modeled recovery read time.
    pub modeled_ms: f64,
}

/// What one [`IterativeJob::step`] cost and computed.
#[derive(Debug, Clone, PartialEq)]
pub struct IterationStats {
    /// 0-based iteration index within the session.
    pub iteration: usize,
    /// Width the wave ran at.
    pub ranks: usize,
    /// Router epoch the wave ran under (bumps on every resize).
    pub epoch: u64,
    /// Owned states that received at least one delta this wave, summed
    /// over all ranks (post-fold: a key hit by several ranks counts once
    /// — wire volume lives in `shuffled_bytes`, not here). Orphans are
    /// excluded.
    pub delta_keys: u64,
    /// Distinct delta keys addressed to states no rank holds — their
    /// folded deltas are dropped after the wave (0 for well-formed apps:
    /// graph contributions always target existing vertices).
    pub orphan_deltas: u64,
    /// Bytes this iteration's delta shuffle (and its collectives) put on
    /// the wire — the number the e12 figure compares to the engine path.
    pub shuffled_bytes: u64,
    pub messages: u64,
    pub remote_messages: u64,
    pub remote_bytes: u64,
    /// Modeled wave time: slowest rank's virtual clock.
    pub modeled_ms: f64,
    pub compute_ms: f64,
    pub net_ms: f64,
}

/// What one live shard migration (resize) cost.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationStats {
    /// The iteration the migration happened before (== steps completed).
    pub before_iteration: usize,
    pub from_ranks: usize,
    pub to_ranks: usize,
    /// Router epoch after the resize.
    pub epoch: u64,
    /// Buckets the resize reassigned (the [`BucketRouter::resize`] moves).
    pub buckets_moved: usize,
    /// Keys that changed owner.
    pub moved_keys: u64,
    /// Bytes the migration shuffle put on the wire.
    pub moved_bytes: u64,
    pub messages: u64,
    /// Modeled migration time: slowest rank's virtual clock.
    pub modeled_ms: f64,
}

/// An iterative session over per-key state `S` keyed by `K` (see the
/// module docs). Between waves the shards live with the driver (one slot
/// per rank), so the warm pool's threads stay stateless and a resize can
/// re-slot without a coordinator; *placement* is owned by the
/// [`BucketRouter`] throughout, and inside a wave each rank only ever
/// touches the shard that router says is its own.
pub struct IterativeJob<K, S> {
    router: BucketRouter,
    /// One shard per rank; `Some` between waves, taken inside a wave.
    slots: Vec<Mutex<Option<HashMap<K, S>>>>,
    /// Session-wide memory tracker: every wave's shuffle buffers charge
    /// here, so [`IterativeJob::job_stats`] reports a session peak.
    tracker: Arc<PeakTracker>,
    steps: usize,
    per_iteration: Vec<IterationStats>,
    migrations: Vec<MigrationStats>,
    /// Checkpoint sink + cadence, when attached.
    checkpoint: Option<(CheckpointStore<K, S>, usize)>,
    checkpoints: Vec<CheckpointStats>,
    speculations: Vec<SpeculationStats>,
    /// Set when this session was rebuilt by [`IterativeJob::recover_from`].
    recovery: Option<RecoveryStats>,
    /// Spans harvested from this session's waves and migrations (empty
    /// unless [`crate::trace`] was enabled around the steps).
    trace: Vec<crate::trace::SpanEvent>,
}

/// The `BLAZE_CHECKPOINT_EVERY` env override: a cadence `k >= 1` makes
/// every [`IterativeJob::load`] / [`IterativeJob::recover_from`]
/// auto-attach a checkpoint store at that cadence — the CI fault leg
/// forces `1` so the whole suite exercises the checkpoint write path.
pub fn env_checkpoint_every() -> Option<usize> {
    resolve_checkpoint_every(std::env::var("BLAZE_CHECKPOINT_EVERY").ok().as_deref())
}

fn resolve_checkpoint_every(env: Option<&str>) -> Option<usize> {
    env.and_then(|s| s.trim().parse().ok()).filter(|&k| k >= 1)
}

impl<K, S> IterativeJob<K, S>
where
    K: FastSerialize + Hash + Eq + Ord + Clone + Send,
    S: FastSerialize + Send + Clone,
{
    /// Partition `states` onto `cluster.ranks()` shards under the
    /// session router (salted with the cluster seed, like the engines'
    /// shuffle). Driver-side: no communication happens until the first
    /// [`IterativeJob::step`].
    pub fn load(
        cluster: &ElasticCluster,
        salt: u64,
        states: impl IntoIterator<Item = (K, S)>,
    ) -> Self {
        let ranks = cluster.ranks();
        let router = BucketRouter::new(ranks, cluster.config().seed ^ salt);
        let mut maps: Vec<HashMap<K, S>> = (0..ranks).map(|_| HashMap::new()).collect();
        for (k, s) in states {
            maps[router.route(&k).0].insert(k, s);
        }
        let mut job = Self {
            router,
            slots: maps.into_iter().map(|m| Mutex::new(Some(m))).collect(),
            tracker: PeakTracker::new(),
            steps: 0,
            per_iteration: Vec::new(),
            migrations: Vec::new(),
            checkpoint: None,
            checkpoints: Vec::new(),
            speculations: Vec::new(),
            recovery: None,
            trace: Vec::new(),
        };
        if let Some(k) = env_checkpoint_every() {
            job.checkpoint = Some((CheckpointStore::new(), k));
        }
        job
    }

    /// Snapshot the shards into `store` every `k` iterations (after the
    /// wave whose 1-based count divides `k`), alongside the wave's
    /// encoded aggregate — see the module docs. Replaces any store
    /// attached earlier (including the `BLAZE_CHECKPOINT_EVERY` one).
    pub fn checkpoint_every(&mut self, store: CheckpointStore<K, S>, k: usize) -> &mut Self {
        assert!(k >= 1, "checkpoint cadence must be >= 1");
        self.checkpoint = Some((store, k));
        self
    }

    /// Snapshot the live shards right now (driver-side, no
    /// communication, no aggregate). The periodic path through
    /// [`IterativeJob::step`] additionally saves the wave's aggregate.
    pub fn checkpoint_now(&mut self, store: &CheckpointStore<K, S>) -> Result<CheckpointStats> {
        self.write_checkpoint(store, Vec::new())
    }

    fn write_checkpoint(
        &mut self,
        store: &CheckpointStore<K, S>,
        aggregate: Vec<u8>,
    ) -> Result<CheckpointStats> {
        // Bucket every pair under the session router and key-sort each
        // bucket, so the snapshot is one sorted run per non-empty bucket
        // — the store's block format verbatim, and exactly the grain
        // recovery-onto-any-width needs.
        let mut chunks: Vec<Vec<(K, S)>> =
            (0..self.router.buckets()).map(|_| Vec::new()).collect();
        for slot in &self.slots {
            let guard = slot.lock().expect("slot lock");
            for (k, s) in guard.as_ref().expect("state present") {
                chunks[self.router.bucket_of(k)].push((k.clone(), s.clone()));
            }
        }
        let mut buckets = Vec::new();
        for (b, mut chunk) in chunks.into_iter().enumerate() {
            if chunk.is_empty() {
                continue;
            }
            chunk.sort_unstable_by(|x, y| x.0.cmp(&y.0));
            buckets.push((b, chunk));
        }
        let meta = CheckpointMeta {
            iteration: self.steps,
            salt: self.router.salt(),
            epoch: self.router.epoch(),
            ranks: self.router.width(),
            assign: self.router.assignments().to_vec(),
        };
        let stats = store.write(meta, buckets, aggregate)?;
        if crate::trace::enabled() {
            let start = crate::trace::vclock();
            let dur = (stats.modeled_ms * 1e6) as u64;
            crate::trace::span_manual(
                crate::trace::SpanKind::Checkpoint,
                start,
                start + dur,
                stats.bytes,
            );
        }
        self.checkpoints.push(stats.clone());
        Ok(stats)
    }

    /// Rebuild a session from the latest snapshot in `store` — recovery
    /// as an elastic resize from disk. The router comes back verbatim
    /// (salt, placement table, epoch); when `cluster`'s width differs
    /// from the checkpointed one, [`BucketRouter::resize`] re-homes the
    /// minimal bucket set using the per-run item counts as loads and
    /// bumps the epoch, exactly like a live resize. Same-width recovery
    /// keeps placement identical, so the continuation is bit-identical
    /// to the uninterrupted run. `Ok(None)` when the store has no
    /// snapshot yet (kill before the first checkpoint: reload from
    /// scratch instead).
    pub fn recover_from(
        cluster: &ElasticCluster,
        store: &CheckpointStore<K, S>,
    ) -> Result<Option<Self>> {
        let tracker = PeakTracker::new();
        let Some(restored) = store.restore(&tracker)? else {
            return Ok(None);
        };
        let meta = restored.meta;
        let mut router = BucketRouter::restore(meta.salt, meta.assign, meta.ranks, meta.epoch);
        let new_ranks = cluster.ranks();
        if new_ranks != meta.ranks {
            let mut loads = vec![0usize; router.buckets()];
            for (b, pairs) in &restored.buckets {
                loads[*b] = pairs.len();
            }
            router.resize(new_ranks, &loads);
        }
        let mut maps: Vec<HashMap<K, S>> = (0..new_ranks).map(|_| HashMap::new()).collect();
        let mut items = 0u64;
        let runs_read = restored.buckets.len();
        for (b, pairs) in restored.buckets {
            items += pairs.len() as u64;
            maps[router.rank_of_bucket(b).0].extend(pairs);
        }
        if crate::trace::enabled() {
            let start = crate::trace::vclock();
            let dur = (restored.modeled_ms * 1e6) as u64;
            crate::trace::span_manual(
                crate::trace::SpanKind::Recover,
                start,
                start + dur,
                restored.bytes,
            );
        }
        let recovery = RecoveryStats {
            iteration: meta.iteration,
            from_ranks: meta.ranks,
            to_ranks: new_ranks,
            epoch: router.epoch(),
            runs_read,
            items,
            bytes: restored.bytes,
            modeled_ms: restored.modeled_ms,
        };
        let mut job = Self {
            router,
            slots: maps.into_iter().map(|m| Mutex::new(Some(m))).collect(),
            tracker,
            steps: meta.iteration,
            per_iteration: Vec::new(),
            migrations: Vec::new(),
            checkpoint: None,
            checkpoints: Vec::new(),
            speculations: Vec::new(),
            recovery: Some(recovery),
            trace: Vec::new(),
        };
        if let Some(k) = env_checkpoint_every() {
            job.checkpoint = Some((store.clone(), k));
        }
        Ok(Some(job))
    }

    /// The session router (placement + epoch).
    pub fn router(&self) -> &BucketRouter {
        &self.router
    }

    /// Current session width (ranks the state is sharded over).
    pub fn ranks(&self) -> usize {
        self.router.width()
    }

    /// Iterations completed.
    pub fn steps_run(&self) -> usize {
        self.steps
    }

    pub fn per_iteration(&self) -> &[IterationStats] {
        &self.per_iteration
    }

    pub fn migrations(&self) -> &[MigrationStats] {
        &self.migrations
    }

    /// Checkpoints written this session (periodic and explicit).
    pub fn checkpoints(&self) -> &[CheckpointStats] {
        &self.checkpoints
    }

    /// Speculative re-execution verdicts recorded this session.
    pub fn speculations(&self) -> &[SpeculationStats] {
        &self.speculations
    }

    /// How this session was recovered, when it came from
    /// [`IterativeJob::recover_from`].
    pub fn recovery(&self) -> Option<&RecoveryStats> {
        self.recovery.as_ref()
    }

    /// Drain the spans this session's waves and migrations recorded
    /// (empty unless [`crate::trace`] tracing was enabled around the
    /// steps). Feed them to [`crate::trace::JobTrace::merge`] alongside
    /// the driver's own buffer.
    pub fn take_trace(&mut self) -> Vec<crate::trace::SpanEvent> {
        std::mem::take(&mut self.trace)
    }

    /// Total states across all shards (driver-side).
    pub fn len_global(&self) -> usize {
        self.slots
            .iter()
            .map(|s| s.lock().expect("slot lock").as_ref().expect("state present").len())
            .sum()
    }

    /// Visit every `(K, S)` state (driver-side, between waves). Shard
    /// order is rank order; order within a shard is unspecified.
    pub fn for_each_state(&self, mut f: impl FnMut(&K, &S)) {
        for slot in &self.slots {
            let guard = slot.lock().expect("slot lock");
            for (k, s) in guard.as_ref().expect("state present") {
                f(k, s);
            }
        }
    }

    /// Dissolve the session, keeping every state.
    pub fn into_states(self) -> Vec<(K, S)> {
        self.slots
            .into_iter()
            .flat_map(|slot| slot.into_inner().expect("slot lock").expect("state present"))
            .collect()
    }

    /// Session totals as a [`JobStats`]: sums over every iteration plus
    /// every migration (migration bytes land in
    /// [`JobStats::migrated_bytes`], *not* `shuffle_bytes`). The caller
    /// fills `startup_ms`/`host_wall_ms`, which belong to its cluster
    /// profile and wall clock.
    pub fn job_stats(&self) -> JobStats {
        let mut s = JobStats::default();
        for it in &self.per_iteration {
            s.modeled_ms += it.modeled_ms;
            s.compute_ms += it.compute_ms;
            s.net_ms += it.net_ms;
            s.shuffle_bytes += it.shuffled_bytes;
            s.messages += it.messages;
            s.remote_messages += it.remote_messages;
            s.remote_bytes += it.remote_bytes;
        }
        for m in &self.migrations {
            s.modeled_ms += m.modeled_ms;
            s.messages += m.messages;
            s.migrated_bytes += m.moved_bytes;
        }
        // Checkpoint writes and the recovery read are session time too
        // (modeled disk, no wire traffic).
        for c in &self.checkpoints {
            s.modeled_ms += c.modeled_ms;
        }
        if let Some(r) = &self.recovery {
            s.modeled_ms += r.modeled_ms;
        }
        s.peak_mem_bytes = self.tracker.peak_bytes();
        s
    }

    /// Apply a pending [`ElasticCluster`] resize to the live shards: no-op
    /// while the widths agree; otherwise [`BucketRouter::resize`] picks
    /// the minimal-move bucket set from the live per-bucket loads, the
    /// moving keys ride one `alltoallv` shuffle on the *new* pool, and
    /// the router epoch bumps. [`IterativeJob::step`] calls this
    /// implicitly, so a mid-run `grow`/`shrink` simply takes effect at
    /// the next wave boundary — DELMA semantics, now including the data.
    pub fn rebalance(&mut self, cluster: &mut ElasticCluster) -> Result<Option<MigrationStats>> {
        let new_ranks = cluster.ranks();
        let old_ranks = self.router.width();
        if new_ranks == old_ranks {
            return Ok(None);
        }

        // Bucket loads from the live shards (driver-side: state sits
        // between waves, so no collective is needed to agree on them).
        let mut loads = vec![0usize; self.router.buckets()];
        for slot in &self.slots {
            let guard = slot.lock().expect("slot lock");
            for k in guard.as_ref().expect("state present").keys() {
                loads[self.router.bucket_of(k)] += 1;
            }
        }
        let moves = self.router.resize(new_ranks, &loads);

        // Re-slot carried shards onto the new width. Shrunk-away slots
        // ride along with a surviving holder; whatever the holder does
        // not own under the new table is staged onto the wire below.
        let old_slots = std::mem::take(&mut self.slots);
        let mut carried: Vec<HashMap<K, S>> = (0..new_ranks).map(|_| HashMap::new()).collect();
        for (r, slot) in old_slots.into_iter().enumerate() {
            let map = slot.into_inner().expect("slot lock").expect("state present");
            let dst = &mut carried[r % new_ranks];
            if dst.is_empty() {
                *dst = map;
            } else {
                dst.extend(map);
            }
        }
        self.slots = carried.into_iter().map(|m| Mutex::new(Some(m))).collect();

        // The migration wave: keep what the new table says is ours,
        // flush the rest to its owner. Keys are globally unique, so no
        // two arrivals collide (the combine is defensively
        // last-writer-wins).
        let router = &self.router;
        let slots = &self.slots;
        let tracker = &self.tracker;
        let pool = cluster.pool_for_wave();
        let out = pool.run_job(new_ranks, |comm: &Communicator| -> Result<u64> {
            let _migrate_span = crate::trace::span(crate::trace::SpanKind::Migrate);
            let me = comm.rank().0;
            let held = slots[me].lock().expect("slot lock").take().expect("state present");
            let (keep, movers) = comm.timed(|| {
                let mut keep = HashMap::with_capacity(held.len());
                let mut movers: Vec<(K, S)> = Vec::new();
                for (k, s) in held {
                    if router.route(&k) == comm.rank() {
                        keep.insert(k, s);
                    } else {
                        movers.push((k, s));
                    }
                }
                (keep, movers)
            });
            let moved = movers.len() as u64;
            let mut shard: DistHashMap<'_, K, S, BucketRouter> =
                DistHashMap::from_local(comm, router.clone(), keep, tracker.clone());
            for (k, s) in movers {
                shard.stage(k, s);
            }
            let flushed = shard.flush(|acc, v| *acc = v);
            // Restore the slot either way: on a failed exchange the
            // session is poisoned (the Err propagates, and movers that
            // were in flight are gone with the wire — `DistHashMap::flush`
            // semantics), but the kept states stay reachable and later
            // calls error instead of panicking on a vacant slot.
            *slots[me].lock().expect("slot lock") = Some(shard.into_local());
            flushed?;
            Ok(moved)
        });

        self.trace.extend(out.trace);
        let mut moved_keys = 0u64;
        for (i, r) in out.results.into_iter().enumerate() {
            moved_keys += r.map_err(|e| anyhow!("rank {i} failed during migration: {e:#}"))?;
        }
        let slowest =
            out.clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
        let stats = MigrationStats {
            before_iteration: self.steps,
            from_ranks: old_ranks,
            to_ranks: new_ranks,
            epoch: self.router.epoch(),
            buckets_moved: moves.len(),
            moved_keys,
            moved_bytes: out.traffic.bytes,
            messages: out.traffic.messages,
            modeled_ms: slowest.0 as f64 / 1e6,
        };
        self.migrations.push(stats.clone());
        Ok(Some(stats))
    }

    /// Run one iteration wave (see the module docs):
    ///
    /// * `contribute(k, s, emit)` — emit `(target_key, delta)`
    ///   contributions from one state; run in sorted-key order.
    /// * `combine(acc, d)` — fold two deltas for the same key. Must be
    ///   associative **and commutative**: it is applied stage-side
    ///   (pre-wire) as well as owner-side.
    /// * `update(k, s, folded)` — apply the folded delta (or `None` when
    ///   nothing arrived for `k`) to the state, in place.
    /// * `measure(k, s)` — per-state [`Monoid`] summand, folded globally
    ///   post-update into [`StepOutcome::aggregate`] (a convergence
    ///   delta, a normalizer, a changed-count — one allreduce, no extra
    ///   wave; the fold order is fixed, so integer carriers are exact).
    ///
    /// A pending cluster resize is applied (shards migrated, epoch
    /// bumped) before the wave runs; a pending [`crate::cluster::FaultPlan`]
    /// kill for this iteration is armed before dispatch and surfaces as
    /// a [`WaveKilled`] error; after a successful wave the checkpoint
    /// cadence (if any) may snapshot the shards.
    pub fn step<D, M>(
        &mut self,
        cluster: &mut ElasticCluster,
        contribute: impl Fn(&K, &S, &mut dyn FnMut(K, D)) + Sync,
        combine: impl Fn(&mut D, D) + Sync,
        update: impl Fn(&K, &mut S, Option<D>) + Sync,
        measure: impl Fn(&K, &S) -> M + Sync,
    ) -> Result<StepOutcome<M>>
    where
        D: FastSerialize + Send,
        M: Monoid,
    {
        self.rebalance(cluster)?;
        let ranks = self.router.width();
        let iteration = self.steps;
        // Fault injection is decided here, *before* dispatch, so the
        // kill is global data every rank sees: the victim panics at the
        // phase point and survivors return early without entering any
        // collective — nobody wedges in a recv (see mpi/pool.rs).
        let kill = cluster.arm_kill(iteration, ranks);
        if let Some(k) = &kill {
            // Driver-side marker: the injected death is a scheduling
            // decision, not something any rank's span buffer survives.
            crate::trace::instant(crate::trace::SpanKind::Kill, k.rank as u64, 0, 0, 0);
        }
        let slowdowns: Vec<(usize, f64)> =
            cluster.fault_plan().map(|p| p.slowdowns().to_vec()).unwrap_or_default();
        let router = &self.router;
        let slots = &self.slots;
        let tracker = &self.tracker;
        let contribute = &contribute;
        let combine = &combine;
        let update = &update;
        let measure = &measure;
        let kill_ref = &kill;
        let slow_ref = &slowdowns;
        let pool = cluster.pool_for_wave();
        let wave = |comm: &Communicator| -> Result<(u64, u64, M, u64)> {
            let _wave_span = crate::trace::span(crate::trace::SpanKind::Wave);
            let me = comm.rank().0;
            let mut shard = slots[me].lock().expect("slot lock").take().expect("state present");
            if let Some(k) = kill_ref.as_ref().filter(|k| k.phase == WavePhase::Contribute) {
                if k.rank == me {
                    // The unwind drops the taken shard: like real process
                    // death, the victim's in-memory state is gone.
                    panic!("injected kill: rank {me} at iteration {iteration} (Contribute)");
                }
                *slots[me].lock().expect("slot lock") = Some(shard);
                return Err(anyhow!("wave aborted: rank {} killed at iteration {iteration}", k.rank));
            }
            // Sorted-key wave order: deterministic emission, and the
            // owner-side fold order below is source-rank order — so a
            // rerun is bit-identical.
            let contribute_span = crate::trace::span(crate::trace::SpanKind::Contribute);
            let mut keys: Vec<K> = shard.keys().cloned().collect();
            comm.timed(|| keys.sort_unstable());
            let mut deltas: DistHashMap<'_, K, D, BucketRouter> =
                DistHashMap::from_local(comm, router.clone(), HashMap::new(), tracker.clone());
            comm.timed(|| {
                for k in &keys {
                    contribute(k, &shard[k], &mut |dk, dv| deltas.stage(dk, dv));
                }
            });
            drop(contribute_span);
            if let Some(k) = kill_ref.as_ref().filter(|k| k.phase == WavePhase::Flush) {
                if k.rank == me {
                    panic!("injected kill: rank {me} at iteration {iteration} (Flush)");
                }
                *slots[me].lock().expect("slot lock") = Some(shard);
                return Err(anyhow!("wave aborted: rank {} killed at iteration {iteration}", k.rank));
            }
            let flush_span = crate::trace::span(crate::trace::SpanKind::Flush);
            if let Err(e) = deltas.flush_combining(combine) {
                // Restore the (untouched) shard so the session surfaces
                // the Err instead of panicking on a vacant slot later.
                *slots[me].lock().expect("slot lock") = Some(shard);
                return Err(e);
            }
            drop(flush_span);
            let arrived = deltas.len_local() as u64;
            let mut folded = deltas.into_local();
            if let Some(k) = kill_ref.as_ref().filter(|k| k.phase == WavePhase::Update) {
                if k.rank == me {
                    panic!("injected kill: rank {me} at iteration {iteration} (Update)");
                }
                *slots[me].lock().expect("slot lock") = Some(shard);
                return Err(anyhow!("wave aborted: rank {} killed at iteration {iteration}", k.rank));
            }
            let update_span = crate::trace::span(crate::trace::SpanKind::Update);
            let aggregate = comm.timed(|| {
                let mut agg = M::identity();
                for k in &keys {
                    let s = shard.get_mut(k).expect("owned key");
                    update(k, s, folded.remove(k));
                    agg = M::combine(agg, measure(k, &*s));
                }
                agg
            });
            drop(update_span);
            let orphans = folded.len() as u64;
            let aggregate = match comm.allreduce(aggregate, M::combine) {
                Ok(agg) => agg,
                Err(e) => {
                    *slots[me].lock().expect("slot lock") = Some(shard);
                    return Err(e);
                }
            };
            // Injected virtual-clock slowdown: inflate this rank's wave
            // clock *after* the collectives (the straggler stands out in
            // the per-rank clocks instead of dragging peers' wait time
            // along — that is the signal the speculation detector reads).
            let mut extra_ns = 0u64;
            if let Some(&(_, f)) = slow_ref.iter().find(|(r, _)| *r == me) {
                if f > 1.0 {
                    extra_ns = (comm.compute_ns() as f64 * (f - 1.0)) as u64;
                    comm.advance(extra_ns);
                }
            }
            *slots[me].lock().expect("slot lock") = Some(shard);
            // `arrived` counted every post-fold key on this owner before
            // classification; orphans are not received-by-a-state.
            Ok((arrived - orphans, orphans, aggregate, extra_ns))
        };
        let out = if kill.is_some() {
            match pool.try_run_on(ranks, wave) {
                Ok(out) => out,
                Err(_panic) => {
                    let k = kill.expect("kill was armed");
                    return Err(anyhow::Error::new(WaveKilled {
                        rank: k.rank,
                        iteration,
                        phase: k.phase,
                    }));
                }
            }
        } else {
            pool.run_job(ranks, wave)
        };

        self.trace.extend(out.trace);
        let mut delta_keys = 0u64;
        let mut orphans = 0u64;
        let mut aggregate = M::identity();
        let mut extras = vec![0u64; ranks];
        for (i, r) in out.results.into_iter().enumerate() {
            let (a, o, g, x) =
                r.map_err(|e| anyhow!("rank {i} failed at iteration {iteration}: {e:#}"))?;
            delta_keys += a;
            orphans += o;
            // The allreduce left the identical fold on every rank.
            aggregate = g;
            extras[i] = x;
        }
        let slowest =
            out.clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
        let mut modeled_ns = slowest.0;

        // Speculative re-execution (Mariane's attempt bookkeeping at
        // wave grain): only consulted when the fault plan injects
        // slowdowns — an unfaulted session pays nothing.
        if !slowdowns.is_empty() && ranks >= 2 {
            let clocks: Vec<u64> = out.clocks.iter().map(|c| c.0).collect();
            let straggler =
                (0..ranks).max_by_key(|&r| (clocks[r], r)).expect("ranks >= 2");
            let smax = clocks[straggler];
            let mut sorted = clocks.clone();
            sorted.sort_unstable();
            let median = sorted[ranks / 2].max(1);
            if smax > 2 * median {
                let spec_tracker = FaultTracker::new(ranks);
                for r in 0..ranks {
                    let t = spec_tracker.claim_next(Rank(r)).expect("one task per rank");
                    debug_assert_eq!(t, r);
                }
                for r in 0..ranks {
                    if r != straggler {
                        spec_tracker.complete(r, Rank(r));
                    }
                }
                spec_tracker.mark_rank_failed(Rank(straggler));
                let backup = (0..ranks)
                    .filter(|&r| r != straggler)
                    .min_by_key(|&r| (clocks[r], r))
                    .expect("ranks >= 2");
                let t = spec_tracker.claim_next(Rank(backup)).expect("reclaimed task");
                debug_assert_eq!(t, straggler);
                spec_tracker.complete(t, Rank(backup));
                // The shard's re-execution is the same deterministic
                // computation minus the injected slowdown; the backup
                // starts it after finishing its own shard.
                let rerun_ns = smax.saturating_sub(extras[straggler]);
                let backup_ns = clocks[backup] + rerun_ns;
                let backup_won = backup_ns < smax;
                if backup_won {
                    let others = (0..ranks)
                        .filter(|&r| r != straggler)
                        .map(|r| clocks[r])
                        .max()
                        .unwrap_or(0);
                    modeled_ns = others.max(backup_ns);
                }
                // Driver-side marker: the straggler whose shard task was
                // re-claimed (the winner is in SpeculationStats).
                crate::trace::instant(
                    crate::trace::SpanKind::Speculate,
                    straggler as u64,
                    0,
                    0,
                    0,
                );
                self.speculations.push(SpeculationStats {
                    iteration,
                    straggler,
                    backup,
                    straggler_ms: smax as f64 / 1e6,
                    backup_ms: backup_ns as f64 / 1e6,
                    backup_won,
                    attempts: spec_tracker.history(),
                });
            }
        }

        let stats = IterationStats {
            iteration,
            ranks,
            epoch: self.router.epoch(),
            delta_keys,
            orphan_deltas: orphans,
            shuffled_bytes: out.traffic.bytes,
            messages: out.traffic.messages,
            remote_messages: out.traffic.remote_messages,
            remote_bytes: out.traffic.remote_bytes,
            modeled_ms: modeled_ns as f64 / 1e6,
            compute_ms: slowest.1 as f64 / 1e6,
            net_ms: slowest.2 as f64 / 1e6,
        };
        self.steps += 1;
        self.per_iteration.push(stats.clone());
        if let Some((store, k)) = self.checkpoint.clone() {
            if self.steps % k == 0 {
                self.write_checkpoint(&store, to_bytes(&aggregate))?;
            }
        }
        Ok(StepOutcome { stats, aggregate })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn elastic(ranks: usize) -> ElasticCluster {
        ElasticCluster::new(ClusterConfig::builder().ranks(ranks).build())
    }

    fn counting_job(cluster: &ElasticCluster, n: u32) -> IterativeJob<u32, u64> {
        IterativeJob::load(cluster, 7, (0..n).map(|k| (k, k as u64)))
    }

    #[test]
    fn load_places_every_state_with_its_router_owner() {
        let cluster = elastic(3);
        let job = counting_job(&cluster, 50);
        assert_eq!(job.len_global(), 50);
        assert_eq!(job.ranks(), 3);
        let router = job.router().clone();
        for (r, slot) in job.slots.iter().enumerate() {
            let guard = slot.lock().unwrap();
            for k in guard.as_ref().unwrap().keys() {
                assert_eq!(router.route(k).0, r, "key {k} placed off-owner");
            }
        }
    }

    #[test]
    fn step_exchanges_deltas_and_updates_in_place() {
        // Each key sends its value to key+1 (mod n); update adds the
        // arrival. A ring like this touches every rank pair over enough
        // keys, and the result is exactly computable.
        let n = 40u32;
        let mut cluster = elastic(4);
        let mut job = counting_job(&cluster, n);
        let out = job
            .step(
                &mut cluster,
                |k: &u32, s: &u64, emit: &mut dyn FnMut(u32, u64)| emit((k + 1) % n, *s),
                |acc: &mut u64, v: u64| *acc += v,
                |_k: &u32, s: &mut u64, d: Option<u64>| *s += d.expect("ring covers every key"),
                |_k: &u32, s: &u64| *s,
            )
            .unwrap();
        let stats = out.stats;
        assert_eq!(stats.iteration, 0);
        assert_eq!(stats.ranks, 4);
        assert_eq!(stats.orphan_deltas, 0);
        assert_eq!(stats.delta_keys, n as u64, "every key receives exactly one delta");
        assert!(stats.shuffled_bytes > 0, "cross-rank deltas must hit the wire");
        // New total = old total + every shipped value = 2 * sum(0..n);
        // the u64 monoid fold is exact.
        let want = (0..n as u64).sum::<u64>() * 2;
        assert_eq!(out.aggregate, want);
        let mut got: Vec<(u32, u64)> = job.into_states();
        got.sort_unstable();
        let want_states: Vec<(u32, u64)> =
            (0..n).map(|k| (k, k as u64 + ((k + n - 1) % n) as u64)).collect();
        assert_eq!(got, want_states);
    }

    #[test]
    fn steps_are_deterministic_across_reruns() {
        let run = || {
            let mut cluster = elastic(3);
            let mut job = counting_job(&cluster, 64);
            for _ in 0..4 {
                job.step(
                    &mut cluster,
                    |k: &u32, s: &u64, emit: &mut dyn FnMut(u32, u64)| {
                        emit(k.wrapping_mul(7) % 64, *s % 17)
                    },
                    |acc: &mut u64, v: u64| *acc = acc.wrapping_add(v),
                    |_k, s: &mut u64, d: Option<u64>| {
                        *s = s.wrapping_add(d.unwrap_or(0)).rotate_left(3)
                    },
                    |_k, s: &u64| *s % 1024,
                )
                .unwrap();
            }
            let mut states = job.into_states();
            states.sort_unstable();
            states
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn rebalance_is_noop_without_a_resize() {
        let mut cluster = elastic(2);
        let mut job = counting_job(&cluster, 20);
        assert!(job.rebalance(&mut cluster).unwrap().is_none());
        assert!(job.migrations().is_empty());
        assert_eq!(job.router().epoch(), 0);
    }

    #[test]
    fn grow_then_shrink_preserves_every_state() {
        let mut cluster = elastic(2);
        let mut job = counting_job(&cluster, 100);
        cluster.grow(2);
        let grown = job.rebalance(&mut cluster).unwrap().expect("width changed");
        assert_eq!(grown.from_ranks, 2);
        assert_eq!(grown.to_ranks, 4);
        assert_eq!(grown.epoch, 1);
        assert!(grown.moved_keys > 0);
        assert!(grown.moved_bytes > 0);
        // Min-mass: growing 2 -> 4 should move about half, never ~all.
        assert!(grown.moved_keys < 80, "moved {} of 100", grown.moved_keys);
        cluster.shrink(3).unwrap();
        job.rebalance(&mut cluster).unwrap().expect("width changed");
        assert_eq!(job.ranks(), 1);
        assert_eq!(job.len_global(), 100);
        let mut got = job.into_states();
        got.sort_unstable();
        assert_eq!(got, (0..100u32).map(|k| (k, k as u64)).collect::<Vec<_>>());
    }

    #[test]
    fn step_applies_pending_resize_and_keeps_computing() {
        let n = 60u32;
        let compute = |job: &mut IterativeJob<u32, u64>, cluster: &mut ElasticCluster| {
            job.step(
                cluster,
                |k: &u32, s: &u64, emit: &mut dyn FnMut(u32, u64)| emit((k + 3) % n, *s + 1),
                |acc: &mut u64, v: u64| *acc += v,
                |_k, s: &mut u64, d: Option<u64>| *s += d.unwrap_or(0),
                |_k, s: &u64| *s,
            )
            .unwrap()
            .stats
        };
        // Resized run: grow mid-run, shrink later.
        let mut cluster = elastic(2);
        let mut job = counting_job(&cluster, n);
        for it in 0..5 {
            if it == 2 {
                cluster.grow(2);
            }
            if it == 4 {
                cluster.shrink(1).unwrap();
            }
            let stats = compute(&mut job, &mut cluster);
            assert_eq!(stats.ranks, cluster.ranks(), "wave must run at the live width");
        }
        assert_eq!(job.migrations().len(), 2);
        assert_eq!(job.router().epoch(), 2);
        let mut resized = job.into_states();
        resized.sort_unstable();
        // Straight-through run: same program, no resizes.
        let mut cluster2 = elastic(2);
        let mut job2 = counting_job(&cluster2, n);
        for _ in 0..5 {
            compute(&mut job2, &mut cluster2);
        }
        let mut straight = job2.into_states();
        straight.sort_unstable();
        assert_eq!(resized, straight, "resize must be invisible to integer results");
    }

    #[test]
    fn orphan_deltas_are_counted_not_lost() {
        let mut cluster = elastic(2);
        let mut job = counting_job(&cluster, 10);
        let stats = job
            .step(
                &mut cluster,
                // Key 3 contributes to a key nobody owns.
                |k: &u32, _s: &u64, emit: &mut dyn FnMut(u32, u64)| {
                    if *k == 3 {
                        emit(999, 1);
                    }
                },
                |acc: &mut u64, v: u64| *acc += v,
                |_k, _s: &mut u64, d: Option<u64>| assert!(d.is_none()),
                |_k, _s: &u64| (),
            )
            .unwrap()
            .stats;
        assert_eq!(stats.orphan_deltas, 1);
        assert_eq!(stats.delta_keys, 0, "no owned state received anything");
        assert_eq!(job.len_global(), 10, "owned states unaffected");
    }

    #[test]
    fn checkpoint_then_recover_continues_bit_identically() {
        use crate::store::CheckpointStore;
        let n = 48u32;
        let compute = |job: &mut IterativeJob<u32, u64>, cluster: &mut ElasticCluster| {
            job.step(
                cluster,
                |k: &u32, s: &u64, emit: &mut dyn FnMut(u32, u64)| emit((k + 5) % n, *s % 23),
                |acc: &mut u64, v: u64| *acc = acc.wrapping_add(v),
                |_k, s: &mut u64, d: Option<u64>| *s = s.wrapping_add(d.unwrap_or(0)),
                |_k, s: &u64| *s,
            )
            .unwrap()
        };
        // Uninterrupted truth: 6 waves straight through.
        let mut cluster = elastic(3);
        let mut truth = counting_job(&cluster, n);
        for _ in 0..6 {
            compute(&mut truth, &mut cluster);
        }
        let mut want = truth.into_states();
        want.sort_unstable();
        // Checkpointed run: snapshot at wave 3, throw the session away,
        // recover onto the SAME width, finish the remaining 3 waves.
        let mut cluster = elastic(3);
        let mut job = counting_job(&cluster, n);
        let store: CheckpointStore<u32, u64> = CheckpointStore::new();
        job.checkpoint_every(store.clone(), 3);
        for _ in 0..3 {
            compute(&mut job, &mut cluster);
        }
        assert_eq!(store.latest_iteration(), Some(3));
        assert_eq!(job.checkpoints().len(), 1);
        drop(job); // the "failure"
        let mut back: IterativeJob<u32, u64> =
            IterativeJob::recover_from(&cluster, &store).unwrap().expect("snapshot present");
        assert_eq!(back.steps_run(), 3);
        assert_eq!(back.recovery().unwrap().epoch, 0, "same width keeps placement");
        for _ in 0..3 {
            compute(&mut back, &mut cluster);
        }
        let mut got = back.into_states();
        got.sort_unstable();
        assert_eq!(got, want, "recovery must be invisible to integer results");
        // And recovery onto a DIFFERENT width preserves the contents.
        let wide = elastic(5);
        let rewide: IterativeJob<u32, u64> =
            IterativeJob::recover_from(&wide, &store).unwrap().expect("snapshot present");
        assert_eq!(rewide.ranks(), 5);
        assert_eq!(rewide.recovery().unwrap().epoch, 1, "cross-width bumps the epoch");
        assert_eq!(rewide.len_global(), n as usize);
    }

    #[test]
    fn resolve_checkpoint_every_accepts_cadences_and_rejects_garbage() {
        assert_eq!(resolve_checkpoint_every(None), None);
        assert_eq!(resolve_checkpoint_every(Some("1")), Some(1));
        assert_eq!(resolve_checkpoint_every(Some(" 8 ")), Some(8));
        assert_eq!(resolve_checkpoint_every(Some("0")), None, "cadence 0 is meaningless");
        assert_eq!(resolve_checkpoint_every(Some("-3")), None);
        assert_eq!(resolve_checkpoint_every(Some("every")), None);
        assert_eq!(resolve_checkpoint_every(Some("")), None);
    }
}
