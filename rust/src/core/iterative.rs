//! The in-memory iterative engine: per-key state pinned rank-local
//! across iterations, with only contribution **deltas** crossing the
//! wire each wave — and live elastic rebalancing when the cluster grows
//! or shrinks mid-run.
//!
//! This is the M3R lesson applied to our stack (and the reason the dist
//! layer exists, per ROADMAP): the batch engines re-shuffle the world
//! every job, so an iterative app (PageRank, label propagation, k-means)
//! pays the full input+state exchange per iteration even though the
//! partitioning never changes. [`IterativeJob`] instead keys every
//! per-item state by one [`BucketRouter`] for the whole session:
//!
//! 1. [`IterativeJob::load`] partitions `(K, S)` states onto the ranks
//!    the router names — after that the state never moves (except for
//!    resizes, below).
//! 2. [`IterativeJob::step`] runs one wave on the session's warm
//!    [`crate::mpi::RankPool`]: each rank walks its own states in sorted
//!    key order emitting `(K, D)` **deltas**, the deltas ride one
//!    [`DistHashMap::flush_combining`] (stage-side pre-fold, so at most
//!    one delta per `(rank, key)` hits the wire) to their owners — the
//!    *same* router, so owner and state always coincide — and the owner
//!    applies `update` in place. A per-step `measure` fold is
//!    allreduced for free (convergence checks, normalizers).
//! 3. On [`crate::cluster::ElasticCluster::grow`]/`shrink`, the next
//!    `step` (or an explicit [`IterativeJob::rebalance`]) applies
//!    [`crate::dist::rebalance_plan`] through [`BucketRouter::resize`]:
//!    only the minimal-move bucket set migrates, over the same
//!    `alltoallv` shuffle, the router epoch is bumped, and the iteration
//!    resumes at the new width. Migrated bytes are reported per resize
//!    and in [`JobStats::migrated_bytes`].
//!
//! Determinism: contributions are emitted in sorted-key order, the
//! stage-side pre-fold accumulates per key in that order, and owners
//! fold arrivals in source-rank order — so repeated runs are
//! bit-identical, and runs across different widths/resizes differ only
//! by floating-point re-association in `combine`/`aggregate` (exactly
//! identical for integer deltas, ulp-level for `f64` sums).

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::cluster::ElasticCluster;
use crate::dist::{BucketRouter, DistHashMap, KeyRouter};
use crate::metrics::PeakTracker;
use crate::mpi::Communicator;
use crate::serial::FastSerialize;

use super::job::JobStats;

/// Apply the entries of a mid-run elasticity plan due at `iteration` to
/// `elastic`: each `(at, node_delta)` pair with `at == iteration` grows
/// (`> 0`) or shrinks (`< 0`) the cluster by that many nodes. The shared
/// driver-loop helper for iterative apps (`pagerank::run_dist`,
/// `components::run_dist`): the next [`IterativeJob::step`] sees the new
/// width and migrates.
pub fn apply_resizes(
    elastic: &mut ElasticCluster,
    resizes: &[(usize, i64)],
    iteration: usize,
) -> Result<()> {
    for &(at, delta) in resizes {
        if at == iteration {
            if delta > 0 {
                elastic.grow(delta as usize);
            } else if delta < 0 {
                elastic.shrink(delta.unsigned_abs() as usize)?;
            }
            // delta == 0 is a no-op, not a phantom Grew{added: 0} event —
            // the audit log and router epoch must stay in step.
        }
    }
    Ok(())
}

/// What one [`IterativeJob::step`] cost and computed.
#[derive(Debug, Clone, PartialEq)]
pub struct IterationStats {
    /// 0-based iteration index within the session.
    pub iteration: usize,
    /// Width the wave ran at.
    pub ranks: usize,
    /// Router epoch the wave ran under (bumps on every resize).
    pub epoch: u64,
    /// Owned states that received at least one delta this wave, summed
    /// over all ranks (post-fold: a key hit by several ranks counts once
    /// — wire volume lives in `shuffled_bytes`, not here). Orphans are
    /// excluded.
    pub delta_keys: u64,
    /// Distinct delta keys addressed to states no rank holds — their
    /// folded deltas are dropped after the wave (0 for well-formed apps:
    /// graph contributions always target existing vertices).
    pub orphan_deltas: u64,
    /// Global sum of `measure` over every state, post-update.
    pub aggregate: f64,
    /// Bytes this iteration's delta shuffle (and its collectives) put on
    /// the wire — the number the e12 figure compares to the engine path.
    pub shuffled_bytes: u64,
    pub messages: u64,
    pub remote_messages: u64,
    pub remote_bytes: u64,
    /// Modeled wave time: slowest rank's virtual clock.
    pub modeled_ms: f64,
    pub compute_ms: f64,
    pub net_ms: f64,
}

/// What one live shard migration (resize) cost.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationStats {
    /// The iteration the migration happened before (== steps completed).
    pub before_iteration: usize,
    pub from_ranks: usize,
    pub to_ranks: usize,
    /// Router epoch after the resize.
    pub epoch: u64,
    /// Buckets the resize reassigned (the [`BucketRouter::resize`] moves).
    pub buckets_moved: usize,
    /// Keys that changed owner.
    pub moved_keys: u64,
    /// Bytes the migration shuffle put on the wire.
    pub moved_bytes: u64,
    pub messages: u64,
    /// Modeled migration time: slowest rank's virtual clock.
    pub modeled_ms: f64,
}

/// An iterative session over per-key state `S` keyed by `K` (see the
/// module docs). Between waves the shards live with the driver (one slot
/// per rank), so the warm pool's threads stay stateless and a resize can
/// re-slot without a coordinator; *placement* is owned by the
/// [`BucketRouter`] throughout, and inside a wave each rank only ever
/// touches the shard that router says is its own.
pub struct IterativeJob<K, S> {
    router: BucketRouter,
    /// One shard per rank; `Some` between waves, taken inside a wave.
    slots: Vec<Mutex<Option<HashMap<K, S>>>>,
    /// Session-wide memory tracker: every wave's shuffle buffers charge
    /// here, so [`IterativeJob::job_stats`] reports a session peak.
    tracker: Arc<PeakTracker>,
    steps: usize,
    per_iteration: Vec<IterationStats>,
    migrations: Vec<MigrationStats>,
}

impl<K, S> IterativeJob<K, S>
where
    K: FastSerialize + Hash + Eq + Ord + Clone + Send,
    S: FastSerialize + Send,
{
    /// Partition `states` onto `cluster.ranks()` shards under the
    /// session router (salted with the cluster seed, like the engines'
    /// shuffle). Driver-side: no communication happens until the first
    /// [`IterativeJob::step`].
    pub fn load(
        cluster: &ElasticCluster,
        salt: u64,
        states: impl IntoIterator<Item = (K, S)>,
    ) -> Self {
        let ranks = cluster.ranks();
        let router = BucketRouter::new(ranks, cluster.config().seed ^ salt);
        let mut maps: Vec<HashMap<K, S>> = (0..ranks).map(|_| HashMap::new()).collect();
        for (k, s) in states {
            maps[router.route(&k).0].insert(k, s);
        }
        Self {
            router,
            slots: maps.into_iter().map(|m| Mutex::new(Some(m))).collect(),
            tracker: PeakTracker::new(),
            steps: 0,
            per_iteration: Vec::new(),
            migrations: Vec::new(),
        }
    }

    /// The session router (placement + epoch).
    pub fn router(&self) -> &BucketRouter {
        &self.router
    }

    /// Current session width (ranks the state is sharded over).
    pub fn ranks(&self) -> usize {
        self.router.width()
    }

    /// Iterations completed.
    pub fn steps_run(&self) -> usize {
        self.steps
    }

    pub fn per_iteration(&self) -> &[IterationStats] {
        &self.per_iteration
    }

    pub fn migrations(&self) -> &[MigrationStats] {
        &self.migrations
    }

    /// Total states across all shards (driver-side).
    pub fn len_global(&self) -> usize {
        self.slots
            .iter()
            .map(|s| s.lock().expect("slot lock").as_ref().expect("state present").len())
            .sum()
    }

    /// Visit every `(K, S)` state (driver-side, between waves). Shard
    /// order is rank order; order within a shard is unspecified.
    pub fn for_each_state(&self, mut f: impl FnMut(&K, &S)) {
        for slot in &self.slots {
            let guard = slot.lock().expect("slot lock");
            for (k, s) in guard.as_ref().expect("state present") {
                f(k, s);
            }
        }
    }

    /// Dissolve the session, keeping every state.
    pub fn into_states(self) -> Vec<(K, S)> {
        self.slots
            .into_iter()
            .flat_map(|slot| slot.into_inner().expect("slot lock").expect("state present"))
            .collect()
    }

    /// Session totals as a [`JobStats`]: sums over every iteration plus
    /// every migration (migration bytes land in
    /// [`JobStats::migrated_bytes`], *not* `shuffle_bytes`). The caller
    /// fills `startup_ms`/`host_wall_ms`, which belong to its cluster
    /// profile and wall clock.
    pub fn job_stats(&self) -> JobStats {
        let mut s = JobStats::default();
        for it in &self.per_iteration {
            s.modeled_ms += it.modeled_ms;
            s.compute_ms += it.compute_ms;
            s.net_ms += it.net_ms;
            s.shuffle_bytes += it.shuffled_bytes;
            s.messages += it.messages;
            s.remote_messages += it.remote_messages;
            s.remote_bytes += it.remote_bytes;
        }
        for m in &self.migrations {
            s.modeled_ms += m.modeled_ms;
            s.messages += m.messages;
            s.migrated_bytes += m.moved_bytes;
        }
        s.peak_mem_bytes = self.tracker.peak_bytes();
        s
    }

    /// Apply a pending [`ElasticCluster`] resize to the live shards: no-op
    /// while the widths agree; otherwise [`BucketRouter::resize`] picks
    /// the minimal-move bucket set from the live per-bucket loads, the
    /// moving keys ride one `alltoallv` shuffle on the *new* pool, and
    /// the router epoch bumps. [`IterativeJob::step`] calls this
    /// implicitly, so a mid-run `grow`/`shrink` simply takes effect at
    /// the next wave boundary — DELMA semantics, now including the data.
    pub fn rebalance(&mut self, cluster: &mut ElasticCluster) -> Result<Option<MigrationStats>> {
        let new_ranks = cluster.ranks();
        let old_ranks = self.router.width();
        if new_ranks == old_ranks {
            return Ok(None);
        }

        // Bucket loads from the live shards (driver-side: state sits
        // between waves, so no collective is needed to agree on them).
        let mut loads = vec![0usize; self.router.buckets()];
        for slot in &self.slots {
            let guard = slot.lock().expect("slot lock");
            for k in guard.as_ref().expect("state present").keys() {
                loads[self.router.bucket_of(k)] += 1;
            }
        }
        let moves = self.router.resize(new_ranks, &loads);

        // Re-slot carried shards onto the new width. Shrunk-away slots
        // ride along with a surviving holder; whatever the holder does
        // not own under the new table is staged onto the wire below.
        let old_slots = std::mem::take(&mut self.slots);
        let mut carried: Vec<HashMap<K, S>> = (0..new_ranks).map(|_| HashMap::new()).collect();
        for (r, slot) in old_slots.into_iter().enumerate() {
            let map = slot.into_inner().expect("slot lock").expect("state present");
            let dst = &mut carried[r % new_ranks];
            if dst.is_empty() {
                *dst = map;
            } else {
                dst.extend(map);
            }
        }
        self.slots = carried.into_iter().map(|m| Mutex::new(Some(m))).collect();

        // The migration wave: keep what the new table says is ours,
        // flush the rest to its owner. Keys are globally unique, so no
        // two arrivals collide (the combine is defensively
        // last-writer-wins).
        let router = &self.router;
        let slots = &self.slots;
        let tracker = &self.tracker;
        let pool = cluster.pool_for_wave();
        let out = pool.run_job(new_ranks, |comm: &Communicator| -> Result<u64> {
            let me = comm.rank().0;
            let held = slots[me].lock().expect("slot lock").take().expect("state present");
            let (keep, movers) = comm.timed(|| {
                let mut keep = HashMap::with_capacity(held.len());
                let mut movers: Vec<(K, S)> = Vec::new();
                for (k, s) in held {
                    if router.route(&k) == comm.rank() {
                        keep.insert(k, s);
                    } else {
                        movers.push((k, s));
                    }
                }
                (keep, movers)
            });
            let moved = movers.len() as u64;
            let mut shard: DistHashMap<'_, K, S, BucketRouter> =
                DistHashMap::from_local(comm, router.clone(), keep, tracker.clone());
            for (k, s) in movers {
                shard.stage(k, s);
            }
            let flushed = shard.flush(|acc, v| *acc = v);
            // Restore the slot either way: on a failed exchange the
            // session is poisoned (the Err propagates, and movers that
            // were in flight are gone with the wire — `DistHashMap::flush`
            // semantics), but the kept states stay reachable and later
            // calls error instead of panicking on a vacant slot.
            *slots[me].lock().expect("slot lock") = Some(shard.into_local());
            flushed?;
            Ok(moved)
        });

        let mut moved_keys = 0u64;
        for (i, r) in out.results.into_iter().enumerate() {
            moved_keys += r.map_err(|e| anyhow!("rank {i} failed during migration: {e:#}"))?;
        }
        let slowest =
            out.clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
        let stats = MigrationStats {
            before_iteration: self.steps,
            from_ranks: old_ranks,
            to_ranks: new_ranks,
            epoch: self.router.epoch(),
            buckets_moved: moves.len(),
            moved_keys,
            moved_bytes: out.traffic.bytes,
            messages: out.traffic.messages,
            modeled_ms: slowest.0 as f64 / 1e6,
        };
        self.migrations.push(stats.clone());
        Ok(Some(stats))
    }

    /// Run one iteration wave (see the module docs):
    ///
    /// * `contribute(k, s, emit)` — emit `(target_key, delta)`
    ///   contributions from one state; run in sorted-key order.
    /// * `combine(acc, d)` — fold two deltas for the same key. Must be
    ///   associative **and commutative**: it is applied stage-side
    ///   (pre-wire) as well as owner-side.
    /// * `update(k, s, folded)` — apply the folded delta (or `None` when
    ///   nothing arrived for `k`) to the state, in place.
    /// * `measure(k, s)` — per-state summand, folded globally post-update
    ///   into [`IterationStats::aggregate`] (a convergence delta, a
    ///   normalizer, a changed-count — one allreduce, no extra wave).
    ///
    /// A pending cluster resize is applied (shards migrated, epoch
    /// bumped) before the wave runs.
    pub fn step<D>(
        &mut self,
        cluster: &mut ElasticCluster,
        contribute: impl Fn(&K, &S, &mut dyn FnMut(K, D)) + Sync,
        combine: impl Fn(&mut D, D) + Sync,
        update: impl Fn(&K, &mut S, Option<D>) + Sync,
        measure: impl Fn(&K, &S) -> f64 + Sync,
    ) -> Result<IterationStats>
    where
        D: FastSerialize + Send,
    {
        self.rebalance(cluster)?;
        let ranks = self.router.width();
        let iteration = self.steps;
        let router = &self.router;
        let slots = &self.slots;
        let tracker = &self.tracker;
        let contribute = &contribute;
        let combine = &combine;
        let update = &update;
        let measure = &measure;
        let pool = cluster.pool_for_wave();
        let out = pool.run_job(ranks, |comm: &Communicator| -> Result<(u64, u64, f64)> {
            let me = comm.rank().0;
            let mut shard = slots[me].lock().expect("slot lock").take().expect("state present");
            // Sorted-key wave order: deterministic emission, and the
            // owner-side fold order below is source-rank order — so a
            // rerun is bit-identical.
            let mut keys: Vec<K> = shard.keys().cloned().collect();
            comm.timed(|| keys.sort_unstable());
            let mut deltas: DistHashMap<'_, K, D, BucketRouter> =
                DistHashMap::from_local(comm, router.clone(), HashMap::new(), tracker.clone());
            comm.timed(|| {
                for k in &keys {
                    contribute(k, &shard[k], &mut |dk, dv| deltas.stage(dk, dv));
                }
            });
            if let Err(e) = deltas.flush_combining(combine) {
                // Restore the (untouched) shard so the session surfaces
                // the Err instead of panicking on a vacant slot later.
                *slots[me].lock().expect("slot lock") = Some(shard);
                return Err(e);
            }
            let arrived = deltas.len_local() as u64;
            let mut folded = deltas.into_local();
            let aggregate = comm.timed(|| {
                let mut agg = 0.0f64;
                for k in &keys {
                    let s = shard.get_mut(k).expect("owned key");
                    update(k, s, folded.remove(k));
                    agg += measure(k, &*s);
                }
                agg
            });
            let orphans = folded.len() as u64;
            let aggregate = match comm.allreduce(aggregate, |a, b| a + b) {
                Ok(agg) => agg,
                Err(e) => {
                    *slots[me].lock().expect("slot lock") = Some(shard);
                    return Err(e);
                }
            };
            *slots[me].lock().expect("slot lock") = Some(shard);
            // `arrived` counted every post-fold key on this owner before
            // classification; orphans are not received-by-a-state.
            Ok((arrived - orphans, orphans, aggregate))
        });

        let mut delta_keys = 0u64;
        let mut orphans = 0u64;
        let mut aggregate = 0.0f64;
        for (i, r) in out.results.into_iter().enumerate() {
            let (a, o, g) =
                r.map_err(|e| anyhow!("rank {i} failed at iteration {iteration}: {e:#}"))?;
            delta_keys += a;
            orphans += o;
            aggregate = g;
        }
        let slowest =
            out.clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
        let stats = IterationStats {
            iteration,
            ranks,
            epoch: self.router.epoch(),
            delta_keys,
            orphan_deltas: orphans,
            aggregate,
            shuffled_bytes: out.traffic.bytes,
            messages: out.traffic.messages,
            remote_messages: out.traffic.remote_messages,
            remote_bytes: out.traffic.remote_bytes,
            modeled_ms: slowest.0 as f64 / 1e6,
            compute_ms: slowest.1 as f64 / 1e6,
            net_ms: slowest.2 as f64 / 1e6,
        };
        self.steps += 1;
        self.per_iteration.push(stats.clone());
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn elastic(ranks: usize) -> ElasticCluster {
        ElasticCluster::new(ClusterConfig::builder().ranks(ranks).build())
    }

    fn counting_job(cluster: &ElasticCluster, n: u32) -> IterativeJob<u32, u64> {
        IterativeJob::load(cluster, 7, (0..n).map(|k| (k, k as u64)))
    }

    #[test]
    fn load_places_every_state_with_its_router_owner() {
        let cluster = elastic(3);
        let job = counting_job(&cluster, 50);
        assert_eq!(job.len_global(), 50);
        assert_eq!(job.ranks(), 3);
        let router = job.router().clone();
        for (r, slot) in job.slots.iter().enumerate() {
            let guard = slot.lock().unwrap();
            for k in guard.as_ref().unwrap().keys() {
                assert_eq!(router.route(k).0, r, "key {k} placed off-owner");
            }
        }
    }

    #[test]
    fn step_exchanges_deltas_and_updates_in_place() {
        // Each key sends its value to key+1 (mod n); update adds the
        // arrival. A ring like this touches every rank pair over enough
        // keys, and the result is exactly computable.
        let n = 40u32;
        let mut cluster = elastic(4);
        let mut job = counting_job(&cluster, n);
        let stats = job
            .step(
                &mut cluster,
                |k: &u32, s: &u64, emit: &mut dyn FnMut(u32, u64)| emit((k + 1) % n, *s),
                |acc: &mut u64, v: u64| *acc += v,
                |_k: &u32, s: &mut u64, d: Option<u64>| *s += d.expect("ring covers every key"),
                |_k: &u32, s: &u64| *s as f64,
            )
            .unwrap();
        assert_eq!(stats.iteration, 0);
        assert_eq!(stats.ranks, 4);
        assert_eq!(stats.orphan_deltas, 0);
        assert_eq!(stats.delta_keys, n as u64, "every key receives exactly one delta");
        assert!(stats.shuffled_bytes > 0, "cross-rank deltas must hit the wire");
        // New total = old total + every shipped value = 2 * sum(0..n).
        let want = (0..n as u64).sum::<u64>() * 2;
        assert_eq!(stats.aggregate, want as f64);
        let mut got: Vec<(u32, u64)> = job.into_states();
        got.sort_unstable();
        let want_states: Vec<(u32, u64)> =
            (0..n).map(|k| (k, k as u64 + ((k + n - 1) % n) as u64)).collect();
        assert_eq!(got, want_states);
    }

    #[test]
    fn steps_are_deterministic_across_reruns() {
        let run = || {
            let mut cluster = elastic(3);
            let mut job = counting_job(&cluster, 64);
            for _ in 0..4 {
                job.step(
                    &mut cluster,
                    |k: &u32, s: &u64, emit: &mut dyn FnMut(u32, u64)| {
                        emit(k.wrapping_mul(7) % 64, *s % 17)
                    },
                    |acc: &mut u64, v: u64| *acc = acc.wrapping_add(v),
                    |_k, s: &mut u64, d: Option<u64>| {
                        *s = s.wrapping_add(d.unwrap_or(0)).rotate_left(3)
                    },
                    |_k, s: &u64| (*s % 1024) as f64,
                )
                .unwrap();
            }
            let mut states = job.into_states();
            states.sort_unstable();
            states
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn rebalance_is_noop_without_a_resize() {
        let mut cluster = elastic(2);
        let mut job = counting_job(&cluster, 20);
        assert!(job.rebalance(&mut cluster).unwrap().is_none());
        assert!(job.migrations().is_empty());
        assert_eq!(job.router().epoch(), 0);
    }

    #[test]
    fn grow_then_shrink_preserves_every_state() {
        let mut cluster = elastic(2);
        let mut job = counting_job(&cluster, 100);
        cluster.grow(2);
        let grown = job.rebalance(&mut cluster).unwrap().expect("width changed");
        assert_eq!(grown.from_ranks, 2);
        assert_eq!(grown.to_ranks, 4);
        assert_eq!(grown.epoch, 1);
        assert!(grown.moved_keys > 0);
        assert!(grown.moved_bytes > 0);
        // Min-mass: growing 2 -> 4 should move about half, never ~all.
        assert!(grown.moved_keys < 80, "moved {} of 100", grown.moved_keys);
        cluster.shrink(3).unwrap();
        job.rebalance(&mut cluster).unwrap().expect("width changed");
        assert_eq!(job.ranks(), 1);
        assert_eq!(job.len_global(), 100);
        let mut got = job.into_states();
        got.sort_unstable();
        assert_eq!(got, (0..100u32).map(|k| (k, k as u64)).collect::<Vec<_>>());
    }

    #[test]
    fn step_applies_pending_resize_and_keeps_computing() {
        let n = 60u32;
        let compute = |job: &mut IterativeJob<u32, u64>, cluster: &mut ElasticCluster| {
            job.step(
                cluster,
                |k: &u32, s: &u64, emit: &mut dyn FnMut(u32, u64)| emit((k + 3) % n, *s + 1),
                |acc: &mut u64, v: u64| *acc += v,
                |_k, s: &mut u64, d: Option<u64>| *s += d.unwrap_or(0),
                |_k, s: &u64| *s as f64,
            )
            .unwrap()
        };
        // Resized run: grow mid-run, shrink later.
        let mut cluster = elastic(2);
        let mut job = counting_job(&cluster, n);
        for it in 0..5 {
            if it == 2 {
                cluster.grow(2);
            }
            if it == 4 {
                cluster.shrink(1).unwrap();
            }
            let stats = compute(&mut job, &mut cluster);
            assert_eq!(stats.ranks, cluster.ranks(), "wave must run at the live width");
        }
        assert_eq!(job.migrations().len(), 2);
        assert_eq!(job.router().epoch(), 2);
        let mut resized = job.into_states();
        resized.sort_unstable();
        // Straight-through run: same program, no resizes.
        let mut cluster2 = elastic(2);
        let mut job2 = counting_job(&cluster2, n);
        for _ in 0..5 {
            compute(&mut job2, &mut cluster2);
        }
        let mut straight = job2.into_states();
        straight.sort_unstable();
        assert_eq!(resized, straight, "resize must be invisible to integer results");
    }

    #[test]
    fn orphan_deltas_are_counted_not_lost() {
        let mut cluster = elastic(2);
        let mut job = counting_job(&cluster, 10);
        let stats = job
            .step(
                &mut cluster,
                // Key 3 contributes to a key nobody owns.
                |k: &u32, _s: &u64, emit: &mut dyn FnMut(u32, u64)| {
                    if *k == 3 {
                        emit(999, 1);
                    }
                },
                |acc: &mut u64, v: u64| *acc += v,
                |_k, _s: &mut u64, d: Option<u64>| assert!(d.is_none()),
                |_k, _s: &u64| 0.0,
            )
            .unwrap();
        assert_eq!(stats.orphan_deltas, 1);
        assert_eq!(stats.delta_keys, 0, "no owned state received anything");
        assert_eq!(job.len_global(), 10, "owned states unaffected");
    }
}
