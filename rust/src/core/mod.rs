//! The MapReduce core: job configuration, emit contexts, shuffle, and the
//! three reduction engines.
//!
//! * [`classic`] — Hadoop-style: full map -> shuffle -> group -> reduce
//!   (the paper's Fig 1).
//! * [`eager`] — Blaze's Eager Reduction: combine into a thread-local
//!   cache *during* map, shuffle only combined pairs (Fig 2).
//! * [`delayed`] — the paper's contribution (§III.D, Figs 6-7): mappers
//!   stage locally key-ordered runs, runs are merge-sorted and shuffled,
//!   and the final reducer sees `(K, Iterable<V>)` — lazily.
//!
//! Classic and delayed both ride [`crate::store`]'s out-of-core sorted
//! runs: staged pairs past the cluster's spill threshold go to disk,
//! the shuffle exchanges them in budget-bounded rounds, and reducers
//! stream groups off a loser-tree merge — inputs past the node's memory
//! budget are first-class, not a crash.
//!
//! [`engine`] wraps a mode dispatch + metrics + result collection around
//! the SPMD bodies; [`scheduler`] adds dynamic task claiming (data-skew
//! mitigation) and fault-tolerant waves on top. [`dataflow`] lifts the
//! single-job surface into a typed multi-stage DAG: fused narrow
//! chains, co-partitioning-aware wide operators, a two-input join, and
//! an `explain()` plan introspection API. [`iterative`] is the
//! in-memory iterative layer (M3R-style): per-key state pinned
//! rank-local on a `BucketRouter`, delta-only waves, live elastic
//! rebalancing.

pub mod classic;
pub mod context;
pub mod dataflow;
pub mod delayed;
pub mod eager;
pub mod engine;
pub mod iterative;
pub mod job;
pub mod monoid;
pub mod partitioner;
pub mod scheduler;
pub mod shuffle;

pub use context::Emitter;
pub use dataflow::{DataflowOutput, Explain, ExplainStage, JoinStrategy, Stage, StageReport};
pub use delayed::DelayedOutput;
pub use engine::MapReduceJob;
pub use iterative::{
    apply_resizes, IterationStats, IterativeJob, MigrationStats, RecoveryStats, SpeculationStats,
    StepOutcome, WaveKilled,
};
pub use job::{JobConfig, JobResult, JobStats, ReductionMode, Scheduling};
pub use monoid::Monoid;
pub use partitioner::RangePartitioner;
pub use scheduler::{
    JobCtx, JobEvent, JobHandle, JobOutcome, SchedJobStats, Scheduler, SchedulerConfig,
    TaskFault, TaskFeed, TenantStats,
};
pub use shuffle::shuffle_runs;
