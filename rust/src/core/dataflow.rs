//! `dataflow` — a typed multi-stage DAG on top of the MapReduce core:
//! the Thrill-DIA-shaped layer (PAPERS.md) that turns the public API
//! from a single-job call into a query plan.
//!
//! A [`Stage<K, V>`] is a lazy plan node. **Narrow** operators
//! ([`Stage::map`], [`Stage::filter`], [`Stage::flat_map`],
//! [`Stage::map_values`]) never execute on their own: they chain into a
//! fused iterator ([`NarrowIter`]) that runs in one pass over
//! rank-local data when the next **wide** operator ([`Stage::group_by`],
//! [`Stage::reduce_by_key`], [`Stage::sort`], [`Stage::join`]) drains
//! it — Thrill's map-chain fusion, and M3R's discipline of keeping
//! intermediates rank-local between stages (no driver round-trip).
//!
//! Each stage carries a **co-partitioning property** ([`Partitioning`]):
//! wide operators leave their output hash-partitioned by key under the
//! plan's one salt, so a downstream wide operator over the same keys is
//! *shuffle-free* — `group_by` after `reduce_by_key` moves zero bytes,
//! and a two-input [`Stage::join`] over two keyed sides is a purely
//! local hash join. A repartition (one shuffle) is emitted only where
//! the partitioning actually changes, and [`Stage::explain`] shows
//! exactly where: stages, fused chains, and shuffle boundaries are a
//! plan property, testable before anything runs.
//!
//! Execution rides the existing machinery: [`crate::mpi::RankPool`]
//! SPMD ranks, [`crate::store`] sorted runs for out-of-core staging,
//! [`super::shuffle::shuffle_runs`] at repartition boundaries,
//! [`crate::dist::DistHashMap`] for hash-side builds, one
//! [`crate::trace::SpanKind::Stage`] span and one [`StageReport`]
//! (bytes + virtual clock) per plan stage.

use std::cmp::Ordering;
use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::hash::Hash;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::cluster::ClusterConfig;
use crate::dist::{DistHashMap, ShardRouter};
use crate::metrics::PeakTracker;
use crate::mpi::{Communicator, RankPool, Universe};
use crate::serial::FastSerialize;
use crate::store::{GroupStream, RunSet, RunWriter};

use super::job::JobStats;
use super::shuffle::{shuffle_pairs, shuffle_runs};

/// Domain separator folded into the cluster seed: every shuffle in one
/// plan routes with the same salt, which is what makes `Keyed` outputs
/// mutually co-partitioned (join sides land on the same owner rank by
/// construction).
const DATAFLOW_SALT: u64 = 0xDA7A_F10A_57A6_E500;

/// How a stage's output is distributed across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Partitioning {
    /// No known placement — a wide consumer must repartition (1 shuffle).
    Arbitrary,
    /// Hash-partitioned by key under the plan salt — any wide consumer
    /// over the same keys is shuffle-free.
    Keyed,
}

/// Rank-local intermediate between stages: either a fused lazy iterator
/// (narrow chains) or a key-sorted run set living in the out-of-core
/// run store (`sort` output). Never leaves the rank.
enum LocalData<K, V> {
    Iter(Box<dyn Iterator<Item = (K, V)>>),
    Runs(RunSet<K, V>),
}

impl<K, V> LocalData<K, V>
where
    K: FastSerialize + Hash + Eq + Ord + Send + 'static,
    V: FastSerialize + Send + 'static,
{
    fn into_rows(self) -> Result<Vec<(K, V)>> {
        match self {
            LocalData::Iter(it) => Ok(it.collect()),
            LocalData::Runs(runs) => {
                let mut merge = runs.into_merge()?;
                let mut rows = Vec::new();
                while let Some(pair) = merge.next()? {
                    rows.push(pair);
                }
                Ok(rows)
            }
        }
    }

    fn into_pairs(self) -> Result<Box<dyn Iterator<Item = (K, V)>>> {
        match self {
            LocalData::Iter(it) => Ok(it),
            data => Ok(Box::new(data.into_rows()?.into_iter())),
        }
    }
}

/// Per-rank execution context threaded through a plan: the SPMD
/// communicator plus the job-wide tracker/budget/salt, and the
/// per-stage measurements this rank recorded so far.
struct ExecCtx<'c> {
    comm: &'c Communicator,
    tracker: Arc<PeakTracker>,
    budget: u64,
    salt: u64,
    stats: Vec<RankStageStat>,
    spilled: u64,
}

/// One rank's measurement of one executed stage (merged across ranks
/// into a [`StageReport`] by the driver).
struct RankStageStat {
    label: String,
    shuffles: usize,
    bytes: u64,
    clock_ns: u64,
}

impl<'c> ExecCtx<'c> {
    /// Run `f` as one plan stage: wrap it in a
    /// [`crate::trace::SpanKind::Stage`] span and attribute the
    /// communicator's sent-byte and virtual-clock deltas to it. Fused
    /// narrow chains drain inside their consumer's `record`, so their
    /// cost lands in the consuming stage — that *is* the fusion.
    fn record<T>(
        &mut self,
        label: &str,
        shuffles: usize,
        f: impl FnOnce(&mut Self) -> Result<T>,
    ) -> Result<T> {
        let span = crate::trace::span(crate::trace::SpanKind::Stage);
        let bytes0 = self.comm.sent_bytes();
        let clock0 = self.comm.clock_ns();
        let out = f(self)?;
        let bytes = self.comm.sent_bytes().saturating_sub(bytes0);
        let clock_ns = self.comm.clock_ns().saturating_sub(clock0);
        span.add_bytes(bytes);
        self.stats.push(RankStageStat { label: label.to_string(), shuffles, bytes, clock_ns });
        Ok(out)
    }
}

/// Stage executor: producing a rank's [`LocalData`] shard, given the
/// rank context. Shared (`Arc`) so plans are cheap to clone and branch.
type Exec<K, V> =
    Arc<dyn for<'a, 'c> Fn(&'a mut ExecCtx<'c>) -> Result<LocalData<K, V>> + Send + Sync>;

/// Fused narrow transform: `(K, V)` in, zero or more `(K2, V2)` out
/// through the emit callback (the `flat_map` shape; `map` and `filter`
/// are special cases).
type NarrowFn<K, V, K2, V2> = Arc<dyn Fn(K, V, &mut dyn FnMut(K2, V2)) + Send + Sync>;

/// The lazy fused chain: pulls `(K, V)` from the upstream iterator and
/// pushes each pair through the narrow transform, queueing its
/// emissions. Nesting one `NarrowIter` inside another is exactly
/// map-chain fusion — the whole chain is one pass, no intermediate
/// collection.
struct NarrowIter<K, V, K2, V2> {
    inner: Box<dyn Iterator<Item = (K, V)>>,
    f: NarrowFn<K, V, K2, V2>,
    queue: VecDeque<(K2, V2)>,
}

impl<K, V, K2, V2> Iterator for NarrowIter<K, V, K2, V2> {
    type Item = (K2, V2);

    fn next(&mut self) -> Option<(K2, V2)> {
        loop {
            if let Some(pair) = self.queue.pop_front() {
                return Some(pair);
            }
            let (k, v) = self.inner.next()?;
            let q = &mut self.queue;
            let f = self.f.as_ref();
            f(k, v, &mut |k2, v2| q.push_back((k2, v2)));
        }
    }
}

/// Join algorithm selection for [`Stage::join_with`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JoinStrategy {
    /// Merge-join when both inputs are co-sorted runs, hash-join
    /// otherwise — resolved at plan-construction time, visible in
    /// `explain()` as `join(hash)` / `join(merge)`.
    Auto,
    /// Build a per-owner hash table of the right side, stream the left
    /// side through it.
    Hash,
    /// Lockstep group-merge over both sides' key-ordered run stores.
    Merge,
}

/// One node of the introspectable plan tree: a wide (or source) op, the
/// narrow chain fused onto its output, and how many shuffles executing
/// it emits (0 when its input is already co-partitioned).
#[derive(Clone, Debug)]
struct PlanNode {
    op: String,
    fused: Vec<String>,
    shuffles: usize,
    inputs: Vec<PlanNode>,
}

/// One stage of an [`Explain`] listing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExplainStage {
    /// The wide/source operator executing this stage.
    pub op: String,
    /// Narrow operators fused into this stage's output pass.
    pub fused: Vec<String>,
    /// Repartition shuffles this stage emits (0 = co-partitioned).
    pub shuffles: usize,
}

/// Plan introspection: the stages a `collect()` will execute, in
/// execution order, with fusion and shuffle boundaries — a plan
/// property, assertable without running anything.
#[derive(Clone, Debug)]
pub struct Explain {
    pub stages: Vec<ExplainStage>,
}

impl Explain {
    /// Total repartition boundaries in the plan.
    pub fn total_shuffles(&self) -> usize {
        self.stages.iter().map(|s| s.shuffles).sum()
    }
}

impl fmt::Display for Explain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "plan: {} stages, {} shuffle(s)",
            self.stages.len(),
            self.total_shuffles()
        )?;
        for (i, s) in self.stages.iter().enumerate() {
            let fused = if s.fused.is_empty() {
                String::new()
            } else {
                format!(" + fused[{}]", s.fused.join(" -> "))
            };
            let part = if s.shuffles == 0 {
                "local".to_string()
            } else {
                format!("{} shuffle", s.shuffles)
            };
            writeln!(f, "  s{i:<3} {:<18}{fused}  ({part})", s.op)?;
        }
        Ok(())
    }
}

/// Driver-side per-stage attribution, merged across ranks: `bytes`
/// summed, `clock_ns` the slowest rank's. Index-aligned with
/// [`Explain::stages`] for the same plan.
#[derive(Clone, Debug)]
pub struct StageReport {
    pub label: String,
    pub shuffles: usize,
    /// Modeled bytes this stage put on the wire, summed over ranks.
    pub bytes: u64,
    /// Slowest rank's virtual-clock time spent in this stage.
    pub clock_ns: u64,
}

/// What a `collect()` returns: the merged rows (sorted by key, stable —
/// rank order within equal keys), whole-job [`JobStats`], and the
/// per-stage breakdown.
#[derive(Debug)]
pub struct DataflowOutput<K, V> {
    pub rows: Vec<(K, V)>,
    pub stats: JobStats,
    pub stages: Vec<StageReport>,
}

/// A typed, lazy dataflow stage — see the module docs. Cloning a stage
/// clones the plan (cheap, `Arc`-shared executors), so plans branch and
/// join freely; nothing executes until [`Stage::collect`].
///
/// ```
/// use blaze_rs::cluster::ClusterConfig;
/// use blaze_rs::core::dataflow::Stage;
///
/// let cluster = ClusterConfig::builder().ranks(2).build();
/// let orders = Stage::from_vec(vec![(1u32, 10u64), (2, 20), (1, 5)]);
/// let totals = orders.reduce_by_key(|a, b| a + b);
/// assert_eq!(totals.explain().total_shuffles(), 1);
/// let out = totals.collect(&cluster).unwrap();
/// assert_eq!(out.rows, vec![(1, 15), (2, 20)]);
/// ```
pub struct Stage<K, V> {
    exec: Exec<K, V>,
    node: PlanNode,
    part: Partitioning,
    sorted: bool,
}

impl<K, V> Clone for Stage<K, V> {
    fn clone(&self) -> Self {
        Stage {
            exec: self.exec.clone(),
            node: self.node.clone(),
            part: self.part,
            sorted: self.sorted,
        }
    }
}

/// Stage pairs into the owner-partitioned run store: already-keyed run
/// sets pass through untouched; anything else is staged into sorted
/// runs and, when not yet co-partitioned, repartitioned via
/// [`shuffle_runs`] (budget-bounded rounds — the one place a wide
/// operator touches the wire).
fn to_owner_runs<K, V>(
    ctx: &mut ExecCtx<'_>,
    data: LocalData<K, V>,
    keyed: bool,
) -> Result<RunSet<K, V>>
where
    K: FastSerialize + Hash + Eq + Ord + Send + 'static,
    V: FastSerialize + Send + 'static,
{
    let runs = match data {
        // Spill already accounted when this run set was first staged.
        LocalData::Runs(runs) => runs,
        LocalData::Iter(it) => {
            let mut writer: RunWriter<'_, K, V> = RunWriter::new(ctx.budget, ctx.tracker.clone());
            for (k, v) in it {
                writer.push(k, v)?;
            }
            let runs = writer.finish()?;
            ctx.spilled += runs.spilled_bytes();
            runs
        }
    };
    if keyed {
        return Ok(runs);
    }
    let router = ShardRouter::new(ctx.comm.size(), ctx.salt);
    let (incoming, _combined) = shuffle_runs(ctx.comm, &router, runs, ctx.budget, None, &ctx.tracker)?;
    ctx.spilled += incoming.spilled_bytes();
    Ok(incoming)
}

/// Per-owner hash join: build a local table of the right side (its
/// co-partitioned shard directly, or repartitioned through a
/// [`DistHashMap`]), then stream the left side through it.
fn hash_join<K, V, V2>(
    ctx: &mut ExecCtx<'_>,
    left: LocalData<K, V>,
    right: LocalData<K, V2>,
    lkeyed: bool,
    rkeyed: bool,
) -> Result<LocalData<K, (V, V2)>>
where
    K: FastSerialize + Hash + Eq + Ord + Clone + Send + 'static,
    V: FastSerialize + Clone + Send + 'static,
    V2: FastSerialize + Clone + Send + 'static,
{
    let build: HashMap<K, Vec<V2>> = if rkeyed {
        // Co-partitioned build side: stays rank-local, zero traffic.
        let mut table: HashMap<K, Vec<V2>> = HashMap::new();
        for (k, v2) in right.into_pairs()? {
            table.entry(k).or_default().push(v2);
        }
        table
    } else {
        let mut dmap: DistHashMap<'_, K, Vec<V2>> =
            DistHashMap::with_tracker(ctx.comm, ctx.salt, ctx.tracker.clone());
        for (k, v2) in right.into_pairs()? {
            dmap.stage(k, vec![v2]);
        }
        dmap.flush(|acc, mut vs| acc.append(&mut vs))?;
        dmap.into_local()
    };
    let probe: Vec<(K, V)> = if lkeyed {
        left.into_rows()?
    } else {
        let router = ShardRouter::new(ctx.comm.size(), ctx.salt);
        shuffle_pairs(ctx.comm, &router, left.into_rows()?, &ctx.tracker)?
    };
    let mut out: Vec<(K, (V, V2))> = Vec::new();
    for (k, v) in probe {
        if let Some(vs) = build.get(&k) {
            for v2 in vs {
                out.push((k.clone(), (v.clone(), v2.clone())));
            }
        }
    }
    Ok(LocalData::Iter(Box::new(out.into_iter())))
}

/// Lockstep merge-join: both sides land in the owner-partitioned run
/// store (free when they already are — the `Auto` trigger), then two
/// group streams advance in key order, cross-producting equal keys.
fn merge_join<K, V, V2>(
    ctx: &mut ExecCtx<'_>,
    left: LocalData<K, V>,
    right: LocalData<K, V2>,
    lkeyed: bool,
    rkeyed: bool,
) -> Result<LocalData<K, (V, V2)>>
where
    K: FastSerialize + Hash + Eq + Ord + Clone + Send + 'static,
    V: FastSerialize + Clone + Send + 'static,
    V2: FastSerialize + Clone + Send + 'static,
{
    let lruns = to_owner_runs(ctx, left, lkeyed)?;
    let rruns = to_owner_runs(ctx, right, rkeyed)?;
    let mut ls = GroupStream::new(lruns.into_merge()?);
    let mut rs = GroupStream::new(rruns.into_merge()?);
    let mut out: Vec<(K, (V, V2))> = Vec::new();
    let mut lg = ls.next_group()?;
    let mut rg = rs.next_group()?;
    while let (Some(l), Some(r)) = (&lg, &rg) {
        match l.0.cmp(&r.0) {
            Ordering::Less => lg = ls.next_group()?,
            Ordering::Greater => rg = rs.next_group()?,
            Ordering::Equal => {
                let (k, lvs) = lg.take().expect("checked Some above");
                let (_, rvs) = rg.take().expect("checked Some above");
                for v in &lvs {
                    for v2 in &rvs {
                        out.push((k.clone(), (v.clone(), v2.clone())));
                    }
                }
                lg = ls.next_group()?;
                rg = rs.next_group()?;
            }
        }
    }
    Ok(LocalData::Iter(Box::new(out.into_iter())))
}

impl<K, V> Stage<K, V>
where
    K: FastSerialize + Hash + Eq + Ord + Clone + Send + Sync + 'static,
    V: FastSerialize + Clone + Send + Sync + 'static,
{
    /// Plan source: the full dataset, strided across ranks at execution
    /// time (rank `r` of `n` takes every `n`-th pair).
    pub fn from_vec(rows: Vec<(K, V)>) -> Stage<K, V> {
        let data = Arc::new(rows);
        let exec: Exec<K, V> = Arc::new(move |ctx: &mut ExecCtx<'_>| {
            let data = Arc::clone(&data);
            ctx.record("input", 0, move |ctx| {
                let rank = ctx.comm.rank().0;
                let size = ctx.comm.size();
                let shard: Vec<(K, V)> = data
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| i % size == rank)
                    .map(|(_, pair)| pair.clone())
                    .collect();
                Ok(LocalData::Iter(Box::new(shard.into_iter())))
            })
        });
        Stage {
            exec,
            node: PlanNode {
                op: "input".to_string(),
                fused: Vec::new(),
                shuffles: 0,
                inputs: Vec::new(),
            },
            part: Partitioning::Arbitrary,
            sorted: false,
        }
    }

    /// Shared narrow-operator scaffolding: chain `f` onto the fused
    /// iterator, note the op on the plan node, don't execute anything.
    /// `keeps_keys` ops (filter, map_values) preserve co-partitioning
    /// and sortedness; key-changing ops reset both.
    fn narrow<K2, V2>(&self, name: &str, keeps_keys: bool, f: NarrowFn<K, V, K2, V2>) -> Stage<K2, V2>
    where
        K2: FastSerialize + Hash + Eq + Ord + Clone + Send + Sync + 'static,
        V2: FastSerialize + Clone + Send + Sync + 'static,
    {
        let up = self.exec.clone();
        let exec: Exec<K2, V2> = Arc::new(move |ctx: &mut ExecCtx<'_>| {
            let data = (up.as_ref())(ctx)?;
            let inner = data.into_pairs()?;
            Ok(LocalData::Iter(Box::new(NarrowIter {
                inner,
                f: f.clone(),
                queue: VecDeque::new(),
            })))
        });
        let mut node = self.node.clone();
        node.fused.push(name.to_string());
        Stage {
            exec,
            node,
            part: if keeps_keys { self.part } else { Partitioning::Arbitrary },
            sorted: if keeps_keys { self.sorted } else { false },
        }
    }

    /// Narrow: transform each pair. Fuses with adjacent narrow ops into
    /// one pass; resets co-partitioning (the key may change).
    pub fn map<K2, V2>(&self, f: impl Fn(K, V) -> (K2, V2) + Send + Sync + 'static) -> Stage<K2, V2>
    where
        K2: FastSerialize + Hash + Eq + Ord + Clone + Send + Sync + 'static,
        V2: FastSerialize + Clone + Send + Sync + 'static,
    {
        self.narrow(
            "map",
            false,
            Arc::new(move |k, v, emit: &mut dyn FnMut(K2, V2)| {
                let (k2, v2) = f(k, v);
                emit(k2, v2);
            }),
        )
    }

    /// Narrow: transform each value, keeping the key — and therefore
    /// the co-partitioning and sortedness of the input.
    pub fn map_values<V2>(&self, f: impl Fn(V) -> V2 + Send + Sync + 'static) -> Stage<K, V2>
    where
        V2: FastSerialize + Clone + Send + Sync + 'static,
    {
        self.narrow(
            "map_values",
            true,
            Arc::new(move |k, v, emit: &mut dyn FnMut(K, V2)| emit(k, f(v))),
        )
    }

    /// Narrow: keep the pairs the predicate accepts. Keys unchanged, so
    /// co-partitioning and sortedness survive — a filter after a wide
    /// op stays shuffle-free downstream.
    pub fn filter(&self, f: impl Fn(&K, &V) -> bool + Send + Sync + 'static) -> Stage<K, V> {
        self.narrow(
            "filter",
            true,
            Arc::new(move |k: K, v: V, emit: &mut dyn FnMut(K, V)| {
                if f(&k, &v) {
                    emit(k, v);
                }
            }),
        )
    }

    /// Narrow: emit zero or more pairs per input pair through the
    /// callback. Resets co-partitioning.
    pub fn flat_map<K2, V2>(
        &self,
        f: impl Fn(K, V, &mut dyn FnMut(K2, V2)) + Send + Sync + 'static,
    ) -> Stage<K2, V2>
    where
        K2: FastSerialize + Hash + Eq + Ord + Clone + Send + Sync + 'static,
        V2: FastSerialize + Clone + Send + Sync + 'static,
    {
        self.narrow("flat_map", false, Arc::new(f))
    }

    fn wide(&self, op: &str, shuffles: usize) -> PlanNode {
        PlanNode {
            op: op.to_string(),
            fused: Vec::new(),
            shuffles,
            inputs: vec![self.node.clone()],
        }
    }

    /// Wide: gather each key's full value multiset on its owner rank.
    /// Shuffle-free when the input is already co-partitioned (the
    /// `reduce_by_key().group_by()` chain) — the grouping then runs
    /// entirely on the rank-local run store.
    pub fn group_by(&self) -> Stage<K, Vec<V>> {
        let up = self.exec.clone();
        let keyed = self.part == Partitioning::Keyed;
        let shuffles = usize::from(!keyed);
        let exec: Exec<K, Vec<V>> = Arc::new(move |ctx: &mut ExecCtx<'_>| {
            let data = (up.as_ref())(ctx)?;
            ctx.record("group_by", shuffles, move |ctx| {
                let runs = to_owner_runs(ctx, data, keyed)?;
                let mut stream = GroupStream::new(runs.into_merge()?);
                let mut out: Vec<(K, Vec<V>)> = Vec::new();
                while let Some((k, vs)) = stream.next_group()? {
                    out.push((k, vs));
                }
                Ok(LocalData::Iter(Box::new(out.into_iter())))
            })
        });
        Stage {
            exec,
            node: self.wide("group_by", shuffles),
            part: Partitioning::Keyed,
            sorted: true,
        }
    }

    /// Wide: fold each key's values with an associative `op`. Folds
    /// rank-locally first (map-side combine), then — only when the
    /// input isn't already co-partitioned — shuffles one pre-folded
    /// pair per (rank, key) through a [`DistHashMap`]. Output is keyed
    /// and key-sorted.
    pub fn reduce_by_key(&self, op: impl Fn(V, V) -> V + Send + Sync + 'static) -> Stage<K, V> {
        let up = self.exec.clone();
        let keyed = self.part == Partitioning::Keyed;
        let shuffles = usize::from(!keyed);
        let exec: Exec<K, V> = Arc::new(move |ctx: &mut ExecCtx<'_>| {
            let data = (up.as_ref())(ctx)?;
            let op = &op;
            ctx.record("reduce_by_key", shuffles, move |ctx| {
                // Local pre-fold: one surviving value per (rank, key).
                let mut acc: HashMap<K, Option<V>> = HashMap::new();
                for (k, v) in data.into_pairs()? {
                    match acc.entry(k) {
                        Entry::Occupied(mut e) => {
                            let slot = e.get_mut();
                            let prev = slot.take().expect("slot refilled below");
                            *slot = Some(op(prev, v));
                        }
                        Entry::Vacant(e) => {
                            e.insert(Some(v));
                        }
                    }
                }
                let owned: Vec<(K, V)> = if keyed {
                    acc.into_iter().map(|(k, s)| (k, s.expect("filled"))).collect()
                } else {
                    let mut dmap: DistHashMap<'_, K, V> =
                        DistHashMap::with_tracker(ctx.comm, ctx.salt, ctx.tracker.clone());
                    for (k, s) in acc {
                        dmap.stage(k, s.expect("filled"));
                    }
                    dmap.flush(|a, v| {
                        let prev = a.clone();
                        *a = op(prev, v);
                    })?;
                    dmap.into_local().into_iter().collect()
                };
                let mut rows = owned;
                rows.sort_by(|a, b| a.0.cmp(&b.0));
                Ok(LocalData::Iter(Box::new(rows.into_iter())))
            })
        });
        Stage {
            exec,
            node: self.wide("reduce_by_key", shuffles),
            part: Partitioning::Keyed,
            sorted: true,
        }
    }

    /// Wide: land the data key-sorted in the owner-partitioned run
    /// store — the output stays as out-of-core runs (not materialized),
    /// which is what arms the merge-join fast path downstream.
    pub fn sort(&self) -> Stage<K, V> {
        let up = self.exec.clone();
        let keyed = self.part == Partitioning::Keyed;
        let shuffles = usize::from(!keyed);
        let exec: Exec<K, V> = Arc::new(move |ctx: &mut ExecCtx<'_>| {
            let data = (up.as_ref())(ctx)?;
            ctx.record("sort", shuffles, move |ctx| {
                Ok(LocalData::Runs(to_owner_runs(ctx, data, keyed)?))
            })
        });
        Stage { exec, node: self.wide("sort", shuffles), part: Partitioning::Keyed, sorted: true }
    }

    /// Two-input equi-join with [`JoinStrategy::Auto`] selection:
    /// merge-join when both sides are co-sorted runs, hash-join
    /// otherwise. See [`Stage::join_with`].
    pub fn join<V2>(&self, right: &Stage<K, V2>) -> Stage<K, (V, V2)>
    where
        V2: FastSerialize + Clone + Send + Sync + 'static,
    {
        self.join_with(right, JoinStrategy::Auto)
    }

    /// Two-input equi-join. Both sides repartition only if not already
    /// co-partitioned (both keyed ⇒ zero shuffles: the plan salt makes
    /// their shards co-resident by construction). Emits one output pair
    /// per matching `(left, right)` value pair. The strategy is
    /// resolved at plan time and shown by `explain()`.
    pub fn join_with<V2>(&self, right: &Stage<K, V2>, strategy: JoinStrategy) -> Stage<K, (V, V2)>
    where
        V2: FastSerialize + Clone + Send + Sync + 'static,
    {
        let use_merge = match strategy {
            JoinStrategy::Auto => self.sorted && right.sorted,
            JoinStrategy::Merge => true,
            JoinStrategy::Hash => false,
        };
        let label: &'static str = if use_merge { "join(merge)" } else { "join(hash)" };
        let lkeyed = self.part == Partitioning::Keyed;
        let rkeyed = right.part == Partitioning::Keyed;
        let shuffles = usize::from(!lkeyed) + usize::from(!rkeyed);
        let lexec = self.exec.clone();
        let rexec = right.exec.clone();
        let exec: Exec<K, (V, V2)> = Arc::new(move |ctx: &mut ExecCtx<'_>| {
            let ldata = (lexec.as_ref())(ctx)?;
            let rdata = (rexec.as_ref())(ctx)?;
            ctx.record(label, shuffles, move |ctx| {
                if use_merge {
                    merge_join(ctx, ldata, rdata, lkeyed, rkeyed)
                } else {
                    hash_join(ctx, ldata, rdata, lkeyed, rkeyed)
                }
            })
        });
        Stage {
            exec,
            node: PlanNode {
                op: label.to_string(),
                fused: Vec::new(),
                shuffles,
                inputs: vec![self.node.clone(), right.node.clone()],
            },
            part: Partitioning::Keyed,
            sorted: use_merge,
        }
    }

    /// The plan this stage will execute, in execution order (inputs
    /// before consumers, left join side before right), ending with the
    /// `collect` materialization stage. Index-aligned with
    /// [`DataflowOutput::stages`].
    pub fn explain(&self) -> Explain {
        fn flatten(node: &PlanNode, out: &mut Vec<ExplainStage>) {
            for input in &node.inputs {
                flatten(input, out);
            }
            out.push(ExplainStage {
                op: node.op.clone(),
                fused: node.fused.clone(),
                shuffles: node.shuffles,
            });
        }
        let mut stages = Vec::new();
        flatten(&self.node, &mut stages);
        stages.push(ExplainStage { op: "collect".to_string(), fused: Vec::new(), shuffles: 0 });
        Explain { stages }
    }

    /// Execute the plan on a one-shot rank fleet built from `cluster`.
    pub fn collect(&self, cluster: &ClusterConfig) -> Result<DataflowOutput<K, V>> {
        self.collect_impl(cluster, None, None)
    }

    /// Execute on a caller-owned warm [`RankPool`] (multi-plan sessions
    /// pay thread start-up once).
    pub fn collect_on(&self, cluster: &ClusterConfig, pool: &RankPool) -> Result<DataflowOutput<K, V>> {
        self.collect_impl(cluster, Some(pool), None)
    }

    /// Execute on an explicit rank subset of a warm pool — the seam the
    /// concurrent [`crate::core::Scheduler`] dispatches through.
    pub fn collect_placed(
        &self,
        cluster: &ClusterConfig,
        pool: &RankPool,
        ranks: &[usize],
    ) -> Result<DataflowOutput<K, V>> {
        self.collect_impl(cluster, Some(pool), Some(ranks))
    }

    fn collect_impl(
        &self,
        cluster: &ClusterConfig,
        pool: Option<&RankPool>,
        placement: Option<&[usize]>,
    ) -> Result<DataflowOutput<K, V>> {
        cluster.validate()?;
        let wall_start = Instant::now();
        let tcfg = cluster.trace();
        let _tracing = crate::trace::enable_scope(tcfg.is_enabled());
        if tcfg.is_enabled() {
            crate::trace::job_start(crate::trace::DRIVER_RANK, 0, 0);
        }
        let ranks = cluster.ranks();
        let tracker = PeakTracker::new();
        let budget = cluster.spill_threshold_bytes();
        let salt = cluster.seed ^ DATAFLOW_SALT;

        let exec = &self.exec;
        let rank_body = |comm: &Communicator| -> Result<(Vec<(K, V)>, Vec<RankStageStat>, u64)> {
            let mut ctx = ExecCtx {
                comm,
                tracker: tracker.clone(),
                budget,
                salt,
                stats: Vec::new(),
                spilled: 0,
            };
            let data = (exec.as_ref())(&mut ctx)?;
            let rows = ctx.record("collect", 0, |_ctx| data.into_rows())?;
            Ok((rows, ctx.stats, ctx.spilled))
        };
        let out = match (pool, placement) {
            (Some(pool), Some(subset)) => {
                pool.ensure_models_on(cluster, subset)?;
                pool.run_job_on(subset, rank_body)
            }
            (Some(pool), None) => {
                pool.ensure_models(cluster)?;
                pool.run_job(ranks, rank_body)
            }
            (None, _) => RankPool::new(Universe::from_cluster(cluster)).run_job(ranks, rank_body),
        };
        let (rank_results, clocks, traffic, rank_spans) =
            (out.results, out.clocks, out.traffic, out.trace);

        let mut rows: Vec<(K, V)> = Vec::new();
        let mut stages: Vec<StageReport> = Vec::new();
        let mut spilled = 0u64;
        for (i, r) in rank_results.into_iter().enumerate() {
            let (shard, stats, rank_spilled) = r.map_err(|e| anyhow!("rank {i} failed: {e:#}"))?;
            spilled += rank_spilled;
            rows.extend(shard);
            if stages.is_empty() {
                stages = stats
                    .into_iter()
                    .map(|s| StageReport {
                        label: s.label,
                        shuffles: s.shuffles,
                        bytes: s.bytes,
                        clock_ns: s.clock_ns,
                    })
                    .collect();
            } else {
                ensure!(
                    stages.len() == stats.len(),
                    "rank {i} recorded a different plan shape — non-SPMD plan"
                );
                for (acc, s) in stages.iter_mut().zip(stats) {
                    acc.bytes += s.bytes;
                    acc.clock_ns = acc.clock_ns.max(s.clock_ns);
                }
            }
        }
        // Deterministic driver-side order: key-sorted, stable within
        // equal keys (rank order — itself deterministic per plan).
        rows.sort_by(|a, b| a.0.cmp(&b.0));

        let profile = cluster.deployment.profile();
        let slowest = clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
        let stats = JobStats {
            modeled_ms: slowest.0 as f64 / 1e6,
            compute_ms: slowest.1 as f64 / 1e6,
            net_ms: slowest.2 as f64 / 1e6,
            startup_ms: profile.startup_ms as f64,
            shuffle_bytes: traffic.bytes,
            messages: traffic.messages,
            remote_messages: traffic.remote_messages,
            remote_bytes: traffic.remote_bytes,
            peak_mem_bytes: tracker.peak_bytes(),
            spilled_bytes: spilled,
            combined_bytes: 0,
            migrated_bytes: 0,
            host_wall_ms: wall_start.elapsed().as_secs_f64() * 1e3,
        };

        if tcfg.is_enabled() {
            crate::trace::span_manual(crate::trace::SpanKind::Job, 0, slowest.0, traffic.bytes);
            let mut tr = crate::trace::JobTrace::merge([crate::trace::take(), rank_spans]);
            tr.extend(crate::trace::collect_worker_spans());
            if let Some(path) = tcfg.export_path() {
                tr.export(path)?;
            }
            crate::trace::store_last(tr);
        }
        Ok(DataflowOutput { rows, stats, stages })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(ranks: usize) -> ClusterConfig {
        ClusterConfig::builder().ranks(ranks).seed(7).build()
    }

    #[test]
    fn fused_chain_matches_serial_reference_and_explain_shows_fusion() {
        let rows: Vec<(u64, u64)> = (0..200).map(|i| (i, i * 3)).collect();
        let plan = Stage::from_vec(rows.clone())
            .map(|k, v| (k % 10, v))
            .filter(|_k, v| v % 2 == 0)
            .reduce_by_key(|a, b| a + b);

        let ex = plan.explain();
        assert_eq!(ex.stages.len(), 3, "input, reduce_by_key, collect");
        assert_eq!(ex.stages[0].op, "input");
        assert_eq!(ex.stages[0].fused, vec!["map".to_string(), "filter".to_string()]);
        assert_eq!(ex.stages[1].op, "reduce_by_key");
        assert_eq!(ex.total_shuffles(), 1, "one repartition boundary");

        let out = plan.collect(&cluster(4)).unwrap();
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for (k, v) in rows {
            let (k, v) = (k % 10, v);
            if v % 2 == 0 {
                *truth.entry(k).or_insert(0) += v;
            }
        }
        let mut expect: Vec<(u64, u64)> = truth.into_iter().collect();
        expect.sort();
        assert_eq!(out.rows, expect);
    }

    #[test]
    fn co_partitioned_group_by_is_shuffle_free() {
        let rows: Vec<(u32, u64)> = (0..300).map(|i| (i % 7, u64::from(i))).collect();
        let plan = Stage::from_vec(rows).reduce_by_key(|a, b| a + b).group_by();

        let ex = plan.explain();
        assert_eq!(ex.stages[2].op, "group_by");
        assert_eq!(ex.stages[2].shuffles, 0, "keyed input ⇒ no repartition");
        assert_eq!(ex.total_shuffles(), 1);

        let out = plan.collect(&cluster(3)).unwrap();
        assert_eq!(out.stages.len(), ex.stages.len(), "reports align with explain");
        assert_eq!(out.stages[2].label, "group_by");
        assert_eq!(out.stages[2].bytes, 0, "co-partitioned group_by must move zero bytes");
        assert!(out.stages[1].bytes > 0, "the reduce repartition must move bytes");
        assert_eq!(out.rows.len(), 7);
        for (_k, vs) in &out.rows {
            assert_eq!(vs.len(), 1, "grouping pre-reduced data: one value per key");
        }
    }

    #[test]
    fn hash_and_merge_join_agree_with_serial_reference() {
        let left: Vec<(u32, u64)> = (0..120).map(|i| (i % 12, u64::from(i))).collect();
        let right: Vec<(u32, String)> =
            (0..12).filter(|i| i % 2 == 0).map(|i| (i, format!("r{i}"))).collect();
        let mut serial: Vec<(u32, (u64, String))> = Vec::new();
        for (k, v) in &left {
            for (k2, v2) in &right {
                if k == k2 {
                    serial.push((*k, (*v, v2.clone())));
                }
            }
        }
        serial.sort();
        let c = cluster(3);
        for strat in [JoinStrategy::Hash, JoinStrategy::Merge] {
            let l = Stage::from_vec(left.clone());
            let r = Stage::from_vec(right.clone());
            let mut rows = l.join_with(&r, strat).collect(&c).unwrap().rows;
            rows.sort();
            assert_eq!(rows, serial, "{strat:?} join must match the serial reference");
        }
    }

    #[test]
    fn auto_join_picks_merge_on_sorted_inputs_and_is_then_shuffle_free() {
        let left: Vec<(u32, u64)> = (0..60).map(|i| (i % 6, u64::from(i))).collect();
        let right: Vec<(u32, u64)> = (0..6).map(|i| (i, u64::from(i) * 100)).collect();

        let unsorted = Stage::from_vec(left.clone()).join(&Stage::from_vec(right.clone()));
        let uex = unsorted.explain();
        assert_eq!(uex.stages[uex.stages.len() - 2].op, "join(hash)");
        assert_eq!(uex.stages[uex.stages.len() - 2].shuffles, 2);

        let sorted = Stage::from_vec(left.clone())
            .sort()
            .join(&Stage::from_vec(right.clone()).sort());
        let sex = sorted.explain();
        assert_eq!(sex.stages[sex.stages.len() - 2].op, "join(merge)");
        assert_eq!(sex.stages[sex.stages.len() - 2].shuffles, 0, "both sides co-partitioned");

        let c = cluster(2);
        let out = sorted.collect(&c).unwrap();
        let join_report = &out.stages[out.stages.len() - 2];
        assert_eq!(join_report.label, "join(merge)");
        assert_eq!(join_report.bytes, 0, "co-partitioned join must move zero bytes");

        let mut a = unsorted.collect(&c).unwrap().rows;
        let mut b = out.rows;
        a.sort();
        b.sort();
        assert_eq!(a, b, "strategy must not change the result");
        assert_eq!(a.len(), 60, "every left row matches exactly one right row");
    }

    #[test]
    fn fused_filter_moves_strictly_fewer_bytes_than_materializing_plan() {
        let rows: Vec<(u64, u64)> = (0..400).map(|i| (i, i)).collect();
        let c = cluster(4);
        // Fused: the filter runs before the one shuffle, so only
        // surviving pairs cross the wire.
        let fused =
            Stage::from_vec(rows.clone()).filter(|k, _| k % 10 == 0).group_by().collect(&c).unwrap();
        // Materializing equivalent: force a full repartition first
        // (stage-by-stage execution), filter after.
        let staged =
            Stage::from_vec(rows).sort().filter(|k, _| k % 10 == 0).group_by().collect(&c).unwrap();
        assert_eq!(fused.rows, staged.rows, "same answer either way");
        assert!(
            fused.stats.shuffle_bytes < staged.stats.shuffle_bytes,
            "fusion must move strictly fewer bytes: fused {} vs staged {}",
            fused.stats.shuffle_bytes,
            staged.stats.shuffle_bytes
        );
    }

    #[test]
    fn repeat_collects_are_deterministic_and_pool_reuse_matches() {
        let rows: Vec<(u32, u64)> = (0..150).map(|i| (i % 9, u64::from(i * i))).collect();
        let lookup: Vec<(u32, u64)> = (0..9).map(|i| (i, u64::from(i) + 1)).collect();
        let plan = Stage::from_vec(rows)
            .filter(|_k, v| v % 3 != 0)
            .join(&Stage::from_vec(lookup))
            .reduce_by_key(|a, b| (a.0 + b.0, a.1.max(b.1)));
        let c = cluster(3);
        let a = plan.collect(&c).unwrap().rows;
        let b = plan.collect(&c).unwrap().rows;
        assert_eq!(a, b, "same plan, same cluster ⇒ same rows");

        let pool = RankPool::from_config(&c);
        let warm = plan.collect_on(&c, &pool).unwrap().rows;
        assert_eq!(a, warm, "warm-pool execution must match one-shot");
    }

    #[test]
    fn empty_input_produces_empty_output_across_wide_ops() {
        let empty: Stage<u32, u64> = Stage::from_vec(Vec::new());
        let c = cluster(2);
        assert!(empty.group_by().collect(&c).unwrap().rows.is_empty());
        assert!(empty.reduce_by_key(|a, b| a + b).collect(&c).unwrap().rows.is_empty());
        let joined = empty.join(&Stage::from_vec(vec![(1u32, 2u64)]));
        assert!(joined.collect(&c).unwrap().rows.is_empty());
    }

    #[test]
    fn tiny_spill_budget_spills_and_stays_correct() {
        let rows: Vec<(u64, u64)> = (0..500).map(|i| (i % 11, i)).collect();
        let big = cluster(2);
        let small = ClusterConfig::builder().ranks(2).seed(7).shuffle_buffer_bytes(256).build();
        let plan = Stage::from_vec(rows).sort().group_by();
        let in_core = plan.collect(&big).unwrap();
        let out_of_core = plan.collect(&small).unwrap();
        assert_eq!(in_core.rows, out_of_core.rows, "spilling must not change results");
        assert_eq!(in_core.stats.spilled_bytes, 0);
        assert!(out_of_core.stats.spilled_bytes > 0, "256-byte budget must spill");
    }

    #[test]
    fn explain_renders_stages_fusion_and_boundaries() {
        let plan = Stage::from_vec(vec![(1u32, 1u64)])
            .map(|k, v| (k, v + 1))
            .reduce_by_key(|a, b| a + b)
            .group_by();
        let text = plan.explain().to_string();
        assert!(text.contains("plan: 4 stages, 1 shuffle(s)"), "got:\n{text}");
        assert!(text.contains("fused[map]"), "got:\n{text}");
        assert!(text.contains("group_by"), "got:\n{text}");
        assert!(text.contains("(local)"), "got:\n{text}");
    }
}
