//! `Monoid` — the typed aggregate for [`super::IterativeJob::step`]'s
//! per-wave `measure` fold.
//!
//! The first cut of the iterative engine allreduced an ad-hoc `f64`
//! sum, which forced integer apps (label propagation's changed-count)
//! through float identity checks like `aggregate == 0.0`. A monoid
//! bound names the contract the allreduce already relied on — an
//! associative `combine` with an `identity` — and lets each app pick
//! the carrier: `u64` for exact counters, `f64` for normalizers. The
//! wave fold is still deterministic (gather-to-root in rank order, one
//! broadcast), so checkpoint/recover tests can assert aggregate
//! *continuity* across a recovery with plain `==` on integer carriers.

use crate::serial::FastSerialize;

/// An associative combine with an identity element. `combine` must be
/// associative; the iterative wave additionally folds in a fixed
/// (rank-major) order, so commutativity is *not* required for
/// reproducibility — but floating-point carriers still re-associate
/// across different widths (the usual ulp caveat).
pub trait Monoid: FastSerialize + Send {
    fn identity() -> Self;
    fn combine(a: Self, b: Self) -> Self;
}

macro_rules! sum_monoid {
    ($($t:ty => $zero:expr),* $(,)?) => {
        $(impl Monoid for $t {
            fn identity() -> Self {
                $zero
            }
            fn combine(a: Self, b: Self) -> Self {
                a + b
            }
        })*
    };
}

sum_monoid!(u32 => 0, u64 => 0, i64 => 0, f64 => 0.0);

impl Monoid for () {
    fn identity() -> Self {}
    fn combine(_: Self, _: Self) -> Self {}
}

impl<A: Monoid, B: Monoid> Monoid for (A, B) {
    fn identity() -> Self {
        (A::identity(), B::identity())
    }
    fn combine(a: Self, b: Self) -> Self {
        (A::combine(a.0, b.0), B::combine(a.1, b.1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integer_sum_is_exact_and_identity_neutral() {
        assert_eq!(u64::combine(u64::identity(), 7), 7);
        assert_eq!(u64::combine(3, 4), 7);
        assert_eq!(i64::combine(-3, 4), 1);
    }

    #[test]
    fn pair_monoid_combines_componentwise() {
        let a: (u64, f64) = (2, 0.5);
        let b: (u64, f64) = (3, 0.25);
        assert_eq!(<(u64, f64)>::combine(a, b), (5, 0.75));
        assert_eq!(<(u64, f64)>::identity(), (0, 0.0));
    }
}
