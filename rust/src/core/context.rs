//! Emit contexts: where mapper output lands before the shuffle.
//!
//! * [`VecEmitter`] — classic mode: append every pair.
//! * [`CombineEmitter`] — eager mode: Blaze's *thread-local cache*; pairs
//!   are combined in a per-rank hash map at emit time so only one value
//!   per key survives to the shuffle.
//!
//! (The old `GroupEmitter` — in-memory grouping without reduction — was
//! retired once the delayed engine moved onto [`crate::store::RunWriter`]
//! sorted runs and [`crate::store::GroupStream`] streaming groups; its
//! multiset-preservation contract is asserted by the store's tests.)

use std::collections::HashMap;
use std::hash::Hash;

// §Perf iteration 4 note: swapping these caches to the in-tree Fx-style
// hasher measured ~6% SLOWER than std SipHash on the wordcount emit path
// (short string keys, hashbrown's SIMD probing already dominates), so the
// change was reverted — std's hasher stays.

/// What mappers see: a sink for `(key, value)` pairs.
pub trait Emitter<K, V> {
    fn emit(&mut self, key: K, value: V);
}

impl<K, V, F: FnMut(K, V)> Emitter<K, V> for F {
    fn emit(&mut self, key: K, value: V) {
        self(key, value)
    }
}

/// Plain append emitter (classic mode).
#[derive(Debug, Default)]
pub struct VecEmitter<K, V> {
    pub pairs: Vec<(K, V)>,
}

impl<K, V> VecEmitter<K, V> {
    pub fn new() -> Self {
        Self { pairs: Vec::new() }
    }
}

impl<K, V> Emitter<K, V> for VecEmitter<K, V> {
    #[inline]
    fn emit(&mut self, key: K, value: V) {
        self.pairs.push((key, value));
    }
}

/// Eager-reduction emitter: combines at emit time (thread-local cache).
pub struct CombineEmitter<'f, K, V> {
    pub cache: HashMap<K, V>,
    combine: &'f (dyn Fn(&mut V, V) + Sync),
    emitted: u64,
}

impl<'f, K: Hash + Eq, V> CombineEmitter<'f, K, V> {
    pub fn new(combine: &'f (dyn Fn(&mut V, V) + Sync)) -> Self {
        Self { cache: HashMap::new(), combine, emitted: 0 }
    }

    /// Raw emissions absorbed (before combining) — eager reduction's
    /// compression ratio is `emitted / cache.len()`.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }
}

impl<K: Hash + Eq, V> Emitter<K, V> for CombineEmitter<'_, K, V> {
    #[inline]
    fn emit(&mut self, key: K, value: V) {
        self.emitted += 1;
        match self.cache.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                (self.combine)(e.get_mut(), value)
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(value);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_emitter_appends_duplicates() {
        let mut e = VecEmitter::new();
        e.emit("a", 1);
        e.emit("a", 2);
        assert_eq!(e.pairs, vec![("a", 1), ("a", 2)]);
    }

    #[test]
    fn combine_emitter_reduces_at_emit() {
        let combine = |acc: &mut u64, v: u64| *acc += v;
        let mut e = CombineEmitter::new(&combine);
        for _ in 0..5 {
            e.emit("x", 1u64);
        }
        e.emit("y", 10);
        assert_eq!(e.cache[&"x"], 5);
        assert_eq!(e.cache[&"y"], 10);
        assert_eq!(e.emitted(), 6);
        assert_eq!(e.cache.len(), 2);
    }

    #[test]
    fn closures_are_emitters() {
        fn run_mapper(em: &mut impl Emitter<u32, u32>) {
            em.emit(1, 2);
        }
        let mut got = Vec::new();
        run_mapper(&mut |k, v| got.push((k, v)));
        assert_eq!(got, vec![(1, 2)]);
    }
}
