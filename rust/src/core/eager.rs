//! Eager Reduction — Blaze's signature mode (paper Fig 2).
//!
//! "Reduce is applied to the output of mapper locally at the MPI slave
//! level and then simultaneously shuffled across the network for the final
//! shuffle phase. There is a Thread-local Cache that reduces movement of
//! data across processors."
//!
//! Implementation: mappers emit into a [`CombineEmitter`] (the thread-local
//! cache) which combines values per key at emit time; the shuffle then
//! moves exactly one value per distinct key per rank, and owners run the
//! same combine on arrival. Requires the combine op to be associative and
//! commutative — the rigidity §III.D motivates Delayed Reduction with.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;

use anyhow::Result;

use crate::dist::ShardRouter;
use crate::metrics::PeakTracker;
use crate::mpi::Communicator;
use crate::serial::FastSerialize;

use super::context::{CombineEmitter, Emitter};
use super::scheduler::TaskFeed;
use super::shuffle::shuffle_pairs;

/// SPMD rank body for one eager-reduction job. Returns this rank's result
/// shard plus spilled/combined byte counts (both always 0 here: the
/// cache *is* the memory bound, and combining at emit time is the mode
/// itself, not a separate combiner pass).
pub fn eager_rank<I, K, V, M>(
    comm: &Communicator,
    feed: &TaskFeed<'_, I>,
    map: &M,
    combine: &(dyn Fn(&mut V, V) + Sync),
    salt: u64,
    tracker: &Arc<PeakTracker>,
) -> Result<(HashMap<K, V>, u64, u64)>
where
    I: Sync,
    K: FastSerialize + Hash + Eq + Send,
    V: FastSerialize + Send,
    M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
{
    // Map + combine into the thread-local cache.
    let map_span = crate::trace::span(crate::trace::SpanKind::Map);
    let mut emitter = CombineEmitter::new(combine);
    let mut rank_feed = feed.for_rank(comm.rank());
    while let Some((task, chunk)) = rank_feed.next() {
        comm.timed(|| {
            for item in chunk {
                map(item, &mut |k, v| emitter.emit(k, v));
            }
        });
        rank_feed.complete(task);
    }
    drop(map_span);

    // Charge the cache (it holds at most one value per distinct key).
    let cache_bytes: u64 = emitter
        .cache
        .iter()
        .map(|(k, v)| (k.size_hint() + v.size_hint() + 16) as u64)
        .sum();
    tracker.alloc(cache_bytes);

    // Shuffle combined pairs to their owners.
    let router = ShardRouter::new(comm.size(), salt);
    let pairs: Vec<(K, V)> = comm.timed(|| emitter.cache.drain().collect());
    tracker.free(cache_bytes);
    let mine = shuffle_pairs(comm, &router, pairs, tracker)?;

    // Final combine on the owner.
    let combine_span = crate::trace::span(crate::trace::SpanKind::Combine);
    let out = comm.timed(|| {
        // Owner-side combine: at most one entry per incoming pair (§Perf
        // iteration 2: pre-size to skip rehash-growth).
        let mut out: HashMap<K, V> = HashMap::with_capacity(mine.len());
        for (k, v) in mine {
            debug_assert_eq!(router.owner(&k), comm.rank());
            match out.entry(k) {
                std::collections::hash_map::Entry::Occupied(mut e) => combine(e.get_mut(), v),
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(v);
                }
            }
        }
        out
    });
    drop(combine_span);
    // Result shards stay charged until the driver merges them; the engine
    // releases this at collection time via the returned map's estimate.
    let out_bytes: u64 =
        out.iter().map(|(k, v)| (k.size_hint() + v.size_hint() + 16) as u64).sum();
    tracker.alloc(out_bytes);
    Ok((out, 0, 0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::job::Scheduling;
    use crate::util::testpool::pool_run;

    #[test]
    fn eager_wordcount_two_ranks() {
        let input: Vec<String> =
            ["a b a", "b c", "a"].iter().map(|s| s.to_string()).collect();
        // One shared feed captured by every rank closure (as the engine
        // does); Dynamic claiming is exercised by engine tests.
        let feed = TaskFeed::new(&input, 2, 1, Scheduling::Static, None);
        let results = pool_run(2, |c| {
            let map = |line: &String, emit: &mut dyn FnMut(String, u64)| {
                for w in line.split_whitespace() {
                    emit(w.to_string(), 1);
                }
            };
            let combine = |acc: &mut u64, v: u64| *acc += v;
            let tracker = PeakTracker::new();
            eager_rank(c, &feed, &map, &combine, 0, &tracker).unwrap().0
        });
        let mut merged: HashMap<String, u64> = HashMap::new();
        for shard in results {
            for (k, v) in shard {
                assert!(merged.insert(k, v).is_none(), "key owned by two ranks");
            }
        }
        assert_eq!(merged[&"a".to_string()], 3);
        assert_eq!(merged[&"b".to_string()], 2);
        assert_eq!(merged[&"c".to_string()], 1);
    }
}
