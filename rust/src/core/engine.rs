//! The job engine: universe setup, mode dispatch, metrics, result
//! collection — what `blaze run` and the apps call.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::cluster::ClusterConfig;
use crate::metrics::PeakTracker;
use crate::mpi::{Communicator, RankPool, Universe};
use crate::serial::FastSerialize;

use super::classic::classic_rank;
use super::delayed::delayed_rank;
use super::eager::eager_rank;
use super::job::{JobConfig, JobResult, JobStats, ReductionMode};
use super::scheduler::{TaskFault, TaskFeed};

/// A configured MapReduce job over a borrowed input slice.
///
/// ```
/// use blaze_rs::prelude::*;
/// use blaze_rs::core::MapReduceJob;
///
/// let cluster = ClusterConfig::builder().ranks(4).build();
/// let lines = vec!["one fish two fish".to_string()];
/// let result = MapReduceJob::new(&cluster, &lines)
///     .run_eager(
///         |line: &String, emit: &mut dyn FnMut(String, u64)| {
///             for w in line.split_whitespace() { emit(w.to_string(), 1); }
///         },
///         |acc, v| *acc += v,
///     )
///     .unwrap();
/// assert_eq!(result.result[&"fish".to_string()], 2);
/// ```
pub struct MapReduceJob<'i, I> {
    cluster: ClusterConfig,
    config: JobConfig,
    input: &'i [I],
    fault: Option<TaskFault>,
    pool: Option<&'i RankPool>,
    placement: Option<&'i [usize]>,
}

impl<'i, I: Sync> MapReduceJob<'i, I> {
    pub fn new(cluster: &ClusterConfig, input: &'i [I]) -> Self {
        let cluster = cluster.clone();
        Self { cluster, config: JobConfig::default(), input, fault: None, pool: None, placement: None }
    }

    pub fn with_config(mut self, config: JobConfig) -> Self {
        self.config = config;
        self
    }

    pub fn with_mode(mut self, mode: ReductionMode) -> Self {
        self.config.mode = mode;
        self
    }

    /// Run on a caller-owned warm [`RankPool`] instead of spawning fresh
    /// rank threads — multi-job sessions (PageRank's wave loop, bench
    /// sweeps, `ElasticCluster` sessions) pay thread start-up once. The
    /// pool must model this cluster's placement/network on its first
    /// `ranks()` ranks (build it with [`RankPool::from_config`]).
    pub fn with_pool(mut self, pool: &'i RankPool) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Run on an explicit rank subset of a warm pool — the seam the
    /// concurrent [`crate::core::Scheduler`] dispatches through. `ranks`
    /// are strictly-ascending pool indices; their count must equal this
    /// cluster's `ranks()` and the pool's topology restricted to them
    /// must structurally match the job cluster's (checked via
    /// [`RankPool::ensure_models_on`]). Inside the job the subset is
    /// renumbered 0..width, so SPMD bodies are placement-oblivious.
    pub fn with_placement(mut self, pool: &'i RankPool, ranks: &'i [usize]) -> Self {
        self.pool = Some(pool);
        self.placement = Some(ranks);
        self
    }

    /// Inject a failure (Dynamic scheduling only): see [`TaskFault`].
    pub fn with_fault(mut self, fault: TaskFault) -> Self {
        self.fault = Some(fault);
        self
    }

    fn salt(&self) -> u64 {
        self.cluster.seed ^ self.config.salt
    }

    /// Run with Blaze eager reduction (combine must be assoc+comm).
    pub fn run_eager<K, V, M>(
        &self,
        map: M,
        combine: impl Fn(&mut V, V) + Sync,
    ) -> Result<JobResult<HashMap<K, V>>>
    where
        K: FastSerialize + Hash + Eq + Send,
        V: FastSerialize + Send,
        M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
    {
        let salt = self.salt();
        self.execute(move |comm, feed, tracker| {
            eager_rank(comm, feed, &map, &combine, salt, tracker)
        })
    }

    /// Run classic (Hadoop-style) MapReduce. `reduce` streams each key's
    /// value multiset as a lazy iterator straight off the grouped merge
    /// — nothing is materialized unless the reducer collects it.
    pub fn run_classic<K, V, M, R>(&self, map: M, reduce: R) -> Result<JobResult<HashMap<K, V>>>
    where
        K: FastSerialize + Hash + Eq + Ord + Send,
        V: FastSerialize + Send,
        M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
        R: Fn(&K, &mut dyn Iterator<Item = V>) -> V + Sync,
    {
        let salt = self.salt();
        let spill = self.cluster.spill_threshold_bytes();
        self.execute(move |comm, feed, tracker| {
            classic_rank(comm, feed, &map, &reduce, None, salt, spill, tracker)
        })
    }

    /// [`MapReduceJob::run_classic`] with the pre-PR-10 materialized
    /// `(K, Vec<V>)` reducer shape — a thin compat shim for callers that
    /// genuinely need the whole group at once.
    pub fn run_classic_vec<K, V, M, R>(&self, map: M, reduce: R) -> Result<JobResult<HashMap<K, V>>>
    where
        K: FastSerialize + Hash + Eq + Ord + Send,
        V: FastSerialize + Send,
        M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
        R: Fn(&K, Vec<V>) -> V + Sync,
    {
        self.run_classic(map, move |k: &K, vs: &mut dyn Iterator<Item = V>| {
            reduce(k, vs.collect())
        })
    }

    /// Run classic MapReduce with a **map-side combiner** (Hadoop's):
    /// `combine` folds equal-key values at run-write and merge time
    /// before the shuffle, cutting wire volume without changing the
    /// result. `combine` must be associative and agree with `reduce`
    /// (applying it to any bracketing of a key's values then reducing
    /// must equal reducing the raw multiset). Folded-away bytes are
    /// reported in [`JobStats::combined_bytes`].
    pub fn run_classic_with_combiner<K, V, M, R>(
        &self,
        map: M,
        combine: impl Fn(&mut V, V) + Sync,
        reduce: R,
    ) -> Result<JobResult<HashMap<K, V>>>
    where
        K: FastSerialize + Hash + Eq + Ord + Send,
        V: FastSerialize + Send,
        M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
        R: Fn(&K, &mut dyn Iterator<Item = V>) -> V + Sync,
    {
        let salt = self.salt();
        let spill = self.cluster.spill_threshold_bytes();
        self.execute(move |comm, feed, tracker| {
            classic_rank(comm, feed, &map, &reduce, Some(&combine), salt, spill, tracker)
        })
    }

    /// Run with the paper's Delayed Reduction. Grouping is out-of-core:
    /// staged pairs past the cluster's spill threshold go to key-ordered
    /// disk runs (see [`crate::store`]).
    pub fn run_delayed<K, V, M, R>(&self, map: M, reduce: R) -> Result<JobResult<HashMap<K, V>>>
    where
        K: FastSerialize + Hash + Eq + Ord + Send,
        V: FastSerialize + Send,
        M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
        R: Fn(&K, &mut dyn Iterator<Item = V>) -> V + Sync,
    {
        let salt = self.salt();
        let spill = self.cluster.spill_threshold_bytes();
        self.execute(move |comm, feed, tracker| {
            delayed_rank(comm, feed, &map, &reduce, salt, spill, tracker)
        })
    }

    /// [`MapReduceJob::run_delayed`] with the materialized `(K, Vec<V>)`
    /// reducer shape — compat shim, see [`MapReduceJob::run_classic_vec`].
    pub fn run_delayed_vec<K, V, M, R>(&self, map: M, reduce: R) -> Result<JobResult<HashMap<K, V>>>
    where
        K: FastSerialize + Hash + Eq + Ord + Send,
        V: FastSerialize + Send,
        M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
        R: Fn(&K, Vec<V>) -> V + Sync,
    {
        self.run_delayed(map, move |k: &K, vs: &mut dyn Iterator<Item = V>| {
            reduce(k, vs.collect())
        })
    }

    /// Mode-dispatched run for monoid reductions (`op` assoc+comm): the
    /// same job runs under any [`ReductionMode`], which is how the benches
    /// compare the three engines apples-to-apples.
    pub fn run_monoid<K, V, M>(
        &self,
        map: M,
        op: impl Fn(V, V) -> V + Sync + Copy,
    ) -> Result<JobResult<HashMap<K, V>>>
    where
        K: FastSerialize + Hash + Eq + Ord + Send,
        V: FastSerialize + Send + Clone,
        M: Fn(&I, &mut dyn FnMut(K, V)) + Sync,
    {
        match self.config.mode {
            ReductionMode::Eager => self.run_eager(map, move |acc: &mut V, v| {
                let cur = acc.clone();
                *acc = op(cur, v);
            }),
            ReductionMode::Classic => {
                self.run_classic(map, move |_k: &K, vs: &mut dyn Iterator<Item = V>| {
                    vs.reduce(op).expect("non-empty group")
                })
            }
            ReductionMode::Delayed => {
                self.run_delayed(map, move |_k: &K, vs: &mut dyn Iterator<Item = V>| {
                    vs.reduce(op).expect("non-empty group")
                })
            }
        }
    }

    /// Shared scaffolding: build the universe, run the SPMD body on every
    /// rank, merge shards, assemble stats.
    fn execute<K, V, B>(&self, body: B) -> Result<JobResult<HashMap<K, V>>>
    where
        K: Hash + Eq + Send,
        V: Send,
        B: Fn(
                &Communicator,
                &TaskFeed<'_, I>,
                &Arc<PeakTracker>,
            ) -> Result<(HashMap<K, V>, u64, u64)>
            + Sync,
    {
        self.cluster.validate()?;
        let wall_start = Instant::now();
        // Tracing: resolve the cluster's knob and (when on) record spans
        // for the duration of this job. `enable_scope(false)` is a no-op
        // guard, so untraced jobs never disturb a concurrently-traced one.
        let tcfg = self.cluster.trace();
        let _tracing = crate::trace::enable_scope(tcfg.is_enabled());
        if tcfg.is_enabled() {
            crate::trace::job_start(crate::trace::DRIVER_RANK, 0, 0);
        }
        let ranks = self.cluster.ranks();
        let tracker = PeakTracker::new();
        let feed = TaskFeed::new(
            self.input,
            ranks,
            self.config.tasks_per_rank,
            self.config.scheduling,
            self.fault,
        );

        let rank_body = |comm: &Communicator| body(comm, &feed, &tracker);
        let out = match (self.pool, self.placement) {
            (Some(pool), Some(subset)) => {
                pool.ensure_models_on(&self.cluster, subset)?;
                pool.run_job_on(subset, rank_body)
            }
            (Some(pool), None) => {
                pool.ensure_models(&self.cluster)?;
                pool.run_job(ranks, rank_body)
            }
            // One-shot: a throwaway pool wired exactly like the old fresh
            // universe (same threads-per-job cost as before the refactor).
            (None, _) => {
                RankPool::new(Universe::from_cluster(&self.cluster)).run_job(ranks, rank_body)
            }
        };
        let (rank_results, clocks, traffic, rank_spans) =
            (out.results, out.clocks, out.traffic, out.trace);

        // Merge shards (disjoint key ownership) and surface rank errors.
        let mut merged: HashMap<K, V> = HashMap::new();
        let mut spilled = 0u64;
        let mut combined = 0u64;
        for (i, r) in rank_results.into_iter().enumerate() {
            let (shard, rank_spilled, rank_combined) =
                r.map_err(|e| anyhow!("rank {i} failed: {e:#}"))?;
            spilled += rank_spilled;
            combined += rank_combined;
            for (k, v) in shard {
                if merged.insert(k, v).is_some() {
                    return Err(anyhow!("key owned by two ranks — router desync"));
                }
            }
        }

        let profile = self.cluster.deployment.profile();
        let slowest = clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
        // Job time excludes cluster bring-up (the paper benchmarks jobs on
        // an already-running cluster); startup is reported separately.
        let stats = JobStats {
            modeled_ms: slowest.0 as f64 / 1e6,
            compute_ms: slowest.1 as f64 / 1e6,
            net_ms: slowest.2 as f64 / 1e6,
            startup_ms: profile.startup_ms as f64,
            shuffle_bytes: traffic.bytes,
            messages: traffic.messages,
            remote_messages: traffic.remote_messages,
            remote_bytes: traffic.remote_bytes,
            peak_mem_bytes: tracker.peak_bytes(),
            spilled_bytes: spilled,
            combined_bytes: combined,
            migrated_bytes: 0,
            host_wall_ms: wall_start.elapsed().as_secs_f64() * 1e3,
        };

        if tcfg.is_enabled() {
            // One whole-job span on the driver lane spanning the slowest
            // rank's virtual clock, then the merged, clock-ordered trace.
            crate::trace::span_manual(crate::trace::SpanKind::Job, 0, slowest.0, traffic.bytes);
            let mut tr = crate::trace::JobTrace::merge([crate::trace::take(), rank_spans]);
            // A throwaway pool (the `None` arm above) has already been
            // dropped here, so a TCP fleet's workers have flushed their
            // Relay span files; a caller-owned warm pool keeps its
            // workers alive and contributes driver-side spans only.
            tr.extend(crate::trace::collect_worker_spans());
            if let Some(path) = tcfg.export_path() {
                tr.export(path)?;
            }
            crate::trace::store_last(tr);
        }
        Ok(JobResult { result: merged, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeploymentKind;
    use crate::core::job::Scheduling;
    use crate::mpi::Rank;

    fn wordcount_input(lines: usize) -> Vec<String> {
        (0..lines).map(|i| format!("w{} w{} common", i % 7, i % 3)).collect()
    }

    fn wc_map(line: &String, emit: &mut dyn FnMut(String, u64)) {
        for w in line.split_whitespace() {
            emit(w.to_string(), 1);
        }
    }

    #[test]
    fn all_three_modes_agree() {
        let input = wordcount_input(100);
        let cluster = ClusterConfig::builder().ranks(4).build();
        let mut outputs = Vec::new();
        for mode in ReductionMode::ALL {
            let job = MapReduceJob::new(&cluster, &input)
                .with_config(JobConfig { mode, ..Default::default() });
            let out = job.run_monoid(wc_map, |a: u64, b: u64| a + b).unwrap();
            outputs.push(out.result);
        }
        assert_eq!(outputs[0], outputs[1]);
        assert_eq!(outputs[1], outputs[2]);
        assert_eq!(outputs[0][&"common".to_string()], 100);
    }

    #[test]
    fn dynamic_scheduling_matches_static() {
        let input = wordcount_input(60);
        let cluster = ClusterConfig::builder().ranks(3).build();
        let sta = MapReduceJob::new(&cluster, &input)
            .with_config(JobConfig { scheduling: Scheduling::Static, ..Default::default() })
            .run_eager(wc_map, |a, b| *a += b)
            .unwrap();
        let dyn_ = MapReduceJob::new(&cluster, &input)
            .with_config(JobConfig { scheduling: Scheduling::Dynamic, ..Default::default() })
            .run_eager(wc_map, |a, b| *a += b)
            .unwrap();
        assert_eq!(sta.result, dyn_.result);
    }

    #[test]
    fn fault_injection_job_still_completes() {
        let input = wordcount_input(80);
        let cluster = ClusterConfig::builder().ranks(4).build();
        let healthy = MapReduceJob::new(&cluster, &input)
            .run_eager(wc_map, |a, b| *a += b)
            .unwrap();
        let faulty = MapReduceJob::new(&cluster, &input)
            .with_fault(TaskFault { rank: Rank(2), after_tasks: 1 })
            .run_eager(wc_map, |a, b| *a += b)
            .unwrap();
        assert_eq!(healthy.result, faulty.result);
    }

    #[test]
    fn stats_populated_and_consistent() {
        let input = wordcount_input(50);
        let cluster = ClusterConfig::builder()
            .deployment(DeploymentKind::Container)
            .nodes(2)
            .slots_per_node(2)
            .build();
        let out = MapReduceJob::new(&cluster, &input)
            .run_eager(wc_map, |a, b| *a += b)
            .unwrap();
        let s = &out.stats;
        assert!(s.modeled_ms > 0.0);
        assert!(s.shuffle_bytes > 0);
        assert!(s.messages > 0);
        assert!(s.remote_bytes <= s.shuffle_bytes);
        assert!(s.peak_mem_bytes > 0);
        assert!(s.host_wall_ms > 0.0);
        // Container startup is 1.2 s in the profile — reported, not
        // folded into modeled_ms.
        assert!(s.startup_ms == 1_200.0);
        assert!(s.modeled_ms < s.startup_ms);
    }

    #[test]
    fn pooled_session_matches_fresh_spawn_across_jobs() {
        let input = wordcount_input(120);
        let cluster = ClusterConfig::builder().ranks(4).build();
        let pool = RankPool::from_config(&cluster);
        for mode in ReductionMode::ALL {
            let fresh = MapReduceJob::new(&cluster, &input)
                .with_mode(mode)
                .run_monoid(wc_map, |a: u64, b| a + b)
                .unwrap();
            let pooled = MapReduceJob::new(&cluster, &input)
                .with_mode(mode)
                .with_pool(&pool)
                .run_monoid(wc_map, |a: u64, b| a + b)
                .unwrap();
            assert_eq!(fresh.result, pooled.result);
            // Per-job traffic accounting must read like a fresh universe
            // even on a reused pool (clocks carry real CPU measurements,
            // so only the deterministic counters are compared).
            assert_eq!(fresh.stats.shuffle_bytes, pooled.stats.shuffle_bytes);
            assert_eq!(fresh.stats.messages, pooled.stats.messages);
        }
        assert_eq!(pool.jobs_run(), 3);
    }

    #[test]
    fn mismatched_pool_is_rejected() {
        let input = wordcount_input(10);
        let cluster = ClusterConfig::builder().ranks(4).build();
        let small_pool = RankPool::from_config(&ClusterConfig::builder().ranks(2).build());
        let err = MapReduceJob::new(&cluster, &input)
            .with_pool(&small_pool)
            .run_eager(wc_map, |a, b| *a += b)
            .unwrap_err();
        assert!(format!("{err:#}").contains("rank pool"), "{err:#}");
    }

    #[test]
    fn placed_subset_matches_fresh_spawn() {
        // A width-2 job placed on ranks {1,3} of a warm single-node
        // width-4 pool must be byte-identical to a fresh 2-rank run —
        // subset renumbering keeps SPMD bodies placement-oblivious.
        let input = wordcount_input(90);
        let pool_cluster = ClusterConfig::builder().nodes(1).slots_per_node(4).build();
        let job_cluster = ClusterConfig::builder().nodes(1).slots_per_node(2).build();
        let pool = RankPool::from_config(&pool_cluster);
        for mode in ReductionMode::ALL {
            let fresh = MapReduceJob::new(&job_cluster, &input)
                .with_mode(mode)
                .run_monoid(wc_map, |a: u64, b| a + b)
                .unwrap();
            let placed = MapReduceJob::new(&job_cluster, &input)
                .with_mode(mode)
                .with_placement(&pool, &[1, 3])
                .run_monoid(wc_map, |a: u64, b| a + b)
                .unwrap();
            assert_eq!(fresh.result, placed.result, "mode {mode}");
            assert_eq!(fresh.stats.shuffle_bytes, placed.stats.shuffle_bytes, "mode {mode}");
            assert_eq!(fresh.stats.messages, placed.stats.messages, "mode {mode}");
        }
        assert_eq!(pool.jobs_run(), 3);
    }

    #[test]
    fn placement_width_mismatch_is_rejected() {
        let input = wordcount_input(10);
        let pool_cluster = ClusterConfig::builder().nodes(1).slots_per_node(4).build();
        let job_cluster = ClusterConfig::builder().nodes(1).slots_per_node(2).build();
        let pool = RankPool::from_config(&pool_cluster);
        let err = MapReduceJob::new(&job_cluster, &input)
            .with_placement(&pool, &[0, 1, 2])
            .run_eager(wc_map, |a, b| *a += b)
            .unwrap_err();
        assert!(format!("{err:#}").contains("rank"), "{err:#}");
    }

    #[test]
    fn combiner_matches_classic_and_cuts_shuffle_volume() {
        // Small key range, many lines: the map-side combiner should
        // collapse almost all raw pairs before the wire while leaving
        // the result untouched — Hadoop's combiner contract.
        let input = wordcount_input(300);
        let cluster = ClusterConfig::builder().ranks(4).build();
        let raw = MapReduceJob::new(&cluster, &input)
            .run_classic(wc_map, |_k, vs: &mut dyn Iterator<Item = u64>| vs.sum())
            .unwrap();
        let combined = MapReduceJob::new(&cluster, &input)
            .run_classic_with_combiner(
                wc_map,
                |a: &mut u64, b: u64| *a += b,
                |_k, vs: &mut dyn Iterator<Item = u64>| vs.sum(),
            )
            .unwrap();
        assert_eq!(raw.result, combined.result);
        assert_eq!(raw.stats.combined_bytes, 0);
        assert!(combined.stats.combined_bytes > 0);
        assert!(
            combined.stats.shuffle_bytes * 2 < raw.stats.shuffle_bytes,
            "combined {} vs raw {}",
            combined.stats.shuffle_bytes,
            raw.stats.shuffle_bytes
        );
    }

    #[test]
    fn tiny_budget_delayed_and_classic_match_unlimited() {
        // The out-of-core tentpole at engine level: a budget far below
        // the staged volume must spill and still give identical results.
        let input = wordcount_input(400);
        let tight = ClusterConfig::builder().ranks(3).shuffle_buffer_bytes(2048).build();
        let roomy = ClusterConfig::builder().ranks(3).shuffle_buffer_bytes(u64::MAX).build();
        for mode in [ReductionMode::Classic, ReductionMode::Delayed] {
            let a = MapReduceJob::new(&tight, &input)
                .with_mode(mode)
                .run_monoid(wc_map, |a: u64, b| a + b)
                .unwrap();
            let b = MapReduceJob::new(&roomy, &input)
                .with_mode(mode)
                .run_monoid(wc_map, |a: u64, b| a + b)
                .unwrap();
            assert_eq!(a.result, b.result, "mode {mode}");
            assert!(a.stats.spilled_bytes > 0, "mode {mode} must spill");
            assert_eq!(b.stats.spilled_bytes, 0, "mode {mode} unlimited must not");
        }
    }

    #[test]
    fn collective_algos_agree_and_hierarchical_coalesces() {
        use crate::mpi::CollectiveAlgo;
        let input = wordcount_input(200);
        let cluster = |algo| {
            ClusterConfig::builder()
                .deployment(DeploymentKind::Container)
                .nodes(2)
                .slots_per_node(3)
                .collective_algo(algo)
                .build()
        };
        let mut outputs = Vec::new();
        for algo in CollectiveAlgo::ALL {
            for mode in ReductionMode::ALL {
                let out = MapReduceJob::new(&cluster(algo), &input)
                    .with_mode(mode)
                    .run_monoid(wc_map, |a: u64, b| a + b)
                    .unwrap();
                outputs.push((algo, out));
            }
        }
        for (algo, out) in &outputs[1..] {
            assert_eq!(out.result, outputs[0].1.result, "{algo} diverged");
        }
        // The same eager shuffle under hierarchical collectives crosses
        // node boundaries in coalesced bundles: fewer remote messages.
        let star = &outputs[1].1.stats; // (Star, Eager)
        let hier = &outputs[7].1.stats; // (Hierarchical, Eager)
        assert!(
            hier.remote_messages < star.remote_messages,
            "hier {} vs star {} remote messages",
            hier.remote_messages,
            star.remote_messages
        );
    }

    #[test]
    fn empty_input_produces_empty_result() {
        let input: Vec<String> = Vec::new();
        let cluster = ClusterConfig::builder().ranks(2).build();
        let out = MapReduceJob::new(&cluster, &input)
            .run_eager(wc_map, |a, b| *a += b)
            .unwrap();
        assert!(out.result.is_empty());
    }

    #[test]
    fn eager_moves_fewer_bytes_than_classic_on_small_keyrange() {
        // The Fig 2 vs Fig 1 claim: eager's shuffle volume collapses when
        // the key range is small.
        let input = wordcount_input(400);
        let cluster = ClusterConfig::builder().ranks(4).build();
        let eager = MapReduceJob::new(&cluster, &input)
            .with_mode(ReductionMode::Eager)
            .run_monoid(wc_map, |a: u64, b| a + b)
            .unwrap();
        let classic = MapReduceJob::new(&cluster, &input)
            .with_mode(ReductionMode::Classic)
            .run_monoid(wc_map, |a: u64, b| a + b)
            .unwrap();
        assert_eq!(eager.result, classic.result);
        assert!(
            eager.stats.shuffle_bytes * 2 < classic.stats.shuffle_bytes,
            "eager {} vs classic {}",
            eager.stats.shuffle_bytes,
            classic.stats.shuffle_bytes
        );
    }
}
