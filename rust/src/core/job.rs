//! Job-level configuration and results.

/// Which reduction strategy the engine runs (see module docs of
/// [`crate::core`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ReductionMode {
    /// Hadoop-style full shuffle then reduce (paper Fig 1).
    Classic,
    /// Blaze eager reduction: combine during map (paper Fig 2).
    #[default]
    Eager,
    /// The paper's Delayed Reduction (§III.D, Figs 6-7).
    Delayed,
}

impl ReductionMode {
    pub const ALL: [ReductionMode; 3] =
        [ReductionMode::Classic, ReductionMode::Eager, ReductionMode::Delayed];
}

impl std::fmt::Display for ReductionMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ReductionMode::Classic => "classic",
            ReductionMode::Eager => "eager",
            ReductionMode::Delayed => "delayed",
        };
        f.write_str(s)
    }
}

impl std::str::FromStr for ReductionMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "classic" => Ok(ReductionMode::Classic),
            "eager" => Ok(ReductionMode::Eager),
            "delayed" => Ok(ReductionMode::Delayed),
            other => Err(anyhow::anyhow!("unknown reduction mode {other:?}")),
        }
    }
}

/// Task assignment policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Scheduling {
    /// Even static split (MPI default; exhibits the data-skew stragglers
    /// the paper complains about in §I).
    Static,
    /// Dynamic work claiming from a shared queue (skew mitigation).
    #[default]
    Dynamic,
}

/// Per-job knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct JobConfig {
    pub mode: ReductionMode,
    pub scheduling: Scheduling,
    /// Input chunks per rank (dynamic scheduling granularity).
    pub tasks_per_rank: usize,
    /// Partition salt (combined with the cluster seed).
    pub salt: u64,
}

impl Default for JobConfig {
    fn default() -> Self {
        Self {
            mode: ReductionMode::default(),
            scheduling: Scheduling::default(),
            tasks_per_rank: 4,
            salt: 0,
        }
    }
}

impl JobConfig {
    pub fn with_mode(mode: ReductionMode) -> Self {
        Self { mode, ..Default::default() }
    }
}

/// Measured + modeled execution statistics for one job.
///
/// These are the *engine-level* numbers for a single SPMD execution.
/// When a job runs through the concurrent admission layer, the
/// scheduler wraps them with queue-level accounting — queue wait,
/// rank subset, harvested trace — in [`crate::core::SchedJobStats`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobStats {
    /// Modeled wall time: slowest rank's virtual clock + cluster startup.
    pub modeled_ms: f64,
    /// Modeled compute part (slowest rank).
    pub compute_ms: f64,
    /// Modeled network part (slowest rank).
    pub net_ms: f64,
    /// Cluster bring-up charged by the deployment profile.
    pub startup_ms: f64,
    /// Total bytes crossing the (virtual) wire.
    pub shuffle_bytes: u64,
    /// Messages on the wire.
    pub messages: u64,
    /// Messages that crossed node boundaries — the count the
    /// hierarchical (node-coalesced) collectives shrink.
    pub remote_messages: u64,
    /// Bytes that crossed node boundaries.
    pub remote_bytes: u64,
    /// Peak modeled data-path memory across the job (Fig 13).
    pub peak_mem_bytes: u64,
    /// Bytes spilled to disk by the shuffle (out-of-core path).
    pub spilled_bytes: u64,
    /// Bytes the map-side combiner folded away before the wire
    /// (0 unless the job ran with a combiner).
    pub combined_bytes: u64,
    /// Bytes moved between ranks by live elastic rebalancing —
    /// [`crate::core::IterativeJob`] shard migrations after an
    /// `ElasticCluster` grow/shrink. 0 for one-shot jobs. Kept separate
    /// from `shuffle_bytes` so the per-iteration delta-shuffle cost and
    /// the one-off resize cost stay individually visible (the e12
    /// `iterative-ablation` figure plots both).
    pub migrated_bytes: u64,
    /// Host wall-clock of the whole job (for harness sanity only —
    /// figures use `modeled_ms`).
    pub host_wall_ms: f64,
}

impl JobStats {
    /// Human-readable one-stop report: the modeled time split, wire
    /// traffic, memory/disk high-water marks, and (when non-zero) the
    /// combiner/migration byte counts. Multi-line, ready to print —
    /// the `blaze run` CLI and the examples use it verbatim.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "modeled {:.2} ms (compute {:.2} + net {:.2} + startup {:.0})\n\
             shuffle {} B in {} msgs ({} msgs / {} B remote)\n\
             peak mem {} B | spilled {} B",
            self.modeled_ms,
            self.compute_ms,
            self.net_ms,
            self.startup_ms,
            self.shuffle_bytes,
            self.messages,
            self.remote_messages,
            self.remote_bytes,
            self.peak_mem_bytes,
            self.spilled_bytes,
        );
        if self.combined_bytes > 0 {
            s.push_str(&format!(" | combined away {} B", self.combined_bytes));
        }
        if self.migrated_bytes > 0 {
            s.push_str(&format!(" | migrated {} B", self.migrated_bytes));
        }
        s.push_str(&format!("\nhost wall {:.1} ms", self.host_wall_ms));
        s
    }
}

/// A completed job: driver-side result + stats.
#[derive(Debug, Clone)]
pub struct JobResult<R> {
    pub result: R,
    pub stats: JobStats,
}

impl<R> JobResult<R> {
    pub fn map<S>(self, f: impl FnOnce(R) -> S) -> JobResult<S> {
        JobResult { result: f(self.result), stats: self.stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for mode in ReductionMode::ALL {
            let parsed: ReductionMode = mode.to_string().parse().unwrap();
            assert_eq!(parsed, mode);
        }
        assert!("hadoop".parse::<ReductionMode>().is_err());
    }

    #[test]
    fn config_defaults_sane() {
        let c = JobConfig::default();
        assert_eq!(c.mode, ReductionMode::Eager);
        assert!(c.tasks_per_rank >= 1);
    }

    #[test]
    fn job_result_map() {
        let r = JobResult { result: 21u32, stats: JobStats::default() };
        assert_eq!(r.map(|x| x * 2).result, 42);
    }
}
