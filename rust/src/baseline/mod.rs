//! The Spark/JVM comparison baseline (Figs 9, 11, 13).
//!
//! We cannot ship a JVM, so this is the documented substitution: the same
//! workloads executed through an RDD-style stage pipeline whose virtual
//! clock and memory accounting charge the JVM costs the paper attributes
//! to Hadoop/Spark — object headers and boxing on every record, slow
//! serialization on the shuffle boundary, generational GC pauses, JVM +
//! executor startup, and disk-backed shuffle files. Constants and sources
//! live in [`jvm`].

pub mod jvm;
pub mod rdd;
pub mod spark;

pub use jvm::JvmCostModel;
pub use spark::{SparkContext, SparkJobStats};
