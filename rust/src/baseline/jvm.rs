//! JVM cost model — the documented constants behind the Spark-sim
//! baseline (DESIGN.md §3 substitution table).
//!
//! Every constant is an order-of-magnitude figure from public JVM/Spark
//! literature; the *figures* only rely on their relative magnitude vs the
//! native path, which is robust:
//!
//! * object header: 12-16 B on HotSpot (16 with alignment); a boxed
//!   `(String, Long)` record costs 3 object headers + fields — the "memory
//!   overhead is a real problem" bullet of §I.
//! * Java serialization: ~50-150 MB/s per core vs >1 GB/s for a
//!   memcpy-shaped binary codec — the "de-serialisation ... is very slow
//!   due to creation and deletion of too many objects" bullet.
//! * generational GC: young collections pause ~1-10 ms and scale with the
//!   live set; allocation-heavy shuffles trigger them continuously.
//! * JVM + executor startup: seconds (the paper's Spark jobs pay it per
//!   application).
//! * Spark shuffles write map output to disk then read it back.

/// Tunable JVM/Spark cost constants (ns / bytes).
#[derive(Debug, Clone, PartialEq)]
pub struct JvmCostModel {
    /// Bytes of header+alignment overhead per heap object.
    pub object_header_bytes: u64,
    /// Heap objects allocated per shuffled record (key box, value box,
    /// tuple wrapper).
    pub objects_per_record: u64,
    /// Expansion factor of deserialized data vs its serialized bytes
    /// (Strings are UTF-16 in the JVM, fields are padded, ...).
    pub heap_expansion: f64,
    /// ns of CPU per record crossing a serialization boundary.
    pub ser_ns_per_record: u64,
    /// ns per serialized byte (≈ 1/(80 MB/s) = 12.5 ns/B).
    pub ser_ns_per_byte: f64,
    /// ns per byte written+read through shuffle files.
    pub shuffle_disk_ns_per_byte: f64,
    /// Young-generation size before a minor GC fires.
    pub young_gen_bytes: u64,
    /// Pause per minor GC, ns.
    pub minor_gc_pause_ns: u64,
    /// JVM + SparkContext startup.
    pub jvm_startup_ms: u64,
    /// Per-executor startup (parallel across executors).
    pub executor_startup_ms: u64,
    /// Per-task scheduling/dispatch overhead (Spark's ~ms task launch).
    pub task_overhead_ns: u64,
}

impl Default for JvmCostModel {
    fn default() -> Self {
        Self {
            object_header_bytes: 16,
            objects_per_record: 3,
            heap_expansion: 3.0,
            ser_ns_per_record: 150,
            ser_ns_per_byte: 12.5,
            shuffle_disk_ns_per_byte: 3.0,
            young_gen_bytes: 64 << 20,
            minor_gc_pause_ns: 3_000_000, // 3 ms
            jvm_startup_ms: 3_000,
            executor_startup_ms: 1_500,
            task_overhead_ns: 1_000_000, // 1 ms per task
        }
    }
}

impl JvmCostModel {
    /// Heap bytes a record of `payload_bytes` occupies once deserialized.
    pub fn record_heap_bytes(&self, payload_bytes: u64) -> u64 {
        (payload_bytes as f64 * self.heap_expansion) as u64
            + self.object_header_bytes * self.objects_per_record
    }

    /// ns to serialize (or deserialize) `records` totalling `bytes`.
    pub fn ser_cost_ns(&self, records: u64, bytes: u64) -> u64 {
        records * self.ser_ns_per_record + (bytes as f64 * self.ser_ns_per_byte) as u64
    }

    /// ns of disk time for `bytes` through shuffle files (write + read).
    pub fn shuffle_disk_ns(&self, bytes: u64) -> u64 {
        (2.0 * bytes as f64 * self.shuffle_disk_ns_per_byte) as u64
    }

    /// ns of GC pauses induced by allocating `bytes` of short-lived data.
    pub fn gc_pause_ns(&self, allocated_bytes: u64) -> u64 {
        (allocated_bytes / self.young_gen_bytes.max(1)) * self.minor_gc_pause_ns
    }

    /// Startup charged to a job with `executors` executors (parallel
    /// executor bring-up).
    pub fn startup_ms(&self, _executors: usize) -> u64 {
        self.jvm_startup_ms + self.executor_startup_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_overhead_dominates_small_records() {
        let m = JvmCostModel::default();
        // A ("word", 1L) record serializes to ~10 bytes but occupies far
        // more heap — the Fig 13 mechanism.
        let heap = m.record_heap_bytes(10);
        assert!(heap >= 70, "heap {heap}");
    }

    #[test]
    fn gc_pauses_scale_with_allocation() {
        let m = JvmCostModel::default();
        assert_eq!(m.gc_pause_ns(0), 0);
        let one_gen = m.gc_pause_ns(64 << 20);
        let ten_gen = m.gc_pause_ns(10 * (64 << 20));
        assert_eq!(one_gen, m.minor_gc_pause_ns);
        assert_eq!(ten_gen, 10 * m.minor_gc_pause_ns);
    }

    #[test]
    fn serialization_slower_than_disk_model_for_small_records() {
        let m = JvmCostModel::default();
        // 1M tiny records: per-record cost dominates byte cost.
        let ser = m.ser_cost_ns(1_000_000, 10_000_000);
        assert!(ser > 150_000_000);
    }
}
