//! Spark-sim: the paper's comparison baseline (§V, Figs 9/11/13), built
//! on the mini-RDD pipeline + JVM cost model.
//!
//! `SparkContext` mirrors the PySpark/MLlib programs the paper compares
//! against: `wordcount` = `textFile.flatMap.map.reduceByKey`, `kmeans` =
//! MLlib's iterative assign/update (one shuffle per iteration), `pi` =
//! the classic `parallelize(range).map(inside).reduce(add)` example.
//! Results are *correct* (the computation really runs); the modeled clock
//! and heap charge what a JVM would pay on the same deployment.

use std::collections::HashMap;

use crate::cluster::ClusterConfig;
use crate::util::rng::Rng;

use super::jvm::JvmCostModel;
use super::rdd::{JobTrace, Rdd};

/// Stats mirroring [`crate::core::JobStats`] for apples-to-apples tables.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparkJobStats {
    pub modeled_ms: f64,
    pub startup_ms: f64,
    pub gc_ms: f64,
    pub shuffle_bytes: u64,
    pub peak_mem_bytes: u64,
    pub stages: usize,
}

/// The simulated Spark driver.
pub struct SparkContext {
    executors: usize,
    partitions_per_executor: usize,
    jvm: JvmCostModel,
    /// Deployment compute scaling (a Spark job on RPis is slow too).
    compute_scale: f64,
}

impl SparkContext {
    pub fn new(cluster: &ClusterConfig) -> Self {
        Self {
            executors: cluster.ranks(),
            partitions_per_executor: 2,
            jvm: JvmCostModel::default(),
            compute_scale: cluster.deployment.profile().effective_compute_scale(),
        }
    }

    pub fn with_jvm(mut self, jvm: JvmCostModel) -> Self {
        self.jvm = jvm;
        self
    }

    fn partitions(&self) -> usize {
        self.executors * self.partitions_per_executor
    }

    fn finish(&self, trace: JobTrace) -> SparkJobStats {
        // Like JobStats::modeled_ms, job time excludes session bring-up
        // (JVM + executors); it is reported in `startup_ms` so tables can
        // show both (a cold spark-submit pays it per application).
        let startup = self.jvm.startup_ms(self.executors) as f64;
        SparkJobStats {
            modeled_ms: trace.elapsed_ns() as f64 / 1e6 * self.compute_scale,
            startup_ms: startup,
            gc_ms: trace.gc_ns as f64 / 1e6,
            shuffle_bytes: trace.shuffle_bytes,
            peak_mem_bytes: trace.heap_bytes_peak,
            stages: trace.stages,
        }
    }

    /// `sc.textFile(..).flatMap(split).map(w -> (w,1)).reduceByKey(+)`.
    pub fn wordcount(&self, lines: &[String]) -> (HashMap<String, u64>, SparkJobStats) {
        let mut trace = JobTrace::new(self.executors);
        let avg_line = lines.iter().map(String::len).sum::<usize>().max(1) as u64
            / lines.len().max(1) as u64;
        let rdd = Rdd::parallelize(lines.to_vec(), self.partitions(), avg_line, &self.jvm, &mut trace);
        // flatMap to (word, 1) pairs — ~12 serialized bytes per pair.
        let pairs = rdd.flat_map(&self.jvm, &mut trace, 12, |line, out| {
            for w in line.split_whitespace() {
                out.push((w.to_string(), 1u64));
            }
        });
        let result = pairs.reduce_by_key(&self.jvm, &mut trace, 12, |a, b| a + b);
        (result, self.finish(trace))
    }

    /// MLlib-style K-means: one (assign -> partial-sum shuffle -> update)
    /// round per iteration over cached points.
    pub fn kmeans(
        &self,
        points: &crate::apps::kmeans::Points,
        k: usize,
        iterations: usize,
    ) -> (Vec<f32>, SparkJobStats) {
        let d = points.d;
        let mut trace = JobTrace::new(self.executors);
        let rows: Vec<usize> = (0..points.n).collect();
        let point_bytes = (d * 4) as u64;
        let rdd = Rdd::parallelize(rows, self.partitions(), point_bytes, &self.jvm, &mut trace);
        // Cache the deserialized points for the job's lifetime (MLlib
        // caches the input RDD) — the big Fig 13 term.
        trace.heap_alloc(points.n as u64 * self.jvm.record_heap_bytes(point_bytes));

        let mut centroids: Vec<f32> = points.data[..k * d].to_vec();
        for _ in 0..iterations {
            // Assign stage (narrow) — really computes, per partition.
            let assigned = Rdd {
                partitions: rdd
                    .partitions
                    .iter()
                    .map(|p| super::rdd::Partition { items: p.items.clone() })
                    .collect(),
            }
            .flat_map(&self.jvm, &mut trace, point_bytes + 8, |i, out| {
                let p = points.row(i);
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for c in 0..k {
                    let q = &centroids[c * d..(c + 1) * d];
                    let mut dist = 0.0f32;
                    for j in 0..d {
                        let diff = p[j] - q[j];
                        dist += diff * diff;
                    }
                    if dist < best_d {
                        best_d = dist;
                        best = c;
                    }
                }
                out.push((best as u32, i));
            });
            // Partial-sum shuffle + update.
            let sums = assigned.reduce_by_key(
                &self.jvm,
                &mut trace,
                point_bytes + 8,
                // Combine keeps the first index; the real sum happens below
                // (the cost model only needs record counts, the math needs
                // the full member list — we recompute sums directly).
                |a, _b| a,
            );
            // Recompute proper means (correctness path).
            let mut new_centroids = vec![0.0f32; k * d];
            let mut counts = vec![0u32; k];
            for i in 0..points.n {
                let p = points.row(i);
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for c in 0..k {
                    let q = &centroids[c * d..(c + 1) * d];
                    let mut dist = 0.0f32;
                    for j in 0..d {
                        let diff = p[j] - q[j];
                        dist += diff * diff;
                    }
                    if dist < best_d {
                        best_d = dist;
                        best = c;
                    }
                }
                counts[best] += 1;
                for j in 0..d {
                    new_centroids[best * d + j] += p[j];
                }
            }
            for c in 0..k {
                if counts[c] > 0 {
                    for j in 0..d {
                        new_centroids[c * d + j] /= counts[c] as f32;
                    }
                } else {
                    new_centroids[c * d..(c + 1) * d]
                        .copy_from_slice(&centroids[c * d..(c + 1) * d]);
                }
            }
            centroids = new_centroids;
            let _ = sums;
        }
        (centroids, self.finish(trace))
    }

    /// `sc.parallelize(chunks).map(count_inside).reduce(+)`.
    pub fn pi(&self, chunks: &[crate::apps::pi::Chunk]) -> (f64, SparkJobStats) {
        let mut trace = JobTrace::new(self.executors);
        let total: u64 = chunks.iter().map(|c| c.samples as u64).sum();
        let rdd =
            Rdd::parallelize(chunks.to_vec(), self.partitions(), 16, &self.jvm, &mut trace);
        let counts = rdd.flat_map(&self.jvm, &mut trace, 16, |chunk, out| {
            let mut rng = Rng::with_stream(chunk.seed, 0x3141);
            let mut inside = 0u64;
            for _ in 0..chunk.samples {
                let x = rng.f64();
                let y = rng.f64();
                inside += u64::from(x * x + y * y <= 1.0);
            }
            out.push((0u8, inside));
        });
        let reduced = counts.reduce_by_key(&self.jvm, &mut trace, 16, |a, b| a + b);
        let inside = reduced.get(&0).copied().unwrap_or(0);
        (crate::apps::pi::estimate(inside, total), self.finish(trace))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::wordcount::{count_serial, generate_corpus};
    use crate::cluster::DeploymentKind;

    fn local_cluster(ranks: usize) -> ClusterConfig {
        ClusterConfig::builder().deployment(DeploymentKind::Local).ranks(ranks).build()
    }

    #[test]
    fn spark_wordcount_correct_but_costed() {
        let corpus = generate_corpus(100, 6, 50, 1);
        let sc = SparkContext::new(&local_cluster(4));
        let (counts, stats) = sc.wordcount(&corpus);
        assert_eq!(counts, count_serial(&corpus));
        assert!(stats.modeled_ms > 0.0);
        assert!(stats.startup_ms > 1_000.0);
        assert!(stats.shuffle_bytes > 0);
        assert!(stats.peak_mem_bytes > 0);
        assert!(stats.stages >= 3);
    }

    #[test]
    fn spark_pays_more_than_blaze_for_same_job() {
        // Fig 9/11/13's qualitative claim, in one assertion.
        let corpus = generate_corpus(500, 8, 100, 2);
        let cluster = local_cluster(4);
        let blaze = crate::apps::wordcount::run(
            &cluster,
            &corpus,
            crate::core::ReductionMode::Eager,
        )
        .unwrap();
        let (counts, spark) = SparkContext::new(&cluster).wordcount(&corpus);
        assert_eq!(counts, blaze.result);
        assert!(
            spark.modeled_ms > blaze.stats.modeled_ms,
            "spark {} <= blaze {}",
            spark.modeled_ms,
            blaze.stats.modeled_ms
        );
        assert!(
            spark.peak_mem_bytes > blaze.stats.peak_mem_bytes,
            "spark mem {} <= blaze mem {}",
            spark.peak_mem_bytes,
            blaze.stats.peak_mem_bytes
        );
    }

    #[test]
    fn spark_pi_estimates_pi() {
        let chunks = crate::apps::pi::make_chunks(100_000, 8, 3);
        let (pi, _) = SparkContext::new(&local_cluster(2)).pi(&chunks);
        assert!((pi - std::f64::consts::PI).abs() < 0.05, "pi {pi}");
    }

    #[test]
    fn spark_kmeans_converges() {
        let pts = crate::apps::kmeans::generate_points(300, 2, 3, 5);
        let sc = SparkContext::new(&local_cluster(2));
        let (centroids, stats) = sc.kmeans(&pts, 3, 5);
        assert_eq!(centroids.len(), 6);
        assert!(stats.stages >= 5, "stages {}", stats.stages);
    }
}
