//! A miniature RDD-style stage pipeline: the execution skeleton the
//! Spark-sim baseline runs workloads through.
//!
//! A job is a linear DAG of stages separated by shuffle boundaries, like
//! Spark's `rdd.map(..).reduceByKey(..).collect()`. Each stage really
//! executes (results are correct) while the JVM cost model charges a
//! virtual clock per partition: task dispatch, per-record boxing,
//! serialization at stage edges, shuffle-file disk time and GC pauses.
//! Stage time = max over partitions (executors run them in parallel).

use std::collections::HashMap;
use std::hash::Hash;

use super::jvm::JvmCostModel;

/// Accumulated cost/trace state for one simulated job.
#[derive(Debug, Default, Clone)]
pub struct JobTrace {
    /// Virtual ns per executor (parallel lanes).
    pub lane_ns: Vec<u64>,
    pub gc_ns: u64,
    pub shuffle_bytes: u64,
    pub heap_bytes_peak: u64,
    heap_bytes_now: u64,
    pub stages: usize,
}

impl JobTrace {
    pub fn new(executors: usize) -> Self {
        Self { lane_ns: vec![0; executors.max(1)], ..Default::default() }
    }

    /// Slowest lane = stage-parallel elapsed time.
    pub fn elapsed_ns(&self) -> u64 {
        self.lane_ns.iter().copied().max().unwrap_or(0)
    }

    /// Add `ns` to one executor lane.
    pub fn charge_lane(&mut self, lane: usize, ns: u64) {
        let n = self.lane_ns.len();
        self.lane_ns[lane % n] += ns;
    }

    /// A stage barrier: all lanes advance to the slowest (Spark stages are
    /// synchronized at shuffle boundaries).
    pub fn barrier(&mut self) {
        let max = self.elapsed_ns();
        for l in &mut self.lane_ns {
            *l = max;
        }
        self.stages += 1;
    }

    pub fn heap_alloc(&mut self, bytes: u64) {
        self.heap_bytes_now += bytes;
        self.heap_bytes_peak = self.heap_bytes_peak.max(self.heap_bytes_now);
    }

    pub fn heap_free(&mut self, bytes: u64) {
        self.heap_bytes_now = self.heap_bytes_now.saturating_sub(bytes);
    }
}

/// One partition of typed records flowing between stages.
pub struct Partition<T> {
    pub items: Vec<T>,
}

/// The mini-RDD: partitioned data + the trace it drags along.
pub struct Rdd<T> {
    pub partitions: Vec<Partition<T>>,
}

impl<T> Rdd<T> {
    /// Spark's `parallelize`: split `items` into `n` partitions. Charges
    /// the initial deserialization of the input into JVM objects.
    pub fn parallelize(
        items: Vec<T>,
        n: usize,
        bytes_per_item: u64,
        jvm: &JvmCostModel,
        trace: &mut JobTrace,
    ) -> Self {
        let n = n.max(1);
        let total = items.len();
        let mut partitions: Vec<Partition<T>> = (0..n).map(|_| Partition { items: Vec::new() }).collect();
        for (i, item) in items.into_iter().enumerate() {
            partitions[i * n / total.max(1)].items.push(item);
        }
        for (lane, p) in partitions.iter().enumerate() {
            let records = p.items.len() as u64;
            let bytes = records * bytes_per_item;
            trace.charge_lane(lane, jvm.ser_cost_ns(records, bytes) + jvm.task_overhead_ns);
            trace.heap_alloc(records * jvm.record_heap_bytes(bytes_per_item));
        }
        trace.barrier();
        Self { partitions }
    }

    /// Narrow map stage (no shuffle): `f` runs per item; `out_bytes`
    /// estimates each output record's serialized size for heap accounting.
    pub fn flat_map<U>(
        self,
        jvm: &JvmCostModel,
        trace: &mut JobTrace,
        out_bytes: u64,
        mut f: impl FnMut(T, &mut Vec<U>),
    ) -> Rdd<U> {
        let mut out_parts = Vec::with_capacity(self.partitions.len());
        for (lane, p) in self.partitions.into_iter().enumerate() {
            let in_records = p.items.len() as u64;
            let start = std::time::Instant::now();
            let mut out = Vec::new();
            for item in p.items {
                f(item, &mut out);
            }
            let real_ns = start.elapsed().as_nanos() as u64;
            let out_records = out.len() as u64;
            let alloc = out_records * jvm.record_heap_bytes(out_bytes);
            trace.heap_alloc(alloc);
            let gc = jvm.gc_pause_ns(alloc);
            trace.gc_ns += gc;
            trace.charge_lane(
                lane,
                real_ns
                    + gc
                    + jvm.task_overhead_ns
                    + in_records * jvm.object_header_bytes / 8, // per-record iterator+unboxing cost, ~2ns/B-of-header
            );
            out_parts.push(Partition { items: out });
        }
        trace.barrier();
        Rdd { partitions: out_parts }
    }

    /// Release this RDD's heap (end of lineage / unpersist).
    pub fn heap_bytes(&self, bytes_per_item: u64, jvm: &JvmCostModel) -> u64 {
        self.partitions
            .iter()
            .map(|p| p.items.len() as u64 * jvm.record_heap_bytes(bytes_per_item))
            .sum()
    }
}

impl<K: Hash + Eq, V> Rdd<(K, V)> {
    /// `reduceByKey`: shuffle boundary + combine. Charges map-side
    /// serialization, shuffle-file disk time, reduce-side deserialization
    /// and GC for the grouped data.
    pub fn reduce_by_key(
        self,
        jvm: &JvmCostModel,
        trace: &mut JobTrace,
        record_bytes: u64,
        mut combine: impl FnMut(V, V) -> V,
    ) -> HashMap<K, V> {
        // Map-side: serialize every record to shuffle files.
        let mut total_records = 0u64;
        for (lane, p) in self.partitions.iter().enumerate() {
            let records = p.items.len() as u64;
            total_records += records;
            let bytes = records * record_bytes;
            trace.charge_lane(
                lane,
                jvm.ser_cost_ns(records, bytes) + jvm.shuffle_disk_ns(bytes) + jvm.task_overhead_ns,
            );
        }
        trace.shuffle_bytes += total_records * record_bytes;
        trace.barrier();

        // Reduce-side: deserialize from shuffle files, then combine (the
        // combine really executes; reducers run in parallel lanes so the
        // measured time is divided across them).
        let lanes = trace.lane_ns.len() as u64;
        let deser_bytes = total_records * record_bytes;
        let deser_ns = jvm.ser_cost_ns(total_records, deser_bytes) / lanes.max(1);
        let grouped_alloc = total_records * jvm.record_heap_bytes(record_bytes);
        trace.heap_alloc(grouped_alloc);
        let gc = jvm.gc_pause_ns(grouped_alloc);
        trace.gc_ns += gc;

        let start = std::time::Instant::now();
        let mut out: HashMap<K, V> = HashMap::new();
        for p in self.partitions {
            for (k, v) in p.items {
                let newv = match out.remove(&k) {
                    Some(old) => combine(old, v),
                    None => v,
                };
                out.insert(k, newv);
            }
        }
        let combine_ns = (start.elapsed().as_nanos() as u64) / lanes.max(1);
        for lane in 0..trace.lane_ns.len() {
            trace.charge_lane(lane, deser_ns + combine_ns + gc + jvm.task_overhead_ns);
        }
        trace.heap_free(grouped_alloc);
        trace.barrier();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_barrier_levels_lanes() {
        let mut t = JobTrace::new(3);
        t.charge_lane(0, 10);
        t.charge_lane(1, 50);
        t.barrier();
        assert_eq!(t.lane_ns, vec![50, 50, 50]);
        assert_eq!(t.stages, 1);
    }

    #[test]
    fn parallelize_distributes_and_charges() {
        let jvm = JvmCostModel::default();
        let mut trace = JobTrace::new(2);
        let rdd = Rdd::parallelize((0..100).collect::<Vec<u32>>(), 2, 8, &jvm, &mut trace);
        assert_eq!(rdd.partitions.len(), 2);
        assert_eq!(rdd.partitions.iter().map(|p| p.items.len()).sum::<usize>(), 100);
        assert!(trace.elapsed_ns() > 0);
        assert!(trace.heap_bytes_peak > 100 * 8);
    }
}
