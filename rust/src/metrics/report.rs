//! Bench report formatting: the tables/series the harness prints for each
//! paper figure, plus JSON export so EXPERIMENTS.md numbers are scriptable.

use crate::util::json::Json;

/// One labelled series of (x, y) points — a line on a paper figure.
#[derive(Debug, Clone, PartialEq)]
pub struct Series {
    pub label: String,
    pub x_name: String,
    pub y_name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(
        label: impl Into<String>,
        x_name: impl Into<String>,
        y_name: impl Into<String>,
    ) -> Self {
        Self {
            label: label.into(),
            x_name: x_name.into(),
            y_name: y_name.into(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// y(last)/y(first): the scaling factor across the sweep.
    pub fn end_to_end_ratio(&self) -> Option<f64> {
        let first = self.points.first()?.1;
        let last = self.points.last()?.1;
        if first == 0.0 {
            None
        } else {
            Some(last / first)
        }
    }
}

/// A figure-shaped report: title + several series + free-form notes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Report {
    pub title: String,
    pub series: Vec<Series>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), ..Default::default() }
    }

    pub fn add(&mut self, series: Series) {
        self.series.push(series);
    }

    pub fn note(&mut self, n: impl Into<String>) {
        self.notes.push(n.into());
    }

    /// Render as an aligned text table (what `blaze bench-figure` prints).
    pub fn to_table(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        for s in &self.series {
            let _ = writeln!(out, "-- {} ({} vs {})", s.label, s.y_name, s.x_name);
            let _ = writeln!(out, "{:>14} {:>16}", s.x_name, s.y_name);
            for (x, y) in &s.points {
                let _ = writeln!(out, "{x:>14.3} {y:>16.3}");
            }
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }

    pub fn to_json(&self) -> String {
        self.to_json_value().to_string_pretty()
    }

    pub fn to_json_value(&self) -> Json {
        Json::obj([
            ("title", Json::str(self.title.clone())),
            (
                "series",
                Json::arr(self.series.iter().map(|s| {
                    Json::obj([
                        ("label", Json::str(s.label.clone())),
                        ("x_name", Json::str(s.x_name.clone())),
                        ("y_name", Json::str(s.y_name.clone())),
                        (
                            "points",
                            Json::arr(
                                s.points
                                    .iter()
                                    .map(|&(x, y)| Json::arr([Json::num(x), Json::num(y)])),
                            ),
                        ),
                    ])
                })),
            ),
            ("notes", Json::arr(self.notes.iter().map(|n| Json::str(n.clone())))),
        ])
    }

    /// Parse a report previously written by [`Report::to_json`].
    pub fn from_json(text: &str) -> anyhow::Result<Report> {
        let v = Json::parse(text)?;
        let mut report = Report::new(
            v.req("title")?.as_str().unwrap_or_default().to_string(),
        );
        for s in v.req("series")?.as_arr().unwrap_or(&[]) {
            let mut series = Series::new(
                s.req("label")?.as_str().unwrap_or_default(),
                s.req("x_name")?.as_str().unwrap_or_default(),
                s.req("y_name")?.as_str().unwrap_or_default(),
            );
            for p in s.req("points")?.as_arr().unwrap_or(&[]) {
                let xy = p.as_arr().unwrap_or(&[]);
                if let [x, y] = xy {
                    series.push(x.as_f64().unwrap_or(0.0), y.as_f64().unwrap_or(0.0));
                }
            }
            report.add(series);
        }
        for n in v.req("notes")?.as_arr().unwrap_or(&[]) {
            report.note(n.as_str().unwrap_or_default());
        }
        Ok(report)
    }

    /// Write JSON next to the repo's bench outputs.
    pub fn save_json(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_contains_all_points() {
        let mut r = Report::new("Fig X");
        let mut s = Series::new("blaze", "nodes", "ms");
        s.push(1.0, 100.0);
        s.push(2.0, 55.0);
        r.add(s);
        r.note("shape: halves with nodes");
        let t = r.to_table();
        assert!(t.contains("Fig X"));
        assert!(t.contains("55.000"));
        assert!(t.contains("note: shape"));
    }

    #[test]
    fn ratio_math() {
        let mut s = Series::new("x", "n", "t");
        s.push(1.0, 100.0);
        s.push(4.0, 25.0);
        assert_eq!(s.end_to_end_ratio(), Some(0.25));
    }

    #[test]
    fn json_roundtrip() {
        let mut r = Report::new("fig");
        let mut s = Series::new("a", "x", "y");
        s.push(1.0, 2.0);
        r.add(s);
        r.note("hello");
        let back = Report::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
    }
}
