//! Small timing helpers used by the engine and the bench harness.

use std::time::{Duration, Instant};

/// Accumulating stopwatch: start/stop many times, read the total.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t) = self.started.take() {
            self.total += t.elapsed();
        }
    }

    pub fn total(&self) -> Duration {
        match self.started {
            Some(t) => self.total + t.elapsed(),
            None => self.total,
        }
    }

    pub fn total_ns(&self) -> u64 {
        self.total().as_nanos() as u64
    }

    /// Time one closure, accumulating its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

/// RAII timer that reports elapsed time into a callback on drop.
pub struct ScopedTimer<F: FnMut(Duration)> {
    start: Instant,
    sink: F,
}

impl<F: FnMut(Duration)> ScopedTimer<F> {
    pub fn new(sink: F) -> Self {
        Self { start: Instant::now(), sink }
    }
}

impl<F: FnMut(Duration)> Drop for ScopedTimer<F> {
    fn drop(&mut self) {
        let elapsed = self.start.elapsed();
        (self.sink)(elapsed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(1)));
        let first = sw.total();
        sw.time(|| std::thread::sleep(Duration::from_millis(1)));
        assert!(sw.total() >= first + Duration::from_millis(1));
    }

    #[test]
    fn stopwatch_running_total_visible() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(1));
        assert!(sw.total() >= Duration::from_millis(1));
        sw.stop();
    }

    #[test]
    fn scoped_timer_fires_on_drop() {
        let mut got = Duration::ZERO;
        {
            let _t = ScopedTimer::new(|d| got = d);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(got >= Duration::from_millis(1));
    }
}
