//! Small timing helpers used by the engine and the bench harness.
//!
//! Since the [`Registry`](super::Registry) landed these are thin
//! wrappers over its duration path: a [`Stopwatch`] can flush its total
//! into a registry histogram ([`Stopwatch::record_into`]) and a
//! [`ScopedTimer`] is a stopwatch bound to a sink — there is one way a
//! duration becomes a recorded metric
//! ([`Registry::observe_duration`](super::Registry::observe_duration)).

use std::time::{Duration, Instant};

use super::Registry;

/// Accumulating stopwatch: start/stop many times, read the total.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t) = self.started.take() {
            self.total += t.elapsed();
        }
    }

    pub fn total(&self) -> Duration {
        match self.started {
            Some(t) => self.total + t.elapsed(),
            None => self.total,
        }
    }

    pub fn total_ns(&self) -> u64 {
        self.total().as_nanos() as u64
    }

    /// Time one closure, accumulating its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }

    /// Flush the accumulated total into a registry histogram — the
    /// bridge from ad-hoc timing to the canonical duration path.
    pub fn record_into(&self, registry: &Registry, name: &str) {
        registry.observe_duration(name, self.total());
    }
}

/// RAII timer that reports elapsed time into a callback on drop. A thin
/// wrapper over [`Stopwatch`]; to land in a [`Registry`] directly, use
/// [`Registry::scoped`](super::Registry::scoped) instead.
pub struct ScopedTimer<F: FnMut(Duration)> {
    watch: Stopwatch,
    sink: F,
}

impl<F: FnMut(Duration)> ScopedTimer<F> {
    pub fn new(sink: F) -> Self {
        let mut watch = Stopwatch::new();
        watch.start();
        Self { watch, sink }
    }
}

impl<F: FnMut(Duration)> Drop for ScopedTimer<F> {
    fn drop(&mut self) {
        self.watch.stop();
        (self.sink)(self.watch.total());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(1)));
        let first = sw.total();
        sw.time(|| std::thread::sleep(Duration::from_millis(1)));
        assert!(sw.total() >= first + Duration::from_millis(1));
    }

    #[test]
    fn stopwatch_running_total_visible() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(1));
        assert!(sw.total() >= Duration::from_millis(1));
        sw.stop();
    }

    #[test]
    fn scoped_timer_fires_on_drop() {
        let mut got = Duration::ZERO;
        {
            let _t = ScopedTimer::new(|d| got = d);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(got >= Duration::from_millis(1));
    }

    #[test]
    fn stopwatch_flushes_into_registry() {
        let r = Registry::new();
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(1)));
        sw.record_into(&r, "stage");
        let h = r.histogram("stage").unwrap();
        assert_eq!(h.count(), 1);
        assert!(h.min() >= 1_000_000);
    }
}
