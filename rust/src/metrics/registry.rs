//! [`Registry`]: the one place durations, counts and distributions are
//! recorded. Counters and gauges are plain named values; histograms
//! keep raw samples and answer quantile queries (p50/p99) by nearest
//! rank — exactly what the trace layer's per-phase summaries and the
//! serving-layer latency gates need.
//!
//! The older timing helpers ([`super::Stopwatch`] /
//! [`super::ScopedTimer`]) are kept as thin wrappers: both funnel into
//! [`Registry::observe_duration`] when bound to a registry, so there is
//! one way a duration becomes a recorded metric.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::Json;

/// A distribution of `u64` samples (durations in ns, sizes in bytes).
/// Samples are kept raw; quantiles are answered by nearest rank over a
/// lazily-sorted copy — exact, not bucketed, which the test pins rely
/// on.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Histogram {
    samples: Vec<u64>,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&mut self, sample: u64) {
        self.samples.push(sample);
    }

    pub fn count(&self) -> u64 {
        self.samples.len() as u64
    }

    pub fn sum(&self) -> u64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum() as f64 / self.samples.len() as f64
        }
    }

    pub fn min(&self) -> u64 {
        self.samples.iter().copied().min().unwrap_or(0)
    }

    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    /// Nearest-rank quantile: the smallest sample with at least
    /// `q * count` samples at or below it. `q` is clamped to [0, 1];
    /// an empty histogram answers 0.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("count", Json::num(self.count() as f64)),
            ("sum", Json::num(self.sum() as f64)),
            ("mean", Json::num(self.mean())),
            ("min", Json::num(self.min() as f64)),
            ("max", Json::num(self.max() as f64)),
            ("p50", Json::num(self.p50() as f64)),
            ("p99", Json::num(self.p99() as f64)),
        ])
    }
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

/// Named counters, gauges and histograms behind one lock. Cheap to
/// share (`&Registry` everywhere); recording is a short critical
/// section, reading snapshots.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n` to the named monotone counter.
    pub fn counter_add(&self, name: &str, n: u64) {
        let mut inner = self.inner.lock().expect("registry lock");
        *inner.counters.entry(name.to_string()).or_insert(0) += n;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().expect("registry lock").counters.get(name).copied().unwrap_or(0)
    }

    /// Set the named gauge to its latest value.
    pub fn gauge_set(&self, name: &str, value: f64) {
        let mut inner = self.inner.lock().expect("registry lock");
        inner.gauges.insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().expect("registry lock").gauges.get(name).copied()
    }

    /// Record one sample into the named histogram.
    pub fn observe(&self, name: &str, sample: u64) {
        let mut inner = self.inner.lock().expect("registry lock");
        inner.histograms.entry(name.to_string()).or_default().observe(sample);
    }

    /// The canonical duration-recording path: everything that times
    /// something ([`Registry::time`], [`super::Stopwatch::record_into`],
    /// [`super::ScopedTimer::into_registry`]) lands here.
    pub fn observe_duration(&self, name: &str, elapsed: Duration) {
        self.observe(name, elapsed.as_nanos() as u64);
    }

    /// Snapshot of the named histogram, if any samples were recorded.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.inner.lock().expect("registry lock").histograms.get(name).cloned()
    }

    /// Time one closure into the named histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.observe_duration(name, t.elapsed());
        out
    }

    /// RAII duration recorder: observes into `name` when dropped.
    pub fn scoped(&self, name: &str) -> RegistryTimer<'_> {
        RegistryTimer { registry: self, name: name.to_string(), start: Instant::now() }
    }

    /// Every metric as one JSON object (counters, gauges, histogram
    /// summaries) — the machine-readable report shape.
    pub fn to_json(&self) -> Json {
        let inner = self.inner.lock().expect("registry lock");
        let counters =
            inner.counters.iter().map(|(k, v)| (k.clone(), Json::num(*v as f64))).collect();
        let gauges = inner.gauges.iter().map(|(k, v)| (k.clone(), Json::num(*v))).collect();
        let histograms =
            inner.histograms.iter().map(|(k, h)| (k.clone(), h.to_json())).collect();
        Json::obj([
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(histograms)),
        ])
    }
}

/// RAII handle from [`Registry::scoped`].
pub struct RegistryTimer<'r> {
    registry: &'r Registry,
    name: String,
    start: Instant,
}

impl Drop for RegistryTimer<'_> {
    fn drop(&mut self) {
        self.registry.observe_duration(&self.name, self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_nearest_rank() {
        let mut h = Histogram::new();
        for v in [5u64, 1, 9, 3, 7] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 25);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 9);
        assert_eq!(h.p50(), 5, "median of 1,3,5,7,9");
        assert_eq!(h.p99(), 9);
        assert_eq!(h.quantile(0.0), 1, "q=0 clamps to the first sample");
        assert_eq!(h.quantile(1.0), 9);
        assert_eq!(Histogram::new().p50(), 0, "empty histogram answers 0");
    }

    #[test]
    fn counters_gauges_histograms_accumulate() {
        let r = Registry::new();
        r.counter_add("frames", 2);
        r.counter_add("frames", 3);
        assert_eq!(r.counter("frames"), 5);
        assert_eq!(r.counter("missing"), 0);
        r.gauge_set("ranks", 8.0);
        r.gauge_set("ranks", 16.0);
        assert_eq!(r.gauge("ranks"), Some(16.0));
        r.observe("bytes", 10);
        r.observe("bytes", 30);
        let h = r.histogram("bytes").unwrap();
        assert_eq!((h.count(), h.sum()), (2, 40));
        assert!(r.histogram("missing").is_none());
    }

    #[test]
    fn time_and_scoped_record_durations() {
        let r = Registry::new();
        let out = r.time("work", || {
            std::thread::sleep(Duration::from_millis(1));
            42
        });
        assert_eq!(out, 42);
        {
            let _t = r.scoped("work");
            std::thread::sleep(Duration::from_millis(1));
        }
        let h = r.histogram("work").unwrap();
        assert_eq!(h.count(), 2);
        assert!(h.min() >= 1_000_000, "both samples at least 1ms");
    }

    #[test]
    fn registry_json_reports_all_families() {
        let r = Registry::new();
        r.counter_add("n", 1);
        r.gauge_set("g", 2.5);
        r.observe("h", 7);
        let j = r.to_json();
        assert_eq!(j.get("counters").and_then(|c| c.get("n")).and_then(Json::as_u64), Some(1));
        assert_eq!(j.get("gauges").and_then(|c| c.get("g")).and_then(Json::as_f64), Some(2.5));
        let h = j.get("histograms").and_then(|c| c.get("h")).unwrap();
        assert_eq!(h.get("p50").and_then(Json::as_u64), Some(7));
    }
}
