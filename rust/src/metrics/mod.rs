//! Metrics: the counter/gauge/histogram registry, timers, memory
//! accounting (Fig 13) and bench report tables.

mod memory;
mod registry;
mod report;
mod timer;

pub use memory::{rss_bytes, MemoryGauge, MemoryScope, PeakTracker};
pub use registry::{Histogram, Registry, RegistryTimer};
pub use report::{Report, Series};
pub use timer::{ScopedTimer, Stopwatch};
