//! Memory accounting for Fig 13 (peak memory, Blaze vs Spark).
//!
//! Two complementary sources:
//!  * [`PeakTracker`] — *modeled* bytes: every buffer the framework
//!    allocates on the data path (shuffle buffers, container shards,
//!    grouped values) is charged/released explicitly, giving a
//!    deterministic high-water mark per framework that is comparable
//!    across Blaze and the Spark-sim baseline (which additionally charges
//!    JVM object overhead — see `baseline/jvm.rs`).
//!  * [`rss_bytes`] — the process's real VmHWM from /proc, reported for
//!    context in EXPERIMENTS.md but not used for the figure (both
//!    frameworks share one process here).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Atomic current/peak byte counters. Cloneable handle.
#[derive(Debug, Default)]
pub struct PeakTracker {
    current: AtomicU64,
    peak: AtomicU64,
}

impl PeakTracker {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Charge `bytes` and update the high-water mark.
    pub fn alloc(&self, bytes: u64) {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Release `bytes` (saturating — double-free tolerant for robustness).
    pub fn free(&self, bytes: u64) {
        let _ = self
            .current
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |c| Some(c.saturating_sub(bytes)));
    }

    pub fn current_bytes(&self) -> u64 {
        self.current.load(Ordering::Relaxed)
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.current.store(0, Ordering::Relaxed);
        self.peak.store(0, Ordering::Relaxed);
    }
}

/// RAII charge: frees on drop.
pub struct MemoryScope {
    tracker: Arc<PeakTracker>,
    bytes: u64,
}

impl MemoryScope {
    pub fn charge(tracker: &Arc<PeakTracker>, bytes: u64) -> Self {
        tracker.alloc(bytes);
        Self { tracker: tracker.clone(), bytes }
    }

    /// Adjust the charge (e.g. a buffer grew).
    pub fn grow(&mut self, extra: u64) {
        self.tracker.alloc(extra);
        self.bytes += extra;
    }
}

impl Drop for MemoryScope {
    fn drop(&mut self) {
        self.tracker.free(self.bytes);
    }
}

/// Convenience gauge pairing a tracker with a label, used in reports.
#[derive(Debug, Clone)]
pub struct MemoryGauge {
    pub label: String,
    pub tracker: Arc<PeakTracker>,
}

impl MemoryGauge {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), tracker: PeakTracker::new() }
    }
}

/// Real process peak RSS (VmHWM) in bytes, from /proc/self/status.
pub fn rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_survives_free() {
        let t = PeakTracker::new();
        t.alloc(100);
        t.alloc(50);
        t.free(120);
        assert_eq!(t.current_bytes(), 30);
        assert_eq!(t.peak_bytes(), 150);
    }

    #[test]
    fn scope_frees_on_drop() {
        let t = PeakTracker::new();
        {
            let mut s = MemoryScope::charge(&t, 64);
            s.grow(36);
            assert_eq!(t.current_bytes(), 100);
        }
        assert_eq!(t.current_bytes(), 0);
        assert_eq!(t.peak_bytes(), 100);
    }

    #[test]
    fn free_is_saturating() {
        let t = PeakTracker::new();
        t.free(10);
        assert_eq!(t.current_bytes(), 0);
    }

    #[test]
    fn concurrent_peaks_monotone() {
        let t = PeakTracker::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let t = t.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        t.alloc(10);
                        t.free(10);
                    }
                });
            }
        });
        assert_eq!(t.current_bytes(), 0);
        assert!(t.peak_bytes() >= 10);
    }

    #[test]
    fn rss_readable_on_linux() {
        assert!(rss_bytes().unwrap_or(0) > 0);
    }
}
