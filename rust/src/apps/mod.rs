//! The paper's workloads, built on the public framework API.
//!
//! Each app exposes `run(...)` returning a [`crate::core::JobResult`] plus
//! app-specific synthetic data generators (deterministic, seeded) so the
//! benches and figures are reproducible end to end.

pub mod analytics;
pub mod components;
pub mod kmeans;
pub mod linreg;
pub mod matmul;
pub mod pagerank;
pub mod pi;
pub mod wordcount;
