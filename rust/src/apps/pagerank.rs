//! PageRank — the §VI future-work extension ("a lot more algorithms can
//! be implemented"), and the workload class the MR-MPI lineage (§II [4])
//! was built for: iterative MapReduce over a graph.
//!
//! Each iteration is one delayed-reduction job:
//!   map:    vertex u with rank r and out-edges E -> (v, r/|E|) for v in E,
//!           plus (u, 0.0) so sinks keep existing;
//!   reduce: (v, Iterable<contrib>) -> damping-combined new rank.
//!
//! The iterable reducer is the point: PageRank's reduce is a sum *plus*
//! the damping affine step per key, which is exactly the shape the paper
//! says eager reduction could not express cleanly (the combine is not the
//! whole reduction).
//!
//! Two distributed paths:
//!  * [`run`] — one engine job per iteration (the Hadoop shape): scores
//!    and keep-alive pairs re-shuffle every wave;
//!  * [`run_dist`] — the in-memory iterative engine ([`IterativeJob`]):
//!    adjacency + score pinned rank-local, delta-only waves, mid-run
//!    `ElasticCluster` grow/shrink with live shard migration. The e12
//!    `iterative-ablation` figure compares the two per iteration.


use anyhow::Result;

use crate::cluster::{ClusterConfig, ElasticCluster};
use crate::core::{
    apply_resizes, IterationStats, IterativeJob, JobStats, MapReduceJob, MigrationStats,
    RecoveryStats, ReductionMode, WaveKilled,
};
use crate::mpi::RankPool;
use crate::store::{CheckpointStats, CheckpointStore};
use crate::util::rng::Rng;

/// One vertex state on the iterative path: `(out-edges, unnormalized score)`.
type PrState = (Vec<u32>, f64);

/// Adjacency-list graph with contiguous u32 vertex ids.
#[derive(Debug, Clone)]
pub struct Graph {
    pub vertices: usize,
    pub edges: Vec<Vec<u32>>, // edges[u] = out-neighbours of u
}

impl Graph {
    /// Deterministic scale-free-ish random graph (preferential-attachment
    /// flavoured: later vertices link to `out_degree` earlier ones, biased
    /// to low ids).
    pub fn random(vertices: usize, out_degree: usize, seed: u64) -> Self {
        assert!(vertices >= 2);
        let mut rng = Rng::with_stream(seed, 0x9A6E);
        let mut edges = vec![Vec::new(); vertices];
        for u in 1..vertices {
            for _ in 0..out_degree {
                // Bias toward low ids: square the unit draw.
                let f = rng.f64();
                let v = ((f * f) * u as f64) as u32;
                if !edges[u].contains(&v) {
                    edges[u].push(v);
                }
            }
        }
        // Vertex 0 links to 1 so it isn't a pure sink.
        edges[0].push(1);
        Self { vertices, edges }
    }

    pub fn edge_count(&self) -> usize {
        self.edges.iter().map(Vec::len).sum()
    }
}

/// Result of a PageRank run.
#[derive(Debug, Clone)]
pub struct PageRankResult {
    pub ranks: Vec<f64>,
    pub iterations: usize,
    /// L1 movement of the last iteration (convergence signal).
    pub last_delta: f64,
    /// Stats of the last iteration's job.
    pub stats: JobStats,
    /// Wire bytes per iteration (one engine job each) — what the e12
    /// `iterative-ablation` figure compares against the DistHashMap path.
    pub per_iteration_shuffle_bytes: Vec<u64>,
    /// Modeled clock per iteration.
    pub per_iteration_modeled_ms: Vec<f64>,
}

/// Run `iterations` of PageRank with damping `d` (0.85 classic) under the
/// given reduction mode (Delayed is the natural fit; Classic agrees;
/// Eager cannot express the affine reduce and is rejected).
pub fn run(
    cluster: &ClusterConfig,
    graph: &Graph,
    iterations: usize,
    damping: f64,
    mode: ReductionMode,
) -> Result<PageRankResult> {
    // One warm pool for the whole run: every iteration's MapReduce job is
    // a wave on the same persistent rank threads (the iterative shape the
    // pooled executor exists for — previously each wave respawned them).
    let pool = RankPool::from_config(cluster);
    run_inner(cluster, graph, iterations, damping, mode, &pool, None)
}

/// PageRank on an explicit rank subset of a warm pool — what the
/// concurrent [`crate::core::Scheduler`] and the `serve-bench` harness
/// dispatch. Every iteration's job runs on the same `ranks` subset
/// (renumbered internally), so the scores are bit-identical to [`run`]
/// on a fresh cluster of the same width.
pub fn run_placed(
    cluster: &ClusterConfig,
    pool: &RankPool,
    ranks: &[usize],
    graph: &Graph,
    iterations: usize,
    damping: f64,
    mode: ReductionMode,
) -> Result<PageRankResult> {
    run_inner(cluster, graph, iterations, damping, mode, pool, Some(ranks))
}

fn run_inner(
    cluster: &ClusterConfig,
    graph: &Graph,
    iterations: usize,
    damping: f64,
    mode: ReductionMode,
    pool: &RankPool,
    placement: Option<&[usize]>,
) -> Result<PageRankResult> {
    anyhow::ensure!(
        mode != ReductionMode::Eager,
        "PageRank's reduce is affine (sum then damp), not a pure monoid \
         combine — eager reduction cannot express it (the paper's §III.D \
         rigidity); use Delayed or Classic"
    );
    let n = graph.vertices;
    let mut ranks: Vec<f64> = vec![1.0 / n as f64; n];
    let vertex_ids: Vec<u32> = (0..n as u32).collect();
    let base = (1.0 - damping) / n as f64;

    let mut last_stats = JobStats::default();
    let mut last_delta = f64::INFINITY;
    let mut per_iteration_shuffle_bytes = Vec::with_capacity(iterations);
    let mut per_iteration_modeled_ms = Vec::with_capacity(iterations);
    for _ in 0..iterations {
        let ranks_in = ranks.clone();
        let job = MapReduceJob::new(cluster, &vertex_ids).with_mode(mode);
        let job = match placement {
            Some(subset) => job.with_placement(pool, subset),
            None => job.with_pool(pool),
        };
        let map = |&u: &u32, emit: &mut dyn FnMut(u32, f64)| {
            let u = u as usize;
            let out = &graph.edges[u];
            // Keep every vertex alive in the key space.
            emit(u as u32, 0.0);
            if !out.is_empty() {
                let share = ranks_in[u] / out.len() as f64;
                for &v in out {
                    emit(v, share);
                }
            }
        };
        let reduce = move |_v: &u32, contribs: &mut dyn Iterator<Item = f64>| {
            base + damping * contribs.sum::<f64>()
        };
        let out = match mode {
            ReductionMode::Delayed => job.run_delayed(map, reduce)?,
            ReductionMode::Classic => job.run_classic(map, reduce)?,
            ReductionMode::Eager => unreachable!("rejected above"),
        };
        let mut next = vec![base; n];
        for (v, r) in out.result {
            next[v as usize] = r;
        }
        // Sinks leak mass; renormalize (standard dangling-node handling).
        let total: f64 = next.iter().sum();
        for r in &mut next {
            *r /= total;
        }
        last_delta = ranks.iter().zip(&next).map(|(a, b)| (a - b).abs()).sum();
        ranks = next;
        per_iteration_shuffle_bytes.push(out.stats.shuffle_bytes);
        per_iteration_modeled_ms.push(out.stats.modeled_ms);
        last_stats = out.stats;
    }
    Ok(PageRankResult {
        ranks,
        iterations,
        last_delta,
        stats: last_stats,
        per_iteration_shuffle_bytes,
        per_iteration_modeled_ms,
    })
}

/// Result of a [`run_dist`] PageRank session.
#[derive(Debug, Clone)]
pub struct DistPageRankResult {
    pub ranks: Vec<f64>,
    pub iterations: usize,
    /// Session totals; per-iteration delta-shuffle bytes sum into
    /// `shuffle_bytes`, resize migrations into `migrated_bytes`.
    pub stats: JobStats,
    pub per_iteration: Vec<IterationStats>,
    pub migrations: Vec<MigrationStats>,
    /// Shard snapshots written at the configured cadence (empty when
    /// checkpointing is off).
    pub checkpoints: Vec<CheckpointStats>,
    /// Checkpoint restores performed after injected kills (empty for a
    /// fault-free run).
    pub recoveries: Vec<RecoveryStats>,
    /// Spans the session's waves recorded (empty unless [`crate::trace`]
    /// tracing was enabled around the run) — merge with the driver's own
    /// buffer via [`crate::trace::JobTrace::merge`].
    pub trace: Vec<crate::trace::SpanEvent>,
}

/// PageRank on the in-memory iterative engine ([`IterativeJob`]): every
/// vertex's adjacency list and score are pinned rank-local for the whole
/// run, keyed by the delta-shuffle's own `BucketRouter`, so an iteration
/// exchanges only `(target, contribution)` deltas — pre-folded per
/// `(rank, target)` before the wire — instead of re-shuffling scores and
/// keep-alive pairs through the engine (the M3R ownership win).
///
/// Scores are held *unnormalized*; the dangling-mass normalizer the
/// reference divides by each iteration rides the step's `measure`
/// allreduce, so normalization costs no extra wave.
///
/// `resizes` is a mid-run elasticity plan: at the start of iteration
/// `at`, apply `delta` nodes (`> 0` grows, `< 0` shrinks) to `elastic` —
/// the next wave migrates the affected shards and resumes at the new
/// width. Results match [`reference`] within ulp-accumulation (the 1e-9
/// acceptance bound with wide margin), resized or not.
pub fn run_dist(
    elastic: &mut ElasticCluster,
    graph: &Graph,
    iterations: usize,
    damping: f64,
    resizes: &[(usize, i64)],
) -> Result<DistPageRankResult> {
    let n = graph.vertices;
    anyhow::ensure!(n > 0, "empty graph");
    let wall = std::time::Instant::now();
    let base = (1.0 - damping) / n as f64;
    let mut job = load_job(elastic, graph);

    // Sum of the unnormalized scores; exactly 1.0 going in because the
    // first reference iteration also divides by nothing.
    let mut total = 1.0f64;
    for it in 0..iterations {
        apply_resizes(elastic, resizes, it)?;
        total = step_once(&mut job, elastic, base, damping, total)?;
    }
    Ok(finish(job, elastic, n, iterations, total, wall, Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new()))
}

/// PageRank that survives the cluster's [`crate::cluster::FaultPlan`]:
/// shards checkpoint every `checkpoint_every` waves (each snapshot also
/// carries the wave's normalizer aggregate, so the restored loop resumes
/// with the exact `total` the uninterrupted loop had), and when a
/// scheduled kill lands the driver replaces the dead node
/// (`replace_delta` adjusts the node count — 0 replaces in kind) and
/// re-enters the wave loop from the last checkpoint. Same-width recovery
/// is bit-identical to an uninterrupted run; cross-width recovery
/// re-associates float sums (≤ ulp accumulation, the 1e-12 test bound).
pub fn run_dist_faulty(
    elastic: &mut ElasticCluster,
    graph: &Graph,
    iterations: usize,
    damping: f64,
    checkpoint_every: usize,
    replace_delta: i64,
) -> Result<DistPageRankResult> {
    let n = graph.vertices;
    anyhow::ensure!(n > 0, "empty graph");
    let wall = std::time::Instant::now();
    let base = (1.0 - damping) / n as f64;
    let store: CheckpointStore<u32, PrState> = CheckpointStore::new();
    let mut job = load_job(elastic, graph);
    job.checkpoint_every(store.clone(), checkpoint_every);

    let mut history: Vec<IterationStats> = Vec::new();
    let mut migrations: Vec<MigrationStats> = Vec::new();
    let mut checkpoints: Vec<CheckpointStats> = Vec::new();
    let mut recoveries: Vec<RecoveryStats> = Vec::new();
    let mut banked_trace: Vec<crate::trace::SpanEvent> = Vec::new();
    let mut total = 1.0f64;
    let mut it = 0;
    while it < iterations {
        match step_once(&mut job, elastic, base, damping, total) {
            Ok(new_total) => {
                total = new_total;
                it = job.steps_run();
            }
            Err(e) if e.downcast_ref::<WaveKilled>().is_some() => {
                // Bank the dying job's records, replace the node, and
                // resume from the last snapshot.
                history.extend(job.per_iteration().iter().cloned());
                migrations.extend(job.migrations().iter().cloned());
                checkpoints.extend(job.checkpoints().iter().cloned());
                banked_trace.extend(job.take_trace());
                elastic.kill_and_replace(replace_delta)?;
                job = match IterativeJob::recover_from(elastic, &store)? {
                    Some(recovered) => {
                        total = store
                            .latest_aggregate::<f64>()?
                            .expect("checkpoint carries the normalizer");
                        recovered
                    }
                    // Killed before the first checkpoint: start over.
                    None => {
                        total = 1.0;
                        load_job(elastic, graph)
                    }
                };
                job.checkpoint_every(store.clone(), checkpoint_every);
                recoveries.extend(job.recovery().cloned());
                it = job.steps_run();
            }
            Err(e) => return Err(e),
        }
    }
    Ok(finish(job, elastic, n, iterations, total, wall, history, migrations, checkpoints, recoveries, banked_trace))
}

fn load_job(elastic: &ElasticCluster, graph: &Graph) -> IterativeJob<u32, PrState> {
    let n = graph.vertices;
    IterativeJob::load(
        elastic,
        0x5047_524B, // "PGRK"
        (0..n as u32).map(|u| (u, (graph.edges[u as usize].clone(), 1.0 / n as f64))),
    )
}

/// One PageRank wave; returns the new global score sum (the normalizer),
/// folded by the step's f64 measure monoid on the allreduce.
fn step_once(
    job: &mut IterativeJob<u32, PrState>,
    elastic: &mut ElasticCluster,
    base: f64,
    damping: f64,
    total: f64,
) -> Result<f64> {
    let t = total;
    let out = job.step(
        elastic,
        move |_u: &u32, state: &PrState, emit: &mut dyn FnMut(u32, f64)| {
            let (out, score) = state;
            if !out.is_empty() {
                let share = (*score / t) / out.len() as f64;
                for &v in out {
                    emit(v, share);
                }
            }
        },
        |acc: &mut f64, v: f64| *acc += v,
        move |_u: &u32, state: &mut PrState, delta: Option<f64>| {
            state.1 = base + damping * delta.unwrap_or(0.0);
        },
        |_u: &u32, state: &PrState| state.1,
    )?;
    Ok(out.aggregate)
}

#[allow(clippy::too_many_arguments)]
fn finish(
    mut job: IterativeJob<u32, PrState>,
    elastic: &ElasticCluster,
    n: usize,
    iterations: usize,
    total: f64,
    wall: std::time::Instant,
    mut history: Vec<IterationStats>,
    mut migrations: Vec<MigrationStats>,
    mut checkpoints: Vec<CheckpointStats>,
    recoveries: Vec<RecoveryStats>,
    mut banked_trace: Vec<crate::trace::SpanEvent>,
) -> DistPageRankResult {
    let mut ranks = vec![0.0f64; n];
    job.for_each_state(|&u, state| ranks[u as usize] = state.1 / total);
    let mut stats = job.job_stats();
    // Waves, migrations, checkpoints and recoveries performed by jobs
    // that died mid-session still cost modeled time; fold the banked
    // records back in (the surviving job's own are already counted).
    stats.modeled_ms += history.iter().map(|s| s.modeled_ms).sum::<f64>()
        + migrations.iter().map(|m| m.modeled_ms).sum::<f64>()
        + checkpoints.iter().map(|c| c.modeled_ms).sum::<f64>()
        + recoveries.iter().map(|r| r.modeled_ms).sum::<f64>()
        - job.recovery().map_or(0.0, |r| r.modeled_ms);
    stats.compute_ms += history.iter().map(|s| s.compute_ms).sum::<f64>();
    stats.net_ms += history.iter().map(|s| s.net_ms).sum::<f64>();
    stats.shuffle_bytes += history.iter().map(|s| s.shuffled_bytes).sum::<u64>();
    stats.messages += history.iter().map(|s| s.messages).sum::<u64>()
        + migrations.iter().map(|m| m.messages).sum::<u64>();
    stats.remote_messages += history.iter().map(|s| s.remote_messages).sum::<u64>();
    stats.remote_bytes += history.iter().map(|s| s.remote_bytes).sum::<u64>();
    stats.migrated_bytes += migrations.iter().map(|m| m.moved_bytes).sum::<u64>();
    history.extend(job.per_iteration().iter().cloned());
    migrations.extend(job.migrations().iter().cloned());
    checkpoints.extend(job.checkpoints().iter().cloned());
    banked_trace.extend(job.take_trace());
    stats.startup_ms = elastic.config().deployment.profile().startup_ms as f64;
    stats.host_wall_ms = wall.elapsed().as_secs_f64() * 1e3;
    DistPageRankResult {
        ranks,
        iterations,
        stats,
        per_iteration: history,
        migrations,
        checkpoints,
        recoveries,
        trace: banked_trace,
    }
}

/// Serial reference for tests.
pub fn reference(graph: &Graph, iterations: usize, damping: f64) -> Vec<f64> {
    let n = graph.vertices;
    let base = (1.0 - damping) / n as f64;
    let mut ranks = vec![1.0 / n as f64; n];
    for _ in 0..iterations {
        let mut next = vec![base; n];
        for u in 0..n {
            let out = &graph.edges[u];
            if out.is_empty() {
                continue;
            }
            let share = ranks[u] / out.len() as f64;
            for &v in out {
                next[v as usize] += damping * share;
            }
        }
        let total: f64 = next.iter().sum();
        for r in &mut next {
            *r /= total;
        }
        ranks = next;
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph() -> Graph {
        Graph::random(200, 4, 3)
    }

    #[test]
    fn graph_generator_deterministic() {
        let a = graph();
        let b = graph();
        assert_eq!(a.edges, b.edges);
        assert!(a.edge_count() > 200);
    }

    #[test]
    fn matches_serial_reference() {
        let g = graph();
        let cluster = ClusterConfig::builder().ranks(4).build();
        let got = run(&cluster, &g, 10, 0.85, ReductionMode::Delayed).unwrap();
        let want = reference(&g, 10, 0.85);
        for (a, b) in got.ranks.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn classic_and_delayed_agree() {
        let g = graph();
        let cluster = ClusterConfig::builder().ranks(3).build();
        let d = run(&cluster, &g, 5, 0.85, ReductionMode::Delayed).unwrap();
        let c = run(&cluster, &g, 5, 0.85, ReductionMode::Classic).unwrap();
        for (a, b) in d.ranks.iter().zip(&c.ranks) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn placed_subset_matches_plain_run() {
        // Same width, renumbered subset of a warm pool: bit-identical
        // scores (no re-association — the comm plane is equivalent).
        let g = graph();
        let pool_cluster = ClusterConfig::builder().nodes(1).slots_per_node(4).build();
        let job_cluster = ClusterConfig::builder().nodes(1).slots_per_node(2).build();
        let pool = RankPool::from_config(&pool_cluster);
        let plain = run(&job_cluster, &g, 5, 0.85, ReductionMode::Delayed).unwrap();
        let placed =
            run_placed(&job_cluster, &pool, &[1, 3], &g, 5, 0.85, ReductionMode::Delayed).unwrap();
        assert_eq!(plain.ranks, placed.ranks);
        assert_eq!(plain.per_iteration_shuffle_bytes, placed.per_iteration_shuffle_bytes);
        assert_eq!(pool.jobs_run(), 5);
    }

    #[test]
    fn eager_mode_rejected_with_explanation() {
        let g = graph();
        let cluster = ClusterConfig::builder().ranks(2).build();
        let err = run(&cluster, &g, 1, 0.85, ReductionMode::Eager).unwrap_err();
        assert!(format!("{err:#}").contains("eager reduction cannot express"));
    }

    #[test]
    fn dist_path_matches_serial_reference() {
        let g = graph();
        let mut elastic = ElasticCluster::new(ClusterConfig::builder().ranks(4).build());
        let got = run_dist(&mut elastic, &g, 10, 0.85, &[]).unwrap();
        let want = reference(&g, 10, 0.85);
        for (a, b) in got.ranks.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        let total: f64 = got.ranks.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "normalized distribution, got {total}");
        assert_eq!(got.per_iteration.len(), 10);
        assert!(got.migrations.is_empty());
        assert_eq!(got.stats.migrated_bytes, 0);
        assert!(got.per_iteration.iter().all(|it| it.orphan_deltas == 0));
    }

    #[test]
    fn dist_path_exchanges_fewer_bytes_per_iteration_than_engine_path() {
        let g = graph();
        let cluster = ClusterConfig::builder().ranks(4).build();
        let engine = run(&cluster, &g, 6, 0.85, ReductionMode::Delayed).unwrap();
        let mut elastic = ElasticCluster::new(cluster);
        let dist = run_dist(&mut elastic, &g, 6, 0.85, &[]).unwrap();
        let min_engine = engine.per_iteration_shuffle_bytes.iter().min().copied().unwrap();
        for it in &dist.per_iteration {
            assert!(
                it.shuffled_bytes < min_engine,
                "iteration {}: dist {} >= engine {}",
                it.iteration,
                it.shuffled_bytes,
                min_engine
            );
        }
    }

    #[test]
    fn dist_path_survives_mid_run_grow_and_shrink() {
        let g = graph();
        let make = || ElasticCluster::new(ClusterConfig::builder().ranks(3).build());
        let straight = run_dist(&mut make(), &g, 12, 0.85, &[]).unwrap();
        let mut elastic = make();
        let resized = run_dist(&mut elastic, &g, 12, 0.85, &[(4, 2), (8, -3)]).unwrap();
        // Same distribution as the unresized run (ulp-level re-association
        // only) and still within the reference bound.
        for (a, b) in resized.ranks.iter().zip(&straight.ranks) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
        let want = reference(&g, 12, 0.85);
        for (a, b) in resized.ranks.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        assert_eq!(resized.migrations.len(), 2);
        assert_eq!(resized.migrations[0].to_ranks, 5);
        assert_eq!(resized.migrations[1].to_ranks, 2);
        assert!(resized.migrations.iter().all(|m| m.moved_bytes > 0 && m.moved_keys > 0));
        assert_eq!(
            resized.stats.migrated_bytes,
            resized.migrations.iter().map(|m| m.moved_bytes).sum::<u64>()
        );
        assert_eq!(elastic.ranks(), 2);
        // The waves really changed width mid-run.
        assert_eq!(resized.per_iteration[0].ranks, 3);
        assert_eq!(resized.per_iteration[5].ranks, 5);
        assert_eq!(resized.per_iteration[11].ranks, 2);
        assert_eq!(resized.per_iteration[11].epoch, 2);
        assert_eq!(straight.stats.migrated_bytes, 0);
    }

    #[test]
    fn ranks_are_distribution_and_converge() {
        let g = graph();
        let cluster = ClusterConfig::builder().ranks(2).build();
        let r = run(&cluster, &g, 25, 0.85, ReductionMode::Delayed).unwrap();
        let total: f64 = r.ranks.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(r.last_delta < 1e-3, "delta {}", r.last_delta);
        // Low-id vertices attract bias in the generator -> highest rank
        // should be a small id.
        let argmax = r
            .ranks
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(argmax < 20, "argmax {argmax}");
    }
}
