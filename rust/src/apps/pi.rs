//! Monte-Carlo Pi estimation — the paper's §V.C workload (Fig 12).
//!
//! "Random coordinates (x,y) are generated in mappers and if they fall
//! within a certain range the mapper emits (key,1), else emits (key,0).
//! The reducer sums over the key and estimates pi as 4 * inside/total."
//!
//! Embarrassingly parallel: per-rank compute dominates, network traffic is
//! one scalar per rank — which is why Fig 12 shows near-linear scaling.
//! The input is a list of chunk descriptors (seeds), so the same job runs
//! through the framework ([`run`], emitting per-sample pairs under any
//! mode — faithful but slow) or the fast paths ([`run_eager_batched`],
//! [`run_kernel`]) that fold counting into the mapper / the Pallas kernel.

use anyhow::{Context, Result};

use crate::cluster::ClusterConfig;
use crate::core::{JobConfig, JobResult, MapReduceJob, ReductionMode};
use crate::mpi::{run_ranks_with_universe, Universe};
use crate::runtime::{ComputeHandle, TensorArg};
use crate::util::rng::Rng;

/// AOT tile size of the `pi_count` kernel.
pub const KERNEL_TILE: usize = 8192;

/// One mapper work item: a deterministic chunk of samples.
#[derive(Debug, Clone, Copy)]
pub struct Chunk {
    pub seed: u64,
    pub samples: usize,
}

/// Split `total` samples into `chunks` deterministic work items.
pub fn make_chunks(total: usize, chunks: usize, seed: u64) -> Vec<Chunk> {
    let chunks = chunks.max(1);
    let base = total / chunks;
    let extra = total % chunks;
    (0..chunks)
        .map(|i| Chunk {
            seed: seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            samples: base + usize::from(i < extra),
        })
        .collect()
}

/// Estimate from (inside, total).
pub fn estimate(inside: u64, total: u64) -> f64 {
    4.0 * inside as f64 / total as f64
}

/// Faithful per-sample framework path: mapper emits (0, 1) or (0, 0) per
/// sample, reducer sums — exactly the paper's description. O(samples)
/// shuffle pairs under Classic; use for mode comparisons, not for scale.
pub fn run(
    cluster: &ClusterConfig,
    chunks: &[Chunk],
    mode: ReductionMode,
) -> Result<JobResult<f64>> {
    let total: u64 = chunks.iter().map(|c| c.samples as u64).sum();
    let job = MapReduceJob::new(cluster, chunks).with_config(JobConfig::with_mode(mode));
    let out = job.run_monoid(
        |chunk: &Chunk, emit: &mut dyn FnMut(u8, u64)| {
            let mut rng = Rng::with_stream(chunk.seed, 0x3141);
            for _ in 0..chunk.samples {
                let x = rng.f64();
                let y = rng.f64();
                emit(0u8, u64::from(x * x + y * y <= 1.0));
            }
        },
        |a: u64, b: u64| a + b,
    )?;
    Ok(out.map(|m| estimate(m.get(&0).copied().unwrap_or(0), total)))
}

/// Eager-batched path: the mapper counts its whole chunk and emits one
/// pair — the shape the paper actually benchmarks (efficient "in terms of
/// memory, speed and scalability").
pub fn run_eager_batched(cluster: &ClusterConfig, chunks: &[Chunk]) -> Result<JobResult<f64>> {
    let total: u64 = chunks.iter().map(|c| c.samples as u64).sum();
    let out = MapReduceJob::new(cluster, chunks).run_eager(
        |chunk: &Chunk, emit: &mut dyn FnMut(u8, u64)| {
            let mut rng = Rng::with_stream(chunk.seed, 0x3141);
            let mut inside = 0u64;
            for _ in 0..chunk.samples {
                let x = rng.f64();
                let y = rng.f64();
                inside += u64::from(x * x + y * y <= 1.0);
            }
            emit(0u8, inside);
        },
        |acc, v| *acc += v,
    )?;
    Ok(out.map(|m| estimate(m.get(&0).copied().unwrap_or(0), total)))
}

/// Kernel path: ranks generate coordinate tiles and the `pi_count` Pallas
/// executable counts in-circle points; one allreduce finishes the job.
pub fn run_kernel(
    cluster: &ClusterConfig,
    chunks: &[Chunk],
    compute: &ComputeHandle,
) -> Result<JobResult<f64>> {
    compute.warmup("pi_count")?;
    let total: u64 = chunks.iter().map(|c| c.samples as u64).sum();
    let universe = Universe::from_cluster(cluster);
    let stats = universe.stats();
    let wall = std::time::Instant::now();

    let ranks = cluster.ranks();
    let per_rank = chunks.len().div_ceil(ranks.max(1)).max(1);

    let (rank_results, clocks) = run_ranks_with_universe(universe, |comm| -> Result<u64> {
        let me = comm.rank().0;
        let mine = chunks.chunks(per_rank).nth(me).unwrap_or(&[]);
        let mut inside = 0u64;
        for chunk in mine {
            let mut rng = Rng::with_stream(chunk.seed, 0x3141);
            let mut remaining = chunk.samples;
            while remaining > 0 {
                let real = remaining.min(KERNEL_TILE);
                // Pad with (2,2): outside the circle, counts zero.
                let mut xy = comm.timed(|| {
                    let mut xy = Vec::with_capacity(KERNEL_TILE * 2);
                    for _ in 0..real {
                        xy.push(rng.f32());
                        xy.push(rng.f32());
                    }
                    xy.resize(KERNEL_TILE * 2, 2.0);
                    xy
                });
                debug_assert_eq!(xy.len(), KERNEL_TILE * 2);
                let (outs, kernel_ns) = compute.run_timed(
                    "pi_count",
                    vec![TensorArg::f32(std::mem::take(&mut xy), &[KERNEL_TILE, 2])],
                )?;
                comm.advance_scaled(kernel_ns);
                inside += outs[0].as_f32()?[0] as u64;
                remaining -= real;
            }
        }
        comm.allreduce_sum_u64(inside)
    });

    let mut inside = 0u64;
    for (i, r) in rank_results.into_iter().enumerate() {
        inside = r.with_context(|| format!("rank {i}"))?;
    }

    let profile = cluster.deployment.profile();
    let slowest = clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
    let (msgs, bytes, rmsgs, rbytes) = stats.snapshot();
    Ok(JobResult {
        result: estimate(inside, total),
        stats: crate::core::JobStats {
            modeled_ms: slowest.0 as f64 / 1e6,
            compute_ms: slowest.1 as f64 / 1e6,
            net_ms: slowest.2 as f64 / 1e6,
            startup_ms: profile.startup_ms as f64,
            shuffle_bytes: bytes,
            messages: msgs,
            remote_messages: rmsgs,
            remote_bytes: rbytes,
            peak_mem_bytes: (KERNEL_TILE * 2 * 4 * ranks) as u64,
            spilled_bytes: 0,
            combined_bytes: 0,
            migrated_bytes: 0,
            host_wall_ms: wall.elapsed().as_secs_f64() * 1e3,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_total() {
        let chunks = make_chunks(1003, 7, 1);
        assert_eq!(chunks.iter().map(|c| c.samples).sum::<usize>(), 1003);
        assert_eq!(chunks.len(), 7);
        // Distinct seeds.
        let mut seeds: Vec<u64> = chunks.iter().map(|c| c.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 7);
    }

    #[test]
    fn pi_converges_eager_batched() {
        let cluster = ClusterConfig::builder().ranks(4).build();
        let chunks = make_chunks(200_000, 16, 5);
        let got = run_eager_batched(&cluster, &chunks).unwrap();
        assert!((got.result - std::f64::consts::PI).abs() < 0.02, "pi = {}", got.result);
    }

    #[test]
    fn faithful_and_batched_agree_exactly() {
        // Same seeds -> same coordinate stream -> identical counts.
        let cluster = ClusterConfig::builder().ranks(2).build();
        let chunks = make_chunks(20_000, 8, 11);
        let a = run(&cluster, &chunks, ReductionMode::Eager).unwrap();
        let b = run_eager_batched(&cluster, &chunks).unwrap();
        assert_eq!(a.result, b.result);
        // Classic shuffles every (key, 0/1) pair; both eager variants
        // collapse to one value per rank.
        let c = run(&cluster, &chunks, ReductionMode::Classic).unwrap();
        assert_eq!(c.result, b.result);
        assert!(b.stats.shuffle_bytes < c.stats.shuffle_bytes);
    }

    #[test]
    fn all_modes_agree() {
        let cluster = ClusterConfig::builder().ranks(2).build();
        let chunks = make_chunks(5_000, 4, 2);
        let e = run(&cluster, &chunks, ReductionMode::Eager).unwrap().result;
        let c = run(&cluster, &chunks, ReductionMode::Classic).unwrap().result;
        let d = run(&cluster, &chunks, ReductionMode::Delayed).unwrap().result;
        assert_eq!(e, c);
        assert_eq!(c, d);
    }
}
