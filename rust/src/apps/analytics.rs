//! Analytics — a TPC-H-flavoured multi-stage chain, the demo workload
//! for the [`crate::core::dataflow`] DAG layer.
//!
//! Two tables (synthetic, seeded, deterministic):
//!
//!  * `customers(cust_id, segment)` — the dimension table: each
//!    customer belongs to one of [`SEGMENTS`] market segments.
//!  * `orders(cust_id, total_cents)` — the fact table: order totals,
//!    customer popularity Zipf-skewed (the shape real order books
//!    have).
//!
//! Two plans over them:
//!
//!  * [`basket_plan`] — `orders.filter(total ≥ min).join(customers)
//!    .group_by()`: each qualifying customer's purchase list. The
//!    acceptance chain: the filter fuses into the orders scan, the join
//!    repartitions both sides (two shuffles), and the `group_by` over
//!    the join's co-partitioned output is *shuffle-free*.
//!  * [`revenue_plan`] — same scan + join, then
//!    `.map(to (segment, total)).reduce_by_key(+)`: revenue per market
//!    segment, with the re-key map fused onto the join stage.

use anyhow::Result;

use crate::cluster::ClusterConfig;
use crate::core::dataflow::{DataflowOutput, Stage};
use crate::util::rng::Rng;

/// TPC-H's five market segments.
pub const SEGMENTS: [&str; 5] =
    ["automobile", "building", "furniture", "household", "machinery"];

/// Synthetic tables: `customers` rows of `(cust_id, segment)` and
/// `orders` rows of `(cust_id, total_cents)`. Customer popularity is
/// Zipf-ish (hot customers order more); totals are 1..=50000 cents.
pub fn generate_tables(
    customers: usize,
    orders: usize,
    seed: u64,
) -> (Vec<(u32, String)>, Vec<(u32, u64)>) {
    assert!(customers > 0, "need at least one customer");
    let mut rng = Rng::with_stream(seed, 0xA11A);
    let customer_rows: Vec<(u32, String)> = (0..customers as u32)
        .map(|id| (id, SEGMENTS[rng.below(SEGMENTS.len() as u64) as usize].to_string()))
        .collect();
    let weights: Vec<f64> = (1..=customers).map(|r| 1.0 / r as f64).collect();
    let order_rows: Vec<(u32, u64)> = (0..orders)
        .map(|_| {
            let cust = rng.weighted(&weights) as u32;
            let total = 1 + rng.below(50_000);
            (cust, total)
        })
        .collect();
    (customer_rows, order_rows)
}

/// filter → join → group_by: each customer's list of qualifying
/// `(total_cents, segment)` purchases. The filter fuses into the orders
/// scan; the `group_by` runs shuffle-free over the join's
/// co-partitioned output (assert it: `plan.explain()`).
pub fn basket_plan(
    customers: &[(u32, String)],
    orders: &[(u32, u64)],
    min_total_cents: u64,
) -> Stage<u32, Vec<(u64, String)>> {
    Stage::from_vec(orders.to_vec())
        .filter(move |_cust, total| *total >= min_total_cents)
        .join(&Stage::from_vec(customers.to_vec()))
        .group_by()
}

/// filter → join → map → reduce_by_key: revenue (cents) per market
/// segment over qualifying orders. The re-key map fuses onto the join
/// stage's output pass; only the final reduce repartitions.
pub fn revenue_plan(
    customers: &[(u32, String)],
    orders: &[(u32, u64)],
    min_total_cents: u64,
) -> Stage<String, u64> {
    Stage::from_vec(orders.to_vec())
        .filter(move |_cust, total| *total >= min_total_cents)
        .join(&Stage::from_vec(customers.to_vec()))
        .map(|_cust, (total, segment)| (segment, total))
        .reduce_by_key(|a, b| a + b)
}

/// Execute [`basket_plan`] on `cluster`.
pub fn run_baskets(
    cluster: &ClusterConfig,
    customers: &[(u32, String)],
    orders: &[(u32, u64)],
    min_total_cents: u64,
) -> Result<DataflowOutput<u32, Vec<(u64, String)>>> {
    basket_plan(customers, orders, min_total_cents).collect(cluster)
}

/// Execute [`revenue_plan`] on `cluster`.
pub fn run_revenue(
    cluster: &ClusterConfig,
    customers: &[(u32, String)],
    orders: &[(u32, u64)],
    min_total_cents: u64,
) -> Result<DataflowOutput<String, u64>> {
    revenue_plan(customers, orders, min_total_cents).collect(cluster)
}

/// Ground truth for tests and the CLI check: single-threaded
/// per-segment revenue.
pub fn revenue_serial(
    customers: &[(u32, String)],
    orders: &[(u32, u64)],
    min_total_cents: u64,
) -> Vec<(String, u64)> {
    let mut by_cust = std::collections::HashMap::new();
    for (id, seg) in customers {
        by_cust.insert(*id, seg.clone());
    }
    let mut revenue: std::collections::HashMap<String, u64> = std::collections::HashMap::new();
    for (cust, total) in orders {
        if *total >= min_total_cents {
            if let Some(seg) = by_cust.get(cust) {
                *revenue.entry(seg.clone()).or_insert(0) += total;
            }
        }
    }
    let mut rows: Vec<(String, u64)> = revenue.into_iter().collect();
    rows.sort();
    rows
}

/// Ground truth for the basket chain: per-customer qualifying purchase
/// multisets (sorted for comparison).
pub fn baskets_serial(
    customers: &[(u32, String)],
    orders: &[(u32, u64)],
    min_total_cents: u64,
) -> Vec<(u32, Vec<(u64, String)>)> {
    let mut by_cust = std::collections::HashMap::new();
    for (id, seg) in customers {
        by_cust.insert(*id, seg.clone());
    }
    let mut baskets: std::collections::HashMap<u32, Vec<(u64, String)>> =
        std::collections::HashMap::new();
    for (cust, total) in orders {
        if *total >= min_total_cents {
            if let Some(seg) = by_cust.get(cust) {
                baskets.entry(*cust).or_default().push((*total, seg.clone()));
            }
        }
    }
    let mut rows: Vec<(u32, Vec<(u64, String)>)> = baskets.into_iter().collect();
    for (_c, vs) in rows.iter_mut() {
        vs.sort();
    }
    rows.sort();
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let a = generate_tables(20, 100, 9);
        let b = generate_tables(20, 100, 9);
        assert_eq!(a, b);
        assert_eq!(a.0.len(), 20);
        assert_eq!(a.1.len(), 100);
    }

    #[test]
    fn revenue_matches_serial_reference() {
        let (customers, orders) = generate_tables(15, 200, 3);
        let cluster = ClusterConfig::builder().ranks(3).seed(3).build();
        let out = run_revenue(&cluster, &customers, &orders, 10_000).unwrap();
        assert_eq!(out.rows, revenue_serial(&customers, &orders, 10_000));
        assert!(!out.rows.is_empty(), "some segment must earn revenue");
    }

    #[test]
    fn baskets_match_serial_reference_and_group_by_is_shuffle_free() {
        let (customers, orders) = generate_tables(12, 150, 5);
        let plan = basket_plan(&customers, &orders, 5_000);
        let ex = plan.explain();
        // input(orders)+filter, input(customers), join, group_by, collect.
        assert_eq!(ex.stages.len(), 5);
        assert_eq!(ex.stages[0].fused, vec!["filter".to_string()]);
        assert_eq!(ex.stages[2].shuffles, 2, "both join sides repartition");
        assert_eq!(ex.stages[3].op, "group_by");
        assert_eq!(ex.stages[3].shuffles, 0, "join output is co-partitioned");
        assert_eq!(ex.total_shuffles(), 2);

        let cluster = ClusterConfig::builder().ranks(3).seed(5).build();
        let out = plan.collect(&cluster).unwrap();
        let mut rows = out.rows;
        for (_c, vs) in rows.iter_mut() {
            vs.sort();
        }
        assert_eq!(rows, baskets_serial(&customers, &orders, 5_000));
        assert_eq!(out.stages[3].bytes, 0, "shuffle-free group_by moved bytes");
    }

    #[test]
    fn revenue_plan_fuses_the_rekey_map_onto_the_join() {
        let (customers, orders) = generate_tables(10, 50, 7);
        let ex = revenue_plan(&customers, &orders, 0).explain();
        let join = ex.stages.iter().find(|s| s.op.starts_with("join")).unwrap();
        assert_eq!(join.fused, vec!["map".to_string()]);
        // Two join-side repartitions + the post-map reduce repartition.
        assert_eq!(ex.total_shuffles(), 3);
    }
}
