//! K-means clustering — the paper's §V.A workload (Figs 8-9), after the
//! iterative-MapReduce formulation of Zhao/Ma/He [15]:
//!
//! each iteration is one MapReduce job —
//!   map:     point -> (nearest centroid id, point)
//!   combine: per-rank partial (sum, count) per centroid  (eager reduction)
//!   reduce:  allreduce partials, new centroid = sum / count
//!
//! Two compute paths per iteration:
//!  * native — scalar distance loop on the rank thread (the C++ shape);
//!  * kernel — the `kmeans_step_d{2,8,32}` Pallas executable: the rank
//!    tiles its shard into 4096-point blocks, PJRT computes (sums, counts,
//!    assign) per block, padding is subtracted exactly using the returned
//!    assignments.

use anyhow::{bail, Context, Result};

use crate::cluster::ClusterConfig;
use crate::core::JobStats;
use crate::mpi::{Communicator, RankPool, TrafficDelta, Universe};
use crate::runtime::{ComputeHandle, TensorArg};
use crate::util::rng::Rng;

/// AOT tile shape (python/compile/aot.py).
pub const KERNEL_TILE: usize = 4096;
pub const KERNEL_K: usize = 16;
pub const KERNEL_DIMS: [usize; 3] = [2, 8, 32];

/// Flat row-major point set.
#[derive(Debug, Clone)]
pub struct Points {
    pub data: Vec<f32>,
    pub n: usize,
    pub d: usize,
}

impl Points {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }
}

/// Gaussian blobs around `k` true centers in [-5, 5]^d.
pub fn generate_points(n: usize, d: usize, k: usize, seed: u64) -> Points {
    let mut rng = Rng::with_stream(seed, 0x6B6D);
    let centers: Vec<f64> = (0..k * d).map(|_| rng.f64() * 10.0 - 5.0).collect();
    let mut data = Vec::with_capacity(n * d);
    for i in 0..n {
        let c = i % k;
        for j in 0..d {
            data.push((centers[c * d + j] + 0.4 * rng.normal()) as f32);
        }
    }
    Points { data, n, d }
}

/// Result of a K-means run.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    pub centroids: Vec<f32>, // k x d row-major
    pub k: usize,
    pub d: usize,
    /// Sum of squared distances to assigned centroids (last iteration).
    pub inertia: f64,
    pub iterations: usize,
    pub stats: JobStats,
}

/// Which per-iteration compute path ranks use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputePath {
    Native,
    /// Requires d in [`KERNEL_DIMS`] and k == [`KERNEL_K`].
    Kernel,
}

/// Run distributed K-means. Points are sharded by rank; each iteration
/// does local assign+combine then a sums/counts allreduce (the iterative
/// MapReduce of [15] with eager reduction). Spawns a throwaway
/// [`RankPool`] — callers running several configurations should hold one
/// warm pool and use [`run_on_pool`].
pub fn run(
    cluster: &ClusterConfig,
    points: &Points,
    k: usize,
    iterations: usize,
    path: ComputePath,
    compute: Option<&ComputeHandle>,
) -> Result<KmeansResult> {
    run_on_pool(cluster, &RankPool::from_config(cluster), points, k, iterations, path, compute)
}

/// [`run`] on a caller-owned warm [`RankPool`]: the whole run — every
/// wave's assign/combine and allreduce — executes on the pool's
/// persistent rank threads.
pub fn run_on_pool(
    cluster: &ClusterConfig,
    pool: &RankPool,
    points: &Points,
    k: usize,
    iterations: usize,
    path: ComputePath,
    compute: Option<&ComputeHandle>,
) -> Result<KmeansResult> {
    anyhow::ensure!(k > 0 && k <= points.n, "k={k} out of range");
    if path == ComputePath::Kernel {
        if !KERNEL_DIMS.contains(&points.d) || k != KERNEL_K {
            bail!(
                "kernel path needs d in {KERNEL_DIMS:?} and k == {KERNEL_K} (got d={}, k={k})",
                points.d
            );
        }
        let handle = compute.context("kernel path needs a ComputeHandle")?;
        handle.warmup(&format!("kmeans_step_d{}", points.d))?;
    }
    let ranks = cluster.ranks();
    pool.ensure_models(cluster)?;
    let wall = std::time::Instant::now();

    let d = points.d;
    let chunk_pts = points.n.div_ceil(ranks.max(1)).max(1);

    // Initial centroids: first k points (deterministic, standard Forgy-ish).
    let init: Vec<f32> = points.data[..k * d].to_vec();

    let out = pool.run_job(ranks, |comm| -> Result<(Vec<f32>, f64)> {
        let me = comm.rank().0;
        let lo = (me * chunk_pts).min(points.n);
        let hi = ((me + 1) * chunk_pts).min(points.n);
        let shard = &points.data[lo * d..hi * d];
        let shard_n = hi - lo;

        let mut centroids = init.clone();
        let mut inertia = 0.0f64;
        for _iter in 0..iterations {
            let (sums, counts, local_inertia) = match path {
                ComputePath::Native => comm.timed(|| native_step(shard, shard_n, d, k, &centroids)),
                ComputePath::Kernel => {
                    let handle = compute.expect("checked above");
                    kernel_step(comm, handle, shard, shard_n, d, k, &centroids)?
                }
            };
            inertia = reduce_and_update(comm, sums, counts, local_inertia, &mut centroids, d, k)?;
        }
        Ok((centroids, inertia))
    });

    let (final_centroids, inertia) = collapse_rank_results(out.results)?;
    let profile = cluster.deployment.profile();
    let slowest = out.clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
    Ok(KmeansResult {
        centroids: final_centroids,
        k,
        d,
        inertia,
        iterations,
        stats: JobStats {
            modeled_ms: slowest.0 as f64 / 1e6,
            compute_ms: slowest.1 as f64 / 1e6,
            net_ms: slowest.2 as f64 / 1e6,
            startup_ms: profile.startup_ms as f64,
            shuffle_bytes: out.traffic.bytes,
            messages: out.traffic.messages,
            remote_messages: out.traffic.remote_messages,
            remote_bytes: out.traffic.remote_bytes,
            peak_mem_bytes: ((k * d + k) * 4 * ranks + points.data.len() * 4) as u64,
            spilled_bytes: 0,
            combined_bytes: 0,
            migrated_bytes: 0,
            host_wall_ms: wall.elapsed().as_secs_f64() * 1e3,
        },
    })
}

/// The Hadoop-shaped variant: **one engine job per wave** (the paper's
/// motivation scenario — each iteration of an iterative app is a separate
/// MapReduce job). With `pool: None` every wave spawns and joins fresh
/// rank threads, exactly the per-job overhead the pooled executor
/// removes; with `Some(pool)` every wave reuses the warm threads. The
/// two produce bit-identical centroids, which is what lets
/// `benches/micro_hot_paths.rs` and the `pool-ablation` figure compare
/// their wall-clock honestly.
pub fn run_wave_jobs(
    cluster: &ClusterConfig,
    points: &Points,
    k: usize,
    iterations: usize,
    pool: Option<&RankPool>,
) -> Result<KmeansResult> {
    anyhow::ensure!(k > 0 && k <= points.n, "k={k} out of range");
    let ranks = cluster.ranks();
    if let Some(pool) = pool {
        pool.ensure_models(cluster)?;
    }
    let wall = std::time::Instant::now();

    let d = points.d;
    let chunk_pts = points.n.div_ceil(ranks.max(1)).max(1);
    let mut centroids: Vec<f32> = points.data[..k * d].to_vec();
    let mut inertia = 0.0f64;
    let mut modeled = (0u64, 0u64, 0u64);
    let mut traffic = TrafficDelta::default();

    for _wave in 0..iterations {
        let current = centroids.clone();
        let wave = |comm: &Communicator| -> Result<(Vec<f32>, f64)> {
            let me = comm.rank().0;
            let lo = (me * chunk_pts).min(points.n);
            let hi = ((me + 1) * chunk_pts).min(points.n);
            let shard = &points.data[lo * d..hi * d];
            let (sums, counts, local_inertia) =
                comm.timed(|| native_step(shard, hi - lo, d, k, &current));
            let mut next = current.clone();
            let iner = reduce_and_update(comm, sums, counts, local_inertia, &mut next, d, k)?;
            Ok((next, iner))
        };
        let out = match pool {
            Some(pool) => pool.run_job(ranks, wave),
            // Spawn-per-wave: a throwaway pool per iteration, the old
            // `run_ranks` cost structure.
            None => RankPool::new(Universe::from_cluster(cluster)).run_job(ranks, wave),
        };
        let (next, iner) = collapse_rank_results(out.results)?;
        centroids = next;
        inertia = iner;
        let slowest =
            out.clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
        modeled.0 += slowest.0;
        modeled.1 += slowest.1;
        modeled.2 += slowest.2;
        traffic.messages += out.traffic.messages;
        traffic.bytes += out.traffic.bytes;
        traffic.remote_messages += out.traffic.remote_messages;
        traffic.remote_bytes += out.traffic.remote_bytes;
    }

    let profile = cluster.deployment.profile();
    Ok(KmeansResult {
        centroids,
        k,
        d,
        inertia,
        iterations,
        stats: JobStats {
            modeled_ms: modeled.0 as f64 / 1e6,
            compute_ms: modeled.1 as f64 / 1e6,
            net_ms: modeled.2 as f64 / 1e6,
            startup_ms: profile.startup_ms as f64,
            shuffle_bytes: traffic.bytes,
            messages: traffic.messages,
            remote_messages: traffic.remote_messages,
            remote_bytes: traffic.remote_bytes,
            peak_mem_bytes: ((k * d + k) * 4 * ranks + points.data.len() * 4) as u64,
            spilled_bytes: 0,
            combined_bytes: 0,
            migrated_bytes: 0,
            host_wall_ms: wall.elapsed().as_secs_f64() * 1e3,
        },
    })
}

/// One wave's reduce: allreduce (sums ++ counts) and inertia, then apply
/// the centroid update in place (identical on every rank). Returns the
/// global inertia.
fn reduce_and_update(
    comm: &Communicator,
    mut sums: Vec<f32>,
    counts: Vec<f32>,
    local_inertia: f64,
    centroids: &mut [f32],
    d: usize,
    k: usize,
) -> Result<f64> {
    // Reduce across ranks: one (k*d + k)-float allreduce.
    sums.extend_from_slice(&counts);
    let reduced = comm.allreduce_sum_f32(sums)?;
    let (rsums, rcounts) = reduced.split_at(k * d);
    let inertia = comm.allreduce(local_inertia, |a, b| a + b)?;
    // Update step (same on every rank — deterministic).
    comm.timed(|| {
        for c in 0..k {
            if rcounts[c] > 0.0 {
                for j in 0..d {
                    centroids[c * d + j] = rsums[c * d + j] / rcounts[c];
                }
            }
        }
    });
    Ok(inertia)
}

/// All ranks must agree on (centroids, inertia); returns rank 0's copy.
fn collapse_rank_results(results: Vec<Result<(Vec<f32>, f64)>>) -> Result<(Vec<f32>, f64)> {
    let mut agreed: Option<Vec<f32>> = None;
    let mut inertia = 0.0;
    for (i, r) in results.into_iter().enumerate() {
        let (c, iner) = r.with_context(|| format!("rank {i}"))?;
        inertia = iner;
        if let Some(prev) = &agreed {
            anyhow::ensure!(prev == &c, "ranks disagree on centroids — nondeterminism bug");
        }
        agreed = Some(c);
    }
    Ok((agreed.context("no ranks")?, inertia))
}

/// Scalar assign+combine over one shard: returns (sums k*d, counts k,
/// inertia).
fn native_step(
    shard: &[f32],
    shard_n: usize,
    d: usize,
    k: usize,
    centroids: &[f32],
) -> (Vec<f32>, Vec<f32>, f64) {
    let mut sums = vec![0.0f32; k * d];
    let mut counts = vec![0.0f32; k];
    let mut inertia = 0.0f64;
    for i in 0..shard_n {
        let p = &shard[i * d..(i + 1) * d];
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for c in 0..k {
            let q = &centroids[c * d..(c + 1) * d];
            let mut dist = 0.0f32;
            for j in 0..d {
                let diff = p[j] - q[j];
                dist += diff * diff;
            }
            if dist < best_d {
                best_d = dist;
                best = c;
            }
        }
        inertia += best_d as f64;
        counts[best] += 1.0;
        for j in 0..d {
            sums[best * d + j] += p[j];
        }
    }
    (sums, counts, inertia)
}

/// Kernel assign+combine: tile the shard into 4096-point blocks, run the
/// AOT executable, subtract the padding rows' contribution exactly.
fn kernel_step(
    comm: &crate::mpi::Communicator,
    handle: &ComputeHandle,
    shard: &[f32],
    shard_n: usize,
    d: usize,
    k: usize,
    centroids: &[f32],
) -> Result<(Vec<f32>, Vec<f32>, f64)> {
    let kernel = format!("kmeans_step_d{d}");
    let mut sums = vec![0.0f32; k * d];
    let mut counts = vec![0.0f32; k];
    // Inertia needs distances; kernel returns assignments only, so compute
    // inertia from assignments (exact, one extra pass).
    let mut inertia = 0.0f64;

    if shard_n == 0 {
        return Ok((sums, counts, inertia));
    }

    let tiles = shard_n.div_ceil(KERNEL_TILE);
    for t in 0..tiles {
        let lo = t * KERNEL_TILE;
        let hi = ((t + 1) * KERNEL_TILE).min(shard_n);
        let real = hi - lo;
        // Pad with copies of the tile's first point.
        let mut tile = Vec::with_capacity(KERNEL_TILE * d);
        tile.extend_from_slice(&shard[lo * d..hi * d]);
        let first_point: Vec<f32> = shard[lo * d..lo * d + d].to_vec();
        for _ in real..KERNEL_TILE {
            tile.extend_from_slice(&first_point);
        }

        let (outs, kernel_ns) = handle.run_timed(
            &kernel,
            vec![
                TensorArg::f32(tile, &[KERNEL_TILE, d]),
                TensorArg::f32(centroids.to_vec(), &[k, d]),
            ],
        )?;
        comm.advance_scaled(kernel_ns);
        let tile_sums = outs[0].as_f32()?;
        let tile_counts = outs[1].as_f32()?;
        let assign = outs[2].as_i32()?;

        comm.timed(|| {
            for (s, ts) in sums.iter_mut().zip(tile_sums) {
                *s += ts;
            }
            for (c, tc) in counts.iter_mut().zip(tile_counts) {
                *c += tc;
            }
            // Subtract the padding rows (they all carry first_point and
            // were assigned to assign[real..]).
            for &a in &assign[real..] {
                let a = a as usize;
                counts[a] -= 1.0;
                for j in 0..d {
                    sums[a * d + j] -= first_point[j];
                }
            }
            // Inertia from assignments (real rows only).
            for (i, &a) in assign[..real].iter().enumerate() {
                let p = &shard[(lo + i) * d..(lo + i + 1) * d];
                let q = &centroids[(a as usize) * d..(a as usize + 1) * d];
                let mut dist = 0.0f32;
                for j in 0..d {
                    let diff = p[j] - q[j];
                    dist += diff * diff;
                }
                inertia += dist as f64;
            }
        });
    }
    Ok((sums, counts, inertia))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_shapes() {
        let p = generate_points(100, 8, 4, 1);
        assert_eq!(p.data.len(), 800);
        assert_eq!(p.row(99).len(), 8);
        // Deterministic.
        assert_eq!(generate_points(100, 8, 4, 1).data, p.data);
    }

    #[test]
    fn native_kmeans_converges_on_blobs() {
        let pts = generate_points(600, 2, 3, 7);
        let cluster = ClusterConfig::builder().ranks(3).build();
        let r1 = run(&cluster, &pts, 3, 1, ComputePath::Native, None).unwrap();
        let r10 = run(&cluster, &pts, 3, 10, ComputePath::Native, None).unwrap();
        assert!(r10.inertia <= r1.inertia, "{} > {}", r10.inertia, r1.inertia);
        // Blobs have sigma 0.4 in 2-D: average sq distance should be small.
        assert!(r10.inertia / 600.0 < 1.0, "avg inertia {}", r10.inertia / 600.0);
    }

    #[test]
    fn results_identical_across_rank_counts() {
        // Floating-point caveat: partial sums are reduced in rank order,
        // so this holds only because allreduce folds in rank order — the
        // determinism test the paper's framework can't make.
        let pts = generate_points(400, 2, 4, 3);
        let one = run(
            &ClusterConfig::builder().ranks(1).build(),
            &pts,
            4,
            5,
            ComputePath::Native,
            None,
        )
        .unwrap();
        let four = run(
            &ClusterConfig::builder().ranks(4).build(),
            &pts,
            4,
            5,
            ComputePath::Native,
            None,
        )
        .unwrap();
        for (a, b) in one.centroids.iter().zip(&four.centroids) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn kernel_path_rejects_unsupported_shapes() {
        let pts = generate_points(100, 3, 2, 1);
        let cluster = ClusterConfig::builder().ranks(1).build();
        assert!(run(&cluster, &pts, 2, 1, ComputePath::Kernel, None).is_err());
    }

    #[test]
    fn warm_pool_run_matches_fresh_run() {
        let pts = generate_points(400, 2, 4, 5);
        let cluster = ClusterConfig::builder().ranks(3).build();
        let fresh = run(&cluster, &pts, 4, 6, ComputePath::Native, None).unwrap();
        let pool = RankPool::from_config(&cluster);
        for _ in 0..3 {
            let pooled =
                run_on_pool(&cluster, &pool, &pts, 4, 6, ComputePath::Native, None).unwrap();
            assert_eq!(pooled.centroids, fresh.centroids);
            assert_eq!(pooled.stats.shuffle_bytes, fresh.stats.shuffle_bytes);
        }
        assert_eq!(pool.jobs_run(), 3);
    }

    #[test]
    fn wave_jobs_agree_with_single_job_run_pooled_or_not() {
        let pts = generate_points(300, 2, 4, 9);
        let cluster = ClusterConfig::builder().ranks(2).build();
        let single = run(&cluster, &pts, 4, 5, ComputePath::Native, None).unwrap();
        let spawned = run_wave_jobs(&cluster, &pts, 4, 5, None).unwrap();
        let pool = RankPool::from_config(&cluster);
        let pooled = run_wave_jobs(&cluster, &pts, 4, 5, Some(&pool)).unwrap();
        assert_eq!(spawned.centroids, single.centroids);
        assert_eq!(pooled.centroids, single.centroids);
        assert_eq!(pooled.inertia, spawned.inertia);
        // One job per wave, all on the same warm pool.
        assert_eq!(pool.jobs_run(), 5);
    }
}
