//! WordCount — "the hello-world program of MapReduce" (paper §V.B,
//! Figs 10-11).
//!
//! Two code paths:
//!  * [`run`] — the framework path: mapper splits lines, reducer sums,
//!    under any [`ReductionMode`].
//!  * [`run_segsum_kernel`] — the AOT path: integer-coded words reduced by
//!    the `wordcount_segsum` Pallas kernel through PJRT (delayed
//!    reduction's final stage as one histogram contraction per tile).
//!
//! The corpus generator reproduces the paper's two regimes: a *small key
//! range* (vocabulary) makes the shuffle the bottleneck and Fig 10's
//! anti-scaling appears; a *large* corpus with a large vocabulary scales
//! linearly (Fig 11).

use std::collections::HashMap;

use anyhow::{ensure, Context, Result};

use crate::cluster::ClusterConfig;
use crate::core::{JobConfig, JobResult, MapReduceJob, ReductionMode};
use crate::mpi::{run_ranks_with_universe, RankPool, Universe};
use crate::runtime::{ComputeHandle, TensorArg};
use crate::util::rng::Rng;

/// Synthetic corpus: `lines` lines of `words_per_line` words drawn from a
/// `vocab`-word vocabulary with a Zipf-ish skew (exponent ~1), the shape
/// real text has. Words are `w<id>` so the kernel path can re-derive ids.
pub fn generate_corpus(lines: usize, words_per_line: usize, vocab: u32, seed: u64) -> Vec<String> {
    assert!(vocab > 0);
    let mut rng = Rng::with_stream(seed, 0xC0_55);
    // Zipf weights 1/rank.
    let weights: Vec<f64> = (1..=vocab as usize).map(|r| 1.0 / r as f64).collect();
    (0..lines)
        .map(|_| {
            let mut line = String::with_capacity(words_per_line * 6);
            for w in 0..words_per_line {
                if w > 0 {
                    line.push(' ');
                }
                let id = rng.weighted(&weights);
                line.push('w');
                line.push_str(&id.to_string());
            }
            line
        })
        .collect()
}

/// The canonical wordcount mapper.
pub fn map_line(line: &String, emit: &mut dyn FnMut(String, u64)) {
    for w in line.split_whitespace() {
        emit(w.to_string(), 1);
    }
}

/// Run wordcount through the framework under `mode`.
pub fn run(
    cluster: &ClusterConfig,
    lines: &[String],
    mode: ReductionMode,
) -> Result<JobResult<HashMap<String, u64>>> {
    MapReduceJob::new(cluster, lines)
        .with_config(JobConfig::with_mode(mode))
        .run_monoid(map_line, |a: u64, b: u64| a + b)
}

/// Run wordcount on an explicit rank subset of a warm pool — what the
/// concurrent [`crate::core::Scheduler`] and the `serve-bench` harness
/// dispatch. `cluster` describes the *job* (its `ranks()` must equal
/// `ranks.len()`); the subset is renumbered internally, so results are
/// byte-identical to [`run`] on a fresh cluster of the same width.
pub fn run_placed(
    cluster: &ClusterConfig,
    pool: &RankPool,
    ranks: &[usize],
    lines: &[String],
    mode: ReductionMode,
) -> Result<JobResult<HashMap<String, u64>>> {
    MapReduceJob::new(cluster, lines)
        .with_config(JobConfig::with_mode(mode))
        .with_placement(pool, ranks)
        .run_monoid(map_line, |a: u64, b: u64| a + b)
}

/// Classic-mode wordcount with the **map-side combiner** (Hadoop's):
/// per-key counts are folded at run-write/merge time before the
/// shuffle, so the wire carries at most one pair per distinct key per
/// rank — `JobStats::combined_bytes` records what the combiner saved.
/// The showcase for the three-way classic/eager/combined comparison.
pub fn run_combined(
    cluster: &ClusterConfig,
    lines: &[String],
) -> Result<JobResult<HashMap<String, u64>>> {
    // `run_classic_with_combiner` IS the mode dispatch — no JobConfig
    // mode needed.
    MapReduceJob::new(cluster, lines).run_classic_with_combiner(
        map_line,
        |a: &mut u64, b: u64| *a += b,
        |_k, vs: &mut dyn Iterator<Item = u64>| vs.sum(),
    )
}

/// Tile sizes fixed at AOT time (see python/compile/aot.py).
pub const SEGSUM_TILE: usize = 8192;
pub const SEGSUM_KEYS: u32 = 1024;

/// Kernel-accelerated wordcount: each rank integer-codes its local words
/// (`w<id>` -> id), reduces them tile-by-tile with the `wordcount_segsum`
/// executable, and the per-rank histograms are allreduced. Requires
/// `vocab <= SEGSUM_KEYS` and `make artifacts`.
pub fn run_segsum_kernel(
    cluster: &ClusterConfig,
    lines: &[String],
    compute: &ComputeHandle,
) -> Result<JobResult<HashMap<String, u64>>> {
    compute.warmup("wordcount_segsum")?;
    let universe = Universe::from_cluster(cluster);
    let stats = universe.stats();
    let wall = std::time::Instant::now();

    let ranks = cluster.ranks();
    let chunk = lines.len().div_ceil(ranks.max(1)).max(1);

    let (rank_results, clocks) = run_ranks_with_universe(universe, |comm| -> Result<Vec<f32>> {
        let me = comm.rank().0;
        let mine = lines.chunks(chunk).nth(me).unwrap_or(&[]);

        // Integer-code local words into (key, value) tiles.
        let (mut keys, mut vals) = comm.timed(|| {
            let mut keys: Vec<i32> = Vec::new();
            let mut vals: Vec<f32> = Vec::new();
            for line in mine {
                for w in line.split_whitespace() {
                    if let Some(id) = w.strip_prefix('w').and_then(|s| s.parse::<i32>().ok()) {
                        keys.push(id);
                        vals.push(1.0);
                    }
                }
            }
            (keys, vals)
        });
        ensure!(
            keys.iter().all(|&k| (k as u32) < SEGSUM_KEYS),
            "vocab exceeds kernel key space"
        );

        // Pad to a whole number of tiles: -1 matches no histogram bucket.
        let padded = keys.len().div_ceil(SEGSUM_TILE).max(1) * SEGSUM_TILE;
        keys.resize(padded, -1);
        vals.resize(padded, 0.0);

        // Reduce tile by tile on the compute service (the node's one
        // accelerator), accumulating the local histogram.
        let mut hist = vec![0.0f32; SEGSUM_KEYS as usize];
        for t in 0..padded / SEGSUM_TILE {
            let lo = t * SEGSUM_TILE;
            let hi = lo + SEGSUM_TILE;
            let (outs, kernel_ns) = compute.run_timed(
                "wordcount_segsum",
                vec![
                    TensorArg::i32(keys[lo..hi].to_vec(), &[SEGSUM_TILE]),
                    TensorArg::f32(vals[lo..hi].to_vec(), &[SEGSUM_TILE]),
                ],
            )?;
            comm.advance_scaled(kernel_ns);
            let tile_hist = outs[0].as_f32()?;
            for (h, t) in hist.iter_mut().zip(tile_hist) {
                *h += t;
            }
        }

        // Global reduce: one 4 KiB vector per rank instead of the raw
        // pair stream — the eager-reduction traffic win, at L1.
        comm.allreduce_sum_f32(hist)
    });

    let mut hist: Option<Vec<f32>> = None;
    for (i, r) in rank_results.into_iter().enumerate() {
        let h = r.with_context(|| format!("rank {i}"))?;
        hist.get_or_insert(h);
    }
    let hist = hist.context("no ranks ran")?;
    let mut result = HashMap::new();
    for (id, &count) in hist.iter().enumerate() {
        if count > 0.0 {
            result.insert(format!("w{id}"), count as u64);
        }
    }

    let profile = cluster.deployment.profile();
    let slowest = clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
    let (msgs, bytes, rmsgs, rbytes) = stats.snapshot();
    Ok(JobResult {
        result,
        stats: crate::core::JobStats {
            modeled_ms: slowest.0 as f64 / 1e6,
            compute_ms: slowest.1 as f64 / 1e6,
            net_ms: slowest.2 as f64 / 1e6,
            startup_ms: profile.startup_ms as f64,
            shuffle_bytes: bytes,
            messages: msgs,
            remote_messages: rmsgs,
            remote_bytes: rbytes,
            peak_mem_bytes: (SEGSUM_KEYS as u64) * 4 * cluster.ranks() as u64,
            spilled_bytes: 0,
            combined_bytes: 0,
            migrated_bytes: 0,
            host_wall_ms: wall.elapsed().as_secs_f64() * 1e3,
        },
    })
}

/// Ground truth for tests: single-threaded count.
pub fn count_serial(lines: &[String]) -> HashMap<String, u64> {
    let mut out = HashMap::new();
    for line in lines {
        for w in line.split_whitespace() {
            *out.entry(w.to_string()).or_insert(0) += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_and_skewed() {
        let a = generate_corpus(50, 8, 100, 9);
        let b = generate_corpus(50, 8, 100, 9);
        assert_eq!(a, b);
        let counts = count_serial(&a);
        // Zipf: w0 should be the most frequent word.
        let max_word = counts.iter().max_by_key(|(_, &c)| c).unwrap().0;
        assert_eq!(max_word, "w0");
    }

    #[test]
    fn framework_matches_serial_truth_all_modes() {
        let corpus = generate_corpus(60, 5, 30, 3);
        let truth = count_serial(&corpus);
        let cluster = ClusterConfig::builder().ranks(3).build();
        for mode in ReductionMode::ALL {
            let got = run(&cluster, &corpus, mode).unwrap();
            assert_eq!(got.result, truth, "mode {mode}");
        }
    }

    #[test]
    fn placed_matches_serial_truth_all_modes() {
        let corpus = generate_corpus(60, 5, 30, 3);
        let truth = count_serial(&corpus);
        let pool_cluster = ClusterConfig::builder().nodes(1).slots_per_node(4).build();
        let job_cluster = ClusterConfig::builder().nodes(1).slots_per_node(2).build();
        let pool = RankPool::from_config(&pool_cluster);
        for mode in ReductionMode::ALL {
            let got = run_placed(&job_cluster, &pool, &[2, 3], &corpus, mode).unwrap();
            assert_eq!(got.result, truth, "mode {mode}");
        }
        assert_eq!(pool.jobs_run(), 3);
    }

    #[test]
    fn combined_matches_plain_classic() {
        let corpus = generate_corpus(80, 6, 20, 5);
        let truth = count_serial(&corpus);
        let cluster = ClusterConfig::builder().ranks(3).build();
        let got = run_combined(&cluster, &corpus).unwrap();
        assert_eq!(got.result, truth);
        assert!(got.stats.combined_bytes > 0, "hot vocab must fold pairs");
    }

    #[test]
    fn empty_corpus() {
        let cluster = ClusterConfig::builder().ranks(2).build();
        let got = run(&cluster, &[], ReductionMode::Eager).unwrap();
        assert!(got.result.is_empty());
    }
}
