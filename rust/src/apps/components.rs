//! Connected components by min-label propagation — the second iterative
//! workload on the in-memory engine (§VI "a lot more algorithms"), and
//! the one that makes the determinism story exact: labels are integers
//! and the delta fold is `min`, so results are **bit-identical** across
//! widths, resizes, and collective algorithms, not just within float
//! tolerance.
//!
//! Each vertex holds `(neighbors, label, changed)` pinned rank-local in
//! an [`IterativeJob`]; a wave sends every neighbor the vertex's current
//! label (pre-folded to one `min` per `(rank, target)` by the delta
//! shuffle), and `update` keeps the minimum. The `measure` allreduce
//! counts changed labels, so the driver stops one settling wave after
//! the flood stops — no extra convergence round.

use anyhow::Result;

use crate::cluster::ElasticCluster;
use crate::core::{
    apply_resizes, IterationStats, IterativeJob, JobStats, MigrationStats, RecoveryStats,
    WaveKilled,
};
use crate::store::{CheckpointStats, CheckpointStore};

use super::pagerank::Graph;

/// One vertex state: `(neighbors, label, changed-last-wave)`.
type VertexState = (Vec<u32>, u32, bool);

/// Result of a [`run_dist`] label-propagation session.
#[derive(Debug, Clone)]
pub struct ComponentsResult {
    /// `labels[v]` = smallest vertex id in `v`'s component.
    pub labels: Vec<u32>,
    /// Waves actually run (≤ the `max_iterations` cap).
    pub iterations: usize,
    /// Whether the flood settled (a wave changed nothing) within the cap.
    pub converged: bool,
    pub stats: JobStats,
    pub per_iteration: Vec<IterationStats>,
    pub migrations: Vec<MigrationStats>,
    /// Shard snapshots written at the configured cadence (empty when
    /// checkpointing is off).
    pub checkpoints: Vec<CheckpointStats>,
    /// Checkpoint restores performed after injected kills (empty for a
    /// fault-free run).
    pub recoveries: Vec<RecoveryStats>,
}

/// Undirected adjacency from a directed [`Graph`]: every edge is
/// mirrored, lists sorted + deduped, self-loops dropped.
pub fn symmetric_adjacency(graph: &Graph) -> Vec<Vec<u32>> {
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); graph.vertices];
    for (u, out) in graph.edges.iter().enumerate() {
        for &v in out {
            if u as u32 != v {
                adj[u].push(v);
                adj[v as usize].push(u as u32);
            }
        }
    }
    for list in &mut adj {
        list.sort_unstable();
        list.dedup();
    }
    adj
}

/// `chains` disjoint directed chains of `len` vertices each — a graph
/// with a known component structure (component `c` = vertices
/// `c*len .. (c+1)*len`, label `c*len`) and diameter `len - 1`, which is
/// what the propagation bound tests pin.
pub fn chain_graph(chains: usize, len: usize) -> Graph {
    assert!(chains > 0 && len > 0);
    let vertices = chains * len;
    let mut edges: Vec<Vec<u32>> = vec![Vec::new(); vertices];
    for c in 0..chains {
        for i in 0..len - 1 {
            let u = c * len + i;
            edges[u].push((u + 1) as u32);
        }
    }
    Graph { vertices, edges }
}

fn load_job(elastic: &ElasticCluster, adj: &[Vec<u32>]) -> IterativeJob<u32, VertexState> {
    IterativeJob::load(
        elastic,
        0x434F_4D50, // "COMP"
        (0..adj.len() as u32).map(|u| (u, (adj[u as usize].clone(), u, false))),
    )
}

/// One propagation wave: flood min labels one hop, return the global
/// changed-vertex count (exact — the measure monoid carrier is `u64`).
fn step_once(job: &mut IterativeJob<u32, VertexState>, elastic: &mut ElasticCluster) -> Result<u64> {
    let out = job.step(
        elastic,
        |_u: &u32, state: &VertexState, emit: &mut dyn FnMut(u32, u32)| {
            for &v in &state.0 {
                emit(v, state.1);
            }
        },
        |acc: &mut u32, v: u32| {
            if v < *acc {
                *acc = v;
            }
        },
        |_u: &u32, state: &mut VertexState, delta: Option<u32>| {
            let before = state.1;
            if let Some(m) = delta {
                if m < state.1 {
                    state.1 = m;
                }
            }
            state.2 = state.1 != before;
        },
        |_u: &u32, state: &VertexState| u64::from(state.2),
    )?;
    Ok(out.aggregate)
}

#[allow(clippy::too_many_arguments)]
fn finish(
    job: IterativeJob<u32, VertexState>,
    elastic: &ElasticCluster,
    n: usize,
    iterations: usize,
    converged: bool,
    wall: std::time::Instant,
    mut history: Vec<IterationStats>,
    mut migrations: Vec<MigrationStats>,
    mut checkpoints: Vec<CheckpointStats>,
    recoveries: Vec<RecoveryStats>,
) -> ComponentsResult {
    let mut labels = vec![0u32; n];
    job.for_each_state(|&u, state| labels[u as usize] = state.1);
    let mut stats = job.job_stats();
    // Waves, migrations, checkpoints and recoveries performed by jobs
    // that died mid-session still cost modeled time; fold the banked
    // records back in (the surviving job's own are already counted).
    stats.modeled_ms += history.iter().map(|s| s.modeled_ms).sum::<f64>()
        + migrations.iter().map(|m| m.modeled_ms).sum::<f64>()
        + checkpoints.iter().map(|c| c.modeled_ms).sum::<f64>()
        + recoveries.iter().map(|r| r.modeled_ms).sum::<f64>()
        - job.recovery().map_or(0.0, |r| r.modeled_ms);
    stats.compute_ms += history.iter().map(|s| s.compute_ms).sum::<f64>();
    stats.net_ms += history.iter().map(|s| s.net_ms).sum::<f64>();
    stats.shuffle_bytes += history.iter().map(|s| s.shuffled_bytes).sum::<u64>();
    stats.messages += history.iter().map(|s| s.messages).sum::<u64>()
        + migrations.iter().map(|m| m.messages).sum::<u64>();
    stats.remote_messages += history.iter().map(|s| s.remote_messages).sum::<u64>();
    stats.remote_bytes += history.iter().map(|s| s.remote_bytes).sum::<u64>();
    stats.migrated_bytes += migrations.iter().map(|m| m.moved_bytes).sum::<u64>();
    history.extend(job.per_iteration().iter().cloned());
    migrations.extend(job.migrations().iter().cloned());
    checkpoints.extend(job.checkpoints().iter().cloned());
    stats.startup_ms = elastic.config().deployment.profile().startup_ms as f64;
    stats.host_wall_ms = wall.elapsed().as_secs_f64() * 1e3;
    ComponentsResult {
        labels,
        iterations,
        converged,
        stats,
        per_iteration: history,
        migrations,
        checkpoints,
        recoveries,
    }
}

/// Label propagation on the iterative engine. `resizes` is the same
/// mid-run elasticity plan [`super::pagerank::run_dist`] takes:
/// `(iteration, node_delta)` pairs applied before that iteration's wave.
pub fn run_dist(
    elastic: &mut ElasticCluster,
    graph: &Graph,
    max_iterations: usize,
    resizes: &[(usize, i64)],
) -> Result<ComponentsResult> {
    let n = graph.vertices;
    anyhow::ensure!(n > 0, "empty graph");
    let wall = std::time::Instant::now();
    let adj = symmetric_adjacency(graph);
    let mut job = load_job(elastic, &adj);

    let mut converged = false;
    let mut iterations = 0;
    for it in 0..max_iterations {
        apply_resizes(elastic, resizes, it)?;
        let changed = step_once(&mut job, elastic)?;
        iterations = it + 1;
        if changed == 0 {
            converged = true;
            break;
        }
    }
    Ok(finish(
        job,
        elastic,
        n,
        iterations,
        converged,
        wall,
        Vec::new(),
        Vec::new(),
        Vec::new(),
        Vec::new(),
    ))
}

/// Label propagation that survives the cluster's [`crate::cluster::FaultPlan`]:
/// shards checkpoint every `checkpoint_every` waves, and when a scheduled
/// kill lands the driver replaces the dead node (`replace_delta` adjusts
/// the node count — 0 replaces in kind) and re-enters the wave loop from
/// the last checkpoint. Because labels are integers and the wave is
/// deterministic, the recovered run's labels are **bit-identical** to an
/// uninterrupted run at any recovery width.
pub fn run_dist_faulty(
    elastic: &mut ElasticCluster,
    graph: &Graph,
    max_iterations: usize,
    checkpoint_every: usize,
    replace_delta: i64,
) -> Result<ComponentsResult> {
    let n = graph.vertices;
    anyhow::ensure!(n > 0, "empty graph");
    let wall = std::time::Instant::now();
    let adj = symmetric_adjacency(graph);
    let store: CheckpointStore<u32, VertexState> = CheckpointStore::new();
    let mut job = load_job(elastic, &adj);
    job.checkpoint_every(store.clone(), checkpoint_every);

    let mut history: Vec<IterationStats> = Vec::new();
    let mut migrations: Vec<MigrationStats> = Vec::new();
    let mut checkpoints: Vec<CheckpointStats> = Vec::new();
    let mut recoveries: Vec<RecoveryStats> = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    while iterations < max_iterations {
        match step_once(&mut job, elastic) {
            Ok(changed) => {
                iterations = job.steps_run();
                if changed == 0 {
                    converged = true;
                    break;
                }
            }
            Err(e) if e.downcast_ref::<WaveKilled>().is_some() => {
                // The dying job's completed waves still cost modeled
                // time; bank its records before dropping it.
                history.extend(job.per_iteration().iter().cloned());
                migrations.extend(job.migrations().iter().cloned());
                checkpoints.extend(job.checkpoints().iter().cloned());
                elastic.kill_and_replace(replace_delta)?;
                job = match IterativeJob::recover_from(elastic, &store)? {
                    Some(recovered) => recovered,
                    // Killed before the first checkpoint: start over.
                    None => load_job(elastic, &adj),
                };
                job.checkpoint_every(store.clone(), checkpoint_every);
                recoveries.extend(job.recovery().cloned());
                iterations = job.steps_run();
            }
            Err(e) => return Err(e),
        }
    }
    Ok(finish(
        job, elastic, n, iterations, converged, wall, history, migrations, checkpoints, recoveries,
    ))
}

/// Serial ground truth: union-find (union-by-min, path halving), so each
/// vertex's root is exactly the smallest id in its component.
pub fn reference(graph: &Graph) -> Vec<u32> {
    let n = graph.vertices;
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            let gp = parent[parent[x as usize] as usize];
            parent[x as usize] = gp;
            x = gp;
        }
        x
    }
    for (u, out) in graph.edges.iter().enumerate() {
        for &v in out {
            let ru = find(&mut parent, u as u32);
            let rv = find(&mut parent, v);
            if ru < rv {
                parent[rv as usize] = ru;
            } else if rv < ru {
                parent[ru as usize] = rv;
            }
        }
    }
    (0..n as u32).map(|v| find(&mut parent, v)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn elastic(ranks: usize) -> ElasticCluster {
        ElasticCluster::new(ClusterConfig::builder().ranks(ranks).build())
    }

    #[test]
    fn chain_graph_shape_and_reference_labels() {
        let g = chain_graph(3, 5);
        assert_eq!(g.vertices, 15);
        assert_eq!(g.edge_count(), 12);
        let want: Vec<u32> = (0..15).map(|v| (v / 5 * 5) as u32).collect();
        assert_eq!(reference(&g), want);
    }

    #[test]
    fn matches_union_find_on_chains() {
        let g = chain_graph(4, 12);
        let got = run_dist(&mut elastic(4), &g, 40, &[]).unwrap();
        assert!(got.converged, "flood must settle within the cap");
        assert_eq!(got.labels, reference(&g));
        // Min labels flood one hop per wave: diameter + 1 settling wave.
        assert!(got.iterations <= 12, "took {} waves", got.iterations);
    }

    #[test]
    fn matches_union_find_on_random_graph() {
        let g = Graph::random(150, 3, 11);
        let got = run_dist(&mut elastic(3), &g, 200, &[]).unwrap();
        assert!(got.converged);
        assert_eq!(got.labels, reference(&g));
        // Every vertex of Graph::random reaches an earlier one, so the
        // undirected graph is one component rooted at 0.
        assert!(got.labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn labels_are_bit_identical_across_a_mid_run_resize() {
        let g = chain_graph(5, 10);
        let straight = run_dist(&mut elastic(2), &g, 40, &[]).unwrap();
        let mut resized_cluster = elastic(2);
        let resized = run_dist(&mut resized_cluster, &g, 40, &[(3, 2), (6, -1)]).unwrap();
        assert_eq!(straight.labels, resized.labels, "integer min is width-invariant");
        assert_eq!(resized.labels, reference(&g));
        assert_eq!(resized.migrations.len(), 2);
        assert!(resized.stats.migrated_bytes > 0);
        assert_eq!(straight.iterations, resized.iterations);
    }

    #[test]
    fn isolated_vertices_keep_their_own_label() {
        // A graph with no edges at all: one wave, nothing changes.
        let g = Graph { vertices: 7, edges: vec![Vec::new(); 7] };
        let got = run_dist(&mut elastic(2), &g, 5, &[]).unwrap();
        assert!(got.converged);
        assert_eq!(got.iterations, 1);
        assert_eq!(got.labels, (0..7).collect::<Vec<u32>>());
    }
}
