//! Linear regression by distributed gradient descent — the second §III.D
//! workload the paper says eager reduction could not express.
//!
//! Each GD iteration is a MapReduce job: mappers compute per-shard
//! gradient partials, the reduce sums them, the driver applies the step.
//! The kernel path runs the fused `linreg_d8` AOT graph per 4096-row tile
//! (grad = X^T(Xw - y)/N plus the shard's squared error).

use anyhow::{bail, Context, Result};

use crate::cluster::ClusterConfig;
use crate::core::JobStats;
use crate::mpi::RankPool;
use crate::runtime::{ComputeHandle, TensorArg};
use crate::util::rng::Rng;

/// AOT tile shape of `linreg_d8`.
pub const KERNEL_TILE: usize = 4096;
pub const KERNEL_D: usize = 8;

/// Synthetic regression data y = X·w* + noise.
#[derive(Debug, Clone)]
pub struct RegData {
    pub x: Vec<f32>, // n x d row-major
    pub y: Vec<f32>, // n
    pub n: usize,
    pub d: usize,
    pub true_w: Vec<f32>,
}

pub fn generate(n: usize, d: usize, noise: f32, seed: u64) -> RegData {
    let mut rng = Rng::with_stream(seed, 0x17_EE);
    let true_w: Vec<f32> = (0..d).map(|_| rng.f32() * 4.0 - 2.0).collect();
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let row: Vec<f32> = (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let mut t = 0.0f32;
        for j in 0..d {
            t += row[j] * true_w[j];
        }
        y.push(t + noise * rng.normal() as f32);
        x.extend(row);
    }
    RegData { x, y, n, d, true_w }
}

#[derive(Debug, Clone)]
pub struct LinregResult {
    pub w: Vec<f32>,
    pub mse: f64,
    pub iterations: usize,
    pub stats: JobStats,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputePath {
    Native,
    /// Requires d == [`KERNEL_D`].
    Kernel,
}

/// Distributed batch gradient descent. Spawns a throwaway [`RankPool`];
/// sweeps should hold one warm pool and call [`run_on_pool`].
pub fn run(
    cluster: &ClusterConfig,
    data: &RegData,
    iterations: usize,
    lr: f32,
    path: ComputePath,
    compute: Option<&ComputeHandle>,
) -> Result<LinregResult> {
    run_on_pool(cluster, &RankPool::from_config(cluster), data, iterations, lr, path, compute)
}

/// [`run`] on a caller-owned warm [`RankPool`]: every GD iteration's
/// gradient partials and allreduce execute on the pool's persistent rank
/// threads.
pub fn run_on_pool(
    cluster: &ClusterConfig,
    pool: &RankPool,
    data: &RegData,
    iterations: usize,
    lr: f32,
    path: ComputePath,
    compute: Option<&ComputeHandle>,
) -> Result<LinregResult> {
    if path == ComputePath::Kernel {
        if data.d != KERNEL_D {
            bail!("kernel path needs d == {KERNEL_D}, got {}", data.d);
        }
        compute.context("kernel path needs a ComputeHandle")?.warmup("linreg_d8")?;
    }
    let ranks = cluster.ranks();
    pool.ensure_models(cluster)?;
    let wall = std::time::Instant::now();

    let d = data.d;
    let chunk = data.n.div_ceil(ranks.max(1)).max(1);

    let out = pool.run_job(ranks, |comm| -> Result<(Vec<f32>, f64)> {
        let me = comm.rank().0;
        let lo = (me * chunk).min(data.n);
        let hi = ((me + 1) * chunk).min(data.n);
        let xs = &data.x[lo * d..hi * d];
        let ys = &data.y[lo..hi];
        let shard_n = hi - lo;

        let mut w = vec![0.0f32; d];
        let mut mse = 0.0f64;
        for _ in 0..iterations {
            // Per-shard gradient + sse. Partials are scaled by shard_n/N
            // so the allreduced gradient is the global mean gradient.
            let (mut grad, sse) = match path {
                ComputePath::Native => comm.timed(|| native_grad(xs, ys, shard_n, d, &w)),
                ComputePath::Kernel => {
                    kernel_grad(comm, compute.expect("checked"), xs, ys, shard_n, d, &w)?
                }
            };
            for g in grad.iter_mut() {
                *g *= shard_n as f32 / data.n as f32;
            }
            grad.push(sse as f32);
            let reduced = comm.allreduce_sum_f32(grad)?;
            let (g, s) = reduced.split_at(d);
            mse = s[0] as f64 / data.n as f64;
            comm.timed(|| {
                for j in 0..d {
                    w[j] -= lr * g[j];
                }
            });
        }
        Ok((w, mse))
    });

    let mut w: Option<Vec<f32>> = None;
    let mut mse = 0.0;
    for (i, r) in out.results.into_iter().enumerate() {
        let (rw, rmse) = r.with_context(|| format!("rank {i}"))?;
        mse = rmse;
        if let Some(prev) = &w {
            anyhow::ensure!(prev == &rw, "ranks disagree on weights");
        }
        w = Some(rw);
    }

    let profile = cluster.deployment.profile();
    let slowest = out.clocks.iter().max_by_key(|(clk, _, _)| *clk).copied().unwrap_or((0, 0, 0));
    Ok(LinregResult {
        w: w.context("no ranks")?,
        mse,
        iterations,
        stats: JobStats {
            modeled_ms: slowest.0 as f64 / 1e6,
            compute_ms: slowest.1 as f64 / 1e6,
            net_ms: slowest.2 as f64 / 1e6,
            startup_ms: profile.startup_ms as f64,
            shuffle_bytes: out.traffic.bytes,
            messages: out.traffic.messages,
            remote_messages: out.traffic.remote_messages,
            remote_bytes: out.traffic.remote_bytes,
            peak_mem_bytes: ((d + 1) * 4 * ranks) as u64 + (data.x.len() * 4) as u64,
            spilled_bytes: 0,
            combined_bytes: 0,
            migrated_bytes: 0,
            host_wall_ms: wall.elapsed().as_secs_f64() * 1e3,
        },
    })
}

/// grad = X^T (Xw - y) / shard_n, sse = ||Xw - y||^2 over the shard.
fn native_grad(xs: &[f32], ys: &[f32], n: usize, d: usize, w: &[f32]) -> (Vec<f32>, f64) {
    let mut grad = vec![0.0f32; d];
    let mut sse = 0.0f64;
    for i in 0..n {
        let row = &xs[i * d..(i + 1) * d];
        let mut pred = 0.0f32;
        for j in 0..d {
            pred += row[j] * w[j];
        }
        let resid = pred - ys[i];
        sse += (resid * resid) as f64;
        for j in 0..d {
            grad[j] += row[j] * resid;
        }
    }
    if n > 0 {
        for g in grad.iter_mut() {
            *g /= n as f32;
        }
    }
    (grad, sse)
}

/// Kernel tile pass: zero-pad (zero rows add nothing), then fix the 1/N.
fn kernel_grad(
    comm: &crate::mpi::Communicator,
    handle: &ComputeHandle,
    xs: &[f32],
    ys: &[f32],
    n: usize,
    d: usize,
    w: &[f32],
) -> Result<(Vec<f32>, f64)> {
    let mut grad = vec![0.0f32; d];
    let mut sse = 0.0f64;
    if n == 0 {
        return Ok((grad, sse));
    }
    let tiles = n.div_ceil(KERNEL_TILE);
    for t in 0..tiles {
        let lo = t * KERNEL_TILE;
        let hi = ((t + 1) * KERNEL_TILE).min(n);
        let real = hi - lo;
        let mut x_tile = xs[lo * d..hi * d].to_vec();
        x_tile.resize(KERNEL_TILE * d, 0.0);
        let mut y_tile = ys[lo..hi].to_vec();
        y_tile.resize(KERNEL_TILE, 0.0);
        let (outs, kernel_ns) = handle.run_timed(
            "linreg_d8",
            vec![
                TensorArg::f32(x_tile, &[KERNEL_TILE, d]),
                TensorArg::f32(y_tile, &[KERNEL_TILE]),
                TensorArg::f32(w.to_vec(), &[d]),
            ],
        )?;
        comm.advance_scaled(kernel_ns);
        let g = outs[0].as_f32()?;
        let s = outs[1].as_f32()?;
        // Kernel normalizes by KERNEL_TILE; rescale to per-real-row then
        // accumulate tile contribution (weighted by rows).
        comm.timed(|| {
            for j in 0..d {
                grad[j] += g[j] * KERNEL_TILE as f32;
            }
            sse += s[0] as f64;
        });
        let _ = real;
    }
    for g in grad.iter_mut() {
        *g /= n as f32;
    }
    Ok((grad, sse))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_recoverable() {
        let data = generate(500, 4, 0.0, 1);
        assert_eq!(data.x.len(), 2000);
        assert_eq!(data.true_w.len(), 4);
    }

    #[test]
    fn gd_recovers_weights_noiseless() {
        let data = generate(2_000, 4, 0.0, 7);
        let cluster = ClusterConfig::builder().ranks(2).build();
        let got = run(&cluster, &data, 300, 0.5, ComputePath::Native, None).unwrap();
        for (w, t) in got.w.iter().zip(&data.true_w) {
            assert!((w - t).abs() < 0.05, "w {w} vs true {t} (mse {})", got.mse);
        }
        assert!(got.mse < 1e-3, "mse {}", got.mse);
    }

    #[test]
    fn mse_decreases_with_iterations() {
        let data = generate(1_000, 6, 0.1, 3);
        let cluster = ClusterConfig::builder().ranks(2).build();
        let short = run(&cluster, &data, 5, 0.3, ComputePath::Native, None).unwrap();
        let long = run(&cluster, &data, 100, 0.3, ComputePath::Native, None).unwrap();
        assert!(long.mse < short.mse);
    }

    #[test]
    fn rank_count_invariance() {
        let data = generate(600, 4, 0.05, 9);
        let a = run(&ClusterConfig::builder().ranks(1).build(), &data, 50, 0.3, ComputePath::Native, None)
            .unwrap();
        let b = run(&ClusterConfig::builder().ranks(3).build(), &data, 50, 0.3, ComputePath::Native, None)
            .unwrap();
        for (x, y) in a.w.iter().zip(&b.w) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn kernel_path_shape_guard() {
        let data = generate(100, 4, 0.0, 1);
        let cluster = ClusterConfig::builder().ranks(1).build();
        assert!(run(&cluster, &data, 1, 0.1, ComputePath::Kernel, None).is_err());
    }

    #[test]
    fn warm_pool_run_matches_fresh_run() {
        let data = generate(400, 4, 0.05, 11);
        let cluster = ClusterConfig::builder().ranks(2).build();
        let fresh = run(&cluster, &data, 20, 0.3, ComputePath::Native, None).unwrap();
        let pool = RankPool::from_config(&cluster);
        for _ in 0..2 {
            let pooled =
                run_on_pool(&cluster, &pool, &data, 20, 0.3, ComputePath::Native, None).unwrap();
            assert_eq!(pooled.w, fresh.w);
            assert_eq!(pooled.mse, fresh.mse);
        }
        assert_eq!(pool.jobs_run(), 2);
    }
}
