//! Distributed matrix multiplication — the §III.D motivating workload.
//!
//! "When the framework was used to develop other algorithms like matrix
//! multiplication ... it felt rigidity due to the eager reduction and it
//! was almost impossible to implement" — because the classic MapReduce
//! matmul keys partial products by output cell `(i, j)` and the reducer
//! must see the *iterable* of all p partial products. Delayed Reduction
//! restores that shape; this module is the E7 ablation's subject.
//!
//! Formulation (one of the standard ones): input items are the row indices
//! of A; the mapper holds B (broadcast, as Blaze would bcast a DistVector)
//! and emits `((i, j), a_ik * b_kj)` per k — the reducer sums the iterable
//! per output cell.

use std::collections::HashMap;

use anyhow::Result;

use crate::cluster::ClusterConfig;
use crate::core::{JobConfig, JobResult, MapReduceJob, ReductionMode};
use crate::util::rng::Rng;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn random(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Rng::with_stream(seed, 0x4D4D);
        Self { rows, cols, data: (0..rows * cols).map(|_| rng.f64() * 2.0 - 1.0).collect() }
    }

    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Reference serial multiply.
    pub fn multiply(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.at(k, j);
                }
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// MapReduce matmul under `mode`. Emits one partial product per (i, k, j)
/// and reduces per output cell — O(m·p·n) pairs, deliberately: this is the
/// workload whose pair volume exposes the difference between engines.
pub fn run(
    cluster: &ClusterConfig,
    a: &Matrix,
    b: &Matrix,
    mode: ReductionMode,
) -> Result<JobResult<Matrix>> {
    assert_eq!(a.cols, b.rows);
    let rows: Vec<u32> = (0..a.rows as u32).collect();
    let out = MapReduceJob::new(cluster, &rows)
        .with_config(JobConfig::with_mode(mode))
        .run_monoid(
            |&i: &u32, emit: &mut dyn FnMut((u32, u32), f64)| {
                let i = i as usize;
                for k in 0..a.cols {
                    let aik = a.at(i, k);
                    for j in 0..b.cols {
                        emit((i as u32, j as u32), aik * b.at(k, j));
                    }
                }
            },
            |x: f64, y: f64| x + y,
        )?;
    Ok(out.map(|cells: HashMap<(u32, u32), f64>| {
        let mut m = Matrix::zeros(a.rows, b.cols);
        for ((i, j), v) in cells {
            m.set(i as usize, j as usize, v);
        }
        m
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_reference_sane() {
        let mut a = Matrix::zeros(2, 2);
        a.set(0, 0, 1.0);
        a.set(0, 1, 2.0);
        a.set(1, 0, 3.0);
        a.set(1, 1, 4.0);
        let id = {
            let mut m = Matrix::zeros(2, 2);
            m.set(0, 0, 1.0);
            m.set(1, 1, 1.0);
            m
        };
        assert_eq!(a.multiply(&id), a);
    }

    #[test]
    fn all_modes_match_serial() {
        let a = Matrix::random(8, 6, 1);
        let b = Matrix::random(6, 5, 2);
        let truth = a.multiply(&b);
        let cluster = ClusterConfig::builder().ranks(3).build();
        for mode in ReductionMode::ALL {
            let got = run(&cluster, &a, &b, mode).unwrap();
            // Addition order differs per mode -> tolerance, not equality.
            assert!(
                got.result.max_abs_diff(&truth) < 1e-9,
                "mode {mode}: diff {}",
                got.result.max_abs_diff(&truth)
            );
        }
    }

    #[test]
    fn delayed_reducer_sees_p_partials() {
        // The §III.D property: with delayed reduction the final reducer
        // receives exactly a.cols partial products per cell.
        let a = Matrix::random(3, 4, 3);
        let b = Matrix::random(4, 2, 4);
        let cluster = ClusterConfig::builder().ranks(2).build();
        let rows: Vec<u32> = (0..a.rows as u32).collect();
        let out = MapReduceJob::new(&cluster, &rows)
            .run_delayed(
                |&i: &u32, emit: &mut dyn FnMut((u32, u32), f64)| {
                    let i = i as usize;
                    for k in 0..a.cols {
                        for j in 0..b.cols {
                            emit((i as u32, j as u32), a.at(i, k) * b.at(k, j));
                        }
                    }
                },
                |_cell, vs: &mut dyn Iterator<Item = f64>| {
                    let vs: Vec<f64> = vs.collect();
                    assert_eq!(vs.len(), 4, "reducer must see all p partials");
                    vs.into_iter().sum()
                },
            )
            .unwrap();
        assert_eq!(out.result.len(), 6);
    }
}
