//! One generator per paper figure (DESIGN.md §5 experiment index).
//!
//! Every generator returns a [`Report`] whose series are the lines of the
//! paper's figure; `quick` shrinks workloads for CI/`cargo bench`, the
//! full sizes populate EXPERIMENTS.md. The y-axis is *modeled* time
//! (virtual clock: measured compute scaled by the deployment profile +
//! charged network), so the curves reflect the simulated cluster rather
//! than this host's core count.

use anyhow::Result;

use crate::apps::{kmeans, pi, wordcount};
use crate::baseline::SparkContext;
use crate::cluster::{ClusterConfig, DeploymentKind};
use crate::core::ReductionMode;
use crate::metrics::{Report, Series};

/// Which experiment to run (ids from DESIGN.md §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FigureId {
    /// E1 — Fig 8: K-means scaling with nodes and dimensionality.
    Fig8,
    /// E2 — Fig 9: K-means, Blaze vs Spark.
    Fig9,
    /// E3 — Fig 10: WordCount anti-scaling at small key range.
    Fig10,
    /// E4 — Fig 11: WordCount at scale, Blaze vs Spark.
    Fig11,
    /// E5 — Fig 12: Pi estimation scaling.
    Fig12,
    /// E6 — Fig 13: Peak memory, Blaze vs Spark.
    Fig13,
    /// E7 — §III.D ablation: matmul/linreg across reduction modes.
    AblationReduction,
    /// E8 — §III deployment overheads (Figs 3-5 architectures).
    Deployment,
    /// E9 — pooled SPMD executor vs spawn-per-wave (host wall clock).
    PoolAblation,
    /// E10 — spill crossover: delayed wordcount swept through the
    /// in-core -> out-of-core transition, plus the three-way
    /// classic/eager/classic+combiner shuffle-bytes comparison.
    SpillCrossover,
    /// E11 — tree ablation: rank count x collective algorithm. The
    /// virtual-clock gap between star and tree collectives widens with
    /// rank count, and the Fig 10 wordcount curve bends when the
    /// runtime gets smarter collectives.
    TreeAblation,
    /// E12 — iterative ablation: PageRank per-iteration wire bytes and
    /// clock, engine path (one job per iteration) vs the in-memory
    /// DistHashMap path (delta-only waves), with a mid-run
    /// `ElasticCluster` grow whose shard-migration bytes are plotted as
    /// their own series.
    IterativeAblation,
    /// E13 — fault ablation: checkpoint overhead per cadence `k`, and
    /// recover-from-checkpoint vs re-run-from-scratch modeled time as a
    /// function of where in the run the kill lands.
    FaultAblation,
}

impl FigureId {
    pub const ALL: [FigureId; 13] = [
        FigureId::Fig8,
        FigureId::Fig9,
        FigureId::Fig10,
        FigureId::Fig11,
        FigureId::Fig12,
        FigureId::Fig13,
        FigureId::AblationReduction,
        FigureId::Deployment,
        FigureId::PoolAblation,
        FigureId::SpillCrossover,
        FigureId::TreeAblation,
        FigureId::IterativeAblation,
        FigureId::FaultAblation,
    ];

    pub fn parse(s: &str) -> Option<FigureId> {
        Some(match s.to_ascii_lowercase().as_str() {
            "fig8" | "e1" => FigureId::Fig8,
            "fig9" | "e2" => FigureId::Fig9,
            "fig10" | "e3" => FigureId::Fig10,
            "fig11" | "e4" => FigureId::Fig11,
            "fig12" | "e5" => FigureId::Fig12,
            "fig13" | "e6" => FigureId::Fig13,
            "ablation-reduction" | "e7" => FigureId::AblationReduction,
            "deployment" | "e8" => FigureId::Deployment,
            "pool-ablation" | "e9" => FigureId::PoolAblation,
            "spill-crossover" | "e10" => FigureId::SpillCrossover,
            "tree-ablation" | "e11" => FigureId::TreeAblation,
            "iterative-ablation" | "e12" => FigureId::IterativeAblation,
            "fault-ablation" | "e13" => FigureId::FaultAblation,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FigureId::Fig8 => "fig8",
            FigureId::Fig9 => "fig9",
            FigureId::Fig10 => "fig10",
            FigureId::Fig11 => "fig11",
            FigureId::Fig12 => "fig12",
            FigureId::Fig13 => "fig13",
            FigureId::AblationReduction => "ablation-reduction",
            FigureId::Deployment => "deployment",
            FigureId::PoolAblation => "pool-ablation",
            FigureId::SpillCrossover => "spill-crossover",
            FigureId::TreeAblation => "tree-ablation",
            FigureId::IterativeAblation => "iterative-ablation",
            FigureId::FaultAblation => "fault-ablation",
        }
    }
}

const NODE_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn vm_cluster(nodes: usize, seed: u64) -> ClusterConfig {
    ClusterConfig::builder()
        .deployment(DeploymentKind::Vm)
        .nodes(nodes)
        .slots_per_node(1)
        .seed(seed)
        .build()
}

/// Run one figure's experiment.
pub fn run_figure(id: FigureId, quick: bool) -> Result<Report> {
    match id {
        FigureId::Fig8 => fig8(quick),
        FigureId::Fig9 => fig9(quick),
        FigureId::Fig10 => fig10(quick),
        FigureId::Fig11 => fig11(quick),
        FigureId::Fig12 => fig12(quick),
        FigureId::Fig13 => fig13(quick),
        FigureId::AblationReduction => ablation_reduction(quick),
        FigureId::Deployment => deployment(quick),
        FigureId::PoolAblation => pool_ablation(quick),
        FigureId::SpillCrossover => spill_crossover(quick),
        FigureId::TreeAblation => tree_ablation(quick),
        FigureId::IterativeAblation => iterative_ablation(quick),
        FigureId::FaultAblation => fault_ablation(quick),
    }
}

/// Fig 8 — K-means on the framework: time vs nodes, one series per
/// dimensionality (the paper: "with increasing dimensions, the algorithm
/// performed better [relative to work done]; scalability was displayed").
fn fig8(quick: bool) -> Result<Report> {
    let n = if quick { 20_000 } else { 200_000 };
    let iters = if quick { 3 } else { 10 };
    let mut report = Report::new("Fig 8 — K-means on blaze-rs (VM cluster)");
    for d in [2usize, 8, 32] {
        let points = kmeans::generate_points(n, d, kmeans::KERNEL_K, 40 + d as u64);
        let mut series = Series::new(format!("d={d}"), "nodes", "modeled_ms");
        for nodes in NODE_SWEEP {
            let cluster = vm_cluster(nodes, 40);
            let r = kmeans::run(&cluster, &points, kmeans::KERNEL_K, iters, kmeans::ComputePath::Native, None)?;
            series.push(nodes as f64, r.stats.modeled_ms);
        }
        if let Some(ratio) = series.end_to_end_ratio() {
            report.note(format!("d={d}: t(8 nodes)/t(1 node) = {ratio:.3} (paper: near-linear speedup)"));
        }
        report.add(series);
    }
    Ok(report)
}

/// Fig 9 — K-means Blaze vs Spark ("faster than Spark by a large margin,
/// scalability close to linear").
fn fig9(quick: bool) -> Result<Report> {
    let n = if quick { 20_000 } else { 200_000 };
    let iters = if quick { 3 } else { 10 };
    let d = 8usize;
    let points = kmeans::generate_points(n, d, kmeans::KERNEL_K, 41);
    let mut report = Report::new("Fig 9 — K-means: blaze-rs vs Spark-sim (VM cluster)");
    let mut blaze = Series::new("blaze-rs", "nodes", "modeled_ms");
    let mut spark = Series::new("spark-sim", "nodes", "modeled_ms");
    for nodes in NODE_SWEEP {
        let cluster = vm_cluster(nodes, 41);
        let b = kmeans::run(&cluster, &points, kmeans::KERNEL_K, iters, kmeans::ComputePath::Native, None)?;
        blaze.push(nodes as f64, b.stats.modeled_ms);
        let (_, s) = SparkContext::new(&cluster).kmeans(&points, kmeans::KERNEL_K, iters);
        spark.push(nodes as f64, s.modeled_ms);
    }
    let factor = spark.points[0].1 / blaze.points[0].1.max(1e-9);
    report.note(format!("1-node Spark/Blaze time ratio = {factor:.2}x (paper: 'large margin')"));
    report.add(blaze);
    report.add(spark);
    Ok(report)
}

/// Fig 10 — WordCount at a *small key range*: "the framework tended to
/// increase processing time with increase in nodes ... part of the issue
/// ... the shuffle phase".
fn fig10(quick: bool) -> Result<Report> {
    // Deliberately SMALL in both modes: Fig 10 is the paper's
    // small-key-range, small-dataset regime ("this task was inefficient in
    // terms of scalability") — growing the corpus moves it into Fig 11's
    // linear regime and the anti-scaling signal disappears.
    let _ = quick;
    let lines = 2_000;
    let corpus = wordcount::generate_corpus(lines, 8, 50, 42);
    let mut report = Report::new("Fig 10 — WordCount, small key range (VM cluster)");
    let mut series = Series::new("vocab=50", "nodes", "modeled_ms");
    for nodes in NODE_SWEEP {
        let cluster = vm_cluster(nodes, 42);
        let r = wordcount::run(&cluster, &corpus, ReductionMode::Eager)?;
        series.push(nodes as f64, r.stats.modeled_ms);
    }
    if let Some(ratio) = series.end_to_end_ratio() {
        report.note(format!(
            "t(8)/t(1) = {ratio:.3} — >1 reproduces the paper's anti-scaling at low key ranges"
        ));
    }
    report.add(series);
    Ok(report)
}

/// Fig 11 — WordCount at scale vs Spark ("on larger dataset, the
/// scalability is linear").
fn fig11(quick: bool) -> Result<Report> {
    let lines = if quick { 20_000 } else { 200_000 };
    let corpus = wordcount::generate_corpus(lines, 10, 10_000, 43);
    let mut report = Report::new("Fig 11 — WordCount at scale: blaze-rs vs Spark-sim");
    let mut blaze = Series::new("blaze-rs (eager)", "nodes", "modeled_ms");
    let mut spark = Series::new("spark-sim", "nodes", "modeled_ms");
    for nodes in NODE_SWEEP {
        let cluster = vm_cluster(nodes, 43);
        let b = wordcount::run(&cluster, &corpus, ReductionMode::Eager)?;
        blaze.push(nodes as f64, b.stats.modeled_ms);
        let (_, s) = SparkContext::new(&cluster).wordcount(&corpus);
        spark.push(nodes as f64, s.modeled_ms);
    }
    let factor = spark.points[0].1 / blaze.points[0].1.max(1e-9);
    report.note(format!("1-node Spark/Blaze ratio = {factor:.2}x"));
    report.add(blaze);
    report.add(spark);
    Ok(report)
}

/// Fig 12 — Pi estimation: "very efficient in terms of memory, speed and
/// scalability; time reduces almost linearly with nodes".
fn fig12(quick: bool) -> Result<Report> {
    let samples = if quick { 1_000_000 } else { 20_000_000 };
    let mut report = Report::new("Fig 12 — Pi estimation (VM cluster)");
    let mut series = Series::new("blaze-rs (eager, batched)", "nodes", "modeled_ms");
    for nodes in NODE_SWEEP {
        let cluster = vm_cluster(nodes, 44);
        let chunks = pi::make_chunks(samples, nodes * 8, 44);
        let r = pi::run_eager_batched(&cluster, &chunks)?;
        series.push(nodes as f64, r.stats.modeled_ms);
    }
    if let Some(ratio) = series.end_to_end_ratio() {
        report.note(format!("t(8)/t(1) = {ratio:.3} (ideal 0.125)"));
    }
    report.add(series);
    Ok(report)
}

/// Fig 13 — Peak memory, Blaze vs Spark, per workload.
fn fig13(quick: bool) -> Result<Report> {
    let cluster = vm_cluster(4, 45);
    let mut report = Report::new("Fig 13 — Peak memory: blaze-rs vs Spark-sim (4 VM nodes)");
    let mut blaze = Series::new("blaze-rs", "workload(0=wc,1=kmeans,2=pi)", "peak_MiB");
    let mut spark = Series::new("spark-sim", "workload(0=wc,1=kmeans,2=pi)", "peak_MiB");
    let mib = |b: u64| b as f64 / (1 << 20) as f64;

    let corpus = wordcount::generate_corpus(if quick { 5_000 } else { 50_000 }, 8, 1_000, 45);
    let b = wordcount::run(&cluster, &corpus, ReductionMode::Eager)?;
    let (_, s) = SparkContext::new(&cluster).wordcount(&corpus);
    blaze.push(0.0, mib(b.stats.peak_mem_bytes));
    spark.push(0.0, mib(s.peak_mem_bytes));

    let points = kmeans::generate_points(if quick { 20_000 } else { 100_000 }, 8, 16, 45);
    let bk = kmeans::run(&cluster, &points, 16, 3, kmeans::ComputePath::Native, None)?;
    let (_, sk) = SparkContext::new(&cluster).kmeans(&points, 16, 3);
    blaze.push(1.0, mib(bk.stats.peak_mem_bytes));
    spark.push(1.0, mib(sk.peak_mem_bytes));

    let chunks = pi::make_chunks(if quick { 500_000 } else { 5_000_000 }, 32, 45);
    let bp = pi::run_eager_batched(&cluster, &chunks)?;
    let (_, sp) = SparkContext::new(&cluster).pi(&chunks);
    blaze.push(2.0, mib(bp.stats.peak_mem_bytes));
    spark.push(2.0, mib(sp.peak_mem_bytes));

    for i in 0..3 {
        let ratio = spark.points[i].1 / blaze.points[i].1.max(1e-9);
        report.note(format!("workload {i}: Spark/Blaze peak-memory ratio = {ratio:.1}x"));
    }
    report.add(blaze);
    report.add(spark);
    Ok(report)
}

/// E7 — the §III.D ablation: matmul + linreg across reduction modes.
/// Eager *can* run the monoid-sum form, but only Delayed restores the
/// `(K, Iterable<V>)` contract (asserted in apps::matmul tests); here we
/// measure what each mode pays.
fn ablation_reduction(quick: bool) -> Result<Report> {
    use crate::apps::matmul::{self, Matrix};
    let size = if quick { 24 } else { 48 };
    let a = Matrix::random(size, size, 7);
    let b = Matrix::random(size, size, 8);
    let cluster = vm_cluster(4, 46);
    let mut report = Report::new("E7 — reduction-mode ablation (matmul partial products)");
    let mut time = Series::new("matmul modeled_ms", "mode(0=classic,1=eager,2=delayed)", "modeled_ms");
    let mut bytes = Series::new("matmul shuffle_bytes", "mode(0=classic,1=eager,2=delayed)", "bytes");
    for (i, mode) in ReductionMode::ALL.iter().enumerate() {
        let r = matmul::run(&cluster, &a, &b, *mode)?;
        time.push(i as f64, r.stats.modeled_ms);
        bytes.push(i as f64, r.stats.shuffle_bytes as f64);
    }
    report.note(format!(
        "classic shuffles every partial product ({} B); eager combines to one value/cell; \
         delayed groups iterables — bytes between the two, semantics of classic",
        bytes.points[0].1
    ));
    report.add(time);
    report.add(bytes);
    Ok(report)
}

/// E9 — the pooled-executor ablation: the same iterative K-means, one
/// engine job per wave, run spawn-per-wave (fresh rank threads every
/// iteration, the pre-pool cost structure) vs on one warm `RankPool`.
/// The y-axis is HOST wall time — this figure measures our runtime's own
/// per-job overhead, not the modeled cluster — and the two executors are
/// checked to produce bit-identical centroids per sweep point.
fn pool_ablation(quick: bool) -> Result<Report> {
    use crate::mpi::RankPool;
    use crate::util::bench::time_once;

    let n = if quick { 2_000 } else { 20_000 };
    let reps = if quick { 3 } else { 5 };
    let points = kmeans::generate_points(n, 2, 8, 48);
    let cluster = vm_cluster(4, 48);
    let pool = RankPool::from_config(&cluster);

    let mut report = Report::new("E9 — pooled SPMD executor vs spawn-per-wave (host wall)");
    let mut spawned = Series::new("spawn-per-wave", "waves", "host_wall_ms");
    let mut pooled = Series::new("pooled (RankPool)", "waves", "host_wall_ms");
    for waves in [5usize, 10, 20, 40] {
        let mut spawn_ms = f64::INFINITY;
        let mut pool_ms = f64::INFINITY;
        for _ in 0..reps {
            let (a, da) = time_once(|| kmeans::run_wave_jobs(&cluster, &points, 8, waves, None));
            let (b, db) =
                time_once(|| kmeans::run_wave_jobs(&cluster, &points, 8, waves, Some(&pool)));
            anyhow::ensure!(
                a?.centroids == b?.centroids,
                "executors diverged at {waves} waves"
            );
            // Min-of-reps: the standard noise filter for wall clocks.
            spawn_ms = spawn_ms.min(da.as_secs_f64() * 1e3);
            pool_ms = pool_ms.min(db.as_secs_f64() * 1e3);
        }
        spawned.push(waves as f64, spawn_ms);
        pooled.push(waves as f64, pool_ms);
    }
    let last = spawned.points.len() - 1;
    report.note(format!(
        "40 waves: spawn-per-wave/pooled host-wall ratio = {:.2}x (ROADMAP thread-pool item)",
        spawned.points[last].1 / pooled.points[last].1.max(1e-9)
    ));
    report.add(spawned);
    report.add(pooled);
    Ok(report)
}

/// E10 — the `store` subsystem's money figure. Part 1 sweeps a delayed
/// wordcount's memory budget from unbounded down through the in-core ->
/// out-of-core crossover: spilled bytes turn on, peak tracked memory
/// collapses toward the budget, the result stays byte-identical. Part 2
/// is the three-way shuffle-bytes comparison the map-side combiner
/// enables: classic (every raw pair), eager (one value per key), and
/// classic+combiner (Hadoop's middle ground).
fn spill_crossover(quick: bool) -> Result<Report> {
    let lines = if quick { 3_000 } else { 30_000 };
    let corpus = wordcount::generate_corpus(lines, 8, 2_000, 49);
    let mut report =
        Report::new("E10 — spill crossover + combiner bytes (4 VM nodes, delayed wordcount)");

    // Part 1: budget sweep. x = log2(budget KiB); the unbounded point is
    // plotted at 2^20 KiB.
    let budgets: [(f64, u64); 6] = [
        (20.0, u64::MAX),
        (10.0, 1 << 20),
        (8.0, 256 << 10),
        (6.0, 64 << 10),
        (4.0, 16 << 10),
        (2.0, 4 << 10),
    ];
    let mut peak = Series::new("peak tracked KiB", "log2(budget_KiB)", "KiB");
    let mut spilled = Series::new("spilled KiB", "log2(budget_KiB)", "KiB");
    let mut time = Series::new("modeled_ms", "log2(budget_KiB)", "ms");
    let mut baseline: Option<std::collections::HashMap<String, u64>> = None;
    let mut crossover: Option<u64> = None;
    for (x, budget) in budgets {
        let cluster = ClusterConfig::builder()
            .deployment(DeploymentKind::Vm)
            .nodes(4)
            .slots_per_node(1)
            .seed(49)
            .shuffle_buffer_bytes(budget)
            .build();
        let r = wordcount::run(&cluster, &corpus, ReductionMode::Delayed)?;
        match &baseline {
            None => baseline = Some(r.result.clone()),
            Some(truth) => anyhow::ensure!(
                r.result == *truth,
                "out-of-core result diverged at budget {budget}"
            ),
        }
        if r.stats.spilled_bytes > 0 && crossover.is_none() {
            crossover = Some(budget);
        }
        peak.push(x, r.stats.peak_mem_bytes as f64 / 1024.0);
        spilled.push(x, r.stats.spilled_bytes as f64 / 1024.0);
        time.push(x, r.stats.modeled_ms);
    }
    match crossover {
        Some(b) => report.note(format!(
            "results byte-identical at every budget; first spill at {} KiB",
            b / 1024
        )),
        None => report.note("no budget spilled — corpus too small for the sweep".to_string()),
    }

    // Part 2: the three-way bytes comparison (ROADMAP combiner item).
    let cluster = vm_cluster(4, 49);
    let classic = wordcount::run(&cluster, &corpus, ReductionMode::Classic)?;
    let eager = wordcount::run(&cluster, &corpus, ReductionMode::Eager)?;
    let combined = wordcount::run_combined(&cluster, &corpus)?;
    anyhow::ensure!(classic.result == eager.result && eager.result == combined.result);
    let mut bytes =
        Series::new("shuffle_bytes", "shape(0=classic,1=eager,2=classic+combiner)", "bytes");
    bytes.push(0.0, classic.stats.shuffle_bytes as f64);
    bytes.push(1.0, eager.stats.shuffle_bytes as f64);
    bytes.push(2.0, combined.stats.shuffle_bytes as f64);
    report.note(format!(
        "combiner folded {} B away pre-wire; classic/combined wire ratio = {:.2}x",
        combined.stats.combined_bytes,
        classic.stats.shuffle_bytes as f64 / combined.stats.shuffle_bytes.max(1) as f64
    ));
    report.add(peak);
    report.add(spilled);
    report.add(time);
    report.add(bytes);
    Ok(report)
}

/// E11 — the collective-algorithm ablation (ISSUE 4 tentpole). Part 1
/// is a pure-collective microbench on the VM network model — rounds of
/// a 64 KiB broadcast + an allreduce, swept over rank count x algorithm.
/// The y-axis is purely the charged network clock (no `timed` compute),
/// so the curves are deterministic: the star root pays `O(P)` serial
/// injections per broadcast while the tree pays `O(log P)` levels, and
/// the gap widens with rank count — the "what if the runtime were
/// smarter" axis over Fig 10's anti-scaling. Part 2 re-runs Fig 10's
/// small-key-range wordcount (2 slots/node so coalescing has same-node
/// company) under each algorithm, showing the curve bending end to end.
fn tree_ablation(quick: bool) -> Result<Report> {
    use crate::cluster::NetworkModel;
    use crate::mpi::{CollectiveAlgo, Communicator, Rank, RankPool, Topology, Universe};

    let rounds = if quick { 3 } else { 10 };
    let mut report =
        Report::new("E11 — tree ablation: rank count x collective algorithm (VM network)");

    // Part 1: collective microbench. 2 slots per node; virtual clock only.
    let rank_sweep: &[usize] = if quick { &[4, 8, 16, 32] } else { &[4, 8, 16, 32, 64] };
    let net = NetworkModel::from_profile(&DeploymentKind::Vm.profile());
    let mut clock_series: Vec<Series> = CollectiveAlgo::ALL
        .iter()
        .map(|a| Series::new(format!("collectives {a}"), "ranks", "modeled_ms"))
        .collect();
    let mut root_msgs_note: Vec<String> = Vec::new();
    for &ranks in rank_sweep {
        for (ai, algo) in CollectiveAlgo::ALL.iter().enumerate() {
            let pool = RankPool::new(
                Universe::new(Topology::block(ranks / 2, 2), net.clone())
                    .with_collective_algo(*algo),
            );
            let out = pool.run_job(ranks, |c: &Communicator| {
                let payload = vec![0xABu8; 64 << 10];
                let mut acc = 0u64;
                for _ in 0..rounds {
                    let v = if c.is_root() { payload.clone() } else { Vec::new() };
                    acc = acc.wrapping_add(c.bcast(Rank::ROOT, v).unwrap().len() as u64);
                    acc = acc.wrapping_add(c.allreduce_sum_u64(c.rank().0 as u64).unwrap());
                }
                (acc, c.sent_messages() + c.received_messages())
            });
            let slowest = out.clocks.iter().map(|(clk, _, _)| *clk).max().unwrap_or(0);
            clock_series[ai].push(ranks as f64, slowest as f64 / 1e6);
            if ranks == *rank_sweep.last().unwrap() {
                root_msgs_note.push(format!("{algo}: root touched {} msgs", out.results[0].1));
            }
        }
    }
    let gap = |i: usize| clock_series[0].points[i].1 - clock_series[1].points[i].1;
    let last = rank_sweep.len() - 1;
    report.note(format!(
        "star-minus-tree clock gap: {:.2} ms at {} ranks -> {:.2} ms at {} ranks (widening = \
         the Fig 10 'smarter runtime' axis)",
        gap(0),
        rank_sweep[0],
        gap(last),
        rank_sweep[last],
    ));
    report.note(format!(
        "root message counts at {} ranks — {}",
        rank_sweep[last],
        root_msgs_note.join("; ")
    ));

    // Part 2: Fig 10's wordcount, per algorithm, 2 slots per node.
    let corpus = wordcount::generate_corpus(2_000, 8, 50, 42);
    let mut wc_series: Vec<Series> = CollectiveAlgo::ALL
        .iter()
        .map(|a| Series::new(format!("wordcount {a}"), "nodes", "modeled_ms"))
        .collect();
    let mut remote_msgs = [0u64; 3];
    for nodes in NODE_SWEEP {
        for (ai, algo) in CollectiveAlgo::ALL.iter().enumerate() {
            let cluster = ClusterConfig::builder()
                .deployment(DeploymentKind::Vm)
                .nodes(nodes)
                .slots_per_node(2)
                .seed(42)
                .collective_algo(*algo)
                .build();
            let r = wordcount::run(&cluster, &corpus, ReductionMode::Eager)?;
            wc_series[ai].push(nodes as f64, r.stats.modeled_ms);
            if nodes == NODE_SWEEP[NODE_SWEEP.len() - 1] {
                remote_msgs[ai] = r.stats.remote_messages;
            }
        }
    }
    report.note(format!(
        "wordcount remote messages at {} nodes: star {}, tree {}, hierarchical {} (node \
         coalescing)",
        NODE_SWEEP[NODE_SWEEP.len() - 1],
        remote_msgs[0],
        remote_msgs[1],
        remote_msgs[2],
    ));
    for s in clock_series {
        report.add(s);
    }
    for s in wc_series {
        report.add(s);
    }
    Ok(report)
}

/// E12 — the iterative-engine ablation (ISSUE 5 tentpole). The same
/// PageRank run two ways on the same graph: the engine path (one
/// delayed-reduction job per iteration — scores and keep-alive pairs
/// re-shuffle every wave) vs the in-memory DistHashMap path
/// (`IterativeJob`: adjacency + score pinned rank-local, only pre-folded
/// contribution deltas on the wire). Per-iteration wire bytes and
/// modeled clock are plotted for both; halfway through, the dist run's
/// `ElasticCluster` grows by two nodes, so the figure also shows the
/// one-off migration bytes and that the iteration resumes (cheaper per
/// wave, wider) instead of restarting. Both paths are checked against
/// the serial reference before anything is plotted.
fn iterative_ablation(quick: bool) -> Result<Report> {
    use crate::apps::pagerank;
    use crate::cluster::ElasticCluster;

    let vertices = if quick { 400 } else { 4_000 };
    let iters = if quick { 8 } else { 20 };
    let damping = 0.85;
    let g = pagerank::Graph::random(vertices, 4, 3);
    let cluster = vm_cluster(4, 50);

    let engine = pagerank::run(&cluster, &g, iters, damping, ReductionMode::Delayed)?;
    let resize_at = iters / 2;
    let mut elastic = ElasticCluster::new(cluster);
    let dist = pagerank::run_dist(&mut elastic, &g, iters, damping, &[(resize_at, 2)])?;
    let want = pagerank::reference(&g, iters, damping);
    for (path, ranks) in [("engine", &engine.ranks), ("dist", &dist.ranks)] {
        for (a, b) in ranks.iter().zip(&want) {
            anyhow::ensure!((a - b).abs() < 1e-9, "{path} path diverged from reference");
        }
    }

    let mut report = Report::new(
        "E12 — iterative ablation: engine path vs DistHashMap path (mid-run grow at half-time)",
    );
    let mut eng_bytes = Series::new("engine bytes/iter", "iteration", "bytes");
    let mut eng_ms = Series::new("engine modeled_ms/iter", "iteration", "ms");
    for (it, (&b, &ms)) in engine
        .per_iteration_shuffle_bytes
        .iter()
        .zip(&engine.per_iteration_modeled_ms)
        .enumerate()
    {
        eng_bytes.push(it as f64, b as f64);
        eng_ms.push(it as f64, ms);
    }
    let mut dist_bytes = Series::new("dist bytes/iter", "iteration", "bytes");
    let mut dist_ms = Series::new("dist modeled_ms/iter", "iteration", "ms");
    for it in &dist.per_iteration {
        dist_bytes.push(it.iteration as f64, it.shuffled_bytes as f64);
        dist_ms.push(it.iteration as f64, it.modeled_ms);
    }
    let mut migrated = Series::new("migration bytes (one-off)", "iteration", "bytes");
    for m in &dist.migrations {
        migrated.push(m.before_iteration as f64, m.moved_bytes as f64);
    }

    let min_engine =
        engine.per_iteration_shuffle_bytes.iter().min().copied().unwrap_or(0) as f64;
    let max_dist = dist_bytes.points.iter().map(|p| p.1).fold(0.0f64, f64::max);
    report.note(format!(
        "per-iteration wire bytes: dist max {max_dist:.0} B vs engine min {min_engine:.0} B \
         (engine/dist ratio {:.2}x) — the delta-shuffle win, held across the resize",
        min_engine / max_dist.max(1.0)
    ));
    let m = &dist.migrations[0];
    report.note(format!(
        "mid-run grow {} -> {} ranks at iteration {}: {} keys / {} B migrated (epoch {}), \
         {} of {} buckets reassigned — min-mass, not a re-shard",
        m.from_ranks,
        m.to_ranks,
        m.before_iteration,
        m.moved_keys,
        m.moved_bytes,
        m.epoch,
        m.buckets_moved,
        crate::dist::DEFAULT_BUCKETS,
    ));
    report.add(eng_bytes);
    report.add(dist_bytes);
    report.add(migrated);
    report.add(eng_ms);
    report.add(dist_ms);
    Ok(report)
}

/// E13 — the fault ablation (ISSUE 6 tentpole). Part 1: what
/// checkpointing *costs* — the same connected-components session run at
/// cadence k ∈ {1, 2, 4, 8}, plotting total snapshot bytes and modeled
/// checkpoint-write time per cadence (both shrink as k grows). Part 2:
/// what checkpointing *buys* — a kill swept across the run, comparing
/// the checkpointed session's total modeled time (prefix + snapshot
/// writes + recovery read + suffix) against the rerun-from-scratch
/// strategy (the wasted prefix plus a full uninterrupted run). Early
/// kills favour rerun (little work lost, and the checkpointed session
/// still pays its snapshot overhead); kills past the midpoint must
/// favour recovery — that crossover is the figure's pinned claim.
fn fault_ablation(quick: bool) -> Result<Report> {
    use crate::apps::components;
    use crate::cluster::{ElasticCluster, FaultPlan, WavePhase};

    let (chains, len) = if quick { (4, 12) } else { (8, 40) };
    let g = components::chain_graph(chains, len);
    let cap = len + 4; // flood needs ~len waves to settle
    let cluster = |seed| {
        ClusterConfig::builder()
            .deployment(DeploymentKind::Vm)
            .nodes(4)
            .slots_per_node(1)
            .seed(seed)
            .build()
    };

    let mut report = Report::new(
        "E13 — fault ablation: checkpoint overhead per cadence; recovery vs rerun-from-scratch",
    );

    // Part 1: overhead vs cadence (no kill — the plan stays empty).
    let mut ck_bytes = Series::new("checkpoint KiB", "cadence k", "KiB");
    let mut ck_ms = Series::new("checkpoint write ms (modeled)", "cadence k", "ms");
    for k in [1usize, 2, 4, 8] {
        let mut elastic = ElasticCluster::new(cluster(51));
        let r = components::run_dist_faulty(&mut elastic, &g, cap, k, 0)?;
        anyhow::ensure!(r.converged && r.recoveries.is_empty());
        let bytes: u64 = r.checkpoints.iter().map(|c| c.bytes).sum();
        let ms: f64 = r.checkpoints.iter().map(|c| c.modeled_ms).sum();
        ck_bytes.push(k as f64, bytes as f64 / 1024.0);
        ck_ms.push(k as f64, ms);
        if k == 1 {
            report.note(format!(
                "cadence 1: {} snapshots, {:.1} KiB, {:.3} ms modeled write time",
                r.checkpoints.len(),
                bytes as f64 / 1024.0,
                ms
            ));
        }
    }

    // Part 2: recovery vs rerun across kill points. The baseline run is
    // checkpoint-free — rerun-from-scratch pays no snapshot overhead.
    let baseline = components::run_dist(&mut ElasticCluster::new(cluster(51)), &g, cap, &[])?;
    anyhow::ensure!(baseline.converged);
    let total = baseline.iterations;
    let full_ms = baseline.stats.modeled_ms;
    let mut recover = Series::new("recover from checkpoint", "kill iteration", "modeled_ms");
    let mut rerun = Series::new("rerun from scratch", "kill iteration", "modeled_ms");
    for frac in [1, 2, 4, 6, 7] {
        let kill_at = (total * frac / 8).min(total - 1);
        let mut elastic = ElasticCluster::new(cluster(51));
        elastic.set_fault_plan(FaultPlan::new().with_kill(kill_at, WavePhase::Flush, 1));
        let r = components::run_dist_faulty(&mut elastic, &g, cap, 1, 0)?;
        anyhow::ensure!(r.converged && r.labels == baseline.labels);
        anyhow::ensure!(!r.recoveries.is_empty(), "kill at {kill_at} must fire");
        let wasted_prefix: f64 =
            baseline.per_iteration[..kill_at].iter().map(|it| it.modeled_ms).sum();
        recover.push(kill_at as f64, r.stats.modeled_ms);
        rerun.push(kill_at as f64, wasted_prefix + full_ms);
    }
    let last = recover.points.len() - 1;
    report.note(format!(
        "kill at iteration {} of {}: recover {:.2} ms vs rerun {:.2} ms — checkpointing pays \
         for itself once the wasted prefix outweighs snapshot + restore overhead",
        recover.points[last].0, total, recover.points[last].1, rerun.points[last].1
    ));
    report.add(ck_bytes);
    report.add(ck_ms);
    report.add(recover);
    report.add(rerun);
    Ok(report)
}

/// E8 — §III deployment comparison: the same WordCount under the three
/// proposed architectures (Figs 3-5) + Local reference.
fn deployment(quick: bool) -> Result<Report> {
    let corpus = wordcount::generate_corpus(if quick { 5_000 } else { 50_000 }, 8, 500, 47);
    let mut report = Report::new("E8 — deployment profiles (paper §III, Figs 3-5)");
    let mut run_ms = Series::new("job (excl. startup)", "kind(0=bm,1=vm,2=ct,3=local)", "modeled_ms");
    let mut startup = Series::new("cluster startup", "kind(0=bm,1=vm,2=ct,3=local)", "ms");
    for (i, kind) in DeploymentKind::ALL.iter().enumerate() {
        let cluster = ClusterConfig::builder().deployment(*kind).nodes(4).slots_per_node(1).seed(47).build();
        let r = wordcount::run(&cluster, &corpus, ReductionMode::Eager)?;
        run_ms.push(i as f64, r.stats.modeled_ms);
        startup.push(i as f64, r.stats.startup_ms);
    }
    report.note("expected ordering: VM startup >> container > bare-metal; RPi compute slowest");
    report.add(run_ms);
    report.add(startup);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_ids_parse() {
        for id in FigureId::ALL {
            assert_eq!(FigureId::parse(id.name()), Some(id));
        }
        assert_eq!(FigureId::parse("fig99"), None);
    }

    #[test]
    fn pool_ablation_quick_runs_both_executors() {
        let r = run_figure(FigureId::PoolAblation, true).unwrap();
        assert_eq!(r.series.len(), 2);
        assert_eq!(r.series[0].points.len(), r.series[1].points.len());
        assert!(!r.notes.is_empty());
    }

    #[test]
    fn spill_crossover_quick_crosses_over_and_ranks_shapes() {
        let r = run_figure(FigureId::SpillCrossover, true).unwrap();
        assert_eq!(r.series.len(), 4);
        let spilled = &r.series[1];
        assert!(
            spilled.points.iter().any(|(_, kib)| *kib > 0.0),
            "sweep must reach the out-of-core regime"
        );
        assert!(
            spilled.points.first().map(|(_, kib)| *kib) == Some(0.0),
            "unbounded budget must stay in core"
        );
        // Three-way ordering: combined folds to one pair per key per
        // rank like eager, but pays the round-based shuffle's framing
        // and agreement traffic on top — so eager stays the leanest.
        let bytes = &r.series[3];
        let (classic, eager, combined) =
            (bytes.points[0].1, bytes.points[1].1, bytes.points[2].1);
        assert!(combined < classic, "combiner must cut classic volume");
        assert!(eager <= combined, "eager stays the leanest");
    }

    #[test]
    fn tree_ablation_quick_gap_widens_with_rank_count() {
        let r = run_figure(FigureId::TreeAblation, true).unwrap();
        assert_eq!(r.series.len(), 6, "3 collective series + 3 wordcount series");
        let star = &r.series[0];
        let tree = &r.series[1];
        assert_eq!(star.points.len(), tree.points.len());
        // Deterministic part (pure network clock): the star-vs-tree gap
        // must widen with rank count and tree must win at the top end.
        let last = star.points.len() - 1;
        let gap_first = star.points[0].1 - tree.points[0].1;
        let gap_last = star.points[last].1 - tree.points[last].1;
        assert!(
            gap_last > gap_first,
            "gap must widen: {gap_first:.3} ms -> {gap_last:.3} ms"
        );
        assert!(
            tree.points[last].1 < star.points[last].1,
            "tree {} ms must beat star {} ms at {} ranks",
            tree.points[last].1,
            star.points[last].1,
            star.points[last].0
        );
        assert_eq!(r.notes.len(), 3);
    }

    #[test]
    fn iterative_ablation_quick_dist_bytes_strictly_below_engine() {
        let r = run_figure(FigureId::IterativeAblation, true).unwrap();
        assert_eq!(r.series.len(), 5, "2 bytes + 1 migration + 2 clock series");
        let eng = &r.series[0];
        let dist = &r.series[1];
        assert_eq!(eng.points.len(), dist.points.len(), "one point per iteration each");
        // The acceptance bar: every dist iteration moves strictly fewer
        // bytes than the cheapest engine iteration — before AND after the
        // mid-run grow.
        let min_engine = eng.points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
        for (x, y) in &dist.points {
            assert!(*y < min_engine, "iteration {x}: dist {y} >= engine min {min_engine}");
        }
        // The resize really happened and its cost is plotted separately.
        let migrated = &r.series[2];
        assert_eq!(migrated.points.len(), 1);
        assert!(migrated.points[0].1 > 0.0, "migration must move bytes");
        assert_eq!(r.notes.len(), 2);
    }

    #[test]
    fn fault_ablation_quick_recovery_beats_rerun_past_midpoint() {
        let r = run_figure(FigureId::FaultAblation, true).unwrap();
        assert_eq!(r.series.len(), 4, "2 overhead + 2 strategy series");
        // Part 1: snapshot volume shrinks (weakly) as the cadence widens.
        let bytes = &r.series[0];
        for w in bytes.points.windows(2) {
            assert!(
                w[1].1 <= w[0].1,
                "checkpoint KiB must not grow with k: {} -> {}",
                w[0].1,
                w[1].1
            );
        }
        assert!(bytes.points[0].1 > 0.0, "cadence 1 must write snapshots");
        // Part 2 — the pinned claim: for every kill past the midpoint,
        // recovering from the checkpoint beats re-running from scratch.
        let recover = &r.series[2];
        let rerun = &r.series[3];
        assert_eq!(recover.points.len(), rerun.points.len());
        let total = recover.points.last().unwrap().0;
        let mut past_midpoint = 0;
        for ((kill, rec), (_, rr)) in recover.points.iter().zip(&rerun.points) {
            if *kill * 2.0 > total {
                past_midpoint += 1;
                assert!(
                    rec < rr,
                    "kill at {kill}: recover {rec:.3} ms must beat rerun {rr:.3} ms"
                );
            }
        }
        assert!(past_midpoint >= 2, "sweep must sample past the midpoint");
    }

    #[test]
    fn fig10_quick_produces_full_sweep() {
        let r = run_figure(FigureId::Fig10, true).unwrap();
        assert_eq!(r.series.len(), 1);
        assert_eq!(r.series[0].points.len(), NODE_SWEEP.len());
    }

    #[test]
    fn fig13_quick_spark_exceeds_blaze() {
        let r = run_figure(FigureId::Fig13, true).unwrap();
        let blaze = &r.series[0];
        let spark = &r.series[1];
        for i in 0..3 {
            assert!(
                spark.points[i].1 > blaze.points[i].1,
                "workload {i}: spark {} <= blaze {}",
                spark.points[i].1,
                blaze.points[i].1
            );
        }
    }

    #[test]
    fn deployment_quick_ordering() {
        let r = run_figure(FigureId::Deployment, true).unwrap();
        let startup = &r.series[1];
        // VM (idx 1) startup >> container (idx 2) >> bare-metal (idx 0).
        assert!(startup.points[1].1 > startup.points[2].1);
        assert!(startup.points[2].1 > startup.points[0].1);
    }
}
