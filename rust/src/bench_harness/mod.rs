//! Benchmark harnesses.
//!
//! * [`figures`] — figure regeneration, one generator per paper
//!   table/figure. `blaze bench-figure <id>` and `cargo bench` both
//!   route through here so the printed series match EXPERIMENTS.md.
//! * [`serve`] — the sustained-load serving harness over the concurrent
//!   scheduler (`blaze serve-bench`, writes `BENCH_9.json`).

pub mod figures;
pub mod serve;

pub use figures::{run_figure, FigureId};
pub use serve::{run_serve_bench, validate_report, DriveMode, ServeBenchConfig};
