//! Figure regeneration harness: one generator per paper table/figure.
//! `blaze bench-figure <id>` and `cargo bench` both route through here so
//! the printed series match EXPERIMENTS.md.

pub mod figures;

pub use figures::{run_figure, FigureId};
